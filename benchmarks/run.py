# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV lines (plus human-readable tables).  QUICK=0 for the paper-sized runs.
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (fig3_4_aggregator, fig3_4_dynamics,
                            fig5_6_tradeoffs, fig7_solver, microbench,
                            sweep_bench, table1_2_energy_delay)
    print("name,us_per_call,derived")
    suites = [
        ("microbench", microbench.main),
        ("table1_2", table1_2_energy_delay.main),
        ("fig3_4", fig3_4_aggregator.main),
        ("fig3_4_dynamics", fig3_4_dynamics.main),
        ("fig5_6", fig5_6_tradeoffs.main),
        ("fig7", fig7_solver.main),
        ("sweep", sweep_bench.main),
    ]
    failures = []
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
        except Exception:                      # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
        print(f"# suite {name} took {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == '__main__':
    main()
