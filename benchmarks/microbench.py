"""Microbenchmarks (wall-clock on the local device): CE-FL round step on a
small LM, FedProx kernel vs unfused XLA, decode step latency, and the
tree-path vs flat-plane-path comparison for a FULL simulated CE-FL round
(local FedProx training + eq.-11 aggregation through the executors).

``main`` writes ``BENCH_kernels.json`` at the repo root — the repo's
recorded perf trajectory, keyed per kernel backend
(``results.<backend>.*``; the file is committed deliberately, see
docs/kernels.md).  ``--backend`` forces the kernel dispatch backend for
the whole run (default: auto-detected); a full run merges its backend
section into the committed file without clobbering the others.

    PYTHONPATH=src python -m benchmarks.microbench                 # full
    PYTHONPATH=src python -m benchmarks.microbench --smoke         # CI
    PYTHONPATH=src python -m benchmarks.microbench --backend interpret
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line
from repro.configs import get_config, reduced
from repro.configs.cefl_paper import ClassifierConfig
from repro.core.api import RoundPlan
from repro.core.engine import MeshExecutor, SimExecutor
from repro.core.round_step import CEFLHyper, build_cefl_round_step, \
    make_dpu_meta
from repro.data import make_token_batches
from repro.kernels import ops, ref
from repro.models import lm as L
from repro.models.classifier import classifier_loss, init_classifier_params
from repro.network import NetworkConfig, make_network
from repro.solver.greedy import fixed_aggregator
from repro.solver.variables import round_indicators

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timeit(fn, n=10):
    fn()  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6     # us


def bench_round_step():
    cfg = reduced(get_config("mamba2-130m"))
    params0 = L.init_lm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    params = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (2,) + x.shape), params0)

    def loss_fn(p, micro, mask):
        return L.lm_loss(p, cfg, micro, example_mask=mask, remat=True,
                         q_block=64, kv_block=64)

    step = jax.jit(build_cefl_round_step(
        loss_fn, CEFLHyper(gamma_max=2, n_micro=1)))
    meta = make_dpu_meta(2, gammas=[2, 2])
    b = make_token_batches(cfg.vocab_size, 2, 1, 2, 128)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    us = _timeit(lambda: step(params, b, meta)[1]["loss"], n=5)
    csv_line("cefl_round_step_smoke_lm", us, "gamma=2,n_dpu=2,seq=128")
    return us


# kernel-bench plane shapes — also recorded in the BENCH config section so
# benchmarks/roofline.py can turn the measured times into achieved bytes/s
FEDPROX_SHAPE = (2048, 1024)
NOVA_STACK = (8, 2048, 1024)


def bench_fedprox_kernel():
    """The flagship kernel through the dispatch layer (the hot-path plane
    op the executors call) vs the unfused XLA expression.  The two sides
    are interleaved and the per-side minimum taken: at ~1.5 ms/launch a
    single back-to-back pair is dominated by CPU frequency/cache drift,
    which systematically penalizes whichever side runs first."""
    x = jax.random.normal(jax.random.PRNGKey(0), FEDPROX_SHAPE)
    g = x * 0.1
    a = x * 0.9

    kern = jax.jit(lambda x, g, a: ops.fedprox_plane(x, g, a, 0.1, 0.01))
    unfused = jax.jit(lambda x, g, a: ref.fedprox_update_ref(
        x, g, a, 0.1, 0.01))
    us_k = us_u = float("inf")
    for _ in range(5):
        us_k = min(us_k, _timeit(lambda: kern(x, g, a)))
        us_u = min(us_u, _timeit(lambda: unfused(x, g, a)))
    csv_line("fedprox_kernel", us_k,
             f"backend={ops.current_backend()} unfused_xla={us_u:.1f}us")
    return us_k, us_u


def bench_nova_kernel():
    """eq.-11 stacked aggregation through the dispatch layer (second
    roofline row: reduction over the DPU axis, not just elementwise)."""
    n, r, lane = NOVA_STACK
    x = jax.random.normal(jax.random.PRNGKey(1), (n, r, lane))
    d = x * 0.01
    w = jnp.full((n,), 1.0 / n)
    kern = jax.jit(lambda x, d, w: ops.nova_aggregate_plane(x, d, w, 0.05))
    us = _timeit(lambda: kern(x, d, w))
    csv_line("nova_stacked_kernel", us,
             f"backend={ops.current_backend()} stack={NOVA_STACK}")
    return us


def bench_solver_backends(*, smoke=False):
    """Per-plan latency of the network-aware solver: jitted batched backend
    (warm, compile-cache hit) vs the numpy oracle (``solver/ref.py``).  The
    full scaling trajectory lives in benchmarks/fig7_solver.py ->
    BENCH_solver.json; this is the one-line smoke variant."""
    from repro.core import MLConstants
    from repro.solver import ObjectiveWeights, sca
    n_ue, n_bs, n_dc = (6, 3, 2) if smoke else (12, 4, 3)
    net = make_network(NetworkConfig(num_ue=n_ue, num_bs=n_bs,
                                     num_dc=n_dc, seed=0))
    nd = n_ue + n_dc
    consts = MLConstants(L=4.0, theta_i=np.full(nd, 2.0),
                         sigma_i=np.ones(nd), zeta1=2.0, zeta2=1.0)
    ow = ObjectiveWeights()
    D_bar = np.full(n_ue, 1500.0)
    kw = dict(distributed=False, max_outer=2)

    def run(backend):
        return sca.solve(net, D_bar, consts, ow, backend=backend,
                         **kw).objective_history[-1]

    run("jit")   # compile once; the engine path always re-solves warm
    t0 = time.time()
    run("jit")
    us_jit = (time.time() - t0) * 1e6
    t0 = time.time()
    run("ref")
    us_ref = (time.time() - t0) * 1e6
    csv_line("solver_plan_jit", us_jit, f"ref={us_ref:.0f}us "
             f"speedup={us_ref / us_jit:.1f}x n_ue={n_ue}")
    return us_jit, us_ref


def bench_decode_step():
    cfg = reduced(get_config("qwen3-32b"))
    p = L.init_lm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    cache = L.init_cache(cfg, 4, 512, jnp.float32)
    tok = jnp.zeros((4,), jnp.int32)
    step = jax.jit(lambda t, c: L.lm_decode_step(p, cfg, t, c))
    us = _timeit(lambda: step(tok, cache)[0], n=10)
    csv_line("decode_step_smoke_qwen3", us, "B=4,cache=512")
    return us


# ----------------------------------------------- tree vs plane round -----

def _sim_round_setup(*, smoke=False):
    """A full simulated CE-FL round on the benchmark config: 12 DPUs (8
    UEs + 4 DCs live), classifier model, gamma=4, m=0.5."""
    n_ue, n_bs, n_dc = (4, 2, 2) if smoke else (8, 4, 4)
    D = 64 if smoke else 512
    gamma = 2 if smoke else 4
    img = (14, 14, 1)
    net = make_network(NetworkConfig(num_ue=n_ue, num_bs=n_bs,
                                     num_dc=n_dc, seed=0))
    ccfg = ClassifierConfig(input_shape=img, hidden=(64,))
    p0 = init_classifier_params(jax.random.PRNGKey(0), ccfg)
    n_dpu = n_ue + n_dc
    plan = RoundPlan.from_w(round_indicators(
        fixed_aggregator(net, np.full(n_ue, float(D)), 0)))
    plan = plan.replace(gamma=np.full(n_dpu, gamma, float),
                        m=np.full(n_dpu, 0.5))
    rng = np.random.RandomState(0)
    datasets = [{"x": jnp.asarray(rng.randn(D, *img).astype(np.float32)),
                 "y": jnp.asarray(rng.randint(0, 10, D))}
                for _ in range(n_dpu)]
    key = jax.random.PRNGKey(0)
    meta = dict(n_dpu=n_dpu, D=D, gamma=gamma, m=0.5, model="mlp-14x14-64")

    def run(executor):
        p, loss = executor.run_round(
            p0, plan, datasets, loss_fn=classifier_loss, eta=0.05,
            mu=0.01, theta=None, agg="cefl", key=key)
        jax.block_until_ready(getattr(p, "data", p))
        return loss
    return run, meta


def bench_sim_round_tree_vs_plane(*, smoke=False):
    """Time the SAME full simulated round through SimExecutor on the
    per-leaf tree path vs the flat-plane Pallas path."""
    run, meta = _sim_round_setup(smoke=smoke)
    n = 2 if smoke else 5
    tree_exec = SimExecutor(use_plane=False)
    plane_exec = SimExecutor(use_plane=True)
    us_tree = _timeit(lambda: run(tree_exec), n=n)
    us_plane = _timeit(lambda: run(plane_exec), n=n)
    speedup = us_tree / us_plane
    csv_line("sim_round_tree", us_tree, f"{meta}")
    csv_line("sim_round_plane", us_plane, f"speedup={speedup:.2f}x")
    return us_tree, us_plane, meta


def bench_mesh_round_tree_vs_plane(*, smoke=False):
    """Same comparison through MeshExecutor (the jitted SPMD round)."""
    run, meta = _sim_round_setup(smoke=smoke)
    n = 2 if smoke else 5
    tree_exec = MeshExecutor(use_plane=False)
    plane_exec = MeshExecutor(use_plane=True)
    us_tree = _timeit(lambda: run(tree_exec), n=n)
    us_plane = _timeit(lambda: run(plane_exec), n=n)
    csv_line("mesh_round_tree", us_tree, f"{meta}")
    csv_line("mesh_round_plane", us_plane,
             f"speedup={us_tree / us_plane:.2f}x")
    return us_tree, us_plane


def _flag_value(argv, flag):
    """Value of ``--flag PATH``, or None; exits with a usage error when
    the flag is present but the value is missing."""
    if flag not in argv:
        return None
    i = argv.index(flag)
    if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
        raise SystemExit(f"{flag} requires an argument")
    return argv[i + 1]


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    out_path = _flag_value(argv, "--out")
    backend = _flag_value(argv, "--backend")
    if backend and backend != "auto":
        ops.set_backend(backend)
    bk = ops.current_backend()
    results = {}
    s_tree, s_plane, meta = bench_sim_round_tree_vs_plane(smoke=smoke)
    results["sim_round_tree_us"] = round(s_tree, 1)
    results["sim_round_plane_us"] = round(s_plane, 1)
    results["sim_round_speedup"] = round(s_tree / s_plane, 3)
    m_tree, m_plane = bench_mesh_round_tree_vs_plane(smoke=smoke)
    results["mesh_round_tree_us"] = round(m_tree, 1)
    results["mesh_round_plane_us"] = round(m_plane, 1)
    results["mesh_round_speedup"] = round(m_tree / m_plane, 3)
    us_k, us_u = bench_fedprox_kernel()
    results["fedprox_kernel_us"] = round(us_k, 1)
    results["fedprox_unfused_xla_us"] = round(us_u, 1)
    results["fedprox_vs_xla_ratio"] = round(us_k / us_u, 3)
    results["nova_stacked_us"] = round(bench_nova_kernel(), 1)
    us_sj, us_sr = bench_solver_backends(smoke=smoke)
    results["solver_plan_jit_us"] = round(us_sj, 1)
    results["solver_plan_ref_us"] = round(us_sr, 1)
    results["solver_plan_speedup"] = round(us_sr / us_sj, 2)
    if not smoke:
        results["cefl_round_step_lm_us"] = round(bench_round_step(), 1)
        results["decode_step_qwen3_us"] = round(bench_decode_step(), 1)
    meta["fedprox_shape"] = list(FEDPROX_SHAPE)
    meta["nova_stack"] = list(NOVA_STACK)
    # per-backend trajectory: results are keyed by the kernel backend this
    # run dispatched to (results.<backend>.*, see docs/kernels.md); a full
    # run merges into the committed file, preserving the other backends'
    # sections and the smoke baseline the CI gate compares against
    out = {"bench": "kernels+round", "smoke": smoke, "config": meta,
           "backend": bk, "jax_backend": jax.default_backend(),
           "results": {bk: results}}
    path = os.path.join(_ROOT, "BENCH_kernels.json")
    if not smoke:
        try:
            with open(path) as f:
                prev = json.load(f)
        except (OSError, ValueError):
            prev = {}
        prev_res = prev.get("results", {})
        if isinstance(prev_res, dict) and any(
                isinstance(v, dict) for v in prev_res.values()):
            merged = dict(prev_res)
            merged[bk] = results
            out["results"] = merged
        for key in ("smoke_baseline", "smoke_baseline_note"):
            if key in prev:
                out[key] = prev[key]
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"[microbench] wrote {path}")
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"[microbench] wrote {out_path}")
    print(json.dumps({bk: results}, indent=2))
    return out


if __name__ == "__main__":
    main()
