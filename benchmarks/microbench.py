"""Microbenchmarks (wall-clock on the local device): CE-FL round step on a
small LM, FedProx kernel vs unfused XLA, decode step latency."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.configs import get_config, reduced
from repro.core.round_step import CEFLHyper, build_cefl_round_step, \
    make_dpu_meta
from repro.data import make_token_batches
from repro.kernels import ops, ref
from repro.models import lm as L


def _timeit(fn, n=10):
    fn()  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6     # us


def bench_round_step():
    cfg = reduced(get_config("mamba2-130m"))
    params0 = L.init_lm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    params = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (2,) + x.shape), params0)

    def loss_fn(p, micro, mask):
        return L.lm_loss(p, cfg, micro, example_mask=mask, remat=True,
                         q_block=64, kv_block=64)

    step = jax.jit(build_cefl_round_step(
        loss_fn, CEFLHyper(gamma_max=2, n_micro=1)))
    meta = make_dpu_meta(2, gammas=[2, 2])
    b = make_token_batches(cfg.vocab_size, 2, 1, 2, 128)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    us = _timeit(lambda: step(params, b, meta)[1]["loss"], n=5)
    csv_line("cefl_round_step_smoke_lm", us, "gamma=2,n_dpu=2,seq=128")


def bench_fedprox_kernel():
    x = jax.random.normal(jax.random.PRNGKey(0), (2048, 1024))
    g = x * 0.1
    a = x * 0.9

    kern = jax.jit(lambda x, g, a: ops.fedprox_update(
        {"p": x}, {"p": g}, {"p": a}, 0.1, 0.01)["p"])
    unfused = jax.jit(lambda x, g, a: ref.fedprox_update_ref(
        x, g, a, 0.1, 0.01))
    us_k = _timeit(lambda: kern(x, g, a))
    us_u = _timeit(lambda: unfused(x, g, a))
    csv_line("fedprox_kernel_interpret", us_k, f"unfused_xla={us_u:.1f}us")


def bench_decode_step():
    cfg = reduced(get_config("qwen3-32b"))
    p = L.init_lm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    cache = L.init_cache(cfg, 4, 512, jnp.float32)
    tok = jnp.zeros((4,), jnp.int32)
    step = jax.jit(lambda t, c: L.lm_decode_step(p, cfg, t, c))
    us = _timeit(lambda: step(tok, cache)[0], n=10)
    csv_line("decode_step_smoke_qwen3", us, "B=4,cache=512")


def main():
    bench_round_step()
    bench_fedprox_kernel()
    bench_decode_step()


if __name__ == "__main__":
    main()
