"""Paper Figs. 3-6, dynamic regime: strategy comparison under the scenario
subsystem (mobility + handover + mesh churn + drift), the environments the
static ``fig3_4_aggregator`` path cannot exercise.

For each (scenario, strategy) cell: aggregation-point migrations, UE
handovers, accuracy, and per-round energy/delay — the mobility/evolution
story of the paper (CE-FL's floating point tracks the moving data/rate
concentration; fixed baselines cannot).

    PYTHONPATH=src python -m benchmarks.run fig3_4_dynamics
    QUICK=0 ... for the paper-size network
"""
from __future__ import annotations

import time

from benchmarks.common import QUICK, csv_line, setup
from repro.core import Engine, EngineOptions

SCENARIOS = ("campus_walk", "vehicular", "flash_crowd") if not QUICK \
    else ("campus_walk", "vehicular")
STRATEGIES = ("cefl", "greedy_data", "fixed:0")


def run_cell(s, scenario, strategy, rounds):
    opts = EngineOptions(rounds=rounds, eta=0.1, solver_outer=2,
                         reoptimize_every=1, seed=0)
    engine = Engine(s["net"], strategy, consts=s["consts"], ow=s["ow"],
                    opts=opts, scenario=scenario)
    res = engine.run(s["make_ues"](), init_params=s["p0"],
                     loss_fn=s["loss_fn"], eval_fn=s["eval_fn"])
    migrations = sum(r.aggregator_moved for r in res.reports)
    handovers = sum(len(r.handovers) for r in res.reports)
    return dict(migrations=migrations, handovers=handovers,
                acc=res.final.acc,
                energy=res.final.cum_energy / len(res),
                delay=res.final.cum_delay / len(res),
                aggregators=res.series("aggregator"))


def main():
    s = setup("fmnist")
    rounds = min(8, s["sizes"]["rounds"])
    t0 = time.time()
    print(f"{'scenario':12s} {'strategy':12s} {'migr':>5s} {'handov':>7s} "
          f"{'acc':>6s} {'E/round':>9s} {'delay':>8s}")
    cells = {}
    for scenario in SCENARIOS:
        for strategy in STRATEGIES:
            c = run_cell(s, scenario, strategy, rounds)
            cells[(scenario, strategy)] = c
            print(f"{scenario:12s} {strategy:12s} {c['migrations']:5d} "
                  f"{c['handovers']:7d} {c['acc']:6.3f} "
                  f"{c['energy']:8.1f}J {c['delay']:7.2f}s")
        print(f"{'':12s} cefl aggregator trace: "
              f"{cells[(scenario, 'cefl')]['aggregators']}")
    elapsed = time.time() - t0

    # the dynamics claim: under mobility, CE-FL's aggregation point
    # migrates while the fixed baseline's cannot
    for scenario in SCENARIOS:
        moved = cells[(scenario, "cefl")]["migrations"]
        csv_line(f"dyn_{scenario}_cefl_migrations", elapsed * 1e6,
                 f"{moved} (fixed=0 by construction)")
        csv_line(f"dyn_{scenario}_handovers", elapsed * 1e6,
                 cells[(scenario, "cefl")]["handovers"])


if __name__ == "__main__":
    main()
