"""Paper Figs. 3-6, dynamic regime: strategy comparison under the scenario
subsystem (mobility + handover + mesh churn + drift), the environments the
static ``fig3_4_aggregator`` path cannot exercise.

Each (scenario, strategy) cell is a declarative spec — the ``bench_*``
preset with the cell's scenario/strategy overridden — executed through
``repro.experiments.sweep`` (one spec grid, one call): aggregation-point
migrations, UE handovers, accuracy, per-round energy/delay — the
mobility/evolution story of the paper (CE-FL's floating point tracks the
moving data/rate concentration; fixed baselines cannot).

    PYTHONPATH=src python -m benchmarks.run fig3_4_dynamics
    QUICK=0 ... for the paper-size network
"""
from __future__ import annotations

import time

from benchmarks.common import QUICK, bench_spec, csv_line
from repro import experiments as E

SCENARIOS = ("campus_walk", "vehicular", "flash_crowd") if not QUICK \
    else ("campus_walk", "vehicular")
STRATEGIES = ("cefl", "greedy_data", "fixed:0")


def cell_spec(scenario: str, strategy: str, rounds: int):
    return bench_spec().override(**{
        "name": f"dyn_{scenario}_{strategy.replace(':', '')}",
        "scenario": scenario, "strategy": strategy,
        "engine.rounds": rounds, "engine.solver_outer": 2,
        "engine.reoptimize_every": 1, "seeds": (0,)})


def summarize(res) -> dict:
    migrations = sum(r.aggregator_moved for r in res.reports)
    handovers = sum(len(r.handovers) for r in res.reports)
    return dict(migrations=migrations, handovers=handovers,
                acc=res.final.acc,
                energy=res.final.cum_energy / len(res),
                delay=res.final.cum_delay / len(res),
                aggregators=res.series("aggregator"))


def main():
    rounds = min(8, bench_spec().engine.rounds)
    specs = [cell_spec(sc, st, rounds)
             for sc in SCENARIOS for st in STRATEGIES]
    t0 = time.time()
    result = E.sweep(specs, executor="sequential")
    cells = {}
    print(f"{'scenario':12s} {'strategy':12s} {'migr':>5s} {'handov':>7s} "
          f"{'acc':>6s} {'E/round':>9s} {'delay':>8s}")
    for scenario in SCENARIOS:
        for strategy in STRATEGIES:
            name = f"dyn_{scenario}_{strategy.replace(':', '')}"
            c = summarize(result.result(0, name))
            cells[(scenario, strategy)] = c
            print(f"{scenario:12s} {strategy:12s} {c['migrations']:5d} "
                  f"{c['handovers']:7d} {c['acc']:6.3f} "
                  f"{c['energy']:8.1f}J {c['delay']:7.2f}s")
        print(f"{'':12s} cefl aggregator trace: "
              f"{cells[(scenario, 'cefl')]['aggregators']}")
    elapsed = time.time() - t0

    # the dynamics claim: under mobility, CE-FL's aggregation point
    # migrates while the fixed baseline's cannot
    for scenario in SCENARIOS:
        moved = cells[(scenario, "cefl")]["migrations"]
        csv_line(f"dyn_{scenario}_cefl_migrations", elapsed * 1e6,
                 f"{moved} (fixed=0 by construction)")
        csv_line(f"dyn_{scenario}_handovers", elapsed * 1e6,
                 cells[(scenario, "cefl")]["handovers"])


if __name__ == "__main__":
    main()
