"""Paper Fig. 7: decentralized solver — (a) centralized vs decentralized for
different consensus-round budgets J; (b) convergence vs network size |N| —
plus the solver-scaling trajectory (jit vs ref backend) that ISSUE 3 pins:
per-plan wall-clock at N in {20, 100, 500, 2000} UEs, recorded to
``BENCH_solver.json`` at the repo root (committed; see docs/solver.md).

    PYTHONPATH=src python -m benchmarks.fig7_solver           # full + json
    PYTHONPATH=src python -m benchmarks.fig7_solver --smoke   # CI smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import QUICK, csv_line, setup
from repro.core import MLConstants
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights, PDHyper, sca

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------- solver scaling -----

def _scaling_case(n_ue, n_bs, n_dc):
    """UE population scales, BS/DC tiers stay fixed (the paper's setting:
    device count dominates infrastructure count)."""
    net = make_network(NetworkConfig(num_ue=n_ue, num_bs=n_bs,
                                     num_dc=n_dc, seed=0))
    nd = n_ue + n_dc
    consts = MLConstants(L=4.0, theta_i=np.full(nd, 2.0),
                         sigma_i=np.ones(nd), zeta1=2.0, zeta2=1.0)
    rng = np.random.RandomState(n_ue)
    D_bar = rng.normal(2000.0, 200.0, n_ue).clip(100)
    return net, consts, D_bar


def solver_scaling(ns=(20, 100, 500, 2000), *, n_bs=8, n_dc=4,
                   max_ref_n=2000, outer=2, repeats=3):
    """Wall-clock per plan (centralized Algorithm 1, the EngineOptions
    default) for the jitted backend vs the numpy oracle.  The jit number is
    the warm re-solve — fresh rates + arrivals each repeat, hitting the
    compile cache exactly like the per-round engine path; the first
    (compiling) solve is recorded separately as ``jit_cold_s``.  The ref
    backend re-traces and materializes the (nC x P) constraint jacobian
    every call (~minutes + GBs past a few thousand UEs); cap it with
    ``max_ref_n`` when sweeping larger populations."""
    ow = ObjectiveWeights()
    rows = []
    for n in ns:
        net, consts, D_bar = _scaling_case(n, n_bs, n_dc)
        kw = dict(distributed=False, max_outer=outer, pd=PDHyper())
        t0 = time.perf_counter()
        sca.solve(net, D_bar, consts, ow, backend="jit", **kw)
        cold = time.perf_counter() - t0
        rng = np.random.RandomState(1)
        warm = []
        for _ in range(repeats):
            net_t = net.resample_rates(rng, 0.15)
            D_t = D_bar * rng.uniform(0.9, 1.1, D_bar.shape)
            t0 = time.perf_counter()
            sca.solve(net_t, D_t, consts, ow, backend="jit", **kw)
            warm.append(time.perf_counter() - t0)
        jit_s = min(warm)
        ref_s = None
        if n <= max_ref_n:
            t0 = time.perf_counter()
            sca.solve(net, D_bar, consts, ow, backend="ref", **kw)
            ref_s = time.perf_counter() - t0
        row = {"n_ue": n, "n_bs": n_bs, "n_dc": n_dc,
               "jit_warm_s": round(jit_s, 4), "jit_cold_s": round(cold, 3),
               "ref_s": None if ref_s is None else round(ref_s, 3),
               "speedup": None if ref_s is None else round(ref_s / jit_s, 2)}
        rows.append(row)
        csv_line(f"solver_scaling_n{n}", jit_s * 1e6,
                 f"ref={ref_s}s speedup={row['speedup']}")
    return rows


def _curve_case(n_ue, n_bs, n_dc):
    """Like :func:`_scaling_case` but consensus-free: the centralized
    solver never reads the (V, V) consensus graph, and skipping it (plus
    the vectorized channel draws) is what makes 10^5-UE topologies
    constructible in milliseconds."""
    net = make_network(NetworkConfig(num_ue=n_ue, num_bs=n_bs,
                                     num_dc=n_dc, seed=0), consensus=False)
    nd = n_ue + n_dc
    consts = MLConstants(L=4.0, theta_i=np.full(nd, 2.0),
                         sigma_i=np.ones(nd), zeta1=2.0, zeta2=1.0)
    rng = np.random.RandomState(n_ue)
    D_bar = rng.normal(2000.0, 200.0, n_ue).clip(100)
    return net, consts, D_bar


def solver_scaling_curve(ns=(2000, 20000, 100000), *, n_bs=8, n_dc=4,
                         outer=2, repeats=3, cohort=2000):
    """The large-N scaling curve of the segment-sum solver (centralized
    Algorithm 1): warm re-solve wall-clock at N in ``ns``, plus one
    COHORT row — population ``ns[-1]``, per-round client sample of
    ``cohort`` UEs, solved through ``topology.subnetwork`` — which is the
    configuration the engine actually runs at 10^5-10^6 UEs
    (``EngineOptions.cohort_size``).  The cohort row reuses the
    (cohort, n_bs, n_dc) jit cache of the matching curve row, so its
    warm time sits at the small-N figure no matter the population."""
    from repro.network.topology import subnetwork
    ow = ObjectiveWeights()
    kw = dict(distributed=False, max_outer=outer, pd=PDHyper())
    rows = []
    for n in ns:
        net, consts, D_bar = _curve_case(n, n_bs, n_dc)
        t0 = time.perf_counter()
        sca.solve(net, D_bar, consts, ow, backend="jit", **kw)
        cold = time.perf_counter() - t0
        rng = np.random.RandomState(1)
        warm = []
        for _ in range(repeats):
            net_t = net.resample_rates(rng, 0.15)
            D_t = D_bar * rng.uniform(0.9, 1.1, D_bar.shape)
            t0 = time.perf_counter()
            sca.solve(net_t, D_t, consts, ow, backend="jit", **kw)
            warm.append(time.perf_counter() - t0)
        row = {"n_ue": n, "n_bs": n_bs, "n_dc": n_dc,
               "jit_warm_s": round(min(warm), 4),
               "jit_cold_s": round(cold, 3)}
        rows.append(row)
        csv_line(f"solver_curve_n{n}", min(warm) * 1e6,
                 f"cold={cold:.2f}s")
    # --- cohort row: gather + warm-solve of the K-UE subproblem ---
    pop = ns[-1]
    net, consts, D_bar = _curve_case(pop, n_bs, n_dc)
    rng = np.random.RandomState(2)
    warm = []
    for _ in range(repeats):
        net_t = net.resample_rates(rng, 0.15)
        D_t = D_bar * rng.uniform(0.9, 1.1, D_bar.shape)
        t0 = time.perf_counter()
        idx = np.sort(rng.choice(pop, cohort, replace=False))
        sub = subnetwork(net_t, idx)
        sub_consts = MLConstants(
            L=consts.L,
            theta_i=np.concatenate([consts.theta_i[:pop][idx],
                                    consts.theta_i[pop:]]),
            sigma_i=np.concatenate([consts.sigma_i[:pop][idx],
                                    consts.sigma_i[pop:]]),
            zeta1=consts.zeta1, zeta2=consts.zeta2)
        sca.solve(sub, D_t[idx], sub_consts, ow, backend="jit", **kw)
        warm.append(time.perf_counter() - t0)
    cohort_row = {"n_ue": pop, "cohort": cohort, "n_bs": n_bs,
                  "n_dc": n_dc, "jit_warm_s": round(min(warm), 4),
                  "includes": "cohort draw + subnetwork gather + solve"}
    csv_line(f"solver_cohort_n{pop}_k{cohort}", min(warm) * 1e6,
             "draw+gather+solve")
    return rows, cohort_row


def run_scaling_curve(*, out_path=None, ns=(2000, 20000, 100000),
                      cohort=2000):
    """Run the curve and record it as the ``scaling_curve`` section of
    BENCH_solver.json (committed) and/or ``out_path`` (the CI-fresh copy
    consumed by ``check_regression.py --solver-scaling``).  Every other
    section of an existing json (results, smoke_baseline,
    scaling_baseline) is preserved."""
    rows, cohort_row = solver_scaling_curve(ns=ns, cohort=cohort)
    section = {
        "mode": "centralized segment-sum solver, consensus-free topology, "
                "max_outer=2, PDHyper defaults; jit_warm_s = best of 3 "
                "warm re-solves (resampled rates/arrivals)",
        "backend": __import__("jax").default_backend(),
        "results": rows,
        "cohort": cohort_row,
    }
    path = os.path.join(_ROOT, "BENCH_solver.json")
    targets = [path] + ([out_path] if out_path else [])
    for p in targets:
        try:
            with open(p) as f:
                out = json.load(f)
        except (OSError, ValueError):
            out = {"bench": "solver_scaling"}
        out["scaling_curve"] = section
        os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
        with open(p, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"[fig7_solver] wrote {p}")
    print(json.dumps(section, indent=2))
    return rows, cohort_row


def run_scaling(*, smoke=False, out_path=None):
    if smoke:
        rows = solver_scaling(ns=(8, 20), n_bs=4, n_dc=2, max_ref_n=20,
                              outer=2, repeats=2)
        for r in rows:
            # regression gate: the jit backend must stay comfortably ahead
            # of the oracle (observed ~200x; 3x is the acceptance floor)
            assert r["speedup"] is not None and r["speedup"] >= 3.0, r
        if out_path:
            out = {"bench": "solver_scaling", "smoke": True,
                   "results": rows}
            os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(out, f, indent=2)
                f.write("\n")
            print(f"[fig7_solver] wrote {out_path}")
        print(json.dumps(rows, indent=2))
        return rows
    rows = solver_scaling()
    out = {"bench": "solver_scaling",
           "mode": "centralized (EngineOptions default), max_outer=2, "
                   "PDHyper defaults; jit_warm_s = warm re-solve with "
                   "resampled rates/arrivals (compile-cache hit)",
           "backend": __import__("jax").default_backend(),
           "results": rows}
    path = os.path.join(_ROOT, "BENCH_solver.json")
    # keep the committed smoke baseline for the CI regression gate
    try:
        with open(path) as f:
            prev = json.load(f)
        if "smoke_baseline" in prev:
            out["smoke_baseline"] = prev["smoke_baseline"]
    except (OSError, ValueError):
        pass
    targets = [path] + ([out_path] if out_path else [])
    for p in targets:
        os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
        with open(p, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"[fig7_solver] wrote {p}")
    print(json.dumps(rows, indent=2))
    return rows


def main(out_path=None):
    s = setup("fmnist")
    net, consts, ow = s["net"], s["consts"], s["ow"]
    N = net.cfg.num_ue
    rng = np.random.RandomState(0)
    D_bar = rng.normal(s["sizes"]["mean_arrivals"],
                       s["sizes"]["mean_arrivals"] / 10, N).clip(100)
    outer = 4 if QUICK else 10

    t0 = time.time()
    print("\n== Fig. 7a: centralized vs decentralized (consensus rounds J) ==")
    res_c = sca.solve(net, D_bar, consts, ow, distributed=False,
                      max_outer=outer)
    print(f"centralized: {[f'{x:.0f}' for x in res_c.objective_history]}")
    finals = {}
    for J in ((10, 50) if QUICK else (10, 50, 70)):
        res_d = sca.solve(net, D_bar, consts, ow, distributed=True,
                          max_outer=outer,
                          pd=PDHyper(max_iters=3, consensus_rounds=J))
        finals[J] = res_d.objective_history[-1]
        print(f"decentralized J={J:3d}: "
              f"{[f'{x:.0f}' for x in res_d.objective_history]}")
    gaps = {J: abs(v - res_c.objective_history[-1])
            / abs(res_c.objective_history[-1]) for J, v in finals.items()}
    print("relative gap to centralized:",
          {J: f"{g:.3f}" for J, g in gaps.items()})

    print("\n== Fig. 7b: scaling with number of UEs ==")
    for n_ue in ((6, 12) if QUICK else (10, 15, 20, 30)):
        net2 = make_network(NetworkConfig(num_ue=n_ue, num_bs=4, num_dc=3))
        nd = n_ue + 3
        c2 = MLConstants(L=consts.L,
                         theta_i=np.full(nd, consts.theta_i.mean()),
                         sigma_i=np.full(nd, consts.sigma_i.mean()),
                         zeta1=consts.zeta1, zeta2=consts.zeta2)
        D2 = rng.normal(s["sizes"]["mean_arrivals"],
                        s["sizes"]["mean_arrivals"] / 10, n_ue).clip(100)
        res = sca.solve(net2, D2, c2, ow, distributed=True,
                        max_outer=outer,
                        pd=PDHyper(max_iters=3, consensus_rounds=30))
        print(f"|N|={n_ue:3d}: obj {res.objective_history[0]:.0f} -> "
              f"{res.objective_history[-1]:.0f} "
              f"({res.iterations} SCA iters)")
    elapsed = time.time() - t0
    Jmax = max(gaps)
    csv_line("fig7_solver_gap", elapsed * 1e6,
             f"gap_J{Jmax}={gaps[Jmax]:.3f}")
    # the paper's qualitative claim: more consensus rounds -> smaller gap
    js = sorted(gaps)
    csv_line("fig7_gap_shrinks_with_J", elapsed * 1e6,
             gaps[js[-1]] <= gaps[js[0]] + 0.05)

    print("\n== Solver backend scaling (jit vs ref) ==")
    run_scaling(smoke=QUICK, out_path=out_path)


if __name__ == "__main__":
    from benchmarks.microbench import _flag_value
    _argv = sys.argv[1:]
    _out = _flag_value(_argv, "--out")
    if "--scaling-curve" in _argv:
        run_scaling_curve(out_path=_out)
    elif "--smoke" in _argv:
        run_scaling(smoke=True, out_path=_out)
    else:
        main(out_path=_out)
