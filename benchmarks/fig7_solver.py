"""Paper Fig. 7: decentralized solver — (a) centralized vs decentralized for
different consensus-round budgets J; (b) convergence vs network size |N|."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, csv_line, setup
from repro.core import MLConstants
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights, PDHyper, sca


def main():
    s = setup("fmnist")
    net, consts, ow = s["net"], s["consts"], s["ow"]
    N = net.cfg.num_ue
    rng = np.random.RandomState(0)
    D_bar = rng.normal(s["sizes"]["mean_arrivals"],
                       s["sizes"]["mean_arrivals"] / 10, N).clip(100)
    outer = 4 if QUICK else 10

    t0 = time.time()
    print("\n== Fig. 7a: centralized vs decentralized (consensus rounds J) ==")
    res_c = sca.solve(net, D_bar, consts, ow, distributed=False,
                      max_outer=outer)
    print(f"centralized: {[f'{x:.0f}' for x in res_c.objective_history]}")
    finals = {}
    for J in ((10, 50) if QUICK else (10, 50, 70)):
        res_d = sca.solve(net, D_bar, consts, ow, distributed=True,
                          max_outer=outer,
                          pd=PDHyper(max_iters=3, consensus_rounds=J))
        finals[J] = res_d.objective_history[-1]
        print(f"decentralized J={J:3d}: "
              f"{[f'{x:.0f}' for x in res_d.objective_history]}")
    gaps = {J: abs(v - res_c.objective_history[-1])
            / abs(res_c.objective_history[-1]) for J, v in finals.items()}
    print("relative gap to centralized:",
          {J: f"{g:.3f}" for J, g in gaps.items()})

    print("\n== Fig. 7b: scaling with number of UEs ==")
    for n_ue in ((6, 12) if QUICK else (10, 15, 20, 30)):
        net2 = make_network(NetworkConfig(num_ue=n_ue, num_bs=4, num_dc=3))
        nd = n_ue + 3
        c2 = MLConstants(L=consts.L,
                         theta_i=np.full(nd, consts.theta_i.mean()),
                         sigma_i=np.full(nd, consts.sigma_i.mean()),
                         zeta1=consts.zeta1, zeta2=consts.zeta2)
        D2 = rng.normal(s["sizes"]["mean_arrivals"],
                        s["sizes"]["mean_arrivals"] / 10, n_ue).clip(100)
        res = sca.solve(net2, D2, c2, ow, distributed=True,
                        max_outer=outer,
                        pd=PDHyper(max_iters=3, consensus_rounds=30))
        print(f"|N|={n_ue:3d}: obj {res.objective_history[0]:.0f} -> "
              f"{res.objective_history[-1]:.0f} "
              f"({res.iterations} SCA iters)")
    elapsed = time.time() - t0
    Jmax = max(gaps)
    csv_line("fig7_solver_gap", elapsed * 1e6,
             f"gap_J{Jmax}={gaps[Jmax]:.3f}")
    # the paper's qualitative claim: more consensus rounds -> smaller gap
    js = sorted(gaps)
    csv_line("fig7_gap_shrinks_with_J", elapsed * 1e6,
             gaps[js[-1]] <= gaps[js[0]] + 0.05)


if __name__ == "__main__":
    main()
