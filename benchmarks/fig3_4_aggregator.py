"""Paper Figs. 3 & 4: floating-aggregator dynamics — data/rate distribution
across DC subnetworks, CE-FL's aggregator switching vs datapoint-greedy and
rate-greedy, and delay/energy vs fixed-aggregator baselines."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_line, setup
from repro.core import Engine, EngineOptions
from repro.solver.greedy import e2e_rate, subnet_datapoints


def main():
    s = setup("fmnist")
    net = s["net"]
    rounds = min(8, s["sizes"]["rounds"])
    t0 = time.time()
    results = {}
    for strat in ("cefl", "greedy_data", "greedy_rate", "fixed:0"):
        opts = EngineOptions(rounds=rounds, eta=0.1, solver_outer=2,
                             reoptimize_every=1, seed=0)
        results[strat] = Engine(
            net, strat, consts=s["consts"], ow=s["ow"], opts=opts).run(
            s["make_ues"](drift_labels=True), init_params=s["p0"],
            loss_fn=s["loss_fn"], eval_fn=s["eval_fn"]).to_history()

    print("\n== Fig. 3: aggregator switching pattern ==")
    print("round | " + " | ".join(f"{k:12s}" for k in results))
    for t in range(rounds):
        print(f"{t:5d} | " + " | ".join(
            f"DC{results[k]['aggregator'][t]:<10d}" for k in results))
    switches = {k: sum(1 for a, b in zip(v["aggregator"], v["aggregator"][1:])
                       if a != b) for k, v in results.items()}
    print("switches:", switches)

    # data concentration snapshot (Fig. 3a)
    ues = s["make_ues"](drift_labels=True, seed_off=5)
    D_bar = np.array([len(ds.step()["y"]) for ds in ues], float)
    print("datapoints per DC subnet:", subnet_datapoints(net, D_bar))
    print("mean E2E rate per DC (Gbps):",
          np.round(e2e_rate(net).mean(0) / 1e9, 3))

    print("\n== Fig. 4: delay & energy vs aggregation strategy ==")
    fixed_E, fixed_D = [], []
    for sdx in range(net.cfg.num_dc):
        opts = EngineOptions(rounds=3, eta=0.1, reoptimize_every=1, seed=0)
        h = Engine(net, f"fixed:{sdx}", consts=s["consts"], ow=s["ow"],
                   opts=opts).run(
            s["make_ues"](seed_off=sdx), init_params=s["p0"],
            loss_fn=s["loss_fn"], eval_fn=s["eval_fn"]).to_history()
        fixed_E.append(h["cum_energy"][-1] / 3)
        fixed_D.append(h["cum_delay"][-1] / 3)
    per_round = {k: (v["cum_energy"][-1] / rounds,
                     v["cum_delay"][-1] / rounds) for k, v in results.items()}
    print(f"{'strategy':12s} {'energy/round':>14s} {'delay/round':>12s}")
    for k, (e, d) in per_round.items():
        print(f"{k:12s} {e:13.2f}J {d:11.2f}s")
    print(f"{'fixed(avg)':12s} {np.mean(fixed_E):13.2f}J "
          f"{np.mean(fixed_D):11.2f}s")
    elapsed = time.time() - t0
    csv_line("fig3_aggregator_switches", elapsed * 1e6,
             f"cefl_switches={switches['cefl']}")
    csv_line("fig4_energy_savings_vs_fixed", elapsed * 1e6,
             f"{100*(1-per_round['cefl'][0]/max(np.mean(fixed_E),1e-9)):.1f}%")


if __name__ == "__main__":
    main()
