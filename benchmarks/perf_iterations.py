"""§Perf hillclimbing harness: named variants of the three chosen
(arch x shape) pairs, re-lowered and re-analyzed; results ->
results/perf/<pair>_<variant>.json.

Each variant encodes one hypothesis from EXPERIMENTS.md §Perf (napkin math
and verdicts live there; this script only executes and records).

  PYTHONPATH=src python -m benchmarks.perf_iterations [pair ...]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json
import sys
from pathlib import Path

from repro.launch.dryrun import dryrun_combo

# variant -> kwargs for dryrun_combo.  "baseline" = paper-faithful naive
# sharding (attention layout left to GSPMD), as recorded in the §Dry-run
# baseline table.
VARIANTS = {
    "llama3_train": [
        ("baseline", dict(attn_hint=False)),
        ("attn_shard", dict()),
        ("attn_shard_micro8_remat14", dict(plan_overrides=dict(
            n_micro=8, remat_chunk=14))),
        ("attn_shard_micro4_remat18", dict(plan_overrides=dict(
            n_micro=4, remat_chunk=18))),
    ],
    "whisper_train": [
        ("baseline", dict(attn_hint=False)),
        ("attn_shard", dict()),
        ("attn_shard_micro4", dict(plan_overrides=dict(n_micro=4))),
        ("attn_shard_micro4_gamma4", dict(gamma_max=4,
                                          plan_overrides=dict(n_micro=4))),
    ],
    "mamba2_train": [
        ("baseline", dict(attn_hint=False)),
        ("embed_replicated", dict(attn_hint=False, plan_overrides=dict(
            embed_replicated=True))),           # hypothesis REFUTED
        ("ssm_shard", dict()),                  # batch->data on SSD acts
        ("ssm_shard_micro4", dict(plan_overrides=dict(n_micro=4))),
        ("ssm_shard_gamma4", dict(gamma_max=4)),
    ],
}

PAIRS = {
    "llama3_train": ("llama3-405b", "train_4k", False),
    "whisper_train": ("whisper-medium", "train_4k", False),
    "mamba2_train": ("mamba2-130m", "train_4k", False),
}


def main():
    which = sys.argv[1:] or list(PAIRS)
    outdir = Path("results/perf")
    outdir.mkdir(parents=True, exist_ok=True)
    for pair in which:
        arch, shape, mp = PAIRS[pair]
        for vname, kw in VARIANTS[pair]:
            path = outdir / f"{pair}_{vname}.json"
            if path.exists():
                print(f"[cached] {pair}/{vname}")
                continue
            print(f"== {pair} / {vname} ==")
            rec = dryrun_combo(arch, shape, multi_pod=mp, verbose=True,
                               **kw)
            rec["variant"] = vname
            path.write_text(json.dumps(rec, indent=1))
        # summary
        print(f"\n-- {pair} summary --")
        for vname, _ in VARIANTS[pair]:
            rec = json.loads((outdir / f"{pair}_{vname}.json").read_text())
            c = rec["chips"]
            comp = rec["flops"] / (c * 197e12)
            mem = rec["bytes_accessed"] / (c * 819e9)
            coll = rec["collective_bytes"] / (c * 50e9)
            print(f"{vname:28s} compute {comp:9.2f}s  memory {mem:9.2f}s  "
                  f"coll {coll:8.2f}s  HBM/dev "
                  f"{rec['bytes_per_device']/1e9:6.1f}G  "
                  f"model/hlo {rec['model_flops']/rec['flops']:.3f}")


if __name__ == "__main__":
    main()
