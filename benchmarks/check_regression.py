"""CI bench-regression gate: compare fresh ``--smoke`` benchmark JSONs
against the committed baselines in ``BENCH_kernels.json`` /
``BENCH_solver.json`` (their ``smoke_baseline`` sections) and fail on
regression.

Only *machine-portable* metrics are gated — same-run/same-machine ratios
(plane vs tree, jit solver vs numpy oracle, fused kernel vs unfused XLA)
— never absolute wall-clock, which is meaningless across CI runners.  A
speedup regresses when ``fresh < baseline / tol``; lower-is-better
ratios (``fedprox_vs_xla_ratio``) regress when ``fresh > baseline *
tol``.  ``tol`` (default 3.0, override ``--tol`` or ``BENCH_TOL``)
absorbs runner noise while still catching the order-of-magnitude rots
the gate exists for (e.g. the jitted solver silently falling back to
per-call retraces, or the fused kernels losing to the unfused path).

The kernels baseline is keyed per kernel backend (``smoke_baseline.
<backend>.*``, matching ``results.<backend>.*`` in BENCH_kernels.json):
the gate reads the fresh run's ``backend`` key and compares against that
section only, skipping gracefully when no baseline for the backend has
been committed yet (``--update`` records one without touching the other
backends' sections).

    PYTHONPATH=src python -m benchmarks.microbench --smoke --out out/k.json
    PYTHONPATH=src python -m benchmarks.fig7_solver --smoke --out out/s.json
    PYTHONPATH=src python -m benchmarks.sweep_bench --smoke --out out/w.json
    PYTHONPATH=src python -m benchmarks.check_regression \
        --kernels out/k.json --solver out/s.json --sweep out/w.json \
        [--tol 3.0]

Refreshing the baselines after an intentional perf change:

    PYTHONPATH=src python -m benchmarks.check_regression --update \
        --kernels out/k.json --solver out/s.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# metric -> how to read it from a smoke-run JSON
KERNEL_METRICS = ("sim_round_speedup", "mesh_round_speedup",
                  "solver_plan_speedup", "fedprox_vs_xla_ratio")

# metrics where SMALLER is better (gated as fresh <= baseline * tol);
# everything else is a speedup gated as fresh >= baseline / tol
LOWER_IS_BETTER = frozenset({"fedprox_vs_xla_ratio"})


def _load(path):
    with open(path) as f:
        return json.load(f)


def _is_per_backend(section) -> bool:
    """True for the per-backend schema ({"cpu": {...}, "tpu": {...}});
    False for the legacy flat metric dict."""
    return (isinstance(section, dict) and section
            and all(isinstance(v, dict) for v in section.values()))


def kernel_ratios(fresh: dict) -> dict:
    """Gated ratios from a fresh microbench JSON — per-backend schema
    (``results.<backend>.*``, the run's own ``backend`` key selects the
    section) or the legacy flat layout."""
    res = fresh["results"]
    if _is_per_backend(res):
        res = res.get(fresh.get("backend")) or next(iter(res.values()))
    return {k: float(res[k]) for k in KERNEL_METRICS if k in res}


def kernel_backend(fresh: dict):
    return fresh.get("backend")


def solver_ratios(fresh: dict) -> dict:
    out = {}
    for row in fresh["results"]:
        if row.get("speedup") is not None:
            out[f"solver_scaling_n{row['n_ue']}_speedup"] = \
                float(row["speedup"])
    return out


# sweep gate: the vmap-vs-sequential ratio is machine-portable; the
# rounds/sec throughput is absolute but gated under the same generous
# tol to catch order-of-magnitude rot (a silently-sequential "vmap"
# executor, per-round retraces) rather than runner-speed noise
SWEEP_METRICS = ("vmap_sweep_speedup", "sweep_rounds_per_sec")


def sweep_ratios(fresh: dict) -> dict:
    res = fresh["results"]
    return {k: float(res[k]) for k in SWEEP_METRICS if k in res}


def compare(baseline: dict, fresh: dict, tol: float):
    """Return (rows, regressions): every baseline metric must exist fresh
    and satisfy fresh >= baseline / tol (speedups), or
    fresh <= baseline * tol for LOWER_IS_BETTER metrics."""
    rows, regressions = [], []
    for k, base in sorted(baseline.items()):
        got = fresh.get(k)
        if k in LOWER_IS_BETTER:
            bound = base * tol
            ok = got is not None and got <= bound
        else:
            bound = base / tol
            ok = got is not None and got >= bound
        rows.append((k, base, got, bound, ok))
        if not ok:
            regressions.append(k)
    return rows, regressions


def _select_baseline(baseline, backend):
    """Pick the backend's section of a per-backend smoke_baseline;
    legacy flat baselines pass through.  Returns None when the baseline
    is per-backend but has no section for this backend — the gate then
    skips (a backend with no committed baseline is tolerated, so CI on
    new hardware doesn't fail before a baseline exists)."""
    if _is_per_backend(baseline):
        if backend is None:
            return next(iter(baseline.values()))
        return baseline.get(backend)
    return baseline


def _gate(name, committed_path, fresh_path, extract, tol, backend_of=None):
    committed = _load(committed_path)
    baseline = committed.get("smoke_baseline")
    if not baseline:
        raise SystemExit(
            f"{committed_path} has no 'smoke_baseline' section — "
            f"regenerate it with --update")
    fresh_json = _load(fresh_path)
    backend = backend_of(fresh_json) if backend_of else None
    baseline = _select_baseline(baseline, backend)
    if baseline is None:
        print(f"== {name}: no committed baseline for backend "
              f"{backend!r} — skipped (run --update to record one) ==")
        return []
    fresh = extract(fresh_json)
    tag = f", backend {backend}" if backend else ""
    rows, regressions = compare(baseline, fresh, tol)
    print(f"== {name} (tol {tol:g}x{tag}) ==")
    for k, base, got, bound, ok in rows:
        got_s = "MISSING" if got is None else f"{got:8.2f}"
        rel = "ceil " if k in LOWER_IS_BETTER else "floor"
        print(f"  {'ok ' if ok else 'REG'} {k:34s} baseline {base:8.2f}  "
              f"fresh {got_s}  {rel} {bound:8.2f}")
    return regressions


def _update(committed_path, fresh_path, extract, backend_of=None):
    committed = _load(committed_path)
    fresh_json = _load(fresh_path)
    ratios = extract(fresh_json)
    backend = backend_of(fresh_json) if backend_of else None
    if backend:
        # per-backend baseline: merge this backend's section, keep others
        base = committed.get("smoke_baseline")
        base = dict(base) if _is_per_backend(base) else {}
        base[backend] = ratios
        committed["smoke_baseline"] = base
    else:
        committed["smoke_baseline"] = ratios
    with open(committed_path, "w") as f:
        json.dump(committed, f, indent=2)
        f.write("\n")
    print(f"[check_regression] wrote smoke_baseline -> {committed_path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", help="fresh microbench --smoke JSON")
    ap.add_argument("--solver", help="fresh fig7_solver --smoke JSON")
    ap.add_argument("--sweep", help="fresh sweep_bench --smoke JSON")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_TOL", "3.0")))
    ap.add_argument("--update", action="store_true",
                    help="write the fresh ratios into the committed "
                         "baselines instead of gating")
    args = ap.parse_args(argv)
    if not args.kernels and not args.solver and not args.sweep:
        ap.error("need --kernels, --solver, and/or --sweep")

    pairs = []
    if args.kernels:
        pairs.append(("kernels", os.path.join(_ROOT, "BENCH_kernels.json"),
                      args.kernels, kernel_ratios, kernel_backend))
    if args.solver:
        pairs.append(("solver", os.path.join(_ROOT, "BENCH_solver.json"),
                      args.solver, solver_ratios, None))
    if args.sweep:
        pairs.append(("sweep", os.path.join(_ROOT, "BENCH_sweep.json"),
                      args.sweep, sweep_ratios, None))

    if args.update:
        for _, committed, fresh, extract, backend_of in pairs:
            _update(committed, fresh, extract, backend_of)
        return 0

    regressions = []
    for name, committed, fresh, extract, backend_of in pairs:
        regressions += _gate(name, committed, fresh, extract, args.tol,
                             backend_of)
    if regressions:
        print(f"BENCH REGRESSION: {regressions}", file=sys.stderr)
        return 1
    print("bench gate: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
