"""CI bench-regression gate: compare fresh ``--smoke`` benchmark JSONs
against the committed baselines in ``BENCH_kernels.json`` /
``BENCH_solver.json`` (their ``smoke_baseline`` sections) and fail on
regression.

Only *machine-portable* metrics are gated — same-run/same-machine ratios
(plane vs tree, jit solver vs numpy oracle, fused kernel vs unfused XLA)
— never absolute wall-clock, which is meaningless across CI runners.  A
speedup regresses when ``fresh < baseline / tol``; lower-is-better
ratios (``fedprox_vs_xla_ratio``) regress when ``fresh > baseline *
tol``.  ``tol`` (default 3.0, override ``--tol`` or ``BENCH_TOL``)
absorbs runner noise while still catching the order-of-magnitude rots
the gate exists for (e.g. the jitted solver silently falling back to
per-call retraces, or the fused kernels losing to the unfused path).

The kernels baseline is keyed per kernel backend (``smoke_baseline.
<backend>.*``, matching ``results.<backend>.*`` in BENCH_kernels.json):
the gate reads the fresh run's ``backend`` key and compares against that
section only, skipping gracefully when no baseline for the backend has
been committed yet (``--update`` records one without touching the other
backends' sections).

    PYTHONPATH=src python -m benchmarks.microbench --smoke --out out/k.json
    PYTHONPATH=src python -m benchmarks.fig7_solver --smoke --out out/s.json
    PYTHONPATH=src python -m benchmarks.sweep_bench --smoke --out out/w.json
    PYTHONPATH=src python -m benchmarks.check_regression \
        --kernels out/k.json --solver out/s.json --sweep out/w.json \
        [--tol 3.0]

Refreshing the baselines after an intentional perf change:

    PYTHONPATH=src python -m benchmarks.check_regression --update \
        --kernels out/k.json --solver out/s.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# metric -> how to read it from a smoke-run JSON
KERNEL_METRICS = ("sim_round_speedup", "mesh_round_speedup",
                  "solver_plan_speedup", "fedprox_vs_xla_ratio")

# metrics where SMALLER is better (gated as fresh <= baseline * tol);
# everything else is a speedup gated as fresh >= baseline / tol
LOWER_IS_BETTER = frozenset({"fedprox_vs_xla_ratio"})

# lower-is-better metric families: the solver scaling curve gates warm
# wall-clock (ms) and growth ratios, where smaller is faster
LOWER_IS_BETTER_PREFIXES = ("solver_curve_", "solver_cohort_")


def lower_is_better(k: str) -> bool:
    return k in LOWER_IS_BETTER or k.startswith(LOWER_IS_BETTER_PREFIXES)


def _load(path):
    with open(path) as f:
        return json.load(f)


def _is_per_backend(section) -> bool:
    """True for the per-backend schema ({"cpu": {...}, "tpu": {...}});
    False for the legacy flat metric dict."""
    return (isinstance(section, dict) and section
            and all(isinstance(v, dict) for v in section.values()))


def kernel_ratios(fresh: dict) -> dict:
    """Gated ratios from a fresh microbench JSON — per-backend schema
    (``results.<backend>.*``, the run's own ``backend`` key selects the
    section) or the legacy flat layout."""
    res = fresh["results"]
    if _is_per_backend(res):
        res = res.get(fresh.get("backend")) or next(iter(res.values()))
    return {k: float(res[k]) for k in KERNEL_METRICS if k in res}


def kernel_backend(fresh: dict):
    return fresh.get("backend")


def solver_ratios(fresh: dict) -> dict:
    out = {}
    for row in fresh["results"]:
        if row.get("speedup") is not None:
            out[f"solver_scaling_n{row['n_ue']}_speedup"] = \
                float(row["speedup"])
    return out


def scaling_backend(fresh: dict):
    sec = fresh.get("scaling_curve", fresh)
    return sec.get("backend") or fresh.get("backend")


def solver_scaling_ratios(fresh: dict) -> dict:
    """Gated metrics from a ``fig7_solver --scaling-curve`` JSON
    (``scaling_curve`` section): per-N warm wall-clock in ms (absolute,
    gated under the generous tol like ``sweep_rounds_per_sec`` — catches
    order-of-magnitude rot such as per-round retraces), the 2e4/2e3
    growth ratio (machine-portable: how super-linear the solver is), and
    the cohort-vs-small-N ratio (machine-portable: client sampling must
    keep the 10^5-population solve at the small-N figure).  All
    lower-is-better."""
    sec = fresh.get("scaling_curve", fresh)
    rows = {r["n_ue"]: r for r in sec["results"]}
    out = {}
    for n, r in sorted(rows.items()):
        out[f"solver_curve_n{n}_warm_ms"] = round(
            float(r["jit_warm_s"]) * 1e3, 2)
    if 2000 in rows and 20000 in rows:
        out["solver_curve_growth_2e4_over_2e3"] = round(
            float(rows[20000]["jit_warm_s"])
            / float(rows[2000]["jit_warm_s"]), 3)
    coh = sec.get("cohort")
    if coh and coh.get("cohort") in rows:
        out["solver_cohort_vs_small_ratio"] = round(
            float(coh["jit_warm_s"])
            / float(rows[coh["cohort"]]["jit_warm_s"]), 3)
    return out


# sweep gate: the vmap-vs-sequential ratio is machine-portable; the
# rounds/sec throughput is absolute but gated under the same generous
# tol to catch order-of-magnitude rot (a silently-sequential "vmap"
# executor, per-round retraces) rather than runner-speed noise
SWEEP_METRICS = ("vmap_sweep_speedup", "sweep_rounds_per_sec")


def sweep_ratios(fresh: dict) -> dict:
    res = fresh["results"]
    return {k: float(res[k]) for k in SWEEP_METRICS if k in res}


def compare(baseline: dict, fresh: dict, tol: float):
    """Return (rows, regressions): every baseline metric must exist fresh
    and satisfy fresh >= baseline / tol (speedups), or
    fresh <= baseline * tol for LOWER_IS_BETTER metrics."""
    rows, regressions = [], []
    for k, base in sorted(baseline.items()):
        got = fresh.get(k)
        if lower_is_better(k):
            bound = base * tol
            ok = got is not None and got <= bound
        else:
            bound = base / tol
            ok = got is not None and got >= bound
        rows.append((k, base, got, bound, ok))
        if not ok:
            regressions.append(k)
    return rows, regressions


def _select_baseline(baseline, backend):
    """Pick the backend's section of a per-backend smoke_baseline;
    legacy flat baselines pass through.  Returns None when the baseline
    is per-backend but has no section for this backend — the gate then
    skips (a backend with no committed baseline is tolerated, so CI on
    new hardware doesn't fail before a baseline exists)."""
    if _is_per_backend(baseline):
        if backend is None:
            return next(iter(baseline.values()))
        return baseline.get(backend)
    return baseline


def _gate(name, committed_path, fresh_path, extract, tol, backend_of=None,
          baseline_key="smoke_baseline", summary=None):
    committed = _load(committed_path)
    baseline = committed.get(baseline_key)
    if not baseline:
        raise SystemExit(
            f"{committed_path} has no {baseline_key!r} section — "
            f"regenerate it with --update")
    fresh_json = _load(fresh_path)
    backend = backend_of(fresh_json) if backend_of else None
    baseline = _select_baseline(baseline, backend)
    if baseline is None:
        print(f"== {name}: no committed baseline for backend "
              f"{backend!r} — skipped (run --update to record one) ==")
        return []
    fresh = extract(fresh_json)
    tag = f", backend {backend}" if backend else ""
    rows, regressions = compare(baseline, fresh, tol)
    print(f"== {name} (tol {tol:g}x{tag}) ==")
    for k, base, got, bound, ok in rows:
        got_s = "MISSING" if got is None else f"{got:8.2f}"
        rel = "ceil " if lower_is_better(k) else "floor"
        print(f"  {'ok ' if ok else 'REG'} {k:34s} baseline {base:8.2f}  "
              f"fresh {got_s}  {rel} {bound:8.2f}")
    if summary is not None:
        summary.extend((name, backend, k, base, got, bound, ok)
                       for k, base, got, bound, ok in rows)
    return regressions


def _update(committed_path, fresh_path, extract, backend_of=None,
            baseline_key="smoke_baseline"):
    committed = _load(committed_path)
    fresh_json = _load(fresh_path)
    ratios = extract(fresh_json)
    backend = backend_of(fresh_json) if backend_of else None
    if backend:
        # per-backend baseline: merge this backend's section, keep others
        base = committed.get(baseline_key)
        base = dict(base) if _is_per_backend(base) else {}
        base[backend] = ratios
        committed[baseline_key] = base
    else:
        committed[baseline_key] = ratios
    with open(committed_path, "w") as f:
        json.dump(committed, f, indent=2)
        f.write("\n")
    print(f"[check_regression] wrote {baseline_key} -> {committed_path}")


def write_step_summary(summary, tol, path) -> None:
    """Append the bench delta table to a GitHub Actions step summary
    (markdown).  ``summary``: (gate, backend, metric, baseline, fresh,
    bound, ok) rows from the _gate calls."""
    lines = [
        "### Bench regression gate",
        "",
        f"tolerance: {tol:g}x — speedups must stay above `baseline/tol`, "
        "lower-is-better metrics below `baseline*tol`",
        "",
        "| gate | metric | baseline | fresh | delta | bound | ok |",
        "|---|---|---:|---:|---:|---:|:---:|",
    ]
    for gate, backend, k, base, got, bound, ok in summary:
        gate_s = f"{gate} ({backend})" if backend else gate
        got_s = "missing" if got is None else f"{got:.2f}"
        delta = "—" if got is None or not base else \
            f"{(got / base - 1.0) * 100:+.1f}%"
        rel = "≤" if lower_is_better(k) else "≥"
        lines.append(f"| {gate_s} | `{k}` | {base:.2f} | {got_s} | {delta} "
                     f"| {rel} {bound:.2f} | {'✅' if ok else '❌'} |")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", help="fresh microbench --smoke JSON")
    ap.add_argument("--solver", help="fresh fig7_solver --smoke JSON")
    ap.add_argument("--sweep", help="fresh sweep_bench --smoke JSON")
    ap.add_argument("--solver-scaling",
                    help="fresh fig7_solver --scaling-curve JSON (gated "
                         "against BENCH_solver.json's scaling_baseline)")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_TOL", "3.0")))
    ap.add_argument("--update", action="store_true",
                    help="write the fresh ratios into the committed "
                         "baselines instead of gating")
    args = ap.parse_args(argv)
    if not (args.kernels or args.solver or args.sweep
            or args.solver_scaling):
        ap.error("need --kernels, --solver, --sweep, and/or "
                 "--solver-scaling")

    solver_json = os.path.join(_ROOT, "BENCH_solver.json")
    pairs = []
    if args.kernels:
        pairs.append(("kernels", os.path.join(_ROOT, "BENCH_kernels.json"),
                      args.kernels, kernel_ratios, kernel_backend,
                      "smoke_baseline"))
    if args.solver:
        pairs.append(("solver", solver_json, args.solver, solver_ratios,
                      None, "smoke_baseline"))
    if args.solver_scaling:
        pairs.append(("solver-scaling", solver_json, args.solver_scaling,
                      solver_scaling_ratios, scaling_backend,
                      "scaling_baseline"))
    if args.sweep:
        pairs.append(("sweep", os.path.join(_ROOT, "BENCH_sweep.json"),
                      args.sweep, sweep_ratios, None, "smoke_baseline"))

    if args.update:
        for _, committed, fresh, extract, backend_of, key in pairs:
            _update(committed, fresh, extract, backend_of,
                    baseline_key=key)
        return 0

    regressions, summary = [], []
    for name, committed, fresh, extract, backend_of, key in pairs:
        regressions += _gate(name, committed, fresh, extract, args.tol,
                             backend_of, baseline_key=key, summary=summary)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary and summary:
        write_step_summary(summary, args.tol, step_summary)
    if regressions:
        print(f"BENCH REGRESSION: {regressions}", file=sys.stderr)
        return 1
    print("bench gate: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
