"""CI bench-regression gate: compare fresh ``--smoke`` benchmark JSONs
against the committed baselines in ``BENCH_kernels.json`` /
``BENCH_solver.json`` (their ``smoke_baseline`` sections) and fail on
regression.

Only *machine-portable* metrics are gated — speedup ratios measured
same-run/same-machine (plane vs tree, jit solver vs numpy oracle) — never
absolute wall-clock, which is meaningless across CI runners.  A metric
regresses when ``fresh < baseline / tol``; ``tol`` (default 3.0, override
``--tol`` or ``BENCH_TOL``) absorbs runner noise while still catching the
order-of-magnitude rots the gate exists for (e.g. the jitted solver
silently falling back to per-call retraces, or the fused kernels losing
to the unfused path).

    PYTHONPATH=src python -m benchmarks.microbench --smoke --out out/k.json
    PYTHONPATH=src python -m benchmarks.fig7_solver --smoke --out out/s.json
    PYTHONPATH=src python -m benchmarks.sweep_bench --smoke --out out/w.json
    PYTHONPATH=src python -m benchmarks.check_regression \
        --kernels out/k.json --solver out/s.json --sweep out/w.json \
        [--tol 3.0]

Refreshing the baselines after an intentional perf change:

    PYTHONPATH=src python -m benchmarks.check_regression --update \
        --kernels out/k.json --solver out/s.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# metric -> how to read it from a smoke-run JSON
KERNEL_METRICS = ("sim_round_speedup", "mesh_round_speedup",
                  "solver_plan_speedup")


def _load(path):
    with open(path) as f:
        return json.load(f)


def kernel_ratios(fresh: dict) -> dict:
    res = fresh["results"]
    return {k: float(res[k]) for k in KERNEL_METRICS if k in res}


def solver_ratios(fresh: dict) -> dict:
    out = {}
    for row in fresh["results"]:
        if row.get("speedup") is not None:
            out[f"solver_scaling_n{row['n_ue']}_speedup"] = \
                float(row["speedup"])
    return out


# sweep gate: the vmap-vs-sequential ratio is machine-portable; the
# rounds/sec throughput is absolute but gated under the same generous
# tol to catch order-of-magnitude rot (a silently-sequential "vmap"
# executor, per-round retraces) rather than runner-speed noise
SWEEP_METRICS = ("vmap_sweep_speedup", "sweep_rounds_per_sec")


def sweep_ratios(fresh: dict) -> dict:
    res = fresh["results"]
    return {k: float(res[k]) for k in SWEEP_METRICS if k in res}


def compare(baseline: dict, fresh: dict, tol: float):
    """Return (rows, regressions): every baseline metric must exist fresh
    and satisfy fresh >= baseline / tol."""
    rows, regressions = [], []
    for k, base in sorted(baseline.items()):
        floor = base / tol
        got = fresh.get(k)
        ok = got is not None and got >= floor
        rows.append((k, base, got, floor, ok))
        if not ok:
            regressions.append(k)
    return rows, regressions


def _gate(name, committed_path, fresh_path, extract, tol):
    committed = _load(committed_path)
    baseline = committed.get("smoke_baseline")
    if not baseline:
        raise SystemExit(
            f"{committed_path} has no 'smoke_baseline' section — "
            f"regenerate it with --update")
    fresh = extract(_load(fresh_path))
    rows, regressions = compare(baseline, fresh, tol)
    print(f"== {name} (tol {tol:g}x) ==")
    for k, base, got, floor, ok in rows:
        got_s = "MISSING" if got is None else f"{got:8.2f}"
        print(f"  {'ok ' if ok else 'REG'} {k:34s} baseline {base:8.2f}  "
              f"fresh {got_s}  floor {floor:8.2f}")
    return regressions


def _update(committed_path, fresh_path, extract):
    committed = _load(committed_path)
    committed["smoke_baseline"] = extract(_load(fresh_path))
    with open(committed_path, "w") as f:
        json.dump(committed, f, indent=2)
        f.write("\n")
    print(f"[check_regression] wrote smoke_baseline -> {committed_path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", help="fresh microbench --smoke JSON")
    ap.add_argument("--solver", help="fresh fig7_solver --smoke JSON")
    ap.add_argument("--sweep", help="fresh sweep_bench --smoke JSON")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_TOL", "3.0")))
    ap.add_argument("--update", action="store_true",
                    help="write the fresh ratios into the committed "
                         "baselines instead of gating")
    args = ap.parse_args(argv)
    if not args.kernels and not args.solver and not args.sweep:
        ap.error("need --kernels, --solver, and/or --sweep")

    pairs = []
    if args.kernels:
        pairs.append(("kernels", os.path.join(_ROOT, "BENCH_kernels.json"),
                      args.kernels, kernel_ratios))
    if args.solver:
        pairs.append(("solver", os.path.join(_ROOT, "BENCH_solver.json"),
                      args.solver, solver_ratios))
    if args.sweep:
        pairs.append(("sweep", os.path.join(_ROOT, "BENCH_sweep.json"),
                      args.sweep, sweep_ratios))

    if args.update:
        for _, committed, fresh, extract in pairs:
            _update(committed, fresh, extract)
        return 0

    regressions = []
    for name, committed, fresh, extract in pairs:
        regressions += _gate(name, committed, fresh, extract, args.tol)
    if regressions:
        print(f"BENCH REGRESSION: {regressions}", file=sys.stderr)
        return 1
    print("bench gate: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
