"""Shared benchmark setup — now a thin view over the declarative
experiment specs (``repro.experiments``).

The network / synthetic data / estimated ML constants / calibrated
objective weights all derive from the registered ``bench_quick`` /
``bench_paper`` presets through ``experiments.build_context`` — the same
single derivation path the spec CLI and the sweep executors use (no more
duplicated seeding or constants-estimation code here).

QUICK=1 (default) uses the scaled-down ``bench_quick`` spec so the whole
harness finishes on one CPU core; QUICK=0 uses the paper's 20/10/5
topology (``bench_paper``).
"""
from __future__ import annotations

import functools
import os

from repro import experiments as E

QUICK = os.environ.get("QUICK", "1") != "0"


def bench_spec(dataset: str = "fmnist") -> E.ExperimentSpec:
    """The benchmark cell spec: QUICK selects the preset, ``dataset``
    swaps the image shape (the CIFAR-like variant)."""
    spec = E.get_experiment("bench_quick" if QUICK else "bench_paper")
    if dataset == "cifar":
        shape = (16, 16, 3) if QUICK else (32, 32, 3)
        spec = spec.override(**{"name": spec.name + "_cifar",
                                "model.input_shape": shape})
    return spec


def bench_sizes():
    spec = bench_spec()
    return dict(num_ue=spec.network.num_ue, num_bs=spec.network.num_bs,
                num_dc=spec.network.num_dc, rounds=spec.engine.rounds,
                mean_arrivals=spec.data.mean_arrivals,
                img=tuple(spec.model.input_shape),
                hidden=tuple(spec.model.hidden), pool=spec.data.pool)


@functools.lru_cache(maxsize=4)
def setup(dataset: str = "fmnist", seed: int = 0):
    """Legacy dict view of the built experiment context (the static
    benches index it by key).  ``make_ues(drift_labels, seed_off)`` keeps
    the old signature; seeds still flow through the spec's single
    derivation point."""
    spec = bench_spec(dataset)
    if seed:
        spec = spec.override(**{"network.topology_seed": seed,
                                "data.pool_seed": seed, "seeds": (seed,)})
    ctx = E.build_context(spec)
    drift_spec = spec.override(**{"data.drift_labels": True})

    def make_ues(drift_labels=False, seed_off=0):
        # the drift context shares ctx's build (drift_labels is stripped
        # from the context cache key) — only the stream flag differs
        c = E.build_context(drift_spec) if drift_labels else ctx
        return c.make_ues(seed + seed_off)

    return dict(net=ctx.net, p0=ctx.p0, make_ues=make_ues,
                eval_fn=ctx.eval_fn, loss_fn=ctx.loss_fn,
                consts=ctx.consts, ow=ctx.ow, sizes=bench_sizes(),
                spec=spec, ctx=ctx)


def csv_line(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
