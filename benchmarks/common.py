"""Shared benchmark setup: network, synthetic F-MNIST/CIFAR-like data,
estimated ML constants, calibrated objective weights.

QUICK=1 (default) uses a scaled-down network/rounds so the whole harness
finishes on one CPU core; QUICK=0 uses the paper's 20/10/5 topology.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cefl_paper import ClassifierConfig
from repro.core.estimation import estimate_constants
from repro.data import make_image_dataset, make_online_ues
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights

QUICK = os.environ.get("QUICK", "1") != "0"


def bench_sizes():
    if QUICK:
        return dict(num_ue=8, num_bs=4, num_dc=3, rounds=10,
                    mean_arrivals=400.0, img=(14, 14, 1), hidden=(64,),
                    pool=8000)
    return dict(num_ue=20, num_bs=10, num_dc=5, rounds=40,
                mean_arrivals=2000.0, img=(28, 28, 1), hidden=(200, 100),
                pool=48000)


@functools.lru_cache(maxsize=4)
def setup(dataset: str = "fmnist", seed: int = 0):
    sz = bench_sizes()
    img = sz["img"] if dataset == "fmnist" else (
        (16, 16, 3) if QUICK else (32, 32, 3))
    net = make_network(NetworkConfig(num_ue=sz["num_ue"],
                                     num_bs=sz["num_bs"],
                                     num_dc=sz["num_dc"], seed=seed))
    (trx, tr_y), (tex, te_y) = make_image_dataset(sz["pool"], img,
                                                  seed=seed)
    ccfg = ClassifierConfig(input_shape=img, hidden=sz["hidden"])
    p0 = init_classifier_params(jax.random.PRNGKey(seed), ccfg)

    def make_ues(drift_labels=False, seed_off=0):
        return make_online_ues(trx, tr_y, num_ue=sz["num_ue"],
                               mean_arrivals=sz["mean_arrivals"],
                               std_arrivals=sz["mean_arrivals"] / 10,
                               seed=seed + seed_off,
                               drift_labels=drift_labels)

    def eval_fn(p):
        return classifier_accuracy(p, jnp.asarray(tex[:1000]),
                                   jnp.asarray(te_y[:1000]))

    # one-shot pre-training estimation (paper Algs. 4-6, App. H-1).
    # Theta/sigma are estimated per-UE; DC entries (data is a mixture of
    # offloaded UE data) take the UE means.
    probe = [ds.step() for ds in make_ues(seed_off=99)]
    consts = estimate_constants(classifier_loss, p0, probe,
                                key=jax.random.PRNGKey(7),
                                iters=3 if QUICK else 8)
    import dataclasses as _dc
    pad = sz["num_dc"]
    consts = _dc.replace(
        consts,
        theta_i=np.concatenate([consts.theta_i,
                                np.full(pad, consts.theta_i.mean())]),
        sigma_i=np.concatenate([consts.sigma_i,
                                np.full(pad, consts.sigma_i.mean())]))
    ow = ObjectiveWeights(xi1=1.0, xi2=1e-2, xi3=2.0, T=sz["rounds"])
    return dict(net=net, p0=p0, make_ues=make_ues, eval_fn=eval_fn,
                loss_fn=classifier_loss, consts=consts, ow=ow, sizes=sz)


def csv_line(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
