"""Multi-seed sweep benchmark: VmapSweepExecutor vs the sequential
fallback on the ``sweep_bench`` preset (8 seeds).

Writes ``BENCH_sweep.json`` at the repo root (committed — part of the
recorded perf trajectory) with:

* ``vmap_sweep_speedup`` — sequential wall-clock / vmap wall-clock,
  same machine, same run.  Machine-portable; the primary gated ratio.
* ``sweep_rounds_per_sec`` — (runs x rounds) / vmap wall-clock.  The
  acceptance throughput number; gated with the standard generous
  tolerance since absolute throughput varies across runners.

The per-seed results of the two executors are asserted identical before
any number is reported — a speedup from diverging numerics is a bug,
not a result.

    PYTHONPATH=src python -m benchmarks.sweep_bench           # full
    PYTHONPATH=src python -m benchmarks.sweep_bench --smoke   # CI smoke
    ... --out bench_out/BENCH_sweep.smoke.json
"""
from __future__ import annotations

import json
import os
import sys
import time

from benchmarks.common import csv_line
from repro import experiments as E

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spec(smoke: bool):
    spec = E.get_experiment("sweep_bench")
    if smoke:
        spec = spec.override(**{"engine.rounds": 3,
                                "seeds": tuple(range(4))})
    return spec


def _time_sweep(spec, executor: str):
    t0 = time.time()
    result = E.sweep(spec, executor=executor)
    return time.time() - t0, result


def run_bench(*, smoke: bool = False) -> dict:
    spec = _spec(smoke)
    # warm everything outside the timed region: the context cache keys on
    # engine.rounds (ObjectiveWeights.T), so build THIS spec's context
    # explicitly, then warm the jit caches (shape-keyed, rounds-agnostic)
    # with a cheap 1-round, 2-seed sweep
    E.build_context(spec)
    warm = spec.override(**{"engine.rounds": 1, "seeds": spec.seeds[:2]})
    E.sweep(warm, executor="vmap")
    E.sweep(warm, executor="sequential")

    t_seq, r_seq = _time_sweep(spec, "sequential")
    t_vmap, r_vmap = _time_sweep(spec, "vmap")
    # bit-exactness before any speedup claim
    for seed in spec.run_seeds:
        a, b = r_seq.result(seed), r_vmap.result(seed)
        assert a.series("loss") == b.series("loss"), seed
        assert a.series("acc") == b.series("acc"), seed
        assert a.series("aggregator") == b.series("aggregator"), seed
    n_rounds = len(spec.run_seeds) * spec.engine.rounds
    results = {
        "seeds": len(spec.run_seeds),
        "rounds": spec.engine.rounds,
        "sequential_s": round(t_seq, 3),
        "vmap_s": round(t_vmap, 3),
        "vmap_sweep_speedup": round(t_seq / t_vmap, 3),
        "sweep_rounds_per_sec": round(n_rounds / t_vmap, 3),
    }
    csv_line("sweep_vmap_8seed" if not smoke else "sweep_vmap_smoke",
             t_vmap / n_rounds * 1e6,
             f"speedup={results['vmap_sweep_speedup']:.2f}x "
             f"rounds_per_sec={results['sweep_rounds_per_sec']:.2f}")
    return results


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    out_path = None
    if "--out" in argv:
        i = argv.index("--out")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            raise SystemExit("--out requires a path argument")
        out_path = argv[i + 1]
    results = run_bench(smoke=smoke)
    out = {"bench": "sweep", "smoke": smoke,
           "spec": _spec(smoke).name, "results": results}
    path = os.path.join(_ROOT, "BENCH_sweep.json")
    if not smoke:
        # preserve the committed smoke baseline (the CI regression gate
        # compares smoke runs against it; see benchmarks/check_regression)
        try:
            with open(path) as f:
                prev = json.load(f)
            if "smoke_baseline" in prev:
                out["smoke_baseline"] = prev["smoke_baseline"]
        except (OSError, ValueError):
            pass
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"[sweep_bench] wrote {path}")
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"[sweep_bench] wrote {out_path}")
    print(json.dumps(results, indent=2))
    return out


if __name__ == "__main__":
    main()
