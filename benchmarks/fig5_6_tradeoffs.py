"""Paper Figs. 5 & 6: (5) model-drift vs global-aggregation delay and UE CPU
frequency; (6) ML-performance weight xi1 vs mini-batch ratios and energy.
Both are solver ablations (Sec. VI-B3/4): sweep one knob, re-solve P, report
the optimized orchestration variables."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import QUICK, csv_line, setup
from repro.network.costs import network_costs, round_energy
from repro.solver import sca


def main():
    s = setup("fmnist")
    net, consts, ow0 = s["net"], s["consts"], s["ow"]
    N = net.cfg.num_ue
    rng = np.random.RandomState(0)
    D_bar = rng.normal(s["sizes"]["mean_arrivals"],
                       s["sizes"]["mean_arrivals"] / 10, N).clip(100)
    outer = 3 if QUICK else 8

    t0 = time.time()
    print("\n== Fig. 5: drift vs delay / CPU frequency ==")
    print(f"{'drift':>6s} {'delta_A+R (s)':>14s} {'mean f_n (GHz)':>15s}")
    drift_rows = []
    for drift in (0.05, 0.3, 1.0, 3.0):
        ow = dataclasses.replace(ow0, drift=drift)
        res = sca.solve(net, D_bar, consts, ow, distributed=False,
                        max_outer=outer)
        w = res.w_rounded
        delay = float(w["delta_A"] + w["delta_R"])
        fmean = float(np.mean(np.asarray(w["f_n"]))) / 1e9
        drift_rows.append((drift, delay, fmean))
        print(f"{drift:6.2f} {delay:14.2f} {fmean:15.3f}")
    # paper: higher drift -> faster rounds (lower delay), faster CPUs
    monotone_delay = drift_rows[0][1] >= drift_rows[-1][1]
    monotone_freq = drift_rows[0][2] <= drift_rows[-1][2]

    print("\n== Fig. 6: xi1 (ML weight) vs mini-batch ratio / energy ==")
    print(f"{'xi1':>8s} {'mean m_i':>9s} {'round energy (J)':>17s}")
    xi_rows = []
    for xi1 in (0.01, 0.1, 1.0, 10.0):
        ow = dataclasses.replace(ow0, xi1=xi1)
        res = sca.solve(net, D_bar, consts, ow, distributed=False,
                        max_outer=outer)
        w = res.w_rounded
        m_mean = float(np.mean(np.asarray(w["m"])))
        E = float(round_energy(network_costs(w, net, D_bar), ow.xi3_sub))
        xi_rows.append((xi1, m_mean, E))
        print(f"{xi1:8.2f} {m_mean:9.3f} {E:17.2f}")
    elapsed = time.time() - t0
    csv_line("fig5_drift_tradeoff", elapsed * 1e6 / 8,
             f"delay_monotone={monotone_delay},freq_monotone={monotone_freq}")
    csv_line("fig6_xi1_tradeoff", elapsed * 1e6 / 8,
             f"m({xi_rows[0][0]})={xi_rows[0][1]:.3f},"
             f"m({xi_rows[-1][0]})={xi_rows[-1][1]:.3f}")


if __name__ == "__main__":
    main()
