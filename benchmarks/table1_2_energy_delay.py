"""Paper Tables I & II: energy and delay to reach target accuracies —
CE-FL vs FedNova vs FedAvg, on the F-MNIST-like and CIFAR-like synthetic
tasks (targets re-based for the synthetic data; DESIGN.md §Assumptions).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, csv_line, setup
from repro.core import Engine, EngineOptions


def first_reach(hist, targets):
    out = {}
    for tgt in targets:
        idx = next((i for i, a in enumerate(hist["acc"]) if a >= tgt), None)
        if idx is None:
            out[tgt] = (float("nan"), float("nan"))
        else:
            out[tgt] = (hist["cum_energy"][idx], hist["cum_delay"][idx])
    return out


def run(dataset="fmnist", targets=(0.4, 0.5, 0.6), seed=0):
    s = setup(dataset, seed)
    rounds = s["sizes"]["rounds"]
    rows = {}
    t0 = time.time()
    for strat in ("cefl", "fednova", "fedavg"):
        opts = EngineOptions(rounds=rounds, eta=0.1,
                             solver_outer=2 if QUICK else 4,
                             reoptimize_every=3, seed=seed)
        h = Engine(s["net"], strat, consts=s["consts"], ow=s["ow"],
                   opts=opts).run(
            s["make_ues"](), init_params=s["p0"], loss_fn=s["loss_fn"],
            eval_fn=s["eval_fn"]).to_history()
        rows[strat] = {"hist": h, "reach": first_reach(h, targets)}
    elapsed = time.time() - t0
    return rows, targets, elapsed


def main():
    for dataset in (("fmnist", "cifar") if not QUICK else ("fmnist",)):
        rows, targets, elapsed = run(dataset)
        print(f"\n== Tables I/II ({dataset}): energy (J) / delay (s) to "
              f"target accuracy ==")
        print(f"{'strategy':10s} " + "  ".join(f"acc>={t:.2f}" for t in targets)
              + "   final_acc")
        for strat, r in rows.items():
            cells = []
            for t in targets:
                e, d = r["reach"][t]
                cells.append(f"{e:8.1f}J/{d:7.1f}s")
            print(f"{strat:10s} " + "  ".join(cells)
                  + f"   {r['hist']['acc'][-1]:.3f}")
        for t in targets:
            e_c, d_c = rows["cefl"]["reach"][t]
            e_n, d_n = rows["fednova"]["reach"][t]
            if np.isfinite(e_c) and np.isfinite(e_n) and e_n > 0:
                sav_e = 100 * (1 - e_c / e_n)
                sav_d = 100 * (1 - d_c / d_n)
                print(f"  vs FedNova savings @ {t:.2f}: "
                      f"energy {sav_e:+.1f}%  delay {sav_d:+.1f}%")
        csv_line(f"table1_energy_{dataset}", elapsed * 1e6 / 3,
                 f"final_acc={rows['cefl']['hist']['acc'][-1]:.3f}")
    return 0


if __name__ == "__main__":
    main()
