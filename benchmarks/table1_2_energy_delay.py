"""Paper Tables I & II: energy and delay to reach target accuracies —
CE-FL vs FedNova vs FedAvg, on the F-MNIST-like and CIFAR-like synthetic
tasks (targets re-based for the synthetic data; DESIGN.md §Assumptions).

Each table row is the ``bench_*`` spec with the strategy overridden; the
three rows run as one spec grid through ``repro.experiments.sweep``.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, bench_spec, csv_line
from repro import experiments as E


def first_reach(hist, targets):
    out = {}
    for tgt in targets:
        idx = next((i for i, a in enumerate(hist["acc"]) if a >= tgt), None)
        if idx is None:
            out[tgt] = (float("nan"), float("nan"))
        else:
            out[tgt] = (hist["cum_energy"][idx], hist["cum_delay"][idx])
    return out


def run(dataset="fmnist", targets=(0.4, 0.5, 0.6), seed=0):
    base = bench_spec(dataset)
    if seed:
        # pre-spec parity: a nonzero seed reseeded topology + pool too
        base = base.override(**{"network.topology_seed": seed,
                                "data.pool_seed": seed})
    specs = [base.override(**{
        "name": f"table1_{strat}", "strategy": strat,
        "engine.solver_outer": 2 if QUICK else 4,
        "engine.reoptimize_every": 3, "seeds": (seed,)})
        for strat in ("cefl", "fednova", "fedavg")]
    t0 = time.time()
    result = E.sweep(specs, executor="sequential")
    rows = {}
    for strat in ("cefl", "fednova", "fedavg"):
        h = result.result(seed, f"table1_{strat}").to_history()
        rows[strat] = {"hist": h, "reach": first_reach(h, targets)}
    return rows, targets, time.time() - t0


def main():
    for dataset in (("fmnist", "cifar") if not QUICK else ("fmnist",)):
        rows, targets, elapsed = run(dataset)
        print(f"\n== Tables I/II ({dataset}): energy (J) / delay (s) to "
              f"target accuracy ==")
        print(f"{'strategy':10s} " + "  ".join(f"acc>={t:.2f}" for t in targets)
              + "   final_acc")
        for strat, r in rows.items():
            cells = []
            for t in targets:
                e, d = r["reach"][t]
                cells.append(f"{e:8.1f}J/{d:7.1f}s")
            print(f"{strat:10s} " + "  ".join(cells)
                  + f"   {r['hist']['acc'][-1]:.3f}")
        for t in targets:
            e_c, d_c = rows["cefl"]["reach"][t]
            e_n, d_n = rows["fednova"]["reach"][t]
            if np.isfinite(e_c) and np.isfinite(e_n) and e_n > 0:
                sav_e = 100 * (1 - e_c / e_n)
                sav_d = 100 * (1 - d_c / d_n)
                print(f"  vs FedNova savings @ {t:.2f}: "
                      f"energy {sav_e:+.1f}%  delay {sav_d:+.1f}%")
        csv_line(f"table1_energy_{dataset}", elapsed * 1e6 / 3,
                 f"final_acc={rows['cefl']['hist']['acc'][-1]:.3f}")
    return 0


if __name__ == "__main__":
    main()
