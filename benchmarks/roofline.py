"""Roofline report builder: reads results/dryrun/*.json (written by
repro.launch.dryrun) and emits the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.utils.roofline import Roofline


def load_rows(outdir="results/dryrun", mesh="16x16"):
    rows = []
    for f in sorted(Path(outdir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "skipped":
            rows.append(rec)
            continue
        if rec.get("status") != "ok" or (mesh and rec["mesh"] != mesh):
            continue
        rows.append(Roofline(
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
            chips=rec["chips"], hlo_flops=rec["flops"],
            hlo_bytes=rec["bytes_accessed"],
            coll_bytes=rec["collective_bytes"],
            model_flops=rec["model_flops"],
            bytes_per_device=rec.get("bytes_per_device", 0)).row())
    return rows


def fmt_table(rows):
    hdr = (f"| {'arch':26s} | {'shape':11s} | {'compute_s':>9s} | "
           f"{'memory_s':>9s} | {'collect_s':>9s} | {'dominant':>10s} | "
           f"{'model/hlo':>9s} | {'HBM/dev':>8s} |")
    lines = [hdr, "|" + "-" * (len(hdr) - 2) + "|"]
    for r in rows:
        if "compute_s" not in r:
            lines.append(f"| {r['arch']:26s} | {r['shape']:11s} | "
                         f"{'skipped: ' + r.get('reason', '')[:52]:s} |")
            continue
        lines.append(
            f"| {r['arch']:26s} | {r['shape']:11s} | {r['compute_s']:9.4f} | "
            f"{r['memory_s']:9.4f} | {r['collective_s']:9.4f} | "
            f"{r['dominant']:>10s} | {r['useful_ratio']:9.3f} | "
            f"{r['bytes_per_device']/1e9:7.1f}G |")
    return "\n".join(lines)


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    for mesh in ("16x16", "2x16x16"):
        rows = load_rows(outdir, mesh)
        if rows:
            print(f"\n### Roofline ({mesh}, {len(rows)} combos)\n")
            print(fmt_table(rows))


if __name__ == "__main__":
    main()
