"""Roofline report builder.

Two modes:

* default — reads results/dryrun/*.json (written by repro.launch.dryrun)
  and emits the EXPERIMENTS.md §Roofline table;
* ``--kernels [BENCH_kernels.json]`` — turns the measured kernel times in
  the per-backend BENCH trajectory into achieved memory bandwidth
  (bytes-moved / wall-clock, bytes derived from the plane shapes the
  bench records in its config section) against a nominal per-backend
  peak, so each backend section reads as a fraction of roofline::

      PYTHONPATH=src python -m benchmarks.roofline --kernels
      PYTHONPATH=src python -m benchmarks.roofline --kernels out/k.json \
          --out bench_out/roofline_kernels.txt        # CI artifact
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

from repro.utils.roofline import HBM_BW, Roofline

# nominal peak memory bandwidth per kernel backend (B/s).  TPU uses the
# same per-chip HBM figure as the dry-run roofline; GPU assumes an
# A100-class HBM2e part; cpu/interpret use a dual-channel-DDR ballpark —
# these normalize the trajectory, they are not calibrated to the runner.
PEAK_BW = {"tpu": HBM_BW, "gpu": 1.5e12, "cpu": 5e10, "interpret": 5e10}


def load_rows(outdir="results/dryrun", mesh="16x16"):
    rows = []
    for f in sorted(Path(outdir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "skipped":
            rows.append(rec)
            continue
        if rec.get("status") != "ok" or (mesh and rec["mesh"] != mesh):
            continue
        rows.append(Roofline(
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
            chips=rec["chips"], hlo_flops=rec["flops"],
            hlo_bytes=rec["bytes_accessed"],
            coll_bytes=rec["collective_bytes"],
            model_flops=rec["model_flops"],
            bytes_per_device=rec.get("bytes_per_device", 0)).row())
    return rows


def fmt_table(rows):
    hdr = (f"| {'arch':26s} | {'shape':11s} | {'compute_s':>9s} | "
           f"{'memory_s':>9s} | {'collect_s':>9s} | {'dominant':>10s} | "
           f"{'model/hlo':>9s} | {'HBM/dev':>8s} |")
    lines = [hdr, "|" + "-" * (len(hdr) - 2) + "|"]
    for r in rows:
        if "compute_s" not in r:
            lines.append(f"| {r['arch']:26s} | {r['shape']:11s} | "
                         f"{'skipped: ' + r.get('reason', '')[:52]:s} |")
            continue
        lines.append(
            f"| {r['arch']:26s} | {r['shape']:11s} | {r['compute_s']:9.4f} | "
            f"{r['memory_s']:9.4f} | {r['collective_s']:9.4f} | "
            f"{r['dominant']:>10s} | {r['useful_ratio']:9.3f} | "
            f"{r['bytes_per_device']/1e9:7.1f}G |")
    return "\n".join(lines)


# ------------------------------------------ kernel bandwidth mode -----

def _kernel_bytes(config: dict) -> dict:
    """Bytes moved per kernel launch, from the plane shapes the bench
    records (f32 planes): fedprox streams x/g/anchor in + x out; stacked
    nova streams x/d in + out (weights are negligible)."""
    out = {}
    shp = config.get("fedprox_shape")
    if shp:
        r, lane = shp
        out["fedprox_kernel_us"] = 4 * r * lane * 4
        out["fedprox_unfused_xla_us"] = 4 * r * lane * 4
    stk = config.get("nova_stack")
    if stk:
        n, r, lane = stk
        out["nova_stacked_us"] = 3 * n * r * lane * 4
    return out


def kernel_rows(bench: dict) -> list:
    """(backend, kernel, us, GB/s achieved, peak fraction) rows from a
    per-backend BENCH_kernels.json (legacy flat files yield one section
    keyed by the file's ``backend``)."""
    res = bench.get("results", {})
    if not any(isinstance(v, dict) for v in res.values()):
        res = {bench.get("backend", "cpu"): res}
    nbytes = _kernel_bytes(bench.get("config", {}))
    rows = []
    for backend in sorted(res):
        peak = PEAK_BW.get(backend)
        for key, moved in nbytes.items():
            us = res[backend].get(key)
            if us is None or us <= 0:
                continue
            bw = moved / (us * 1e-6)
            frac = bw / peak if peak else float("nan")
            rows.append((backend, key.replace("_us", ""), us, bw, frac))
    return rows


def fmt_kernel_table(rows) -> str:
    hdr = (f"| {'backend':9s} | {'kernel':20s} | {'us':>9s} | "
           f"{'GB/s':>8s} | {'of peak':>8s} |")
    lines = [hdr, "|" + "-" * (len(hdr) - 2) + "|"]
    for backend, kern, us, bw, frac in rows:
        lines.append(f"| {backend:9s} | {kern:20s} | {us:9.1f} | "
                     f"{bw / 1e9:8.2f} | {frac:7.1%} |")
    return "\n".join(lines)


def kernel_report(path=None, out=None) -> str:
    path = path or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_kernels.json")
    bench = json.loads(Path(path).read_text())
    rows = kernel_rows(bench)
    body = (f"### Kernel achieved bandwidth ({len(rows)} rows, "
            f"from {os.path.basename(path)})\n\n" + fmt_kernel_table(rows))
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        Path(out).write_text(body + "\n")
        print(f"[roofline] wrote {out}")
    return body


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if "--kernels" in argv:
        i = argv.index("--kernels")
        path = (argv[i + 1] if i + 1 < len(argv)
                and not argv[i + 1].startswith("--") else None)
        out = None
        if "--out" in argv:
            j = argv.index("--out")
            if j + 1 >= len(argv) or argv[j + 1].startswith("--"):
                raise SystemExit("--out requires a path argument")
            out = argv[j + 1]
        print(kernel_report(path, out))
        return
    outdir = argv[0] if argv else "results/dryrun"
    for mesh in ("16x16", "2x16x16"):
        rows = load_rows(outdir, mesh)
        if rows:
            print(f"\n### Roofline ({mesh}, {len(rows)} combos)\n")
            print(fmt_table(rows))


if __name__ == "__main__":
    main()
