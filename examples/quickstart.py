"""Quickstart: CE-FL on a synthetic edge network in ~a minute on CPU.

Builds a 6-UE / 3-BS / 2-DC network, streams non-iid online data to the UEs,
lets the network-aware solver pick offloading + the floating aggregation DC
each round, and trains the paper's image classifier cooperatively at UEs+DCs
— all through the typed orchestration Engine (see docs/orchestration.md).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import Engine, EngineOptions, MLConstants
from repro.data import make_image_dataset, make_online_ues
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights


def main():
    net = make_network(NetworkConfig(num_ue=6, num_bs=3, num_dc=2))
    (trx, tr_y), (tex, te_y) = make_image_dataset(6000, (14, 14, 1))
    ues = make_online_ues(trx, tr_y, num_ue=6, mean_arrivals=300,
                          std_arrivals=30)
    cfg = ClassifierConfig(input_shape=(14, 14, 1), hidden=(64,))
    p0 = init_classifier_params(jax.random.PRNGKey(0), cfg)
    consts = MLConstants(L=5.0, theta_i=np.ones(8) * 2.0,
                         sigma_i=np.ones(8) * 3.0, zeta1=2.0, zeta2=1.0)

    engine = Engine(net, "cefl", consts=consts, ow=ObjectiveWeights(),
                    opts=EngineOptions(rounds=8, eta=0.1, solver_outer=2,
                                       reoptimize_every=4))

    print("\nround  acc    loss   aggregator  energy(J)  delay(s)")

    @engine.on_round_end
    def show(r):
        print(f"{r.round:5d}  {r.acc:.3f}  {r.loss:.3f}  "
              f"DC{r.aggregator:<9d} {r.energy:9.2f} {r.delay:9.2f}")

    result = engine.run(ues, init_params=p0, loss_fn=classifier_loss,
                        eval_fn=lambda p: classifier_accuracy(
                            p, jnp.asarray(tex[:500]), jnp.asarray(te_y[:500])))

    final = result.final
    print(f"\nfinal accuracy {final.acc:.3f}; "
          f"total energy {final.cum_energy:.1f} J, "
          f"total delay {final.cum_delay:.1f} s")


if __name__ == "__main__":
    main()
