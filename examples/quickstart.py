"""Quickstart: CE-FL on a synthetic edge network in ~a minute on CPU.

Builds a 6-UE / 3-BS / 2-DC network, streams non-iid online data to the UEs,
lets the network-aware solver pick offloading + the floating aggregation DC
each round, and trains the paper's image classifier cooperatively at UEs+DCs.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import CEFLOptions, MLConstants, run_cefl
from repro.data import make_image_dataset, make_online_ues
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights


def main():
    net = make_network(NetworkConfig(num_ue=6, num_bs=3, num_dc=2))
    (trx, tr_y), (tex, te_y) = make_image_dataset(6000, (14, 14, 1))
    ues = make_online_ues(trx, tr_y, num_ue=6, mean_arrivals=300,
                          std_arrivals=30)
    cfg = ClassifierConfig(input_shape=(14, 14, 1), hidden=(64,))
    p0 = init_classifier_params(jax.random.PRNGKey(0), cfg)
    consts = MLConstants(L=5.0, theta_i=np.ones(8) * 2.0,
                         sigma_i=np.ones(8) * 3.0, zeta1=2.0, zeta2=1.0)

    hist = run_cefl(
        net, ues, init_params=p0, loss_fn=classifier_loss,
        eval_fn=lambda p: classifier_accuracy(
            p, jnp.asarray(tex[:500]), jnp.asarray(te_y[:500])),
        consts=consts, ow=ObjectiveWeights(),
        opts=CEFLOptions(rounds=8, strategy="cefl", eta=0.1,
                         solver_outer=2, reoptimize_every=4))

    print("\nround  acc    aggregator  energy(J)  delay(s)")
    for t in hist["round"]:
        print(f"{t:5d}  {hist['acc'][t]:.3f}  DC{hist['aggregator'][t]:<9d} "
              f"{hist['energy'][t]:9.2f} {hist['delay'][t]:9.2f}")
    print(f"\nfinal accuracy {hist['acc'][-1]:.3f}; "
          f"total energy {hist['cum_energy'][-1]:.1f} J, "
          f"total delay {hist['cum_delay'][-1]:.1f} s")


if __name__ == "__main__":
    main()
