"""Quickstart: CE-FL on a synthetic edge network in ~a minute on CPU.

One declarative spec — the registered ``quickstart`` preset — builds the
6-UE / 3-BS / 2-DC network, streams non-iid online data to the UEs, lets
the network-aware solver pick offloading + the floating aggregation DC
each round, and trains the paper's image classifier cooperatively at
UEs+DCs (docs/experiments.md).  Equivalent CLI:

  PYTHONPATH=src python -m repro.experiments run quickstart

This script is the library-API version of the same run:

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import experiments


def main():
    spec = experiments.get_experiment("quickstart")
    print(f"spec: {spec.name} — {spec.network.num_ue} UEs / "
          f"{spec.network.num_bs} BSs / {spec.network.num_dc} DCs, "
          f"strategy={spec.strategy}, {spec.engine.rounds} rounds")
    print("\nround  acc    loss   aggregator  energy(J)  delay(s)")

    def show(r):
        print(f"{r.round:5d}  {r.acc:.3f}  {r.loss:.3f}  "
              f"DC{r.aggregator:<9d} {r.energy:9.2f} {r.delay:9.2f}")

    result = experiments.run(spec, callbacks=(show,))

    final = result.final
    print(f"\nfinal accuracy {final.acc:.3f}; "
          f"total energy {final.cum_energy:.1f} J, "
          f"total delay {final.cum_delay:.1f} s")


if __name__ == "__main__":
    main()
