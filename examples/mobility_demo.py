"""Mobility demo: watch the floating aggregation point actually float.

Runs the same 20-round ``campus_walk`` scenario (random-waypoint UE
mobility -> fresh Shannon rates -> handovers -> data re-concentration)
under the network-aware ``cefl`` strategy and under a ``fixed:0``
baseline.  CE-FL's aggregation point migrates to chase the data/rate
concentration while the baseline stays put; every handover and migration
is recorded on the per-round :class:`~repro.core.api.RoundReport`.

  PYTHONPATH=src python examples/mobility_demo.py
  PYTHONPATH=src python examples/mobility_demo.py --scenario vehicular
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import Engine, EngineOptions, MLConstants
from repro.data import make_image_dataset, make_online_ues
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.scenario import available_scenarios
from repro.solver import ObjectiveWeights


def run_one(strategy, scenario, net, data, consts, ow, rounds, seed):
    (trx, tr_y), (tex, te_y) = data
    ccfg = ClassifierConfig(input_shape=trx.shape[1:], hidden=(32,))
    p0 = init_classifier_params(jax.random.PRNGKey(0), ccfg)
    ues = make_online_ues(trx, tr_y, num_ue=net.cfg.num_ue,
                          mean_arrivals=300, std_arrivals=30, seed=seed)
    engine = Engine(net, strategy, consts=consts, ow=ow, scenario=scenario,
                    opts=EngineOptions(rounds=rounds, eta=0.1,
                                       solver_outer=2, reoptimize_every=1,
                                       seed=seed))
    return engine.run(
        ues, init_params=p0, loss_fn=classifier_loss,
        eval_fn=lambda p: classifier_accuracy(
            p, jnp.asarray(tex[:400]), jnp.asarray(te_y[:400])))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--scenario", default="campus_walk",
                    choices=available_scenarios())
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    net = make_network(NetworkConfig(num_ue=8, num_bs=4, num_dc=3))
    data = make_image_dataset(6000, (14, 14, 1))
    nd = net.cfg.num_ue + net.cfg.num_dc
    consts = MLConstants(L=4.0, theta_i=np.full(nd, 2.0),
                         sigma_i=np.ones(nd), zeta1=2.0, zeta2=1.0)
    ow = ObjectiveWeights(T=args.rounds)

    results = {}
    for strat in ("cefl", "fixed:0"):
        print(f"== {strat} under scenario {args.scenario!r} ==")
        res = run_one(strat, args.scenario, net, data, consts, ow,
                      args.rounds, args.seed)
        results[strat] = res
        print("round | agg DC | moved | handovers           | active UEs")
        for r in res.reports:
            ho = " ".join(f"{u}:{a}->{b}" for u, a, b in r.handovers)
            print(f"{r.round:5d} | DC {r.aggregator}   | "
                  f"{'MOVE ' if r.aggregator_moved else '  .  '} | "
                  f"{ho:19s} | {r.active_ues}")
        print()

    cefl, fixed = results["cefl"], results["fixed:0"]
    migrations = sum(r.aggregator_moved for r in cefl.reports)
    handovers = sum(len(r.handovers) for r in cefl.reports)
    print(f"cefl:    {migrations} aggregation-point migrations, "
          f"{handovers} UE handovers, final acc {cefl.final.acc:.3f}")
    print(f"fixed:0: {sum(r.aggregator_moved for r in fixed.reports)} "
          f"migrations (stays at DC 0), final acc {fixed.final.acc:.3f}")
    assert migrations >= 1, "expected the floating aggregator to migrate"
    assert handovers >= 1, "expected at least one UE handover"
    assert not any(r.aggregator_moved for r in fixed.reports)
    print("OK: the aggregation point floats under cefl and stays put "
          "under fixed:0")


if __name__ == "__main__":
    main()
