"""Mobility demo: watch the floating aggregation point actually float.

Runs the registered ``campus_walk_vs_fixed`` spec (random-waypoint UE
mobility -> fresh Shannon rates -> handovers -> data re-concentration)
under the network-aware ``cefl`` strategy and under a ``fixed:0``
baseline — two cells of one declarative spec grid.  CE-FL's aggregation
point migrates to chase the data/rate concentration while the baseline
stays put; every handover and migration is recorded on the per-round
:class:`~repro.core.api.RoundReport`.

  PYTHONPATH=src python examples/mobility_demo.py
  PYTHONPATH=src python examples/mobility_demo.py --scenario vehicular
"""
import argparse

from repro import experiments as E
from repro.scenario import available_scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--scenario", default="campus_walk",
                    choices=available_scenarios())
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    base = E.get_experiment("campus_walk_vs_fixed").override(**{
        "scenario": args.scenario, "engine.rounds": args.rounds,
        "seeds": (args.seed,)})
    specs = [base.override(**{"name": "cefl", "strategy": "cefl"}),
             base.override(**{"name": "fixed", "strategy": "fixed:0"})]
    results = {}
    for spec in specs:
        print(f"== {spec.strategy} under scenario {args.scenario!r} ==")
        res = E.sweep(spec, executor="sequential").result(args.seed)
        results[spec.name] = res
        print("round | agg DC | moved | handovers           | active UEs")
        for r in res.reports:
            ho = " ".join(f"{u}:{a}->{b}" for u, a, b in r.handovers)
            print(f"{r.round:5d} | DC {r.aggregator}   | "
                  f"{'MOVE ' if r.aggregator_moved else '  .  '} | "
                  f"{ho:19s} | {r.active_ues}")
        print()

    cefl, fixed = results["cefl"], results["fixed"]
    migrations = sum(r.aggregator_moved for r in cefl.reports)
    handovers = sum(len(r.handovers) for r in cefl.reports)
    print(f"cefl:    {migrations} aggregation-point migrations, "
          f"{handovers} UE handovers, final acc {cefl.final.acc:.3f}")
    print(f"fixed:0: {sum(r.aggregator_moved for r in fixed.reports)} "
          f"migrations (stays at DC 0), final acc {fixed.final.acc:.3f}")
    assert migrations >= 1, "expected the floating aggregator to migrate"
    assert handovers >= 1, "expected at least one UE handover"
    assert not any(r.aggregator_moved for r in fixed.reports)
    print("OK: the aggregation point floats under cefl and stays put "
          "under fixed:0")


if __name__ == "__main__":
    main()
