"""Train an LM with the mesh-native CE-FL round (thin wrapper over the
launcher, which drives the engine's MeshExecutor round step).  With no
flags this trains the reduced mamba2 smoke model; the full 130M run is the
assignment's "~100M model for a few hundred steps":

  PYTHONPATH=src python examples/train_lm_cefl.py                  # smoke
  PYTHONPATH=src python examples/train_lm_cefl.py --full           # 130M
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full mamba2-130m (~130M params), 200 rounds — "
                         "hours on CPU, minutes on accelerators")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    if args.full:
        argv = ["--arch", "mamba2-130m", "--steps",
                str(args.steps or 200), "--batch", "8", "--seq", "512",
                "--n-dpu", "2", "--gamma", "2",
                "--checkpoint", "results/ckpt_mamba2_cefl"]
    else:
        argv = ["--arch", "mamba2-130m", "--reduced", "--steps",
                str(args.steps or 30), "--batch", "8", "--seq", "256",
                "--n-dpu", "2", "--gamma", "2"]
    train_main(argv)


if __name__ == "__main__":
    main()
