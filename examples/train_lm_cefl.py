"""Train an LM with the mesh-native CE-FL round — the ``lm_smoke`` /
``lm_mamba2_130m`` presets run through the spec API.  With no flags this
trains the reduced mamba2 smoke model; the full 130M run is the
assignment's "~100M model for a few hundred steps":

  PYTHONPATH=src python examples/train_lm_cefl.py                  # smoke
  PYTHONPATH=src python examples/train_lm_cefl.py --full           # 130M

Equivalent CLI:  PYTHONPATH=src python -m repro.experiments run lm_smoke
"""
import argparse

from repro.experiments import get_experiment
from repro.experiments.lm import run_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full mamba2-130m (~130M params), 200 rounds — "
                         "hours on CPU, minutes on accelerators")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    if args.full:
        spec = get_experiment("lm_mamba2_130m")
        if args.steps:
            spec = spec.override(**{"engine.rounds": args.steps})
        run_lm(spec, checkpoint="results/ckpt_mamba2_cefl")
    else:
        spec = get_experiment("lm_smoke").override(
            **{"engine.rounds": args.steps or 30, "model.gamma": 2})
        run_lm(spec)


if __name__ == "__main__":
    main()
