"""Serve a small LM with batched requests: prefill the prompt batch, then
step the batched decode loop (greedy sampling) — the serving path that
decode_32k / long_500k lower on the production mesh.

  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m --reduced
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m  # full 130M
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import lm as L


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch={args.batch}")
    key, k_prompt, k_enc = jax.random.split(jax.random.PRNGKey(0), 3)
    params = L.init_lm_params(key, cfg, jnp.float32)
    prompts = jax.random.randint(k_prompt, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(k_enc, (args.batch, cfg.encoder_seq,
                                        cfg.d_model)) * 0.1

    t0 = time.time()
    logits, cache = L.prefill(params, cfg, prompts, cache_len=args.cache_len,
                              enc_embed=enc)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} tokens "
          f"in {time.time()-t0:.2f}s")

    step = jax.jit(lambda tok, c: L.lm_decode_step(params, cfg, tok, c))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step(tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = jnp.stack(generated, axis=1)
    print(f"[serve] generated {args.gen} tokens/seq in {dt:.2f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s batched)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {list(map(int, toks[b]))}")


if __name__ == "__main__":
    main()
