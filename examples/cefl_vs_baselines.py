"""End-to-end driver: parameter estimation (Algs. 4-6) -> network-aware
CE-FL vs FedNova vs FedAvg on the paper's full-size 20/10/5 network, with
per-strategy accuracy / energy / delay curves (Tables I-II style), driven
through the typed orchestration Engine (docs/orchestration.md).

  PYTHONPATH=src python examples/cefl_vs_baselines.py [--rounds 20] [--full]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import Engine, EngineOptions
from repro.core.estimation import estimate_constants
from repro.data import make_image_dataset, make_online_ues
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--full", action="store_true",
                    help="paper-size network (20 UE / 10 BS / 5 DC) and "
                         "28x28 images")
    args = ap.parse_args()

    if args.full:
        n_ue, n_bs, n_dc, img, hidden, arrivals = 20, 10, 5, (28, 28, 1), \
            (200, 100), 2000
    else:
        n_ue, n_bs, n_dc, img, hidden, arrivals = 8, 4, 3, (14, 14, 1), \
            (64,), 400
    net = make_network(NetworkConfig(num_ue=n_ue, num_bs=n_bs, num_dc=n_dc))
    (trx, tr_y), (tex, te_y) = make_image_dataset(20000, img)
    cfg = ClassifierConfig(input_shape=img, hidden=hidden)
    p0 = init_classifier_params(jax.random.PRNGKey(0), cfg)

    print("[1/3] one-shot parameter estimation (Algs. 4-6) ...")
    probe_ues = make_online_ues(trx, tr_y, num_ue=n_ue,
                                mean_arrivals=arrivals,
                                std_arrivals=arrivals / 10, seed=99)
    consts = estimate_constants(classifier_loss, p0,
                                [ds.step() for ds in probe_ues],
                                key=jax.random.PRNGKey(7), iters=3)
    # Theta/sigma are estimated per UE; the solver wants one entry per DPU
    # (N+S) — DC data is a mixture of offloaded UE data, so use UE means
    consts = dataclasses.replace(
        consts,
        theta_i=np.concatenate([consts.theta_i,
                                np.full(n_dc, consts.theta_i.mean())]),
        sigma_i=np.concatenate([consts.sigma_i,
                                np.full(n_dc, consts.sigma_i.mean())]))
    print(f"    L={consts.L:.2f} zeta1={consts.zeta1:.2f} "
          f"zeta2={consts.zeta2:.2f} Theta~{consts.theta_i.mean():.2f} "
          f"sigma~{consts.sigma_i.mean():.2f}")

    print("[2/3] running CE-FL and baselines ...")
    results = {}
    for strat in ("cefl", "fednova", "fedavg"):
        ues = make_online_ues(trx, tr_y, num_ue=n_ue,
                              mean_arrivals=arrivals,
                              std_arrivals=arrivals / 10)
        engine = Engine(
            net, strat, consts=consts, ow=ObjectiveWeights(T=args.rounds),
            opts=EngineOptions(rounds=args.rounds, eta=0.1,
                               solver_outer=3, reoptimize_every=3))
        res = engine.run(
            ues, init_params=p0, loss_fn=classifier_loss,
            eval_fn=lambda p: classifier_accuracy(
                p, jnp.asarray(tex[:1000]), jnp.asarray(te_y[:1000])))
        results[strat] = res
        print(f"    {strat:8s} acc {res.final.acc:.3f}  "
              f"loss {res.final.loss:.3f}  "
              f"E {res.final.cum_energy:9.1f} J  "
              f"delay {res.final.cum_delay:8.1f} s")

    print("[3/3] summary (CE-FL savings vs baselines at final round):")
    for base in ("fednova", "fedavg"):
        e0 = results[base].final.cum_energy
        e1 = results["cefl"].final.cum_energy
        print(f"    energy vs {base}: {100 * (1 - e1 / e0):+.1f}%")


if __name__ == "__main__":
    main()
