"""End-to-end driver: parameter estimation (Algs. 4-6) -> network-aware
CE-FL vs FedNova vs FedAvg with per-strategy accuracy / energy / delay
(Tables I-II style) — expressed as a declarative spec grid: one base
spec (estimated constants included), three strategy overrides, one
``experiments.sweep`` call.

  PYTHONPATH=src python examples/cefl_vs_baselines.py [--rounds 20] [--full]
"""
import argparse

from repro import experiments as E
from repro.experiments.spec import (ConstsSpec, DataSpec, EngineSpec,
                                    ExperimentSpec, ModelSpec, NetworkSpec)


def base_spec(full: bool, rounds: int) -> ExperimentSpec:
    if full:
        net, img, hidden, arrivals = (20, 10, 5), (28, 28, 1), \
            (200, 100), 2000.0
    else:
        net, img, hidden, arrivals = (8, 4, 3), (14, 14, 1), (64,), 400.0
    return ExperimentSpec(
        name="cefl_vs_baselines",
        model=ModelSpec(input_shape=img, hidden=hidden),
        data=DataSpec(pool=20000, mean_arrivals=arrivals,
                      std_arrivals=arrivals / 10, eval_examples=1000),
        network=NetworkSpec(num_ue=net[0], num_bs=net[1], num_dc=net[2]),
        consts=ConstsSpec(mode="estimate", estimate_iters=3),
        engine=EngineSpec(rounds=rounds, eta=0.1, solver_outer=3,
                          reoptimize_every=3),
        strategy="cefl", scenario="static", seeds=(0,))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--full", action="store_true",
                    help="paper-size network (20 UE / 10 BS / 5 DC) and "
                         "28x28 images")
    args = ap.parse_args()

    base = base_spec(args.full, args.rounds)
    print("[1/3] building context (one-shot Algs. 4-6 estimation) ...")
    ctx = E.build_context(base)
    c = ctx.consts
    print(f"    L={c.L:.2f} zeta1={c.zeta1:.2f} zeta2={c.zeta2:.2f} "
          f"Theta~{c.theta_i.mean():.2f} sigma~{c.sigma_i.mean():.2f}")

    print("[2/3] running CE-FL and baselines ...")
    specs = [base.override(**{"name": strat, "strategy": strat})
             for strat in ("cefl", "fednova", "fedavg")]
    result = E.sweep(specs, executor="sequential")
    finals = {}
    for strat in ("cefl", "fednova", "fedavg"):
        res = result.result(0, strat)
        finals[strat] = res.final
        print(f"    {strat:8s} acc {res.final.acc:.3f}  "
              f"loss {res.final.loss:.3f}  "
              f"E {res.final.cum_energy:9.1f} J  "
              f"delay {res.final.cum_delay:8.1f} s")

    print("[3/3] summary (CE-FL savings vs baselines at final round):")
    for baseline in ("fednova", "fedavg"):
        e0 = finals[baseline].cum_energy
        e1 = finals["cefl"].cum_energy
        print(f"    energy vs {baseline}: {100 * (1 - e1 / e0):+.1f}%")


if __name__ == "__main__":
    main()
