"""Roofline term computation (TPU v5e targets) from dry-run artifacts.

  compute    = HLO_FLOPs / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = collective_bytes / (chips * 50e9 B/s per ICI link)

MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for training;
2*N*D for single-token decode; 2*N*D_prompt for prefill.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    bytes_per_device: float = 0.0

    @property
    def compute_s(self):
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self):
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self):
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self):
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def bound_step_time(self):
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu_upper_bound(self):
        """Model-FLOPs utilization if the dominant term were the step time."""
        return self.model_flops / (self.bound_step_time * self.chips
                                   * PEAK_FLOPS + 1e-30)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_upper_bound": self.mfu_upper_bound,
            "bytes_per_device": self.bytes_per_device,
        }


def model_flops_for(cfg, shape, gamma: int = 1) -> float:
    """Analytic MODEL_FLOPS per lowered step."""
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens * gamma
    if shape.mode == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
