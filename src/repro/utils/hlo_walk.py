"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so scanned
layer stacks / microbatch accumulation / blocked attention are massively
under-counted.  Optimized HLO annotates ``known_trip_count`` on while ops;
this walker parses the HLO text, builds the computation call graph, and
returns loop-amplified totals:

  flops        — 2 * prod(output dims) * prod(contracting dims) per dot
  bytes        — per-instruction operand+output buffer traffic, fusions
                 counted at their boundary (inner ops are loop-local)
  collectives  — operand bytes per collective kind, amplified

Elementwise FLOPs outside dots are ignored (<5% for these models); both the
raw cost_analysis numbers and these amplified numbers are reported in
EXPERIMENTS.md so the amplification factor is visible.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s+(%[\w.\-]+|ROOT\s+%[\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_NAME_RE = re.compile(r"%[\w.\-]+")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "conditional", "call", "after-all",
                   "partition-id", "replica-id"}

# TPU-fusion-adjusted byte accounting: only ops that move data through HBM
# on a fused TPU program are charged.  Unfused elementwise chains in the
# CPU-compiled HLO (add/mul/convert/...) would live inside fusions on TPU,
# so charging their operands would overcount HBM traffic ~10-40x (see
# EXPERIMENTS.md §Roofline notes).
_BYTES_OPS = {"dot", "fusion", "custom-call", "convolution",
              "dynamic-update-slice", "dynamic-slice", "gather", "scatter",
              "reduce", "sort", "concatenate", "pad", "slice", "reverse",
              "copy", "transpose", "cholesky", "triangular-solve",
              } | set(COLLECTIVE_KINDS)


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(shapes) -> int:
    tot = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES.get(dt, 4)
    return tot


class Instr:
    __slots__ = ("name", "op", "out_shapes", "operands", "line",
                 "called", "trip")

    def __init__(self, name, op, out_shapes, operands, line, called, trip):
        self.name, self.op = name, op
        self.out_shapes, self.operands = out_shapes, operands
        self.line, self.called, self.trip = line, called, trip


_OP_TOKEN_RE = re.compile(r"^([a-z][\w\-]*)\(")


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name = m.group(1).replace("ROOT", "").strip()
    rhs = m.group(2)
    # output shape: up to the op token.  rhs = "<shape> <op>(...)..."
    om = re.search(r"\s([a-z][\w\-]*)\(", rhs)
    if not om:
        return None
    op = om.group(1)
    out_txt = rhs[:om.start()]
    out_shapes = _shape_list(out_txt)
    # operand names: inside the op's parens (first balanced group)
    rest = rhs[om.end():]
    depth, args_end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args_end = i
                break
    operands = _NAME_RE.findall(rest[:args_end])
    attrs = rest[args_end:]
    called = []
    for key in ("body=", "calls=", "to_apply=", "branch_computations="):
        for mm in re.finditer(re.escape(key) + r"\{?([^,)}\s]+)", attrs):
            for nm in _NAME_RE.findall(mm.group(0)):
                called.append((key[:-1], nm))
    trip = None
    tm = _TRIP_RE.search(line)
    if tm:
        trip = int(tm.group(1))
    return Instr(name, op, out_shapes, operands, line, called, trip)


def parse_module(hlo_text: str):
    """-> (computations: {name: [Instr]}, entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            cur = cm.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            comps[cur].append(ins)
    return comps, entry


def _dot_flops(ins: Instr, shapes: Dict[str, list]) -> float:
    out_elems = 1
    for _, dims in ins.out_shapes:
        for d in dims:
            out_elems *= d
    lhs_entry = shapes.get(ins.operands[0]) if ins.operands else None
    lhs = lhs_entry[0][1] if lhs_entry else None
    contract = 1
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    if cm and lhs:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs):
                contract *= lhs[int(idx)]
    return 2.0 * out_elems * contract


def amplified_costs(hlo_text: str) -> Dict:
    comps, entry = parse_module(hlo_text)
    # symbol tables: output shapes (dtype, dims) per instruction name
    tables = {}
    for cname, instrs in comps.items():
        t = {}
        for ins in instrs:
            if ins.out_shapes:
                t[ins.name] = ins.out_shapes
        tables[cname] = t

    memo = {}
    unknown_trips = []

    def cost(cname: str) -> Dict:
        if cname in memo:
            return memo[cname]
        flops = 0.0
        nbytes = 0.0
        coll = defaultdict(float)
        table = tables.get(cname, {})
        for ins in comps.get(cname, []):
            base_kind = ins.op.replace("-start", "").replace("-done", "")
            if ins.op.endswith("-done"):
                continue
            if ins.op == "dot":
                flops += _dot_flops(ins, table)
            if base_kind in COLLECTIVE_KINDS:
                ob = sum(_nbytes(table[o]) for o in ins.operands
                         if o in table)
                if ob == 0:
                    ob = _nbytes(ins.out_shapes)
                coll[base_kind] += ob
            if base_kind in _BYTES_OPS:
                opnd_bytes = sum(_nbytes(table[o]) for o in ins.operands
                                 if o in table)
                nbytes += opnd_bytes + _nbytes(ins.out_shapes)
            mult = 1
            for kind, sub in ins.called:
                if sub == cname or sub not in comps:
                    continue
                sub_cost = cost(sub)
                if kind == "body":
                    mult = ins.trip if ins.trip else 1
                    if ins.trip is None:
                        unknown_trips.append(ins.name)
                elif kind == "to_apply":
                    continue     # scalar reducers: negligible
                else:
                    mult = 1
                flops += mult * sub_cost["flops"]
                nbytes += mult * sub_cost["bytes"]
                for k, v in sub_cost["collectives"].items():
                    coll[k] += mult * v
        memo[cname] = {"flops": flops, "bytes": nbytes,
                       "collectives": dict(coll)}
        return memo[cname]

    total = cost(entry) if entry else {"flops": 0, "bytes": 0,
                                       "collectives": {}}
    total = dict(total)
    total["collective_bytes_total"] = sum(total["collectives"].values())
    total["unknown_trip_counts"] = unknown_trips
    return total
