"""HLO text analysis: collective-byte accounting for the roofline report.

``cost_analysis()`` gives FLOPs and memory traffic but not collective bytes;
we parse the compiled HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def shape_bytes(shape_str: str) -> int:
    """'bf16[128,1024]' -> bytes."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of operand bytes per collective kind (plus 'total').

    Counts each op's *operand* sizes (the data entering the collective).
    -start/-done pairs are counted once (on -start; plain ops directly).
    """
    totals = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # counted at -start
        kind = m.group(1)
        # operand shapes: everything inside the call parens
        call = line[m.end():]
        depth = 1
        args = []
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = call[:i]
                    break
        nbytes = sum(shape_bytes(s.group(0))
                     for s in _SHAPE_RE.finditer(args if isinstance(args, str)
                                                 else ""))
        totals[kind] += nbytes
        counts[kind] += 1
    out = dict(totals)
    out["total"] = sum(totals.values())
    out["counts"] = dict(counts)
    return out


def collective_summary(hlo_text: str) -> str:
    cb = collective_bytes(hlo_text)
    parts = [f"{k}: {v/1e9:.3f} GB (n={cb['counts'].get(k, 0)})"
             for k, v in sorted(cb.items())
             if k not in ("total", "counts") and v]
    return "; ".join(parts) + f" | total {cb['total']/1e9:.3f} GB"
