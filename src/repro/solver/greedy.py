"""Baseline aggregator-selection strategies (paper Sec. VI-B2):

  * datapoint greedy — pick the DC whose subnetwork holds the most datapoints
  * data-rate greedy — pick the DC with the best average end-to-end UE->DC
    rate (eq. 100)
  * fixed — always the same DC

Each returns a full decision dict: the non-aggregation variables come from a
shared heuristic (offload proportionally to uplink rate; best-rate BS
associations), so comparisons isolate the aggregator choice.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.network.costs import network_costs
from repro.solver import variables as V


def heuristic_base(net, D_bar, offload_frac: float = 0.5) -> Dict:
    """Non-aggregation decisions shared by all greedy baselines."""
    N, B, S = net.dims
    w = V.init_w(net, D_bar)
    up = np.asarray(net.R_nb)
    rho_nb = offload_frac * up / up.sum(axis=1, keepdims=True)
    rho_bs = np.asarray(net.R_bs_max) / np.asarray(
        net.R_bs_max).sum(axis=1, keepdims=True)
    w = dict(w)
    w["rho_nb"] = jnp.asarray(rho_nb)
    w["rho_bs"] = jnp.asarray(rho_bs)
    w["I_nb"] = jax.nn.one_hot(jnp.argmax(jnp.asarray(up), axis=1), B)
    w["I_bn"] = jax.nn.one_hot(jnp.argmax(jnp.asarray(net.R_bn), axis=0), B).T
    w["R_bs"] = jnp.asarray(net.R_bs_max) * 0.9
    w = V.project(w, net)
    return w


def _with_aggregator(w, net, D_bar, s_idx: int) -> Dict:
    S = net.cfg.num_dc
    w = dict(w)
    w["I_s"] = jax.nn.one_hot(jnp.asarray(s_idx), S)
    c = network_costs(w, net, D_bar)
    w["delta_A"] = c["delta_A_req"]
    w["delta_R"] = c["delta_R_req"]
    return w


def subnet_datapoints(net, D_bar) -> np.ndarray:
    """Datapoints per DC subnetwork (UEs assigned by subnet_of_ue)."""
    return np.bincount(np.asarray(net.subnet_of_ue),
                       weights=np.asarray(D_bar, np.float64),
                       minlength=net.cfg.num_dc)


def e2e_rate(net) -> np.ndarray:
    """eq. (100): R^{E2E}_{n,s} = max_b 1/(1/R_nb + 1/R_bs_max)."""
    inv = 1.0 / np.asarray(net.R_nb)[:, :, None] \
        + 1.0 / np.asarray(net.R_bs_max)[None, :, :]
    return (1.0 / inv).max(axis=1)          # (N, S)


def datapoint_greedy(net, D_bar, base=None) -> Dict:
    base = base if base is not None else heuristic_base(net, D_bar)
    s = int(np.argmax(subnet_datapoints(net, D_bar)))
    return _with_aggregator(base, net, D_bar, s)


def rate_greedy(net, D_bar, base=None) -> Dict:
    base = base if base is not None else heuristic_base(net, D_bar)
    s = int(np.argmax(e2e_rate(net).mean(axis=0)))
    return _with_aggregator(base, net, D_bar, s)


def fixed_aggregator(net, D_bar, s_idx: int, base=None) -> Dict:
    base = base if base is not None else heuristic_base(net, D_bar)
    return _with_aggregator(base, net, D_bar, s_idx)
