"""Non-convex constraint vector C(w) <= 0 of problem P (dualized in Alg. 2):

  * delay coupling (50)-(53): per-UE / per-DC aggregation-path delays must
    fit within the delta^A / delta^R decision variables
  * binary enforcement (63)-(65) on the relaxed indicators

Convex constraints (boxes / simplexes, eqs. 45-49, 54-62) live in the
projection sets D_d (variables.project).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.network import costs as C


def constraint_vector(w, net, D_bar):
    """Concatenated residual vector; feasibility <=> all entries <= 0."""
    costs = C.network_costs(w, net, D_bar)
    c50 = costs["d_n_A"] + costs["d_n_P"] - w["delta_A"]           # (N,)
    c51 = costs["d_s_D"] + costs["d_s_P"] + costs["d_s_A"] \
        - w["delta_A"]                                             # (S,)
    c52 = costs["d_b_R"] + costs["d_b_B"] - w["delta_R"]           # (B,)
    c53 = costs["d_s_R"] - w["delta_R"]                            # (S,)
    b63 = jnp.sum(w["I_s"] * (1 - w["I_s"]))[None]                 # (1,)
    b64 = jnp.sum(w["I_nb"] * (1 - w["I_nb"]), axis=1)             # (N,)
    b65 = jnp.sum(w["I_bn"] * (1 - w["I_bn"]), axis=0)             # (N,)
    return jnp.concatenate([c50, c51, c52, c53, b63, b64, b65])


def _dims_of(net_or_dims):
    """Accept a Network / NetView or a bare (N, B, S) tuple, so the jitted
    backend can size things from dims alone (before any net view exists)."""
    if isinstance(net_or_dims, tuple):
        return net_or_dims
    return net_or_dims.dims


def num_constraints(net):
    N, B, S = _dims_of(net)
    return N + S + B + S + 1 + N + N


def constraint_scale(net):
    """Row scaling for conditioning: delay rows are O(10-100) seconds, the
    binary-enforcement rows are O(1)."""
    N, B, S = _dims_of(net)
    return jnp.concatenate([
        jnp.full((N + S,), 1e-2),      # (50)-(51) vs delta_A
        jnp.full((B + S,), 1e-1),      # (52)-(53) vs delta_R
        jnp.ones((1 + 2 * N,)),        # (63)-(65)
    ])


def max_violation(w, net, D_bar) -> float:
    return float(jnp.max(constraint_vector(w, net, D_bar)))
