"""Algorithm 3: iterative decentralized consensus on the dual variables over
the communication graph H (Sec. V), with Xiao-Boyd constant edge weights
W_dd' = z, W_dd = 1 - z * degree(d), z < 1 / max_degree.
"""
from __future__ import annotations

import numpy as np


def consensus_weights(adjacency: np.ndarray, z_hat: float = 1e-3):
    """Doubly-stochastic weight matrix per the paper's construction."""
    A = np.asarray(adjacency, dtype=np.float64)
    V = A.shape[0]
    deg = A.sum(axis=1)
    z = min(1.0 / V, 1.0 / (deg.max() + 1.0)) - z_hat
    z = max(z, 1e-6)
    W = z * A
    np.fill_diagonal(W, 1.0 - z * deg)
    return W


def consensus_rounds(values: np.ndarray, W: np.ndarray, J: int):
    """values: (V, ...) per-node copies; J averaging rounds (eq. 99)."""
    out = np.asarray(values, dtype=np.float64)
    flat = out.reshape(out.shape[0], -1)
    for _ in range(J):
        flat = W @ flat
    return flat.reshape(out.shape)


def consensus_error(values: np.ndarray) -> float:
    """Max deviation from the global average (diagnostic)."""
    flat = np.asarray(values).reshape(values.shape[0], -1)
    return float(np.abs(flat - flat.mean(axis=0, keepdims=True)).max())
