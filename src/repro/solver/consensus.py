"""Algorithm 3: iterative decentralized consensus on the dual variables over
the communication graph H (Sec. V), with Xiao-Boyd constant edge weights
W_dd' = z, W_dd = 1 - z * degree(d), z < 1 / max_degree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _normalize_adjacency(adjacency: np.ndarray) -> np.ndarray:
    """Undirected simple-graph view of an adjacency matrix: symmetrized
    (an edge in either direction counts) and self-loop free.  Without this,
    a self-loop or a one-directional edge breaks the double stochasticity
    of the Xiao-Boyd weights (column sums drift from 1), so consensus would
    no longer preserve the network-wide dual average."""
    A = (np.asarray(adjacency, dtype=np.float64) != 0).astype(np.float64)
    A = np.maximum(A, A.T)
    np.fill_diagonal(A, 0.0)
    return A


def consensus_weights(adjacency: np.ndarray, z_hat: float = 1e-3):
    """Doubly-stochastic weight matrix per the paper's construction."""
    A = _normalize_adjacency(adjacency)
    V = A.shape[0]
    deg = A.sum(axis=1)
    z = min(1.0 / V, 1.0 / (deg.max() + 1.0)) - z_hat
    z = max(z, 1e-6)
    W = z * A
    np.fill_diagonal(W, 1.0 - z * deg)
    return W


def consensus_rounds(values: np.ndarray, W: np.ndarray, J: int):
    """values: (V, ...) per-node copies; J averaging rounds (eq. 99)."""
    out = np.asarray(values, dtype=np.float64)
    flat = out.reshape(out.shape[0], -1)
    for _ in range(J):
        flat = W @ flat
    return flat.reshape(out.shape)


def consensus_scan(values: jnp.ndarray, W: jnp.ndarray, J: int):
    """Jit-friendly :func:`consensus_rounds`: the J mixing rounds run as a
    single ``lax.scan`` over the (traced) weight matrix, so the whole
    consensus phase is one XLA while-op instead of J host-side matmuls.
    ``J`` must be static (it keys the jit cache via the enclosing trace)."""
    vals = jnp.asarray(values)
    flat = vals.reshape(vals.shape[0], -1)

    def mix(x, _):
        return W @ x, None

    out, _ = jax.lax.scan(mix, flat, None, length=J)
    return out.reshape(vals.shape)


def consensus_error(values) -> float:
    """Max deviation from the global average (diagnostic)."""
    flat = np.asarray(values).reshape(values.shape[0], -1)
    return float(np.abs(flat - flat.mean(axis=0, keepdims=True)).max())
