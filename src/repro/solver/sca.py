"""Algorithm 1: successive convex solver wrapper for network-aware CE-FL.

Each outer iteration convexifies P at w^l (proximal surrogate), solves the
surrogate with the distributed primal-dual method (Algorithm 2 + consensus
Algorithm 3), and moves w^{l+1} = w^l + zeta (w_hat - w^l) (eq. 81).

Two backends share this entry point (``solve(..., backend=...)``):

* ``"jit"`` (default) — the batched JAX path: the whole outer iteration
  (Algorithm 2 inner solve + eq.-81 step + projection + objective) is ONE
  jitted function over flat (P,) decision vectors.  Shapes are static,
  keyed only on the network dims, and every network quantity (rates,
  arrivals, consensus weights, ML constants arrays) is a *traced* argument,
  so warm-started re-solves across rounds hit the compile cache.
* ``"ref"`` — the original host-side numpy / Python-loop oracle
  (``solver/ref.py``), kept for differential testing and benchmarking.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.network.costs import network_costs
from repro.solver import constraints as K
from repro.solver import ref as _ref
from repro.solver import variables as V
from repro.solver.consensus import consensus_weights
from repro.solver.objective import (ObjectiveWeights, apply_required_deltas,
                                    objective, objective_breakdown)
from repro.solver.primal_dual import PDHyper, make_surrogate
from repro.solver.ref import SCAResult  # noqa: F401  (public re-export)

if TYPE_CHECKING:   # annotation-only: keeps repro.solver import-cycle free
    from repro.core.convergence import MLConstants

_OUTER_STEP_CACHE: Dict[tuple, callable] = {}


def jit_cache_size() -> int:
    """Number of distinct compiled outer steps (diagnostics/tests)."""
    return len(_OUTER_STEP_CACHE)


def _consts_scalars(consts: MLConstants):
    return (float(consts.L), float(consts.zeta1), float(consts.zeta2),
            float(consts.F0_gap))


def _outer_step(dims, hyper: PDHyper, ow: ObjectiveWeights, cs,
                distributed: bool, zeta: float, gamma_cap: float = 20.0):
    """The jitted SCA outer iteration for static (dims, hyper, ow, zeta)."""
    from repro.core.convergence import MLConstants  # local: avoids cycle
    key = (tuple(dims), hyper, ow, cs, distributed, float(zeta), gamma_cap)
    if key in _OUTER_STEP_CACHE:
        return _OUTER_STEP_CACHE[key]
    spec = V.WSpec(dims)
    surrogate = make_surrogate(spec, hyper, ow, cs, distributed=distributed,
                               gamma_cap=gamma_cap)
    L_s, zeta1_s, zeta2_s, f0_s = cs

    def step(w, Lambda, net, D_bar, theta_i, sigma_i, scale_flat, W_cons):
        consts = MLConstants(L=L_s, theta_i=theta_i, sigma_i=sigma_i,
                             zeta1=zeta1_s, zeta2=zeta2_s, F0_gap=f0_s)
        w_hat, Lambda, _, max_viol = surrogate(
            w, Lambda, net, D_bar, theta_i, sigma_i, scale_flat, W_cons)
        w_new = w + zeta * (w_hat - w)                          # eq. (81)
        w_phys = V.project(spec.unflatten(w_new * scale_flat), net,
                           gamma_cap=gamma_cap)
        w_phys = apply_required_deltas(w_phys, net, D_bar)
        obj = objective(w_phys, net, D_bar, consts, ow)
        return spec.flatten(w_phys) / scale_flat, Lambda, obj, max_viol

    _OUTER_STEP_CACHE[key] = jax.jit(step)
    return _OUTER_STEP_CACHE[key]


def _solve_jit(net, D_bar, consts: MLConstants, ow: ObjectiveWeights,
               *, zeta: float, max_outer: int, tol: float,
               pd: PDHyper, distributed: bool,
               w0: Optional[Dict]) -> SCAResult:
    spec = V.WSpec(net.dims)
    nv = V.NetView.from_network(net)
    scaler = V.Scaler(net)
    scale_flat = scaler.flat(spec)
    D_j = jnp.asarray(D_bar, jnp.float32)
    theta_i = jnp.asarray(consts.theta_i, jnp.float32)
    sigma_i = jnp.asarray(consts.sigma_i, jnp.float32)
    n_nodes = net.node_count() if distributed else 1
    W_cons = jnp.asarray(consensus_weights(net.adjacency), jnp.float32) \
        if distributed else jnp.zeros((1, 1), jnp.float32)
    Lambda = jnp.zeros((n_nodes, K.num_constraints(spec.dims)), jnp.float32)

    # feasible start — same construction as the oracle (host-side, once)
    w_phys = V.project(w0 if w0 is not None else V.init_w(net, D_bar), net)
    w_phys = apply_required_deltas(w_phys, net, D_bar, slack=1.05)
    w = spec.flatten(w_phys) / scale_flat

    step = _outer_step(spec.dims, pd, ow, _consts_scalars(consts),
                       distributed, zeta)
    hist = [float(objective(w_phys, net, D_bar, consts, ow))]
    viol = []
    ell = 0
    for ell in range(max_outer):
        w, Lambda, obj, max_viol = step(w, Lambda, nv, D_j, theta_i,
                                        sigma_i, scale_flat, W_cons)
        obj = float(obj)
        viol.append(float(max_viol))
        improved = hist[-1] - obj
        hist.append(obj)
        if 0 <= improved < tol * max(1.0, abs(hist[0])):
            break
    w_phys = spec.unflatten(w * scale_flat)
    w_rounded = V.round_indicators(w_phys)
    c = network_costs(w_rounded, net, D_bar)
    w_rounded["delta_A"] = c["delta_A_req"]
    w_rounded["delta_R"] = c["delta_R_req"]
    return SCAResult(
        w=w_phys, w_rounded=w_rounded, objective_history=hist,
        violation_history=viol,
        breakdown=objective_breakdown(w_rounded, net, D_bar, consts, ow),
        iterations=ell + 1)


def select_aggregator(w: Dict, net, D_bar, consts, ow) -> int:
    """Exact discrete rounding of the floating-aggregator indicator I_s.

    With few SCA outer iterations the relaxed I_s stays near the simplex
    interior, so argmax rounding picks a vertex by noise rather than by
    cost.  S is small (DC tier), so enumerate the S one-hot candidates —
    each with its own required delay budgets — and return the index that
    minimizes the true objective.  This is what makes the aggregation
    point actually *float* round-to-round under dynamic scenarios.
    """
    S = int(np.asarray(w["I_s"]).shape[0])
    objs = []
    for s in range(S):
        ws = dict(w)
        ws["I_s"] = jax.nn.one_hot(jnp.asarray(s), S)
        ws = apply_required_deltas(ws, net, D_bar)
        objs.append(float(objective(ws, net, D_bar, consts, ow)))
    return int(np.argmin(objs))


def solve(net, D_bar, consts: MLConstants, ow: ObjectiveWeights,
          *, zeta: float = 0.5, max_outer: int = 20, tol: float = 1e-4,
          pd: Optional[PDHyper] = None, distributed: bool = True,
          w0: Optional[Dict] = None, seed: int = 0,
          backend: str = "jit") -> SCAResult:
    """Solve problem P at the current network state.

    ``backend="jit"`` runs the batched jitted solver (static shapes keyed
    on ``net.dims``; re-solves with fresh rates / arrivals reuse the
    compiled step).  ``backend="ref"`` runs the Python-loop numpy oracle.
    """
    pd = pd or PDHyper()
    if backend == "ref":
        return _ref.solve(net, D_bar, consts, ow, zeta=zeta,
                          max_outer=max_outer, tol=tol, pd=pd,
                          distributed=distributed, w0=w0, seed=seed)
    if backend != "jit":
        raise ValueError(f"unknown solver backend {backend!r} "
                         "(expected 'jit' or 'ref')")
    if w0 is not None:
        w0 = {k: jnp.asarray(np.asarray(v), jnp.float32)
              for k, v in w0.items()}
    return _solve_jit(net, D_bar, consts, ow, zeta=zeta,
                      max_outer=max_outer, tol=tol, pd=pd,
                      distributed=distributed, w0=w0)


# ----------------------------------------------------- trace contract --

from repro.analysis.jaxpr.contracts import Program, contract  # noqa: E402


@contract(
    "solver_sca_step",
    collectives={},
    forbid_f64=False,   # outer step mixes np host constants by design
    # jnp.sort/cumsum (simplex projections) are internally jitted
    # single-eqn helpers in jax 0.4.37 — library noise, not our nesting
    fusion_allow=("sort", "cumsum"),
)
def _sca_step_contract():
    """One centralized SCA outer iteration on a 6-UE/3-BS/2-DC net."""
    from repro.core.convergence import MLConstants
    from repro.network import NetworkConfig, make_network

    net = make_network(NetworkConfig(num_ue=6, num_bs=3, num_dc=2, seed=0))
    D_bar = np.full(6, 1000.0)
    consts = MLConstants(L=4.0, theta_i=np.ones(8) * 2,
                         sigma_i=np.ones(8), zeta1=2.0, zeta2=1.0)
    ow = ObjectiveWeights()
    pd = PDHyper(max_iters=2, consensus_rounds=2)

    # mirror the _solve_jit staging (host-side, once)
    spec = V.WSpec(net.dims)
    nv = V.NetView.from_network(net)
    scale_flat = V.Scaler(net).flat(spec)
    D_j = jnp.asarray(D_bar, jnp.float32)
    theta_i = jnp.asarray(consts.theta_i, jnp.float32)
    sigma_i = jnp.asarray(consts.sigma_i, jnp.float32)
    W_cons = jnp.zeros((1, 1), jnp.float32)
    Lambda = jnp.zeros((1, K.num_constraints(spec.dims)), jnp.float32)
    w_phys = V.project(V.init_w(net, D_bar), net)
    w_phys = apply_required_deltas(w_phys, net, D_bar, slack=1.05)
    w = spec.flatten(w_phys) / scale_flat
    step = _outer_step(spec.dims, pd, ow, _consts_scalars(consts),
                       False, 0.5)
    return Program(fn=step, args=(w, Lambda, nv, D_j, theta_i, sigma_i,
                                  scale_flat, W_cons))
