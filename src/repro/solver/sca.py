"""Algorithm 1: successive convex solver wrapper for network-aware CE-FL.

Each outer iteration convexifies P at w^l (proximal surrogate), solves the
surrogate with the distributed primal-dual method (Algorithm 2 + consensus
Algorithm 3), and moves w^{l+1} = w^l + zeta (w_hat - w^l) (eq. 81).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.convergence import MLConstants
from repro.solver import variables as V
from repro.solver.consensus import consensus_weights
from repro.solver.objective import ObjectiveWeights, objective, \
    objective_breakdown
from repro.solver.primal_dual import PDHyper, solve_surrogate


@dataclasses.dataclass
class SCAResult:
    w: Dict
    w_rounded: Dict
    objective_history: list
    violation_history: list
    breakdown: dict
    iterations: int


def solve(net, D_bar, consts: MLConstants, ow: ObjectiveWeights,
          *, zeta: float = 0.5, max_outer: int = 20, tol: float = 1e-4,
          pd: Optional[PDHyper] = None, distributed: bool = True,
          w0: Optional[Dict] = None, seed: int = 0) -> SCAResult:
    pd = pd or PDHyper()
    masks = V.ownership_masks(net)
    n_nodes = len(masks) if distributed else 1
    W_cons = consensus_weights(net.adjacency) if distributed else None
    from repro.network.costs import network_costs
    from repro.solver.constraints import num_constraints
    import jax.numpy as jnp
    scaler = V.Scaler(net)
    Lambda = np.zeros((n_nodes, num_constraints(net)))
    w_phys = V.project(w0 if w0 is not None else V.init_w(net, D_bar), net)

    def with_feasible_deltas(wp, slack=1.0):
        c = network_costs(wp, net, D_bar)
        wp = dict(wp)
        wp["delta_A"] = jnp.asarray(c["delta_A_req"] * slack)
        wp["delta_R"] = jnp.asarray(c["delta_R_req"] * slack)
        return wp

    w_phys = with_feasible_deltas(w_phys, 1.05)
    w = scaler.from_phys(w_phys)

    hist, viol = [], []
    hist.append(float(objective(w_phys, net, D_bar, consts, ow)))
    for ell in range(max_outer):
        w_hat, Lambda, info = solve_surrogate(
            w, Lambda, net, D_bar, consts, ow, pd, masks,
            distributed=distributed, W_cons=W_cons, scaler=scaler)
        w_new = {k: w[k] + zeta * (w_hat[k] - w[k]) for k in w}
        w_phys = with_feasible_deltas(
            V.project(scaler.to_phys(w_new), net))
        w = scaler.from_phys(w_phys)
        obj = float(objective(w_phys, net, D_bar, consts, ow))
        viol.append(info["max_violation"])
        improved = hist[-1] - obj
        hist.append(obj)
        if 0 <= improved < tol * max(1.0, abs(hist[0])):
            break
    w_rounded = V.round_indicators(w_phys)
    c = network_costs(w_rounded, net, D_bar)
    w_rounded["delta_A"] = c["delta_A_req"]
    w_rounded["delta_R"] = c["delta_R_req"]
    return SCAResult(
        w=w_phys, w_rounded=w_rounded, objective_history=hist,
        violation_history=viol,
        breakdown=objective_breakdown(w_rounded, net, D_bar, consts, ow),
        iterations=ell + 1)
