"""Algorithm 2 (PD CE-FL): batched, jit-traceable distributed primal-dual
solution of the convexified surrogate problem P_{w^l} (eqs. 86-98).

The proximal surrogate (eqs. 82-85) has an isotropic quadratic around w^l,
so each node's partial-Lagrangian minimization (93) has the closed form

    w_d* = Proj_{D_d} [ w^l - (grad_d J + sum_c Lambda_d[c] grad_d C_c)
                               / (lambda1 + L_C * sum_c Lambda_d[c]) ]

followed by the eq.-(96) local dual ascent and Algorithm-3 consensus.

This module is the hot jitted backend.  The decision dict is solved as one
flat (P,) vector (:class:`~repro.solver.variables.WSpec`); per-node work is
expressed as

  * a ``vmap`` over the V nodes' candidate evaluations (one vjp of the
    constraint vector per dual row instead of a materialized jacobian),
  * the Algorithm-2 masked merge as a segment-structured gather over the
    flat UE->BS/DC owner index (``variables.ownership_merge``) — the dense
    (V, P) ownership matrix is never materialized,
  * the per-node convexified constraints (eqs. 84-85) as a ``vmap`` of the
    constraint linearization over on-the-fly masked diffs, with the
    quadratic terms as one ``jax.ops.segment_sum``
    (``variables.node_sq_norms``),
  * the J consensus rounds as one ``lax.scan`` (:func:`consensus_scan`),
  * the primal-dual alternations as a ``lax.while_loop`` with the same
    tol-based early exit as the oracle.

The Python-loop oracle this must agree with lives in ``solver/ref.py``
(``tests/test_solver_diff.py`` enforces parity).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.solver import constraints as K
from repro.solver import variables as V
from repro.solver.consensus import consensus_scan
from repro.solver.objective import ObjectiveWeights, objective


@dataclasses.dataclass(frozen=True)
class PDHyper:
    """Hyper-parameters of Algorithm 2 (frozen: instances key jit caches)."""
    lambda1: float = 10.0       # proximal weight (eq. 83)
    L_C: float = 10.0           # constraint Lipschitz constant (eq. 85)
    kappa: float = 0.5          # dual step (eq. 96)
    max_iters: int = 8          # primal-dual alternations
    consensus_rounds: int = 30  # J (Alg. 3)
    tol: float = 1e-4


def make_surrogate(spec: V.WSpec, hyper: PDHyper, ow: ObjectiveWeights,
                   consts_scalars, *, distributed: bool,
                   gamma_cap: float = 20.0):
    """Build the traceable Algorithm-2 body for static (dims, hyper, ow).

    ``consts_scalars``: (L, zeta1, zeta2, F0_gap) — the scalar MLConstants
    fields (static); the per-DPU theta_i / sigma_i arrays stay traced.

    Returns ``fn(w_l, Lambda, net, D_bar, theta_i, sigma_i, scale_flat,
    W_cons) -> (w_hat, Lambda', pd_iters, max_violation)`` operating on
    NORMALIZED flat vectors; every argument is traced, so one jit of ``fn``
    serves all re-solves at the same network dims.
    """
    from repro.core.convergence import MLConstants  # local: avoids cycle
    L_s, zeta1_s, zeta2_s, f0_s = consts_scalars
    lam1, L_C, kappa = hyper.lambda1, hyper.L_C, hyper.kappa
    cscale = K.constraint_scale(spec.dims)
    # The oracle's ctilde always spreads C0 over the FULL node count (the
    # per-node decomposition of eq. 84), in the centralized variant too.
    # The dense (V, P) ownership matrix is NEVER materialized here: the
    # centralized path needs only the node count, and the distributed
    # path runs the segment-sum ownership ops over the flat owner index
    # (variables.ownership_merge / owner_mask / node_sq_norms) — at
    # N=10^5 UEs the matrix would be ~1 TB.
    N_d, B_d, S_d = spec.dims
    V_nodes = N_d + B_d + S_d

    def fn(w_l, Lambda, net, D_bar, theta_i, sigma_i, scale_flat, W_cons):
        consts = MLConstants(L=L_s, theta_i=theta_i, sigma_i=sigma_i,
                             zeta1=zeta1_s, zeta2=zeta2_s, F0_gap=f0_s)

        def phys(x):
            return spec.unflatten(x * scale_flat)

        def obj_flat(x):
            return objective(phys(x), net, D_bar, consts, ow)

        def con_flat(x):
            return K.constraint_vector(phys(x), net, D_bar) * cscale

        def proj_flat(x):
            return spec.flatten(
                V.project(phys(x), net, gamma_cap=gamma_cap)) / scale_flat

        gJ = jax.grad(obj_flat)(w_l)
        C0, con_lin = jax.linearize(con_flat, w_l)
        _, con_vjp = jax.vjp(con_flat, w_l)

        def candidate(lmb):
            """Closed-form minimizer of a node's surrogate Lagrangian (93):
            Lambda_d @ JC via one vjp — the jacobian is never built."""
            denom = lam1 + L_C * jnp.sum(lmb)
            g = gJ + con_vjp(lmb)[0]
            return proj_flat(w_l - g / denom)

        def pd_iteration(Lambda):
            if distributed:
                cands = jax.vmap(candidate)(Lambda)              # (V, P)
                w_hat = proj_flat(V.ownership_merge(cands, spec.dims))
                d = w_hat - w_l
                # per-node masked diffs (rows of the old (V, P) product)
                # built on the fly inside the vmap; squared norms via one
                # segment_sum over the flat owner index
                lin = jax.vmap(
                    lambda v: con_lin(d * V.owner_mask(v, spec.dims)))(
                        jnp.arange(V_nodes))                     # (V, nC)
                sq = 0.5 * L_C * V.node_sq_norms(d, spec.dims)
                ctilde = C0 / V_nodes + lin + sq[:, None]        # (84)-(85)
                new_L = Lambda + kappa * ctilde                  # (96)
                new_L = consensus_scan(new_L, W_cons,
                                       hyper.consensus_rounds)   # Alg. 3
            else:
                w_hat = candidate(Lambda[0])
                diff = w_hat - w_l
                ctilde = C0 / V_nodes + con_lin(diff) \
                    + 0.5 * L_C * jnp.sum(diff * diff)
                new_L = Lambda + kappa * ctilde[None]            # (94)
            return w_hat, jnp.maximum(new_L, 0.0)

        def cond(carry):
            it, _, _, delta = carry
            return (it < hyper.max_iters) & (delta >= hyper.tol)

        def body(carry):
            it, Lambda, _, _ = carry
            w_hat, new_L = pd_iteration(Lambda)
            delta = jnp.max(jnp.abs(new_L - Lambda))
            return it + 1, new_L, w_hat, delta

        init = (jnp.int32(0), jnp.asarray(Lambda, jnp.float32), w_l,
                jnp.float32(jnp.inf))
        iters, Lambda_new, w_hat, _ = jax.lax.while_loop(cond, body, init)
        return w_hat, Lambda_new, iters, jnp.max(con_flat(w_hat))

    return fn
