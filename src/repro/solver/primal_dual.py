"""Algorithm 2 (PD CE-FL): iterative distributed primal-dual solution of the
convexified surrogate problem P_{w^l} (eqs. 86-98).

The proximal surrogate (eqs. 82-85) has an isotropic quadratic around w^l,
so each node's partial-Lagrangian minimization (93) has the closed form

    w_d* = Proj_{D_d} [ w^l - (grad_d J + sum_c Lambda_d[c] grad_d C_c)
                               / (lambda1 + L_C * sum_c Lambda_d[c]) ]

followed by the eq.-(96) local dual ascent and Algorithm-3 consensus.
Per the paper's variable decomposition, each node updates only its owned
block (ownership masks; the shared I_s / delta variables are co-owned by
the DCs and averaged).  Iterate exchange between rounds is simulated via
the same communication graph (see DESIGN.md §Assumptions).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.solver import constraints as K
from repro.solver import variables as V
from repro.solver.consensus import consensus_rounds, consensus_weights
from repro.solver.objective import ObjectiveWeights, objective


@dataclasses.dataclass
class PDHyper:
    lambda1: float = 10.0       # proximal weight (eq. 83)
    L_C: float = 10.0           # constraint Lipschitz constant (eq. 85)
    kappa: float = 0.5          # dual step (eq. 96)
    max_iters: int = 8          # primal-dual alternations
    consensus_rounds: int = 30  # J (Alg. 3)
    tol: float = 1e-4


def _tree_add_scaled(w, g, scale):
    return {k: w[k] - scale * g[k] for k in w}


def _masked_merge(base, candidates, masks):
    """Assemble w_hat = sum_d mask_d * cand_d (+ untouched components)."""
    out = {}
    for kname in base:
        acc = jnp.zeros_like(base[kname])
        tot = jnp.zeros_like(base[kname])
        for cand, m in zip(candidates, masks):
            acc = acc + m[kname] * cand[kname]
            tot = tot + m[kname]
        out[kname] = jnp.where(tot > 0, acc / jnp.maximum(tot, 1e-12),
                               base[kname])
    return out


def solve_surrogate(w_l: Dict, Lambda: np.ndarray, net, D_bar, consts,
                    ow: ObjectiveWeights, hyper: PDHyper, masks,
                    *, distributed: bool = True, W_cons=None,
                    scaler: Optional[V.Scaler] = None):
    """One full run of Algorithm 2 at SCA iterate w^l (NORMALIZED space).

    Lambda: (V, nC) per-node duals (or (1, nC) for the centralized variant).
    Returns (w_hat, Lambda_new, info)."""
    scaler = scaler or V.Scaler(net)
    V_nodes = len(masks)

    def obj_n(wn):
        return objective(scaler.to_phys(wn), net, D_bar, consts, ow)

    def con_n(wn):
        c = K.constraint_vector(scaler.to_phys(wn), net, D_bar)
        return c * K.constraint_scale(net)

    def project_n(wn):
        return scaler.from_phys(V.project(scaler.to_phys(wn), net,
                                          gamma_cap=scaler.gamma_cap))

    gJ = jax.grad(obj_n)(w_l)
    C0 = np.asarray(con_n(w_l))
    JC = jax.jacobian(con_n)(w_l)
    nC = C0.shape[0]
    lam1, L_C, kappa = hyper.lambda1, hyper.L_C, hyper.kappa

    def candidate(lmb):
        """Closed-form minimizer of node's surrogate Lagrangian (93)."""
        lmb_j = jnp.asarray(lmb, jnp.float32)
        denom = lam1 + L_C * jnp.sum(lmb_j)
        g = {k: gJ[k] + jnp.tensordot(lmb_j, JC[k], axes=(0, 0))
             for k in w_l}
        step = {k: w_l[k] - g[k] / denom for k in w_l}
        return project_n(step)

    def ctilde(w_hat, mask):
        """Convexified constraints at node d's block (eqs. 84-85)."""
        diff = {k: (w_hat[k] - w_l[k]) * mask[k] for k in w_l}
        lin = np.zeros(nC)
        sq = 0.0
        for k in w_l:
            jc = np.asarray(JC[k]).reshape(nC, -1)
            lin += jc @ np.asarray(diff[k]).reshape(-1)
            sq += float(jnp.sum(diff[k] ** 2))
        return C0 / V_nodes + lin + 0.5 * L_C * sq

    Lambda = np.array(Lambda, dtype=np.float64)
    history = []
    for it in range(hyper.max_iters):
        if distributed:
            cands = [candidate(Lambda[d]) for d in range(V_nodes)]
            w_hat = project_n(_masked_merge(w_l, cands, masks))
            new_L = np.stack([Lambda[d] + kappa * ctilde(w_hat, masks[d])
                              for d in range(V_nodes)])
            new_L = consensus_rounds(new_L, W_cons, hyper.consensus_rounds)
            new_L = np.maximum(new_L, 0.0)
        else:
            w_hat = candidate(Lambda[0])
            full_mask = {k: jnp.ones_like(w_l[k]) for k in w_l}
            c_full = ctilde(w_hat, full_mask) * 1.0
            # centralized (94): average of per-node contributions = global/V
            new_L = np.maximum(Lambda + kappa * c_full[None] / 1.0, 0.0)
        delta = float(np.abs(new_L - Lambda).max())
        Lambda = new_L
        history.append(delta)
        if delta < hyper.tol:
            break
    info = {"dual_delta": history,
            "max_violation": float(np.max(con_n(w_hat)))}
    return w_hat, Lambda, info
