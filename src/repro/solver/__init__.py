from repro.solver.consensus import (  # noqa: F401
    consensus_error, consensus_rounds, consensus_scan, consensus_weights,
)
from repro.solver.constraints import (  # noqa: F401
    constraint_vector, max_violation, num_constraints,
)
from repro.solver.objective import (  # noqa: F401
    ObjectiveWeights, apply_required_deltas, ml_bound, objective,
    objective_breakdown,
)
from repro.solver.primal_dual import PDHyper, make_surrogate  # noqa: F401
from repro.solver.ref import solve_surrogate  # noqa: F401  (oracle Alg. 2)
from repro.solver.sca import SCAResult, solve  # noqa: F401
from repro.solver import greedy, ref, variables  # noqa: F401
