"""Objective of problem P (paper eq. 44): ML-performance bound (term a,
replaced by the Corollary-1 / eq.-33-style bound with tau ~ delta^A+delta^R)
+ delay (term b) + weighted energies (terms c-e).  Fully differentiable jnp.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict

import jax.numpy as jnp

from repro.network import costs as C

if TYPE_CHECKING:   # annotation-only: keeps repro.solver import-cycle free
    from repro.core.convergence import MLConstants


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    xi1: float = 1.0          # ML performance weight
    xi2: float = 1e-2         # delay weight
    xi3: float = 1e-3         # energy weight
    xi3_sub: tuple = (1.0, 1.0, 1.0, 1.0, 1.0, 1.0)   # xi_{3,1..6}
    eta: float = 1e-2
    mu: float = 0.01
    theta: float = 1.0
    T: int = 50
    drift: float = 0.3        # Delta_i (Table III default)


def a_stats_jnp(gamma, eta, mu):
    r = 1.0 - eta * mu
    g = jnp.maximum(gamma, 0.5)
    if abs(r - 1.0) < 1e-12:
        return g, g, jnp.ones_like(g)
    a1 = (1.0 - r ** g) / (1.0 - r)
    a2 = (1.0 - r ** (2 * g)) / (1.0 - r ** 2)
    return a1, a2, jnp.ones_like(g)


def ml_bound(w: Dict, net, D_bar, consts: MLConstants,
             ow: ObjectiveWeights):
    """Differentiable eq.-25/33 bound as a function of the decision vars."""
    N = net.cfg.num_ue
    D_n, D_b, D_s = C.data_configuration(w, jnp.asarray(D_bar, jnp.float32))
    D_i = jnp.concatenate([D_n, D_s])
    D_i = jnp.maximum(D_i, 1.0)
    D_tot = jnp.sum(D_i)
    p_i = D_i / D_tot
    m_i = jnp.clip(w["m"], 1e-3, 1.0)
    gamma_i = jnp.maximum(w["gamma"], 0.5)
    eta, mu, theta, T = ow.eta, ow.mu, ow.theta, ow.T
    L = consts.L
    th = jnp.asarray(consts.theta_i, jnp.float32)
    sg = jnp.asarray(consts.sigma_i, jnp.float32)
    a1, a2, alast = a_stats_jnp(gamma_i, eta, mu)

    term_a = 4.0 * consts.F0_gap / (theta * eta * T)
    tau = w["delta_A"] + w["delta_R"]
    term_b = 4.0 * tau * ow.drift * (N + net.cfg.num_dc) / (theta * eta)
    noise = (p_i ** 2) * (1 - m_i) * (D_i - 1) * th ** 2 * sg ** 2 \
        / (m_i * D_i ** 2) * (a2 / a1 ** 2)
    term_c = 16.0 * eta * L * theta * jnp.sum(noise)
    inner = (1 - m_i) * (D_i - 1) * th ** 2 * sg ** 2 * p_i * gamma_i \
        / (m_i * a1 * D_i ** 2) * (a2 - alast ** 2)
    term_e = 12.0 * eta ** 2 * L ** 2 * jnp.sum(inner)
    het = jnp.max(gamma_i ** 2 * (a1 - alast) / a1)
    term_d = 12.0 * eta ** 2 * L ** 2 * consts.zeta2 * het
    return term_a + term_b + term_c + term_d + term_e


def objective(w: Dict, net, D_bar, consts: MLConstants,
              ow: ObjectiveWeights):
    """J(w): eq. (44) for one representative round."""
    costs = C.network_costs(w, net, D_bar)
    ml = ml_bound(w, net, D_bar, consts, ow)
    delay = w["delta_A"] + w["delta_R"]
    energy = C.round_energy(costs, ow.xi3_sub)
    return ow.xi1 * ml + ow.xi2 * delay + ow.xi3 * energy


def apply_required_deltas(w: Dict, net, D_bar, slack: float = 1.0) -> Dict:
    """Overwrite the delay budgets delta^A / delta^R with the realized path
    requirements (eqs. 34/40) times ``slack`` — the feasible-point
    construction shared by both solver backends and the baseline
    strategies.  Differentiable; works under jit with a traced net view."""
    c = C.network_costs(w, net, D_bar)
    w = dict(w)
    w["delta_A"] = jnp.asarray(c["delta_A_req"] * slack)
    w["delta_R"] = jnp.asarray(c["delta_R_req"] * slack)
    return w


def objective_breakdown(w, net, D_bar, consts, ow):
    costs = C.network_costs(w, net, D_bar)
    return {
        "ml": float(ml_bound(w, net, D_bar, consts, ow)),
        "delay": float(w["delta_A"] + w["delta_R"]),
        "delay_required": (float(costs["delta_A_req"]),
                           float(costs["delta_R_req"])),
        "energy": float(C.round_energy(costs, ow.xi3_sub)),
        "total": float(objective(w, net, D_bar, consts, ow)),
    }
