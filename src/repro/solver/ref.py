"""Reference (oracle) solver: the original host-side numpy / Python-loop
implementation of Algorithms 1-3 (Sec. V).

This is the pre-jit code path, kept verbatim as the differential-test oracle
for the batched jitted backend (``sca.solve(backend="jit")`` — see
``solver/sca.py`` and ``solver/primal_dual.py``).  It loops over nodes and
primal-dual iterations in Python and keeps the duals in float64 numpy; the
jit backend must agree with it on the objective (1e-4 rel.) and on the
rounded plan (see ``tests/test_solver_diff.py``).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.solver import constraints as K

if TYPE_CHECKING:   # annotation-only: keeps repro.solver import-cycle free
    from repro.core.convergence import MLConstants
from repro.solver import variables as V
from repro.solver.consensus import consensus_rounds, consensus_weights
from repro.solver.objective import (ObjectiveWeights, apply_required_deltas,
                                    objective, objective_breakdown)
from repro.solver.primal_dual import PDHyper


@dataclasses.dataclass
class SCAResult:
    w: Dict
    w_rounded: Dict
    objective_history: list
    violation_history: list
    breakdown: dict
    iterations: int


def _masked_merge(base, candidates, masks):
    """Assemble w_hat = sum_d mask_d * cand_d (+ untouched components)."""
    out = {}
    for kname in base:
        acc = jnp.zeros_like(base[kname])
        tot = jnp.zeros_like(base[kname])
        for cand, m in zip(candidates, masks):
            acc = acc + m[kname] * cand[kname]
            tot = tot + m[kname]
        out[kname] = jnp.where(tot > 0, acc / jnp.maximum(tot, 1e-12),
                               base[kname])
    return out


def solve_surrogate(w_l: Dict, Lambda: np.ndarray, net, D_bar, consts,
                    ow: ObjectiveWeights, hyper: PDHyper, masks,
                    *, distributed: bool = True, W_cons=None,
                    scaler: Optional[V.Scaler] = None):
    """One full run of Algorithm 2 at SCA iterate w^l (NORMALIZED space).

    Lambda: (V, nC) per-node duals (or (1, nC) for the centralized variant).
    Returns (w_hat, Lambda_new, info)."""
    scaler = scaler or V.Scaler(net)
    V_nodes = len(masks)

    def obj_n(wn):
        return objective(scaler.to_phys(wn), net, D_bar, consts, ow)

    def con_n(wn):
        c = K.constraint_vector(scaler.to_phys(wn), net, D_bar)
        return c * K.constraint_scale(net)

    def project_n(wn):
        return scaler.from_phys(V.project(scaler.to_phys(wn), net,
                                          gamma_cap=scaler.gamma_cap))

    gJ = jax.grad(obj_n)(w_l)
    C0 = np.asarray(con_n(w_l))
    JC = jax.jacobian(con_n)(w_l)
    nC = C0.shape[0]
    lam1, L_C, kappa = hyper.lambda1, hyper.L_C, hyper.kappa

    def candidate(lmb):
        """Closed-form minimizer of node's surrogate Lagrangian (93)."""
        lmb_j = jnp.asarray(lmb, jnp.float32)
        denom = lam1 + L_C * jnp.sum(lmb_j)
        g = {k: gJ[k] + jnp.tensordot(lmb_j, JC[k], axes=(0, 0))
             for k in w_l}
        step = {k: w_l[k] - g[k] / denom for k in w_l}
        return project_n(step)

    def ctilde(w_hat, mask):
        """Convexified constraints at node d's block (eqs. 84-85)."""
        diff = {k: (w_hat[k] - w_l[k]) * mask[k] for k in w_l}
        lin = np.zeros(nC)
        sq = 0.0
        for k in w_l:
            jc = np.asarray(JC[k]).reshape(nC, -1)
            lin += jc @ np.asarray(diff[k]).reshape(-1)
            sq += float(jnp.sum(diff[k] ** 2))
        return C0 / V_nodes + lin + 0.5 * L_C * sq

    Lambda = np.array(Lambda, dtype=np.float64)
    history = []
    for it in range(hyper.max_iters):
        if distributed:
            cands = [candidate(Lambda[d]) for d in range(V_nodes)]
            w_hat = project_n(_masked_merge(w_l, cands, masks))
            new_L = np.stack([Lambda[d] + kappa * ctilde(w_hat, masks[d])
                              for d in range(V_nodes)])
            new_L = consensus_rounds(new_L, W_cons, hyper.consensus_rounds)
            new_L = np.maximum(new_L, 0.0)
        else:
            w_hat = candidate(Lambda[0])
            full_mask = {k: jnp.ones_like(w_l[k]) for k in w_l}
            c_full = ctilde(w_hat, full_mask) * 1.0
            # centralized (94): average of per-node contributions = global/V
            new_L = np.maximum(Lambda + kappa * c_full[None] / 1.0, 0.0)
        delta = float(np.abs(new_L - Lambda).max())
        Lambda = new_L
        history.append(delta)
        if delta < hyper.tol:
            break
    info = {"dual_delta": history,
            "max_violation": float(np.max(con_n(w_hat)))}
    return w_hat, Lambda, info


def solve(net, D_bar, consts: MLConstants, ow: ObjectiveWeights,
          *, zeta: float = 0.5, max_outer: int = 20, tol: float = 1e-4,
          pd: Optional[PDHyper] = None, distributed: bool = True,
          w0: Optional[Dict] = None, seed: int = 0) -> SCAResult:
    """Algorithm 1 with the Python-loop Algorithm 2 inner solver (oracle)."""
    pd = pd or PDHyper()
    masks = V.ownership_masks(net)
    n_nodes = len(masks) if distributed else 1
    W_cons = consensus_weights(net.adjacency) if distributed else None
    from repro.network.costs import network_costs
    scaler = V.Scaler(net)
    Lambda = np.zeros((n_nodes, K.num_constraints(net)))
    w_phys = V.project(w0 if w0 is not None else V.init_w(net, D_bar), net)
    w_phys = apply_required_deltas(w_phys, net, D_bar, slack=1.05)
    w = scaler.from_phys(w_phys)

    hist, viol = [], []
    hist.append(float(objective(w_phys, net, D_bar, consts, ow)))
    for ell in range(max_outer):
        w_hat, Lambda, info = solve_surrogate(
            w, Lambda, net, D_bar, consts, ow, pd, masks,
            distributed=distributed, W_cons=W_cons, scaler=scaler)
        w_new = {k: w[k] + zeta * (w_hat[k] - w[k]) for k in w}
        w_phys = apply_required_deltas(
            V.project(scaler.to_phys(w_new), net), net, D_bar)
        w = scaler.from_phys(w_phys)
        obj = float(objective(w_phys, net, D_bar, consts, ow))
        viol.append(info["max_violation"])
        improved = hist[-1] - obj
        hist.append(obj)
        if 0 <= improved < tol * max(1.0, abs(hist[0])):
            break
    w_rounded = V.round_indicators(w_phys)
    c = network_costs(w_rounded, net, D_bar)
    w_rounded["delta_A"] = c["delta_A_req"]
    w_rounded["delta_R"] = c["delta_R_req"]
    return SCAResult(
        w=w_phys, w_rounded=w_rounded, objective_history=hist,
        violation_history=viol,
        breakdown=objective_breakdown(w_rounded, net, D_bar, consts, ow),
        iterations=ell + 1)
