"""Decision-variable dict for problem P: initialization (feasible point),
projection onto the per-node convex sets D_d (boxes / simplexes, eqs. 45-49,
54-62, 66-68), ownership masks for the distributed solver, rounding of the
relaxed indicator variables, and the flat (P,)-vector representation the
jitted batched backend solves over (:class:`WSpec`, :func:`ownership_matrix`,
:class:`NetView`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

# Canonical key order of the decision dict w (matches core.api.PLAN_KEYS).
W_KEYS = ("rho_nb", "rho_bs", "f_n", "z_s", "gamma", "m",
          "I_s", "I_nb", "I_bn", "R_bs", "delta_A", "delta_R")


def flat_dim(w):
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(w))


def w_shapes(dims) -> Dict[str, tuple]:
    """Per-key shapes of w at network dims (N, B, S)."""
    N, B, S = dims
    return {
        "rho_nb": (N, B), "rho_bs": (B, S), "f_n": (N,), "z_s": (S,),
        "gamma": (N + S,), "m": (N + S,), "I_s": (S,), "I_nb": (N, B),
        "I_bn": (B, N), "R_bs": (B, S), "delta_A": (), "delta_R": (),
    }


class WSpec:
    """Static flattening spec: w dict <-> one (P,) float32 vector.

    Keyed only on the network dims, so every jitted solver function traced
    against a spec has static shapes and re-solves across rounds (same dims,
    fresh rates) hit the compile cache.
    """

    def __init__(self, dims):
        self.dims = tuple(int(d) for d in dims)
        self.shapes = w_shapes(self.dims)
        self.sizes = {k: int(np.prod(s, dtype=np.int64))
                      for k, s in self.shapes.items()}
        self.offsets = {}
        off = 0
        for k in W_KEYS:
            self.offsets[k] = off
            off += self.sizes[k]
        self.total = off

    def flatten(self, w: Dict) -> jnp.ndarray:
        return jnp.concatenate([
            jnp.ravel(jnp.asarray(w[k], jnp.float32)) for k in W_KEYS])

    def unflatten(self, flat) -> Dict:
        return {k: flat[self.offsets[k]:self.offsets[k] + self.sizes[k]]
                .reshape(self.shapes[k]) for k in W_KEYS}


def owner_index(dims) -> np.ndarray:
    """(P,) owner node id of every flat component (UEs 0..N-1, BSs N..N+B-1,
    DCs N+B..N+B+S-1); the co-owned delta_A / delta_R entries get -1."""
    N, B, S = dims
    ue = np.arange(N)
    bs = N + np.arange(B)
    dc = N + B + np.arange(S)
    parts = {
        "rho_nb": np.repeat(ue, B), "rho_bs": np.repeat(bs, S),
        "f_n": ue, "z_s": dc,
        "gamma": np.concatenate([ue, dc]), "m": np.concatenate([ue, dc]),
        "I_s": dc, "I_nb": np.repeat(ue, B), "I_bn": np.repeat(bs, N),
        "R_bs": np.repeat(bs, S),
        "delta_A": np.array([-1]), "delta_R": np.array([-1]),
    }
    return np.concatenate([parts[k] for k in W_KEYS])


def ownership_matrix(dims) -> np.ndarray:
    """(V, P) ownership-mask matrix, built with array ops (no per-node
    loops).  Rows partition the flat w: exactly-one-owner components are
    one-hot columns; the DC-co-owned delta entries carry weight 1/S on every
    DC row, so ``M @ candidates`` is the Algorithm-2 masked merge.

    Host-side / oracle use only: the jitted backend never materializes
    this (V, P) matrix — it runs the segment-sum equivalents below
    (:func:`ownership_merge` / :func:`owner_mask` / :func:`node_sq_norms`)
    over the flat :func:`owner_index` map, which is what lets the solver
    scale to 10^5 UEs (the dense matrix is ~1 TB there)."""
    N, B, S = dims
    Vn = N + B + S
    own = owner_index(dims)
    M = (own[None, :] == np.arange(Vn)[:, None]).astype(np.float32)
    dc_rows = np.zeros(Vn, np.float32)
    dc_rows[N + B:] = 1.0 / S
    M[:, own < 0] = dc_rows[:, None]
    return M


def ownership_merge(cands, dims):
    """Algorithm-2 masked merge ``einsum("vp,vp->p", M_own, cands)``
    without the (V, P) matrix: every single-owner component gathers its
    owner's candidate row; the DC-co-owned delta entries take the mean
    over the DC rows (the 1/S weights of :func:`ownership_matrix`).
    Traceable; ``cands`` is the (V, P) candidate stack."""
    N, B, S = dims
    own = jnp.asarray(owner_index(dims))
    gathered = cands[jnp.clip(own, 0), jnp.arange(own.shape[0])]
    dc_mean = jnp.mean(cands[N + B:], axis=0)
    return jnp.where(own >= 0, gathered, dc_mean)


def owner_mask(v, dims):
    """Row ``v`` of :func:`ownership_matrix`, built on the fly from the
    flat owner index — safe to vmap over traced node ids inside jit, so
    the per-node masked diffs of Algorithm 2 never bake a (V, P)
    constant into the executable."""
    N, B, S = dims
    own = jnp.asarray(owner_index(dims))
    co_owned = (own < 0) & (v >= N + B)
    return jnp.where(own == v, 1.0, jnp.where(co_owned, 1.0 / S, 0.0))


def node_sq_norms(d, dims):
    """Per-node squared norms ``sum_p (d * mask_v)_p^2`` for all V nodes
    as ONE ``jax.ops.segment_sum`` over the flat owner index (instead of
    reducing a masked (V, P) materialization).  The co-owned delta
    entries contribute ``(d/S)^2`` to every DC row."""
    N, B, S = dims
    own_np = owner_index(dims)
    seg = jnp.asarray(np.maximum(own_np, 0))
    single = jnp.asarray((own_np >= 0).astype(np.float32))
    sq = jax.ops.segment_sum(single * d * d, seg, num_segments=N + B + S)
    delta_sq = jnp.sum((1.0 - single) * d * d) / (S * S)
    is_dc = jnp.arange(N + B + S) >= N + B
    return sq + jnp.where(is_dc, delta_sq, 0.0)


def init_w(net, D_bar, rng=None) -> Dict:
    """Feasible start: keep all data local, uniform BS->DC dispersion,
    aggregator = DC 0, mid-range compute settings."""
    rng = rng or np.random.RandomState(0)
    N, B, S = net.dims
    cfg = net.cfg
    w = {
        "rho_nb": jnp.zeros((N, B)) + 0.02,
        "rho_bs": jnp.ones((B, S)) / S,
        "f_n": jnp.full((N,), 0.5 * (cfg.f_min + cfg.f_max)),
        "z_s": jnp.full((S,), 0.5 * cfg.dc_point_capacity),
        "gamma": jnp.full((N + S,), 2.0),
        "m": jnp.full((N + S,), 0.5),
        "I_s": jnp.ones((S,)) / S,
        "I_nb": jnp.ones((N, B)) / B,
        "I_bn": jnp.ones((B, N)) / B,
        "R_bs": jnp.asarray(net.R_bs_max * 0.5),
        "delta_A": jnp.asarray(50.0),
        "delta_R": jnp.asarray(5.0),
    }
    return w


def _project_simplex(v, z=1.0):
    """Euclidean projection of rows of v onto {x >= 0, sum x = z}."""
    orig = v.shape
    v2 = v.reshape(-1, orig[-1])
    u = jnp.sort(v2, axis=1)[:, ::-1]
    css = jnp.cumsum(u, axis=1) - z
    ind = jnp.arange(1, orig[-1] + 1)
    cond = u - css / ind > 0
    rho = jnp.sum(cond, axis=1)
    theta = css[jnp.arange(v2.shape[0]), rho - 1] / rho
    return jnp.maximum(v2 - theta[:, None], 0.0).reshape(orig)


def _project_simplex_ineq(v, z=1.0):
    """Projection onto {x >= 0, sum x <= z}."""
    clipped = jnp.maximum(v, 0.0)
    over = jnp.sum(clipped, axis=-1, keepdims=True) > z
    proj = _project_simplex(v, z)
    return jnp.where(over, proj, clipped)


def project(w: Dict, net, gamma_cap: float = 20.0) -> Dict:
    cfg = net.cfg
    N, B, S = net.dims
    out = dict(w)
    out["rho_nb"] = _project_simplex_ineq(w["rho_nb"])          # (45),(55)
    out["rho_bs"] = _project_simplex(w["rho_bs"])               # (46),(56)
    out["I_s"] = _project_simplex(w["I_s"])                     # (47),(67)
    out["I_nb"] = _project_simplex(w["I_nb"])                   # (48),(68)
    out["I_bn"] = _project_simplex(w["I_bn"].T).T               # (49),(68)
    out["f_n"] = jnp.clip(w["f_n"], cfg.f_min, cfg.f_max)       # (57)
    out["z_s"] = jnp.clip(w["z_s"], 1e3, cfg.dc_point_capacity)  # (54)
    out["gamma"] = jnp.clip(w["gamma"], 0.5, gamma_cap)         # (59)
    out["m"] = jnp.clip(w["m"], 1e-3, 1.0)                      # (58)
    R = jnp.clip(w["R_bs"], 0.0, jnp.asarray(net.R_bs_max))     # (14)
    tot = jnp.sum(R, axis=0)
    scale = jnp.minimum(1.0, jnp.asarray(net.R_s_max) / (tot + 1e-9))
    out["R_bs"] = R * scale[None, :]                            # (15)
    out["delta_A"] = jnp.maximum(w["delta_A"], 0.0)             # (60)
    out["delta_R"] = jnp.maximum(w["delta_R"], 0.0)
    return out


def ownership_masks(net) -> List[Dict]:
    """One mask pytree per node (UEs, then BSs, then DCs), the dict view of
    :func:`ownership_matrix` rows.  Shared variables (delta_A, delta_R) are
    co-owned by the DCs (their updates are averaged); every other component
    has exactly one owner."""
    spec = WSpec(net.dims)
    M = ownership_matrix(spec.dims)
    return [{k: jnp.asarray(v) for k, v in spec.unflatten(row).items()}
            for row in M]


class Scaler:
    """Normalize decision variables to O(1) so the isotropic proximal
    surrogate (eq. 83) is well-conditioned.  The physical<->normalized maps
    are linear, so convexity/feasibility arguments are unaffected."""

    def __init__(self, net, gamma_cap: float = 20.0, delta_A_scale=100.0,
                 delta_R_scale=10.0):
        cfg = net.cfg
        self.gamma_cap = gamma_cap
        self.scale = {
            "rho_nb": 1.0, "rho_bs": 1.0, "I_s": 1.0, "I_nb": 1.0,
            "I_bn": 1.0, "m": 1.0,
            "f_n": cfg.f_max,
            "z_s": cfg.dc_point_capacity,
            "gamma": gamma_cap,
            "R_bs": jnp.asarray(net.R_bs_max),
            "delta_A": delta_A_scale,
            "delta_R": delta_R_scale,
        }

    def to_phys(self, w_norm: Dict) -> Dict:
        return {k: w_norm[k] * self.scale[k] for k in w_norm}

    def from_phys(self, w_phys: Dict) -> Dict:
        return {k: w_phys[k] / self.scale[k] for k in w_phys}

    def flat(self, spec: "WSpec") -> jnp.ndarray:
        """The (P,) per-component scale vector (flat-space to_phys is a
        single elementwise multiply)."""
        return spec.flatten({
            k: jnp.broadcast_to(jnp.asarray(self.scale[k], jnp.float32),
                                spec.shapes[k]) for k in W_KEYS})


@jax.tree_util.register_pytree_node_class
class NetView:
    """Network view whose rate arrays are jax leaves, so jitted solver code
    can take them as *traced* arguments: per-round rate resampling and data
    arrivals never retrace — only the dims / cfg (static aux data) key the
    compile cache.  Duck-types the ``Network`` surface that ``costs`` /
    ``project`` / ``Scaler`` read (``cfg``, ``dims``, rate arrays)."""

    ARRAYS = ("R_nb", "R_bn", "R_ss", "R_sb", "R_bs_max", "R_s_max")

    def __init__(self, cfg, dims, arrays):
        self.cfg = cfg
        self._dims = tuple(int(d) for d in dims)
        for name, arr in zip(self.ARRAYS, arrays):
            setattr(self, name, arr)

    @property
    def dims(self):
        return self._dims

    @classmethod
    def from_network(cls, net) -> "NetView":
        return cls(net.cfg, net.dims,
                   [jnp.asarray(getattr(net, a), jnp.float32)
                    for a in cls.ARRAYS])

    def tree_flatten(self):
        leaves = tuple(getattr(self, a) for a in self.ARRAYS)
        cfg_key = tuple(getattr(self.cfg, f.name)
                        for f in dataclasses.fields(self.cfg))
        return leaves, (type(self.cfg), cfg_key, self._dims)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        cfg_cls, cfg_key, dims = aux
        return cls(cfg_cls(*cfg_key), dims, list(leaves))


def round_indicators(w: Dict) -> Dict:
    """Map relaxed indicators to feasible binaries (argmax rounding),
    satisfying (47)-(49) and (61)-(62)."""
    out = dict(w)
    S = w["I_s"].shape[0]
    out["I_s"] = jax.nn.one_hot(jnp.argmax(w["I_s"]), S)
    out["I_nb"] = jax.nn.one_hot(jnp.argmax(w["I_nb"], axis=1),
                                 w["I_nb"].shape[1])
    out["I_bn"] = jax.nn.one_hot(jnp.argmax(w["I_bn"], axis=0),
                                 w["I_bn"].shape[0]).T
    return out
