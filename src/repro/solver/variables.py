"""Decision-variable dict for problem P: initialization (feasible point),
projection onto the per-node convex sets D_d (boxes / simplexes, eqs. 45-49,
54-62, 66-68), ownership masks for the distributed solver, and rounding of
the relaxed indicator variables.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def flat_dim(w):
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(w))


def init_w(net, D_bar, rng=None) -> Dict:
    """Feasible start: keep all data local, uniform BS->DC dispersion,
    aggregator = DC 0, mid-range compute settings."""
    rng = rng or np.random.RandomState(0)
    N, B, S = net.dims
    cfg = net.cfg
    w = {
        "rho_nb": jnp.zeros((N, B)) + 0.02,
        "rho_bs": jnp.ones((B, S)) / S,
        "f_n": jnp.full((N,), 0.5 * (cfg.f_min + cfg.f_max)),
        "z_s": jnp.full((S,), 0.5 * cfg.dc_point_capacity),
        "gamma": jnp.full((N + S,), 2.0),
        "m": jnp.full((N + S,), 0.5),
        "I_s": jnp.ones((S,)) / S,
        "I_nb": jnp.ones((N, B)) / B,
        "I_bn": jnp.ones((B, N)) / B,
        "R_bs": jnp.asarray(net.R_bs_max * 0.5),
        "delta_A": jnp.asarray(50.0),
        "delta_R": jnp.asarray(5.0),
    }
    return w


def _project_simplex(v, z=1.0):
    """Euclidean projection of rows of v onto {x >= 0, sum x = z}."""
    orig = v.shape
    v2 = v.reshape(-1, orig[-1])
    u = jnp.sort(v2, axis=1)[:, ::-1]
    css = jnp.cumsum(u, axis=1) - z
    ind = jnp.arange(1, orig[-1] + 1)
    cond = u - css / ind > 0
    rho = jnp.sum(cond, axis=1)
    theta = css[jnp.arange(v2.shape[0]), rho - 1] / rho
    return jnp.maximum(v2 - theta[:, None], 0.0).reshape(orig)


def _project_simplex_ineq(v, z=1.0):
    """Projection onto {x >= 0, sum x <= z}."""
    clipped = jnp.maximum(v, 0.0)
    over = jnp.sum(clipped, axis=-1, keepdims=True) > z
    proj = _project_simplex(v, z)
    return jnp.where(over, proj, clipped)


def project(w: Dict, net, gamma_cap: float = 20.0) -> Dict:
    cfg = net.cfg
    N, B, S = net.dims
    out = dict(w)
    out["rho_nb"] = _project_simplex_ineq(w["rho_nb"])          # (45),(55)
    out["rho_bs"] = _project_simplex(w["rho_bs"])               # (46),(56)
    out["I_s"] = _project_simplex(w["I_s"])                     # (47),(67)
    out["I_nb"] = _project_simplex(w["I_nb"])                   # (48),(68)
    out["I_bn"] = _project_simplex(w["I_bn"].T).T               # (49),(68)
    out["f_n"] = jnp.clip(w["f_n"], cfg.f_min, cfg.f_max)       # (57)
    out["z_s"] = jnp.clip(w["z_s"], 1e3, cfg.dc_point_capacity)  # (54)
    out["gamma"] = jnp.clip(w["gamma"], 0.5, gamma_cap)         # (59)
    out["m"] = jnp.clip(w["m"], 1e-3, 1.0)                      # (58)
    R = jnp.clip(w["R_bs"], 0.0, jnp.asarray(net.R_bs_max))     # (14)
    tot = jnp.sum(R, axis=0)
    scale = jnp.minimum(1.0, jnp.asarray(net.R_s_max) / (tot + 1e-9))
    out["R_bs"] = R * scale[None, :]                            # (15)
    out["delta_A"] = jnp.maximum(w["delta_A"], 0.0)             # (60)
    out["delta_R"] = jnp.maximum(w["delta_R"], 0.0)
    return out


def ownership_masks(net) -> List[Dict]:
    """One 0/1 mask pytree per node (UEs, then BSs, then DCs).  Shared
    variables (I_s, delta_A, delta_R) are co-owned by the DCs (their updates
    are averaged); every other component has exactly one owner."""
    N, B, S = net.dims
    masks = []

    def zeros_like_w():
        return {
            "rho_nb": np.zeros((N, B)), "rho_bs": np.zeros((B, S)),
            "f_n": np.zeros((N,)), "z_s": np.zeros((S,)),
            "gamma": np.zeros((N + S,)), "m": np.zeros((N + S,)),
            "I_s": np.zeros((S,)), "I_nb": np.zeros((N, B)),
            "I_bn": np.zeros((B, N)), "R_bs": np.zeros((B, S)),
            "delta_A": np.zeros(()), "delta_R": np.zeros(()),
        }

    for n in range(N):
        m = zeros_like_w()
        m["rho_nb"][n, :] = 1
        m["f_n"][n] = 1
        m["gamma"][n] = 1
        m["m"][n] = 1
        m["I_nb"][n, :] = 1
        masks.append(m)
    for b in range(B):
        m = zeros_like_w()
        m["rho_bs"][b, :] = 1
        m["I_bn"][b, :] = 1
        m["R_bs"][b, :] = 1
        masks.append(m)
    for s in range(S):
        m = zeros_like_w()
        m["z_s"][s] = 1
        m["gamma"][N + s] = 1
        m["m"][N + s] = 1
        m["I_s"][s] = 1            # one simplex coordinate per DC
        m["delta_A"] = np.ones(()) / S
        m["delta_R"] = np.ones(()) / S
        masks.append(m)
    return [{k: jnp.asarray(v) for k, v in m.items()} for m in masks]


class Scaler:
    """Normalize decision variables to O(1) so the isotropic proximal
    surrogate (eq. 83) is well-conditioned.  The physical<->normalized maps
    are linear, so convexity/feasibility arguments are unaffected."""

    def __init__(self, net, gamma_cap: float = 20.0, delta_A_scale=100.0,
                 delta_R_scale=10.0):
        cfg = net.cfg
        self.gamma_cap = gamma_cap
        self.scale = {
            "rho_nb": 1.0, "rho_bs": 1.0, "I_s": 1.0, "I_nb": 1.0,
            "I_bn": 1.0, "m": 1.0,
            "f_n": cfg.f_max,
            "z_s": cfg.dc_point_capacity,
            "gamma": gamma_cap,
            "R_bs": jnp.asarray(net.R_bs_max),
            "delta_A": delta_A_scale,
            "delta_R": delta_R_scale,
        }

    def to_phys(self, w_norm: Dict) -> Dict:
        return {k: w_norm[k] * self.scale[k] for k in w_norm}

    def from_phys(self, w_phys: Dict) -> Dict:
        return {k: w_phys[k] / self.scale[k] for k in w_phys}


def round_indicators(w: Dict) -> Dict:
    """Map relaxed indicators to feasible binaries (argmax rounding),
    satisfying (47)-(49) and (61)-(62)."""
    out = dict(w)
    S = w["I_s"].shape[0]
    out["I_s"] = jax.nn.one_hot(jnp.argmax(w["I_s"]), S)
    out["I_nb"] = jax.nn.one_hot(jnp.argmax(w["I_nb"], axis=1),
                                 w["I_nb"].shape[1])
    out["I_bn"] = jax.nn.one_hot(jnp.argmax(w["I_bn"], axis=0),
                                 w["I_bn"].shape[0]).T
    return out
