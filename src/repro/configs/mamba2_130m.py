"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,            # attention-free
    num_kv_heads=0,
    d_ff=0,                 # Mamba block carries its own 2x expansion
    vocab_size=50280,
    layer_pattern="M",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=64),
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba-2 / SSD), 130m config",
)
