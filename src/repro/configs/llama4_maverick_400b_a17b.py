"""llama4-maverick-400b-a17b [moe] — 128e top-1 MoE + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,              # shared-expert width
    vocab_size=202048,
    moe=MoEConfig(num_experts=128, top_k=1, expert_ff=8192, shared_expert=True,
                  every_n_layers=2),  # interleaved MoE (every other layer dense)
    frontend="vq_tokens",   # early-fusion vision tokens stubbed as in-vocab ids
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4 (Maverick 400B-A17B: 128e top-1 + shared expert)",
)
