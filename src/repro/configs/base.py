"""Config dataclasses for the repro framework.

Every assigned architecture gets one module in this package defining a
``ModelConfig``; ``repro.configs.get_config(arch_id)`` resolves it.  Input
shapes (train / prefill / decode / long-context-decode) are global and shared
across architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int                 # d_ff of each expert
    capacity_factor: float = 1.25
    dense_residual: bool = False   # Arctic: dense MLP in parallel with MoE
    shared_expert: bool = False    # Llama-4: always-on shared expert
    every_n_layers: int = 1        # MoE on layers where (layer % every_n) == every_n-1
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128           # N (dstate)
    head_dim: int = 64             # P (headdim); nheads = expand*d_model/head_dim
    expand: int = 2
    chunk_size: int = 64           # SSD chunk length
    conv_width: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention variants ---
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None      # native window (starcoder2) or opt-in
    # --- layer-type pattern -----------------------------------------------
    # string of 'A' (attention) / 'M' (mamba) repeated cyclically over layers
    layer_pattern: str = "A"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0                   # >0 => encoder-decoder
    encoder_seq: int = 1500                   # frames after conv frontend (stub)
    # --- modality frontend stub ---
    frontend: str = "none"                    # none | audio_embed | vq_tokens
    gated_mlp: bool = True                    # SwiGLU (3 mats) vs GELU (2 mats)
    # --- numerics / training ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # citation for the config values
    source: str = ""

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def pattern_for_layer(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    @property
    def attn_free(self) -> bool:
        return "A" not in self.layer_pattern and not self.is_encdec

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        n_layers = self.num_layers
        for i in range(n_layers):
            kind = self.pattern_for_layer(i)
            if kind == "A":
                qkv = d * self.num_heads * self.head_dim + 2 * d * self.num_kv_heads * self.head_dim
                o = self.num_heads * self.head_dim * d
                total += qkv + o
            else:  # mamba block
                s = self.ssm
                d_inner = s.expand * d
                nheads = d_inner // s.head_dim
                in_proj = d * (2 * d_inner + 2 * s.state_dim + nheads)
                out_proj = d_inner * d
                total += in_proj + out_proj + d_inner * s.conv_width
            # mlp/moe
            n_mats = 3 if self.gated_mlp else 2
            if self.moe is not None and (i % self.moe.every_n_layers == self.moe.every_n_layers - 1):
                m = self.moe
                total += m.num_experts * n_mats * d * m.expert_ff
                total += d * m.num_experts  # router
                if m.dense_residual or m.shared_expert:
                    total += n_mats * d * (self.d_ff or m.expert_ff)
            else:
                if self.d_ff:
                    total += n_mats * d * self.d_ff
            total += 2 * d  # norms
        if self.is_encdec:
            # encoder layers: self-attn + mlp; decoder counted above, add cross-attn
            enc = self.encoder_layers * (4 * d * self.num_heads * self.head_dim + 3 * d * self.d_ff + 2 * d)
            cross = n_layers * 4 * d * self.num_heads * self.head_dim
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive_frac_layers = [i for i in range(self.num_layers)
                                if i % m.every_n_layers == m.every_n_layers - 1]
        inactive = len(inactive_frac_layers) * (m.num_experts - m.top_k) * 3 * self.d_model * m.expert_ff
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized variant of the same family (2 layers, d_model<=512,
    <=4 experts) as required by the assignment."""
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    num_heads = max(2, min(4, cfg.num_heads))
    num_kv = max(1, min(num_heads, cfg.num_kv_heads if cfg.num_kv_heads <= num_heads else num_heads))
    while num_heads % num_kv:
        num_kv -= 1
    kw = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=head_dim,
        encoder_layers=2 if cfg.is_encdec else 0,
        encoder_seq=16 if cfg.is_encdec else cfg.encoder_seq,
    )
    if "A" in cfg.layer_pattern and "M" in cfg.layer_pattern:
        kw["layer_pattern"] = "MA"   # keep the hybrid nature, 2-layer period
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), expert_ff=128,
            every_n_layers=min(cfg.moe.every_n_layers, 2))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16, chunk_size=8)
    if cfg.sliding_window is not None:
        kw["sliding_window"] = 64
    kw.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
