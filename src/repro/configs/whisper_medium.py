"""whisper-medium [audio] — encoder-decoder, conv frontend (stub)
[arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    encoder_seq=1500,       # 30s audio -> 1500 frames after conv frontend (stub)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,        # MHA
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    frontend="audio_embed",
    gated_mlp=False,        # Whisper uses a standard GELU MLP
    tie_embeddings=True,
    source="arXiv:2212.04356 (Whisper medium)",
)
