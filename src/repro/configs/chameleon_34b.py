"""chameleon-34b [vlm] — early fusion, VQ image tokens [arXiv:2405.09818]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,       # includes 8192 VQ image codes (early fusion)
    qk_norm=True,           # Chameleon uses qk-norm for stability
    frontend="vq_tokens",   # image tokenizer stubbed: ids already in-vocab
    source="arXiv:2405.09818 (Chameleon 34B)",
)
