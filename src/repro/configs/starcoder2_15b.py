"""starcoder2-15b [dense] — GQA, RoPE, native sliding-window 4096
[arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    sliding_window=4096,    # native to the model card
    gated_mlp=False,        # StarCoder2 uses a standard (non-gated) GELU MLP
    source="arXiv:2402.19173 (StarCoder2-15B)",
)
