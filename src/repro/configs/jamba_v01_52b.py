"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    # Jamba period-8 block: attention at index 4 of each period, mamba elsewhere
    layer_pattern="MMMMAMMM",
    moe=MoEConfig(num_experts=16, top_k=2, expert_ff=14336, every_n_layers=2),
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, chunk_size=64),
    source="arXiv:2403.19887 (Jamba v0.1)",
)
