"""The paper's own FL workload: small image classifier (F-MNIST / CIFAR-10 scale)
trained with CE-FL over the UE/BS/DC network (Sec. VI / App. G).

This is not one of the assigned LM architectures; it is the model used to
reproduce the paper's Tables I-II and Figs 3-7.  We express it as an MLP-Mixer
style flat classifier so it fits the generic ModelConfig plumbing, but the FL
experiments use ``repro.models.classifier`` directly.
"""
import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    name: str = "cefl-paper-cnn"
    input_shape: tuple = (28, 28, 1)   # F-MNIST; CIFAR variant: (32, 32, 3)
    num_classes: int = 10
    hidden: tuple = (200, 100)
    dtype: str = "float32"


CLASSIFIER = ClassifierConfig()
CLASSIFIER_CIFAR = ClassifierConfig(name="cefl-paper-cnn-cifar", input_shape=(32, 32, 3))

# ModelConfig view (used only by the registry; FL experiments use CLASSIFIER)
CONFIG = ModelConfig(
    name="cefl-paper",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    head_dim=64,
    source="paper Sec. VI / App. G (F-MNIST workload)",
)
