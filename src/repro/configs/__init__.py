"""Architecture config registry.

Each assigned architecture is a module defining ``CONFIG``; ``get_config``
resolves by id (dashes or underscores accepted).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES, ModelConfig, MoEConfig, SSMConfig, ShapeConfig, reduced,
)

ARCH_IDS = [
    "mamba2-130m",
    "arctic-480b",
    "jamba-v0.1-52b",
    "whisper-medium",
    "codeqwen1.5-7b",
    "qwen3-32b",
    "chameleon-34b",
    "starcoder2-15b",
    "llama4-maverick-400b-a17b",
    "llama3-405b",
]

_MODULES = {
    "mamba2-130m": "mamba2_130m",
    "arctic-480b": "arctic_480b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "whisper-medium": "whisper_medium",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "qwen3-32b": "qwen3_32b",
    "chameleon-34b": "chameleon_34b",
    "starcoder2-15b": "starcoder2_15b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama3-405b": "llama3_405b",
    "cefl-paper": "cefl_paper",
}


def get_config(arch_id: str) -> ModelConfig:
    key = arch_id.replace("_", "-").lower()
    if key not in _MODULES:
        # allow python-style ids too
        matches = [k for k, v in _MODULES.items() if v == arch_id]
        if matches:
            key = matches[0]
        else:
            raise KeyError(f"unknown architecture {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[key]}")
    return mod.CONFIG
