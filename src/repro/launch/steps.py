"""Per-(architecture x input-shape x mesh) execution plans: step functions,
abstract input specs, and shardings.  Used by dryrun.py (lower+compile) and
train.py / serve.py (real execution).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.round_step import CEFLHyper, build_cefl_round_step
from repro.models import lm as L
from repro.models.common import ShardCtx
from repro.sharding import specs as SP

ACT_BUDGET = 2.5e9       # per-device saved-activation budget (bytes)
SW_LONG = 8192           # sliding window for the long_500k dense variant


@dataclasses.dataclass(frozen=True)
class Plan:
    arch: str
    cfg: ModelConfig
    shape: ShapeConfig
    multi_pod: bool
    n_dpu: int
    n_micro: int
    mb: int                  # examples per microbatch (per DPU)
    remat_chunk: int
    gamma_max: int
    seq_shard_decode: bool
    wide_cache: bool
    skip: Optional[str] = None     # reason, if the combo is skipped
    embed_replicated: bool = False  # perf variant: replicate (un)embedding

    @property
    def mesh_name(self):
        return "2x16x16" if self.multi_pod else "16x16"

    @property
    def chips(self):
        return 512 if self.multi_pod else 256


def _divisor_at_least(n: int, target: float) -> int:
    """Smallest divisor of n that is >= target."""
    for d in range(1, n + 1):
        if n % d == 0 and d >= target:
            return d
    return n


def make_plan(arch: str, shape_name: str, *, multi_pod: bool,
              gamma_max: int = 1, data_ax: int = 16,
              remat_chunk: Optional[int] = None,
              n_micro: Optional[int] = None,
              embed_replicated: bool = False) -> Plan:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    skip = None
    seq_shard = False
    wide = False
    if shape_name == "long_500k":
        if cfg.is_encdec:
            skip = ("enc-dec full-attention architecture: no faithful "
                    "sub-quadratic variant (DESIGN.md §Arch-applicability)")
        elif cfg.attn_free:
            pass                            # SSM: native O(1) decode
        elif cfg.family == "hybrid":
            seq_shard, wide = True, True    # full cache, 256-way seq shard
        elif cfg.sliding_window is None:
            cfg = dataclasses.replace(cfg, sliding_window=SW_LONG)
            seq_shard = True                # window cache seq-sharded
        else:
            seq_shard = True                # native window (starcoder2)
    elif shape.mode == "decode" and not cfg.attn_free:
        seq_shard = True

    n_dpu = 2 if (multi_pod and shape.mode == "train") else 1
    mb = None
    if shape.mode == "train":
        per_dpu = shape.global_batch // n_dpu
        if n_micro is None:
            # keep one example per data shard per microbatch by default
            n_micro = max(1, per_dpu // data_ax)
        mb = per_dpu // n_micro
        assert mb * n_micro == per_dpu
        if remat_chunk is None:
            from repro.models.blocks import num_periods
            n_per = num_periods(cfg)
            tokens_per_dev = shape.seq_len * mb // data_ax
            bytes_per_chunkless = n_per * tokens_per_dev * cfg.d_model * 2
            remat_chunk = _divisor_at_least(
                n_per, bytes_per_chunkless / ACT_BUDGET)
    return Plan(arch=arch, cfg=cfg, shape=shape, multi_pod=multi_pod,
                n_dpu=n_dpu, n_micro=n_micro or 1, mb=mb or 0,
                remat_chunk=remat_chunk or 1, gamma_max=gamma_max,
                seq_shard_decode=seq_shard, wide_cache=wide, skip=skip,
                embed_replicated=embed_replicated)


# ------------------------------------------------------------- specs -----

def input_specs(plan: Plan) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    cfg, shape = plan.cfg, plan.shape
    S = shape.seq_len
    if shape.mode == "train":
        sh = (plan.n_dpu, plan.n_micro, plan.mb, S)
        out = {"tokens": jax.ShapeDtypeStruct(sh, jnp.int32),
               "labels": jax.ShapeDtypeStruct(sh, jnp.int32)}
        if cfg.is_encdec:
            out["enc_embed"] = jax.ShapeDtypeStruct(
                sh[:-1] + (cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return out
    if shape.mode == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, S),
                                              jnp.int32)}
        if cfg.is_encdec:
            out["enc_embed"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return out
    # decode: one new token + the cache
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)}


def abstract_params(plan: Plan):
    cfg = plan.cfg
    p = jax.eval_shape(lambda: L.init_lm_params(jax.random.PRNGKey(0), cfg))
    if plan.shape.mode == "train":
        p = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((plan.n_dpu,) + s.shape, s.dtype),
            p)
    return p


def abstract_cache(plan: Plan):
    cfg, shape = plan.cfg, plan.shape
    return jax.eval_shape(
        lambda: L.init_cache(cfg, shape.global_batch, shape.seq_len))


def shard_ctx(plan: Plan, mesh) -> ShardCtx:
    if plan.shape.mode == "decode" and plan.shape.global_batch == 1:
        batch_axes: Tuple = ()
        cache_axes = ("model", "data") if plan.wide_cache else ("model",)
    else:
        batch_axes = ("pod", "data") if plan.multi_pod else ("data",)
        cache_axes = ("model",)
    return ShardCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model",
                    cache_axes=cache_axes,
                    seq_shard_decode=plan.seq_shard_decode)


def param_shardings(plan: Plan, mesh):
    base = jax.eval_shape(
        lambda: L.init_lm_params(jax.random.PRNGKey(0), plan.cfg))
    specs = SP.param_specs(plan.cfg, base)
    if plan.embed_replicated:
        specs = dict(specs)
        specs["embed"] = P(None, None)
        if "unembed" in specs:
            specs["unembed"] = P(None, None)
    shapes = base
    if plan.shape.mode == "train":
        lead = "pod" if plan.multi_pod else None
        specs = jax.tree_util.tree_map(lambda s: P(lead, *s), specs)
        shapes = abstract_params(plan)
    specs = SP.sanitize_tree(specs, shapes, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def batch_shardings(plan: Plan, mesh):
    if plan.shape.mode == "train":
        lead = "pod" if plan.multi_pod else None
        def spec(s):
            extra = (None,) * (len(s.shape) - 4)
            return NamedSharding(mesh, P(lead, None, "data",
                                         *( (None,) + extra )))
        return jax.tree_util.tree_map(spec, input_specs(plan))
    ctx = shard_ctx(plan, mesh)
    b_ax = tuple(ctx.batch_axes) or None
    def spec(s):
        rest = (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, P(b_ax, *rest))
    return jax.tree_util.tree_map(spec, input_specs(plan))


def cache_shardings(plan: Plan, mesh):
    ctx = shard_ctx(plan, mesh)
    b_ax = tuple(ctx.batch_axes) or None
    cache = abstract_cache(plan)
    specs = SP.cache_specs(plan.cfg, cache,
                           batch_axes=b_ax,
                           seq_axes=tuple(ctx.cache_axes),
                           seq_shard=plan.seq_shard_decode)
    specs = SP.sanitize_tree(specs, cache, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


# ------------------------------------------------------------- steps -----

def build_train_step(plan: Plan, hyper: Optional[CEFLHyper] = None):
    cfg = plan.cfg
    # >100B-param configs: bf16 gradient accumulators (HBM headroom)
    big = cfg.param_count() > 100e9
    hyper = hyper or CEFLHyper(gamma_max=plan.gamma_max,
                               n_micro=plan.n_micro,
                               grad_dtype="bfloat16" if big else "float32")

    def loss_fn(params, micro, mask):
        loss, aux = L.lm_loss(params, cfg, micro, example_mask=mask,
                              remat=True, remat_chunk=plan.remat_chunk)
        return loss, aux

    return build_cefl_round_step(loss_fn, hyper)


def build_prefill_step(plan: Plan, mesh=None):
    cfg, shape = plan.cfg, plan.shape

    def prefill_step(params, batch):
        logits, cache = L.prefill(params, cfg, batch["tokens"],
                                  cache_len=shape.seq_len,
                                  enc_embed=batch.get("enc_embed"))
        return logits, cache

    return prefill_step


def build_serve_step(plan: Plan, mesh=None):
    cfg = plan.cfg
    from repro.models.common import NO_SHARD
    ctx = shard_ctx(plan, mesh) if mesh is not None else NO_SHARD

    def serve_step(params, cache, batch):
        logits, new_cache = L.lm_decode_step(params, cfg, batch["tokens"],
                                             cache, ctx=ctx)
        return logits, new_cache

    return serve_step
