import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination on the production meshes, print memory/cost analysis, and
dump roofline inputs as JSON.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --out results/dryrun

The XLA host-device-count flag above MUST precede every other import (jax
locks the device count at first init), which is why this module sets it in
its first two lines and why nothing else in the repo sets it globally.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES
from repro.core.round_step import make_dpu_meta
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.sharding.specs import sanitize_spec
from repro.utils.hlo import collective_bytes
from repro.utils.hlo_walk import amplified_costs
from repro.utils.roofline import model_flops_for


def _flt(d):
    return {k: (float(v) if isinstance(v, (int, float)) else v)
            for k, v in (d or {}).items()}


def dryrun_combo(arch: str, shape_name: str, *, multi_pod: bool,
                 gamma_max: int = 1, verbose: bool = True,
                 keep_hlo: bool = False, plan_overrides=None,
                 attn_hint: bool = True) -> dict:
    """Lower + compile one combination; returns the roofline record."""
    plan = ST.make_plan(arch, shape_name, multi_pod=multi_pod,
                        gamma_max=gamma_max, **(plan_overrides or {}))
    rec = {"arch": arch, "shape": shape_name, "mesh": plan.mesh_name,
           "chips": plan.chips, "mode": plan.shape.mode,
           "n_micro": plan.n_micro, "remat_chunk": plan.remat_chunk,
           "seq_shard_decode": plan.seq_shard_decode,
           "wide_cache": plan.wide_cache,
           "sliding_window": plan.cfg.sliding_window}
    if plan.skip:
        rec["status"] = "skipped"
        rec["reason"] = plan.skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    # constrain attention activations (batch->data, heads->model); see
    # repro.models.attention.set_shard_hint
    # Measured: the constraint is a ~10-30x win for train (GSPMD otherwise
    # leaves full batch on every device in the attention backward) but hurts
    # prefill memory, where GSPMD's free layout is better — so train only.
    from repro.models import attention as attn_mod
    use_hint = attn_hint and plan.shape.mode == "train"
    attn_mod.set_shard_hint(mesh if use_hint else None, ("data",), "model")
    params = ST.abstract_params(plan)
    p_shard = ST.param_shardings(plan, mesh)
    b_spec = ST.input_specs(plan)
    b_shard = ST.batch_shardings(plan, mesh)

    # NamedShardings carry the mesh; no ambient mesh context needed
    if True:
        if plan.shape.mode == "train":
            step = ST.build_train_step(plan)
            meta = make_dpu_meta(plan.n_dpu)
            meta_shard = jax.tree_util.tree_map(
                lambda x: NamedSharding(mesh, P(*((None,) * x.ndim))), meta)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, b_shard, meta_shard),
                             out_shardings=(p_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(params, b_spec, meta)
        elif plan.shape.mode == "prefill":
            step = ST.build_prefill_step(plan, mesh)
            c_shard = ST.cache_shardings(plan, mesh)
            logit_shard = NamedSharding(mesh, sanitize_spec(
                P(("data",), "model"),
                (plan.shape.global_batch, plan.cfg.vocab_size), mesh))
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                             out_shardings=(logit_shard, c_shard))
            lowered = jitted.lower(params, b_spec)
        else:
            step = ST.build_serve_step(plan, mesh)
            cache = ST.abstract_cache(plan)
            c_shard = ST.cache_shardings(plan, mesh)
            ctx = ST.shard_ctx(plan, mesh)
            b_ax = tuple(ctx.batch_axes) or None
            logit_shard = NamedSharding(mesh, sanitize_spec(
                P(b_ax, "model"),
                (plan.shape.global_batch, plan.cfg.vocab_size), mesh))
            jitted = jax.jit(step,
                             in_shardings=(p_shard, c_shard, b_shard),
                             out_shardings=(logit_shard, c_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, cache, b_spec)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    attn_mod.set_shard_hint(None)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax <= 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    amp = amplified_costs(hlo)          # trip-count-aware totals
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # NOTE: compiled HLO is the per-device SPMD program; *_device values
        # are per-chip, the headline values are global (x chips).
        "flops_raw_device": flops,             # XLA, loop bodies once
        "bytes_accessed_raw_device": nbytes,
        "flops_device": amp["flops"],          # trip-count amplified
        "bytes_device": amp["bytes"],
        "flops": amp["flops"] * plan.chips,
        "bytes_accessed": amp["bytes"] * plan.chips,
        "collectives_raw": {k: v for k, v in coll.items() if k != "counts"},
        "collectives": {k: v * plan.chips
                        for k, v in amp["collectives"].items()},
        "collective_bytes": amp["collective_bytes_total"] * plan.chips,
        "unknown_trip_counts": amp["unknown_trip_counts"][:8],
        "collective_counts": coll.get("counts", {}),
        "model_flops": model_flops_for(plan.cfg, plan.shape,
                                       gamma=gamma_max),
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        },
    })
    # memory_analysis of the SPMD module is already per-device
    arg_b = rec["memory_analysis"]["argument_size_bytes"]
    tmp_b = rec["memory_analysis"]["temp_size_bytes"]
    rec["bytes_per_device"] = arg_b + tmp_b
    if keep_hlo:
        rec["hlo"] = hlo
    if verbose:
        mf = rec["model_flops"]
        print(f"[{arch} x {shape_name} x {plan.mesh_name}] "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"global flops {rec['flops']:.3e} "
              f"(model/hlo {mf/max(rec['flops'],1):.2f}) "
              f"bytes {rec['bytes_accessed']:.3e} "
              f"coll {rec['collective_bytes']/1e9:.2f}GB | "
              f"args+tmp/device {rec['bytes_per_device']/1e9:.2f}GB")
        print(f"  memory_analysis: {rec['memory_analysis']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--gamma-max", type=int, default=1)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                path = outdir / f"{tag}.json"
                if path.exists():
                    print(f"[skip cached] {tag}")
                    continue
                try:
                    rec = dryrun_combo(arch, shape, multi_pod=mp,
                                       gamma_max=args.gamma_max,
                                       keep_hlo=True)
                    if "hlo" in rec:     # archive compressed HLO next to it
                        import gzip
                        (outdir / f"{tag}.hlo.txt.gz").write_bytes(
                            gzip.compress(rec.pop("hlo").encode()))
                except Exception as e:   # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e)}
                    failures.append(tag)
                path.write_text(json.dumps(rec, indent=1))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete.")


if __name__ == "__main__":
    main()
