"""Production meshes.  Defined as functions (never module-level constants)
so importing this module never touches jax device state."""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ('data','model'); 2x16x16 = 512 chips
    multi-pod ('pod','data','model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests / examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))
