"""Production meshes.  Defined as functions (never module-level constants)
so importing this module never touches jax device state."""
from __future__ import annotations

import jax


def _auto_kw(n):
    """axis_types=Auto on jax versions that have it (>= 0.5); {} otherwise
    (older jax is Auto-only, so omitting the kwarg is equivalent)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ('data','model'); 2x16x16 = 512 chips
    multi-pod ('pod','data','model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kw(len(shape)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests / examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"), **_auto_kw(2))
