"""CE-FL LM training launcher — DEPRECATED argparse shim.

The launcher is now spec-driven: ``repro.experiments.lm.run_lm`` runs
the identical mesh-native round step from an
:class:`~repro.experiments.spec.ExperimentSpec` (presets ``lm_smoke`` /
``lm_mamba2_130m``).  This module keeps the old CLI working by
translating its flags into spec overrides:

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --steps 20 --batch 8 --seq 256 [--reduced] [--gamma 2]

is equivalent to

  PYTHONPATH=src python -m repro.experiments run lm_smoke \
      --set model.arch=mamba2-130m --set engine.rounds=20 ...
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-dpu", type=int, default=2)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--gamma", type=int, default=1)
    ap.add_argument("--eta", type=float, default=3e-2)
    ap.add_argument("--mu", type=float, default=0.01)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-sized config variant")
    ap.add_argument("--tree", action="store_true",
                    help="run the per-leaf tree round instead of the "
                         "flat-plane Pallas hot path")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.experiments import get_experiment
    from repro.experiments.lm import run_lm

    spec = get_experiment("lm_smoke").override(**{
        "name": "launch.train",
        "model.arch": args.arch,
        "model.reduced": args.reduced,
        "model.batch": args.batch,
        "model.seq": args.seq,
        "model.n_dpu": args.n_dpu,
        "model.n_micro": args.n_micro,
        "model.gamma": args.gamma,
        "engine.rounds": args.steps,
        "engine.eta": args.eta,
        "engine.mu": args.mu,
        "seeds": (args.seed,),
    })
    result = run_lm(spec, checkpoint=args.checkpoint,
                    use_plane=not args.tree)
    return [r.loss for r in result.reports]


if __name__ == "__main__":
    main()
