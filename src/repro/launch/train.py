"""CE-FL LM training launcher (real execution on local devices).

Runs the mesh-native CE-FL round step — built through the orchestration
engine's :class:`~repro.core.engine.MeshExecutor` — on an actual (small)
mesh: the CPU path that examples and tests use; on a TPU slice the
identical code runs on ``make_production_mesh()``.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --steps 20 --batch 8 --seq 256 [--reduced] [--gamma 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.engine import MeshExecutor
from repro.core.round_step import CEFLHyper, make_dpu_meta
from repro.data import make_token_batches
from repro.kernels.plane import ParamPlane
from repro.models import lm as L
from repro.training.checkpoint import save_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-dpu", type=int, default=2)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--gamma", type=int, default=1)
    ap.add_argument("--eta", type=float, default=3e-2)
    ap.add_argument("--mu", type=float, default=0.01)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-sized config variant")
    ap.add_argument("--tree", action="store_true",
                    help="run the per-leaf tree round instead of the "
                         "flat-plane Pallas hot path")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.n_dpu} DPUs x gamma={args.gamma}")
    key = jax.random.PRNGKey(args.seed)
    params0 = L.init_lm_params(key, cfg, jnp.float32)
    if args.tree:
        params = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (args.n_dpu,) + x.shape),
            params0)
    else:
        # flat-plane hot path: params stay (n_dpu, R, LANE) for the whole
        # run; the tree view is materialized only at the checkpoint
        params = ParamPlane.from_tree(params0).broadcast(args.n_dpu)

    def loss_fn(p, micro, mask):
        return L.lm_loss(p, cfg, micro, example_mask=mask, remat=True,
                         q_block=min(512, args.seq),
                         kv_block=min(512, args.seq))

    hyper = CEFLHyper(eta=args.eta, mu=args.mu,
                      theta=float(args.gamma),   # tau_eff compensation
                      gamma_max=args.gamma, n_micro=args.n_micro)
    step = MeshExecutor().build_step(loss_fn, hyper)   # jitted, donating
    meta = make_dpu_meta(args.n_dpu,
                         gammas=[args.gamma] * args.n_dpu)

    mb = args.batch // (args.n_dpu * args.n_micro)
    losses = []
    for t in range(args.steps):
        b = make_token_batches(
            cfg.vocab_size, args.n_dpu, args.n_micro, mb, args.seq,
            seed=args.seed * 10000 + t,
            enc_seq=cfg.encoder_seq if cfg.is_encdec else 0,
            d_model=cfg.d_model)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.time()
        params, metrics = step(params, b, meta)
        loss = float(metrics["loss"])
        losses.append(loss)
        print(f"  round {t:4d}  loss {loss:8.4f}  ({time.time()-t0:.2f}s)")
    if args.checkpoint:
        final = (params[0].to_tree() if isinstance(params, ParamPlane)
                 else jax.tree_util.tree_map(lambda x: x[0], params))
        save_checkpoint(args.checkpoint, final, step=args.steps)
        print(f"[train] checkpoint -> {args.checkpoint}")
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
