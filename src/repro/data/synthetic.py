"""Deterministic synthetic datasets.

* ``make_image_dataset`` — F-MNIST / CIFAR-10-shaped 10-class image task
  (class-conditional Gaussian blobs over structured templates: learnable but
  not trivial — a linear probe gets ~70-80%, matching the role the real
  datasets play in the paper's tables).  Real downloads are unavailable
  offline; see DESIGN.md §Assumptions.
* ``make_online_ues`` — per-UE OnlineDataset streams (App. G: N(2000,200)
  arrivals, 5-of-10 label support non-iid).
* ``make_token_batches`` — LM token pipeline for the assigned architectures
  (zipf-ish synthetic ids + shifted labels, CE-FL DPU/microbatch layout).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.drift import OnlineDataset


def make_image_dataset(num: int = 20000, shape=(28, 28, 1),
                       num_classes: int = 10, seed: int = 0,
                       noise: float = 0.35):
    """Class-conditional structured images + test split."""
    rng = np.random.RandomState(seed)
    H, W, C = shape
    # class templates: low-frequency random patterns
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float64)
    templates = []
    for c in range(num_classes):
        f1, f2 = rng.uniform(0.5, 3.0, 2)
        p1, p2 = rng.uniform(0, 2 * np.pi, 2)
        t = np.sin(2 * np.pi * f1 * xx / W + p1) \
            * np.cos(2 * np.pi * f2 * yy / H + p2)
        t = t[..., None] * rng.uniform(0.5, 1.0, (1, 1, C))
        templates.append(t)
    templates = np.stack(templates)           # (K, H, W, C)
    y = rng.randint(0, num_classes, num)
    x = templates[y] + noise * rng.randn(num, H, W, C)
    x = x.astype(np.float32)
    n_test = num // 5
    return (x[n_test:], y[n_test:]), (x[:n_test], y[:n_test])


def make_online_ues(train_x, train_y, num_ue: int = 20,
                    labels_per_ue: int = 5, mean_arrivals: float = 2000.0,
                    std_arrivals: float = 200.0, seed: int = 0,
                    drift_labels: bool = False) -> List[OnlineDataset]:
    """App. G non-iid streams: each UE sees 5 of the 10 labels."""
    rng = np.random.RandomState(seed)
    num_classes = int(train_y.max()) + 1
    ues = []
    for n in range(num_ue):
        support = rng.choice(num_classes, labels_per_ue, replace=False)
        ues.append(OnlineDataset(
            features=train_x, labels=train_y, label_support=support,
            mean_arrivals=mean_arrivals, std_arrivals=std_arrivals,
            seed=seed * 1000 + n, drift_labels=drift_labels))
    return ues


def make_token_batches(vocab: int, n_dpu: int, n_micro: int, mb: int,
                       seq: int, seed: int = 0, enc_seq: int = 0,
                       d_model: int = 0):
    """CE-FL-layout LM batch: tokens/labels (n_dpu, n_micro, mb, S)."""
    rng = np.random.RandomState(seed)
    # zipf-ish marginal with local repetition structure
    base = rng.zipf(1.3, (n_dpu, n_micro, mb, seq)).astype(np.int64)
    tokens = (base % vocab).astype(np.int32)
    labels = np.roll(tokens, -1, axis=-1)
    out = {"tokens": tokens, "labels": labels}
    if enc_seq:
        out["enc_embed"] = rng.randn(
            n_dpu, n_micro, mb, enc_seq, d_model).astype(np.float32) * 0.1
    return out
