from repro.data.synthetic import (  # noqa: F401
    make_image_dataset, make_online_ues, make_token_batches,
)
