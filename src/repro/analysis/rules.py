"""The JAX lint rules (RPA001-RPA010), distilled from PR 1-7 incidents.

Each rule is a heuristic AST pass.  The common machinery:

* *traced scope* — a function we believe runs under a JAX trace: it is
  decorated with / wrapped by ``jax.jit`` / ``vmap`` / ``grad`` / ...,
  passed as a body to ``lax.scan`` / ``while_loop`` / ``cond`` /
  ``fori_loop``, or (transitively) called from such a function in the
  same module.
* *taint* — inside a traced scope, the function's parameters (minus
  ``static_argnames``) and everything derived from them are treated as
  traced values; values derived only from ``.shape`` / ``.ndim`` /
  ``.dtype`` / ``len()`` / ``isinstance()`` are static and untainted.

Heuristics can over- or under-approximate — that is what the inline
``# repro: noqa(RULE)`` escape hatch (with a justification) is for; the
known-bad/known-good corpus (:mod:`repro.analysis.corpus`) pins the
intended behavior of every rule.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

# --------------------------------------------------------------- rules --


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    summary: str
    hint: str


RULES: Dict[str, Rule] = {r.code: r for r in [
    Rule("RPA000", "syntax-error",
         "the file does not parse",
         "fix the syntax error; nothing else can be checked"),
    Rule("RPA001", "prng-key-reuse",
         "a PRNG key is consumed by more than one jax.random call",
         "split the key first: `key, sub = jax.random.split(key)` and "
         "consume each subkey exactly once"),
    Rule("RPA002", "prng-split-without-consume",
         "jax.random.split result is discarded",
         "bind the new keys (`key, sub = jax.random.split(key)`); a "
         "discarded split advances nothing and usually shadows a reuse"),
    Rule("RPA003", "host-sync-in-jit",
         "host-side conversion (float/int/bool/.item()/np.asarray) of a "
         "traced value inside a traced scope",
         "keep the value on device (jnp.*) or move the conversion outside "
         "the jitted function; host syncs break tracing or force a "
         "blocking device round-trip"),
    Rule("RPA004", "python-branch-on-traced",
         "Python `if`/`while`/`assert` on a traced value inside a traced "
         "scope",
         "use jnp.where / lax.cond / lax.while_loop, or mark the argument "
         "static; Python control flow on tracers raises "
         "TracerBoolConversionError or bakes in one branch"),
    Rule("RPA005", "mutable-static-arg",
         "mutable/non-hashable default or partial-bound arg on a jitted "
         "function",
         "use hashable values (tuples, frozen dataclasses) for static "
         "args; mutable defaults are shared across calls and unhashable "
         "statics either TypeError or silently retrace per call"),
    Rule("RPA006", "unregistered-dataclass-in-jit",
         "a non-frozen, non-pytree-registered dataclass instance is "
         "passed into a jitted call",
         "register it (jax.tree_util.register_dataclass / "
         "register_pytree_node) or freeze it and pass it static; "
         "unregistered instances are leaves and fail or silently retrace"),
    Rule("RPA007", "module-import-cycle",
         "module-level import cycle inside the package",
         "move one import into the function that needs it or behind "
         "`if TYPE_CHECKING:` (see solver/sca.py); cycles make import "
         "order load-bearing and broke repro.solver<->repro.core in PR 3"),
    Rule("RPA008", "np-on-traced-value",
         "numpy (host) op applied to a traced value inside a traced scope",
         "use the jnp.* equivalent; np.* forces the tracer to concretize "
         "(TracerArrayConversionError) or silently computes on stale "
         "host copies"),
    Rule("RPA009", "callback-in-hot-scan",
         "a host callback (pure_callback / io_callback / jax.debug.print "
         "/ jax.debug.callback / id_tap) inside a lax.scan / fori_loop / "
         "while_loop body",
         "hoist the callback out of the loop or accumulate into the "
         "carry and report after the loop; a per-iteration host "
         "round-trip serializes the scan and blocks fusion (the jaxpr "
         "twin is audit pass JXP005)"),
    Rule("RPA010", "f64-literal-promotion",
         "a float-literal jnp constructor (array/asarray of a float "
         "list/tuple, linspace/logspace/geomspace) without an explicit "
         "dtype",
         "pin the dtype: `jnp.array([0.5], dtype=jnp.float32)`; bare "
         "float-list literals are STRONG-typed and become f64 the "
         "moment jax_enable_x64 flips, widening the whole downstream "
         "graph (the jaxpr twin is audit pass JXP002; Python scalars — "
         "including `jnp.full(shape, 0.5)` fills — stay weak-typed and "
         "are fine)"),
]}


@dataclasses.dataclass(frozen=True)
class RawFinding:
    """A rule hit before noqa filtering (module-local coordinates)."""
    line: int
    col: int
    code: str
    message: str


# ------------------------------------------------------------ helpers --

_TRACE_ENTRY = {"jit", "vmap", "pmap", "grad", "value_and_grad",
                "jacfwd", "jacrev", "hessian", "checkpoint", "remat"}
_LAX_BODY = {"scan", "while_loop", "cond", "fori_loop", "switch", "map",
             "associative_scan", "custom_root", "custom_linear_solve"}
_LOOP_PRIMS = {"scan", "while_loop", "fori_loop", "map",
               "associative_scan"}       # bodies run per iteration
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval",
                 "weak_type"}
_STATIC_CALLS = {"len", "isinstance", "issubclass", "hasattr", "getattr",
                 "type", "id", "repr", "str"}
_KEY_PRODUCERS = {"PRNGKey", "key", "split", "fold_in", "clone", "wrap_key_data"}
_HOST_CASTS = {"float", "int", "bool", "complex"}
_NP_STATIC_OK = {"dtype", "shape", "ndim", "result_type", "promote_types",
                 "broadcast_shapes", "issubdtype", "iinfo", "finfo"}


def _qualname(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain, e.g. ``jax.random.split``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _target_names(target: ast.AST) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(_target_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


class _Aliases:
    """Import aliases of one module: what names mean jax / numpy / etc."""

    def __init__(self, tree: ast.Module):
        self.np: Set[str] = set()
        self.jnp: Set[str] = set()
        self.jax: Set[str] = set()
        self.random: Set[str] = set()       # modules that ARE jax.random
        self.lax: Set[str] = set()
        self.partial: Set[str] = {"functools.partial"}
        self.jit: Set[str] = set()          # bare names that are jax.jit
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "numpy":
                        self.np.add(a.asname or "numpy")
                    elif a.name == "jax.numpy" and a.asname:
                        self.jnp.add(a.asname)
                    elif a.name == "jax":
                        self.jax.add(a.asname or "jax")
                    elif a.name == "jax.random" and a.asname:
                        self.random.add(a.asname)
                    elif a.name == "jax.lax" and a.asname:
                        self.lax.add(a.asname)
                    elif a.name == "functools":
                        self.partial.add((a.asname or "functools")
                                         + ".partial")
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    bound = a.asname or a.name
                    if node.module == "jax":
                        if a.name == "numpy":
                            self.jnp.add(bound)
                        elif a.name == "random":
                            self.random.add(bound)
                        elif a.name == "lax":
                            self.lax.add(bound)
                        elif a.name == "jit":
                            self.jit.add(bound)
                    elif node.module == "functools" and a.name == "partial":
                        self.partial.add(bound)
                    elif node.module == "jax.numpy":
                        pass        # from jax.numpy import x — fine
        for j in self.jax:
            self.random.add(f"{j}.random")
            self.lax.add(f"{j}.lax")
            self.jit.add(f"{j}.jit")

    def is_np_call(self, q: Optional[str]) -> Optional[str]:
        """If ``q`` is ``np.<fn>``, return ``<fn>``."""
        if not q or "." not in q:
            return None
        head, _, rest = q.partition(".")
        return rest if head in self.np and "." not in rest else None

    def is_jnp_call(self, q: Optional[str]) -> Optional[str]:
        """If ``q`` is ``jnp.<fn>`` / ``jax.numpy.<fn>``, return ``<fn>``."""
        if not q or "." not in q:
            return None
        head, _, rest = q.partition(".")
        if head in self.jnp and "." not in rest:
            return rest
        if head in self.jax and rest.startswith("numpy."):
            tail = rest[len("numpy."):]
            return tail if "." not in tail else None
        return None

    def is_random_call(self, q: Optional[str]) -> Optional[str]:
        """If ``q`` is ``jax.random.<fn>`` (any alias), return ``<fn>``."""
        if not q:
            return None
        for prefix in self.random:
            if q.startswith(prefix + "."):
                rest = q[len(prefix) + 1:]
                return rest if "." not in rest else None
        return None

    def is_jit(self, q: Optional[str]) -> bool:
        return q in self.jit

    def trace_entry(self, q: Optional[str]) -> bool:
        """jax.jit/vmap/grad/... wrapper call."""
        if q is None:
            return False
        if q in self.jit:
            return True
        head, _, rest = q.partition(".")
        return head in self.jax and rest in _TRACE_ENTRY

    def lax_body_call(self, q: Optional[str]) -> bool:
        if q is None or "." not in q:
            return False
        head, _, rest = q.rpartition(".")
        return head in self.lax and rest in _LAX_BODY


# ------------------------------------------------- traced-scope finder --

def _decorator_static_argnames(dec: ast.AST) -> Set[str]:
    names: Set[str] = set()
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg == "static_argnames" and isinstance(
                    kw.value, (ast.Tuple, ast.List)):
                for elt in kw.value.elts:
                    if isinstance(elt, ast.Constant) and \
                            isinstance(elt.value, str):
                        names.add(elt.value)
            elif kw.arg == "static_argnames" and isinstance(
                    kw.value, ast.Constant) and \
                    isinstance(kw.value.value, str):
                names.add(kw.value.value)
    return names


class _Module:
    """Per-module analysis state shared by the rules."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.aliases = _Aliases(tree)
        # every function node in the module, by bare name (last def wins
        # is fine for the heuristic)
        self.functions: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, node)
        self.traced: Dict[ast.AST, Set[str]] = {}   # fn node -> static args
        self.jitted_names: Set[str] = set()  # names bound to jitted callables
        self.loop_bodies: Set[ast.AST] = set()  # fn nodes that run per
        #   iteration of a lax loop (scan/fori/while/map/associative_scan)
        self._find_traced()

    def _is_jit_decorator(self, dec: ast.AST) -> bool:
        al = self.aliases
        q = _qualname(dec)
        if al.trace_entry(q):
            return True
        if isinstance(dec, ast.Call):
            q = _qualname(dec.func)
            if al.trace_entry(q):
                return True
            # @partial(jax.jit, ...)
            if q in al.partial and dec.args and \
                    al.trace_entry(_qualname(dec.args[0])):
                return True
        return False

    def _mark(self, name_or_node, static: Set[str] = frozenset()):
        node = self.functions.get(name_or_node) \
            if isinstance(name_or_node, str) else name_or_node
        if node is not None and node not in self.traced:
            self.traced[node] = set(static)

    def _find_traced(self) -> None:
        al = self.aliases
        # 1. decorated functions
        for fn in self.functions.values():
            for dec in fn.decorator_list:
                if self._is_jit_decorator(dec):
                    self._mark(fn, _decorator_static_argnames(dec))
        # 2. wrapper calls: jax.jit(f), jax.vmap(f), lax.scan(f, ...)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            q = _qualname(node.func)
            if al.trace_entry(q) and node.args:
                target = node.args[0]
                static = _decorator_static_argnames(node)
                tq = _qualname(target)
                if tq and "." not in tq:
                    self._mark(tq, static)
                elif isinstance(target, ast.Lambda):
                    self._mark(target, static)
                elif isinstance(target, ast.Call):
                    # jax.jit(partial(f, ...)) / jax.jit(vmap(f))
                    iq = _qualname(target.func)
                    if (iq in al.partial or al.trace_entry(iq)) and \
                            target.args:
                        inner = _qualname(target.args[0])
                        if inner and "." not in inner:
                            self._mark(inner, static)
            elif al.lax_body_call(q):
                prim = q.rpartition(".")[2]
                if prim == "while_loop":
                    bodies = list(node.args[:2])
                elif prim == "fori_loop":
                    # fori_loop(lower, upper, body_fun, init) — the body
                    # is the THIRD positional (args[:1] would mark
                    # `lower`, a silent no-op)
                    bodies = list(node.args[2:3])
                elif prim == "switch":
                    bodies = [e for a in node.args[1:2]
                              for e in (a.elts if isinstance(
                                  a, (ast.List, ast.Tuple)) else [a])]
                else:
                    bodies = list(node.args[:1])
                bodies += [kw.value for kw in node.keywords
                           if kw.arg in ("f", "body_fun", "cond_fun",
                                         "body", "fn")]
                is_loop = prim in _LOOP_PRIMS
                for b in bodies:
                    bq = _qualname(b)
                    target = None
                    if bq and "." not in bq:
                        self._mark(bq)
                        target = self.functions.get(bq)
                    elif isinstance(b, ast.Lambda):
                        self._mark(b)
                        target = b
                    if is_loop and target is not None:
                        self.loop_bodies.add(target)
        # names bound to jitted callables: g = jax.jit(f, ...)
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call) and \
                    al.trace_entry(_qualname(node.value.func)):
                for t in node.targets:
                    self.jitted_names.update(_target_names(t))
        # 3. transitive closure: plain local functions called from a
        #    traced body run under the same trace
        changed = True
        while changed:
            changed = False
            for fn in list(self.traced):
                body = fn.body if isinstance(
                    fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    else [fn.body]
                for node in (n for stmt in body for n in ast.walk(stmt)):
                    if isinstance(node, ast.Call):
                        cq = _qualname(node.func)
                        if cq and "." not in cq and cq in self.functions:
                            callee = self.functions[cq]
                            if callee not in self.traced:
                                self._mark(callee)
                                changed = True
        # 4. loop-body closure: a function called from a per-iteration
        #    body runs per iteration too (RPA009 scope)
        changed = True
        while changed:
            changed = False
            for fn in list(self.loop_bodies):
                body = fn.body if isinstance(
                    fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    else [fn.body]
                for node in (n for stmt in body for n in ast.walk(stmt)):
                    if isinstance(node, ast.Call):
                        cq = _qualname(node.func)
                        if cq and "." not in cq and cq in self.functions:
                            callee = self.functions[cq]
                            if callee not in self.loop_bodies:
                                self.loop_bodies.add(callee)
                                changed = True


# ------------------------------------------------------ taint analysis --

def _fn_params(fn) -> List[str]:
    if isinstance(fn, ast.Lambda):
        a = fn.args
    else:
        a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _expr_tainted(node: ast.AST, tainted: Set[str]) -> bool:
    """Does ``node`` (an expression) derive from a traced value?"""
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return _expr_tainted(node.value, tainted)
    if isinstance(node, ast.Call):
        q = _qualname(node.func)
        if q in _STATIC_CALLS:
            return False
        args = list(node.args) + [kw.value for kw in node.keywords]
        fn_tainted = isinstance(node.func, ast.Attribute) and \
            _expr_tainted(node.func, tainted)
        return fn_tainted or any(_expr_tainted(a, tainted) for a in args)
    if isinstance(node, ast.Constant):
        return False
    if isinstance(node, ast.Compare):
        # `x is None` / `x is not None` are static identity checks
        if len(node.ops) == 1 and isinstance(
                node.ops[0], (ast.Is, ast.IsNot)):
            return False
        return any(_expr_tainted(c, tainted)
                   for c in [node.left] + node.comparators)
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.expr, ast.keyword)):
            inner = child.value if isinstance(child, ast.keyword) else child
            if inner is not None and _expr_tainted(inner, tainted):
                return True
    return False


def _propagate_taint(fn, tainted: Set[str]) -> Set[str]:
    """Two fixpoint passes: names assigned from tainted exprs are tainted."""
    body = fn.body if isinstance(fn, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)) else [fn.body]
    for _ in range(2):
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Assign):
                    if _expr_tainted(node.value, tainted):
                        for t in node.targets:
                            tainted.update(_target_names(t))
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    if node.value is not None and \
                            _expr_tainted(node.value, tainted):
                        tainted.update(_target_names(node.target))
                elif isinstance(node, ast.For):
                    if _expr_tainted(node.iter, tainted):
                        tainted.update(_target_names(node.target))
                elif isinstance(node, ast.withitem):
                    if node.optional_vars is not None and \
                            _expr_tainted(node.context_expr, tainted):
                        tainted.update(_target_names(node.optional_vars))
    return tainted


# ------------------------------------------- per-rule implementations --

def _check_traced_scopes(mod: _Module, findings: List[RawFinding]) -> None:
    """RPA003 (host syncs), RPA004 (python branches), RPA008 (np misuse)."""
    al = mod.aliases
    for fn, static in mod.traced.items():
        tainted = set(_fn_params(fn)) - static
        # inner defs get their own traced entry via the closure pass;
        # don't double-report their bodies here
        inner_fns = {n for stmt in (
            fn.body if isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))
            else [fn.body])
            for n in ast.walk(stmt)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)) and n is not fn}
        skip_lines = set()
        for inner in inner_fns:
            for n in ast.walk(inner):
                if hasattr(n, "lineno"):
                    skip_lines.add(n.lineno)
        if hasattr(fn, "lineno"):
            skip_lines.discard(fn.lineno)
        tainted = _propagate_taint(fn, tainted)
        body = fn.body if isinstance(fn, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)) \
            else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                line = getattr(node, "lineno", None)
                if line is None or line in skip_lines:
                    continue
                if isinstance(node, ast.Call):
                    q = _qualname(node.func)
                    # float(x) / int(x) / bool(x) on a traced value
                    if q in _HOST_CASTS and node.args and \
                            _expr_tainted(node.args[0], tainted):
                        findings.append(RawFinding(
                            node.lineno, node.col_offset, "RPA003",
                            f"`{q}()` on a traced value forces a host "
                            f"sync inside a jitted scope"))
                    # x.item() / x.tolist()
                    elif isinstance(node.func, ast.Attribute) and \
                            node.func.attr in ("item", "tolist") and \
                            _expr_tainted(node.func.value, tainted):
                        findings.append(RawFinding(
                            node.lineno, node.col_offset, "RPA003",
                            f"`.{node.func.attr}()` on a traced value "
                            f"inside a jitted scope"))
                    else:
                        np_fn = al.is_np_call(q)
                        if np_fn and any(
                                _expr_tainted(a, tainted)
                                for a in node.args):
                            if np_fn in ("asarray", "array"):
                                findings.append(RawFinding(
                                    node.lineno, node.col_offset,
                                    "RPA003",
                                    f"`{q}()` materializes a traced "
                                    f"value on host inside a jitted "
                                    f"scope"))
                            elif np_fn not in _NP_STATIC_OK:
                                findings.append(RawFinding(
                                    node.lineno, node.col_offset,
                                    "RPA008",
                                    f"`{q}()` is a host numpy op on a "
                                    f"traced value; use jnp.{np_fn}"))
                elif isinstance(node, (ast.If, ast.While)) and \
                        _expr_tainted(node.test, tainted):
                    kw = "if" if isinstance(node, ast.If) else "while"
                    findings.append(RawFinding(
                        node.lineno, node.col_offset, "RPA004",
                        f"Python `{kw}` on a traced value inside a "
                        f"jitted scope"))
                elif isinstance(node, ast.Assert) and \
                        _expr_tainted(node.test, tainted):
                    findings.append(RawFinding(
                        node.lineno, node.col_offset, "RPA004",
                        "Python `assert` on a traced value inside a "
                        "jitted scope"))


class _KeyState:
    """Per-function PRNG bookkeeping for RPA001/RPA002."""

    def __init__(self):
        self.consumed: Dict[str, Tuple[int, int]] = {}  # name -> (line, col)

    def copy(self) -> "_KeyState":
        st = _KeyState()
        st.consumed = dict(self.consumed)
        return st


def _check_prng(mod: _Module, findings: List[RawFinding]) -> None:
    """RPA001 key reuse and RPA002 discarded splits, per function scope."""
    al = mod.aliases
    seen: Set[Tuple[int, int, str]] = set()

    def emit(line, col, code, msg):
        if (line, col, code) not in seen:
            seen.add((line, col, code))
            findings.append(RawFinding(line, col, code, msg))

    def consume(call: ast.Call, state: _KeyState):
        rf = al.is_random_call(_qualname(call.func))
        if rf is None:
            return
        for arg in call.args[:1]:       # the key is the first positional
            if isinstance(arg, ast.Name):
                name = arg.id
                if name in state.consumed:
                    l0, _ = state.consumed[name]
                    emit(call.lineno, call.col_offset, "RPA001",
                         f"PRNG key `{name}` already consumed at line "
                         f"{l0}; every jax.random call needs a fresh "
                         f"subkey")
                state.consumed[name] = (call.lineno, call.col_offset)

    def scan_expr(node: ast.AST, state: _KeyState):
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                consume(n, state)

    def run_body(body: List[ast.stmt], state: _KeyState):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue        # nested scopes analyzed separately
            if isinstance(stmt, ast.Expr) and isinstance(
                    stmt.value, ast.Call):
                q = _qualname(stmt.value.func)
                if al.is_random_call(q) == "split":
                    emit(stmt.lineno, stmt.col_offset, "RPA002",
                         "jax.random.split result is discarded")
                scan_expr(stmt.value, state)
            elif isinstance(stmt, ast.Assign):
                scan_expr(stmt.value, state)
                names = [n for t in stmt.targets
                         for n in _target_names(t)]
                if names == ["_"] and isinstance(stmt.value, ast.Call) \
                        and al.is_random_call(
                            _qualname(stmt.value.func)) == "split":
                    emit(stmt.lineno, stmt.col_offset, "RPA002",
                         "jax.random.split result is discarded (bound "
                         "to `_`)")
                for n in names:
                    state.consumed.pop(n, None)     # reassignment refreshes
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                if stmt.value is not None:
                    scan_expr(stmt.value, state)
                for n in _target_names(stmt.target):
                    state.consumed.pop(n, None)
            elif isinstance(stmt, ast.If):
                scan_expr(stmt.test, state)
                s1, s2 = state.copy(), state.copy()
                run_body(stmt.body, s1)
                run_body(stmt.orelse, s2)
                state.consumed = {**s1.consumed, **s2.consumed}
            elif isinstance(stmt, (ast.For, ast.While)):
                if isinstance(stmt, ast.For):
                    scan_expr(stmt.iter, state)
                    loop_names = set(_target_names(stmt.target))
                else:
                    scan_expr(stmt.test, state)
                    loop_names = set()
                # two passes: the second catches loop-carried reuse of a
                # key assigned outside the loop
                for _ in range(2):
                    body_state = state.copy()
                    for n in loop_names:
                        body_state.consumed.pop(n, None)
                    run_body(stmt.body, body_state)
                    state.consumed.update({
                        k: v for k, v in body_state.consumed.items()
                        if k not in loop_names})
                run_body(stmt.orelse, state)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    scan_expr(item.context_expr, state)
                run_body(stmt.body, state)
            elif isinstance(stmt, ast.Try):
                run_body(stmt.body, state)
                for h in stmt.handlers:
                    run_body(h.body, state.copy())
                run_body(stmt.orelse, state)
                run_body(stmt.finalbody, state)
            elif isinstance(stmt, ast.Return) and stmt.value is not None:
                scan_expr(stmt.value, state)
            else:
                for n in ast.walk(stmt):
                    if isinstance(n, ast.Call):
                        consume(n, state)

    for fn in mod.functions.values():
        run_body(fn.body, _KeyState())
    # module level too (scripts/benchmarks)
    top = [s for s in mod.tree.body
           if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef))]
    run_body(top, _KeyState())


def _mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        q = _qualname(node.func)
        return q in ("list", "dict", "set", "bytearray") or (
            q is not None and q.split(".")[-1] in ("array", "zeros",
                                                   "ones", "empty")
            and q.split(".")[0] in ("np", "numpy"))
    return False


def _check_static_args(mod: _Module, findings: List[RawFinding]) -> None:
    """RPA005: mutable defaults on jitted functions; mutable partial-bound
    args wrapped in jax.jit."""
    for fn in mod.traced:
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(fn.args.defaults) + [
            d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            if _mutable_literal(d):
                findings.append(RawFinding(
                    d.lineno, d.col_offset, "RPA005",
                    f"jitted function `{fn.name}` has a mutable default "
                    f"argument; as a static arg it is unhashable and as "
                    f"a traced arg it aliases across calls"))
    al = mod.aliases
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and al.trace_entry(_qualname(node.func)) and node.args):
            continue
        target = node.args[0]
        if isinstance(target, ast.Call) and \
                _qualname(target.func) in al.partial:
            bound = list(target.args[1:]) + [kw.value
                                             for kw in target.keywords]
            for b in bound:
                if _mutable_literal(b):
                    findings.append(RawFinding(
                        b.lineno, b.col_offset, "RPA005",
                        "mutable value bound via functools.partial "
                        "under jax.jit; partials hash by bound-arg "
                        "identity, so this retraces per construction"))


def _check_dataclass_pytree(mod: _Module,
                            findings: List[RawFinding]) -> None:
    """RPA006: non-frozen, unregistered dataclass instances into jit."""
    dataclasses_local: Dict[str, ast.ClassDef] = {}
    registered: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            for dec in node.decorator_list:
                q = _qualname(dec) or (
                    _qualname(dec.func) if isinstance(dec, ast.Call)
                    else None)
                if q in ("dataclass", "dataclasses.dataclass",
                         "struct.dataclass", "flax.struct.dataclass"):
                    frozen = isinstance(dec, ast.Call) and any(
                        kw.arg == "frozen" and isinstance(
                            kw.value, ast.Constant) and kw.value.value
                        for kw in dec.keywords)
                    if q in ("struct.dataclass", "flax.struct.dataclass"):
                        registered.add(node.name)
                    elif not frozen:
                        dataclasses_local[node.name] = node
                elif q and q.split(".")[-1] in (
                        "register_pytree_node_class",
                        "register_pytree_with_keys_class"):
                    registered.add(node.name)
        elif isinstance(node, ast.Call):
            q = _qualname(node.func)
            if q and q.split(".")[-1] in (
                    "register_pytree_node", "register_pytree_with_keys",
                    "register_dataclass", "register_static") and node.args:
                reg = _qualname(node.args[0])
                if reg:
                    registered.add(reg.split(".")[-1])
    if not dataclasses_local:
        return
    jitted = set(mod.jitted_names) | {
        fn.name for fn in mod.traced
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        q = _qualname(node.func)
        direct = q in jitted
        # jax.jit(f)(X(...)) — immediate invocation
        if not direct and isinstance(node.func, ast.Call):
            direct = mod.aliases.trace_entry(_qualname(node.func.func))
        if not direct:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Call):
                cq = _qualname(arg.func)
                if cq in dataclasses_local and cq not in registered:
                    findings.append(RawFinding(
                        arg.lineno, arg.col_offset, "RPA006",
                        f"dataclass `{cq}` is passed into jitted "
                        f"`{q}` but is neither frozen (hashable "
                        f"static) nor pytree-registered"))


_CALLBACK_TAILS = {"pure_callback", "io_callback", "id_tap", "id_print"}
_DEBUG_TAILS = {("debug", "print"), ("debug", "callback"),
                ("debug", "breakpoint")}


def _check_loop_callbacks(mod: _Module,
                          findings: List[RawFinding]) -> None:
    """RPA009: host callbacks inside per-iteration lax loop bodies."""
    seen: Set[Tuple[int, int]] = set()
    for fn in mod.loop_bodies:
        body = fn.body if isinstance(fn, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)) \
            else [fn.body]
        for node in (n for stmt in body for n in ast.walk(stmt)):
            if not isinstance(node, ast.Call):
                continue
            q = _qualname(node.func)
            if not q:
                continue
            parts = q.split(".")
            if parts[-1] in _CALLBACK_TAILS or \
                    tuple(parts[-2:]) in _DEBUG_TAILS:
                key = (node.lineno, node.col_offset)
                if key not in seen:
                    seen.add(key)
                    findings.append(RawFinding(
                        node.lineno, node.col_offset, "RPA009",
                        f"`{q}` runs a host round-trip on EVERY "
                        f"iteration of a lax loop body"))


# dtype's positional slot; `full` is deliberately absent — a Python-
# scalar fill keeps the result WEAK-typed (verified on jax 0.4.37), so
# it cannot widen anything
_RPA010_DTYPE_POS = {"array": 1, "asarray": 1}
_RPA010_FACTORIES = {"linspace", "logspace", "geomspace"}


def _float_literal_in(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Constant) and isinstance(n.value, float)
               for n in ast.walk(node))


def _check_f64_literals(mod: _Module,
                        findings: List[RawFinding]) -> None:
    """RPA010: strong-typed float literals with no explicit dtype.

    Module-wide (not just traced scopes): a bare float-list constant
    anywhere becomes a strong f64 under ``jax_enable_x64`` and widens
    whatever consumes it.  Python scalars (and ``jnp.asarray(0.5)`` of
    one) stay weak-typed and are deliberately NOT flagged.
    """
    al = mod.aliases
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fn_name = al.is_jnp_call(_qualname(node.func))
        if fn_name is None:
            continue
        if any(kw.arg == "dtype" for kw in node.keywords):
            continue
        if fn_name in _RPA010_DTYPE_POS:
            if len(node.args) > _RPA010_DTYPE_POS[fn_name]:
                continue            # dtype passed positionally
            literal = bool(node.args) and isinstance(
                node.args[0], (ast.List, ast.Tuple)) and \
                _float_literal_in(node.args[0])
            if literal:
                findings.append(RawFinding(
                    node.lineno, node.col_offset, "RPA010",
                    f"`{fn_name}` of a float literal without dtype is "
                    f"STRONG-typed: it becomes f64 and widens the "
                    f"graph under jax_enable_x64"))
        elif fn_name in _RPA010_FACTORIES and any(
                _float_literal_in(a) for a in node.args):
            findings.append(RawFinding(
                node.lineno, node.col_offset, "RPA010",
                f"`{fn_name}` with float-literal bounds and no dtype "
                f"defaults to f64 under jax_enable_x64"))


# -------------------------------------------------------- module pass --

def module_findings(tree: ast.Module) -> List[RawFinding]:
    """All single-module rule findings (everything except RPA007)."""
    mod = _Module(tree)
    findings: List[RawFinding] = []
    _check_traced_scopes(mod, findings)
    _check_prng(mod, findings)
    _check_static_args(mod, findings)
    _check_dataclass_pytree(mod, findings)
    _check_loop_callbacks(mod, findings)
    _check_f64_literals(mod, findings)
    findings.sort(key=lambda f: (f.line, f.col, f.code))
    return findings


# ------------------------------------------------ import-cycle (RPA007) --

def _module_level_imports(tree: ast.Module):
    """(node, stmt) for imports executed at module import time, skipping
    `if TYPE_CHECKING:` guards (the sanctioned cycle-free annotation
    pattern) and anything inside a function/class body."""

    def walk(stmts):
        for stmt in stmts:
            if isinstance(stmt, (ast.Import, ast.ImportFrom)):
                yield stmt
            elif isinstance(stmt, ast.If):
                q = _qualname(stmt.test)
                if q and q.split(".")[-1] == "TYPE_CHECKING":
                    yield from walk(stmt.orelse)
                else:
                    yield from walk(stmt.body)
                    yield from walk(stmt.orelse)
            elif isinstance(stmt, ast.Try):
                yield from walk(stmt.body)
                for h in stmt.handlers:
                    yield from walk(h.body)
                yield from walk(stmt.orelse)
                yield from walk(stmt.finalbody)
            elif isinstance(stmt, (ast.With,)):
                yield from walk(stmt.body)

    yield from walk(tree.body)


def import_edges(modname: str, tree: ast.Module,
                 known: Set[str]):
    """Edges (target_module, line) from module-level imports, restricted
    to modules in ``known`` (the linted set)."""
    pkg_parts = modname.split(".")[:-1]
    for stmt in _module_level_imports(tree):
        if isinstance(stmt, ast.Import):
            for a in stmt.names:
                name = a.name
                while name:
                    if name in known:
                        if not modname.startswith(name + "."):
                            yield name, stmt.lineno
                        break
                    name = name.rpartition(".")[0]
        else:
            if stmt.level:      # relative import
                base = pkg_parts[:len(pkg_parts) - (stmt.level - 1)]
                root = ".".join(base)
            else:
                root = stmt.module or ""
            candidates = []
            if stmt.level and stmt.module:
                root = f"{root}.{stmt.module}" if root else stmt.module
            for a in stmt.names:
                candidates.append(f"{root}.{a.name}" if root else a.name)
            if root:
                candidates.append(root)
            hit = set()
            for cand in candidates:
                name = cand
                while name:
                    if name in known:
                        # a submodule importing from an ancestor package
                        # (`from repro.core import api` inside
                        # repro.core.engine) resolves to the sibling
                        # submodule, not to the package __init__ — the
                        # idiomatic re-export pattern is not a cycle
                        if name not in hit and \
                                not modname.startswith(name + "."):
                            hit.add(name)
                            yield name, stmt.lineno
                        break
                    name = name.rpartition(".")[0]


def find_cycles(graph: Dict[str, Dict[str, int]]):
    """Strongly connected components with >1 node (or a self edge):
    yields (members, {module: line-of-offending-import})."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    counter = [0]
    sccs: List[List[str]] = []

    def strongconnect(v: str):
        work = [(v, iter(sorted(graph.get(v, {}))))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(sorted(graph.get(w, {})))))
                    advanced = True
                    break
                elif w in on_stack:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == node:
                        break
                sccs.append(comp)

    for v in sorted(graph):
        if v not in index:
            strongconnect(v)
    for comp in sccs:
        members = set(comp)
        if len(comp) > 1 or (comp and comp[0] in graph.get(comp[0], {})):
            lines = {}
            for m in comp:
                for target, line in sorted(graph.get(m, {}).items()):
                    if target in members:
                        lines[m] = line
                        break
            yield sorted(members), lines
