"""Known-bad / known-good snippet corpus: the executable spec of every
lint rule.

``python -m repro.analysis selftest`` (and tests/test_analysis.py)
asserts that each ``bad`` snippet triggers its rule and each ``good``
snippet does not.  When a rule's heuristic changes, this corpus is what
must keep passing — add a snippet here for every false positive/negative
found in the wild before changing the rule.

RPA007 (import cycles) is cross-module: its corpus entries are
``{path: source}`` file sets instead of single sources.
"""
from __future__ import annotations

from typing import Dict, List

# Single-module snippets: rule code -> {"bad": [...], "good": [...]}.
# Every snippet is a complete module.
CORPUS: Dict[str, Dict[str, List[str]]] = {
    "RPA001": {
        "bad": [
            # the classic: one key, two draws
            """
import jax

def sample(key):
    a = jax.random.normal(key, (3,))
    b = jax.random.uniform(key, (3,))
    return a + b
""",
            # reuse across loop iterations: key defined outside the loop
            """
import jax

def rollout(key, steps):
    out = []
    for _ in range(steps):
        out.append(jax.random.normal(key, ()))
    return out
""",
            # consumed by split, then consumed again by a draw
            """
import jax

def draw(key):
    key2, sub = jax.random.split(key)
    noise = jax.random.normal(key, (4,))
    return noise, sub
""",
        ],
        "good": [
            # split-then-consume, each subkey once
            """
import jax

def sample(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (3,))
    b = jax.random.uniform(k2, (3,))
    return a + b
""",
            # fresh key per iteration via reassignment
            """
import jax

def rollout(key, steps):
    out = []
    for _ in range(steps):
        key, sub = jax.random.split(key)
        out.append(jax.random.normal(sub, ()))
    return out
""",
            # one consumption per branch is not a reuse
            """
import jax

def draw(key, gaussian):
    if gaussian:
        return jax.random.normal(key, ())
    else:
        return jax.random.uniform(key, ())
""",
            # per-element keys from an indexed array are fresh
            """
import jax

def draws(key, n):
    keys = jax.random.split(key, n)
    return [jax.random.normal(keys[i], ()) for i in range(n)]
""",
        ],
    },
    "RPA002": {
        "bad": [
            """
import jax

def advance(key):
    jax.random.split(key)
    return jax.random.normal(key, ())
""",
            """
import jax

def advance(key):
    _ = jax.random.split(key)
    return key
""",
        ],
        "good": [
            """
import jax

def advance(key):
    key, sub = jax.random.split(key)
    return jax.random.normal(sub, ())
""",
        ],
    },
    "RPA003": {
        "bad": [
            # float() of a traced reduction inside a jitted function
            """
import jax
import jax.numpy as jnp

@jax.jit
def loss(x):
    return float(jnp.mean(x ** 2))
""",
            # .item() inside a scan body
            """
import jax
import jax.numpy as jnp

def run(xs):
    def body(carry, x):
        carry = carry + x.item()
        return carry, carry
    return jax.lax.scan(body, 0.0, xs)
""",
            # np.asarray materializes the tracer on host
            """
import jax
import numpy as np

@jax.jit
def norm(x):
    return np.asarray(x).sum()
""",
        ],
        "good": [
            # float() of a static scalar argument is fine
            """
import jax
import functools

@functools.partial(jax.jit, static_argnames=("scale",))
def scaled(x, scale):
    return x * float(scale)
""",
            # host conversion outside the jitted scope
            """
import jax
import jax.numpy as jnp

@jax.jit
def loss(x):
    return jnp.mean(x ** 2)

def eval_loss(x):
    return float(loss(x))
""",
        ],
    },
    "RPA004": {
        "bad": [
            """
import jax

@jax.jit
def relu(x):
    if x > 0:
        return x
    return 0.0
""",
            # while on a traced value inside a jitted helper
            """
import jax

@jax.jit
def drain(x):
    while x > 0:
        x = x - 1
    return x
""",
        ],
        "good": [
            # branching on shape/ndim is static under jit
            """
import jax
import jax.numpy as jnp

@jax.jit
def maybe_flatten(x):
    if x.ndim > 2:
        return x.reshape(x.shape[0], -1)
    return x
""",
            # branching on a static argument
            """
import jax
import functools

@functools.partial(jax.jit, static_argnames=("mode",))
def act(x, mode):
    if mode == "relu":
        return jax.numpy.maximum(x, 0)
    return x
""",
            # lax.cond is the traced-friendly branch
            """
import jax
import jax.numpy as jnp

@jax.jit
def relu(x):
    return jnp.where(x > 0, x, 0.0)
""",
            # `is None` dispatch on an optional arg is static
            """
import jax

@jax.jit
def shift(x, offset=None):
    if offset is None:
        return x
    return x + offset
""",
        ],
    },
    "RPA005": {
        "bad": [
            # mutable default on a jitted function
            """
import jax

@jax.jit
def apply(x, dims=[0, 1]):
    return x.sum()
""",
            # mutable value bound via partial under jit
            """
import jax
import functools

def f(cfg, x):
    return x * cfg["scale"]

g = jax.jit(functools.partial(f, {"scale": 2.0}))
""",
        ],
        "good": [
            # hashable tuple default
            """
import jax
import functools

@functools.partial(jax.jit, static_argnames=("dims",))
def apply(x, dims=(0, 1)):
    return x.sum(dims)
""",
            # partial binding a hashable scalar
            """
import jax
import functools

def f(scale, x):
    return x * scale

g = jax.jit(functools.partial(f, 2.0))
""",
        ],
    },
    "RPA006": {
        "bad": [
            """
import dataclasses
import jax


@dataclasses.dataclass
class State:
    x: object
    step: int


@jax.jit
def advance(state):
    return state


def main(x):
    return advance(State(x=x, step=0))
""",
        ],
        "good": [
            # registered via register_dataclass
            """
import dataclasses
import jax


@dataclasses.dataclass
class State:
    x: object
    step: int


jax.tree_util.register_dataclass(
    State, data_fields=["x"], meta_fields=["step"])


@jax.jit
def advance(state):
    return state


def main(x):
    return advance(State(x=x, step=0))
""",
            # frozen dataclass: hashable, usable as a static arg
            """
import dataclasses
import jax


@dataclasses.dataclass(frozen=True)
class Hyper:
    eta: float


@jax.jit
def advance(x, hyper):
    return x * hyper.eta


def main(x):
    return advance(x, Hyper(eta=0.1))
""",
        ],
    },
    "RPA008": {
        "bad": [
            # np reduction on a traced value
            """
import jax
import numpy as np

@jax.jit
def mean_loss(x):
    return np.mean(x ** 2)
""",
            # np inside a vmapped helper
            """
import jax
import numpy as np

def per_row(x):
    return np.clip(x, 0.0, 1.0)

batched = jax.vmap(per_row)
""",
        ],
        "good": [
            # jnp on traced values
            """
import jax
import jax.numpy as jnp

@jax.jit
def mean_loss(x):
    return jnp.mean(x ** 2)
""",
            # np on host-side static values inside a jitted scope
            """
import jax
import numpy as np

@jax.jit
def pad_to(x):
    width = np.maximum(8, x.shape[0])
    return x.sum() + width
""",
            # np use outside any traced scope
            """
import numpy as np

def host_stats(x):
    return np.mean(x), np.std(x)
""",
        ],
    },
    "RPA009": {
        "bad": [
            # debug.print inside a scan body — per-iteration host trip
            """
import jax
import jax.numpy as jnp
from jax import lax


def body(carry, x):
    jax.debug.print("step {}", x)
    return carry + x, None


def run(xs):
    return lax.scan(body, jnp.zeros(()), xs)
""",
            # pure_callback in a fori_loop body (third positional arg)
            """
import jax
import jax.numpy as jnp
from jax import lax


def log_host(x):
    return x


def body(i, acc):
    v = jax.pure_callback(log_host, jax.ShapeDtypeStruct((), jnp.float32),
                          acc)
    return acc + v


def run(n):
    return lax.fori_loop(0, n, body, jnp.zeros(()))
""",
            # transitively: helper called from a while_loop body
            """
import jax
from jax import lax
from jax.experimental import io_callback


def report(x):
    io_callback(print, None, x)
    return x


def body(x):
    return report(x) - 1.0


def run(x):
    return lax.while_loop(lambda x: x > 0, body, x)
""",
        ],
        "good": [
            # callback outside the loop — one host trip per call
            """
import jax
import jax.numpy as jnp
from jax import lax


def body(carry, x):
    return carry + x, None


def run(xs):
    out, _ = lax.scan(body, jnp.zeros(()), xs)
    jax.debug.print("total {}", out)
    return out
""",
            # plain jnp math inside the body
            """
import jax.numpy as jnp
from jax import lax


def body(i, acc):
    return acc + jnp.sin(i.astype(jnp.float32))


def run(n):
    return lax.fori_loop(0, n, body, jnp.zeros(()))
""",
        ],
    },
    "RPA010": {
        "bad": [
            # float list literal, no dtype: strong f64 under x64
            """
import jax.numpy as jnp

SCALES = jnp.array([0.5, 1.0, 2.0])
""",
            # asarray of a float tuple literal
            """
import jax.numpy as jnp


def grid():
    return jnp.asarray((0.1, 0.2))
""",
            # linspace with float bounds and no dtype
            """
import jax.numpy as jnp


def axis():
    return jnp.linspace(0.0, 1.0, 16)
""",
        ],
        "good": [
            # full with a Python-scalar fill stays WEAK-typed — safe
            """
import jax.numpy as jnp


def fill(n):
    return jnp.full((n,), 0.5)
""",
            # pinned dtype
            """
import jax.numpy as jnp

SCALES = jnp.array([0.5, 1.0, 2.0], dtype=jnp.float32)
""",
            # dtype passed positionally
            """
import jax.numpy as jnp


def weights(ds):
    return jnp.asarray(ds, jnp.float32)
""",
            # Python scalar stays weak-typed — safe by design
            """
import jax.numpy as jnp


def half():
    return jnp.asarray(0.5)
""",
            # int literals never promote to f64
            """
import jax.numpy as jnp

IDX = jnp.array([0, 1, 2])
""",
            # factory with explicit dtype keyword
            """
import jax.numpy as jnp


def axis():
    return jnp.linspace(0.0, 1.0, 16, dtype=jnp.float32)
""",
        ],
    },
}

# Cross-module corpora for RPA007: name -> {"files": {...}, "expect": bool}
CYCLE_CORPUS: Dict[str, dict] = {
    "two_module_cycle": {
        "expect": True,
        "files": {
            "src/repro/pkg_a/__init__.py": "",
            "src/repro/pkg_a/alpha.py":
                "from repro.pkg_a.beta import helper\n\n"
                "def entry():\n    return helper()\n",
            "src/repro/pkg_a/beta.py":
                "import repro.pkg_a.alpha\n\n"
                "def helper():\n    return repro.pkg_a.alpha\n",
        },
    },
    "type_checking_guard_is_fine": {
        "expect": False,
        "files": {
            "src/repro/pkg_b/__init__.py": "",
            "src/repro/pkg_b/alpha.py":
                "from typing import TYPE_CHECKING\n\n"
                "if TYPE_CHECKING:\n"
                "    from repro.pkg_b.beta import Helper\n\n"
                "def entry(h):\n    return h\n",
            "src/repro/pkg_b/beta.py":
                "from repro.pkg_b.alpha import entry\n\n"
                "class Helper:\n    run = staticmethod(entry)\n",
        },
    },
    "function_local_import_is_fine": {
        "expect": False,
        "files": {
            "src/repro/pkg_c/__init__.py": "",
            "src/repro/pkg_c/alpha.py":
                "def entry():\n"
                "    from repro.pkg_c.beta import helper\n"
                "    return helper()\n",
            "src/repro/pkg_c/beta.py":
                "from repro.pkg_c.alpha import entry\n\n"
                "def helper():\n    return entry\n",
        },
    },
}
