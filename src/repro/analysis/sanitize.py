"""Runtime sanitizers: retrace guard, PRNG-key-reuse detector, NaN/Inf.

The static linter (:mod:`repro.analysis.rules`) catches what an AST can
see; this module catches what only a run can:

* :class:`CompileMonitor` / :func:`no_retrace` — count actual XLA
  compilations and jaxpr traces through ``jax.monitoring`` events.  One
  module-level listener increments global counters (registered once,
  never unregistered — listener APIs differ across jax versions);
  monitors snapshot the counters, so nesting is free.  This generalizes
  the PR-3/PR-4 bespoke ``sca.jit_cache_size()`` probes: the event
  counter sees EVERY jit cache in the process, not one module's.
* :class:`KeyReuseDetector` — wraps the consuming ``jax.random``
  functions and records every concrete (host-side) key that passes
  through; consuming the same key twice raises.  Traced keys are
  skipped: inside a jit the static rule (RPA001) is the defense.
* :func:`check_finite` — NaN/Inf sweep over a pytree / ParamPlane.

``EngineOptions(sanitize=True)`` turns all three on for a run (see
``repro.core.engine``); the ``assert_no_retrace`` pytest fixture
(:mod:`repro.analysis.pytest_plugin`) exposes the retrace guard to
tests.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional

import jax
import numpy as np


class SanitizerError(AssertionError):
    """A runtime sanitizer tripped (key reuse, NaN/Inf, retrace)."""


# ------------------------------------------------- compile monitoring --

_BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"

_counts_lock = threading.Lock()
_COUNTS: Dict[str, int] = {"backend_compile": 0, "jaxpr_trace": 0}
_LISTENER_REGISTERED = False


def _on_event_duration(event: str, duration: float, **_kw) -> None:
    if event == _BACKEND_COMPILE_EVENT:
        with _counts_lock:
            _COUNTS["backend_compile"] += 1
    elif event == _TRACE_EVENT:
        with _counts_lock:
            _COUNTS["jaxpr_trace"] += 1


def _ensure_listener() -> None:
    global _LISTENER_REGISTERED
    if not _LISTENER_REGISTERED:
        jax.monitoring.register_event_duration_secs_listener(
            _on_event_duration)
        _LISTENER_REGISTERED = True


def compile_counts() -> Dict[str, int]:
    """Process-lifetime counters of backend compiles / jaxpr traces."""
    _ensure_listener()
    with _counts_lock:
        return dict(_COUNTS)


@dataclasses.dataclass
class CompileMonitor:
    """Counts backend compiles / jaxpr traces inside a ``with`` block.

    >>> with CompileMonitor() as mon:
    ...     f(x)                      # warm call
    >>> mon.compiles, mon.traces
    (0, 0)

    ``compiles`` is the number of XLA backend compilations — the
    expensive event a no-retrace guarantee pins to zero.  ``traces``
    counts jaxpr traces, which also fire for cache-hitting wrappers
    (e.g. new closures over the same computation), so it is reported
    for diagnostics but not asserted on by default.
    """
    compiles: int = 0
    traces: int = 0
    _start: Optional[Dict[str, int]] = None

    def __enter__(self) -> "CompileMonitor":
        self._start = compile_counts()
        return self

    def __exit__(self, *exc) -> None:
        self.refresh()

    def refresh(self) -> "CompileMonitor":
        now = compile_counts()
        assert self._start is not None, "monitor not entered"
        self.compiles = now["backend_compile"] - \
            self._start["backend_compile"]
        self.traces = now["jaxpr_trace"] - self._start["jaxpr_trace"]
        return self


@contextlib.contextmanager
def no_retrace(what: str = "block", *, allow_compiles: int = 0):
    """Assert that a block triggers no (or at most ``allow_compiles``)
    XLA backend compilations — the post-warmup no-retrace contract.

    Raises :class:`SanitizerError` naming the offending block; yields
    the :class:`CompileMonitor` for extra assertions.
    """
    with CompileMonitor() as mon:
        yield mon
    mon.refresh()
    if mon.compiles > allow_compiles:
        raise SanitizerError(
            f"{what}: {mon.compiles} backend compile(s) "
            f"(allowed {allow_compiles}), {mon.traces} jaxpr trace(s) — "
            f"a warm path retraced; check for changing static args, "
            f"weak-type flips, or unhashed cache keys")


# ---------------------------------------------- PRNG reuse detection --

# jax.random functions that consume a key as their first argument
_CONSUMING_FNS = (
    "split", "fold_in", "bits", "uniform", "normal", "bernoulli",
    "randint", "choice", "permutation", "categorical", "gumbel",
    "truncated_normal", "laplace", "exponential", "gamma", "beta",
    "dirichlet", "poisson", "rademacher", "cauchy", "logistic",
)


def _concrete_key_bytes(key) -> Optional[bytes]:
    """Stable bytes of a concrete key; None for tracers / non-keys."""
    if isinstance(key, jax.core.Tracer):
        return None
    try:
        arr = np.asarray(key)
    except Exception:
        return None
    if arr.dtype == np.uint32 and arr.ndim == 1 and arr.size in (2, 4):
        return arr.tobytes()
    if arr.dtype.kind == "V" or str(arr.dtype).startswith("key"):
        # typed PRNG keys: go through the raw key data
        try:
            return np.asarray(jax.random.key_data(key)).tobytes()
        except Exception:
            return None
    return None


class KeyReuseDetector:
    """Context manager: raise (or record) when one concrete PRNG key is
    consumed by two ``jax.random`` calls.

    >>> with KeyReuseDetector():
    ...     k = jax.random.PRNGKey(0)
    ...     jax.random.normal(k, ())
    ...     jax.random.uniform(k, ())      # raises SanitizerError

    ``mode="record"`` collects ``.reuses`` instead of raising (the
    engine's sanitize report path).  Detection is host-side only: keys
    that are tracers (inside jit/vmap) are skipped — the static rule
    RPA001 covers those.
    """

    def __init__(self, mode: str = "raise"):
        assert mode in ("raise", "record")
        self.mode = mode
        self.reuses: list = []
        self._seen: Dict[bytes, str] = {}
        self._originals: Dict[str, object] = {}

    def _wrap(self, name: str, fn):
        detector = self

        def wrapped(*args, **kwargs):
            key = args[0] if args else kwargs.get("key")
            kb = _concrete_key_bytes(key) if key is not None else None
            if kb is not None:
                prev = detector._seen.get(kb)
                if prev is not None:
                    reuse = (f"PRNG key consumed twice: jax.random.{name} "
                             f"got a key already consumed by "
                             f"jax.random.{prev}")
                    detector.reuses.append(reuse)
                    if detector.mode == "raise":
                        raise SanitizerError(
                            reuse + " — split the key and consume each "
                            "subkey exactly once")
                else:
                    detector._seen[kb] = name
            return fn(*args, **kwargs)

        wrapped.__name__ = getattr(fn, "__name__", name)
        return wrapped

    def __enter__(self) -> "KeyReuseDetector":
        for name in _CONSUMING_FNS:
            fn = getattr(jax.random, name, None)
            if fn is not None and name not in self._originals:
                self._originals[name] = fn
                setattr(jax.random, name, self._wrap(name, fn))
        return self

    def __exit__(self, *exc) -> None:
        for name, fn in self._originals.items():
            setattr(jax.random, name, fn)
        self._originals.clear()


# --------------------------------------------------------- NaN / Inf --

def check_finite(tree, what: str = "value") -> None:
    """Raise :class:`SanitizerError` if any array leaf has NaN/Inf.

    Accepts pytrees and ParamPlane (a registered pytree).  One fused
    reduction per leaf; the host sync happens only in sanitize mode, by
    design — this is a debugging net, not a hot path.
    """
    import jax.numpy as jnp
    bad = []
    leaves, _ = jax.tree_util.tree_flatten(tree)
    for i, leaf in enumerate(leaves):
        if not hasattr(leaf, "dtype") or not np.issubdtype(
                np.dtype(leaf.dtype), np.floating):
            continue
        if not bool(jnp.isfinite(leaf).all()):
            bad.append(i)
    if bad:
        raise SanitizerError(
            f"{what}: non-finite values in leaf indices {bad} — enable "
            f"jax_debug_nans or bisect the round to locate the source")
