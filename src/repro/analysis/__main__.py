"""CLI: ``python -m repro.analysis <command>``.

Commands:

* ``lint PATHS...`` — run the JAX lint rules over files/directories.
  Exit 0 when clean (suppressions honored), 1 when findings remain.
  ``--json`` for machine-readable output, ``--out FILE`` to also write
  the report to a file (the CI artifact), ``--select RPA001,RPA004`` to
  restrict rules, ``--no-hints`` for compact output.
* ``selftest`` — run every rule against its known-bad/known-good corpus
  (:mod:`repro.analysis.corpus`); exit 1 on any miss.  This is the
  linter's own tier-1 gate in CI.
* ``rules`` — print the rule catalogue.
* ``audit`` — trace every registered hot-path contract and run the
  jaxpr/HLO passes (:mod:`repro.analysis.jaxpr`).  Exit 1 on any
  violation.  ``--devices N`` forces N virtual CPU devices (the sharded
  contracts need 8); ``--select NAME,...`` restricts contracts;
  ``--passes JXP001,...`` restricts passes; ``--json``/``--out`` as for
  ``lint``.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.corpus import CORPUS, CYCLE_CORPUS
from repro.analysis.linter import (lint_paths, lint_project, lint_source,
                                   render_findings)
from repro.analysis.rules import RULES


def _cmd_lint(args) -> int:
    select = args.select.split(",") if args.select else None
    findings = lint_paths(args.paths, select=select)
    report = render_findings(findings,
                             fmt="json" if args.json else "text",
                             hints=not args.no_hints)
    print(report)
    if args.out:
        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(report + "\n")
    return 1 if findings else 0


def _cmd_selftest(_args) -> int:
    failures = []
    for code, cases in sorted(CORPUS.items()):
        for kind in ("bad", "good"):
            for i, snippet in enumerate(cases.get(kind, [])):
                hits = {f.code for f in lint_source(snippet)}
                if kind == "bad" and code not in hits:
                    failures.append(f"{code} bad[{i}]: expected a "
                                    f"{code} finding, got {sorted(hits)}")
                elif kind == "good" and code in hits:
                    failures.append(f"{code} good[{i}]: unexpected "
                                    f"{code} finding")
    for name, case in sorted(CYCLE_CORPUS.items()):
        hits = {f.code for f in lint_project(case["files"],
                                             select=["RPA007"])}
        if case["expect"] and "RPA007" not in hits:
            failures.append(f"RPA007 {name}: expected a cycle finding")
        elif not case["expect"] and "RPA007" in hits:
            failures.append(f"RPA007 {name}: unexpected cycle finding")
    n_bad = sum(len(c.get("bad", [])) for c in CORPUS.values())
    n_good = sum(len(c.get("good", [])) for c in CORPUS.values())
    if failures:
        print("\n".join(failures))
        print(f"selftest FAILED: {len(failures)} corpus miss(es)")
        return 1
    print(f"selftest OK: {n_bad} known-bad + {n_good} known-good "
          f"snippets, {len(CYCLE_CORPUS)} cycle corpora, "
          f"{len(RULES) - 1} rules")
    return 0


def _cmd_audit(args) -> int:
    if args.devices:
        # must land before the backend initializes; jax initializes its
        # CPU client lazily, so setting the flag here (pre-first-trace)
        # is sufficient even though repro.analysis imported jax already
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    import json as _json

    from repro.analysis.jaxpr import render_report, run_audit
    select = args.select.split(",") if args.select else None
    pass_ids = args.passes.split(",") if args.passes else None
    report = run_audit(select=select, pass_ids=pass_ids)
    text = (_json.dumps(report.to_json(), indent=2) if args.json
            else render_report(report, hints=not args.no_hints))
    print(text)
    if args.out:
        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(text + "\n")
    return 0 if report.ok else 1


def _cmd_rules(_args) -> int:
    for code, rule in sorted(RULES.items()):
        if code == "RPA000":
            continue
        print(f"{code} [{rule.name}]\n    {rule.summary}\n"
              f"    hint: {rule.hint}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-native static analysis for the repro codebase")
    sub = parser.add_subparsers(dest="command", required=True)
    p_lint = sub.add_parser("lint", help="lint files/directories")
    p_lint.add_argument("paths", nargs="+")
    p_lint.add_argument("--json", action="store_true")
    p_lint.add_argument("--out", default=None,
                        help="also write the report to this file")
    p_lint.add_argument("--select", default=None,
                        help="comma-separated rule codes to run")
    p_lint.add_argument("--no-hints", action="store_true")
    p_lint.set_defaults(fn=_cmd_lint)
    p_self = sub.add_parser("selftest",
                            help="check every rule against its corpus")
    p_self.set_defaults(fn=_cmd_selftest)
    p_rules = sub.add_parser("rules", help="print the rule catalogue")
    p_rules.set_defaults(fn=_cmd_rules)
    p_audit = sub.add_parser(
        "audit", help="trace registered contracts, run jaxpr/HLO passes")
    p_audit.add_argument("--json", action="store_true")
    p_audit.add_argument("--out", default=None,
                         help="also write the report to this file")
    p_audit.add_argument("--select", default=None,
                         help="comma-separated contract names")
    p_audit.add_argument("--passes", default=None,
                         help="comma-separated pass ids (JXP001,...)")
    p_audit.add_argument("--devices", type=int, default=None,
                         help="force N virtual CPU devices (sharded "
                              "contracts need 8)")
    p_audit.add_argument("--no-hints", action="store_true")
    p_audit.set_defaults(fn=_cmd_audit)
    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
