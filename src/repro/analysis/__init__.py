"""JAX-native static analysis + runtime sanitizers for the repro codebase.

Every PR so far hand-fixed an instance of the same JAX hazard classes:
module-level import cycles (PR 3), silent retraces of per-round re-solves
(PR 3/4), PRNG stream-order audits to keep runs bit-exact (PR 2/5), and
host syncs hiding on eval paths.  This package turns those one-off
defenses into standing, CI-enforced rules:

* :mod:`repro.analysis.rules` + :mod:`repro.analysis.linter` — an
  AST-based linter (``python -m repro.analysis lint src/``) with
  JAX-specific rules (RPA001-RPA008) distilled from the repo's own bug
  history, inline ``# repro: noqa(RULE)`` suppression, and a self-test
  corpus of known-bad/known-good snippets
  (``python -m repro.analysis selftest``).
* :mod:`repro.analysis.sanitize` — runtime companions: a
  compile-count/retrace guard over ``jax.monitoring`` events
  (:class:`CompileMonitor`, the ``assert_no_retrace`` pytest fixture in
  :mod:`repro.analysis.pytest_plugin`), a PRNG-key-reuse detector, and
  NaN/Inf checks — all switched on end-to-end by
  ``EngineOptions(sanitize=True)``.
* :mod:`repro.analysis.jaxpr` — the trace-level program auditor
  (``python -m repro.analysis audit``): hot-path entry points register
  tiny-shape contracts at their definition sites; five passes
  (JXP001-JXP005) check collectives, dtype discipline, memory budgets,
  buffer donation, and fusion boundaries over the jaxprs and lowered
  StableHLO that XLA actually sees.

See ``docs/static_analysis.md`` for the rule catalogue and the PR-1..5
incidents that motivated each rule.
"""
from repro.analysis.linter import (Finding, lint_paths, lint_project,
                                   lint_source, render_findings)
from repro.analysis.rules import RULES, Rule
from repro.analysis.sanitize import (CompileMonitor, KeyReuseDetector,
                                     SanitizerError, check_finite,
                                     compile_counts, no_retrace)

__all__ = [
    "Finding", "lint_paths", "lint_project", "lint_source",
    "render_findings", "RULES", "Rule",
    "CompileMonitor", "KeyReuseDetector", "SanitizerError",
    "check_finite", "compile_counts", "no_retrace",
    "jaxpr",
]


def __getattr__(name):
    # the jaxpr auditor imports jax at module level; loaded on demand so
    # the AST linter/CLI paths stay import-light
    if name == "jaxpr":
        import importlib
        return importlib.import_module("repro.analysis.jaxpr")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
