"""Pytest plumbing for the runtime sanitizers.

Loaded via ``pytest_plugins = ("repro.analysis.pytest_plugin",)`` in the
root conftest.  Provides:

* ``assert_no_retrace`` — a fixture returning the
  :func:`repro.analysis.sanitize.no_retrace` context-manager factory,
  pre-labelled with the test name::

      def test_warm_path(assert_no_retrace):
          run_once()                      # warmup: compiles are fine
          with assert_no_retrace():
              run_once()                  # must hit every cache

  The generalized form of the PR-3 ("NetView never retraces") and PR-4
  (evolved-network no-retrace) bespoke tests: instead of watching one
  module's jit cache, it counts actual XLA backend compiles
  process-wide, so any accidental retrace — solver, kernels, eval —
  fails the test.
* ``compile_monitor`` — a bare :class:`CompileMonitor` factory for
  tests that want counts without the assertion.
"""
from __future__ import annotations

import contextlib
import functools

import pytest

from repro.analysis.sanitize import CompileMonitor, no_retrace


@pytest.fixture
def assert_no_retrace(request):
    """Factory for ``with assert_no_retrace(allow_compiles=0): ...``."""
    return functools.partial(no_retrace, f"test {request.node.name}")


@pytest.fixture
def compile_monitor():
    """Factory for ``with compile_monitor() as mon: ...`` (no assert)."""

    @contextlib.contextmanager
    def make():
        with CompileMonitor() as mon:
            yield mon

    return make
