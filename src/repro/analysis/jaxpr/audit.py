"""Audit orchestrator: discover contracts, trace, run passes, report.

``run_audit()`` is what ``python -m repro.analysis audit`` and the CI
lane call: it imports every contract-defining module (registration is a
decorator side effect), builds each contract's tiny Program, runs the
applicable passes, and returns a structured report.  Contracts that
need more devices than the host offers (the sharded twins want an
8-device mesh) are *skipped with a note*, never silently dropped —
the CI audit job forces 8 virtual CPU devices so they always run there.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax

# importing the pass modules registers them in PASSES
from repro.analysis.jaxpr import (collectives, donation, dtypes, fusion,
                                  memory)                  # noqa: F401
from repro.analysis.jaxpr.contracts import discover
from repro.analysis.jaxpr.passes import (PASS_DOCS, AuditFinding,
                                         ProgramTrace, run_passes)


@dataclasses.dataclass
class ContractReport:
    name: str
    module: str
    doc: str
    passes_run: List[str]
    findings: List[AuditFinding]
    skipped: Optional[str] = None
    elapsed_s: float = 0.0

    @property
    def violations(self) -> List[AuditFinding]:
        return [f for f in self.findings if not f.waived]


@dataclasses.dataclass
class AuditReport:
    contracts: List[ContractReport]

    @property
    def violations(self) -> List[AuditFinding]:
        return [f for c in self.contracts for f in c.violations]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "n_contracts": len(self.contracts),
            "n_passes": len(PASS_DOCS),
            "pass_catalogue": {
                pid: {"name": name, "summary": summary}
                for pid, (name, summary) in sorted(PASS_DOCS.items())},
            "contracts": [{
                "name": c.name, "module": c.module, "doc": c.doc,
                "passes_run": c.passes_run, "skipped": c.skipped,
                "elapsed_s": round(c.elapsed_s, 3),
                "findings": [dataclasses.asdict(f) for f in c.findings],
            } for c in self.contracts],
        }


def audit_contract(spec, pass_ids=None) -> ContractReport:
    """Trace one registered contract and run its passes."""
    if jax.device_count() < spec.min_devices:
        return ContractReport(
            name=spec.name, module=spec.module, doc=spec.doc,
            passes_run=[], findings=[],
            skipped=f"needs {spec.min_devices} devices, have "
                    f"{jax.device_count()} (XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=N)")
    start = time.perf_counter()
    ids = list(pass_ids if pass_ids is not None
               else spec.applicable_passes())
    try:
        program = spec.build()
        findings = run_passes(ProgramTrace(spec, program), ids)
    except Exception as exc:        # noqa: BLE001 — reported, not raised
        findings = [AuditFinding(
            spec.name, "JXP000",
            f"contract build/trace failed: {type(exc).__name__}: {exc}",
            hint="the builder in the contract's defining module no "
                 "longer matches the entry point it audits — fix the "
                 "builder alongside the refactor that broke it")]
    return ContractReport(
        name=spec.name, module=spec.module, doc=spec.doc,
        passes_run=ids, findings=findings,
        elapsed_s=time.perf_counter() - start)


def run_audit(select: Optional[Sequence[str]] = None,
              pass_ids: Optional[Sequence[str]] = None) -> AuditReport:
    """Audit every registered contract (or the ``select`` subset)."""
    registry: Dict[str, object] = discover()
    names = sorted(registry)
    if select:
        unknown = sorted(set(select) - set(names))
        if unknown:
            raise ValueError(f"unknown contract(s) {unknown}; "
                             f"registered: {names}")
        names = [n for n in names if n in set(select)]
    return AuditReport(contracts=[
        audit_contract(registry[n], pass_ids) for n in names])


def render_report(report: AuditReport, hints: bool = True) -> str:
    lines: List[str] = []
    for c in report.contracts:
        if c.skipped:
            lines.append(f"SKIP {c.name} [{c.module}] — {c.skipped}")
            continue
        status = "FAIL" if c.violations else " ok "
        waived = sum(1 for f in c.findings if f.waived)
        extra = f", {waived} waived" if waived else ""
        lines.append(f"{status} {c.name} [{c.module}] "
                     f"({', '.join(c.passes_run)}; "
                     f"{len(c.violations)} finding(s){extra}; "
                     f"{c.elapsed_s:.2f}s) — {c.doc}")
        for f in c.findings:
            lines.append("     " + f.render() if hints
                         else f"     {f.pass_id}: {f.message}")
    run = [c for c in report.contracts if not c.skipped]
    skipped = len(report.contracts) - len(run)
    tail = (f"audit: {len(run)} contract(s) traced, "
            f"{len(report.violations)} violation(s)")
    if skipped:
        tail += f", {skipped} skipped (device count)"
    lines.append(tail)
    return "\n".join(lines)
