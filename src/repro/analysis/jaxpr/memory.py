"""JXP003 — memory estimator.

Two bounds:

* **peak live bytes** — a liveness sweep over the traced jaxpr: walk
  equations in order, allocate each output aval, free a value after its
  last use, and track the high-water mark.  Loop/call bodies contribute
  ``max(body peak)`` on top of the bytes live at their call site (one
  iteration resident at a time — the scan/while execution model).  The
  estimate ignores XLA fusion (which only *lowers* residency), so it is
  a sound upper bound for catching the failure class that matters:
  an accidentally materialized cross product (e.g. a ``(G, D, R, LANE)``
  broadcast) explodes the estimate even at audit's tiny shapes.
* **TilePlan budgets** — for each declared ``(R, L, n_operands, dtype,
  backend)`` the pass re-derives the kernel grid's
  :class:`~repro.kernels.tiling.TilePlan` and checks its double-buffered
  resident block bytes against the VMEM/SMEM budget that sized it
  (``MEMORY_BUDGET_BYTES``) — the regression gate for anyone retuning
  ``DOUBLE_BUFFER``/``ROW_CAP`` or the budget table itself.
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis.jaxpr.passes import (AuditFinding, audit_pass,
                                         aval_bytes, subjaxprs)

try:
    from jax.extend import core as _core
    _ = (_core.Jaxpr, _core.ClosedJaxpr)
except (ImportError, AttributeError):           # pragma: no cover
    from jax import core as _core               # type: ignore[no-redef]


def estimate_peak_bytes(jaxpr) -> int:
    """Estimated peak live bytes of one jaxpr (see module docstring)."""
    if isinstance(jaxpr, _core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    last_use: Dict[object, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for var in eqn.invars:
            if not isinstance(var, _core.Literal):
                last_use[var] = i
    for var in jaxpr.outvars:
        if not isinstance(var, _core.Literal):
            last_use[var] = len(jaxpr.eqns)
    sizes: Dict[object, int] = {
        v: aval_bytes(v.aval)
        for v in list(jaxpr.invars) + list(jaxpr.constvars)}
    current = sum(sizes.values())
    peak = current
    for i, eqn in enumerate(jaxpr.eqns):
        inner = max((estimate_peak_bytes(sub) for sub in subjaxprs(eqn)),
                    default=0)
        peak = max(peak, current + inner)
        for var in eqn.outvars:
            size = aval_bytes(var.aval)
            sizes[var] = size
            current += size
        peak = max(peak, current)
        for var, size in list(sizes.items()):
            if last_use.get(var, -1) <= i:
                current -= size
                del sizes[var]
    return peak


def _fmt(n: int) -> str:
    if n >= 1 << 20:
        return f"{n / (1 << 20):.2f} MiB"
    return f"{n / 1024:.1f} KiB"


@audit_pass("JXP003")
def check_memory(trace, spec) -> List[AuditFinding]:
    findings: List[AuditFinding] = []
    if spec.memory_budget_bytes is not None:
        peak = estimate_peak_bytes(trace.jaxpr())
        if peak > spec.memory_budget_bytes:
            findings.append(AuditFinding(
                spec.name, "JXP003",
                f"estimated peak live bytes {_fmt(peak)} exceed the "
                f"contract budget {_fmt(spec.memory_budget_bytes)}",
                hint="an intermediate materializes a cross product the "
                     "contract's tiny shapes should never produce — "
                     "look for a broadcast that should be an einsum/"
                     "scan carry, or raise the budget with a comment "
                     "if the growth is intentional"))
    if spec.tile_plans:
        # lazy: keeps this module import-light for the RPA007 graph
        import jax.numpy as jnp
        from repro.kernels.tiling import MEMORY_BUDGET_BYTES, plan_tiles
        for entry in spec.tile_plans:
            rows, lanes, n_operands, dtype, backend = entry
            plan = plan_tiles(rows, lanes, n_operands=n_operands,
                              dtype=jnp.dtype(dtype), backend=backend)
            budget = MEMORY_BUDGET_BYTES.get(backend)
            if budget is None:
                continue
            block = plan.block_bytes(n_operands, jnp.dtype(dtype))
            if block > budget:
                findings.append(AuditFinding(
                    spec.name, "JXP003",
                    f"TilePlan({rows}x{lanes}, {n_operands} operands, "
                    f"{dtype}, {backend}) resident block {_fmt(block)} "
                    f"exceeds the {backend} budget {_fmt(budget)}",
                    hint="plan_tiles sized a grid that no longer fits "
                         "its memory space — re-check DOUBLE_BUFFER/"
                         "ROW_CAP and MEMORY_BUDGET_BYTES in "
                         "kernels/tiling.py"))
    return findings
