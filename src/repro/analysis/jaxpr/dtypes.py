"""JXP002 — dtype discipline.

The master plane is f32 and the kernel layer round-trips bf16 leaves;
nothing on the hot path may silently widen.  Two checks:

* **f64 promotion probe** — re-trace the program under
  ``jax.experimental.enable_x64()``.  Weak-typed Python scalars stay
  narrow under x64; *strong* f64 values (``jnp.array([0.5])`` with no
  dtype, ``np.asarray`` constants, ``np.float64`` scalars) widen the
  whole downstream graph.  Any equation producing an f64/c128 output
  under the probe is exactly the site that breaks the moment a user —
  or a dependency — flips ``jax_enable_x64``.  (The AST twin is rule
  RPA010; this pass sees through helpers the linter cannot.)
* **declared output dtypes** — the normal (x64-off) trace's outputs
  must match ``out_dtypes`` when the contract declares them: the bf16
  leaf round-trip pin (an upstream promotion to f32 fails here).
"""
from __future__ import annotations

from collections import Counter
from typing import List

from repro.analysis.jaxpr.passes import AuditFinding, audit_pass, iter_eqns

_WIDE = ("float64", "complex128")


@audit_pass("JXP002")
def check_dtypes(trace, spec) -> List[AuditFinding]:
    findings: List[AuditFinding] = []
    if spec.forbid_f64:
        wide = Counter()
        for eqn in iter_eqns(trace.jaxpr_x64()):
            for var in eqn.outvars:
                dtype = getattr(var.aval, "dtype", None)
                if (dtype is not None and dtype.name in _WIDE
                        # weak-typed scalars (jnp.log of a Python float)
                        # cannot widen strong f32 arrays — only strong
                        # f64 values poison the downstream graph
                        and not getattr(var.aval, "weak_type", False)):
                    wide[(eqn.primitive.name, dtype.name)] += 1
        for (prim, dtype), n in sorted(wide.items()):
            findings.append(AuditFinding(
                spec.name, "JXP002",
                f"{n} `{prim}` equation(s) produce {dtype} under "
                f"jax_enable_x64 — a strong-typed wide literal is "
                f"promoting the graph",
                hint="pin the dtype at the source: "
                     "`jnp.array([...], dtype=jnp.float32)` / "
                     "`jnp.asarray(x, jnp.float32)`; Python scalars "
                     "are weak-typed and safe, list literals and "
                     "np arrays are not (AST twin: RPA010)"))
    if spec.out_dtypes is not None:
        actual = tuple(getattr(a, "dtype", None) and a.dtype.name
                       for a in trace.jaxpr().out_avals)
        if actual != tuple(spec.out_dtypes):
            findings.append(AuditFinding(
                spec.name, "JXP002",
                f"output dtypes {actual} != declared "
                f"{tuple(spec.out_dtypes)}",
                hint="an op in the program widened/narrowed the "
                     "carried dtype — for the bf16 round-trip keep "
                     "scalars weak (Python floats) and avoid strong "
                     "f32 constants on the leaf path"))
    return findings
