"""JXP004 — donation audit.

``donate_argnums`` is a *request*: XLA only honors it when a donated
input buffer can alias some output (same shape/dtype/layout).  A
refactor that changes an output shape — or moves the donated arg behind
a copy — silently degrades to "allocate both", doubling the round's
plane memory without any error (jax emits a one-line warning that CI
logs swallow).  This pass reads the lowered StableHLO: every donated
buffer must carry a ``tf.aliasing_output`` attribute, one per donated
array leaf.
"""
from __future__ import annotations

from typing import List

import jax

from repro.analysis.jaxpr.passes import AuditFinding, audit_pass


@audit_pass("JXP004")
def check_donation(trace, spec) -> List[AuditFinding]:
    program = trace.program
    if not program.donate_argnums:
        return []
    expected = sum(
        len(jax.tree_util.tree_leaves(program.args[i]))
        for i in program.donate_argnums)
    actual = trace.lowered_text().count("tf.aliasing_output")
    if actual == expected:
        return []
    return [AuditFinding(
        spec.name, "JXP004",
        f"{actual} of {expected} donated buffer(s) are aliased in the "
        f"lowered executable (donate_argnums="
        f"{program.donate_argnums})",
        hint="an unusable donation silently allocates input AND output "
             "— check that every donated leaf's shape/dtype matches an "
             "output (the plane stack must flow through unreshaped) "
             "and that no host-side copy sits between the caller and "
             "the jit boundary")]
