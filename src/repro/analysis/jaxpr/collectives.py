"""JXP001 — collective audit.

Two layers:

* **jaxpr** — count collective primitives (``psum``, ``all_gather``,
  ...) across all sub-jaxprs and compare against the contract's
  ``collectives`` map.  This is what proves ``reduce="exact"`` really
  all-gathers and never psums (the bitwise-exactness contract of
  ``repro.sharding.plane``) while ``reduce="psum"`` runs exactly one
  psum per reduction — the two modes produce *provably different*
  jaxprs, pinned per-commit.
* **compiled HLO** — GSPMD inserts its own collectives when
  partitioning a jitted program over sharded inputs; the contract's
  ``hlo_collectives`` set enumerates the allowed ops and anything else
  (a surprise ``all-to-all`` from a layout change, a ``reduce-scatter``
  from a donation interaction) is a finding.
"""
from __future__ import annotations

import re
from typing import List

from repro.analysis.jaxpr.passes import (AuditFinding, audit_pass,
                                         count_primitives)

#: Collective primitives as they appear in jaxprs.
COLLECTIVE_PRIMS = ("psum", "all_gather", "all_to_all", "ppermute",
                    "pmax", "pmin", "psum_scatter", "reduce_scatter")

#: Collective ops as they appear in compiled (post-GSPMD) HLO text.
_HLO_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|all-to-all|collective-permute|"
    r"reduce-scatter|collective-broadcast)\b")


def _expect_ok(expected, actual: int) -> bool:
    if isinstance(expected, str) and expected.endswith("+"):
        return actual >= int(expected[:-1])
    return actual == int(expected)


@audit_pass("JXP001")
def check_collectives(trace, spec) -> List[AuditFinding]:
    findings: List[AuditFinding] = []
    if spec.collectives is not None:
        counts = count_primitives(trace.jaxpr(), COLLECTIVE_PRIMS)
        for prim in COLLECTIVE_PRIMS:
            expected = spec.collectives.get(prim, 0)
            actual = counts[prim]
            if not _expect_ok(expected, actual):
                findings.append(AuditFinding(
                    spec.name, "JXP001",
                    f"jaxpr contains {actual} `{prim}` (expected "
                    f"{expected})",
                    hint="a collective appeared/disappeared in the "
                         "traced program — check the reduce mode and "
                         "shard_map body; `reduce='exact'` must "
                         "all-gather (never psum), `reduce='psum'` "
                         "runs exactly one psum per reduction"))
        unknown = sorted(
            set(spec.collectives) - set(COLLECTIVE_PRIMS))
        if unknown:
            findings.append(AuditFinding(
                spec.name, "JXP001",
                f"contract names unknown collective primitive(s) "
                f"{unknown}",
                hint=f"known: {COLLECTIVE_PRIMS}"))
    if spec.hlo_collectives is not None:
        found = sorted(set(_HLO_COLLECTIVE_RE.findall(
            trace.compiled_text())))
        extra = [op for op in found if op not in spec.hlo_collectives]
        if extra:
            findings.append(AuditFinding(
                spec.name, "JXP001",
                f"compiled HLO contains unexpected collective(s) "
                f"{extra} (allowed: {sorted(spec.hlo_collectives)})",
                hint="GSPMD inserted a collective the contract does "
                     "not allow — inspect the input shardings and "
                     "out_shardings of the jitted step; an accidental "
                     "replication<->shard flip shows up here first"))
    return findings
