"""Pass framework for the jaxpr/HLO contract auditor.

A *pass* is a function ``(trace: ProgramTrace, spec: ContractSpec) ->
List[AuditFinding]`` registered in :data:`PASSES`.  :class:`ProgramTrace`
owns the (lazily computed, cached) artifacts every pass reads:

* ``jaxpr()`` — ``jax.make_jaxpr`` of the program on its example args;
* ``jaxpr_x64()`` — the same trace under ``jax.experimental.enable_x64``
  (the f64-promotion probe: weak Python scalars stay narrow, strong
  literals widen — exactly what runs if a user flips the flag);
* ``lowered_text()`` — StableHLO of the jitted lowering (with the
  contract's ``donate_argnums`` applied — the donation audit reads the
  ``tf.aliasing_output`` attributes);
* ``compiled_text()`` — post-GSPMD compiled HLO (collective audit of
  the partitioned executable).

The eqn walker descends into every sub-jaxpr (pjit bodies, scan/while
bodies, shard_map, cond branches) so counts cover the whole program.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Iterator, List, Tuple

import jax

try:    # jax >= 0.4.36 moved the jaxpr types to jax.extend.core
    from jax.extend import core as _core
    _ = (_core.Jaxpr, _core.ClosedJaxpr)
except (ImportError, AttributeError):           # pragma: no cover
    from jax import core as _core               # type: ignore[no-redef]


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    """One contract violation (or waived observation)."""
    contract: str
    pass_id: str
    message: str
    hint: str = ""
    waived: bool = False

    def render(self) -> str:
        tag = " [waived]" if self.waived else ""
        out = f"{self.contract}: {self.pass_id}{tag}: {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


#: pass id -> (name, one-line summary) — the catalogue the CLI prints.
PASS_DOCS: Dict[str, Tuple[str, str]] = {
    "JXP001": ("collective-audit",
               "jaxpr collective-primitive counts match the contract; "
               "compiled HLO contains no unexpected collectives"),
    "JXP002": ("dtype-discipline",
               "no silent f32->f64 promotion under jax_enable_x64; "
               "declared output dtypes (bf16 round-trip) hold"),
    "JXP003": ("memory-estimator",
               "estimated peak live bytes stay under the contract "
               "budget; TilePlans fit their VMEM/SMEM budgets"),
    "JXP004": ("donation-audit",
               "buffers passed with donate_argnums are actually "
               "aliased in the compiled executable"),
    "JXP005": ("fusion-boundary",
               "no nested call boundary (pjit/closed_call/custom-call) "
               "inside a scan/while loop body"),
}

PASSES: Dict[str, Callable] = {}


def audit_pass(pass_id: str):
    """Register a pass implementation under its JXP id."""

    def deco(fn):
        PASSES[pass_id] = fn
        return fn

    return deco


# ------------------------------------------------------- jaxpr walker --

def subjaxprs(eqn) -> Iterator:
    """Every jaxpr nested in one equation's params (pjit/scan/while
    bodies, cond/switch branch lists, shard_map, custom_*_call)."""
    for val in eqn.params.values():
        yield from _as_jaxprs(val)


def _as_jaxprs(val) -> Iterator:
    if isinstance(val, _core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, _core.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from _as_jaxprs(item)


def iter_eqns(jaxpr) -> Iterator:
    """All equations of ``jaxpr`` (open or closed), depth-first."""
    if isinstance(jaxpr, _core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub)


def count_primitives(jaxpr, names) -> Dict[str, int]:
    """Occurrence count of each primitive name across all nesting."""
    counts = {n: 0 for n in names}
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in counts:
            counts[name] += 1
    return counts


def aval_bytes(aval) -> int:
    """Concrete byte size of a shaped aval (0 for tokens/abstract)."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    size = 1
    for dim in shape:
        if not isinstance(dim, int):
            return 0        # polymorphic dim — don't guess
        size *= dim
    return size * dtype.itemsize


# ---------------------------------------------------- trace artifacts --

class ProgramTrace:
    """Lazily computed, cached trace artifacts of one contract Program."""

    def __init__(self, spec, program):
        self.spec = spec
        self.program = program
        self._cache: dict = {}

    def _memo(self, key, thunk):
        if key not in self._cache:
            self._cache[key] = thunk()
        return self._cache[key]

    def jaxpr(self):
        return self._memo("jaxpr", lambda: jax.make_jaxpr(
            self.program.fn)(*self.program.args))

    def jaxpr_x64(self):
        def trace():
            from jax.experimental import enable_x64
            with enable_x64():
                return jax.make_jaxpr(self.program.fn)(*self.program.args)
        return self._memo("jaxpr_x64", trace)

    def _lowered(self):
        def lower():
            donate = self.program.donate_argnums
            with warnings.catch_warnings():
                # an UNUSED donation warns at lowering time; the
                # donation pass reports it as a structured finding
                warnings.simplefilter("ignore")
                return jax.jit(self.program.fn,
                               donate_argnums=donate).lower(
                                   *self.program.args)
        return self._memo("lowered", lower)

    def lowered_text(self) -> str:
        return self._memo("lowered_text",
                          lambda: self._lowered().as_text())

    def compiled_text(self) -> str:
        return self._memo("compiled_text",
                          lambda: self._lowered().compile().as_text())


def run_passes(trace: ProgramTrace, pass_ids=None) -> List[AuditFinding]:
    """Run the contract's applicable passes (or ``pass_ids``) over one
    ProgramTrace; waived findings are tagged, not dropped."""
    spec = trace.spec
    ids = pass_ids if pass_ids is not None else spec.applicable_passes()
    findings: List[AuditFinding] = []
    for pid in ids:
        for f in PASSES[pid](trace, spec):
            if pid in spec.waivers:
                f = dataclasses.replace(
                    f, waived=True,
                    message=f"{f.message} (waived: {spec.waivers[pid]})")
            findings.append(f)
    return findings
