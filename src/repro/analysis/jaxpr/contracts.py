"""Contract registry for the trace-level program auditor.

A *contract* pins the traced/compiled shape of one hot-path entry point:
which collectives its jaxpr may contain, that it never promotes the
master plane to f64, how many bytes its intermediates may keep live,
that donated buffers are actually donated, and that no nested call
boundary hides inside its round loop (the PR-7 fusion regression).

Entry points register at their definition sites with the lightweight
:func:`contract` decorator::

    @contract("fused_round", collectives={}, memory_budget_bytes=1 << 22)
    def _fused_round_contract():
        \"\"\"One-line description shown in the audit report.\"\"\"
        spec, args = _tiny_round_args()
        return Program(fn=_plane_round_fn(_audit_loss, spec, "cpu", None),
                       args=args)

Registration is a dict insert — the decorated *builder* only runs when
``python -m repro.analysis audit`` traces it with tiny static shapes, so
hot modules pay nothing at import time.  This module must stay free of
module-level ``repro.core``/``repro.solver``/``repro.sharding`` imports
(the RPA007 cycle rule): those packages import *us* to register.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

#: Modules whose import registers the repo's hot-path contracts.  Kept
#: here (not imported at module level!) so ``discover()`` is the single
#: lazy entry point — the audit CLI and tests both go through it.
DISCOVER_MODULES: Tuple[str, ...] = (
    "repro.core.fedprox",
    "repro.core.aggregation",
    "repro.core.engine",
    "repro.solver.sca",
    "repro.experiments.sweep",
    "repro.sharding.plane",
)


@dataclasses.dataclass(frozen=True)
class Program:
    """A concrete traceable program: a callable plus tiny example args.

    ``fn`` may be jitted or plain — the passes trace through either.
    ``donate_argnums`` names the positional args whose buffers the
    compiled executable must alias (the donation audit, JXP004).
    """
    fn: Callable
    args: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class ContractSpec:
    """One registered contract: a lazy Program builder + expectations.

    Expectation fields (all optional — a pass only runs when its inputs
    are present; see ``applicable_passes``):

    * ``collectives`` — jaxpr collective-primitive counts, e.g.
      ``{"all_gather": 2, "psum": 0}``.  Values are exact ints or
      ``"N+"`` (at least N).  Collective primitives NOT mentioned are
      expected absent (zero-surprise default).  ``None`` skips JXP001.
    * ``hlo_collectives`` — allowed collective op names in the COMPILED
      HLO (GSPMD may insert its own); anything else found is a finding.
      Triggers a ``.compile()`` of the lowering.
    * ``forbid_f64`` / ``enable_x64`` — JXP002 traces the program under
      ``jax.experimental.enable_x64()`` and flags any f64/c128 equation
      output: a literal or helper that silently widens under x64.
    * ``out_dtypes`` — expected dtype names of the program outputs on
      the normal (x64-off) trace, e.g. ``("bfloat16",)`` for the bf16
      leaf round-trip contract.
    * ``memory_budget_bytes`` — JXP003 bound on estimated peak live
      bytes of the traced program (tiny shapes; catches accidentally
      materialized cross products).
    * ``tile_plans`` — ``(R, L, n_operands, dtype, backend)`` tuples;
      JXP003 re-derives each TilePlan and checks its double-buffered
      block bytes against the backend VMEM/SMEM budget that sized it.
    * ``fusion_allow`` / ``fusion_max_inner_eqns`` — JXP005 escape
      hatches: named inner jits to permit (jnp internals like
      ``take_along_axis`` are allowed by default) and a size below
      which an inner call is considered trivially inlinable.
    * ``min_devices`` — contracts that build a device mesh skip (with a
      note) when fewer devices are available.
    * ``waivers`` — ``{pass_id: reason}``: findings from that pass are
      reported but do not fail the audit (the suppression mechanism;
      the reason string is mandatory documentation).
    """
    name: str
    build: Callable[[], Program]
    module: str
    doc: str = ""
    collectives: Optional[Mapping[str, object]] = None
    hlo_collectives: Optional[frozenset] = None
    enable_x64: bool = True
    forbid_f64: bool = True
    out_dtypes: Optional[Tuple[str, ...]] = None
    memory_budget_bytes: Optional[int] = None
    tile_plans: Tuple[tuple, ...] = ()
    fusion_allow: Tuple[str, ...] = ()
    fusion_max_inner_eqns: int = 0
    min_devices: int = 1
    waivers: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def applicable_passes(self) -> Tuple[str, ...]:
        out = []
        if self.collectives is not None or \
                self.hlo_collectives is not None:
            out.append("JXP001")
        if self.forbid_f64 or self.out_dtypes is not None:
            out.append("JXP002")
        if self.memory_budget_bytes is not None or self.tile_plans:
            out.append("JXP003")
        out.append("JXP004")    # no-ops without donate_argnums
        out.append("JXP005")
        return tuple(out)


REGISTRY: Dict[str, ContractSpec] = {}


def contract(name: str, **expectations):
    """Register a Program builder under ``name`` (see module docstring).

    The decorated function is returned unchanged; its docstring becomes
    the contract description in the audit report.
    """

    def deco(build: Callable[[], Program]):
        if name in REGISTRY and REGISTRY[name].build is not build:
            raise ValueError(f"duplicate contract name {name!r} "
                             f"(already registered by "
                             f"{REGISTRY[name].module})")
        REGISTRY[name] = ContractSpec(
            name=name, build=build, module=build.__module__,
            doc=(build.__doc__ or "").strip().split("\n")[0],
            **expectations)
        return build

    return deco


def discover() -> Dict[str, ContractSpec]:
    """Import every contract-defining module and return the registry."""
    import importlib
    for mod in DISCOVER_MODULES:
        importlib.import_module(mod)
    return dict(REGISTRY)
