"""Trace-level program auditor: contract passes over jaxprs and lowered
StableHLO of the repo's hot-path entry points.

The AST linter (``repro.analysis.rules``) sees source; this package sees
the programs XLA actually runs.  Entry points register tiny-shape
builders with the :func:`~repro.analysis.jaxpr.contracts.contract`
decorator at their definition sites; ``python -m repro.analysis audit``
traces them and runs five passes (JXP001-JXP005: collectives, dtype
discipline, memory budgets, donation, fusion boundaries).  See
``docs/static_analysis.md`` for the pass catalogue and the PR-7/PR-9
incidents each pass codifies.

Import discipline: this ``__init__`` (and ``contracts``/``passes``)
stay free of ``repro.core``/``solver``/``sharding`` imports — those
packages import *us* at module level to register their contracts; the
audit side only touches them lazily through ``discover()``.
"""
from repro.analysis.jaxpr.contracts import (REGISTRY, ContractSpec,
                                            Program, contract, discover)
from repro.analysis.jaxpr.passes import (PASS_DOCS, PASSES, AuditFinding,
                                         ProgramTrace, count_primitives,
                                         iter_eqns, run_passes)

__all__ = [
    "REGISTRY", "ContractSpec", "Program", "contract", "discover",
    "PASS_DOCS", "PASSES", "AuditFinding", "ProgramTrace",
    "count_primitives", "iter_eqns", "run_passes",
    "run_audit", "render_report", "AuditReport",
]


def __getattr__(name):
    # run_audit pulls in the pass implementations (jax-heavy); loaded on
    # first use so `import repro.core.fedprox` (which imports contracts
    # for registration) stays light
    if name in ("run_audit", "render_report", "AuditReport",
                "audit_contract", "ContractReport"):
        from repro.analysis.jaxpr import audit as _audit
        return getattr(_audit, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
