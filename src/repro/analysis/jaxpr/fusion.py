"""JXP005 — fusion-boundary detector (the PR-7 regression, codified).

PR 7 found the mesh plane round running at HALF speed because a jitted
kernel fallback called from inside the round's ``fori_loop`` lowered to
a nested XLA call boundary that blocked fusion with the surrounding
loop body.  The fix (``ops._tracing``) inlines the expression when
already under a trace — this pass pins that property: no ``pjit`` /
``closed_call`` / ``custom-call`` equation may appear inside a
``scan``/``while`` body.

Escape hatches, because jax.numpy itself jits tiny helpers
(``take_along_axis`` traces as a nested pjit on jax 0.4.x):

* ``DEFAULT_FUSION_ALLOW`` + the contract's ``fusion_allow`` — inner
  jits allowed *by name*;
* ``fusion_max_inner_eqns`` — bodies at or below this equation count
  are considered trivially inlinable (default 0: strict).
"""
from __future__ import annotations

from typing import List

from repro.analysis.jaxpr.passes import (AuditFinding, audit_pass,
                                         subjaxprs)

#: Loop primitives whose bodies must stay call-free.
LOOP_PRIMS = ("scan", "while")

#: Call-boundary primitives (jax 0.4.x spells nested jit `pjit`).
CALL_PRIMS = ("pjit", "closed_call", "core_call", "xla_call",
              "custom_call")

#: jax.numpy-internal helper jits that XLA inlines anyway.
DEFAULT_FUSION_ALLOW = ("take_along_axis", "_where", "_one_hot",
                        "_take", "clip")


def _inner_eqn_count(eqn) -> int:
    return sum(len(sub.eqns) for sub in subjaxprs(eqn))


def _collect(jaxpr, in_loop: bool, hits: list) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if in_loop and name in CALL_PRIMS:
            hits.append(eqn)
        nested_loop = in_loop or name in LOOP_PRIMS
        for sub in subjaxprs(eqn):
            _collect(sub, nested_loop, hits)


@audit_pass("JXP005")
def check_fusion_boundaries(trace, spec) -> List[AuditFinding]:
    jaxpr = trace.jaxpr()
    closed = getattr(jaxpr, "jaxpr", jaxpr)
    hits: list = []
    _collect(closed, False, hits)
    allow = set(DEFAULT_FUSION_ALLOW) | set(spec.fusion_allow)
    findings: List[AuditFinding] = []
    for eqn in hits:
        label = str(eqn.params.get("name", eqn.primitive.name))
        if label in allow:
            continue
        n_eqns = _inner_eqn_count(eqn)
        if n_eqns <= spec.fusion_max_inner_eqns:
            continue
        findings.append(AuditFinding(
            spec.name, "JXP005",
            f"nested `{eqn.primitive.name}` boundary `{label}` "
            f"({n_eqns} inner eqns) inside a loop body",
            hint="a jit-inside-jit lowers to an XLA call that blocks "
                 "fusion with the surrounding scan/fori_loop (the PR-7 "
                 "mesh-round 2x regression) — inline the expression "
                 "when traced (see ops._tracing) or hoist the call out "
                 "of the loop; if it is a known-trivial jnp helper, "
                 "add it to the contract's fusion_allow"))
    return findings
