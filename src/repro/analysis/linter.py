"""Linter driver: file walking, noqa suppression, rendering, exit codes.

Entry points:

* :func:`lint_paths` — lint files/directories on disk (what the CLI and
  the CI ``analysis`` job call).
* :func:`lint_project` — lint an in-memory ``{path: source}`` mapping
  (what the self-test corpus uses; also enables the import-cycle rule on
  synthetic file sets).
* :func:`lint_source` — one in-memory module (single-module rules only).

Suppression: ``# repro: noqa(RPA003)`` on the offending line silences
that rule there; ``# repro: noqa(RPA003,RPA008)`` silences several; a
bare ``# repro: noqa`` silences every rule on the line.  Suppressions
are expected to carry a one-line justification in the same comment.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence

from repro.analysis.rules import (RULES, RawFinding, find_cycles,
                                  import_edges, module_findings)

_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa(?:\(\s*([A-Z0-9,\s]+?)\s*\))?", re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation, ready to render."""
    path: str
    line: int
    col: int
    code: str
    message: str

    @property
    def hint(self) -> str:
        return RULES[self.code].hint

    def render(self, *, hints: bool = True) -> str:
        base = f"{self.path}:{self.line}:{self.col + 1}: " \
               f"{self.code} [{RULES[self.code].name}] {self.message}"
        return f"{base}\n    hint: {self.hint}" if hints else base

    def to_json(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "code": self.code, "rule": RULES[self.code].name,
                "message": self.message, "hint": self.hint}


def _noqa_codes(source_line: str) -> Optional[set]:
    """Codes suppressed on this line; empty set means 'all'."""
    m = _NOQA_RE.search(source_line)
    if not m:
        return None
    if m.group(1) is None:
        return set()
    return {c.strip().upper() for c in m.group(1).split(",") if c.strip()}


def _suppressed(finding: RawFinding, lines: Sequence[str]) -> bool:
    if 1 <= finding.line <= len(lines):
        codes = _noqa_codes(lines[finding.line - 1])
        if codes is not None and (not codes or finding.code in codes):
            return True
    return False


def _module_name(path: str, package: str = "repro") -> Optional[str]:
    """Map ``.../src/repro/core/engine.py`` -> ``repro.core.engine``."""
    parts = os.path.normpath(path).split(os.sep)
    if package not in parts:
        return None
    rel = parts[parts.index(package):]
    if rel[-1].endswith(".py"):
        rel[-1] = rel[-1][:-3]
    if rel[-1] == "__init__":
        rel = rel[:-1]
    return ".".join(rel)


def lint_project(files: Dict[str, str], *,
                 select: Optional[Iterable[str]] = None,
                 package: str = "repro") -> List[Finding]:
    """Lint a ``{path: source}`` mapping: per-module rules plus the
    cross-module import-cycle rule (RPA007)."""
    wanted = set(select) if select is not None else set(RULES)
    findings: List[Finding] = []
    trees: Dict[str, ast.Module] = {}
    lines: Dict[str, List[str]] = {}
    for path, source in sorted(files.items()):
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            findings.append(Finding(path, e.lineno or 1, 0, "RPA000",
                                    f"syntax error: {e.msg}"))
            continue
        trees[path] = tree
        lines[path] = source.splitlines()
        for raw in module_findings(tree):
            if raw.code in wanted and not _suppressed(raw, lines[path]):
                findings.append(Finding(path, raw.line, raw.col,
                                        raw.code, raw.message))
    if "RPA007" in wanted:
        findings.extend(_cycle_findings(trees, lines, package))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return findings


def _cycle_findings(trees: Dict[str, ast.Module],
                    lines: Dict[str, List[str]],
                    package: str) -> List[Finding]:
    mod_of_path: Dict[str, str] = {}
    for path in trees:
        mod = _module_name(path, package)
        if mod:
            mod_of_path[path] = mod
    known = set(mod_of_path.values())
    # also count packages (repro.core -> repro/core/__init__.py)
    graph: Dict[str, Dict[str, int]] = {}
    path_of_mod = {m: p for p, m in mod_of_path.items()}
    for path, mod in mod_of_path.items():
        edges: Dict[str, int] = {}
        for target, line in import_edges(mod, trees[path], known):
            if target != mod and target not in edges:
                edges[target] = line
        graph[mod] = edges
    out: List[Finding] = []
    for members, line_of in find_cycles(graph):
        for mod in members:
            path = path_of_mod.get(mod)
            line = line_of.get(mod)
            if path is None or line is None:
                continue
            raw = RawFinding(line, 0, "RPA007",
                             f"module-level import cycle: "
                             f"{' -> '.join(members + [members[0]])}")
            if not _suppressed(raw, lines[path]):
                out.append(Finding(path, raw.line, raw.col, raw.code,
                                   raw.message))
    return out


def lint_source(source: str, path: str = "<string>", *,
                select: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint one in-memory module (no cross-module rules)."""
    wanted = set(select) if select is not None else set(RULES) - {"RPA007"}
    return lint_project({path: source}, select=wanted)


def _iter_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for name in sorted(names):
                    if name.endswith(".py"):
                        out.append(os.path.join(root, name))
        elif p.endswith(".py"):
            out.append(p)
    return out


def lint_paths(paths: Sequence[str], *,
               select: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint files/directories on disk (the CLI entry point)."""
    files: Dict[str, str] = {}
    for path in _iter_py_files(paths):
        with open(path, encoding="utf-8") as f:
            files[path] = f.read()
    return lint_project(files, select=select)


def render_findings(findings: List[Finding], *, fmt: str = "text",
                    hints: bool = True) -> str:
    if fmt == "json":
        return json.dumps([f.to_json() for f in findings], indent=2)
    parts = [f.render(hints=hints) for f in findings]
    parts.append(f"{len(findings)} finding(s)"
                 if findings else "clean: 0 findings")
    return "\n".join(parts)
