from repro.sharding.specs import (  # noqa: F401
    batch_spec, cache_specs, param_specs, shard_ctx_for,
)
