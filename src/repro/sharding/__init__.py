from repro.sharding.specs import (  # noqa: F401
    batch_spec, cache_specs, param_specs, sanitize_spec, sanitize_tree,
    shard_ctx_for,
)
from repro.sharding.plane import (  # noqa: F401
    DPU_AXIS, ROW_AXIS, fedprox_accum_plane_sharded,
    local_round_plane_sharded, nova_aggregate_plane_sharded, plane_axes,
    plane_mesh, robust_aggregate_plane_sharded,
)
