"""PartitionSpec rules for every parameter / cache / batch tensor.

Scheme (per pod): 2-D sharding over ('data', 'model'):
  * d_model-like dims -> 'data'  (FSDP / ZeRO-3 style, gathered on use)
  * heads / d_ff / experts / d_inner dims -> 'model' (tensor parallel)
  * batch -> ('pod', 'data'); weights replicated over 'pod'
  * decode KV caches: batch -> 'data', sequence -> 'model' (the shard_map
    psum-softmax attention consumes this layout)

Rules key off leaf path names, so any model assembled from repro.models
blocks is covered automatically.
"""
from __future__ import annotations


import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import ShardCtx


def _leaf_spec(path, leaf, *, data: str, model: str) -> P:
    names = [getattr(p, "key", None) for p in path
             if hasattr(p, "key")]
    last = names[-1] if names else None
    stacked = any(n in ("blocks", "cross") for n in names[:-1]) or \
        (len(names) >= 2 and names[0] == "enc")
    lead = (None,) if stacked and leaf.ndim >= 1 else ()

    def spec(*dims):
        return P(*(lead + dims))

    in_mamba = "mamba" in names
    in_moe = "moe" in names
    if last == "embed":
        return P(model, data)
    if last == "unembed":
        return P(data, model)
    if last == "pos_embed":
        return P(None, data)
    if in_moe or last == "router":
        if last == "router":
            return spec(data, None)
        if last in ("w_in", "w_gate"):
            return spec(model, data, None)
        if last == "w_out":
            return spec(model, None, data)
    if in_mamba:
        if last == "w_in":
            return spec(data, model)
        if last == "w_out":
            return spec(model, data)
        if last == "conv_w":
            return spec(None, model)
        if last in ("conv_b", "norm"):
            return spec(model)
        return spec(*((None,) * (leaf.ndim - len(lead))))
    if last in ("wq", "wk", "wv"):
        return spec(data, model, None)
    if last == "wo":
        return spec(model, None, data)
    if last in ("w_in", "w_gate"):
        return spec(data, model)
    if last == "w_out":
        return spec(model, data)
    # norms, scalars, q_norm/k_norm, ln*, final_norm
    return spec(*((None,) * (leaf.ndim - len(lead))))


def param_specs(cfg: ModelConfig, params, *, data: str = "data",
                model: str = "model"):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, data=data, model=model),
        params)


def batch_spec(multi_pod: bool):
    axes = ("pod", "data") if multi_pod else ("data",)
    return axes


def cache_specs(cfg: ModelConfig, cache, *, batch_axes=("data",),
                seq_axes=("model",), seq_shard: bool = True):
    """Specs for a decode cache pytree from lm.init_cache."""
    def leaf(path, x):
        names = [getattr(p, "key", None) for p in path if hasattr(p, "key")]
        last = names[-1] if names else None
        if last == "pos":
            return P()
        if last in ("k", "v", "xk", "xv"):
            # (n_periods, B, S, Hkv, D)
            s_ax = seq_axes if (seq_shard and last in ("k", "v")) else None
            return P(None, batch_axes, s_ax, None, None)
        if last == "h":       # (n_periods, B, H, P, N)
            return P(None, batch_axes, None, None, None)
        if last == "conv":    # (n_periods, B, W-1, Cc)
            return P(None, batch_axes, None, None)
        return P(*((None,) * x.ndim))

    return jax.tree_util.tree_map_with_path(leaf, cache)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim
    (pjit requires exact divisibility on argument shardings; kv-head counts
    like 8 on a 16-way model axis degrade to replication)."""
    entries = []
    for i, dim in enumerate(shape):
        entry = spec[i] if i < len(spec) else None
        if entry is None:
            entries.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        entries.append(entry if (size and dim % size == 0) else None)
    return P(*entries)


def sanitize_tree(specs, shapes, mesh):
    return jax.tree_util.tree_map(
        lambda s, x: sanitize_spec(s, x.shape, mesh), specs, shapes)


def shard_ctx_for(mesh, *, multi_pod: bool, seq_shard_decode: bool,
                  wide_cache: bool = False) -> ShardCtx:
    """wide_cache: shard cache sequence over model AND data (long_500k b=1)."""
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    cache_axes = ("model", "data") if wide_cache else ("model",)
    if wide_cache:
        batch_axes = ("pod",) if multi_pod else ()
    return ShardCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model",
                    cache_axes=cache_axes, seq_shard_decode=seq_shard_decode)
