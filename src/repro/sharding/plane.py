"""Sharded parameter-plane execution over a ``('dpu', 'rows')`` device
mesh — the multi-device form of the fused CE-FL round.

Mesh axes:

* ``'dpu'`` — data parallelism over the per-DPU leading axis of stacked
  ``(G, R, LANE)`` planes and their minibatch index/weight arrays: each
  device trains its own slice of the DPU group (eqs. 5-10) and the eq.-11
  aggregation combines the per-device ``d_i`` blocks.
* ``'rows'`` — FSDP-style sharding of the ``(R, LANE)`` master/anchor
  plane rows (the LM-track layout, built on ``sharding/specs.py``):
  parameters are stored row-sharded, all-gathered just-in-time for the
  loss/grad evaluation, and each device keeps only its own row block of
  the gradient and optimizer state.

Divisibility follows the ``sanitize_spec`` rule: an axis whose size does
not divide the corresponding plane dim degrades to replication for that
dim (``R`` is always a multiple of ``SUBLANE = 8``, so row sharding holds
for any rows axis up to 8; the DPU axis degrades whenever the live group
size ``G`` is ragged).

Bit-exactness contract (the ``shard-parity`` CI lane): with the default
``reduce="exact"`` mode, every sharded op and the sharded fused round are
**bitwise identical** to the single-device path.  The eq.-10/11 weighted
reduction all-gathers the per-DPU ``d_i`` stack over ``'dpu'`` and runs
the SAME local reduction (same contracted size, same order) on every
device — redundant compute, zero reduction reordering.  ``reduce="psum"``
is the scale mode the paper-sized meshes want: each device accumulates
its local partial weighted sum and one ``psum`` combines them — one
G/n_dpu-sized reduction per device instead of G, but float addition
reorders, so it is allclose- (not bitwise-) equal and stays opt-in.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import fedprox
from repro.kernels import ops
from repro.kernels.plane import LANE, as_plane
from repro.sharding.specs import sanitize_spec

DPU_AXIS = "dpu"
ROW_AXIS = "rows"

_MESH_CACHE: dict = {}


def plane_mesh(shape: Optional[Tuple[int, int]] = None) -> Mesh:
    """The ``('dpu', 'rows')`` mesh for a device-count split ``shape``
    (cached per shape so jit caches keyed on the mesh stay warm).  With
    ``shape=None`` all devices go to the DPU axis."""
    devices = jax.devices()
    if shape is None:
        shape = (len(devices), 1)
    d, r = int(shape[0]), int(shape[1])
    if d < 1 or r < 1 or d * r > len(devices):
        raise ValueError(
            f"mesh_shape {shape} needs {d * r} devices, "
            f"have {len(devices)} (hint: XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N for a virtual mesh)")
    key = (d, r, tuple(id(dev) for dev in devices[:d * r]))
    mesh = _MESH_CACHE.get(key)
    if mesh is None:
        mesh = Mesh(np.asarray(devices[:d * r]).reshape(d, r),
                    (DPU_AXIS, ROW_AXIS))
        _MESH_CACHE[key] = mesh
    return mesh


def plane_axes(mesh: Mesh, n_lead: Optional[int], n_rows: int):
    """(dpu_axis_or_None, rows_axis_or_None) after the sanitize_spec
    divisibility degradation for an (n_lead, n_rows, LANE) stack."""
    spec = sanitize_spec(P(DPU_AXIS, ROW_AXIS, None),
                         (n_lead if n_lead is not None else 0,
                          n_rows, LANE), mesh)
    g_ax = spec[0] if n_lead is not None else None
    return g_ax, spec[1]


# ------------------------------------------------- sharded plane ops -----
#
# The three round kernels, data-parallel over 'dpu' / row-sharded over
# 'rows'.  Each is a thin shard_map around the single-device ops.* entry
# point, so backend dispatch (cpu/interpret/gpu/tpu) stays in ONE place.

@functools.lru_cache(maxsize=64)
def _fedprox_accum_fn(mesh: Mesh, backend: str):
    def fn(x, g, anchor, acc, coef, active, eta, mu):
        g_ax, r_ax = plane_axes(mesh, x.shape[0], x.shape[1])
        stacked = P(g_ax, r_ax, None)
        anchor_spec = stacked if anchor.ndim == 3 else P(r_ax, None)

        def body(x_l, g_l, an_l, acc_l, coef_l, act_l, eta_s, mu_s):
            return ops.fedprox_accum_plane(x_l, g_l, an_l, acc_l, coef_l,
                                           act_l, eta_s, mu_s,
                                           backend=backend)

        return shard_map(
            body, mesh=mesh,
            in_specs=(stacked, stacked, anchor_spec, stacked,
                      P(g_ax), P(g_ax), P(), P()),
            out_specs=(stacked, stacked), check_rep=False)(
                x, g, anchor, acc, coef, active, eta, mu)

    return jax.jit(fn)


def fedprox_accum_plane_sharded(x, g, anchor, acc, coef, active, eta, mu, *,
                                mesh: Mesh, backend: Optional[str] = None):
    """Sharded batched proximal step + eq.-10 accumulation: purely
    elementwise over (G, R, LANE), so any sharding is bitwise exact."""
    b = ops.resolve_backend(backend)
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    return _fedprox_accum_fn(mesh, b)(x, g, anchor, acc, f32(coef),
                                      f32(active), f32(eta), f32(mu))


@functools.lru_cache(maxsize=64)
def _nova_fn(mesh: Mesh, backend: str, reduce: str):
    def fn(x, d_stack, weights, theta_eta):
        g_ax, r_ax = plane_axes(mesh, d_stack.shape[0], x.shape[0])

        def body(x_l, d_l, w_l, te):
            if reduce == "psum" and g_ax is not None:
                # local partial weighted sum + one psum over 'dpu'
                # (eq. 10/11 at scale; reduction reorders -> allclose)
                part = jnp.einsum("g,grl->rl", w_l, d_l)
                return x_l - te * jax.lax.psum(part, DPU_AXIS)
            if g_ax is not None:
                d_l = jax.lax.all_gather(d_l, DPU_AXIS, axis=0, tiled=True)
                w_l = jax.lax.all_gather(w_l, DPU_AXIS, tiled=True)
            return ops.nova_aggregate_plane(x_l, d_l, w_l, te,
                                            backend=backend)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(r_ax, None), P(g_ax, r_ax, None), P(g_ax), P()),
            out_specs=P(r_ax, None), check_rep=False)(
                x, d_stack, weights, theta_eta)

    return jax.jit(fn)


def nova_aggregate_plane_sharded(x, d_stack, weights, theta_eta, *,
                                 mesh: Mesh, reduce: str = "exact",
                                 backend: Optional[str] = None):
    """Sharded eq.-11 aggregation.  ``weights`` already normalized (the
    plane-level contract).  ``reduce="exact"`` (default) all-gathers the
    d-stack over 'dpu' and reduces locally — bitwise equal to the
    single-device op; ``reduce="psum"`` combines local partials with one
    psum (allclose)."""
    if reduce not in ("exact", "psum"):
        raise ValueError(f"unknown reduce mode {reduce!r}")
    b = ops.resolve_backend(backend)
    return _nova_fn(mesh, b, reduce)(
        x, d_stack, jnp.asarray(weights, jnp.float32),
        jnp.asarray(theta_eta, jnp.float32))


@functools.lru_cache(maxsize=64)
def _robust_fn(mesh: Mesh, backend: str, mode: str, trim_frac: float):
    def fn(x, d_stack, theta_eta):
        g_ax, r_ax = plane_axes(mesh, d_stack.shape[0], x.shape[0])

        def body(x_l, d_l, te):
            # the coordinate-wise sort needs the full DPU stack: gather
            # over 'dpu', reduce each device's own row block locally
            if g_ax is not None:
                d_l = jax.lax.all_gather(d_l, DPU_AXIS, axis=0, tiled=True)
            return ops.robust_aggregate_plane(x_l, d_l, te, mode=mode,
                                              trim_frac=trim_frac,
                                              backend=backend)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(r_ax, None), P(g_ax, r_ax, None), P()),
            out_specs=P(r_ax, None), check_rep=False)(x, d_stack, theta_eta)

    return jax.jit(fn)


def robust_aggregate_plane_sharded(x, d_stack, theta_eta, *, mesh: Mesh,
                                   mode: str = "trimmed_mean",
                                   trim_frac: float = 0.1,
                                   backend: Optional[str] = None):
    """Sharded byzantine-robust eq.-11: all-gather the d-stack over
    'dpu', per-coordinate trimmed-mean/median on own rows — bitwise equal
    to the single-device op."""
    b = ops.resolve_backend(backend)
    return _robust_fn(mesh, b, mode, float(trim_frac))(
        x, d_stack, jnp.asarray(theta_eta, jnp.float32))


# ------------------------------------------------ sharded fused round -----

_SHARDED_ROUND_CACHE: dict = {}


def _sharded_round_fn(loss_fn, spec, mesh: Mesh, kernel_backend: str,
                      eval_fn=None, reduce: str = "exact"):
    """The shard_map'd twin of ``fedprox._plane_round_fn``: one jitted
    program for a homogeneous-group round — gamma-step training scan,
    eq.-10 normalization, eq.-11 aggregation, optional fused eval — with
    the (G, R, LANE) stack split over 'dpu' and plane rows over 'rows'.

    Row sharding is FSDP-shaped: params/acc/gradient state live row-
    sharded; the full plane is all-gathered per local step only for the
    loss/grad evaluation, and each device slices back its own row block
    of the gradient.  Losses are computed redundantly per 'rows' member
    (identical values).  The aggregate is returned row-sharded and
    replicated over 'dpu' — bitwise identical to the single-device
    ``round_run`` under ``reduce="exact"``.
    """
    backend = ops.resolve_backend(kernel_backend)
    key = (loss_fn, spec, mesh, backend, eval_fn, reduce)
    if key in _SHARDED_ROUND_CACHE:
        return _SHARDED_ROUND_CACHE[key]

    def plane_loss(pp, batch, w):
        return loss_fn(spec.unflatten(pp), batch, w)

    vgrad = jax.vmap(jax.value_and_grad(plane_loss))
    take = jax.vmap(lambda xd, ik: xd[ik])

    def round_run(p0, anchor, data_stack, idx, weights, a, eta, mu,
                  w_abs, theta_eta):
        G = p0.shape[0]
        g_ax, r_ax = plane_axes(mesh, G, spec.rows)
        stacked = P(g_ax, r_ax, None)
        master = P(r_ax, None)
        per_dpu = P(g_ax)
        step_arr = P(None, g_ax, None)        # (gamma, G, bucket)
        data_specs = jax.tree_util.tree_map(lambda _: per_dpu, data_stack)

        def shard_body(p0_l, anchor_l, data_l, idx_l, w_l, a_l, eta_s,
                       mu_s, wabs_l, te_s):
            R_loc = p0_l.shape[1]

            def gather_rows(x, axis):
                if r_ax is None:
                    return x
                return jax.lax.all_gather(x, ROW_AXIS, axis=axis,
                                          tiled=True)

            def my_rows(x, axis):
                if r_ax is None:
                    return x
                start = jax.lax.axis_index(ROW_AXIS) * R_loc
                return jax.lax.dynamic_slice_in_dim(x, start, R_loc, axis)

            ones = jnp.ones((p0_l.shape[0],), jnp.float32)
            acc0 = jnp.zeros_like(p0_l)

            def body(carry, inp):
                p, acc = carry
                idx_k, wts_k, a_k = inp
                batch_k = jax.tree_util.tree_map(
                    lambda xd: take(xd, idx_k), data_l)
                losses, g_full = vgrad(gather_rows(p, 1), batch_k, wts_k)
                p, acc = ops.fedprox_accum_plane(
                    p, my_rows(g_full, 1), anchor_l, acc, a_k * ones,
                    ones, eta_s, mu_s, backend=backend)
                return (p, acc), losses

            (_p, acc), losses = jax.lax.scan(
                body, (p0_l, acc0), (idx_l, w_l, a_l))
            d = acc / jnp.sum(a_l)
            if reduce == "psum" and g_ax is not None:
                s = jnp.sum(jax.lax.all_gather(wabs_l, DPU_AXIS,
                                               tiled=True))
                part = jnp.einsum("g,grl->rl", wabs_l / s, d)
                new = anchor_l - te_s * jax.lax.psum(part, DPU_AXIS)
            else:
                if g_ax is not None:
                    d = jax.lax.all_gather(d, DPU_AXIS, axis=0, tiled=True)
                    wabs_l = jax.lax.all_gather(wabs_l, DPU_AXIS,
                                                tiled=True)
                w = wabs_l / jnp.sum(wabs_l)   # the single normalization
                new = ops.nova_aggregate_plane(anchor_l, d, w, te_s,
                                               backend=backend)
            if eval_fn is None:
                return new, losses, ()
            # eval on the gathered full plane, redundantly per shard —
            # same compute graph as single-device, so bitwise identical
            return new, losses, eval_fn(spec.unflatten(gather_rows(new, 0)))

        return shard_map(
            shard_body, mesh=mesh,
            in_specs=(stacked, master, data_specs, step_arr, step_arr,
                      P(None), P(), P(), per_dpu, P()),
            out_specs=(master, P(None, g_ax),
                       () if eval_fn is None else P()),
            check_rep=False)(
                p0, anchor, data_stack, idx, weights, a, eta, mu,
                w_abs, theta_eta)

    _SHARDED_ROUND_CACHE[key] = jax.jit(round_run)
    return _SHARDED_ROUND_CACHE[key]


def local_round_plane_sharded(params, loss_fn, datasets, *, gamma: int,
                              m_frac: float, eta: float, mu: float, keys,
                              theta: float, mesh: Mesh,
                              kernel_backend: str = "auto", eval_fn=None,
                              reduce: str = "exact"):
    """Drop-in sharded twin of :func:`fedprox.local_round_plane` — same
    host staging (identical PRNG draws), same return contract, with the
    device program shard_map'd over ``mesh``.  ``reduce="exact"`` is
    bitwise equal to the single-device round."""
    if reduce not in ("exact", "psum"):
        raise ValueError(f"unknown reduce mode {reduce!r}")
    plane = as_plane(params)
    spec = plane.spec
    G = len(datasets)
    p0 = plane.broadcast(G).data
    Ds = [jax.tree_util.tree_leaves(d)[0].shape[0] for d in datasets]
    bszs = [fedprox.batch_size(D, m_frac) for D in Ds]
    bucket = fedprox._bucket(max(bszs))
    assert all(fedprox._bucket(b) == bucket for b in bszs), \
        "grouping must put same-bucket DPUs together"
    a = fedprox.a_coefficients(gamma, eta, mu)
    step_keys = jax.vmap(lambda k: jax.random.split(k, gamma))(
        jnp.stack(keys))
    data_stack, idx, weights = fedprox._stage_group_batches(
        datasets, step_keys, Ds, bucket, gamma, m_frac)
    run = _sharded_round_fn(loss_fn, spec, mesh, kernel_backend, eval_fn,
                            reduce)
    new_data, losses, acc = run(
        p0, plane.data, data_stack, idx, weights, a,
        jnp.asarray(eta, jnp.float32), jnp.asarray(mu, jnp.float32),
        jnp.asarray(Ds, jnp.float32),
        jnp.asarray(theta * eta, jnp.float32))
    mean_loss = np.asarray(losses).mean(axis=0)
    return (plane.with_data(new_data), mean_loss,
            None if eval_fn is None else float(acc))


# ---------------------------------------------------- trace contracts --

from repro.analysis.jaxpr.contracts import Program, contract  # noqa: E402


def _audit_nova_args(mesh: Mesh):
    x = jnp.zeros((8, 1024), jnp.float32)
    d_stack = jnp.ones((4, 8, 1024), jnp.float32)
    weights = jnp.full((4,), 0.25, jnp.float32)
    return (x, d_stack, weights, jnp.asarray(0.05, jnp.float32))


@contract(
    "nova_sharded_exact",
    min_devices=8,
    collectives={"all_gather": 2, "psum": 0},
)
def _nova_exact_contract():
    """reduce="exact" eq.-11: gathers the d-stack + weights over 'dpu'
    and reduces locally — bitwise path, so psum MUST NOT appear."""
    mesh = plane_mesh((4, 2))
    return Program(fn=_nova_fn(mesh, "cpu", "exact"),
                   args=_audit_nova_args(mesh))


@contract(
    "nova_sharded_psum",
    min_devices=8,
    collectives={"psum": 1, "all_gather": 0},
)
def _nova_psum_contract():
    """reduce="psum" eq.-11: local partial weighted sums combined by
    EXACTLY ONE psum over 'dpu' (allclose path)."""
    mesh = plane_mesh((4, 2))
    return Program(fn=_nova_fn(mesh, "cpu", "psum"),
                   args=_audit_nova_args(mesh))


def _audit_sharded_round_program(reduce: str) -> Program:
    from repro.core import fedprox as _fp
    mesh = plane_mesh((4, 2))
    spec, args = _fp._audit_round_args(n_group=4)
    fn = _sharded_round_fn(_fp._audit_loss, spec, mesh, "cpu",
                           reduce=reduce)
    return Program(fn=fn, args=args)


@contract(
    "sharded_round_exact",
    min_devices=8,
    collectives={"psum": 0, "all_gather": "1+"},
)
def _sharded_round_exact_contract():
    """FSDP-shaped sharded round, reduce="exact": row/dpu all-gathers
    only — the bitwise twin of the fused single-device round."""
    return _audit_sharded_round_program("exact")


@contract(
    "sharded_round_psum",
    min_devices=8,
    collectives={"psum": 1, "all_gather": "1+"},
)
def _sharded_round_psum_contract():
    """Sharded round, reduce="psum": exactly one eq.-11 psum over 'dpu'
    on top of the FSDP row gathers."""
    return _audit_sharded_round_program("psum")
