"""Minimal optimizer library (pure pytree transforms, optax-style API).

CE-FL's local training is SGD-based (FedProx); AdamW is provided for the
standard (non-FL) LM-training example path.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable     # (grads, state, params) -> (updates, state)


def sgd(lr: float, momentum: float = 0.0):
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), ()
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        upd = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0):
    def init(params):
        z = lambda x: jnp.zeros(x.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(
                g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m, v, p: -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            m, v, params)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)
