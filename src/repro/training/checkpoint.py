"""Checkpointing: flat-leaf .npz files with a JSON treedef manifest —
dependency-free, deterministic, restartable.

``save_checkpoint`` flattens any pytree into ``arrays.npz`` plus a
``manifest.json`` (treedef string, per-leaf shapes/dtypes, step, caller
metadata).  ``load_checkpoint`` restores into the *structure* of a caller
``like_tree`` and validates it against the manifest before any leaf is
assigned — a structure mismatch used to silently misassign leaves; now it
raises with the exact discrepancy.  The saved ``metadata`` dict rides back
to the caller (the experiments resume path stores its state skeleton
there).
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def save_checkpoint(path, tree, step: int = 0, metadata: dict = None):
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = a.astype(np.float32)     # npz can't store bf16; manifest
        arrays[f"leaf_{i}"] = a          # records the original dtype
    np.savez(path / "arrays.npz", **arrays)
    manifest = {
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "step": step,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "metadata": metadata or {},
    }
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))


def read_manifest(path) -> dict:
    """The checkpoint's manifest dict (treedef string, num_leaves, step,
    per-leaf shapes/dtypes, metadata) without touching the arrays."""
    return json.loads((Path(path) / "manifest.json").read_text())


def _validate(manifest: dict, like_tree, path, strict_shapes: bool):
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    errs = []
    if len(leaves) != manifest["num_leaves"]:
        errs.append(f"leaf count: checkpoint has {manifest['num_leaves']}, "
                    f"like_tree has {len(leaves)}")
    if str(treedef) != manifest["treedef"]:
        errs.append(f"treedef: checkpoint {manifest['treedef']} != "
                    f"like_tree {treedef}")
    if strict_shapes and len(leaves) == manifest["num_leaves"]:
        for i, (leaf, want) in enumerate(zip(leaves, manifest["shapes"])):
            got = list(np.shape(leaf))
            if got != want:
                errs.append(f"leaf {i} shape: checkpoint {want}, "
                            f"like_tree {got}")
    if errs:
        raise ValueError(
            f"checkpoint {path} does not match like_tree: "
            + "; ".join(errs))
    return treedef


def load_checkpoint(path, like_tree, *, strict_shapes: bool = True):
    """Restore a checkpoint into the structure of ``like_tree``.

    The manifest is validated against ``like_tree`` (leaf count, treedef,
    and — unless ``strict_shapes=False`` — per-leaf shapes) *before* any
    leaf is assigned, so a structure mismatch raises instead of silently
    misassigning leaves.  ``strict_shapes=False`` is for states whose leaf
    shapes are legitimately data-dependent (e.g. the experiments RunState,
    whose online-data buffers grow round to round).

    Returns ``(tree, step, metadata)`` — ``metadata`` is the dict passed
    to :func:`save_checkpoint` (the resume path needs it).
    """
    path = Path(path)
    manifest = read_manifest(path)
    treedef = _validate(manifest, like_tree, path, strict_shapes)
    data = np.load(path / "arrays.npz")
    leaves = [_restore_dtype(data[f"leaf_{i}"], manifest["dtypes"][i])
              for i in range(manifest["num_leaves"])]
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            manifest["step"], manifest["metadata"])


def _restore_dtype(a: np.ndarray, want: str):
    """Restore the recorded dtype WITHOUT bouncing through jnp — with
    x64 disabled, ``jnp.asarray`` silently truncates float64/int64
    leaves, which breaks the bit-exact resume guarantee for run state."""
    if str(a.dtype) == want:
        return a
    if want == "bfloat16":
        try:
            import ml_dtypes
            return a.astype(ml_dtypes.bfloat16)
        except ImportError:          # bf16 master copy stays f32
            return jnp.asarray(a).astype(jnp.bfloat16)
    return a.astype(want)
