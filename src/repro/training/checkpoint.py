"""Checkpointing: flat-leaf .npz files with a JSON treedef manifest —
dependency-free, deterministic, restartable.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def save_checkpoint(path, tree, step: int = 0, metadata: dict = None):
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = a.astype(np.float32)     # npz can't store bf16; manifest
        arrays[f"leaf_{i}"] = a          # records the original dtype
    np.savez(path / "arrays.npz", **arrays)
    manifest = {
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "step": step,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "metadata": metadata or {},
    }
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))


def load_checkpoint(path, like_tree):
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    leaves = [jnp.asarray(data[f"leaf_{i}"]).astype(manifest["dtypes"][i])
              for i in range(manifest["num_leaves"])]
    _, treedef = jax.tree_util.tree_flatten(like_tree)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
