from repro.training.checkpoint import load_checkpoint, save_checkpoint  # noqa: F401
from repro.training.optim import adamw, sgd  # noqa: F401
