"""Mamba-2 (SSD: state-space duality, arXiv:2405.21060) mixer.

Training / prefill use the chunked dual form: intra-chunk attention-like
matmuls + an inter-chunk state recurrence carried by ``lax.scan``.  Decode is
the O(1) recurrent step.  ngroups=1 (B/C shared across heads), following the
130m config.

Shapes: d_inner = expand * d_model; H = d_inner / head_dim (P); state N.
State: h (B, H, P, N).  Conv state: (B, conv_width-1, d_conv) where
d_conv = d_inner + 2N (the xBC channels, as in the reference implementation).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig


def mamba_dims(d_model: int, s: SSMConfig):
    d_inner = s.expand * d_model
    nheads = d_inner // s.head_dim
    d_conv = d_inner + 2 * s.state_dim
    return d_inner, nheads, d_conv


def init_mamba_params(key, d_model: int, s: SSMConfig, dtype) -> dict:
    d_inner, H, d_conv = mamba_dims(d_model, s)
    ks = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d_model)
    d_in_proj = 2 * d_inner + 2 * s.state_dim + H   # z, x, B, C, dt
    dt = jnp.exp(jax.random.uniform(ks[2], (H,)) *
                 (np.log(s.dt_max) - np.log(s.dt_min)) + np.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))          # inverse softplus
    return {
        "w_in": (jax.random.normal(ks[0], (d_model, d_in_proj)) * scale).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, d_conv)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_conv,), dtype),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias.astype(jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "w_out": (jax.random.normal(ks[3], (d_inner, d_model))
                  * (1.0 / np.sqrt(d_inner))).astype(dtype),
    }


def _split_in_proj(proj, d_inner, N, H):
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner:2 * d_inner + 2 * N]
    dt = proj[..., 2 * d_inner + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv over sequence.  xBC: (B,S,Cc); conv_w: (W,Cc).
    conv_state: (B,W-1,Cc) trailing context (for decode/prefill chaining)."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(W))
    new_state = xp[:, xp.shape[1] - (W - 1):]
    return jax.nn.silu((out + conv_b).astype(jnp.float32)).astype(xBC.dtype), new_state


def _gated_rmsnorm(y, z, scale, eps=1e-5):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))


def ssd_forward(params: dict, x_in: jnp.ndarray, s: SSMConfig,
                init_state: Optional[dict] = None,
                return_state: bool = False):
    """Chunked SSD. x_in: (B, S, d_model); S % chunk == 0.
    Returns y (B,S,d_model) and optionally {"h":..., "conv":...}."""
    B, S, d_model = x_in.shape
    d_inner, H, d_conv = mamba_dims(d_model, s)
    N, P, Q = s.state_dim, s.head_dim, s.chunk_size
    assert S % Q == 0, (S, Q)
    nc = S // Q

    proj = x_in @ params["w_in"]
    z, xBC, dt_raw = _split_in_proj(proj, d_inner, N, H)
    conv_state0 = None if init_state is None else init_state["conv"]
    xBC, conv_state = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                   conv_state0)
    x = xBC[..., :d_inner].reshape(B, S, H, P).astype(jnp.float32)
    Bm = xBC[..., d_inner:d_inner + N].astype(jnp.float32)       # (B,S,N)
    Cm = xBC[..., d_inner + N:].astype(jnp.float32)              # (B,S,N)

    # optional activation-sharding hint (batch->data, heads->model); same
    # rationale as attention.set_shard_hint (see EXPERIMENTS.md §Perf)
    from repro.models.attention import _constrain_bshd
    x = _constrain_bshd(x)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["a_log"])                                # (H,)
    log_a = dt * A[None, None, :]                                # (B,S,H) <= 0

    # chunk views
    xc = x.reshape(B, nc, Q, H, P)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)
    dtc = dt.reshape(B, nc, Q, H)
    lac = log_a.reshape(B, nc, Q, H)
    cum = jnp.cumsum(lac, axis=2)                                # inclusive
    chunk_decay = cum[:, :, -1]                                  # (B,nc,H)

    # intra-chunk (dual / attention-like) term
    # L[t,j] = exp(cum_t - cum_j) for t >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bctn,bcjn->bctj", Cc, Bc)                   # (B,nc,Q,Q)
    scores = cb[..., None] * L * dtc[:, :, None, :, :]           # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bctjh,bcjhp->bcthp", scores, xc)

    # inter-chunk recurrence over chunk index
    # state contribution of chunk: sum_j exp(cum_end - cum_j) dt_j B_j x_j
    w_end = jnp.exp(chunk_decay[:, :, None, :] - cum) * dtc      # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", w_end, Bc, xc)

    def step(h, inp):
        cs, cd = inp                                             # (B,H,P,N),(B,H)
        h_new = h * jnp.exp(cd)[:, :, None, None] + cs
        return h_new, h                                          # emit previous

    if init_state is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    else:
        h0 = init_state["h"]
    h_final, h_prevs = jax.lax.scan(
        step, h0, (chunk_state.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                             # (B,nc,H,P,N)

    y_inter = jnp.einsum("bctn,bchpn,bcth->bcthp", Cc, h_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + params["d_skip"][None, None, :, None] * x.reshape(B, S, H, P)
    y = y.reshape(B, S, d_inner)
    y = _gated_rmsnorm(y, z, params["norm"])
    out = (y.astype(x_in.dtype)) @ params["w_out"]
    if return_state:
        return out, {"h": h_final, "conv": conv_state}
    return out


def mamba_decode_step(params: dict, x_in: jnp.ndarray, state: dict,
                      s: SSMConfig):
    """Single-token recurrent step. x_in: (B, d_model); state h/conv."""
    B, d_model = x_in.shape
    d_inner, H, d_conv = mamba_dims(d_model, s)
    N, P = s.state_dim, s.head_dim
    proj = x_in @ params["w_in"]
    z, xBC, dt_raw = _split_in_proj(proj, d_inner, N, H)
    # conv: append token, take last W window
    conv_state = state["conv"]                                   # (B,W-1,Cc)
    window = jnp.concatenate([conv_state.astype(xBC.dtype), xBC[:, None]], axis=1)
    out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    xBC = jax.nn.silu(out.astype(jnp.float32))
    new_conv = window[:, 1:]
    x = xBC[:, :d_inner].reshape(B, H, P)
    Bm = xBC[:, d_inner:d_inner + N]
    Cm = xBC[:, d_inner + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["a_log"])
    a = jnp.exp(dt * A[None])                                    # (B,H)
    h = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bm, x)
    y = jnp.einsum("bn,bhpn->bhp", Cm, h)
    y = y + params["d_skip"][None, :, None] * x
    y = _gated_rmsnorm(y.reshape(B, d_inner), z, params["norm"])
    out = y.astype(x_in.dtype) @ params["w_out"]
    return out, {"h": h, "conv": new_conv}


def init_mamba_state(batch: int, d_model: int, s: SSMConfig, dtype):
    d_inner, H, d_conv = mamba_dims(d_model, s)
    return {
        "h": jnp.zeros((batch, H, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, d_conv), dtype),
    }
