"""Mixture-of-Experts with capacity-based einsum dispatch (GSPMD-friendly).

Tokens are processed in groups (default: one sequence per group); dispatch and
combine tensors are (G, T, E, C) one-hots so all routing is expressed as
einsums that XLA/GSPMD can shard (expert dim on the `model` mesh axis turns
the dispatch einsums into all-to-all-style collectives).

Variants covered (per the assigned architectures):
  * top-1 (llama4-maverick) / top-2 (arctic, jamba)
  * dense residual branch in parallel (arctic)
  * always-on shared expert (llama4)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def init_moe_params(key, d_model: int, m: MoEConfig, dtype) -> dict:
    import numpy as np
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / np.sqrt(d_model)
    scale_out = 1.0 / np.sqrt(m.expert_ff)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, m.num_experts)) * scale_in
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (m.num_experts, d_model, m.expert_ff))
                   * scale_in).astype(dtype),
        "w_in": (jax.random.normal(ks[2], (m.num_experts, d_model, m.expert_ff))
                 * scale_in).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (m.num_experts, m.expert_ff, d_model))
                  * scale_out).astype(dtype),
    }
    return p


def moe_capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(c, m.top_k)


def moe_forward(params: dict, x: jnp.ndarray, m: MoEConfig,
                group_size: int = 1024,
                capacity: int = None) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (y, aux) with aux = {load_balance, router_z}.

    ``capacity``: expert capacity override; pass ``group_size`` (worst case)
    for drop-free routing (used by the decode path)."""
    B, S, d = x.shape
    T = B * S
    group_size = min(group_size, T)
    assert T % group_size == 0, (T, group_size)
    G = T // group_size
    xg = x.reshape(G, group_size, d)
    E, k = m.num_experts, m.top_k
    C = capacity if capacity is not None else moe_capacity(group_size, m)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (G,T,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)  # (G,T,k,E)
    # position of each (token, choice) within its expert queue
    flat = onehot.reshape(G, group_size * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                      # (G,T*k,E)
    keep = (pos < C).astype(jnp.float32) * flat
    disp_flat = keep[..., None] * jax.nn.one_hot(pos, C, dtype=jnp.float32)
    disp = disp_flat.reshape(G, group_size, k, E, C)
    dispatch_tok = jnp.sum(disp, axis=2)                       # (G,T,E,C)
    combine_tok = jnp.sum(disp * gate_vals[..., None, None], axis=2)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch_tok.astype(x.dtype), xg)
    h = jnp.einsum("gecd,edf->gecf", xe, params["w_in"])
    g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_out"])
    y = jnp.einsum("gtec,gecd->gtd", combine_tok.astype(x.dtype), ye)

    # aux losses (Switch-style)
    density = jnp.mean(jnp.sum(onehot, axis=2), axis=1)        # (G,E) dispatch frac
    prob_mean = jnp.mean(probs, axis=1)                        # (G,E)
    load_balance = E * jnp.mean(jnp.sum(density * prob_mean, axis=-1))
    router_z = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    aux = {"load_balance": load_balance, "router_z": router_z}
    return y.reshape(B, S, d), aux
