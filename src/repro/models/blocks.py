"""Decoder layers (attention / mamba mixers + MLP / MoE) and period specs.

Layers are scanned over "periods": the smallest repeating pattern of layer
kinds (attention vs mamba) and MoE placement.  Params for one period are a
dict ``{"layer_0": {...}, ...}``; the full stack adds a leading period axis to
every leaf, consumed by ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.common import (ShardCtx, apply_rope, dense_init,
                                 rms_norm, rope_frequencies)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str          # 'A' | 'M'
    use_moe: bool
    has_mlp: bool      # dense MLP present (False for mamba2 pure blocks)


def period_spec(cfg: ModelConfig) -> List[LayerSpec]:
    pat = cfg.layer_pattern
    moe_n = cfg.moe.every_n_layers if cfg.moe else 1
    plen = int(np.lcm(len(pat), moe_n)) if cfg.moe else len(pat)
    specs = []
    for i in range(plen):
        kind = pat[i % len(pat)]
        use_moe = cfg.moe is not None and (i % moe_n == moe_n - 1)
        has_mlp = (cfg.d_ff > 0) and not use_moe
        specs.append(LayerSpec(kind, use_moe, has_mlp))
    return specs


def num_periods(cfg: ModelConfig) -> int:
    plen = len(period_spec(cfg))
    assert cfg.num_layers % plen == 0, (cfg.name, cfg.num_layers, plen)
    return cfg.num_layers // plen


# ---------------------------------------------------------------- init ----

def init_attn_params(key, cfg: ModelConfig, dtype, cross: bool = False):
    d, Hq, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (Hq, Dh), dtype),
        "wk": dense_init(ks[1], d, (Hkv, Dh), dtype),
        "wv": dense_init(ks[2], d, (Hkv, Dh), dtype),
        "wo": (jax.random.normal(ks[3], (Hq, Dh, d)) /
               np.sqrt(Hq * Dh)).astype(dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((Dh,), dtype)
        p["k_norm"] = jnp.zeros((Dh,), dtype)
    return p


def init_mlp_params(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], d, (f,), dtype),
        "w_out": dense_init(ks[1], f, (d,), dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], d, (f,), dtype)
    return p


def init_layer_params(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), dtype)}
    if spec.kind == "A":
        p["attn"] = init_attn_params(ks[0], cfg, dtype)
    else:
        p["mamba"] = mamba_lib.init_mamba_params(ks[0], d, cfg.ssm, dtype)
    if spec.use_moe:
        p["ln2"] = jnp.zeros((d,), dtype)
        p["moe"] = moe_lib.init_moe_params(ks[1], d, cfg.moe, dtype)
        if cfg.moe.dense_residual or cfg.moe.shared_expert:
            p["mlp"] = init_mlp_params(ks[2], cfg, dtype)
    elif spec.has_mlp:
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = init_mlp_params(ks[1], cfg, dtype)
    return p


def init_period_params(key, cfg: ModelConfig, dtype):
    specs = period_spec(cfg)
    ks = jax.random.split(key, len(specs))
    return {f"layer_{j}": init_layer_params(ks[j], cfg, specs[j], dtype)
            for j in range(len(specs))}


def init_stacked_params(key, cfg: ModelConfig, dtype):
    """Period params with a leading ``num_periods`` axis on every leaf."""
    n = num_periods(cfg)
    ks = jax.random.split(key, n)
    per = [init_period_params(k, cfg, dtype) for k in ks]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *per)


# --------------------------------------------------------------- apply ----

def mlp_forward(p, x, cfg: ModelConfig):
    h = x @ p["w_in"]
    if cfg.gated_mlp:
        g = x @ p["w_gate"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return h @ p["w_out"]


def attn_forward(p, x, cfg: ModelConfig, *, angles, causal=True,
                 kv_override=None, q_block=512, kv_block=512):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    kv_src = x if kv_override is None else kv_override
    k = jnp.einsum("bsd,dhx->bshx", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhx->bshx", kv_src, p["wv"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k_angles = angles if kv_override is None else None
        if k_angles is not None:
            k = apply_rope(k, k_angles)
    out = attn_lib.blocked_attention(
        q, k, v, causal=causal,
        window=cfg.sliding_window if causal else None,
        q_block=q_block, kv_block=kv_block)
    return jnp.einsum("bshx,hxd->bsd", out, p["wo"]), (k, v)


def attn_decode(p, x, cfg: ModelConfig, cache, pos, *, ctx: ShardCtx,
                window=None):
    """x: (B, d) single token; cache: {'k','v'} (B,S,Hkv,D); pos scalar."""
    q = jnp.einsum("bd,dhx->bhx", x, p["wq"])
    k = jnp.einsum("bd,dhx->bhx", x, p["wk"])
    v = jnp.einsum("bd,dhx->bhx", x, p["wv"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if not cfg.is_encdec:   # RoPE (enc-dec uses learned absolute positions)
        angle = rope_frequencies(cfg.head_dim, cfg.rope_theta,
                                 jnp.asarray(pos)[None])      # (1, D/2)
        q = apply_rope(q[:, None], angle)[:, 0]
        k = apply_rope(k[:, None], angle)[:, 0]
    S = cache["k"].shape[1]
    if window is not None and S == window:
        # rolling window cache: write at pos % window
        slot = jnp.mod(pos, window)
    else:
        slot = pos
    kc = jax.lax.dynamic_update_slice(cache["k"], k[:, None].astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v[:, None].astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    cache_len = jnp.minimum(pos + 1, S)
    eff_window = None if (window is not None and S == window) else window
    if ctx.seq_shard_decode and ctx.on_mesh:
        out = attn_lib.decode_attention_seq_sharded(
            q, kc, vc, cache_len, ctx=ctx, window=eff_window)
    else:
        out = attn_lib.decode_attention_plain(q, kc, vc, cache_len,
                                              window=eff_window)
    y = jnp.einsum("bhx,hxd->bd", out, p["wo"])
    return y, {"k": kc, "v": vc}


def cross_attn_decode(p, x, cfg: ModelConfig, cross_cache):
    """Decoder cross-attention against a fixed encoder cache."""
    q = jnp.einsum("bd,dhx->bhx", x, p["wq"])
    kc, vc = cross_cache["k"], cross_cache["v"]
    out = attn_lib.decode_attention_plain(q, kc, vc, kc.shape[1])
    return jnp.einsum("bhx,hxd->bd", out, p["wo"])


def layer_forward(params, x, cfg: ModelConfig, spec: LayerSpec, *,
                  angles, ssm_state=None, return_ssm_state=False,
                  q_block=512, kv_block=512):
    """Full-sequence layer (train / prefill).  Returns (x, aux, kv, ssm_state)."""
    aux = {"load_balance": jnp.zeros((), jnp.float32),
           "router_z": jnp.zeros((), jnp.float32)}
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    kv = None
    new_state = None
    if spec.kind == "A":
        y, kv = attn_forward(params["attn"], h, cfg, angles=angles,
                             q_block=q_block, kv_block=kv_block)
    else:
        if return_ssm_state:
            y, new_state = mamba_lib.ssd_forward(
                params["mamba"], h, cfg.ssm, init_state=ssm_state,
                return_state=True)
        else:
            y = mamba_lib.ssd_forward(params["mamba"], h, cfg.ssm,
                                      init_state=ssm_state)
    x = x + y
    if spec.use_moe:
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        y, moe_aux = moe_lib.moe_forward(params["moe"], h, cfg.moe)
        if "mlp" in params:   # arctic dense residual / llama4 shared expert
            y = y + mlp_forward(params["mlp"], h, cfg)
        aux = moe_aux
        x = x + y
    elif spec.has_mlp:
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        x = x + mlp_forward(params["mlp"], h, cfg)
    return x, aux, kv, new_state


def layer_decode(params, x, cfg: ModelConfig, spec: LayerSpec, cache, pos, *,
                 ctx: ShardCtx, window=None):
    """Single-token layer step.  cache is the per-layer cache dict."""
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if spec.kind == "A":
        y, new_cache = attn_decode(params["attn"], h, cfg, cache, pos,
                                   ctx=ctx, window=window)
    else:
        y, new_cache = mamba_lib.mamba_decode_step(params["mamba"], h,
                                                   cache, cfg.ssm)
    x = x + y
    if spec.use_moe:
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        gs = min(1024, h.shape[0])
        y, _ = moe_lib.moe_forward(params["moe"], h[:, None], cfg.moe,
                                   group_size=gs, capacity=gs)  # drop-free
        y = y[:, 0]
        if "mlp" in params:
            y = y + mlp_forward(params["mlp"], h, cfg)
        x = x + y
    elif spec.has_mlp:
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        x = x + mlp_forward(params["mlp"], h, cfg)
    return x, new_cache
