"""Shared NN building blocks (pure JAX, explicit param pytrees)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """How the model should express distribution.

    ``batch_axes``: mesh axis names carrying the batch dimension.
    ``model_axis``: tensor-parallel axis name (heads / ff / experts).
    ``seq_shard_decode``: decode KV caches are sequence-sharded over
    ``cache_axes`` and attention runs the shard_map psum safe-softmax.
    ``None`` mesh -> single-device paths everywhere (tests / CPU examples).
    """
    mesh: Optional[object] = None          # jax.sharding.Mesh
    batch_axes: tuple = ("data",)
    model_axis: Optional[str] = "model"
    cache_axes: tuple = ("model",)
    seq_shard_decode: bool = False

    @property
    def on_mesh(self) -> bool:
        return self.mesh is not None


NO_SHARD = ShardCtx()


def dense_init(key, in_dim, out_shape, dtype, scale=None):
    fan_in = in_dim
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, (in_dim,) + tuple(out_shape)) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


def rope_frequencies(head_dim: int, theta: float, positions: jnp.ndarray):
    """positions: (...,) int32 -> (…, head_dim//2) angles."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions[..., None].astype(jnp.float32) * freq


def apply_rope(x, angles):
    """x: (..., S, H, D); angles: (S, D//2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def softmax_cross_entropy(logits, labels, mask=None):
    """logits: (..., V) f32-accumulated; labels int32; mask broadcastable."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
