"""Language models assembled from blocks: decoder-only LMs (dense / MoE / SSM /
hybrid / early-fusion VLM) and the Whisper-style encoder-decoder.

Layers are scanned over periods (see blocks.period_spec) with optional remat.
Loss is computed with a sequence-chunked cross-entropy so the (B, S, V)
logits tensor is never materialized.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models import mamba as mamba_lib
from repro.models.common import (NO_SHARD, ShardCtx, embed_init,
                                 rms_norm, rope_frequencies)


# ------------------------------------------------------------------ init ---

def init_lm_params(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "blocks": B.init_stacked_params(ks[1], cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype).T
    if cfg.is_encdec:
        params["enc"] = {
            "blocks": _init_encoder_params(ks[3], cfg, dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
        params["cross"] = _init_cross_params(ks[4], cfg, dtype)
        # sized for the largest decode shape Whisper runs (decode_32k)
        pos_table = max(32768, cfg.encoder_seq)
        params["pos_embed"] = (jax.random.normal(ks[5], (pos_table, cfg.d_model))
                               * 0.01).astype(dtype)
    return params


def _init_encoder_params(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, cfg.encoder_layers)
    spec = B.LayerSpec("A", False, True)
    per = [B.init_layer_params(k, cfg, spec, dtype) for k in ks]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *per)


def _init_cross_params(key, cfg: ModelConfig, dtype):
    """Cross-attention params for every decoder layer (stacked over periods)."""
    n = B.num_periods(cfg)
    plen = len(B.period_spec(cfg))
    ks = jax.random.split(key, n * plen)
    per = []
    for i in range(n):
        period = {}
        for j in range(plen):
            period[f"layer_{j}"] = {
                "xattn": B.init_attn_params(ks[i * plen + j], cfg, dtype,
                                            cross=True),
                "ln_x": jnp.zeros((cfg.d_model,), dtype),
            }
        per.append(period)
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *per)


# -------------------------------------------------------------- forward ---

def _angles(cfg: ModelConfig, S: int):
    if cfg.is_encdec:
        return None
    pos = jnp.arange(S, dtype=jnp.int32)
    return rope_frequencies(cfg.head_dim, cfg.rope_theta, pos)


def _sinusoid(S: int, d: int):
    pos = np.arange(S)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], -1),
                       jnp.float32)


def lm_backbone(params, cfg: ModelConfig, x, *, remat: bool = True,
                enc_out=None, q_block=512, kv_block=512,
                remat_chunk: int = 1):
    """Run the decoder stack on embeddings x (B, S, d).  Returns (x, aux).

    ``remat_chunk``: periods per checkpoint region.  With chunk g the saved
    residual stream is n_periods/g copies instead of n_periods (activation
    memory / g at ~2x in-chunk recompute) — the coarse-remat lever used by
    the deep/wide configs (llama3-405b) and tuned in EXPERIMENTS.md §Perf.
    """
    specs = B.period_spec(cfg)
    S = x.shape[1]
    angles = _angles(cfg, S)

    def period_fn(x, pp):
        lb = jnp.zeros((), jnp.float32)
        rz = jnp.zeros((), jnp.float32)
        block_p, cross_p = pp if cfg.is_encdec else (pp, None)
        for j, spec in enumerate(specs):
            x, aux, _, _ = B.layer_forward(
                block_p[f"layer_{j}"], x, cfg, spec, angles=angles,
                q_block=q_block, kv_block=kv_block)
            if cfg.is_encdec:
                cp = cross_p[f"layer_{j}"]
                h = rms_norm(x, cp["ln_x"], cfg.norm_eps)
                y, _ = B.attn_forward(cp["xattn"], h, cfg, angles=None,
                                      causal=False, kv_override=enc_out,
                                      q_block=q_block, kv_block=kv_block)
                x = x + y
            lb = lb + aux["load_balance"]
            rz = rz + aux["router_z"]
        return x, (lb, rz)

    xs = (params["blocks"], params["cross"]) if cfg.is_encdec \
        else params["blocks"]
    n_per = B.num_periods(cfg)
    zero = jnp.zeros((), jnp.float32)

    if remat_chunk > 1 and n_per % remat_chunk == 0:
        # two-level remat: outer scan over chunks saves only chunk inputs;
        # inside a chunk's backward, each period is rematted again, so the
        # transient working set is one period, not one chunk.
        xs = jax.tree_util.tree_map(
            lambda a: a.reshape((n_per // remat_chunk, remat_chunk)
                                + a.shape[1:]), xs)
        inner_period = jax.checkpoint(period_fn) if remat else period_fn

        def chunk_fn(x, pp_chunk):
            def inner(carry, pp):
                x, lb, rz = carry
                x, (dlb, drz) = inner_period(x, pp)
                return (x, lb + dlb, rz + drz), None
            (x, lb, rz), _ = jax.lax.scan(inner, (x, zero, zero), pp_chunk)
            return x, (lb, rz)

        body = jax.checkpoint(chunk_fn) if remat else chunk_fn
    else:
        body = jax.checkpoint(period_fn) if remat else period_fn

    def scan_body(carry, pp):
        x, lb, rz = carry
        x, (dlb, drz) = body(x, pp)
        return (x, lb + dlb, rz + drz), None

    (x, lb, rz), _ = jax.lax.scan(scan_body, (x, zero, zero), xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    n = cfg.num_layers
    return x, {"load_balance": lb / n, "router_z": rz / n}


def encoder_forward(params, cfg: ModelConfig, enc_embed, *,
                    q_block=512, kv_block=512):
    """Whisper encoder on stubbed frame embeddings (B, T_enc, d)."""
    x = enc_embed + _sinusoid(enc_embed.shape[1],
                              cfg.d_model).astype(enc_embed.dtype)[None]

    def enc_layer(x, lp):  # bidirectional self-attention + MLP
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, _ = B.attn_forward(lp["attn"], h, cfg, angles=None, causal=False,
                              q_block=q_block, kv_block=kv_block)
        x = x + y
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + B.mlp_forward(lp["mlp"], h, cfg)
        return x, None

    x, _ = jax.lax.scan(enc_layer, x, params["enc"]["blocks"])
    return rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)


def embed_tokens(params, cfg: ModelConfig, tokens, pos_offset=0):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.is_encdec:
        S = tokens.shape[-1]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos_offset, S, 0)
        x = x + pe
    return x


def unembed(params, cfg: ModelConfig, x):
    table = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("...d,dv->...v", x, table,
                      preferred_element_type=jnp.float32)


def chunked_loss(params, cfg: ModelConfig, x, labels, mask=None,
                 chunk: int = 512):
    """Cross-entropy without materializing (B, S, V).  x: (B,S,d)."""
    Bb, S, d = x.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xc = x.reshape(Bb, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(Bb, nc, chunk).swapaxes(0, 1)
    if mask is None:
        mask = jnp.ones((Bb, S), jnp.float32)
    mc = mask.reshape(Bb, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint   # recompute chunk logits in backward (V-sized tiles)
    def chunk_nll(xx, ll, mm):
        logits = unembed(params, cfg, xx)
        logz = jax.scipy.special.logsumexp(logits.astype(jnp.float32),
                                           axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   ll[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mm)

    def step(acc, inp):
        xx, ll, mm = inp
        return (acc[0] + chunk_nll(xx, ll, mm), acc[1] + jnp.sum(mm)), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg: ModelConfig, batch, *, remat: bool = True,
            q_block=512, kv_block=512, example_mask=None,
            remat_chunk: int = 1):
    """batch: {'tokens','labels'} (+'enc_embed' for enc-dec).  Returns
    (loss, aux).  ``example_mask``: (B,) 0/1 — CE-FL mini-batch ratio m_i."""
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encoder_forward(params, cfg, batch["enc_embed"],
                                  q_block=q_block, kv_block=kv_block)
    x, aux = lm_backbone(params, cfg, x, remat=remat, enc_out=enc_out,
                         q_block=q_block, kv_block=kv_block,
                         remat_chunk=remat_chunk)
    mask = None
    if example_mask is not None:
        mask = jnp.broadcast_to(example_mask[:, None],
                                tokens.shape).astype(jnp.float32)
    loss = chunked_loss(params, cfg, x, batch["labels"], mask)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss * aux["load_balance"] \
            + cfg.moe.router_z_loss * aux["router_z"]
    return loss, aux


# ---------------------------------------------------------------- decode ---

def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    """Stacked-by-period cache pytree.  For sliding-window configs the
    attention cache is a rolling buffer of size min(window, cache_len)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    specs = B.period_spec(cfg)
    n = B.num_periods(cfg)
    S = cache_len if cfg.sliding_window is None \
        else min(cfg.sliding_window, cache_len)
    period = {}
    for j, spec in enumerate(specs):
        if spec.kind == "A":
            shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
            period[f"layer_{j}"] = {"k": jnp.zeros(shape, dtype),
                                    "v": jnp.zeros(shape, dtype)}
        else:
            period[f"layer_{j}"] = mamba_lib.init_mamba_state(
                batch, cfg.d_model, cfg.ssm, dtype)
        if cfg.is_encdec:   # fixed cross-attention cache (encoder K/V)
            xshape = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
            period[f"layer_{j}"]["xk"] = jnp.zeros(xshape, dtype)
            period[f"layer_{j}"]["xv"] = jnp.zeros(xshape, dtype)
    blocks = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), period)
    return {"blocks": blocks, "pos": jnp.zeros((), jnp.int32)}


def lm_decode_step(params, cfg: ModelConfig, tokens, cache, *,
                   ctx: ShardCtx = NO_SHARD, enc_out=None):
    """tokens: (B,) int32 — one new token per sequence.  Returns
    (logits (B, V), new_cache)."""
    specs = B.period_spec(cfg)
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.is_encdec:
        x = x + jnp.take(params["pos_embed"], pos, axis=0).astype(x.dtype)

    def period_fn(x, pp):
        if cfg.is_encdec:
            block_p, cross_p, cache_p = pp
        else:
            block_p, cache_p = pp
            cross_p = None
        new_caches = {}
        for j, spec in enumerate(specs):
            x, nc = B.layer_decode(block_p[f"layer_{j}"], x, cfg, spec,
                                   cache_p[f"layer_{j}"], pos, ctx=ctx,
                                   window=cfg.sliding_window)
            if cfg.is_encdec:
                cp = cross_p[f"layer_{j}"]
                h = rms_norm(x, cp["ln_x"], cfg.norm_eps)
                y = B.cross_attn_decode(cp["xattn"], h, cfg,
                                        {"k": cache_p[f"layer_{j}"]["xk"],
                                         "v": cache_p[f"layer_{j}"]["xv"]})
                x = x + y
                nc = dict(nc)
                nc["xk"] = cache_p[f"layer_{j}"]["xk"]
                nc["xv"] = cache_p[f"layer_{j}"]["xv"]
            new_caches[f"layer_{j}"] = nc
        return x, new_caches

    def scan_body(x, pp):
        return period_fn(x, pp)

    xs = (params["blocks"], params["cross"], cache["blocks"]) \
        if cfg.is_encdec else (params["blocks"], cache["blocks"])
    x, new_blocks = jax.lax.scan(scan_body, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, {"blocks": new_blocks, "pos": pos + 1}


def make_cross_cache(params, cfg: ModelConfig, enc_out):
    """Precompute per-decoder-layer cross-attention K/V from encoder output
    and merge into the cache blocks (enc-dec only)."""
    def per_period(cp):
        out = {}
        for j in range(len(B.period_spec(cfg))):
            p = cp[f"layer_{j}"]["xattn"]
            k = jnp.einsum("bsd,dhx->bshx", enc_out, p["wk"])
            v = jnp.einsum("bsd,dhx->bshx", enc_out, p["wv"])
            out[f"layer_{j}"] = {"xk": k, "xv": v}
        return out

    return jax.vmap(per_period)(params["cross"])


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, *,
            ctx: ShardCtx = NO_SHARD, enc_embed=None,
            q_block=512, kv_block=512):
    """Process a prompt (B, S) and return (last_logits, cache)."""
    specs = B.period_spec(cfg)
    S = tokens.shape[1]
    x = embed_tokens(params, cfg, tokens)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encoder_forward(params, cfg, enc_embed,
                                  q_block=q_block, kv_block=kv_block)
    angles = _angles(cfg, S)

    def period_fn(x, pp):
        if cfg.is_encdec:
            block_p, cross_p = pp
        else:
            block_p, cross_p = pp, None
        caches = {}
        for j, spec in enumerate(specs):
            if spec.kind == "A":
                x_res = x
                h = rms_norm(x, block_p[f"layer_{j}"]["ln1"], cfg.norm_eps)
                y, (k, v) = B.attn_forward(
                    block_p[f"layer_{j}"]["attn"], h, cfg, angles=angles,
                    q_block=q_block, kv_block=kv_block)
                x = x_res + y
                if cfg.sliding_window is not None and S > cfg.sliding_window:
                    k = k[:, -cfg.sliding_window:]
                    v = v[:, -cfg.sliding_window:]
                caches[f"layer_{j}"] = {"k": k, "v": v}
                lp = block_p[f"layer_{j}"]
                if "moe" in lp:
                    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                    y, _ = B.moe_lib.moe_forward(lp["moe"], h, cfg.moe)
                    if "mlp" in lp:
                        y = y + B.mlp_forward(lp["mlp"], h, cfg)
                    x = x + y
                elif "mlp" in lp:
                    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                    x = x + B.mlp_forward(lp["mlp"], h, cfg)
            else:
                x, aux, _, st = B.layer_forward(
                    block_p[f"layer_{j}"], x, cfg, spec, angles=angles,
                    return_ssm_state=True, q_block=q_block, kv_block=kv_block)
                caches[f"layer_{j}"] = st
            if cfg.is_encdec:
                cp = cross_p[f"layer_{j}"]
                h = rms_norm(x, cp["ln_x"], cfg.norm_eps)
                y, (xk, xv) = B.attn_forward(cp["xattn"], h, cfg, angles=None,
                                             causal=False, kv_override=enc_out,
                                             q_block=q_block, kv_block=kv_block)
                x = x + y
                caches[f"layer_{j}"]["xk"] = xk
                caches[f"layer_{j}"]["xv"] = xv
        return x, caches

    xs = (params["blocks"], params["cross"]) if cfg.is_encdec \
        else params["blocks"]
    x, blocks = jax.lax.scan(period_fn, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1]
    logits = unembed(params, cfg, last)
    cache = {"blocks": _pad_cache_to(blocks, cfg, cache_len),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def _pad_cache_to(blocks, cfg: ModelConfig, cache_len: int):
    """Grow attention K/V caches from prompt length to cache_len capacity."""
    target = cache_len if cfg.sliding_window is None \
        else min(cfg.sliding_window, cache_len)

    def pad(path, x):
        names = [getattr(p, "key", None) for p in path]
        if names and names[-1] in ("k", "v") and x.ndim == 5:
            n, b, s, h, d = x.shape
            if s < target:
                padding = jnp.zeros((n, b, target - s, h, d), x.dtype)
                return jnp.concatenate([x, padding], axis=2)
            return x[:, :, :target]
        return x

    return jax.tree_util.tree_map_with_path(pad, blocks)
