"""Attention: blocked (flash-style) causal/sliding-window for train & prefill,
plus single-token decode paths (plain and sequence-sharded shard_map psum).

All softmax statistics are kept in float32 regardless of activation dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ShardCtx

NEG_INF = -1e30

# Optional activation-sharding hint for attention tensors (B, S, H, D).
# Without it, GSPMD inherits the d_model-sharded layout from the FSDP
# weights and picks head-only (often uneven, e.g. 2-of-56) partitions for
# the attention einsums, leaving the full batch on every device — measured
# ~8-10x compute blowup on arctic/llama3 (EXPERIMENTS.md §Perf).  The
# launcher calls set_shard_hint(mesh, batch_axes, model_axis) before
# tracing; tests/CPU paths leave it unset.
_SHARD_HINT = None


def set_shard_hint(mesh=None, batch_axes=("data",), model_axis="model"):
    global _SHARD_HINT
    if mesh is None:
        _SHARD_HINT = None
    else:
        _SHARD_HINT = (mesh, tuple(batch_axes) or None, model_axis)


def _constrain_bshd(x):
    """Constrain (B, S, H, D) activations: batch->data, heads->model."""
    if _SHARD_HINT is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh, b, m = _SHARD_HINT
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(b, None, m, None)))
    except Exception:       # rank mismatch under exotic transforms: skip
        return x


def blocked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      q_block: int = 512, kv_block: int = 512,
                      flash_vjp: bool = True):
    """Memory-O(S*block) attention with online softmax.

    q: (B, S, Hq, D); k, v: (B, S, Hkv, D).  Returns (B, S, Hq, D).
    ``window``: sliding-window width (keys with q_pos - k_pos >= window are
    masked).  Blocks are processed fully and masked; see EXPERIMENTS.md for the
    FLOP accounting note.

    ``flash_vjp``: use the custom flash backward (recompute probabilities per
    block; saves only out+lse).  Without it, AD through the scans stores every
    (q_block x kv_block) probability tile — O(S^2) memory.
    """
    if flash_vjp:
        return _flash_attention(q, k, v, causal, window, q_block, kv_block)
    return _blocked_attention_fwd_only(q, k, v, causal=causal, window=window,
                                       q_block=q_block, kv_block=kv_block)[0]


def _blocked_attention_fwd_only(q, k, v, *, causal, window, q_block,
                                kv_block):
    """Forward pass; returns (out, lse) with lse: (B, Hkv, G, S) f32."""
    q = _constrain_bshd(q)
    k = _constrain_bshd(k)
    v = _constrain_bshd(v)
    B, S, Hq, D = q.shape
    S_kv = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    def _fit(n, b):
        b = min(b, n)
        while n % b:
            b -= 1
        return b

    q_block = _fit(S, q_block)
    kv_block = _fit(S_kv, kv_block)
    nq, nk = S // q_block, S_kv // kv_block
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    qb = q.reshape(B, nq, q_block, Hkv, G, D)
    kb = k.reshape(B, nk, kv_block, Hkv, D)
    vb = v.reshape(B, nk, kv_block, Hkv, D)

    q_pos = jnp.arange(S, dtype=jnp.int32).reshape(nq, q_block)
    k_pos = jnp.arange(S_kv, dtype=jnp.int32).reshape(nk, kv_block)

    def one_q_block(_, qi):
        qq, qpos = qi  # (B, q_block, Hkv, G, D), (q_block,)

        def kv_step(carry, ki):
            acc, m, l = carry
            kk, vv, kpos = ki
            # s: (B, K, G, q_block, kv_block), f32
            s = jnp.einsum("bqkgd,bskd->bkgqs", qq, kk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_block, kv_block), dtype=bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vv.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, Hkv, G, q_block, D), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), k_pos))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        # (B, K, G, q_block, D) -> (B, q_block, K, G, D)
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (blocks, lses) = jax.lax.scan(one_q_block, None,
                                     (qb.swapaxes(0, 1), q_pos))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hq, D)
    # lses: (nq, B, Hkv, G, q_block) -> (B, Hkv, G, S)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, S)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, window, q_block, kv_block):
    out, _ = _blocked_attention_fwd_only(q, k, v, causal=causal,
                                         window=window, q_block=q_block,
                                         kv_block=kv_block)
    return out


def _flash_fwd(q, k, v, causal, window, q_block, kv_block):
    out, lse = _blocked_attention_fwd_only(q, k, v, causal=causal,
                                           window=window, q_block=q_block,
                                           kv_block=kv_block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_block, kv_block, res, dout):
    """Recompute probabilities per (q,kv) block pair (FlashAttention-2
    backward), accumulating dq over kv blocks and dk/dv over q blocks."""
    q, k, v, out, lse = res
    q = _constrain_bshd(q)
    k = _constrain_bshd(k)
    v = _constrain_bshd(v)
    dout = _constrain_bshd(dout)
    B, S, Hq, D = q.shape
    S_kv = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv

    def _fit(n, b):
        b = min(b, n)
        while n % b:
            b -= 1
        return b

    qb_sz = _fit(S, q_block)
    kb_sz = _fit(S_kv, kv_block)
    nq, nk = S // qb_sz, S_kv // kb_sz
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    qg = q.reshape(B, nq, qb_sz, Hkv, G, D)
    kg = k.reshape(B, nk, kb_sz, Hkv, D)
    vg = v.reshape(B, nk, kb_sz, Hkv, D)
    og = out.reshape(B, nq, qb_sz, Hkv, G, D)
    dog = dout.reshape(B, nq, qb_sz, Hkv, G, D)
    lseg = lse.reshape(B, Hkv, G, nq, qb_sz)
    # delta_i = rowsum(dout * out): (B, Hkv, G, nq, qb)
    delta = jnp.einsum("bqtkgd,bqtkgd->bkgqt", dog.astype(jnp.float32),
                       og.astype(jnp.float32))
    q_pos = jnp.arange(S, dtype=jnp.int32).reshape(nq, qb_sz)
    k_pos = jnp.arange(S_kv, dtype=jnp.int32).reshape(nk, kb_sz)

    def kv_blk(dq_acc, j):
        kk = kg[:, j]                     # (B, kb, K, D)
        vv = vg[:, j]
        kpos = k_pos[j]

        def q_blk(carry, i):
            dk_a, dv_a, dq_in = carry
            qq = qg[:, i]                 # (B, qb, K, G, D)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qq, kk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qb_sz, kb_sz), dtype=bool)
            if causal:
                mask &= q_pos[i][:, None] >= kpos[None, :]
            if window is not None:
                mask &= q_pos[i][:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lseg[:, :, :, i][..., None])      # (B,K,G,qb,kb)
            do = dog[:, i].astype(jnp.float32)                # (B,qb,K,G,D)
            dv_blk = jnp.einsum("bkgqs,bqkgd->bskd", p, do)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", do,
                            vv.astype(jnp.float32))
            ds = p * (dp - delta[:, :, :, i][..., None]) * scale
            dq_blk = jnp.einsum("bkgqs,bskd->bqkgd", ds,
                                kk.astype(jnp.float32))
            dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", ds,
                                qq.astype(jnp.float32))
            dq_in = dq_in.at[:, i].add(dq_blk)
            return (dk_a + dk_blk, dv_a + dv_blk, dq_in), None

        dk0 = jnp.zeros((B, kb_sz, Hkv, D), jnp.float32)
        dv0 = jnp.zeros((B, kb_sz, Hkv, D), jnp.float32)
        (dk_j, dv_j, dq_acc), _ = jax.lax.scan(
            q_blk, (dk0, dv0, dq_acc), jnp.arange(nq))
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, nq, qb_sz, Hkv, G, D), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(kv_blk, dq0, jnp.arange(nk))
    dq = dq.reshape(B, S, Hq, D).astype(q.dtype)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S_kv, Hkv, D
                                                    ).astype(k.dtype)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S_kv, Hkv, D
                                                    ).astype(v.dtype)
    return dq, dk, dv


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def decode_attention_plain(q, k_cache, v_cache, cache_len, *,
                           window: Optional[int] = None):
    """Single-token decode. q: (B, Hq, D); caches: (B, S, Hkv, D);
    cache_len: () or (B,) number of valid positions (the new token's position
    is cache_len-1 after insertion).  Returns (B, Hq, D)."""
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S, dtype=jnp.int32)
    clen = jnp.asarray(cache_len)
    clen = clen[:, None] if clen.ndim == 1 else clen[None]
    valid = pos[None, :] < clen
    if window is not None:
        valid &= pos[None, :] >= clen - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


def decode_attention_seq_sharded(q, k_cache, v_cache, cache_len, *,
                                 ctx: ShardCtx,
                                 window: Optional[int] = None):
    """Decode attention with the KV cache sequence-sharded over
    ``ctx.cache_axes``.  Each shard computes a partial safe-softmax
    (m, l, o); partials are combined with psum/pmax over the cache axes.

    q: (B, Hq, D) — batch sharded over ctx.batch_axes, replicated over cache
    axes.  caches: (B, S, Hkv, D) with S sharded over ctx.cache_axes.
    """
    from jax.sharding import PartitionSpec as P

    axes = tuple(ctx.cache_axes)
    B, S, Hkv, D = k_cache.shape
    n_shards = 1
    for a in axes:
        n_shards *= ctx.mesh.shape[a]
    s_local = S // n_shards

    def local(q, kc, vc, clen):
        # global offset of this shard's sequence slice
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * ctx.mesh.shape[a] + jax.lax.axis_index(a)
        offset = idx * s_local
        Hq = q.shape[1]
        G = Hq // Hkv
        scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
        qg = q.reshape(q.shape[0], Hkv, G, D)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        pos = offset + jnp.arange(s_local, dtype=jnp.int32)
        cl = jnp.asarray(clen)
        cl = cl[:, None] if cl.ndim == 1 else cl[None]
        valid = pos[None, :] < cl
        if window is not None:
            valid &= pos[None, :] >= cl - window
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)
        m = jax.lax.pmax(m_loc, axes)
        p = jnp.exp(s - m[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), axes)
        o = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
        o = jax.lax.psum(o, axes)
        out = o / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(q.shape[0], Hq, D).astype(q.dtype)

    b_ax = tuple(ctx.batch_axes) if ctx.batch_axes else None
    in_specs = (P(b_ax, None, None), P(b_ax, axes, None, None),
                P(b_ax, axes, None, None), P())
    out_specs = P(b_ax, None, None)
    fn = jax.shard_map(local, mesh=ctx.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(q, k_cache, v_cache, jnp.asarray(cache_len))
