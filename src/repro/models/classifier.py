"""The paper's FL workload model (Sec. VI / App. G): a small image classifier
trained with CE-FL / FedNova / FedAvg on (synthetic) F-MNIST / CIFAR-10.

Kept deliberately simple (MLP on flattened pixels) so hundreds of FL rounds
run quickly on CPU; the FL orchestration layer is model-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cefl_paper import ClassifierConfig


def init_classifier_params(key, cfg: ClassifierConfig):
    dims = [int(np.prod(cfg.input_shape))] + list(cfg.hidden) + [cfg.num_classes]
    ks = jax.random.split(key, len(dims) - 1)
    params = {}
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = (jax.random.normal(ks[i], (din, dout))
                           * np.sqrt(2.0 / din)).astype(cfg.dtype)
        params[f"b{i}"] = jnp.zeros((dout,), cfg.dtype)
    return params


def classifier_logits(params, x):
    """x: (B, *input_shape) or (B, D)."""
    h = x.reshape(x.shape[0], -1)
    n = len(params) // 2
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def classifier_loss(params, batch, example_weights=None):
    """Mean cross-entropy; ``example_weights``: (B,) 0/1 mini-batch mask."""
    logits = classifier_logits(params, batch["x"]).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    nll = logz - gold
    if example_weights is not None:
        w = example_weights.astype(jnp.float32)
        return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    return jnp.mean(nll)


def classifier_accuracy(params, x, y):
    pred = jnp.argmax(classifier_logits(params, x), axis=-1)
    return jnp.mean((pred == y).astype(jnp.float32))
