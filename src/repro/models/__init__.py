"""Model zoo: decoder-only LMs (dense / MoE / SSM / hybrid / VLM), the
Whisper-style encoder-decoder, and the paper's FL classifier."""
from repro.models import attention, blocks, classifier, lm, mamba, moe  # noqa: F401
from repro.models.common import NO_SHARD, ShardCtx  # noqa: F401
