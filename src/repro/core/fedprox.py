"""FedProx-style heterogeneous local training at a DPU (paper Sec. II-D).

Implements eqs. (5)-(10): gamma_i local SGD steps on the proximal loss
g_i(x, x^t) = F_i(x) + (mu/2)||x - x^t||^2, with mini-batch ratio m_i, and
the FedNova-normalized accumulated gradient

    d_i = (1/||a_i||_1) sum_l a_{i,l} grad F_i(x^{t,l}),
    a_{i,l} = (1 - eta*mu)^(gamma_i - 1 - l).

``local_train`` is the simulation-level entry point (one DPU, its own
dataset); the mesh-native vectorized round lives in repro.core.round_step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def a_coefficients(gamma: int, eta: float, mu: float) -> jnp.ndarray:
    """a_{i,l} for l = 0..gamma-1 (eq. 8)."""
    ell = jnp.arange(gamma, dtype=jnp.float32)
    return (1.0 - eta * mu) ** (gamma - 1.0 - ell)


def a_norms(gamma, eta, mu):
    a = a_coefficients(gamma, eta, mu)
    return jnp.sum(a), jnp.sum(a * a)


@dataclasses.dataclass
class LocalResult:
    params: dict          # x_i^{(t, gamma_i)}
    d_i: jnp.ndarray      # normalized accumulated gradient (pytree)
    num_examples: int     # D_i^{(t)}
    gamma: int
    sgd_flops: float      # processed examples * gamma (for cost models)
    loss: float = float("nan")   # mean mini-batch loss over the gamma steps


def sample_minibatch(key, num_examples: int, m_frac: float):
    """Uniform without-replacement mini-batch indices (size m_frac * D)."""
    bsz = max(1, int(round(m_frac * num_examples)))
    return jax.random.choice(key, num_examples, (bsz,), replace=False)


def _bucket(n: int) -> int:
    """Round batch sizes up to a power of two so jitted steps are reused
    across rounds with varying dataset sizes."""
    b = 1
    while b < n:
        b *= 2
    return b


_STEP_CACHE = {}


def _prox_step(loss_fn, params, anchor, batch, weights, eta, mu):
    """One proximal SGD step on g_i(x, x^t) (eq. 6) — the single source of
    truth for both the sequential and the vmapped batched paths."""
    loss, gF = jax.value_and_grad(loss_fn)(params, batch, weights)
    new = jax.tree_util.tree_map(
        lambda p, g, x0: p - eta * (g + mu * (p - x0)),
        params, gF, anchor)
    return new, gF, loss


def _prox_step_fn(loss_fn):
    if loss_fn not in _STEP_CACHE:
        _STEP_CACHE[loss_fn] = jax.jit(functools.partial(_prox_step, loss_fn))
    return _STEP_CACHE[loss_fn]


def local_train(params, loss_fn: Callable, data: dict, *, gamma: int,
                m_frac: float, eta: float, mu: float, key) -> LocalResult:
    """Run gamma proximal SGD steps at one DPU.

    loss_fn(params, batch, example_weights) -> weighted mean loss.
    data: dict of arrays with leading dim D_i (the DPU's current dataset).
    Mini-batches are padded to power-of-two buckets (zero example weights)
    so the jitted step is shared across DPUs and rounds.
    """
    anchor = params
    D = jax.tree_util.tree_leaves(data)[0].shape[0]
    a = a_coefficients(gamma, eta, mu)
    a1 = float(jnp.sum(a))
    step = _prox_step_fn(loss_fn)
    acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    keys = jax.random.split(key, gamma)
    eta_j = jnp.asarray(eta, jnp.float32)
    mu_j = jnp.asarray(mu, jnp.float32)
    loss_sum = 0.0
    for k in range(gamma):
        idx = np.asarray(sample_minibatch(keys[k], D, m_frac))
        bsz = _bucket(len(idx))
        pad = np.concatenate([idx, np.zeros(bsz - len(idx), idx.dtype)])
        weights = jnp.asarray(
            np.concatenate([np.ones(len(idx)), np.zeros(bsz - len(idx))]),
            jnp.float32)
        batch = jax.tree_util.tree_map(lambda x: x[pad], data)
        params, gF, loss = step(params, anchor, batch, weights, eta_j, mu_j)
        loss_sum += float(loss)
        acc = jax.tree_util.tree_map(
            lambda acU, g: acU + a[k] * g, acc, gF)       # eq. (10) numerator
    d_i = jax.tree_util.tree_map(lambda x: x / a1, acc)
    return LocalResult(params=params, d_i=d_i, num_examples=D, gamma=gamma,
                       sgd_flops=float(gamma) * m_frac * D,
                       loss=loss_sum / gamma)


_BATCH_STEP_CACHE = {}


def _prox_step_batched_fn(loss_fn):
    """`_prox_step` for a stack of DPUs (leading group axis on
    params/batch/weights; the anchor x^t is shared)."""
    if loss_fn not in _BATCH_STEP_CACHE:
        step = jax.vmap(functools.partial(_prox_step, loss_fn),
                        in_axes=(0, None, 0, 0, None, None))
        _BATCH_STEP_CACHE[loss_fn] = jax.jit(step)
    return _BATCH_STEP_CACHE[loss_fn]


def local_train_batched(params, loss_fn: Callable, datasets, *, gamma: int,
                        m_frac: float, eta: float, mu: float, keys):
    """``local_train`` for a homogeneous-(gamma, m) group of DPUs, all
    starting from the same global ``params``, through ONE vmapped proximal
    step per local iteration instead of one jitted call per DPU.

    ``datasets``: list of per-DPU data dicts (sizes may differ — every
    DPU's mini-batch must land in the same power-of-two bucket, which the
    caller guarantees by grouping).  ``keys``: one PRNG key per DPU; each
    is split into gamma step keys exactly like the sequential path, so the
    per-DPU mini-batch draws match ``local_train`` bit-for-bit.
    """
    G = len(datasets)
    anchor = params
    Ds = [jax.tree_util.tree_leaves(d)[0].shape[0] for d in datasets]
    bszs = [max(1, int(round(m_frac * D))) for D in Ds]
    bucket = _bucket(max(bszs))
    assert all(_bucket(b) == bucket for b in bszs), \
        "grouping must put same-bucket DPUs together"
    a = a_coefficients(gamma, eta, mu)
    a1 = float(jnp.sum(a))
    step = _prox_step_batched_fn(loss_fn)
    p_stack = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), params)
    acc = jax.tree_util.tree_map(
        lambda x: jnp.zeros((G,) + x.shape, x.dtype), params)
    step_keys = [jax.random.split(k, gamma) for k in keys]
    eta_j = jnp.asarray(eta, jnp.float32)
    mu_j = jnp.asarray(mu, jnp.float32)
    loss_sum = np.zeros(G)
    for k in range(gamma):
        micro, wts = [], []
        for j, d in enumerate(datasets):
            idx = np.asarray(sample_minibatch(step_keys[j][k], Ds[j], m_frac))
            pad = np.concatenate([idx, np.zeros(bucket - len(idx), idx.dtype)])
            wts.append(np.concatenate([np.ones(len(idx)),
                                       np.zeros(bucket - len(idx))]))
            micro.append(jax.tree_util.tree_map(lambda x: x[pad], d))
        batch = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *micro)
        weights = jnp.asarray(np.stack(wts), jnp.float32)
        p_stack, gF, losses = step(p_stack, anchor, batch, weights,
                                   eta_j, mu_j)
        loss_sum += np.asarray(losses)
        acc = jax.tree_util.tree_map(
            lambda acU, g: acU + a[k] * g, acc, gF)
    d_stack = jax.tree_util.tree_map(lambda x: x / a1, acc)
    return [LocalResult(
        params=jax.tree_util.tree_map(lambda x: x[j], p_stack),
        d_i=jax.tree_util.tree_map(lambda x: x[j], d_stack),
        num_examples=Ds[j], gamma=gamma,
        sgd_flops=float(gamma) * m_frac * Ds[j],
        loss=float(loss_sum[j] / gamma)) for j in range(G)]


def verify_accumulation_identity(params0, result: LocalResult, *, eta, mu):
    """Check eq. (9): sum_l a_l grad F = (x^t - x^{t,gamma})/eta  holds only
    for mu=0 (with prox, the update uses grad g, not grad F).  Returns the
    max abs deviation of the mu=0 identity — used by tests."""
    diff = jax.tree_util.tree_map(
        lambda x0, xg: (x0 - xg) / eta, params0, result.params)
    a1 = float(jnp.sum(a_coefficients(result.gamma, eta, mu)))
    dev = jax.tree_util.tree_map(
        lambda d, acc: jnp.max(jnp.abs(d - acc * a1)), diff, result.d_i)
    return max(float(x) for x in jax.tree_util.tree_leaves(dev))
