"""FedProx-style heterogeneous local training at a DPU (paper Sec. II-D).

Implements eqs. (5)-(10): gamma_i local SGD steps on the proximal loss
g_i(x, x^t) = F_i(x) + (mu/2)||x - x^t||^2, with mini-batch ratio m_i, and
the FedNova-normalized accumulated gradient

    d_i = (1/||a_i||_1) sum_l a_{i,l} grad F_i(x^{t,l}),
    a_{i,l} = (1 - eta*mu)^(gamma_i - 1 - l).

``local_train`` is the simulation-level entry point (one DPU, its own
dataset); the mesh-native vectorized round lives in repro.core.round_step.

Backends (``backend=`` on both entry points):

* ``"plane"`` (default, the hot path): parameters/gradients live on the
  flat ``(G, R, LANE)`` parameter plane (``kernels.plane``).  All gamma
  local iterations of a whole homogeneous DPU group run as ONE jitted
  ``lax.scan`` whose per-step body is a vmapped loss/grad evaluation plus
  a single fused Pallas launch (``fedprox_accum_2d``) doing the proximal
  update AND the eq.-10 accumulation — no per-leaf tree_map chains, no
  per-step host sync.
* ``"tree"`` — the pre-plane per-leaf reference path, kept for
  equivalence tests and the tree-vs-plane benchmark.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.plane import ParamPlane, as_plane


def a_coefficients(gamma: int, eta: float, mu: float) -> jnp.ndarray:
    """a_{i,l} for l = 0..gamma-1 (eq. 8)."""
    ell = jnp.arange(gamma, dtype=jnp.float32)
    return (1.0 - eta * mu) ** (gamma - 1.0 - ell)


def a_norms(gamma, eta, mu):
    a = a_coefficients(gamma, eta, mu)
    return jnp.sum(a), jnp.sum(a * a)


@dataclasses.dataclass
class LocalResult:
    params: object        # x_i^{(t, gamma_i)} (pytree or ParamPlane)
    d_i: object           # normalized accumulated gradient (same kind)
    num_examples: int     # D_i^{(t)}
    gamma: int
    sgd_flops: float      # processed examples * gamma (for cost models)
    loss: float = float("nan")   # mean mini-batch loss over the gamma steps


def batch_size(num_examples: int, m_frac: float) -> int:
    """clamp(round(m_frac * D), 1, D) — the one mini-batch size rule
    (0 for a degenerate D == 0 dataset)."""
    if num_examples <= 0:
        return 0
    return max(1, min(num_examples, int(round(m_frac * num_examples))))


def sample_minibatch(key, num_examples: int, m_frac: float):
    """Uniform without-replacement mini-batch indices of size
    ``batch_size(D, m_frac)``; empty for a degenerate D == 0 dataset
    (offloading splits can leave a DPU with nothing)."""
    bsz = batch_size(num_examples, m_frac)
    if bsz == 0:
        return jnp.zeros((0,), jnp.int32)
    return jax.random.choice(key, num_examples, (bsz,), replace=False)


def _bucket(n: int) -> int:
    """Round batch sizes up to a power of two so jitted steps are reused
    across rounds with varying dataset sizes."""
    b = 1
    while b < n:
        b *= 2
    return b


# ------------------------------------------------- plane hot path -----

_PLANE_TRAIN_CACHE = {}
_PLANE_ROUND_CACHE = {}


def _plane_train_core(loss_fn, spec, batched_anchor: bool, backend: str):
    """The (untraced) full gamma-step local-training loop of a DPU group
    on parameter planes.  The tree view needed by ``loss_fn`` is a
    compile-time slice/reshape of the plane inside the traced graph (its
    transpose re-flattens the gradient) — there is no host-level
    flatten/unflatten anywhere in the loop, and the per-step mini-batch
    GATHER happens inside the scan too: the group's datasets arrive as
    one stacked (G, Db, ...) device tree plus (gamma, G, bucket) index
    arrays, so rounds cost zero per-DPU host gathers.

    ``batched_anchor``: the anchor is (G, R, LANE) — one per element —
    instead of one (R, LANE) plane shared by the group.  This is the
    multi-run form (``local_train_multi``): elements from different
    seeded runs, each proximal to its own global model, in one scan.
    """
    del batched_anchor  # the fused kernel broadcasts either anchor form

    def plane_loss(pp, batch, w):
        return loss_fn(spec.unflatten(pp), batch, w)

    vgrad = jax.vmap(jax.value_and_grad(plane_loss))
    take = jax.vmap(lambda xd, ik: xd[ik])     # per-DPU in-jit gather

    def run(p_stack, anchor, data_stack, idx, weights, a, eta, mu):
        """p_stack: (G, R, LANE); anchor: (R, LANE) shared or
        (G, R, LANE) per-element; ``data_stack`` leaves (G, Db, ...);
        idx: (gamma, G, bucket) i32; weights (gamma, G, bucket);
        a: (gamma,) FedNova coefficients."""
        G = p_stack.shape[0]
        ones = jnp.ones((G,), jnp.float32)
        acc0 = jnp.zeros_like(p_stack)

        def body(carry, inp):
            p, acc = carry
            idx_k, w_k, a_k = inp
            batch_k = jax.tree_util.tree_map(
                lambda xd: take(xd, idx_k), data_stack)
            losses, g = vgrad(p, batch_k, w_k)
            p, acc = ops.fedprox_accum_plane(
                p, g, anchor, acc, a_k * ones, ones, eta, mu,
                backend=backend)
            return (p, acc), losses

        (p, acc), losses = jax.lax.scan(
            body, (p_stack, acc0), (idx, weights, a))
        return p, acc, losses      # losses: (gamma, G)

    return run


def _plane_train_fn(loss_fn, spec, batched_anchor: bool = False,
                    kernel_backend: str = "auto"):
    """Jitted :func:`_plane_train_core` (cached per loss/spec/backend —
    ``"auto"`` resolves against the process default at build time)."""
    backend = ops.resolve_backend(kernel_backend)
    key = (loss_fn, spec, batched_anchor, backend)
    if key not in _PLANE_TRAIN_CACHE:
        _PLANE_TRAIN_CACHE[key] = jax.jit(
            _plane_train_core(loss_fn, spec, batched_anchor, backend))
    return _PLANE_TRAIN_CACHE[key]


def _plane_round_fn(loss_fn, spec, kernel_backend: str = "auto",
                    eval_fn=None):
    """ONE jitted program for a whole homogeneous-group round: the full
    gamma-step training scan, the eq.-10 normalization d = acc/||a||_1,
    the eq.-11 aggregation, and (when ``eval_fn`` is given) the eval
    forward pass on the aggregated model — train+eval in a single jit
    per group, so an eval round costs zero extra dispatches beyond the
    round itself.  Returns (new_plane_data, losses, acc_or_())."""
    backend = ops.resolve_backend(kernel_backend)
    key = (loss_fn, spec, backend, eval_fn)
    if key not in _PLANE_ROUND_CACHE:
        run = _plane_train_core(loss_fn, spec, False, backend)

        def round_run(p_stack, anchor, data_stack, idx, weights, a,
                      eta, mu, w_abs, theta_eta):
            _p, acc, losses = run(p_stack, anchor, data_stack, idx,
                                  weights, a, eta, mu)
            d = acc / jnp.sum(a)               # == host acc/float(sum(a))
            w = w_abs / jnp.sum(w_abs)         # the single normalization
            new = ops.nova_aggregate_plane(anchor, d, w, theta_eta,
                                           backend=backend)
            if eval_fn is None:
                return new, losses, ()
            return new, losses, eval_fn(spec.unflatten(new))

        _PLANE_ROUND_CACHE[key] = jax.jit(round_run)
    return _PLANE_ROUND_CACHE[key]


def local_round_plane(params, loss_fn: Callable, datasets, *, gamma: int,
                      m_frac: float, eta: float, mu: float, keys,
                      theta: float, kernel_backend: str = "auto",
                      eval_fn=None):
    """One FUSED CE-FL round for a homogeneous-(gamma, m) DPU group.

    The gamma-step training scan, the eq.-10 normalization, the eq.-11
    aggregation at ``theta``, and (optionally) the eval forward pass on
    the aggregated model run as ONE jitted program — semantically equal
    to ``local_train_batched`` + ``aggregation.aggregate`` + ``eval_fn``
    but with zero intermediate host round-trips.  The engine's
    :class:`~repro.core.engine.SimExecutor` routes single-group plane
    rounds here.

    Returns ``(new_plane, per_dpu_mean_losses, acc)`` where the losses
    are a host ``(G,)`` array (mean over the gamma steps, the
    ``LocalResult.loss`` convention) and ``acc`` is None unless
    ``eval_fn`` was given.
    """
    plane = as_plane(params)
    spec = plane.spec
    G = len(datasets)
    p0 = plane.broadcast(G).data
    Ds = [jax.tree_util.tree_leaves(d)[0].shape[0] for d in datasets]
    bszs = [batch_size(D, m_frac) for D in Ds]
    bucket = _bucket(max(bszs))
    assert all(_bucket(b) == bucket for b in bszs), \
        "grouping must put same-bucket DPUs together"
    a = a_coefficients(gamma, eta, mu)
    step_keys = jax.vmap(lambda k: jax.random.split(k, gamma))(
        jnp.stack(keys))
    data_stack, idx, weights = _stage_group_batches(datasets, step_keys, Ds,
                                                    bucket, gamma, m_frac)
    run = _plane_round_fn(loss_fn, spec, kernel_backend, eval_fn)
    new_data, losses, acc = run(
        p0, plane.data, data_stack, idx, weights, a,
        jnp.asarray(eta, jnp.float32), jnp.asarray(mu, jnp.float32),
        jnp.asarray(Ds, jnp.float32),
        jnp.asarray(theta * eta, jnp.float32))
    mean_loss = np.asarray(losses).mean(axis=0)         # (G,) — one sync
    return (plane.with_data(new_data), mean_loss,
            None if eval_fn is None else float(acc))


@functools.lru_cache(maxsize=512)
def _choice_all_steps(num_examples: int, bsz: int):
    """Jitted vmapped without-replacement choice: (gamma, 2) step keys ->
    (gamma, bsz) indices.  Identical draws to per-step sample_minibatch
    calls (jax.random is elementwise in the key), but ONE dispatch per DPU
    per round instead of gamma."""
    return jax.jit(jax.vmap(
        lambda k: jax.random.choice(k, num_examples, (bsz,),
                                    replace=False)))


def _stage_group_batches(datasets, step_keys, Ds, bucket, gamma, m_frac):
    """Stage a group's round data DEVICE-SIDE: datasets right-padded to a
    shared power-of-two example bucket and stacked to (G, Db, ...), plus
    (gamma, G, bucket) mini-batch index/weight arrays (same PRNG streams
    as the sequential path).  The per-step gather then happens inside the
    training scan — unlike the old host-side pre-gather, nothing here
    synchronizes on a device value, so staging costs O(G) async dispatches
    instead of O(G) blocking round-trips (the dominant term of the old
    ``sim_round_plane_us`` profile)."""
    G = len(datasets)
    Db = _bucket(max(Ds))
    data_stack = jax.tree_util.tree_map(
        lambda *xs: jnp.stack([
            jnp.pad(x, [(0, Db - x.shape[0])] + [(0, 0)] * (x.ndim - 1))
            for x in xs]), *datasets)
    idx_cols = []
    wts = np.zeros((gamma, G, bucket), np.float32)
    for j in range(G):
        bsz = batch_size(Ds[j], m_frac)
        idx = _choice_all_steps(Ds[j], bsz)(step_keys[j])   # (gamma, bsz)
        idx_cols.append(jnp.pad(idx, ((0, 0), (0, bucket - bsz))))
        wts[:, j, :bsz] = 1.0
    idx_all = jnp.stack(idx_cols, axis=1).astype(jnp.int32)
    return data_stack, idx_all, jnp.asarray(wts)


def _local_train_batched_plane(params, loss_fn, datasets, *, gamma, m_frac,
                               eta, mu, keys, keep_planes=False,
                               anchors=None, kernel_backend="auto"):
    G = len(datasets)
    if anchors is None:
        plane = as_plane(params)
        spec = plane.spec
        p0 = plane.broadcast(G).data
        anchor = plane.data
    else:
        planes = [as_plane(a) for a in anchors]
        spec = planes[0].spec
        assert all(p.spec == spec for p in planes), \
            "multi-run groups must share one FlatSpec (same model)"
        p0 = jnp.stack([p.data for p in planes], axis=0)
        anchor = p0
    Ds = [jax.tree_util.tree_leaves(d)[0].shape[0] for d in datasets]
    bszs = [batch_size(D, m_frac) for D in Ds]
    bucket = _bucket(max(bszs))
    assert all(_bucket(b) == bucket for b in bszs), \
        "grouping must put same-bucket DPUs together"
    a = a_coefficients(gamma, eta, mu)
    a1 = float(jnp.sum(a))
    # one vmapped split for the whole group (same per-DPU streams as
    # sequential `jax.random.split(k, gamma)` calls)
    step_keys = jax.vmap(lambda k: jax.random.split(k, gamma))(
        jnp.stack(keys))
    data_stack, idx, weights = _stage_group_batches(datasets, step_keys, Ds,
                                                    bucket, gamma, m_frac)
    run = _plane_train_fn(loss_fn, spec,
                          batched_anchor=anchors is not None,
                          kernel_backend=kernel_backend)
    p_stack, acc, losses = run(p0, anchor,
                               data_stack, idx, weights, a,
                               jnp.asarray(eta, jnp.float32),
                               jnp.asarray(mu, jnp.float32))
    d_stack = acc / a1
    mean_loss = np.asarray(losses).mean(axis=0)         # (G,)

    def view(stack, j):
        p = ParamPlane(data=stack[j], spec=spec)
        return p if keep_planes else p.to_tree()

    return [LocalResult(
        params=view(p_stack, j), d_i=view(d_stack, j),
        num_examples=Ds[j], gamma=gamma,
        sgd_flops=float(gamma) * m_frac * Ds[j],
        loss=float(mean_loss[j])) for j in range(G)]


# ------------------------------------------------ tree reference path -----

_STEP_CACHE = {}


def _prox_step(loss_fn, params, anchor, batch, weights, eta, mu):
    """One proximal SGD step on g_i(x, x^t) (eq. 6) — the single source of
    truth for both the sequential and the vmapped batched tree paths."""
    loss, gF = jax.value_and_grad(loss_fn)(params, batch, weights)
    new = jax.tree_util.tree_map(
        lambda p, g, x0: p - eta * (g + mu * (p - x0)),
        params, gF, anchor)
    return new, gF, loss


def _prox_step_fn(loss_fn):
    if loss_fn not in _STEP_CACHE:
        _STEP_CACHE[loss_fn] = jax.jit(functools.partial(_prox_step, loss_fn))
    return _STEP_CACHE[loss_fn]


def _local_train_tree(params, loss_fn, data, *, gamma, m_frac, eta, mu,
                      key) -> LocalResult:
    anchor = params
    D = jax.tree_util.tree_leaves(data)[0].shape[0]
    a = a_coefficients(gamma, eta, mu)
    a1 = float(jnp.sum(a))
    step = _prox_step_fn(loss_fn)
    acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    keys = jax.random.split(key, gamma)
    eta_j = jnp.asarray(eta, jnp.float32)
    mu_j = jnp.asarray(mu, jnp.float32)
    loss_sum = 0.0
    for k in range(gamma):
        idx = np.asarray(sample_minibatch(keys[k], D, m_frac))
        bsz = _bucket(len(idx))
        pad = np.concatenate([idx, np.zeros(bsz - len(idx), idx.dtype)])
        weights = jnp.asarray(
            np.concatenate([np.ones(len(idx)), np.zeros(bsz - len(idx))]),
            jnp.float32)
        batch = jax.tree_util.tree_map(lambda x: x[pad], data)
        params, gF, loss = step(params, anchor, batch, weights, eta_j, mu_j)
        loss_sum += float(loss)
        acc = jax.tree_util.tree_map(
            lambda acU, g: acU + a[k] * g, acc, gF)       # eq. (10) numerator
    d_i = jax.tree_util.tree_map(lambda x: x / a1, acc)
    return LocalResult(params=params, d_i=d_i, num_examples=D, gamma=gamma,
                       sgd_flops=float(gamma) * m_frac * D,
                       loss=loss_sum / gamma)


_BATCH_STEP_CACHE = {}


def _prox_step_batched_fn(loss_fn):
    """`_prox_step` for a stack of DPUs (leading group axis on
    params/batch/weights; the anchor x^t is shared)."""
    if loss_fn not in _BATCH_STEP_CACHE:
        step = jax.vmap(functools.partial(_prox_step, loss_fn),
                        in_axes=(0, None, 0, 0, None, None))
        _BATCH_STEP_CACHE[loss_fn] = jax.jit(step)
    return _BATCH_STEP_CACHE[loss_fn]


def _local_train_batched_tree(params, loss_fn, datasets, *, gamma, m_frac,
                              eta, mu, keys):
    G = len(datasets)
    anchor = params
    Ds = [jax.tree_util.tree_leaves(d)[0].shape[0] for d in datasets]
    bszs = [batch_size(D, m_frac) for D in Ds]
    bucket = _bucket(max(bszs))
    assert all(_bucket(b) == bucket for b in bszs), \
        "grouping must put same-bucket DPUs together"
    a = a_coefficients(gamma, eta, mu)
    a1 = float(jnp.sum(a))
    step = _prox_step_batched_fn(loss_fn)
    p_stack = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), params)
    acc = jax.tree_util.tree_map(
        lambda x: jnp.zeros((G,) + x.shape, x.dtype), params)
    step_keys = [jax.random.split(k, gamma) for k in keys]
    eta_j = jnp.asarray(eta, jnp.float32)
    mu_j = jnp.asarray(mu, jnp.float32)
    loss_sum = np.zeros(G)
    for k in range(gamma):
        micro, wts = [], []
        for j, d in enumerate(datasets):
            idx = np.asarray(sample_minibatch(step_keys[j][k], Ds[j], m_frac))
            pad = np.concatenate([idx, np.zeros(bucket - len(idx), idx.dtype)])
            wts.append(np.concatenate([np.ones(len(idx)),
                                       np.zeros(bucket - len(idx))]))
            micro.append(jax.tree_util.tree_map(lambda x: x[pad], d))
        batch = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *micro)
        weights = jnp.asarray(np.stack(wts), jnp.float32)
        p_stack, gF, losses = step(p_stack, anchor, batch, weights,
                                   eta_j, mu_j)
        loss_sum += np.asarray(losses)
        acc = jax.tree_util.tree_map(
            lambda acU, g: acU + a[k] * g, acc, gF)
    d_stack = jax.tree_util.tree_map(lambda x: x / a1, acc)
    return [LocalResult(
        params=jax.tree_util.tree_map(lambda x: x[j], p_stack),
        d_i=jax.tree_util.tree_map(lambda x: x[j], d_stack),
        num_examples=Ds[j], gamma=gamma,
        sgd_flops=float(gamma) * m_frac * Ds[j],
        loss=float(loss_sum[j] / gamma)) for j in range(G)]


# --------------------------------------------------- public entry points -----

def _empty_result(params, gamma: int, keep_planes: bool) -> LocalResult:
    """A D == 0 DPU trains nothing: params unchanged, d_i = 0, nan loss."""
    if keep_planes:
        plane = as_plane(params)
        return LocalResult(params=plane,
                           d_i=plane.with_data(jnp.zeros_like(plane.data)),
                           num_examples=0, gamma=gamma, sgd_flops=0.0)
    tree = params.to_tree() if isinstance(params, ParamPlane) else params
    return LocalResult(params=tree,
                       d_i=jax.tree_util.tree_map(jnp.zeros_like, tree),
                       num_examples=0, gamma=gamma, sgd_flops=0.0)


def local_train(params, loss_fn: Callable, data: dict, *, gamma: int,
                m_frac: float, eta: float, mu: float, key,
                backend: str = "plane", kernel_backend: str = "auto",
                keep_planes: bool = False) -> LocalResult:
    """Run gamma proximal SGD steps at one DPU.

    loss_fn(params, batch, example_weights) -> weighted mean loss.
    data: dict of arrays with leading dim D_i (the DPU's current dataset).
    Mini-batches are padded to power-of-two buckets (zero example weights)
    so the jitted step is shared across DPUs and rounds.

    ``backend="plane"`` (default) runs the whole loop on the flat
    parameter plane through the fused Pallas kernels (the per-DPU PRNG
    stream and numerics match the tree path to float tolerance).
    """
    if jax.tree_util.tree_leaves(data)[0].shape[0] == 0:
        return _empty_result(params, gamma,
                             keep_planes and backend != "tree")
    if backend == "tree":
        return _local_train_tree(params, loss_fn, data, gamma=gamma,
                                 m_frac=m_frac, eta=eta, mu=mu, key=key)
    return _local_train_batched_plane(
        params, loss_fn, [data], gamma=gamma, m_frac=m_frac, eta=eta,
        mu=mu, keys=[key], keep_planes=keep_planes,
        kernel_backend=kernel_backend)[0]


def local_train_batched(params, loss_fn: Callable, datasets, *, gamma: int,
                        m_frac: float, eta: float, mu: float, keys,
                        backend: str = "plane", kernel_backend: str = "auto",
                        keep_planes: bool = False):
    """``local_train`` for a homogeneous-(gamma, m) group of DPUs, all
    starting from the same global ``params``.

    ``datasets``: list of per-DPU data dicts (sizes may differ — every
    DPU's mini-batch must land in the same power-of-two bucket, which the
    caller guarantees by grouping).  ``keys``: one PRNG key per DPU; each
    is split into gamma step keys exactly like the sequential path, so the
    per-DPU mini-batch draws match ``local_train`` bit-for-bit.

    ``backend="plane"`` (default): ONE jitted scan for all gamma steps —
    a vmapped loss/grad plus a single fused kernel launch per local
    iteration.  ``backend="tree"``: one vmapped jitted step per iteration
    with per-leaf tree_map update/accumulation (the reference path).
    ``keep_planes`` returns ParamPlane-backed results (the executors'
    end-to-end plane path); ignored by the tree backend.
    """
    live = [j for j, d in enumerate(datasets)
            if jax.tree_util.tree_leaves(d)[0].shape[0] > 0]
    if len(live) < len(datasets):
        out = [_empty_result(params, gamma,
                             keep_planes and backend != "tree")
               for _ in datasets]
        if live:
            sub = local_train_batched(
                params, loss_fn, [datasets[j] for j in live], gamma=gamma,
                m_frac=m_frac, eta=eta, mu=mu,
                keys=[keys[j] for j in live], backend=backend,
                kernel_backend=kernel_backend, keep_planes=keep_planes)
            for j, r in zip(live, sub):
                out[j] = r
        return out
    if backend == "tree":
        return _local_train_batched_tree(params, loss_fn, datasets,
                                         gamma=gamma, m_frac=m_frac,
                                         eta=eta, mu=mu, keys=keys)
    return _local_train_batched_plane(params, loss_fn, datasets,
                                      gamma=gamma, m_frac=m_frac, eta=eta,
                                      mu=mu, keys=keys,
                                      keep_planes=keep_planes,
                                      kernel_backend=kernel_backend)


def local_train_multi(anchors, loss_fn: Callable, datasets, *, gamma: int,
                      m_frac: float, eta: float, mu: float, keys,
                      kernel_backend: str = "auto",
                      keep_planes: bool = True):
    """Grouped local training where every element carries ITS OWN global
    params/anchor — the cross-run hot path of the multi-seed sweep
    executor (``repro.experiments``): elements (run k, DPU i) drawn from
    K different seeded runs batch into ONE jitted scan, each proximal to
    its own run's global model.

    ``anchors``: one ParamPlane (or pytree) per element, all sharing one
    FlatSpec; ``datasets``/``keys``: as ``local_train_batched`` (all
    datasets non-empty; empty DPUs are the caller's ``_empty_result``).
    Per-element numerics are identical to ``local_train`` with that
    element's anchor: the kernel applies the same elementwise update
    whether the anchor is shared or per-element, and the per-element PRNG
    streams don't depend on the group composition.
    """
    assert len(anchors) == len(datasets) == len(keys)
    assert all(jax.tree_util.tree_leaves(d)[0].shape[0] > 0
               for d in datasets), "local_train_multi needs live datasets"
    return _local_train_batched_plane(
        None, loss_fn, datasets, gamma=gamma, m_frac=m_frac, eta=eta,
        mu=mu, keys=keys, keep_planes=keep_planes, anchors=anchors,
        kernel_backend=kernel_backend)


# ------------------------------------------------ trace-level contracts -----
#
# Registered for `python -m repro.analysis audit` (docs/static_analysis.md).
# The builders below only run when the auditor traces them — registration
# itself is a dict insert.

def _audit_loss(params, batch, w):
    """Pure-jnp weighted CE loss for contract tracing.  Deliberately no
    jax.nn helpers: one_hot/log_softmax are internally jitted and
    default to f64 under jax_enable_x64, which would pollute the
    dtype (JXP002) and fusion-boundary (JXP005) audits with library
    noise instead of auditing OUR round program."""
    logits = batch["x"] @ params["w"] + params["b"]
    s = logits - jax.lax.stop_gradient(
        jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(s), axis=-1))
    n_classes = logits.shape[-1]
    one = (batch["y"][:, None] == jnp.arange(n_classes)[None, :])
    ll = jnp.sum(s * one.astype(jnp.float32), axis=-1) - lse
    return -jnp.sum(ll * w) / jnp.maximum(jnp.sum(w), 1.0)


def _audit_round_args(n_group: int = 2, n_examples: int = 8,
                      n_features: int = 4, n_classes: int = 3,
                      gamma: int = 2, m_frac: float = 1.0):
    """Tiny staged fused-round arguments — the exact 10-tuple
    ``local_round_plane`` feeds ``_plane_round_fn`` (shared with the
    sharded-round and sweep contract builders)."""
    rng = np.random.RandomState(0)
    params = {"w": jnp.zeros((n_features, n_classes), jnp.float32),
              "b": jnp.zeros((n_classes,), jnp.float32)}
    plane = as_plane(params)
    datasets = [
        {"x": jnp.asarray(rng.normal(size=(n_examples, n_features)),
                          jnp.float32),
         "y": jnp.asarray(rng.randint(0, n_classes, size=(n_examples,)),
                          jnp.int32)}
        for _ in range(n_group)]
    Ds = [n_examples] * n_group
    bucket = _bucket(batch_size(n_examples, m_frac))
    a = a_coefficients(gamma, 0.1, 0.01)
    step_keys = jax.vmap(lambda k: jax.random.split(k, gamma))(
        jnp.stack([jax.random.PRNGKey(i) for i in range(n_group)]))
    data_stack, idx, weights = _stage_group_batches(
        datasets, step_keys, Ds, bucket, gamma, m_frac)
    args = (plane.broadcast(n_group).data, plane.data, data_stack, idx,
            weights, a, jnp.asarray(0.1, jnp.float32),
            jnp.asarray(0.01, jnp.float32), jnp.asarray(Ds, jnp.float32),
            jnp.asarray(0.1, jnp.float32))
    return plane.spec, args


from repro.analysis.jaxpr.contracts import Program, contract  # noqa: E402


@contract(
    "fused_round",
    collectives={},                 # single-device: zero collectives
    memory_budget_bytes=4 << 20,    # tiny shapes; ~0.6 MiB today
)
def _fused_round_contract():
    """Single-group fused round: gamma-step train scan + eq.-10/11."""
    spec, args = _audit_round_args()
    return Program(fn=_plane_round_fn(_audit_loss, spec, "cpu", None),
                   args=args)


def verify_accumulation_identity(params0, result: LocalResult, *, eta, mu):
    """Check eq. (9): sum_l a_l grad F = (x^t - x^{t,gamma})/eta  holds only
    for mu=0 (with prox, the update uses grad g, not grad F).  Returns the
    max abs deviation of the mu=0 identity — used by tests."""
    from repro.kernels.plane import as_tree
    res_params = as_tree(result.params)
    res_d = as_tree(result.d_i)
    diff = jax.tree_util.tree_map(
        lambda x0, xg: (x0 - xg) / eta, as_tree(params0), res_params)
    a1 = float(jnp.sum(a_coefficients(result.gamma, eta, mu)))
    dev = jax.tree_util.tree_map(
        lambda d, acc: jnp.max(jnp.abs(d - acc * a1)), diff, res_d)
    return max(float(x) for x in jax.tree_util.tree_leaves(dev))
