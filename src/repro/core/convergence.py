"""Theorem 1 / Corollary 1: ML convergence bound evaluation (paper eqs. 25,
33).  The solver's objective uses ``corollary_bound`` (eq. 33) as term (a) of
problem P, with tau^t replaced by delta^A + delta^R (Sec. IV-1).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class MLConstants:
    """Estimated via repro.core.estimation (paper Algs. 4-7, App. H)."""
    L: float = 1.0            # smoothness
    theta_i: np.ndarray = None    # local data variability (per DPU)
    sigma_i: np.ndarray = None    # local sample std (per DPU)
    zeta1: float = 1.0
    zeta2: float = 0.0
    F0_gap: float = 1.0       # F(x^0) - F*


def a_norm_stats(gamma, eta, mu):
    """(||a||_1, ||a||_2^2, a_{-1}) for a_l = (1-eta*mu)^(gamma-1-l),
    vectorized over gamma (float arrays allowed for the relaxed solver)."""
    r = 1.0 - eta * mu
    gamma = np.maximum(np.asarray(gamma, dtype=np.float64), 1e-6)
    if abs(r - 1.0) < 1e-12:
        a1, a2, alast = gamma, gamma, np.ones_like(gamma)
    else:
        a1 = (1.0 - r ** gamma) / (1.0 - r)
        a2 = (1.0 - r ** (2 * gamma)) / (1.0 - r ** 2)
        alast = np.ones_like(gamma)  # a_{gamma-1} = r^0 = 1
    return a1, a2, alast


def theorem1_bound(*, consts: MLConstants, p_i, D_i, m_i, gamma_i,
                   tau_sum_drift: float, eta: float, theta: float,
                   T: int, mu: float = 0.01) -> dict:
    """Evaluate the five terms of eq. (25) for one representative round
    (time-invariant orchestration); returns each term + total.

    p_i, D_i, m_i, gamma_i: per-DPU arrays. tau_sum_drift: sum_t sum_i
    tau^t Delta_i^t (the drift penalty numerator)."""
    p_i = np.asarray(p_i, np.float64)
    D_i = np.maximum(np.asarray(D_i, np.float64), 1.0)
    m_i = np.clip(np.asarray(m_i, np.float64), 1e-6, 1.0)
    gamma_i = np.asarray(gamma_i, np.float64)
    L = consts.L
    th = np.asarray(consts.theta_i, np.float64)
    sg = np.asarray(consts.sigma_i, np.float64)
    a1, a2, alast = a_norm_stats(gamma_i, eta, mu)
    term_a = 4.0 * consts.F0_gap / (theta * eta * T)
    term_b = 4.0 * tau_sum_drift / (theta * eta * T)
    noise = (p_i ** 2) * (1 - m_i) * (D_i - 1) * (th ** 2) * (sg ** 2) \
        / (m_i * D_i ** 2) * (a2 / a1 ** 2)
    term_c = 16.0 * eta * L * theta * np.sum(noise)
    inner = (1 - m_i) * (D_i - 1) * (th ** 2) * (sg ** 2) * p_i * gamma_i \
        / (m_i * a1 * D_i ** 2) * (a2 - alast ** 2)
    term_e = 12.0 * (eta ** 2) * (L ** 2) * np.sum(inner)
    het = np.max((gamma_i ** 2) * (a1 - alast) / a1)
    term_d = 12.0 * (eta ** 2) * (L ** 2) * consts.zeta2 * het
    total = term_a + term_b + term_c + term_d + term_e
    return {"initial_gap": term_a, "drift": term_b, "sgd_noise": term_c,
            "heterogeneity": term_d, "local_divergence": term_e,
            "total": total}


def corollary_bound(*, consts: MLConstants, d: int, gamma_bar: float,
                    T: int, theta: float, tau_tilde: float,
                    m_min: float, gamma_max: float) -> float:
    """Eq. (33): the O(1/sqrt(T)) bound with eta = sqrt(d/(gamma_bar T))."""
    L = consts.L
    th_max = float(np.max(consts.theta_i))
    sg_max = float(np.max(consts.sigma_i))
    t1 = 4 * np.sqrt(gamma_bar) / (theta * np.sqrt(d * T)) * consts.F0_gap
    t2 = 4 * tau_tilde * np.sqrt(gamma_bar) / (theta * np.sqrt(d * T))
    t3 = 16 * L * theta * th_max * sg_max ** 2 / m_min * np.sqrt(
        d / (gamma_bar * T))
    t4 = 12 * L ** 2 * d * th_max * sg_max ** 2 * gamma_max / (
        gamma_bar * m_min * T)
    t5 = 12 * L ** 2 * consts.zeta2 * d * gamma_max ** 2 / (gamma_bar * T)
    return t1 + t2 + t3 + t4 + t5


def step_size_condition(gamma_i, eta, mu, L, zeta1) -> bool:
    """Theorem 1 hypothesis: 4 eta^2 L^2 max_i gamma^2(||a||_1-a_{-1})/||a||_1
    <= 1/(2 zeta1^2 + 1)."""
    a1, _, alast = a_norm_stats(gamma_i, eta, mu)
    lhs = 4 * eta ** 2 * L ** 2 * np.max(
        np.asarray(gamma_i, np.float64) ** 2 * (a1 - alast) / a1)
    return bool(lhs <= 1.0 / (2 * zeta1 ** 2 + 1))
