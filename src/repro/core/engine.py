"""The CE-FL orchestration engine: one loop, two execution backends.

Each global round t (paper Secs. II+IV-VI):
  1. the pluggable :class:`~repro.scenario.Scenario` evolves the world:
     UE mobility re-derives rates/associations, the server mesh churns,
     and UEs observe new (possibly drifted) online data,
  2. the pluggable :class:`~repro.core.api.DecisionStrategy` picks the
     orchestration plan w^t (offloading rho, compute settings f/z/gamma/m,
     floating aggregator I_s) — warm-started from the previous plan,
  3. data offloading is realized (UE -> BS -> DC partitions),
  4. every DPU runs FedProx local training (eqs. 5-10) via the configured
     executor,
  5. scaled accumulated gradients are aggregated at the floating
     aggregation DC (eq. 11) — or FedNova / FedAvg for the baselines,
  6. delay / energy are charged per Sec. II-E and reported through
     :class:`~repro.core.api.RoundReport` callbacks.

Executors:
  * :class:`SimExecutor` — the simulation path: per-DPU FedProx with
    homogeneous-(gamma, m) DPUs batched through one vmapped proximal step
    (``fedprox.local_train_batched``).
  * :class:`MeshExecutor` — wraps the jitted SPMD round
    (``core.round_step.build_cefl_round_step``), the same code path the
    production launcher (``launch/train.py``) runs on real meshes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, fedprox
from repro.core import strategies as _strategies  # noqa: F401  (registers)
from repro.core.api import (DecisionContext, EngineOptions, RoundCallback,
                            RoundPlan, RoundReport, RunResult, get_strategy,
                            weighted_mean)
from repro.core.round_step import CEFLHyper, build_cefl_round_step
from repro.kernels.plane import ParamPlane, as_plane, as_tree
from repro.network.costs import network_costs, round_delay, round_energy
from repro.scenario import get_scenario


# ------------------------------------------------------- offloading -----

def realize_offloading(rng, data_per_ue: List[dict], w, net):
    """Split each UE's round data per rho_nb / rho_bs into DPU datasets.

    Returns (ue_datasets, dc_datasets) as lists of {'x','y'} dicts.  The
    split conserves datapoints exactly: every input point lands at exactly
    one DPU, even in the all-offload edge case (each UE always keeps at
    least one point by clawing it back from its BS allocation) and the
    degenerate case where every rho_bs share floors to zero (the whole BS
    pool then goes to the DC with the largest rho share).
    """
    if isinstance(w, RoundPlan):
        w = w.to_w()
    N, B, S = net.dims
    rho_nb = np.asarray(w["rho_nb"])
    rho_bs = np.asarray(w["rho_bs"])
    bs_pool_x, bs_pool_y = [[] for _ in range(B)], [[] for _ in range(B)]
    ue_data = []
    for n, d in enumerate(data_per_ue):
        x, y = np.asarray(d["x"]), np.asarray(d["y"])
        D = len(y)
        if D == 0:
            ue_data.append({"x": jnp.asarray(x), "y": jnp.asarray(y)})
            continue
        perm = rng.permutation(D)
        counts = np.floor(rho_nb[n] * D).astype(int)
        # all-offload guard: every UE keeps >= 1 point, taken back from
        # its largest BS allocation (rather than duplicating a point)
        excess = counts.sum() - (D - 1)
        while excess > 0:
            j = int(np.argmax(counts))
            take = min(excess, counts[j])
            counts[j] -= take
            excess -= take
        start = 0
        for b in range(B):
            take = perm[start:start + counts[b]]
            start += counts[b]
            if len(take):
                bs_pool_x[b].append(x[take])
                bs_pool_y[b].append(y[take])
        keep = perm[start:]
        ue_data.append({"x": jnp.asarray(x[keep]), "y": jnp.asarray(y[keep])})
    dc_x, dc_y = [[] for _ in range(S)], [[] for _ in range(S)]
    for b in range(B):
        if not bs_pool_x[b]:
            continue
        x = np.concatenate(bs_pool_x[b])
        y = np.concatenate(bs_pool_y[b])
        perm = rng.permutation(len(y))
        counts = np.floor(rho_bs[b] * len(y)).astype(int)
        # BSs keep no data: the rounding remainder goes to the DC with the
        # largest rho share (covers the all-floored-to-zero pool case);
        # shave from the largest counts if a row ever over-allocates.
        rem = len(y) - counts.sum()
        while rem < 0:
            j = int(np.argmax(counts))
            give = min(-rem, counts[j])
            counts[j] -= give
            rem += give
        counts[int(np.argmax(rho_bs[b]))] += rem
        start = 0
        for s in range(S):
            take = perm[start:start + counts[s]]
            start += counts[s]
            if len(take):
                dc_x[s].append(x[take])
                dc_y[s].append(y[take])
    dc_data = []
    for s in range(S):
        if dc_x[s]:
            dc_data.append({"x": jnp.asarray(np.concatenate(dc_x[s])),
                            "y": jnp.asarray(np.concatenate(dc_y[s]))})
        else:
            dc_data.append(None)
    return ue_data, dc_data


# -------------------------------------------------------- executors -----

def _plan_settings(plan: RoundPlan):
    gammas = np.maximum(np.rint(np.asarray(plan.gamma)), 1).astype(int)
    ms = np.clip(np.asarray(plan.m), 0.05, 1.0)
    return gammas, ms


def _aggregate(params, results, agg: str, *, eta: float,
               theta: Optional[float], robust: str = "none",
               trim_frac: float = 0.1):
    weights = [r.num_examples for r in results]
    if robust != "none":
        # byzantine counter: coordinate-wise trimmed-mean/median instead
        # of the weighted sum.  Deliberately weight-free — and theta
        # (when not pinned) is the UNWEIGHTED gamma mean, because the
        # D_i a compromised client reports are not trusted either.
        if agg == "fedavg":
            return aggregation.robust_fedavg_aggregate(
                [r.params for r in results], mode=robust,
                trim_frac=trim_frac)
        theta_val = float(theta) if (agg != "fednova"
                                     and theta is not None) \
            else float(np.mean([r.gamma for r in results]))
        return aggregation.robust_aggregate(
            params, [r.d_i for r in results], theta=theta_val, eta=eta,
            mode=robust, trim_frac=trim_frac)
    if agg == "fedavg":
        return aggregation.fedavg_aggregate(
            [r.params for r in results], weights)
    if agg == "fednova":
        return aggregation.fednova_aggregate(
            params, [r.d_i for r in results], weights,
            [r.gamma for r in results], eta=eta)
    wn = np.asarray(weights, float)
    wn = wn / wn.sum()
    theta_val = theta if theta is not None else float(
        np.sum(wn * np.array([r.gamma for r in results])))   # tau_eff
    return aggregation.aggregate(params, [r.d_i for r in results], weights,
                                 theta=theta_val, eta=eta)


def _corrupt_value(x, fn):
    """Apply a plane-space transform to a ParamPlane or pytree value."""
    plane = as_plane(x)
    out = plane.with_data(fn(plane.data))
    return out if isinstance(x, ParamPlane) else out.to_tree()


def corrupt_local_results(results, live, corrupt, anchor, noise_key):
    """Apply the round's update corruptions (``ScenarioEvents.corrupted``
    triples ``(ue, mode, scale)``) to the matching ``LocalResult``s, in
    place, between local training and aggregation.

    sign_flip: d_i -> -scale * d_i and params -> anchor - scale *
    (params - anchor) (the anchor-relative flip, so fedavg model
    averaging sees the same attack direction eq.-11 does).  gauss: adds
    scale-std Gaussian noise to both, with per-target subkeys split off
    ``noise_key`` in deterministic (sorted) order.
    """
    by_dpu = {i: j for j, (i, _) in enumerate(live)}
    todo = [c for c in sorted(corrupt) if c[0] in by_dpu]
    n_gauss = sum(1 for _, mode, _ in todo if mode == "gauss")
    nkeys = iter(jax.random.split(noise_key, 2 * n_gauss)) if n_gauss \
        else iter(())
    anchor_data = as_plane(anchor).data
    for ue, mode, scale in todo:
        r = results[by_dpu[ue]]
        if mode == "sign_flip":
            r.d_i = _corrupt_value(r.d_i, lambda d: -scale * d)
            r.params = _corrupt_value(
                r.params, lambda p: anchor_data - scale * (p - anchor_data))
        elif mode == "gauss":
            kd, kp = next(nkeys), next(nkeys)
            r.d_i = _corrupt_value(
                r.d_i, lambda d: d + scale * jax.random.normal(
                    kd, d.shape, d.dtype))
            r.params = _corrupt_value(
                r.params, lambda p: p + scale * jax.random.normal(
                    kp, p.shape, p.dtype))
        else:
            raise ValueError(f"unknown corruption mode {mode!r}")


@dataclasses.dataclass
class SimExecutor:
    """Simulation backend: per-DPU FedProx on each DPU's own dataset.

    With ``batch_homogeneous`` (default), DPUs sharing (gamma, m,
    mini-batch bucket) train through one vmapped proximal step per local
    iteration — numerically identical to the sequential path (per-DPU PRNG
    streams are preserved), but with G-DPU groups costing one dispatch
    instead of G.

    With ``use_plane`` (default), parameters stay on the flat parameter
    plane end-to-end: local training runs the fused kernels
    (``fedprox.local_train*`` plane backend, dispatched per
    ``kernel_backend`` — see ``kernels/ops.py``) and eq.-11 aggregation is
    one fused kernel launch over the stacked d_i planes.
    ``use_plane=False`` is the pre-plane per-leaf tree path, kept for
    equivalence tests and the tree-vs-plane benchmark.

    With ``fuse_round`` (default), a round whose live DPUs form ONE
    homogeneous (gamma, m, bucket) group — the common case outside
    heterogeneous-plan strategies — runs as a single jitted program
    (``fedprox.local_round_plane``): training scan + eq.-10 + eq.-11
    aggregation, and on eval-cadence rounds the engine passes ``eval_fn``
    so the eval forward pass fuses into the SAME program (no separate
    vmapped eval dispatch, no tree materialization).

    With ``mesh_shape`` set, the fused round runs shard_map'd over the
    ``('dpu', 'rows')`` device mesh (``repro.sharding.plane``): the DPU
    stack data-parallel over 'dpu', plane rows FSDP-sharded over 'rows'
    — bitwise identical to the single-device fused round.  Rounds that
    cannot fuse (heterogeneous groups, fedavg, corruption, robust agg)
    fall back to the single-device paths.
    """
    batch_homogeneous: bool = True
    use_plane: bool = True
    fuse_round: bool = True
    kernel_backend: str = "auto"    # ops.resolve_backend name
    mesh_shape: Optional[tuple] = None   # (dpu, rows) device split

    @property
    def fused_eval(self) -> bool:
        """The engine hands eval_fn to ``run_round`` when this is set
        (the executor then returns the round's accuracy, or None when a
        round couldn't fuse and eval must run separately)."""
        return self.use_plane and self.batch_homogeneous and \
            self.fuse_round

    def run_round(self, params, plan: RoundPlan, datasets, *, loss_fn,
                  eta: float, mu: float, theta: Optional[float], agg: str,
                  key, eval_fn=None, corrupt=(), robust_agg: str = "none",
                  trim_frac: float = 0.1):
        backend = "plane" if self.use_plane else "tree"
        if self.use_plane:
            params = as_plane(params)
        gammas, ms = _plan_settings(plan)
        live = [(i, d) for i, d in enumerate(datasets)
                if d is not None and len(d["y"])]
        if not live:
            out = (params, float("nan"))
            return out + (None,) if eval_fn is not None else out
        # gaussian update corruption needs one extra key; clean rounds
        # keep the historical split count so existing seeded traces are
        # unchanged bit for bit
        needs_noise = any(mode == "gauss" for _, mode, _ in corrupt)
        keys = jax.random.split(key, len(live) + (1 if needs_noise else 0))
        noise_key = keys[len(live)] if needs_noise else None
        results = [None] * len(live)
        if self.batch_homogeneous:
            groups: Dict[tuple, list] = {}
            for j, (i, d) in enumerate(live):
                bucket = fedprox._bucket(
                    fedprox.batch_size(len(d["y"]), ms[i]))
                groups.setdefault(
                    (int(gammas[i]), float(ms[i]), bucket), []).append(j)
            if (self.fuse_round and self.use_plane and len(groups) == 1
                    and agg in ("cefl", "fednova")
                    and not corrupt and robust_agg == "none"):
                # single homogeneous group: the whole round (train +
                # aggregate [+ eval]) is ONE jitted program
                (gamma, m, _bucket), idxs = next(iter(groups.items()))
                # tau_eff = sum_i p_i gamma_i degenerates to gamma here,
                # which is also FedNova's theta
                theta_val = float(theta) if (agg == "cefl"
                                             and theta is not None) \
                    else float(gamma)
                Ds = [len(live[j][1]["y"]) for j in idxs]
                if self.mesh_shape is not None:
                    # deferred import: sharding is opt-in, the engine's
                    # import surface stays mesh-free
                    from repro.sharding import plane as shard_plane
                    new_params, losses, acc = \
                        shard_plane.local_round_plane_sharded(
                            params, loss_fn, [live[j][1] for j in idxs],
                            gamma=gamma, m_frac=m, eta=eta, mu=mu,
                            keys=[keys[j] for j in idxs], theta=theta_val,
                            mesh=shard_plane.plane_mesh(self.mesh_shape),
                            kernel_backend=self.kernel_backend,
                            eval_fn=eval_fn)
                else:
                    new_params, losses, acc = fedprox.local_round_plane(
                        params, loss_fn, [live[j][1] for j in idxs],
                        gamma=gamma, m_frac=m, eta=eta, mu=mu,
                        keys=[keys[j] for j in idxs], theta=theta_val,
                        kernel_backend=self.kernel_backend, eval_fn=eval_fn)
                mean_loss = weighted_mean(list(losses), Ds)
                if eval_fn is not None:
                    return new_params, mean_loss, acc
                return new_params, mean_loss
            for (gamma, m, _bucket), idxs in groups.items():
                out = fedprox.local_train_batched(
                    params, loss_fn, [live[j][1] for j in idxs],
                    gamma=gamma, m_frac=m, eta=eta, mu=mu,
                    keys=[keys[j] for j in idxs],
                    backend=backend, keep_planes=self.use_plane,
                    kernel_backend=self.kernel_backend)
                for j, r in zip(idxs, out):
                    results[j] = r
        else:
            for j, (i, d) in enumerate(live):
                results[j] = fedprox.local_train(
                    params, loss_fn, d, gamma=int(gammas[i]),
                    m_frac=float(ms[i]), eta=eta, mu=mu, key=keys[j],
                    backend=backend, keep_planes=self.use_plane,
                    kernel_backend=self.kernel_backend)
        if corrupt:
            corrupt_local_results(results, live, corrupt, params, noise_key)
        new_params = _aggregate(params, results, agg, eta=eta, theta=theta,
                                robust=robust_agg, trim_frac=trim_frac)
        mean_loss = weighted_mean([r.loss for r in results],
                                  [r.num_examples for r in results])
        if eval_fn is not None:
            # couldn't fuse (heterogeneous groups / fedavg): the caller
            # evaluates separately
            return new_params, mean_loss, None
        return new_params, mean_loss


@dataclasses.dataclass
class MeshExecutor:
    """Mesh backend: the paper loop through the jitted SPMD round step.

    Active DPUs are packed on a leading DPU axis (datasets right-padded to
    a shared power-of-two batch, the CE-FL mini-batch ratio applied as a
    leading-example mask), so one ``round_step`` call trains and
    aggregates every DPU — the same code the production launcher runs on
    TPU meshes.  Differences vs :class:`SimExecutor`: mini-batches are the
    deterministic leading slice rather than random draws (identical when
    m=1), the reported loss is the unweighted DPU mean of the final local
    iteration (not the weighted all-step mean), and FedAvg
    model-averaging has no SPMD equivalent here.

    The jitted step is cached per (loss_fn, gamma_max, DPU count, batch
    bucket, mu); theta is applied outside the jit so per-round tau_eff
    changes never recompile.

    With ``use_plane`` (default) the round runs on the flat parameter
    plane: the jitted step receives a ``(n_dpu, R, LANE)`` ParamPlane and
    ``round_step`` dispatches to the fused Pallas kernels (interpret mode
    on CPU) — zero pytree flatten/unflatten in the inner loop.
    """
    agg_schedule: str = "all_reduce"
    use_plane: bool = True
    kernel_backend: str = "auto"    # ops.resolve_backend name
    mesh_shape: Optional[tuple] = None   # (dpu, rows): device_put the
                                         # plane stack with a NamedSharding
                                         # over the plane mesh; GSPMD then
                                         # partitions the jitted step
    _cache: dict = dataclasses.field(default_factory=dict, repr=False)

    def build_step(self, micro_loss_fn, hyper: CEFLHyper, *, jit=True):
        """The jitted SPMD round step for a mesh-layout ``micro_loss_fn``
        (params, microbatch, mask) -> (loss, aux).  Used directly by the
        LM launcher; ``run_round`` goes through the same cache."""
        step = build_cefl_round_step(micro_loss_fn, hyper)
        return jax.jit(step, donate_argnums=(0,)) if jit else step

    def _get_step(self, loss_fn, n_dpu, bucket, gamma_max, mu, eta):
        cache_key = (id(loss_fn), n_dpu, bucket, gamma_max, mu, eta)
        if cache_key not in self._cache:
            def micro_loss(p, micro, mask):
                return loss_fn(p, micro, mask), {}
            hyper = CEFLHyper(eta=eta, mu=mu, theta=1.0,
                              gamma_max=gamma_max, n_micro=1,
                              agg_schedule=self.agg_schedule,
                              kernel_backend=self.kernel_backend)
            # no donation here: run_round still needs the undonated params
            self._cache[cache_key] = jax.jit(
                build_cefl_round_step(micro_loss, hyper))
        return self._cache[cache_key]

    def run_round(self, params, plan: RoundPlan, datasets, *, loss_fn,
                  eta: float, mu: float, theta: Optional[float], agg: str,
                  key, corrupt=(), robust_agg: str = "none",
                  trim_frac: float = 0.1):
        del key, trim_frac  # deterministic leading-slice mini-batches
        if agg == "fedavg":
            raise NotImplementedError(
                "MeshExecutor aggregates accumulated gradients (eq. 11); "
                "FedAvg model averaging needs SimExecutor")
        if corrupt or robust_agg != "none":
            raise NotImplementedError(
                "update corruption / robust aggregation run between local "
                "training and aggregation, which the fused SPMD round "
                "step does not expose; use SimExecutor")
        gammas, ms = _plan_settings(plan)
        live = [(i, d) for i, d in enumerate(datasets)
                if d is not None and len(d["y"])]
        if not live:
            return params, float("nan")
        Ds = [len(d["y"]) for _, d in live]
        bucket = fedprox._bucket(max(Ds))
        n = len(live)
        padded = []
        for (i, d), D in zip(live, Ds):
            padded.append(jax.tree_util.tree_map(
                lambda x: jnp.pad(
                    x, [(0, bucket - D)] + [(0, 0)] * (x.ndim - 1)), d))
        # (n_dpu, n_micro=1, mb, ...) mesh batch layout
        batch = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs)[:, None], *padded)
        live_gammas = np.array([gammas[i] for i, _ in live])
        gamma_max = int(live_gammas.max())
        # real examples sit first, so folding the pad into the mini-batch
        # ratio makes the leading-example mask select ceil(m_i * D_i) of
        # them and none of the padding
        m_eff = np.array([ms[i] * D / bucket for (i, _), D in zip(live, Ds)])
        w = np.asarray(Ds, float)
        w = w / w.sum()
        if agg == "fednova" or theta is None:
            theta_val = float(np.sum(w * live_gammas))      # tau_eff
        else:
            theta_val = float(theta)
        meta = {"gamma": jnp.asarray(live_gammas, jnp.int32),
                "m_frac": jnp.asarray(m_eff, jnp.float32),
                "weight": jnp.asarray(w, jnp.float32)}
        step = self._get_step(loss_fn, n, bucket, gamma_max, mu, eta)
        if self.use_plane:
            plane = as_plane(params)
            stack = plane.broadcast(n)
            if self.mesh_shape is not None:
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P
                from repro.sharding import plane as shard_plane
                from repro.sharding.specs import sanitize_spec
                mesh = shard_plane.plane_mesh(self.mesh_shape)
                spec = sanitize_spec(
                    P(shard_plane.DPU_AXIS, shard_plane.ROW_AXIS, None),
                    stack.data.shape, mesh)
                stack = stack.with_data(jax.device_put(
                    stack.data, NamedSharding(mesh, spec)))
            new_stack, metrics = step(stack, batch, meta)
            # theta=1 inside the step; rescale outside the jit so per-round
            # tau_eff never triggers recompilation (plane arithmetic only)
            new_params = plane.with_data(
                plane.data + theta_val * (new_stack.data[0] - plane.data))
            return new_params, float(metrics["loss"])
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)
        new_stack, metrics = step(stacked, batch, meta)
        # the step ran with theta=1; rescale the global update outside the
        # jit so per-round tau_eff never triggers recompilation
        new_params = jax.tree_util.tree_map(
            lambda p, p1: p + theta_val * (p1[0] - p), params, new_stack)
        return new_params, float(metrics["loss"])


# ---------------------------------------------------- cohort sampling -----

def _gather_plan(plan: RoundPlan, cohort: np.ndarray, n_ue: int) -> RoundPlan:
    """Restrict a full-population plan to the cohort rows (the warm-start
    view handed to the solver, and the costing view of off-cadence
    rounds)."""
    g = np.asarray(plan.gamma)
    m = np.asarray(plan.m)
    return RoundPlan(
        rho_nb=jnp.asarray(np.asarray(plan.rho_nb)[cohort]),
        rho_bs=plan.rho_bs,
        f_n=jnp.asarray(np.asarray(plan.f_n)[cohort]),
        z_s=plan.z_s,
        gamma=jnp.asarray(np.concatenate([g[:n_ue][cohort], g[n_ue:]])),
        m=jnp.asarray(np.concatenate([m[:n_ue][cohort], m[n_ue:]])),
        I_s=plan.I_s,
        I_nb=jnp.asarray(np.asarray(plan.I_nb)[cohort]),
        I_bn=jnp.asarray(np.asarray(plan.I_bn)[:, cohort]),
        R_bs=plan.R_bs, delta_A=plan.delta_A, delta_R=plan.delta_R)


def _scatter_plan(sub: RoundPlan, cohort: np.ndarray, net,
                  opts: EngineOptions) -> RoundPlan:
    """Embed a cohort plan back into a full-population RoundPlan.

    Non-cohort UEs sit the round out: zero offloading (they hold no round
    data anyway), idle CPU frequency ``f_min``, the default (gamma, m)
    settings, and rate-argmax one-hot associations — every field still
    satisfies :meth:`RoundPlan.validate` at the full dims.
    """
    N, B, S = net.dims
    K = int(cohort.shape[0])
    rho_nb = np.zeros((N, B), np.float32)
    rho_nb[cohort] = np.asarray(sub.rho_nb)
    f_n = np.full(N, net.cfg.f_min, np.float32)
    f_n[cohort] = np.asarray(sub.f_n)
    gamma = np.full(N + S, float(opts.gamma_default), np.float32)
    sg = np.asarray(sub.gamma)
    gamma[:N][cohort] = sg[:K]
    gamma[N:] = sg[K:]
    m = np.full(N + S, float(opts.m_default), np.float32)
    sm = np.asarray(sub.m)
    m[:N][cohort] = sm[:K]
    m[N:] = sm[K:]
    I_nb = np.eye(B, dtype=np.float32)[
        np.argmax(np.asarray(net.R_nb), axis=1)]
    I_nb[cohort] = np.asarray(sub.I_nb)
    I_bn = np.zeros((B, N), np.float32)
    I_bn[np.argmax(np.asarray(net.R_bn), axis=0), np.arange(N)] = 1.0
    I_bn[:, cohort] = np.asarray(sub.I_bn)
    return RoundPlan(
        rho_nb=jnp.asarray(rho_nb), rho_bs=sub.rho_bs,
        f_n=jnp.asarray(f_n), z_s=sub.z_s,
        gamma=jnp.asarray(gamma), m=jnp.asarray(m),
        I_s=sub.I_s, I_nb=jnp.asarray(I_nb), I_bn=jnp.asarray(I_bn),
        R_bs=sub.R_bs, delta_A=sub.delta_A, delta_R=sub.delta_R)


# ----------------------------------------------------------- engine -----

def _rng_state_dict(rng: np.random.RandomState) -> dict:
    """A numpy ``RandomState`` state as array/scalar leaves (MT19937)."""
    kind, keys, pos, has_gauss, cached = rng.get_state()
    assert kind == "MT19937", kind
    return {"keys": np.asarray(keys), "pos": int(pos),
            "has_gauss": int(has_gauss), "cached": float(cached)}


def _rng_from_state_dict(d: dict) -> np.random.RandomState:
    rng = np.random.RandomState()
    rng.set_state(("MT19937", np.asarray(d["keys"], np.uint32),
                   int(d["pos"]), int(d["has_gauss"]), float(d["cached"])))
    return rng


@dataclasses.dataclass
class LoopState:
    """The full mutable state of one orchestration run between rounds.

    Everything the loop reads or writes lives here (the engine itself
    stays stateless across rounds), so a run can be advanced one round at
    a time (:meth:`Engine.begin_round` / :meth:`Engine.finish_round`),
    checkpointed mid-run (:meth:`state_dict`), and resumed bit-exactly.
    ``loss_fn`` / ``eval_fn`` are behavior, not state — they are rebound
    by the caller on resume and excluded from :meth:`state_dict`.
    """
    rng: np.random.RandomState
    key: jax.Array
    params: object
    loss_fn: object = None
    eval_fn: object = None
    reports: List[RoundReport] = dataclasses.field(default_factory=list)
    cum_E: float = 0.0
    cum_D: float = 0.0
    plan: Optional[RoundPlan] = None
    prev_agg: Optional[int] = None
    t: int = 0
    stopped: bool = False
    last_acc: float = float("nan")

    def state_dict(self) -> dict:
        """Array/scalar leaves of the loop state (reports excluded — the
        metric trace serializes as JSON-able records at the experiments
        layer, see ``repro.experiments.runstate``)."""
        plane = as_plane(self.params)
        plan = {} if self.plan is None else \
            {k: np.asarray(v) for k, v in self.plan.to_w().items()}
        return {
            "t": int(self.t),
            "cum_E": float(self.cum_E), "cum_D": float(self.cum_D),
            "prev_agg": -1 if self.prev_agg is None else int(self.prev_agg),
            "last_acc": float(self.last_acc),
            "stopped": int(self.stopped),
            "rng": _rng_state_dict(self.rng),
            "key": np.asarray(self.key),
            "params_plane": np.asarray(plane.data),
            "plan": plan,
        }

    def load_state_dict(self, d: dict, *, use_plane: bool) -> None:
        self.t = int(d["t"])
        self.cum_E = float(d["cum_E"])
        self.cum_D = float(d["cum_D"])
        self.prev_agg = None if int(d["prev_agg"]) < 0 else \
            int(d["prev_agg"])
        self.last_acc = float(d["last_acc"])
        self.stopped = bool(int(d["stopped"]))
        self.rng = _rng_from_state_dict(d["rng"])
        self.key = jnp.asarray(np.asarray(d["key"], np.uint32))
        spec = as_plane(self.params).spec
        plane = ParamPlane(data=jnp.asarray(d["params_plane"]), spec=spec)
        self.params = plane if use_plane else plane.to_tree()
        self.plan = RoundPlan.from_w(d["plan"]) if d["plan"] else None


@dataclasses.dataclass
class StagedRound:
    """Host-side output of :meth:`Engine.begin_round`: everything the
    executor needs to run the device work of round ``t``."""
    t: int
    net_t: object
    D_bar: np.ndarray
    plan: RoundPlan
    datasets: list                 # ue_data + dc_data, one entry per DPU
    n_dc: int
    key: jax.Array
    events: object
    t0: float
    # --- per-round client sampling (EngineOptions.cohort_size) ---
    cohort: Optional[np.ndarray] = None   # sorted drawn UE indices, or None
    sub_net: object = None                # topology.subnetwork view
    sub_plan: Optional[RoundPlan] = None  # the cohort-dims plan (costing)


class Engine:
    """Drives the CE-FL loop with a pluggable strategy and executor.

    >>> engine = Engine(net, "cefl", consts=consts, ow=ow,
    ...                 opts=EngineOptions(rounds=8))
    >>> result = engine.run(online_ues, init_params=p0,
    ...                     loss_fn=loss_fn, eval_fn=eval_fn)
    >>> result.final.acc, result.to_history()["loss"]
    """

    def __init__(self, net, strategy=None, *, consts, ow,
                 opts: Optional[EngineOptions] = None,
                 executor=None, scenario=None,
                 callbacks: Sequence[RoundCallback] = (),
                 validate_plans: bool = True):
        self.net = net
        self.opts = opts or EngineOptions()
        self.strategy = get_strategy(
            strategy if strategy is not None else self.opts.strategy)
        # environment dynamics: a name from the scenario registry
        # ("static", "campus_walk", ...) or a Scenario instance
        self.scenario = get_scenario(
            scenario if scenario is not None else self.opts.scenario)
        self.executor = executor if executor is not None else \
            SimExecutor(kernel_backend=self.opts.kernel_backend,
                        mesh_shape=self.opts.mesh_shape)
        self.callbacks: List[RoundCallback] = list(callbacks)
        self.validate_plans = validate_plans
        self.consts = consts
        self.ow = ow

    def on_round_end(self, callback: RoundCallback) -> RoundCallback:
        """Register a callback (usable as a decorator).  Returning True
        from a callback stops the run after the current round."""
        self.callbacks.append(callback)
        return callback

    def decide(self, net_t, D_bar, t: int,
               prev_plan: Optional[RoundPlan], *,
               consts=None) -> RoundPlan:
        """``consts`` overrides the engine's MLConstants for this call —
        the cohort path hands in constants gathered to the cohort's
        per-DPU rows."""
        ctx = DecisionContext(round=t,
                              consts=self.consts if consts is None
                              else consts,
                              ow=self.ow, opts=self.opts,
                              prev_plan=prev_plan)
        # strategies receive D_bar as a device array: the jit solver backend
        # consumes it directly (no numpy bounce on the decision hot path)
        plan = self.strategy.decide(net_t, jnp.asarray(D_bar, jnp.float32),
                                    ctx)
        if self.validate_plans:
            plan.validate(net_t)
        return plan

    # --- the round loop, exposed one round at a time -------------------
    #
    # init_loop / begin_round / finish_round are the resumable form of
    # the loop: Engine.run is literally init + while + (begin, execute,
    # finish), and the multi-seed sweep executors in repro.experiments
    # drive K LoopStates through the same three calls in lockstep so the
    # per-seed host work (scenario tick, solver decision, offloading,
    # PRNG chains) stays bit-identical to a solo Engine.run.

    @property
    def aggregation(self) -> str:
        return getattr(self.strategy, "aggregation", "cefl")

    @property
    def mu_effective(self) -> float:
        return self.opts.mu if getattr(self.strategy, "proximal", True) \
            else 0.0

    def init_loop(self, online_datasets, *, init_params, loss_fn=None,
                  eval_fn=None) -> LoopState:
        """Bind the scenario and build the round-0 loop state."""
        del online_datasets  # streams carry their own state; staged later
        opts = self.opts
        params = init_params
        if getattr(self.executor, "use_plane", False):
            # plane-backed executors keep params flat across rounds;
            # tree views are materialized only at API boundaries (eval,
            # RoundReport, the final RunResult)
            params = as_plane(init_params)
        self.scenario.bind(self.net, opts)
        return LoopState(rng=np.random.RandomState(opts.seed),
                         key=jax.random.PRNGKey(opts.seed),
                         params=params, loss_fn=loss_fn, eval_fn=eval_fn)

    def _cohort_consts(self, n_ue: int, cohort: np.ndarray):
        """MLConstants with the per-DPU arrays gathered to the cohort's
        (K + S) rows (scalar / mis-sized fields pass through)."""
        c = self.consts

        def gather(a):
            a = np.asarray(a)
            if a.ndim == 0 or a.shape[0] < n_ue:
                return a
            return np.concatenate([a[:n_ue][cohort], a[n_ue:]])

        return dataclasses.replace(c, theta_i=gather(c.theta_i),
                                   sigma_i=gather(c.sigma_i))

    def begin_round(self, state: LoopState, online_datasets) -> StagedRound:
        """Host side of round ``state.t``: scenario tick, cohort draw,
        plan decision, offloading realization, PRNG advance.  Mutates
        ``state`` (rng, key, plan) exactly as the solo loop does."""
        opts = self.opts
        t = state.t
        t0 = time.time()
        # one scenario tick: evolved network (same cfg/dims -> the
        # solver's NetView pytree keeps hitting its compile cache),
        # drifted per-UE data, and the round's environment events
        net_t, data_per_ue, events = self.scenario.step(
            t, online_datasets, state.rng)
        N = len(data_per_ue)
        cohort = sub_net = sub_plan = None
        if opts.cohort_size is not None and opts.cohort_size < N:
            # per-round client sampling: K UEs drawn uniformly without
            # replacement; the rest observe no round data, so the
            # executors' live-DPU filter drops them before any device
            # work and the solver sees only the (K, B, S) subproblem.
            # The rng draw happens ONLY on this branch, so cohort-off
            # runs keep their seeded traces bit-identical.
            if opts.distributed_solver:
                raise ValueError(
                    "cohort_size is incompatible with distributed_solver: "
                    "the cohort subnetwork has no consensus graph")
            cohort = np.sort(state.rng.choice(N, opts.cohort_size,
                                              replace=False))
            mask = np.zeros(N, bool)
            mask[cohort] = True
            data_per_ue = [
                d if mask[n] else
                jax.tree_util.tree_map(lambda x: x[:0], d)
                for n, d in enumerate(data_per_ue)]
            from repro.network.topology import subnetwork
            sub_net = subnetwork(net_t, cohort)
        D_bar = np.array([len(d["y"]) for d in data_per_ue], float)
        if state.plan is None or t % opts.reoptimize_every == 0:
            if cohort is None:
                state.plan = self.decide(net_t, D_bar, t,
                                         prev_plan=state.plan)
            else:
                # gather -> solve the K-UE subproblem -> scatter.  A
                # fixed K keeps hitting the solver's (K, B, S) compile
                # cache no matter how large the population is.
                sub_prev = None if state.plan is None else \
                    _gather_plan(state.plan, cohort, N)
                sub_plan = self.decide(
                    sub_net, D_bar[cohort], t, prev_plan=sub_prev,
                    consts=self._cohort_consts(N, cohort))
                state.plan = _scatter_plan(sub_plan, cohort, net_t, opts)
                if self.validate_plans:
                    state.plan.validate(net_t)
        elif cohort is not None:
            sub_plan = _gather_plan(state.plan, cohort, N)
        ue_data, dc_data = realize_offloading(state.rng, data_per_ue,
                                              state.plan, net_t)
        state.key, sub = jax.random.split(state.key)
        return StagedRound(t=t, net_t=net_t, D_bar=D_bar, plan=state.plan,
                           datasets=ue_data + dc_data, n_dc=len(dc_data),
                           key=sub, events=events, t0=t0,
                           cohort=cohort, sub_net=sub_net,
                           sub_plan=sub_plan)

    def should_eval(self, t: int) -> bool:
        every = max(1, getattr(self.opts, "eval_every", 1))
        return t % every == 0 or t == self.opts.rounds - 1

    def execute_round(self, state: LoopState, staged: StagedRound, *,
                      fuse_eval: bool = True):
        """Device phase of round ``staged.t``: executor dispatch with the
        round's adversary corruptions and the configured robust
        aggregation threaded through.  Updates ``state.params`` and
        returns ``(mean_loss, acc)`` — ``acc`` is None unless the round
        fused its eval.  The single source of truth for the executor
        call: ``_run_loop``, the sweep executors, and the scenario fuzzer
        all route through here."""
        opts = self.opts
        kw = {}
        corrupt = tuple(getattr(staged.events, "corrupted", ()) or ())
        if corrupt or opts.robust_agg != "none":
            # passed only when active so custom executors with the
            # pre-adversary run_round signature keep working on clean runs
            kw["corrupt"] = corrupt
            kw["robust_agg"] = opts.robust_agg
            kw["trim_frac"] = opts.trim_frac
        if (fuse_eval and state.eval_fn is not None
                and self.should_eval(staged.t)
                and getattr(self.executor, "fused_eval", False)):
            # fuse the eval forward pass into the round program; the
            # executor returns acc=None if the round couldn't fuse
            # (finish_round then evaluates separately)
            kw["eval_fn"] = state.eval_fn
        out = self.executor.run_round(
            state.params, staged.plan, staged.datasets,
            loss_fn=state.loss_fn, eta=opts.eta, mu=self.mu_effective,
            theta=opts.theta, agg=self.aggregation, key=staged.key, **kw)
        if "eval_fn" in kw:
            state.params, mean_loss, acc = out
        else:
            state.params, mean_loss = out
            acc = None
        return mean_loss, acc

    def finish_round(self, state: LoopState, staged: StagedRound,
                     mean_loss: float, acc: Optional[float] = None) -> \
            RoundReport:
        """Account the finished round: costs, eval (per the cadence, or
        the precomputed ``acc`` a sweep executor hands in), report,
        callbacks.  Advances ``state.t``."""
        plan = staged.plan
        scale = tuple(getattr(staged.events, "compute_scale", ()) or ())
        if staged.cohort is not None and staged.sub_plan is not None:
            # cohort round: charge the K-UE subproblem, not all N UEs'
            # model-upload paths — non-cohort UEs transmit nothing
            w = staged.sub_plan.to_w()
            cost_net = staged.sub_net
            cost_D = staged.D_bar[staged.cohort]
            if scale:
                scale = tuple(np.asarray(scale)[staged.cohort])
        else:
            w = plan.to_w()
            cost_net = staged.net_t
            cost_D = staged.D_bar
        if scale:
            # stragglers: the plan's idealized f_n vs the realized rate —
            # the slowdown is charged through the Sec. II-E cost model
            # (compute delay ~ 1/f_n, compute energy ~ f_n^2)
            w = dict(w)
            w["f_n"] = jnp.asarray(w["f_n"]) * jnp.asarray(
                scale, jnp.float32)
        costs = network_costs(w, cost_net, cost_D)
        E = float(round_energy(costs, self.ow.xi3_sub))
        Dl = float(round_delay(costs))
        state.cum_E += E
        state.cum_D += Dl
        if acc is None:
            if self.should_eval(staged.t):
                acc = float(state.eval_fn(as_tree(state.params)))
            else:
                acc = state.last_acc
        state.last_acc = float(acc)
        gammas, ms = _plan_settings(plan)
        dc_data = staged.datasets[len(staged.datasets) - staged.n_dc:]
        report = RoundReport(
            round=staged.t, acc=float(acc), loss=mean_loss,
            energy=E, delay=Dl, cum_energy=state.cum_E,
            cum_delay=state.cum_D,
            aggregator=plan.aggregator,
            dc_points=tuple(0 if d is None else len(d["y"])
                            for d in dc_data),
            gamma_mean=float(gammas.mean()), m_mean=float(ms.mean()),
            plan=plan, wall_time=time.time() - staged.t0,
            handovers=tuple(staged.events.handovers),
            aggregator_moved=(state.prev_agg is not None
                              and plan.aggregator != state.prev_agg),
            active_ues=int(staged.events.active_ues))
        if self.opts.sanitize:
            # deferred import: the analysis package is a debug dependency,
            # not part of the engine's import-time surface
            from repro.analysis.sanitize import check_finite
            check_finite(state.params,
                         f"params after round {staged.t}")
        state.prev_agg = plan.aggregator
        state.reports.append(report)
        for cb in self.callbacks:
            if cb(report) is True:
                state.stopped = True
        state.t += 1
        return report

    def run(self, online_datasets, *, init_params, loss_fn,
            eval_fn) -> RunResult:
        """Run the full orchestration loop.

        ``online_datasets``: one ``core.drift.OnlineDataset`` per UE.
        ``loss_fn(params, batch, example_weights) -> scalar``;
        ``eval_fn(params) -> accuracy``.
        """
        state = self.init_loop(online_datasets, init_params=init_params,
                               loss_fn=loss_fn, eval_fn=eval_fn)
        return self.run_loop(state, online_datasets)

    def run_loop(self, state: LoopState, online_datasets) -> RunResult:
        """Drive an (initialized or resumed) LoopState to completion.

        With ``opts.sanitize`` the whole loop runs under the
        :class:`repro.analysis.sanitize.KeyReuseDetector`: any host-level
        ``jax.random`` call that consumes an already-consumed key raises,
        and :meth:`finish_round` additionally checks the aggregated
        params for NaN/Inf every round.
        """
        if self.opts.sanitize:
            from repro.analysis.sanitize import KeyReuseDetector
            with KeyReuseDetector(mode="raise"):
                return self._run_loop(state, online_datasets)
        return self._run_loop(state, online_datasets)

    def _run_loop(self, state: LoopState, online_datasets) -> RunResult:
        while state.t < self.opts.rounds and not state.stopped:
            staged = self.begin_round(state, online_datasets)
            mean_loss, acc = self.execute_round(state, staged)
            self.finish_round(state, staged, mean_loss, acc)
        return RunResult(reports=state.reports,
                         params=as_tree(state.params))


# ---------------------------------------------------- trace contracts --

from repro.analysis.jaxpr.contracts import Program, contract  # noqa: E402


def _audit_micro_loss(p, micro, mask):
    return fedprox._audit_loss(p, micro, mask), {}


def _audit_mesh_round_args(n_dpu: int = 4, mb: int = 8,
                           n_features: int = 4, n_classes: int = 3):
    """Tiny (stack, batch, meta) triple in the exact mesh layout
    ``MeshExecutor.run_round`` stages (batch leaves (n_dpu, n_micro=1,
    mb, ...), absolute-size weights)."""
    rng = np.random.RandomState(0)
    params = {"w": jnp.zeros((n_features, n_classes), jnp.float32),
              "b": jnp.zeros((n_classes,), jnp.float32)}
    stack = as_plane(params).broadcast(n_dpu)
    batch = {"x": jnp.asarray(rng.normal(size=(n_dpu, 1, mb, n_features)),
                              jnp.float32),
             "y": jnp.asarray(rng.randint(0, n_classes,
                                          size=(n_dpu, 1, mb)), jnp.int32)}
    meta = {"gamma": jnp.full((n_dpu,), 2, jnp.int32),
            "m_frac": jnp.ones((n_dpu,), jnp.float32),
            "weight": jnp.full((n_dpu,), float(mb), jnp.float32)}
    return stack, batch, meta


_AUDIT_HYPER = CEFLHyper(eta=0.1, mu=0.01, theta=1.0, gamma_max=2,
                         n_micro=1, kernel_backend="cpu")


@contract(
    "mesh_round_donation",
    collectives={},
)
def _mesh_round_donation_contract():
    """build_step donation: the (n_dpu, R, LANE) plane stack passed with
    donate_argnums=(0,) must alias an output in the compiled step."""
    stack, batch, meta = _audit_mesh_round_args()
    step = build_cefl_round_step(_audit_micro_loss, _AUDIT_HYPER)
    return Program(fn=step, args=(stack, batch, meta),
                   donate_argnums=(0,))


@contract(
    "mesh_round_gspmd",
    min_devices=8,
    hlo_collectives=frozenset(
        {"all-gather", "all-reduce", "collective-permute"}),
)
def _mesh_round_gspmd_contract():
    """run_round mesh_shape path: GSPMD partitioning of the fused round
    over the ('dpu', 'rows') plane mesh must introduce no collectives
    beyond the gather/reduce/permute schedule of eq. 11."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.sharding import plane as shard_plane
    from repro.sharding.specs import sanitize_spec

    stack, batch, meta = _audit_mesh_round_args(n_dpu=4)
    mesh = shard_plane.plane_mesh((4, 2))
    spec = sanitize_spec(
        P(shard_plane.DPU_AXIS, shard_plane.ROW_AXIS, None),
        stack.data.shape, mesh)
    stack = stack.with_data(jax.device_put(
        stack.data, NamedSharding(mesh, spec)))
    step = build_cefl_round_step(_audit_micro_loss, _AUDIT_HYPER)
    return Program(fn=step, args=(stack, batch, meta))
