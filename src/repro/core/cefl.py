"""Deprecated dict-based CE-FL entry points, kept as thin shims.

The orchestration loop now lives in the typed API:

  * :mod:`repro.core.api`        — RoundPlan / RoundReport / RunResult,
                                   DecisionStrategy protocol + registry
  * :mod:`repro.core.strategies` — the built-in strategies
  * :mod:`repro.core.engine`     — Engine + Sim/Mesh executors

``run_cefl`` still works (and now actually fills the ``loss`` series and
warm-starts successive SCA solves), but new code should construct an
:class:`~repro.core.engine.Engine` directly — see docs/orchestration.md.
"""
from __future__ import annotations

import warnings
from typing import Dict

from repro.core.api import EngineOptions
from repro.core.api import EngineOptions as CEFLOptions  # noqa: F401
from repro.core.api import DecisionContext, RoundPlan, get_strategy
from repro.core.convergence import MLConstants
from repro.core.engine import Engine, SimExecutor
from repro.core.engine import realize_offloading  # noqa: F401  (back-compat)
from repro.solver.objective import ObjectiveWeights


def decide(strategy: str, net, D_bar, consts, ow, opts, w_prev=None) -> Dict:
    """Deprecated: resolve ``strategy`` through the registry and return the
    decision as a plain dict (old call sites).  Use
    ``api.get_strategy(name).decide(net, D_bar, ctx)`` instead."""
    warnings.warn("core.cefl.decide is deprecated; use "
                  "repro.core.api.get_strategy", DeprecationWarning,
                  stacklevel=2)
    prev = RoundPlan.from_w(w_prev) if isinstance(w_prev, dict) else w_prev
    ctx = DecisionContext(round=0, consts=consts, ow=ow, opts=opts,
                          prev_plan=prev)
    return get_strategy(strategy).decide(net, D_bar, ctx).to_w()


def run_cefl(net, online_datasets, *, init_params, loss_fn, eval_fn,
             consts: MLConstants, ow: ObjectiveWeights,
             opts: EngineOptions) -> Dict:
    """Deprecated shim over :class:`~repro.core.engine.Engine`.

    Returns the legacy history dict (``RunResult.to_history()``).
    """
    warnings.warn(
        "run_cefl is deprecated; use repro.core.engine.Engine — "
        "Engine(net, opts.strategy, consts=..., ow=..., opts=...)"
        ".run(...).to_history() is equivalent", DeprecationWarning,
        stacklevel=2)
    engine = Engine(net, opts.strategy, consts=consts, ow=ow, opts=opts,
                    executor=SimExecutor())
    return engine.run(online_datasets, init_params=init_params,
                      loss_fn=loss_fn, eval_fn=eval_fn).to_history()
