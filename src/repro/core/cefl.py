"""CE-FL end-to-end orchestration (simulation level, paper Secs. II+IV-VI):

each global round t:
  1. UEs observe new online data (concept drift),
  2. the network-aware solver (SCA / greedy / fixed) picks the orchestration
     w^t — offloading rho, compute settings f/z/gamma/m, aggregator I_s,
  3. data offloading is realized (UE -> BS -> DC partitions),
  4. every DPU runs FedProx local training (eqs. 5-10),
  5. scaled accumulated gradients are BS-relayed and aggregated at the
     floating aggregation DC (eq. 11),
  6. delay / energy are charged per Sec. II-E.

Baselines: FedNova and FedAvg (no offloading, fixed aggregator, homogeneous
average settings), per Sec. VI-B1.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, fedprox
from repro.core.convergence import MLConstants
from repro.network.costs import network_costs, round_delay, round_energy
from repro.solver import greedy as greedy_mod
from repro.solver.objective import ObjectiveWeights
from repro.solver import sca
from repro.solver.variables import round_indicators


@dataclasses.dataclass
class CEFLOptions:
    rounds: int = 20
    eta: float = 0.05
    mu: float = 0.01
    theta: Optional[float] = None   # None -> sum_i p_i gamma_i (tau_eff),
                                    # the paper's "compensating" scaling
    strategy: str = "cefl"    # cefl | greedy_data | greedy_rate | fixed:<s>
    reoptimize_every: int = 1
    solver_outer: int = 4
    distributed_solver: bool = False   # centralized is faster for sims
    gamma_default: int = 2
    m_default: float = 0.5
    rate_jitter: float = 0.15
    seed: int = 0


def realize_offloading(rng, data_per_ue: List[dict], w, net):
    """Split each UE's round data per rho_nb / rho_bs into DPU datasets.
    Returns (ue_datasets, dc_datasets) as lists of {'x','y'} dicts."""
    N, B, S = net.dims
    rho_nb = np.asarray(w["rho_nb"])
    rho_bs = np.asarray(w["rho_bs"])
    bs_pool_x, bs_pool_y = [[] for _ in range(B)], [[] for _ in range(B)]
    ue_data = []
    for n, d in enumerate(data_per_ue):
        x, y = np.asarray(d["x"]), np.asarray(d["y"])
        D = len(y)
        perm = rng.permutation(D)
        counts = np.floor(rho_nb[n] * D).astype(int)
        start = 0
        for b in range(B):
            take = perm[start:start + counts[b]]
            start += counts[b]
            if len(take):
                bs_pool_x[b].append(x[take])
                bs_pool_y[b].append(y[take])
        keep = perm[start:]
        if len(keep) == 0:
            keep = perm[:1]          # every UE keeps >=1 point
        ue_data.append({"x": jnp.asarray(x[keep]), "y": jnp.asarray(y[keep])})
    dc_x, dc_y = [[] for _ in range(S)], [[] for _ in range(S)]
    for b in range(B):
        if not bs_pool_x[b]:
            continue
        x = np.concatenate(bs_pool_x[b])
        y = np.concatenate(bs_pool_y[b])
        perm = rng.permutation(len(y))
        counts = np.floor(rho_bs[b] * len(y)).astype(int)
        # BSs keep no data: dump the rounding remainder on the best DC
        counts[np.argmax(counts)] += len(y) - counts.sum()
        start = 0
        for s in range(S):
            take = perm[start:start + counts[s]]
            start += counts[s]
            if len(take):
                dc_x[s].append(x[take])
                dc_y[s].append(y[take])
    dc_data = []
    for s in range(S):
        if dc_x[s]:
            dc_data.append({"x": jnp.asarray(np.concatenate(dc_x[s])),
                            "y": jnp.asarray(np.concatenate(dc_y[s]))})
        else:
            dc_data.append(None)
    return ue_data, dc_data


def decide(strategy: str, net, D_bar, consts, ow, opts, w_prev=None):
    if strategy == "cefl":
        res = sca.solve(net, D_bar, consts, ow,
                        max_outer=opts.solver_outer,
                        distributed=opts.distributed_solver,
                        w0=w_prev)
        return res.w_rounded
    base = greedy_mod.heuristic_base(net, D_bar)
    base = dict(base)
    base["gamma"] = jnp.full_like(base["gamma"], float(opts.gamma_default))
    base["m"] = jnp.full_like(base["m"], opts.m_default)
    if strategy == "greedy_data":
        return greedy_mod.datapoint_greedy(net, D_bar, base)
    if strategy == "greedy_rate":
        return greedy_mod.rate_greedy(net, D_bar, base)
    if strategy.startswith("fixed:"):
        return greedy_mod.fixed_aggregator(net, D_bar,
                                           int(strategy.split(":")[1]), base)
    if strategy in ("fednova", "fedavg"):
        # conventional FedL: no offloading, everything at the UEs,
        # fixed aggregator 0, average settings
        w = greedy_mod.fixed_aggregator(net, D_bar, 0, base)
        w = dict(w)
        w["rho_nb"] = jnp.zeros_like(w["rho_nb"])
        c = network_costs(w, net, D_bar)
        w["delta_A"], w["delta_R"] = c["delta_A_req"], c["delta_R_req"]
        return w
    raise ValueError(strategy)


def run_cefl(net, online_datasets, *, init_params, loss_fn, eval_fn,
             consts: MLConstants, ow: ObjectiveWeights,
             opts: CEFLOptions) -> Dict:
    """Main loop.  online_datasets: list of core.drift.OnlineDataset (one
    per UE).  loss_fn(params, batch)->scalar; eval_fn(params)->accuracy."""
    rng = np.random.RandomState(opts.seed)
    key = jax.random.PRNGKey(opts.seed)
    N, B, S = net.dims
    params = init_params
    hist = {"round": [], "acc": [], "loss": [], "energy": [], "delay": [],
            "aggregator": [], "cum_energy": [], "cum_delay": [],
            "dc_points": [], "gamma_mean": [], "m_mean": []}
    cum_E, cum_D = 0.0, 0.0
    w = None
    strategy = opts.strategy
    is_baseline = strategy in ("fednova", "fedavg")
    for t in range(opts.rounds):
        data_per_ue = [ds.step() for ds in online_datasets]
        D_bar = np.array([len(d["y"]) for d in data_per_ue], float)
        net_t = net.resample_rates(rng, opts.rate_jitter)
        if t % opts.reoptimize_every == 0 or w is None:
            w = decide(strategy, net_t, D_bar, consts, ow, opts, w_prev=None)
            w = round_indicators(w)
        ue_data, dc_data = realize_offloading(rng, data_per_ue, w, net_t)
        gammas = np.maximum(np.rint(np.asarray(w["gamma"])), 1).astype(int)
        ms = np.clip(np.asarray(w["m"]), 0.05, 1.0)
        results, weights, idxs = [], [], []
        for i, d in enumerate(ue_data + dc_data):
            if d is None or len(d["y"]) == 0:
                continue
            key, k = jax.random.split(key)
            if strategy == "fedavg":
                # FedAvg: plain local SGD (mu=0), aggregate local MODELS
                r = fedprox.local_train(params, loss_fn, d,
                                        gamma=int(gammas[i]),
                                        m_frac=float(ms[i]),
                                        eta=opts.eta, mu=0.0, key=k)
            else:
                r = fedprox.local_train(params, loss_fn, d,
                                        gamma=int(gammas[i]),
                                        m_frac=float(ms[i]),
                                        eta=opts.eta,
                                        mu=0.0 if is_baseline else opts.mu,
                                        key=k)
            results.append(r)
            weights.append(r.num_examples)
            idxs.append(i)
        if strategy == "fedavg":
            params = aggregation.fedavg_aggregate(
                [r.params for r in results], weights)
        elif strategy == "fednova":
            params = aggregation.fednova_aggregate(
                params, [r.d_i for r in results], weights,
                [r.gamma for r in results], eta=opts.eta)
        else:
            wn = np.asarray(weights, float)
            wn = wn / wn.sum()
            theta = opts.theta if opts.theta is not None else float(
                np.sum(wn * np.array([r.gamma for r in results])))
            params = aggregation.aggregate(
                params, [r.d_i for r in results], weights,
                theta=theta, eta=opts.eta)
        costs = network_costs(w, net_t, D_bar)
        E = float(round_energy(costs, ow.xi3_sub))
        Dl = float(round_delay(costs))
        cum_E += E
        cum_D += Dl
        acc = float(eval_fn(params))
        hist["round"].append(t)
        hist["acc"].append(acc)
        hist["energy"].append(E)
        hist["delay"].append(Dl)
        hist["cum_energy"].append(cum_E)
        hist["cum_delay"].append(cum_D)
        hist["aggregator"].append(int(np.argmax(np.asarray(w["I_s"]))))
        hist["dc_points"].append([0 if d is None else len(d["y"])
                                  for d in dc_data])
        hist["gamma_mean"].append(float(gammas.mean()))
        hist["m_mean"].append(float(ms.mean()))
    return hist
