"""Global aggregation at the floating aggregation DC (paper eq. 11).

The aggregator receives scaled accumulated gradients D_i * d_i (BSs sum the
gradients of their associated UEs first, Sec. II-D), sums them, and applies

    x^{t+1} = x^t - (theta * eta / D^t) * sum_i D_i d_i.

Weight contract (docs/kernels.md): every public entry point in this module
takes ABSOLUTE dataset sizes D_i and normalizes them exactly once through
:func:`normalize_weights` — the single normalization point of the tree
path.  The kernel level (``kernels.ops.nova_aggregate_plane`` and below)
takes already-normalized weights and never re-normalizes.

All entry points accept either pytrees or :class:`~repro.kernels.plane.
ParamPlane` values; plane inputs stay on the flat layout end-to-end and
dispatch to the fused Pallas aggregation kernel.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ops import normalize_weights  # noqa: F401  (canonical
#   import path for the weight contract; defined at the kernel-wrapper
#   layer so kernels/ops.py has no dependency on core)
from repro.kernels.plane import ParamPlane


def _stack_planes(planes: Sequence[ParamPlane]) -> jnp.ndarray:
    return jnp.stack([p.data for p in planes], axis=0)


def bs_relay_sum(scaled_gradients: Sequence, groups: Sequence[Sequence[int]]):
    """Sum scaled gradients per BS group (keeps the uplink payload one model
    wide per BS, Sec. II-D footnote 2).  Returns one summed pytree (or
    ParamPlane) per group."""
    out = []
    for g in groups:
        if not g:
            continue
        acc = scaled_gradients[g[0]]
        if isinstance(acc, ParamPlane):
            data = acc.data
            for i in g[1:]:
                data = data + scaled_gradients[i].data
            out.append(acc.with_data(data))
        else:
            for i in g[1:]:
                acc = jax.tree_util.tree_map(jnp.add, acc,
                                             scaled_gradients[i])
            out.append(acc)
    return out


def aggregate(x_t, d_list: List, weights: Sequence[float], *, theta: float,
              eta: float):
    """eq. (11).  weights: absolute D_i; normalized here (once)."""
    w = normalize_weights(weights)
    if isinstance(x_t, ParamPlane):
        out = ops.nova_aggregate_plane(x_t.data, _stack_planes(d_list), w,
                                       theta * eta)
        return x_t.with_data(out)
    acc = None
    for d_i, w_i in zip(d_list, w):
        scaled = jax.tree_util.tree_map(lambda x: w_i * x, d_i)
        acc = scaled if acc is None else jax.tree_util.tree_map(
            jnp.add, acc, scaled)
    return jax.tree_util.tree_map(lambda x, d: x - theta * eta * d, x_t, acc)


def fedavg_aggregate(local_params: List, weights: Sequence[float]):
    """Plain FedAvg: weighted average of local models (absolute weights)."""
    w = normalize_weights(weights)
    if isinstance(local_params[0], ParamPlane):
        stack = _stack_planes(local_params)
        return local_params[0].with_data(
            jnp.einsum("n,nrl->rl", w, stack))
    acc = None
    for p_i, w_i in zip(local_params, w):
        scaled = jax.tree_util.tree_map(lambda x: w_i * x, p_i)
        acc = scaled if acc is None else jax.tree_util.tree_map(
            jnp.add, acc, scaled)
    return acc


def fednova_aggregate(x_t, d_list: List, weights: Sequence[float],
                      gammas: Sequence[float], *, eta: float):
    """FedNova (Wang et al. 2020): x^{t+1} = x^t - eta * tau_eff * sum p_i d_i
    with tau_eff = sum_i p_i gamma_i (momentum-free case).  Absolute
    weights; this is eq. 11 with theta = tau_eff."""
    p = normalize_weights(weights)
    tau_eff = float(jnp.sum(p * jnp.asarray(gammas, jnp.float32)))
    return aggregate(x_t, d_list, weights, theta=tau_eff, eta=eta)


# ------------------------------------------- byzantine-robust counters --

def _robust_kwargs(n: int, mode: str, trim_frac: float) -> dict:
    if mode not in ops.ROBUST_MODES:
        raise ValueError(
            f"unknown robust mode {mode!r}; known: {ops.ROBUST_MODES}")
    median = mode == "median"
    return {"k": 0 if median else ops.trim_count(n, trim_frac),
            "median": median}


def robust_aggregate(x_t, d_list: List, *, theta: float, eta: float,
                     mode: str = "trimmed_mean", trim_frac: float = 0.1):
    """eq. 11 with the weighted sum replaced by a coordinate-wise trimmed
    mean / median over the d_i stack — the byzantine counter
    (``EngineOptions.robust_agg``).  Deliberately takes NO weights: the
    D_i a compromised client reports are not trusted."""
    from repro.kernels import ref as _ref
    if isinstance(x_t, ParamPlane):
        out = ops.robust_aggregate_plane(
            x_t.data, _stack_planes(d_list), theta * eta, mode=mode,
            trim_frac=trim_frac)
        return x_t.with_data(out)
    kw = _robust_kwargs(len(d_list), mode, trim_frac)
    return jax.tree_util.tree_map(
        lambda xl, *dl: _ref.robust_aggregate_ref(
            xl, jnp.stack(dl), theta * eta, **kw), x_t, *d_list)


def _robust_agg_program():
    import functools
    x = jnp.zeros((8, 1024), jnp.float32)
    d_stack = jnp.ones((5, 8, 1024), jnp.float32)
    fn = jax.jit(functools.partial(
        ops.robust_aggregate_plane, mode="trimmed_mean", trim_frac=0.2,
        backend="cpu"))
    return Program(fn=fn, args=(x, d_stack,
                                jnp.asarray(0.05, jnp.float32)))


from repro.analysis.jaxpr.contracts import Program, contract  # noqa: E402


@contract(
    "robust_aggregation",
    collectives={},
    memory_budget_bytes=2 << 20,
)
def _robust_aggregation_contract():
    """Coordinate-wise trimmed-mean eq.-11 on a tiny plane stack."""
    return _robust_agg_program()


@contract(
    "fedprox_plane_bf16",
    collectives={},
    out_dtypes=("bfloat16",),
)
def _fedprox_bf16_contract():
    """bf16 leaf round-trip: the fused proximal step must return bf16
    when fed bf16 planes (weak Python-float eta/mu keep it narrow)."""
    x = jnp.ones((8, 1024), jnp.bfloat16)
    fn = jax.jit(lambda p, g, a: ops.fedprox_plane(p, g, a, 0.1, 0.01,
                                                   backend="cpu"))
    return Program(fn=fn, args=(x, x, x))


def robust_fedavg_aggregate(local_params: List, *,
                            mode: str = "trimmed_mean",
                            trim_frac: float = 0.1):
    """Robust FedAvg: coordinate-wise trimmed-mean/median of the local
    models (Yin et al. 2018), reusing the fused kernel with x = 0 and
    theta_eta = -1 so x_new = reduce(stack)."""
    from repro.kernels import ref as _ref
    if isinstance(local_params[0], ParamPlane):
        stack = _stack_planes(local_params)
        zero = jnp.zeros(stack.shape[1:], stack.dtype)
        return local_params[0].with_data(
            ops.robust_aggregate_plane(zero, stack, -1.0, mode=mode,
                                       trim_frac=trim_frac))
    kw = _robust_kwargs(len(local_params), mode, trim_frac)
    return jax.tree_util.tree_map(
        lambda *pl_: _ref.robust_reduce_ref(jnp.stack(pl_), **kw),
        *local_params)
