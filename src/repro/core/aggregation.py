"""Global aggregation at the floating aggregation DC (paper eq. 11).

The aggregator receives scaled accumulated gradients D_i * d_i (BSs sum the
gradients of their associated UEs first, Sec. II-D), sums them, and applies

    x^{t+1} = x^t - (theta * eta / D^t) * sum_i D_i d_i.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp


def bs_relay_sum(scaled_gradients: Sequence, groups: Sequence[Sequence[int]]):
    """Sum scaled gradients per BS group (keeps the uplink payload one model
    wide per BS, Sec. II-D footnote 2).  Returns one summed pytree per group."""
    out = []
    for g in groups:
        if not g:
            continue
        acc = scaled_gradients[g[0]]
        for i in g[1:]:
            acc = jax.tree_util.tree_map(jnp.add, acc, scaled_gradients[i])
        out.append(acc)
    return out


def aggregate(x_t, d_list: List, weights: Sequence[float], *, theta: float,
              eta: float):
    """eq. (11).  weights: D_i (absolute dataset sizes); normalized inside."""
    total = float(sum(weights))
    acc = None
    for d_i, D_i in zip(d_list, weights):
        scaled = jax.tree_util.tree_map(lambda x: (D_i / total) * x, d_i)
        acc = scaled if acc is None else jax.tree_util.tree_map(
            jnp.add, acc, scaled)
    return jax.tree_util.tree_map(lambda x, d: x - theta * eta * d, x_t, acc)


def fedavg_aggregate(local_params: List, weights: Sequence[float]):
    """Plain FedAvg: weighted average of local models."""
    total = float(sum(weights))
    acc = None
    for p_i, D_i in zip(local_params, weights):
        scaled = jax.tree_util.tree_map(lambda x: (D_i / total) * x, p_i)
        acc = scaled if acc is None else jax.tree_util.tree_map(
            jnp.add, acc, scaled)
    return acc


def fednova_aggregate(x_t, d_list: List, weights: Sequence[float],
                      gammas: Sequence[float], *, eta: float):
    """FedNova (Wang et al. 2020): x^{t+1} = x^t - eta * tau_eff * sum p_i d_i
    with tau_eff = sum_i p_i gamma_i (momentum-free case)."""
    total = float(sum(weights))
    p = [w / total for w in weights]
    tau_eff = sum(pi * gi for pi, gi in zip(p, gammas))
    acc = None
    for d_i, pi in zip(d_list, p):
        scaled = jax.tree_util.tree_map(lambda x: pi * x, d_i)
        acc = scaled if acc is None else jax.tree_util.tree_map(
            jnp.add, acc, scaled)
    return jax.tree_util.tree_map(
        lambda x, d: x - eta * tau_eff * d, x_t, acc)
