"""Built-in decision strategies (paper Sec. IV + VI-B baselines), each
registered under the name the old ``core/cefl.py`` string dispatch used:

  cefl         — Algorithm 1 (SCA over problem P), warm-started from the
                 previous round's plan
  greedy_data  — datapoint-greedy floating aggregator (Sec. VI-B2)
  greedy_rate  — data-rate-greedy floating aggregator (eq. 100)
  fixed:<s>    — always aggregate at DC s
  fednova      — conventional FedL, FedNova aggregation (no offloading)
  fedavg       — conventional FedL, model averaging (no offloading)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import (DecisionContext, RoundPlan, register_strategy)
from repro.solver import greedy as greedy_mod
from repro.solver import sca
from repro.solver.objective import apply_required_deltas
from repro.solver.variables import round_indicators


def _heuristic_base(net, D_bar, opts):
    """Shared non-aggregation decisions for the greedy/fixed baselines."""
    base = dict(greedy_mod.heuristic_base(net, D_bar))
    base["gamma"] = jnp.full_like(base["gamma"], float(opts.gamma_default))
    base["m"] = jnp.full_like(base["m"], opts.m_default)
    return base


@register_strategy("cefl")
class CEFLStrategy:
    """Network-aware CE-FL: successive convex approximation over P."""
    aggregation = "cefl"
    proximal = True

    def decide(self, net, D_bar, ctx: DecisionContext) -> RoundPlan:
        opts = ctx.opts
        # warm start from the previous plan: device arrays end-to-end (the
        # jit backend flattens them straight onto the solver plane).  The
        # plan's indicators are rounded one-hots — mix them back toward
        # the simplex interior so the relaxed SCA iterate isn't pinned at
        # the previous vertex when the network has moved on.
        w0 = None
        if ctx.prev_plan is not None:
            w0 = dict(ctx.prev_plan.to_w())
            for k, ax in (("I_s", 0), ("I_nb", 1), ("I_bn", 0)):
                x = jnp.asarray(w0[k], jnp.float32)
                w0[k] = 0.5 * x + 0.5 / x.shape[ax]
        D_j = jnp.asarray(D_bar, jnp.float32)
        res = sca.solve(net, D_j, ctx.consts, ctx.ow,
                        max_outer=opts.solver_outer,
                        distributed=opts.distributed_solver, w0=w0,
                        backend=opts.solver_backend)
        # floating aggregation point: exact enumeration over the rounded
        # plan (argmax of a near-uniform relaxed I_s is noise)
        w = dict(res.w_rounded)
        s = sca.select_aggregator(w, net, D_j, ctx.consts, ctx.ow)
        w["I_s"] = jax.nn.one_hot(jnp.asarray(s), w["I_s"].shape[0])
        w = apply_required_deltas(w, net, D_j)
        return RoundPlan.from_w(w)


class _GreedyBase:
    aggregation = "cefl"
    proximal = True

    def _pick(self, net, D_bar):
        raise NotImplementedError

    def decide(self, net, D_bar, ctx: DecisionContext) -> RoundPlan:
        base = _heuristic_base(net, D_bar, ctx.opts)
        w = greedy_mod.fixed_aggregator(net, D_bar, self._pick(net, D_bar),
                                        base)
        return RoundPlan.from_w(round_indicators(w))


@register_strategy("greedy_data")
class GreedyDataStrategy(_GreedyBase):
    def _pick(self, net, D_bar):
        return int(np.argmax(greedy_mod.subnet_datapoints(net, D_bar)))


@register_strategy("greedy_rate")
class GreedyRateStrategy(_GreedyBase):
    def _pick(self, net, D_bar):
        return int(np.argmax(greedy_mod.e2e_rate(net).mean(axis=0)))


@register_strategy("fixed")
class FixedStrategy(_GreedyBase):
    """Always aggregate at DC ``s`` — spec string ``fixed:<s>``."""

    def __init__(self, s_idx=""):
        if s_idx == "":
            raise ValueError("fixed strategy needs a DC index: 'fixed:<s>'")
        self.s_idx = int(s_idx)

    def _pick(self, net, D_bar):
        return self.s_idx


class _ConventionalFedL:
    """Conventional FedL baseline (Sec. VI-B1): no offloading, everything
    trained at the UEs, fixed aggregator DC 0, homogeneous settings."""
    proximal = False

    def decide(self, net, D_bar, ctx: DecisionContext) -> RoundPlan:
        base = _heuristic_base(net, D_bar, ctx.opts)
        w = dict(greedy_mod.fixed_aggregator(net, D_bar, 0, base))
        w["rho_nb"] = jnp.zeros_like(w["rho_nb"])
        w = apply_required_deltas(round_indicators(w), net, D_bar)
        return RoundPlan.from_w(w)


@register_strategy("fednova")
class FedNovaStrategy(_ConventionalFedL):
    aggregation = "fednova"


@register_strategy("fedavg")
class FedAvgStrategy(_ConventionalFedL):
    aggregation = "fedavg"
