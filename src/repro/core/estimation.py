"""Monte-Carlo estimation of the ML constants (paper App. H, Algs. 4-7).

  * Theta_i: local data variability (Assumption 2) — Alg. 4
  * L: smoothness (Assumption 1) — Alg. 5 (local max -> global max at s_est)
  * zeta1, zeta2: bounded dissimilarity (Assumption 3) — Alg. 6 via least
    squares on (sum p_i ||g_i||^2, ||sum p_i g_i||^2) pairs
  * dynamic re-estimation wrapper — Alg. 7 (running max)

All estimates are scaled by ``safety`` (paper uses 1.5x) before use.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convergence import MLConstants


def _rand_params_like(key, params, scale=1.0):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    ks = jax.random.split(key, len(leaves))
    new = [jax.random.normal(k, l.shape, l.dtype) * scale
           for k, l in zip(ks, leaves)]
    return jax.tree_util.tree_unflatten(treedef, new)


def _flat(g):
    return jnp.concatenate([x.reshape(-1) for x in
                            jax.tree_util.tree_leaves(g)])


def estimate_theta(loss_fn: Callable, params_template, data: dict, *,
                   key, iters: int = 10, sample: int = 32) -> float:
    """Alg. 4: Theta_i ~= max_j mean_{xi,xi'} ||grad f(x;xi)-grad f(x;xi')||
    / ||xi - xi'||  over random models x_j."""
    D = jax.tree_util.tree_leaves(data)[0].shape[0]
    n = min(sample, D)
    per_ex_grad = jax.vmap(
        jax.grad(lambda p, x, y: loss_fn(p, {"x": x[None], "y": y[None]})),
        in_axes=(None, 0, 0))
    best = 0.0
    for j in range(iters):
        kj, key = jax.random.split(key)
        p = _rand_params_like(kj, params_template, 0.5)
        idx = jax.random.choice(kj, D, (n,), replace=False)
        xs, ys = data["x"][idx], data["y"][idx]
        gs = per_ex_grad(p, xs, ys)
        G = jax.vmap(_flat)(gs)                      # (n, P)
        X = xs.reshape(n, -1).astype(jnp.float32)
        gd = jnp.linalg.norm(G[:, None] - G[None, :], axis=-1)
        xd = jnp.linalg.norm(X[:, None] - X[None, :], axis=-1)
        mask = xd > 1e-9
        ratio = jnp.where(mask, gd / jnp.maximum(xd, 1e-9), 0.0)
        best = max(best, float(jnp.mean(ratio)))  # Alg.4 averages pairs
    return best


def estimate_L(loss_fn: Callable, params_template, data: dict, *,
               key, iters: int = 10) -> float:
    """Alg. 5 local part: max_j ||grad F(x1)-grad F(x2)|| / ||x1-x2||."""
    grad_fn = jax.grad(lambda p: loss_fn(p, data))
    best = 0.0
    for j in range(iters):
        k1, k2, key = jax.random.split(key, 3)
        p1 = _rand_params_like(k1, params_template, 0.5)
        p2 = _rand_params_like(k2, params_template, 0.5)
        g1, g2 = _flat(grad_fn(p1)), _flat(grad_fn(p2))
        dx = _flat(p1) - _flat(p2)
        best = max(best, float(jnp.linalg.norm(g1 - g2) /
                               jnp.maximum(jnp.linalg.norm(dx), 1e-9)))
    return best


def estimate_zeta(loss_fn: Callable, params_template,
                  datasets: Sequence[dict], *, key, iters: int = 10):
    """Alg. 6: linear regression of sum p_i||g_i||^2 on ||sum p_i g_i||^2."""
    D = np.array([jax.tree_util.tree_leaves(d)[0].shape[0]
                  for d in datasets], np.float64)
    p = D / D.sum()
    lhs, rhs = [], []
    for j in range(iters):
        kj, key = jax.random.split(key)
        x = _rand_params_like(kj, params_template, 0.5)
        gs = [_flat(jax.grad(lambda pp: loss_fn(pp, d))(x)) for d in datasets]
        lhs.append(float(sum(pi * float(jnp.sum(g * g))
                             for pi, g in zip(p, gs))))
        gbar = sum(pi * g for pi, g in zip(p, gs))
        rhs.append(float(jnp.sum(gbar * gbar)))
    A = np.stack([np.array(rhs), np.ones(len(rhs))], axis=1)
    sol, *_ = np.linalg.lstsq(A, np.array(lhs), rcond=None)
    zeta1 = max(float(sol[0]), 1.0)                    # Assumption 3: >= 1
    zeta2 = max(float(sol[1]), 0.0)
    return zeta1, zeta2


def estimate_constants(loss_fn: Callable, params_template,
                       datasets: Sequence[dict], *, key,
                       iters: int = 8, safety: float = 1.5,
                       f0_gap: float = 2.3) -> MLConstants:
    """One-shot pre-training estimation (App. H-1) across all DPUs."""
    ks = jax.random.split(key, len(datasets) + 2)
    theta = np.array([
        estimate_theta(loss_fn, params_template, d, key=ks[i], iters=iters)
        for i, d in enumerate(datasets)])
    L = max(estimate_L(loss_fn, params_template, d, key=ks[-2], iters=iters)
            for d in datasets)
    z1, z2 = estimate_zeta(loss_fn, params_template, datasets, key=ks[-1],
                           iters=iters)
    # sigma_i^2 = sample variance of the data VECTORS (Prop. 1 pairs it with
    # Theta_i^2 ||xi - xi'||^2 terms): mean squared distance to the mean.
    sigma = []
    for d in datasets:
        flat = np.asarray(d["x"]).reshape(d["x"].shape[0], -1)
        sigma.append(np.sqrt(np.mean(np.sum(
            (flat - flat.mean(0, keepdims=True)) ** 2, axis=1))))
    sigma = np.array(sigma)
    return MLConstants(L=safety * L, theta_i=safety * theta,
                       sigma_i=sigma, zeta1=safety * z1, zeta2=safety * z2,
                       F0_gap=f0_gap)


def dynamic_update(old: MLConstants, new: MLConstants) -> MLConstants:
    """Alg. 7 post-processing: element-wise running max."""
    return MLConstants(
        L=max(old.L, new.L),
        theta_i=np.maximum(old.theta_i, new.theta_i),
        sigma_i=np.maximum(old.sigma_i, new.sigma_i),
        zeta1=max(old.zeta1, new.zeta1),
        zeta2=max(old.zeta2, new.zeta2),
        F0_gap=max(old.F0_gap, new.F0_gap))


def sgd_variance_bound(m_frac: float, D: int, sigma: float,
                       theta: float) -> float:
    """Proposition 1: E||grad_tilde F - grad F||^2 <=
    2 (1-m)(D-1)/(m D^2) * sigma^2 * Theta^2 (without-replacement)."""
    m = np.clip(m_frac, 1e-9, 1.0)
    return float(2 * (1 - m) * (D - 1) / (m * D ** 2) * sigma ** 2
                 * theta ** 2)
