"""Model/concept drift (paper Definition 1) and online dataset dynamics.

Drift Delta_i bounds the per-unit-time change of the *fractional* local loss:

    (D_i^{t+1}/D^{t+1}) F_i^{t+1}(x) - (D_i^t/D^t) F_i^t(x) <= tau^t Delta_i^t.

``estimate_drift`` measures the left-hand side empirically on probe models;
``OnlineDataset`` realizes the paper's dynamic data model (App. G): per-round
arrivals ~ N(2000, 200), non-iid 5-of-10 label support per UE.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fractional_loss(loss_fn: Callable, params, data: dict, D_total: int):
    D_i = jax.tree_util.tree_leaves(data)[0].shape[0]
    return (D_i / D_total) * loss_fn(params, data)


def estimate_drift(loss_fn: Callable, params_probes: Sequence,
                   data_t: dict, data_tp1: dict, D_t: int, D_tp1: int,
                   tau: float) -> float:
    """Empirical Delta_i over a set of probe models (max over probes).

    The probe pytrees are stacked on a leading axis and evaluated through
    ONE vmapped fractional-loss difference: ``loss_fn`` is traced once for
    the whole probe set instead of once per probe (the old Python loop
    re-traced per probe; ``_estimate_drift_loop`` keeps it as the
    regression oracle).
    """
    probes = list(params_probes)
    if not probes:
        raise ValueError("estimate_drift needs at least one probe model")
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *probes)

    def diff(p):
        return fractional_loss(loss_fn, p, data_tp1, D_tp1) \
            - fractional_loss(loss_fn, p, data_t, D_t)

    vals = jax.vmap(diff)(stacked)
    return float(jnp.max(vals)) / max(tau, 1e-9)


def _estimate_drift_loop(loss_fn: Callable, params_probes: Sequence,
                         data_t: dict, data_tp1: dict, D_t: int, D_tp1: int,
                         tau: float) -> float:
    """Pre-vmap per-probe loop (regression oracle for ``estimate_drift``)."""
    vals = []
    for p in params_probes:
        f1 = fractional_loss(loss_fn, p, data_tp1, D_tp1)
        f0 = fractional_loss(loss_fn, p, data_t, D_t)
        vals.append(float(f1 - f0) / max(tau, 1e-9))
    return max(vals)


@dataclasses.dataclass
class OnlineDataset:
    """Per-UE dynamic dataset: each round new points arrive (mean/var per
    App. G) drawn from the UE's label support; a fraction of old points
    expires.  Deterministic given the numpy seed."""
    features: np.ndarray          # pool (N, ...) to draw from
    labels: np.ndarray            # pool labels (N,)
    label_support: np.ndarray     # labels this UE can observe
    mean_arrivals: float = 2000.0
    std_arrivals: float = 200.0
    retention: float = 0.0        # fraction of previous data kept
    seed: int = 0
    drift_labels: bool = False    # label support rotates over time (drift)

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)
        self._x: Optional[np.ndarray] = None
        self._y: Optional[np.ndarray] = None
        self._round = 0
        num_classes = int(self.labels.max()) + 1
        self._by_label = {c: np.nonzero(self.labels == c)[0]
                          for c in range(num_classes)}

    @property
    def num_classes(self):
        return int(self.labels.max()) + 1

    # -- full-state resume (repro.experiments.runstate) -----------------

    def state_dict(self) -> dict:
        """Everything that evolves round to round: PRNG state, the live
        data buffer, and the round counter.  Leaves are arrays/scalars so
        the dict rides through ``training.checkpoint`` unchanged.  ``_x``
        is None until the first ``step``; a zero-length buffer keeps the
        tree structure identical at every round."""
        kind, keys, pos, has_gauss, cached = self._rng.get_state()
        assert kind == "MT19937", kind
        empty_x = self.features[:0]
        return {
            "rng": {"keys": np.asarray(keys), "pos": int(pos),
                    "has_gauss": int(has_gauss), "cached": float(cached)},
            "x": empty_x if self._x is None else np.asarray(self._x),
            "y": self.labels[:0] if self._y is None
                 else np.asarray(self._y),
            "has_data": int(self._x is not None),
            "round": int(self._round),
        }

    def load_state_dict(self, d: dict) -> None:
        self._rng.set_state(("MT19937",
                             np.asarray(d["rng"]["keys"], np.uint32),
                             int(d["rng"]["pos"]),
                             int(d["rng"]["has_gauss"]),
                             float(d["rng"]["cached"])))
        if int(d["has_data"]):
            self._x = np.asarray(d["x"])
            self._y = np.asarray(d["y"])
        else:
            self._x = self._y = None
        self._round = int(d["round"])

    def step(self) -> dict:
        """Advance one global round; returns {'x', 'y'} current local data."""
        support = np.array(self.label_support)
        if self.drift_labels and self._round > 0:
            shift = self._round % self.num_classes
            support = (support + shift) % self.num_classes
        n_new = max(1, int(self._rng.normal(self.mean_arrivals,
                                            self.std_arrivals)))
        per_label = np.array_split(np.arange(n_new), len(support))
        idx = np.concatenate([
            self._rng.choice(self._by_label[int(c)], size=len(part),
                             replace=True)
            for c, part in zip(support, per_label) if len(part)])
        x_new, y_new = self.features[idx], self.labels[idx]
        if self._x is not None and self.retention > 0:
            keep = self._rng.rand(len(self._x)) < self.retention
            x_new = np.concatenate([self._x[keep], x_new])
            y_new = np.concatenate([self._y[keep], y_new])
        self._x, self._y = x_new, y_new
        self._round += 1
        return {"x": jnp.asarray(x_new), "y": jnp.asarray(y_new)}
