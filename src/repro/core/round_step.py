"""Mesh-native CE-FL round: the paper's heterogeneous FedProx round (eqs.
5-11) as a single jittable SPMD step.

DPU mapping (see DESIGN.md §3): every param leaf carries a leading ``n_dpu``
axis.  DPU cohorts are placed on a mesh axis (the ``pod`` axis on the
multi-pod mesh -> 2 DPUs; optionally the ``data`` axis -> 16 DPUs for models
whose per-DPU replica fits).  During the gamma_max local iterations there is
**no cross-DPU collective** — vmap over the DPU axis keeps everything
cohort-local (within-cohort data parallelism still all-reduces grads, which
is intra-DPU and allowed).  The round ends with the eq.-11 weighted
aggregation, the only cross-DPU collective, realizing the floating
aggregation point as a collective schedule (all_reduce by default,
reduce_scatter+all_gather or hierarchical as perf variants).

Heterogeneity is vectorized: all DPUs run to gamma_max; per-DPU activity
masks and the FedNova coefficients a_{i,l} = (1-eta*mu)^(gamma_i-1-l) zero
out inactive steps, so control flow stays SPMD-uniform.

Batches arrive pre-split as (n_dpu, n_micro, mb, ...): every local SGD
iteration gradient-accumulates over the n_micro microbatches (the microbatch
axis is unsharded; mb is the within-DPU data-parallel axis), so one local
step sees the DPU's full round batch with the m_i mini-batch ratio applied
as a per-example mask.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.plane import ParamPlane


@dataclasses.dataclass(frozen=True)
class CEFLHyper:
    eta: float = 1e-2          # local SGD step size
    mu: float = 0.01           # FedProx proximal coefficient
    theta: float = 1.0         # global scaling (vartheta in eq. 11)
    gamma_max: int = 1         # max local iterations (per-DPU gamma <= this)
    n_micro: int = 1           # microbatches per DPU batch
    agg_schedule: str = "all_reduce"   # all_reduce | reduce_scatter | hierarchical
    grad_dtype: str = "float32"        # accumulated-gradient dtype
    kernel_backend: str = "auto"       # plane-path kernel dispatch (see
                                       # kernels/ops.py); "auto" resolves
                                       # to the process default at build


def a_l1(gamma, eta, mu):
    """||a_i||_1 = sum_l (1-eta*mu)^(gamma-1-l), traced-gamma safe."""
    r = 1.0 - eta * mu
    g = gamma.astype(jnp.float32)
    if abs(r - 1.0) < 1e-12:    # repro: noqa(RPA004) eta/mu are Python scalars baked from CEFLHyper; only gamma is traced
        return g
    return (1.0 - jnp.exp(g * jnp.log(r))) / (1.0 - r)


def build_cefl_round_step(loss_fn: Callable, hyper: CEFLHyper):
    """loss_fn(params, microbatch, example_mask) -> (loss, aux).

    Returns round_step(params, batch, meta) -> (new_params, metrics) where
    every ``params`` leaf has a leading n_dpu axis, ``batch`` leaves are
    (n_dpu, n_micro, mb, ...), and meta = {'gamma': (n_dpu,) i32,
    'm_frac': (n_dpu,) f32, 'weight': (n_dpu,) f32 (absolute D_i sizes;
    normalized inside the step — already-normalized weights pass through
    unchanged)}.

    ``params`` may instead be a :class:`~repro.kernels.plane.ParamPlane`
    with ``(n_dpu, R, LANE)`` data: the round then runs on the flat plane
    through the fused kernel ops, dispatched per ``hyper.kernel_backend``
    (tiled Pallas grids on accelerators, jitted jnp on CPU — see
    ``kernels/ops.py``), and returns a ParamPlane — the hot path both
    executors use.  ``grad_dtype`` applies to the tree path only; planes
    accumulate in f32 (the master dtype).
    """
    eta, mu, theta = hyper.eta, hyper.mu, hyper.theta
    gamma_max, n_micro = hyper.gamma_max, hyper.n_micro
    acc_dt = jnp.dtype(hyper.grad_dtype)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def local(params_i, batch_i, gamma_i, m_i):
        anchor = params_i
        mb = jax.tree_util.tree_leaves(batch_i)[0].shape[1]

        def batch_grad(p):
            """grad of F_i over the DPU's full round batch: gradient
            accumulation over the n_micro microbatches (eq. 7, with the
            CE-FL mini-batch ratio as a leading-example mask)."""
            mask = (jnp.arange(mb) < jnp.ceil(m_i * mb)).astype(jnp.float32)

            def micro_step(carry, micro):
                loss_s, g_acc = carry
                (loss, _aux), gF = grad_fn(p, micro, mask)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, gF)
                return (loss_s + loss, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, acc_dt), p)
            (loss_s, g), _ = jax.lax.scan(
                micro_step, (jnp.zeros((), jnp.float32), g0), batch_i)
            inv = 1.0 / n_micro
            g = jax.tree_util.tree_map(lambda x: x * inv, g)
            return loss_s * inv, g

        def one_step(k, p):
            loss, gF = batch_grad(p)
            if eta * mu > 0:
                a_k = jnp.exp((gamma_i.astype(jnp.float32) - 1.0 - k)
                              * jnp.log(1.0 - eta * mu))
            else:
                a_k = jnp.ones(())
            active = (k < gamma_i).astype(jnp.float32)
            p_new = jax.tree_util.tree_map(
                lambda pp, g, x0: (pp.astype(jnp.float32)
                                   - active * eta * (g.astype(jnp.float32)
                                   + mu * (pp.astype(jnp.float32)
                                           - x0.astype(jnp.float32)))
                                   ).astype(pp.dtype),
                p, gF, anchor)
            return p_new, gF, (active * a_k), loss

        if gamma_max == 1:
            # single local iteration: no param-update chain needed
            _p_fin, gF, w, loss_val = one_step(jnp.zeros((), jnp.int32),
                                               params_i)
            acc = jax.tree_util.tree_map(
                lambda g: (w * g.astype(jnp.float32)).astype(acc_dt), gF)
        else:
            def body(k, carry):
                p, acc, _ = carry
                p_new, gF, w, loss = one_step(k, p)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + (w * g.astype(jnp.float32)).astype(acc_dt),
                    acc, gF)
                return (p_new, acc, loss)

            acc0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, acc_dt), params_i)
            _p_fin, acc, loss_val = jax.lax.fori_loop(
                0, gamma_max, body, (params_i, acc0,
                                     jnp.zeros((), jnp.float32)))

        norm = a_l1(gamma_i, eta, mu)
        d_i = jax.tree_util.tree_map(lambda x: x / norm.astype(x.dtype), acc)
        return d_i, loss_val

    def round_step_plane(plane: ParamPlane, batch, meta):
        """The same round on the flat parameter plane: per-iteration
        proximal update + eq.-10 accumulation are ONE fused Pallas launch
        over all DPUs (``fedprox_accum_2d``), and the eq.-11 reduction is
        the fused aggregation kernel — no per-leaf tree_map chains.  The
        tree view ``loss_fn`` needs is a compile-time slice of the plane
        inside the traced graph."""
        spec = plane.spec
        p0 = plane.data                       # (n_dpu, R, LANE)
        n = p0.shape[0]
        gamma_v = meta["gamma"]
        m_v = meta["m_frac"]
        w = meta["weight"].astype(jnp.float32)
        w = w / jnp.sum(w)                    # weight contract: absolute ok
        backend = ops.resolve_backend(hyper.kernel_backend)
        mb = jax.tree_util.tree_leaves(batch)[0].shape[2]
        plane_grad = jax.value_and_grad(
            lambda pp, micro, mask: loss_fn(spec.unflatten(pp), micro, mask),
            has_aux=True)

        def grad_one(pp, batch_i, m_i):
            """grad of F_i wrt the DPU's plane row: microbatch gradient
            accumulation stays on the plane (eq. 7 + mini-batch mask)."""
            mask = (jnp.arange(mb) < jnp.ceil(m_i * mb)).astype(jnp.float32)

            def micro_step(carry, micro):
                loss_s, g_acc = carry
                (loss, _aux), gp = plane_grad(pp, micro, mask)
                return (loss_s + loss, g_acc + gp), None

            (loss_s, g), _ = jax.lax.scan(
                micro_step, (jnp.zeros((), jnp.float32),
                             jnp.zeros_like(pp)), batch_i)
            inv = 1.0 / n_micro
            return loss_s * inv, g * inv

        vgrad = jax.vmap(grad_one)

        def body(k, carry):
            p, acc, _ = carry
            losses, g = vgrad(p, batch, m_v)              # (n,), (n, R, LANE)
            if eta * mu > 0:
                a_k = jnp.exp((gamma_v.astype(jnp.float32) - 1.0 - k)
                              * jnp.log(1.0 - eta * mu))
            else:
                a_k = jnp.ones((n,), jnp.float32)
            active = (k < gamma_v).astype(jnp.float32)
            p_new, acc = ops.fedprox_accum_plane(
                p, g, p0, acc, a_k, active, eta, mu, backend=backend)
            return (p_new, acc, losses)

        acc0 = jnp.zeros_like(p0)
        _p_fin, acc, losses = jax.lax.fori_loop(
            0, gamma_max, body, (p0, acc0, jnp.zeros((n,), jnp.float32)))
        norm = a_l1(gamma_v, eta, mu)
        d = acc / norm[:, None, None]
        # eq. (11): fused weighted reduction + update, every replica row
        new_data = ops.nova_aggregate_plane(p0, d, w, theta * eta,
                                            backend=backend)
        metrics = {"loss": jnp.mean(losses)}
        return plane.with_data(new_data), metrics

    def round_step(params, batch, meta):
        if isinstance(params, ParamPlane):
            return round_step_plane(params, batch, meta)
        d, aux = jax.vmap(local)(params, batch, meta["gamma"],
                                 meta["m_frac"])
        w = meta["weight"].astype(jnp.float32)
        w = w / jnp.sum(w)                    # weight contract: absolute ok
        # eq. (11): the only cross-DPU reduction
        d_bar = jax.tree_util.tree_map(
            lambda x: jnp.tensordot(w.astype(x.dtype), x, axes=(0, 0)), d)
        new_params = jax.tree_util.tree_map(
            lambda p, db: (p.astype(jnp.float32)
                           - theta * eta * db.astype(jnp.float32)[None]
                           ).astype(p.dtype),
            params, d_bar)
        metrics = {"loss": jnp.mean(aux)}
        return new_params, metrics

    return round_step


def make_dpu_meta(n_dpu: int, *, gammas=None, m_fracs=None, weights=None):
    """``weights`` follow the absolute-size contract (docs/kernels.md):
    pass D_i dataset sizes; the round step normalizes once internally
    (pre-normalized weights are fine too — normalization is idempotent)."""
    gammas = jnp.asarray(gammas if gammas is not None
                         else [1] * n_dpu, jnp.int32)
    m_fracs = jnp.asarray(m_fracs if m_fracs is not None
                          else [1.0] * n_dpu, jnp.float32)
    if weights is None:
        weights = [1.0 / n_dpu] * n_dpu
    weights = jnp.asarray(weights, jnp.float32)
    return {"gamma": gammas, "m_frac": m_fracs, "weight": weights}
