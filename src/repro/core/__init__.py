# The paper's primary contribution: CE-FL — cooperative edge-assisted
# dynamic federated learning with an optimized floating aggregation point.
from repro.core import (  # noqa: F401
    aggregation, cefl, convergence, drift, estimation, fedprox, round_step,
)
from repro.core.cefl import CEFLOptions, run_cefl  # noqa: F401
from repro.core.convergence import MLConstants  # noqa: F401
from repro.core.round_step import (  # noqa: F401
    CEFLHyper, build_cefl_round_step, make_dpu_meta,
)
