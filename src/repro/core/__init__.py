# The paper's primary contribution: CE-FL — cooperative edge-assisted
# dynamic federated learning with an optimized floating aggregation point.
from repro.core import (  # noqa: F401
    aggregation, api, cefl, convergence, drift, engine, estimation, fedprox,
    round_step, strategies,
)
from repro.core.api import (  # noqa: F401
    DecisionContext, DecisionStrategy, EngineOptions, RoundPlan, RoundReport,
    RunResult, available_strategies, get_strategy, register_strategy,
)
from repro.core.cefl import CEFLOptions, run_cefl  # noqa: F401
from repro.core.convergence import MLConstants  # noqa: F401
from repro.core.engine import (  # noqa: F401
    Engine, MeshExecutor, SimExecutor, realize_offloading,
)
from repro.core.round_step import (  # noqa: F401
    CEFLHyper, build_cefl_round_step, make_dpu_meta,
)
