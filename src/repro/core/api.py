"""Typed orchestration API for CE-FL (paper Secs. II+IV-VI).

This module is the single vocabulary every orchestration layer speaks:

* :class:`RoundPlan` — the network-aware decision w^t (offloading rho,
  compute settings f/z/gamma/m, aggregator I_s, link allocations) as a
  frozen, validated dataclass instead of a magic-key dict.
* :class:`RoundReport` — what one global round produced (accuracy, mean
  local loss, energy, delay, aggregator, per-DC data placement).
* :class:`RunResult` — a whole run: the report sequence plus the final
  params, with :meth:`RunResult.to_history` providing the legacy dict
  schema the benchmarks/plots were written against.
* :class:`DecisionStrategy` — the pluggable protocol for "given the
  network and the data profile, pick w^t", with a string-keyed registry
  (:func:`register_strategy` / :func:`get_strategy`) replacing the old
  if/elif chain in ``core/cefl.py``.

The execution side (Engine + Sim/Mesh executors) lives in
``repro.core.engine``; built-in strategies in ``repro.core.strategies``.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import jax.numpy as jnp
import numpy as np

# Decision-variable keys, in the canonical order of the solver dict `w`
# (repro.network.costs docstring).
PLAN_KEYS: Tuple[str, ...] = (
    "rho_nb", "rho_bs", "f_n", "z_s", "gamma", "m",
    "I_s", "I_nb", "I_bn", "R_bs", "delta_A", "delta_R",
)


@dataclasses.dataclass
class EngineOptions:
    """Hyper-parameters of the orchestration loop (old ``CEFLOptions``)."""
    rounds: int = 20
    eta: float = 0.05
    mu: float = 0.01
    theta: Optional[float] = None   # None -> sum_i p_i gamma_i (tau_eff),
                                    # the paper's "compensating" scaling
    strategy: str = "cefl"          # any name in available_strategies()
    scenario: str = "static"        # environment dynamics preset (any name
                                    # in repro.scenario.available_scenarios)
    reoptimize_every: int = 1
    solver_outer: int = 4
    distributed_solver: bool = False   # centralized is faster for sims
    solver_backend: str = "jit"     # "jit" (batched, compiled) | "ref"
                                    # (numpy oracle, solver/ref.py)
    gamma_default: int = 2
    m_default: float = 0.5
    rate_jitter: float = 0.15
    seed: int = 0
    eval_every: int = 1             # eval cadence: eval_fn runs on rounds
                                    # t % eval_every == 0 and the last
                                    # round; off-cadence rounds carry the
                                    # last measured accuracy forward
    kernel_backend: str = "auto"    # how the plane kernel ops run: "auto"
                                    # (hardware-detected process default),
                                    # "cpu" (jitted jnp), "interpret"
                                    # (Pallas interpreter), "gpu"/"tpu"
                                    # (tiled compiled grids) — see
                                    # repro.kernels.ops
    sanitize: bool = False          # runtime sanitizer (repro.analysis):
                                    # NaN/Inf check on the aggregated
                                    # params each round + host-level PRNG
                                    # key-reuse detection across the loop.
                                    # Debug aid — adds a device sync per
                                    # round, keep off in benchmarks
    robust_agg: str = "none"        # byzantine-robust aggregation counter:
                                    # "none" (weighted eq. 11), or
                                    # "trimmed_mean" / "median" — the
                                    # UNWEIGHTED coordinate-wise robust
                                    # reduce (core.aggregation.
                                    # robust_aggregate) over the DPU stack
    trim_frac: float = 0.1          # trim fraction per side for
                                    # robust_agg="trimmed_mean" (k =
                                    # min(floor(n*frac), (n-1)//2))
    mesh_shape: Optional[Tuple[int, int]] = None
                                    # (dpu, rows) device-mesh split for the
                                    # sharded parameter plane
                                    # (repro.sharding.plane): data-parallel
                                    # over the DPU stack x FSDP rows.  None
                                    # -> single-device execution.  Needs
                                    # prod(mesh_shape) <= jax.device_count()
    cohort_size: Optional[int] = None
                                    # per-round client sampling: K UEs drawn
                                    # uniformly without replacement each
                                    # round; the others sit out (no data, no
                                    # solver rows, no cost).  None/K >= N ->
                                    # full participation.  The scale knob
                                    # for 10^4-10^6-UE populations


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """The decision w^t of one global round (executable, i.e. indicators
    already rounded to one-hot).  All leaves are jnp arrays."""
    rho_nb: jnp.ndarray      # (N, B) UE -> BS offload fractions
    rho_bs: jnp.ndarray      # (B, S) BS -> DC dispersion (rows on simplex)
    f_n: jnp.ndarray         # (N,)   UE CPU frequencies
    z_s: jnp.ndarray         # (S,)   DC per-machine processing rates
    gamma: jnp.ndarray       # (N+S,) local SGD iterations per DPU
    m: jnp.ndarray           # (N+S,) mini-batch ratios per DPU
    I_s: jnp.ndarray         # (S,)   one-hot floating-aggregator choice
    I_nb: jnp.ndarray        # (N, B) one-hot UE uplink BS association
    I_bn: jnp.ndarray        # (B, N) one-hot BS downlink association (cols)
    R_bs: jnp.ndarray        # (B, S) wired BS->DC rate allocation
    delta_A: jnp.ndarray     # ()     aggregation-phase delay budget
    delta_R: jnp.ndarray     # ()     broadcast-phase delay budget

    @classmethod
    def from_w(cls, w: Dict) -> "RoundPlan":
        """Build from a solver decision dict (extra keys ignored)."""
        missing = [k for k in PLAN_KEYS if k not in w]
        if missing:
            raise KeyError(f"decision dict missing keys {missing}")
        return cls(**{k: jnp.asarray(w[k]) for k in PLAN_KEYS})

    def to_w(self) -> Dict:
        """The solver-facing dict view (what sca/greedy/costs consume)."""
        return {k: getattr(self, k) for k in PLAN_KEYS}

    @property
    def aggregator(self) -> int:
        """Index of the floating aggregation DC (argmax of I_s)."""
        return int(np.argmax(np.asarray(self.I_s)))

    def replace(self, **updates) -> "RoundPlan":
        return dataclasses.replace(
            self, **{k: jnp.asarray(v) for k, v in updates.items()})

    def validate(self, net=None, *, atol: float = 1e-4) -> "RoundPlan":
        """Check the simplex/box/one-hot feasibility of an executable plan.

        Raises ``ValueError`` listing every violated condition; returns
        ``self`` so calls can be chained.
        """
        errs: List[str] = []
        rho_nb = np.asarray(self.rho_nb)
        rho_bs = np.asarray(self.rho_bs)
        if rho_nb.min() < -atol:
            errs.append(f"rho_nb has negative entries (min {rho_nb.min()})")
        if (rho_nb.sum(axis=1) > 1 + atol).any():
            errs.append("rho_nb row sums exceed 1 (eq. 55)")
        if rho_bs.min() < -atol:
            errs.append(f"rho_bs has negative entries (min {rho_bs.min()})")
        if np.abs(rho_bs.sum(axis=1) - 1.0).max() > atol:
            errs.append("rho_bs rows must lie on the simplex (eq. 56)")

        def _one_hot(x, axis, name):
            x = np.asarray(x)
            if np.abs(x.sum(axis=axis) - 1.0).max() > atol or \
                    np.abs(x * (1.0 - x)).max() > atol:
                errs.append(f"{name} is not one-hot (eqs. 61-62)")

        _one_hot(self.I_s, 0, "I_s")
        _one_hot(self.I_nb, 1, "I_nb")
        _one_hot(self.I_bn, 0, "I_bn")
        gamma = np.asarray(self.gamma)
        m = np.asarray(self.m)
        if (gamma <= 0).any():
            errs.append("gamma must be positive (eq. 59)")
        if (m <= 0).any() or (m > 1 + atol).any():
            errs.append("m must lie in (0, 1] (eq. 58)")
        if net is not None:
            N, B, S = net.dims
            shapes = {"rho_nb": (N, B), "rho_bs": (B, S), "f_n": (N,),
                      "z_s": (S,), "gamma": (N + S,), "m": (N + S,),
                      "I_s": (S,), "I_nb": (N, B), "I_bn": (B, N),
                      "R_bs": (B, S)}
            for k, want in shapes.items():
                got = tuple(np.asarray(getattr(self, k)).shape)
                if got != want:
                    errs.append(f"{k} shape {got} != {want} for dims "
                                f"N={N} B={B} S={S}")
            if np.asarray(self.f_n).min() < net.cfg.f_min - atol or \
                    np.asarray(self.f_n).max() > net.cfg.f_max + atol:
                errs.append("f_n outside [f_min, f_max] (eq. 57)")
        if errs:
            raise ValueError("invalid RoundPlan: " + "; ".join(errs))
        return self


@dataclasses.dataclass(frozen=True)
class RoundReport:
    """Everything one global round produced (paper Sec. II-E accounting)."""
    round: int
    acc: float               # eval_fn(params) after aggregation
    loss: float              # mean local training loss across active DPUs.
                             # SimExecutor: example-weighted mean over all
                             # gamma steps; MeshExecutor: unweighted DPU
                             # mean of the final local iteration — compare
                             # within one executor, not across the two
    energy: float            # round energy (J), eq. 44 terms c-e
    delay: float             # round delay (s), delta_A + delta_R
    cum_energy: float
    cum_delay: float
    aggregator: int          # DC index of the floating aggregation point
    dc_points: Tuple[int, ...]   # datapoints that landed at each DC
    gamma_mean: float
    m_mean: float
    plan: Optional[RoundPlan] = None
    wall_time: float = 0.0   # seconds spent in this round (train + eval)
    # --- environment dynamics (filled by the scenario subsystem) ---
    handovers: Tuple[Tuple[int, int, int], ...] = ()
                             # UE-BS re-associations this round, each
                             # (ue, old_bs, new_bs)
    aggregator_moved: bool = False
                             # floating aggregation point migrated vs the
                             # previous round's plan
    active_ues: int = -1     # UEs that contributed data (join/leave churn)


@dataclasses.dataclass
class RunResult:
    """A full orchestration run: per-round reports + final model."""
    reports: List[RoundReport]
    params: Any = None

    def __len__(self):
        return len(self.reports)

    @property
    def final(self) -> RoundReport:
        return self.reports[-1]

    def series(self, field: str) -> list:
        return [getattr(r, field) for r in self.reports]

    def to_history(self) -> Dict[str, list]:
        """Legacy ``run_cefl`` dict schema (plots/benchmarks back-compat).

        Unlike the old loop, the ``loss`` series is actually populated.
        """
        return {
            "round": self.series("round"),
            "acc": self.series("acc"),
            "loss": self.series("loss"),
            "energy": self.series("energy"),
            "delay": self.series("delay"),
            "aggregator": self.series("aggregator"),
            "cum_energy": self.series("cum_energy"),
            "cum_delay": self.series("cum_delay"),
            "dc_points": [list(r.dc_points) for r in self.reports],
            "gamma_mean": self.series("gamma_mean"),
            "m_mean": self.series("m_mean"),
        }


@dataclasses.dataclass(frozen=True)
class DecisionContext:
    """Read-only context handed to a strategy's ``decide``."""
    round: int
    consts: Any                       # core.convergence.MLConstants
    ow: Any                           # solver.objective.ObjectiveWeights
    opts: EngineOptions
    prev_plan: Optional[RoundPlan] = None   # warm start for SCA et al.


@runtime_checkable
class DecisionStrategy(Protocol):
    """Pluggable network-aware decision maker.

    Optional class attributes consumed by the Engine:
      * ``aggregation``: "cefl" (eq. 11 scaled-gradient), "fednova", or
        "fedavg" (model averaging).  Default "cefl".
      * ``proximal``: whether local training uses the FedProx mu.
        Default True.
    """

    def decide(self, net, D_bar, ctx: DecisionContext) -> RoundPlan:
        ...


_STRATEGY_REGISTRY: Dict[str, Callable[..., DecisionStrategy]] = {}


def register_strategy(name: str):
    """Class decorator: ``@register_strategy("cefl")``.  The factory is
    called with the (optional) ``:``-suffix of the spec string, e.g.
    ``"fixed:2"`` -> ``factory("2")``."""
    if ":" in name:
        raise ValueError(f"strategy name {name!r} must not contain ':'")

    def deco(factory):
        if name in _STRATEGY_REGISTRY:
            raise ValueError(f"strategy {name!r} already registered")
        _STRATEGY_REGISTRY[name] = factory
        return factory
    return deco


def available_strategies() -> List[str]:
    return sorted(_STRATEGY_REGISTRY)


def get_strategy(spec) -> DecisionStrategy:
    """Resolve ``"name"`` / ``"name:arg"`` / a strategy instance."""
    if not isinstance(spec, str):
        return spec
    name, _, arg = spec.partition(":")
    try:
        factory = _STRATEGY_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; available: "
            f"{available_strategies()}") from None
    return factory(arg) if arg else factory()


RoundCallback = Callable[[RoundReport], Optional[bool]]


def weighted_mean(values: Sequence[float], weights: Sequence[float]) -> float:
    w = np.asarray(weights, float)
    if w.sum() <= 0:
        return float("nan")
    return float(np.sum(np.asarray(values, float) * w) / w.sum())
