"""Scenario protocol + registry: per-round evolution of the CE-FL world.

The paper's environment is *dynamic* (Sec. III): users move, UE-BS
associations change hands, server-mesh links churn, and the local data
distributions drift — that is the regime where the floating aggregation
point earns its keep.  A :class:`Scenario` owns exactly that evolution:
each round it advances the network (a fresh ``Network`` with re-derived
rates / associations, same dims+cfg so the jitted solver never retraces)
and the data (per-UE round datasets after drift schedules), and reports
what happened as :class:`ScenarioEvents` so ``RoundReport`` can record
handovers and aggregation-point migrations.

Scenarios are registered by name (``register_scenario`` /
``get_scenario``), mirroring the strategy registry in ``core/api.py``:
``Engine(net, "cefl", scenario="campus_walk")`` or
``EngineOptions(scenario="vehicular")``.  See ``scenario/presets.py`` for
the built-ins and docs/scenarios.md for the full story.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Protocol, Sequence, Tuple, \
    runtime_checkable


@dataclasses.dataclass(frozen=True)
class ScenarioEvents:
    """What the environment did this round (consumed by ``RoundReport``
    and, for the adversarial channels, by the executors / cost model)."""
    round: int
    handovers: Tuple[Tuple[int, int, int], ...] = ()  # (ue, old_bs, new_bs)
    joined: Tuple[int, ...] = ()                      # UEs back online
    left: Tuple[int, ...] = ()                        # UEs gone offline
    mesh_down: Tuple[Tuple[int, int], ...] = ()       # DC-DC links in outage
    active_ues: int = -1
    # adversary channels (scenario/adversary.py): update corruptions the
    # executor applies between local training and aggregation, and the
    # per-UE realized compute-rate scaling finish_round charges through
    # the cost model (empty tuples = clean round)
    corrupted: Tuple[Tuple[int, str, float], ...] = ()  # (ue, mode, scale)
    compute_scale: Tuple[float, ...] = ()               # (N,) f_n scaling


@runtime_checkable
class Scenario(Protocol):
    """Pluggable environment dynamics.

    ``bind`` attaches the scenario to a base network + engine options and
    resets all internal state (so one instance can drive repeated runs
    deterministically); ``step`` advances one global round and returns
    ``(net_t, data_per_ue, events)``.  ``step`` must call ``ds.step()`` on
    every online dataset exactly once per round (datasets own their PRNG
    streams) and draw any scenario randomness from the passed ``rng`` —
    the engine's seeded ``RandomState`` — so a run is a pure function of
    the seed.
    """

    def bind(self, net, opts) -> None:
        ...

    def step(self, t: int, online_datasets: Sequence, rng):
        ...


_SCENARIO_REGISTRY: Dict[str, Callable[..., Scenario]] = {}


def register_scenario(name: str):
    """Class/function decorator: ``@register_scenario("campus_walk")``.
    The factory is called with the optional ``:``-suffix of the spec
    string, e.g. ``"campus_walk:fast"`` -> ``factory("fast")``."""
    if ":" in name:
        raise ValueError(f"scenario name {name!r} must not contain ':'")

    def deco(factory):
        if name in _SCENARIO_REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        _SCENARIO_REGISTRY[name] = factory
        return factory
    return deco


def available_scenarios() -> List[str]:
    return sorted(_SCENARIO_REGISTRY)


def get_scenario(spec) -> Scenario:
    """Resolve ``"name"`` / ``"name:arg"`` / a scenario instance."""
    if not isinstance(spec, str):
        return spec
    name, _, arg = spec.partition(":")
    try:
        factory = _SCENARIO_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: "
            f"{available_scenarios()}") from None
    return factory(arg) if arg else factory()


@register_scenario("static")
class StaticScenario:
    """The frozen pre-scenario world: per-round lognormal rate jitter
    (``Network.resample_rates``) and untouched online datasets.  This is
    the engine default and reproduces the legacy ``Engine.run`` behavior
    bit-for-bit (same rng draw order)."""

    def __init__(self, jitter=""):
        self._jitter_arg = float(jitter) if jitter != "" else None
        self._net = None
        self._jitter = None

    def bind(self, net, opts):
        self._net = net
        self._jitter = self._jitter_arg if self._jitter_arg is not None \
            else getattr(opts, "rate_jitter", 0.15)

    def step(self, t, online_datasets, rng):
        data = [ds.step() for ds in online_datasets]
        net_t = self._net.resample_rates(rng, self._jitter)
        return net_t, data, ScenarioEvents(round=t,
                                           active_ues=len(online_datasets))

    # full-state resume: the static world keeps no mutable state beyond
    # what bind() derives; the jitter draws live on the engine rng
    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, d: dict) -> None:
        pass
