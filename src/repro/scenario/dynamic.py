"""DynamicScenario: the full per-round world evolution (paper Sec. III).

Each global round, in a fixed order (so the run is a pure function of the
engine seed):

  1. drift schedules transform the per-UE round data (label rotation,
     arrival bursts, UE join/leave),
  2. the mobility model advances UE positions on the 2-D field,
  3. UE<->BS channel gains are re-derived from the new distances
     (path loss x squared-Rayleigh fading) and pushed through the
     eq. 12-13 Shannon model into fresh ``R_nb`` / ``R_bn``,
  4. UE-BS serving associations are re-evaluated with a handover
     hysteresis margin on the mean (path-loss-only) channel; handovers
     update ``subnet_of_ue`` and the consensus-graph UE rows,
  5. the DC server mesh churns: each DC-DC link is independently in
     outage with ``mesh_outage_p`` (rate x ``mesh_outage_factor``, edge
     dropped from the consensus graph, ring connectivity preserved), and
     the wired rates get the usual lognormal congestion jitter.

The evolved network is a plain ``Network`` with *identical cfg and dims*
— downstream, ``sca.solve`` wraps it in the PR-3 ``NetView`` pytree whose
rate arrays are traced arguments, so a dynamic run re-solves every round
without a single retrace (asserted in tests/test_scenario.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.network.topology import Network, pathloss_gain, shannon_rate
from repro.scenario.base import ScenarioEvents
from repro.scenario.mobility import (FieldLayout, MobilityModel,
                                     layout_from_network)


def _components(adj: np.ndarray):
    """Connected components of a symmetric 0/1 adjacency matrix, as lists
    of node indices in ascending order (deterministic)."""
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    comps = []
    for start in range(n):
        if seen[start]:
            continue
        stack, members = [start], []
        seen[start] = True
        while stack:
            u = stack.pop()
            members.append(u)
            for v in np.nonzero(adj[u])[0]:
                if not seen[v]:
                    seen[v] = True
                    stack.append(int(v))
        comps.append(sorted(members))
    return comps


@dataclasses.dataclass
class DynamicScenario:
    """Mobility + network evolution + drift schedules, composed.

    ``mobility=None`` keeps the radio plane static (legacy lognormal
    jitter) while drift schedules still run — the ``label_shift`` /
    pure-data presets.
    """
    mobility: Optional[MobilityModel] = None
    schedules: Sequence = ()
    area: float = 2000.0
    dt: float = 60.0                   # seconds of motion per global round
    handover_margin_db: float = 2.0
    mesh_outage_p: float = 0.0
    mesh_outage_factor: float = 1e-3
    wired_jitter: float = 0.1
    radio_jitter: Optional[float] = None   # static-radio (mobility=None)
                                           # jitter; None -> the engine's
                                           # EngineOptions.rate_jitter

    def __post_init__(self):
        self._net0: Optional[Network] = None
        self._layout: Optional[FieldLayout] = None
        self._serving: Optional[np.ndarray] = None
        self._radio_jitter = 0.15

    # ------------------------------------------------------------ bind --

    def bind(self, net, opts):
        self._net0 = net
        # resolved fresh on every bind: the configured value stays None,
        # so rebinding to different EngineOptions tracks their rate_jitter
        self._radio_jitter = self.radio_jitter if self.radio_jitter \
            is not None else getattr(opts, "rate_jitter", 0.15)
        self._layout = None
        self._serving = None
        for sch in self.schedules:
            if hasattr(sch, "reset"):
                sch.reset(net.cfg.num_ue)

    # ------------------------------------------------------------ state --

    @property
    def layout(self) -> Optional[FieldLayout]:
        return self._layout

    @property
    def serving_bs(self) -> Optional[np.ndarray]:
        """(N,) index of each UE's current serving BS (None until round 0;
        the association trace the determinism tests pin)."""
        return self._serving

    def _ensure_initialized(self, rng):
        # spatial state exists only when mobility drives the radio plane;
        # with mobility=None the base network's rates/associations stand
        if self.mobility is None or self._layout is not None:
            return
        net = self._net0
        self._layout = layout_from_network(net, rng, self.area)
        self.mobility.init(rng, self._layout.ue_pos, self.area)
        d = self._distances()
        self._serving = np.argmax(pathloss_gain(d), axis=1)

    def _distances(self) -> np.ndarray:
        lay = self._layout
        return np.linalg.norm(
            lay.ue_pos[:, None, :] - lay.bs_pos[None, :, :], axis=-1)

    # ------------------------------------------- full-state resume ------

    def state_dict(self) -> dict:
        """Spatial + association + schedule state for mid-run resume
        (``repro.experiments.runstate``).  The engine rng is NOT here —
        it lives on the engine's LoopState; restoring both reproduces the
        remaining rounds bit-exactly."""
        out = {"initialized": int(self._layout is not None)}
        if self._layout is not None:
            lay = self._layout
            out["layout"] = {"area": float(lay.area),
                             "dc_pos": np.asarray(lay.dc_pos),
                             "bs_pos": np.asarray(lay.bs_pos),
                             "ue_pos": np.asarray(lay.ue_pos)}
            out["serving"] = np.asarray(self._serving)
        if self.mobility is not None:
            out["mobility"] = self.mobility.state_dict()
        out["schedules"] = {
            str(i): sch.state_dict()
            for i, sch in enumerate(self.schedules)
            if hasattr(sch, "state_dict")}
        return out

    def load_state_dict(self, d: dict) -> None:
        if int(d["initialized"]):
            lay = d["layout"]
            self._layout = FieldLayout(
                area=float(lay["area"]), dc_pos=np.asarray(lay["dc_pos"]),
                bs_pos=np.asarray(lay["bs_pos"]),
                ue_pos=np.asarray(lay["ue_pos"]))
            self._serving = np.asarray(d["serving"])
        else:
            self._layout = None
            self._serving = None
        if self.mobility is not None and "mobility" in d:
            self.mobility.load_state_dict(d["mobility"])
        for i, sch in enumerate(self.schedules):
            if hasattr(sch, "load_state_dict") and str(i) in d["schedules"]:
                sch.load_state_dict(d["schedules"][str(i)])

    # ------------------------------------------------------------- step --

    def step(self, t, online_datasets, rng):
        net = self._net0
        N, B, S = net.dims
        self._ensure_initialized(rng)

        # 1. data: advance every online stream, then compose the drift
        # schedules in UE order (offline UEs still step — deterministic
        # rejoin trajectories)
        for sch in self.schedules:
            if hasattr(sch, "begin_round"):
                sch.begin_round(t, N, rng)
        data = []
        for ue, ds in enumerate(online_datasets):
            d = ds.step()
            for sch in self.schedules:
                d = sch.apply(t, ue, d, rng)
            data.append(d)
        joined, left = (), ()
        for sch in self.schedules:
            if hasattr(sch, "events"):
                j, l_ = sch.events()
                joined, left = joined + tuple(j), left + tuple(l_)

        # adversary channels (scenario/adversary.py): update corruptions
        # for the executor, multiplicative per-UE compute-rate scaling
        # for the cost model
        corrupted = ()
        for sch in self.schedules:
            if hasattr(sch, "corrupted"):
                corrupted = corrupted + tuple(sch.corrupted(t))
        scale = None
        for sch in self.schedules:
            if hasattr(sch, "compute_scale"):
                s = np.asarray(sch.compute_scale(t, N), float)
                scale = s if scale is None else scale * s

        # 2.-4. radio plane
        if self.mobility is not None:
            self._layout.ue_pos = self.mobility.step(
                t, rng, self._layout.ue_pos, self.area, self.dt)
            d = self._distances()
            mean_gain = pathloss_gain(d)
            fade_up = rng.rayleigh(1.0, (N, B)) ** 2
            fade_dn = rng.rayleigh(1.0, (B, N)) ** 2
            cfg = net.cfg
            R_nb = shannon_rate(cfg.bandwidth_hz, cfg.ue_tx_power,
                                mean_gain * fade_up, cfg.noise_density)
            R_bn = shannon_rate(cfg.bandwidth_hz, cfg.bs_tx_power,
                                mean_gain.T * fade_dn, cfg.noise_density)
            handovers, subnet_of_ue = self._handover(mean_gain)
        else:
            jit = np.exp(rng.normal(0.0, self._radio_jitter,
                                    net.R_nb.shape))
            R_nb = net.R_nb * jit
            R_bn = net.R_bn * np.exp(rng.normal(0.0, self._radio_jitter,
                                                net.R_bn.shape))
            handovers, subnet_of_ue = (), np.asarray(net.subnet_of_ue)

        # 5. wired plane: congestion jitter + mesh link churn
        wjit = lambda x: x * np.exp(  # noqa: E731
            rng.normal(0.0, self.wired_jitter, x.shape))
        R_ss = wjit(np.asarray(net.R_ss, float).copy())
        R_sb = wjit(np.asarray(net.R_sb, float).copy())
        outage = np.zeros((S, S), bool)
        if self.mesh_outage_p > 0.0 and S > 1:
            up = np.triu(rng.uniform(0.0, 1.0, (S, S))
                         < self.mesh_outage_p, 1)
            outage = up | up.T
            R_ss = np.where(outage, R_ss * self.mesh_outage_factor, R_ss)
        adjacency = self._rebuild_adjacency(subnet_of_ue, outage)
        mesh_down = tuple((int(i), int(j)) for i, j in
                          zip(*np.nonzero(np.triu(outage, 1))))

        net_t = dataclasses.replace(
            net, R_nb=R_nb, R_bn=R_bn, R_ss=R_ss, R_sb=R_sb,
            subnet_of_ue=subnet_of_ue, adjacency=adjacency)
        active = sum(1 for d in data if len(d["y"]))
        events = ScenarioEvents(
            round=t, handovers=handovers, joined=joined, left=left,
            mesh_down=mesh_down, active_ues=active,
            corrupted=tuple(sorted(corrupted)),
            compute_scale=() if scale is None
            else tuple(float(x) for x in scale))
        return net_t, data, events

    # -------------------------------------------------------- internals --

    def _handover(self, mean_gain) -> Tuple[tuple, np.ndarray]:
        """Hysteresis handover on the mean channel: switch serving BS only
        when the best candidate beats the current one by the margin."""
        net = self._net0
        N = mean_gain.shape[0]
        margin = 10.0 ** (self.handover_margin_db / 10.0)
        best = np.argmax(mean_gain, axis=1)
        cur_gain = mean_gain[np.arange(N), self._serving]
        switch = mean_gain[np.arange(N), best] > cur_gain * margin
        switch &= best != self._serving
        handovers = tuple(
            (int(n), int(self._serving[n]), int(best[n]))
            for n in np.nonzero(switch)[0])
        self._serving = np.where(switch, best, self._serving)
        subnet_of_ue = np.asarray(net.subnet_of_bs)[self._serving]
        return handovers, subnet_of_ue

    def _rebuild_adjacency(self, subnet_of_ue, outage) -> np.ndarray:
        """Consensus graph tracking the physical evolution: each UE's BS
        edge follows its serving BS (mobility scenarios only — with a
        static radio plane the base graph stands), and DC-DC edges drop
        during outages with the surviving components re-linked so the
        mesh stays connected (App. G-C guarantees)."""
        net = self._net0
        N, B, S = net.dims
        A = np.array(net.adjacency, dtype=int, copy=True)
        if self.mobility is not None and self._serving is not None:
            A[:N, N:N + B] = 0
            A[N:N + B, :N] = 0
            for n in range(N):
                b = N + int(self._serving[n])
                A[n, b] = A[b, n] = 1
        if outage.any():
            dc = slice(N + B, N + B + S)
            A_dc = A[dc, dc] & ~outage.astype(int)
            np.fill_diagonal(A_dc, 0)
            # repair connectivity: chain the connected components together
            # (degree >= 1 alone is not enough — the mesh can split into
            # pairs), so consensus (Alg. 3) always has a connected graph
            comp = _components(A_dc)
            reps = [members[0] for members in comp]
            for r1, r2 in zip(reps, reps[1:]):
                A_dc[r1, r2] = A_dc[r2, r1] = 1
            A[dc, dc] = A_dc
        return A
