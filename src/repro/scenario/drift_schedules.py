"""Composable data-drift schedules (paper Definition 1 made concrete).

A schedule transforms the per-UE round dataset *after* ``OnlineDataset``
produced it, so arbitrary drift processes compose over the existing online
data model without touching it:

* :class:`LabelRotation` — periodic label-space rotation (concept drift:
  the y|x mapping shifts every ``period`` rounds).
* :class:`ArrivalBurst` — multiplies a window's arrival volume (flash
  crowd / lull; resampling with replacement, rng-driven).
* :class:`JoinLeave` — a per-UE on/off Markov process; offline UEs
  contribute an empty round dataset (their ``OnlineDataset`` still steps,
  so rejoin trajectories stay deterministic).

Schedules implement ``apply(t, ue, data, rng) -> data`` and optionally
``begin_round(t, n_ue, rng)`` (once per round, before any ``apply``) and
``events() -> (joined, left)``.  ``DynamicScenario`` threads them in UE
order every round, so rng consumption — and therefore the whole run — is
a pure function of the engine seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


def _as_np(data):
    return np.asarray(data["x"]), np.asarray(data["y"])


def empty_like(data) -> dict:
    """A zero-example round dataset with the same feature shape/dtypes."""
    x, y = _as_np(data)
    return {"x": x[:0], "y": y[:0]}


@dataclasses.dataclass
class LabelRotation:
    """Rotate labels by ``shift`` classes every ``period`` rounds."""
    period: int = 5
    shift: int = 1
    num_classes: int = 10

    def apply(self, t, ue, data, rng):
        k = (t // max(self.period, 1)) * self.shift % self.num_classes
        if k == 0 or len(data["y"]) == 0:
            return data
        x, y = _as_np(data)
        return {"x": x, "y": (y + k) % self.num_classes}

    # stateless: the rotation is a pure function of the round index
    def state_dict(self):
        return {}

    def load_state_dict(self, d):
        pass


@dataclasses.dataclass
class ArrivalBurst:
    """Scale arrival volume by ``factor`` for rounds in [start, start+length)
    on the UEs in ``ues`` (None = all).  factor > 1 resamples up with
    replacement (a burst), factor < 1 subsamples (a lull)."""
    start: int = 0
    length: int = 0
    factor: float = 3.0
    ues: Optional[Tuple[int, ...]] = None

    def apply(self, t, ue, data, rng):
        if not (self.start <= t < self.start + self.length):
            return data
        if self.ues is not None and ue not in self.ues:
            return data
        x, y = _as_np(data)
        D = len(y)
        if D == 0:
            return data
        n = int(round(D * self.factor))
        if self.factor > 0.0:
            n = max(1, n)           # a lull never silences a UE entirely
        if n == 0:
            return empty_like(data)  # factor=0: a true zero-arrival window
        idx = rng.choice(D, size=n, replace=True) if n > D \
            else rng.permutation(D)[:n]
        return {"x": x[idx], "y": y[idx]}

    # stateless: window membership is a pure function of the round index
    def state_dict(self):
        return {}

    def load_state_dict(self, d):
        pass


@dataclasses.dataclass
class JoinLeave:
    """Per-UE availability churn: online UEs drop with prob ``p_leave``,
    offline UEs return with prob ``p_return``; never fewer than
    ``min_active`` stay online."""
    p_leave: float = 0.12
    p_return: float = 0.45
    min_active: int = 2

    def __post_init__(self):
        self._active = None
        self._joined: Tuple[int, ...] = ()
        self._left: Tuple[int, ...] = ()

    def reset(self, n_ue: int):
        self._active = np.ones(n_ue, bool)
        self._joined, self._left = (), ()

    def begin_round(self, t, n_ue, rng):
        if self._active is None or len(self._active) != n_ue:
            self.reset(n_ue)
        draws = rng.uniform(0.0, 1.0, n_ue)
        joined, left = [], []
        for ue in range(n_ue):
            if self._active[ue]:
                if draws[ue] < self.p_leave \
                        and int(self._active.sum()) > self.min_active:
                    self._active[ue] = False
                    left.append(ue)
            elif draws[ue] < self.p_return:
                self._active[ue] = True
                joined.append(ue)
        self._joined, self._left = tuple(joined), tuple(left)

    def events(self):
        return self._joined, self._left

    def state_dict(self):
        if self._active is None:
            return {"initialized": 0}
        # copy: ``begin_round`` mutates ``_active`` in place, and a
        # snapshot must not alias live state
        return {"initialized": 1, "active": np.array(self._active, bool),
                "joined": np.asarray(self._joined, np.int64),
                "left": np.asarray(self._left, np.int64)}

    def load_state_dict(self, d):
        if not int(d["initialized"]):
            self._active = None
            self._joined, self._left = (), ()
            return
        self._active = np.array(d["active"], bool)
        self._joined = tuple(int(u) for u in np.asarray(d["joined"]))
        self._left = tuple(int(u) for u in np.asarray(d["left"]))

    def apply(self, t, ue, data, rng):
        if self._active is not None and not self._active[ue]:
            return empty_like(data)
        return data
