"""Named scenario presets (the registry's built-ins).

Mirrors the strategy presets in ``core/strategies.py``: each name maps to
a configured :class:`~repro.scenario.dynamic.DynamicScenario` (``static``
lives in ``scenario/base.py``).  Paper touchstones: ``campus_walk`` and
``vehicular`` realize the Sec. III mobility-driven network evolution at
pedestrian / vehicular timescales, ``flash_crowd`` the spatial+volume
burst, ``label_shift`` pure concept drift (Definition 1), and ``churn``
device availability dynamics.  docs/scenarios.md tabulates all of them.
"""
from __future__ import annotations

import numpy as np

from repro.scenario.adversary import (ByzantineUpdate, Dropout, LabelPoison,
                                      Straggler)
from repro.scenario.base import register_scenario
from repro.scenario.drift_schedules import (ArrivalBurst, JoinLeave,
                                            LabelRotation)
from repro.scenario.dynamic import DynamicScenario
from repro.scenario.mobility import GaussMarkov, RandomWaypoint


@register_scenario("campus_walk")
def campus_walk(arg: str = "") -> DynamicScenario:
    """Pedestrians on a campus: random-waypoint walking speeds, one-minute
    rounds, light mesh churn.  ``campus_walk:fast`` doubles the motion per
    round (shorter demo runs still see handovers)."""
    dt = 120.0 if arg == "fast" else 60.0
    return DynamicScenario(
        mobility=RandomWaypoint(speed=(0.8, 2.0)),
        area=1500.0, dt=dt, handover_margin_db=2.0,
        mesh_outage_p=0.02, wired_jitter=0.1)


@register_scenario("vehicular")
def vehicular(arg: str = "") -> DynamicScenario:
    """Vehicles on an urban grid: Gauss-Markov velocities around 18 m/s,
    half-minute rounds (~500 m of motion each), aggressive handover,
    noticeable mesh churn."""
    return DynamicScenario(
        mobility=GaussMarkov(mean_speed=18.0, alpha=0.75, sigma=5.0),
        area=2500.0, dt=30.0, handover_margin_db=1.0,
        mesh_outage_p=0.05, wired_jitter=0.15)


@register_scenario("flash_crowd")
def flash_crowd(arg: str = "") -> DynamicScenario:
    """A crowd converges on a hotspot in rounds 5-12 while its arrival
    volume triples: the floating aggregator has to chase the data."""
    return DynamicScenario(
        mobility=RandomWaypoint(speed=(1.0, 3.0), attractor=(0.82, 0.5),
                                attract_rounds=(5, 12)),
        schedules=(ArrivalBurst(start=5, length=7, factor=3.0),),
        area=1500.0, dt=90.0, handover_margin_db=2.0,
        mesh_outage_p=0.02, wired_jitter=0.1)


@register_scenario("label_shift")
def label_shift(arg: str = "") -> DynamicScenario:
    """Pure concept drift: static radio plane, labels rotate one class
    every ``period`` rounds (``label_shift:<period>``)."""
    period = int(arg) if arg else 4
    return DynamicScenario(
        mobility=None,
        schedules=(LabelRotation(period=period, shift=1),),
        wired_jitter=0.1)


@register_scenario("churn")
def churn(arg: str = "") -> DynamicScenario:
    """Device availability churn on top of slow pedestrian drift: UEs
    leave/rejoin round to round (their data streams keep evolving while
    offline)."""
    return DynamicScenario(
        mobility=RandomWaypoint(speed=(0.3, 1.0)),
        schedules=(JoinLeave(p_leave=0.15, p_return=0.45, min_active=2),),
        area=1500.0, dt=60.0, handover_margin_db=3.0,
        mesh_outage_p=0.03, wired_jitter=0.1)


# ------------------------------------------------- adversarial presets --

@register_scenario("byzantine")
def byzantine(arg: str = "") -> DynamicScenario:
    """Sign-flip byzantine UEs on a static radio plane:
    ``byzantine:<frac>`` compromises ``round(frac * N)`` evenly spaced
    UEs (default 0.2; ``byzantine:0`` is the clean twin with identical
    rng consumption, the acceptance-test baseline).  Pair with
    ``EngineOptions(robust_agg="trimmed_mean")`` to defend."""
    frac = float(arg) if arg else 0.2
    return DynamicScenario(
        mobility=None,
        schedules=(ByzantineUpdate(mode="sign_flip", frac=frac,
                                   scale=4.0),),
        wired_jitter=0.1)


@register_scenario("poisoned")
def poisoned(arg: str = "") -> DynamicScenario:
    """Label-flipping data poisoning (``poisoned:<frac>``, default 0.3)
    on a static radio plane: compromised UEs train on y -> C-1-y."""
    frac = float(arg) if arg else 0.3
    return DynamicScenario(
        mobility=None,
        schedules=(LabelPoison(frac=frac),),
        wired_jitter=0.1)


@register_scenario("stragglers")
def stragglers(arg: str = "") -> DynamicScenario:
    """Straggler-dominated edge: 30% of UEs compute at
    ``f_n / slowdown`` (``stragglers:<slowdown>``, default 4x) and every
    UE hard-drops i.i.d. with p=0.1, over slow pedestrian drift."""
    slowdown = float(arg) if arg else 4.0
    return DynamicScenario(
        mobility=RandomWaypoint(speed=(0.3, 1.0)),
        schedules=(Straggler(frac=0.3, slowdown=slowdown),
                   Dropout(p=0.1, min_active=1)),
        area=1500.0, dt=60.0, handover_margin_db=3.0,
        mesh_outage_p=0.02, wired_jitter=0.1)


@register_scenario("fuzzmix")
def fuzzmix(arg: str = "") -> DynamicScenario:
    """A randomly composed scenario — mobility x channel x drift x
    adversary — fully determined by the integer arg (``fuzzmix:<seed>``).
    This is the fuzzer's composition axis: because the draw seed IS the
    scenario spec string, any failing composition replays through a
    plain ExperimentSpec."""
    rng = np.random.RandomState(int(arg) if arg else 0)
    mobility = [
        None,
        RandomWaypoint(speed=(0.5, 2.0)),
        GaussMarkov(mean_speed=12.0, alpha=0.7, sigma=4.0),
    ][rng.randint(3)]
    pool = [
        lambda: LabelRotation(period=int(rng.randint(2, 6)),
                              shift=int(rng.randint(1, 12))),
        lambda: ArrivalBurst(start=int(rng.randint(0, 3)),
                             length=int(rng.randint(1, 4)),
                             factor=float(rng.uniform(0.5, 3.0))),
        lambda: JoinLeave(p_leave=float(rng.uniform(0.05, 0.25)),
                          p_return=float(rng.uniform(0.3, 0.7)),
                          min_active=2),
        lambda: ByzantineUpdate(
            mode=("sign_flip", "gauss")[rng.randint(2)],
            frac=float(rng.uniform(0.1, 0.35)),
            scale=float(rng.uniform(1.0, 6.0))),
        lambda: LabelPoison(frac=float(rng.uniform(0.1, 0.4))),
        lambda: Straggler(frac=float(rng.uniform(0.1, 0.5)),
                          slowdown=float(rng.uniform(2.0, 8.0))),
        lambda: Dropout(p=float(rng.uniform(0.05, 0.25)), min_active=1),
    ]
    picks = sorted(rng.choice(len(pool), size=rng.randint(1, 4),
                              replace=False))
    schedules = tuple(pool[i]() for i in picks)
    return DynamicScenario(
        mobility=mobility, schedules=schedules,
        area=float(rng.uniform(1000.0, 2500.0)),
        dt=float(rng.uniform(30.0, 120.0)),
        handover_margin_db=float(rng.uniform(1.0, 3.0)),
        mesh_outage_p=float(rng.uniform(0.0, 0.08)),
        wired_jitter=float(rng.uniform(0.05, 0.2)))
