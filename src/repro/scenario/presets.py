"""Named scenario presets (the registry's built-ins).

Mirrors the strategy presets in ``core/strategies.py``: each name maps to
a configured :class:`~repro.scenario.dynamic.DynamicScenario` (``static``
lives in ``scenario/base.py``).  Paper touchstones: ``campus_walk`` and
``vehicular`` realize the Sec. III mobility-driven network evolution at
pedestrian / vehicular timescales, ``flash_crowd`` the spatial+volume
burst, ``label_shift`` pure concept drift (Definition 1), and ``churn``
device availability dynamics.  docs/scenarios.md tabulates all of them.
"""
from __future__ import annotations

from repro.scenario.base import register_scenario
from repro.scenario.drift_schedules import (ArrivalBurst, JoinLeave,
                                            LabelRotation)
from repro.scenario.dynamic import DynamicScenario
from repro.scenario.mobility import GaussMarkov, RandomWaypoint


@register_scenario("campus_walk")
def campus_walk(arg: str = "") -> DynamicScenario:
    """Pedestrians on a campus: random-waypoint walking speeds, one-minute
    rounds, light mesh churn.  ``campus_walk:fast`` doubles the motion per
    round (shorter demo runs still see handovers)."""
    dt = 120.0 if arg == "fast" else 60.0
    return DynamicScenario(
        mobility=RandomWaypoint(speed=(0.8, 2.0)),
        area=1500.0, dt=dt, handover_margin_db=2.0,
        mesh_outage_p=0.02, wired_jitter=0.1)


@register_scenario("vehicular")
def vehicular(arg: str = "") -> DynamicScenario:
    """Vehicles on an urban grid: Gauss-Markov velocities around 18 m/s,
    half-minute rounds (~500 m of motion each), aggressive handover,
    noticeable mesh churn."""
    return DynamicScenario(
        mobility=GaussMarkov(mean_speed=18.0, alpha=0.75, sigma=5.0),
        area=2500.0, dt=30.0, handover_margin_db=1.0,
        mesh_outage_p=0.05, wired_jitter=0.15)


@register_scenario("flash_crowd")
def flash_crowd(arg: str = "") -> DynamicScenario:
    """A crowd converges on a hotspot in rounds 5-12 while its arrival
    volume triples: the floating aggregator has to chase the data."""
    return DynamicScenario(
        mobility=RandomWaypoint(speed=(1.0, 3.0), attractor=(0.82, 0.5),
                                attract_rounds=(5, 12)),
        schedules=(ArrivalBurst(start=5, length=7, factor=3.0),),
        area=1500.0, dt=90.0, handover_margin_db=2.0,
        mesh_outage_p=0.02, wired_jitter=0.1)


@register_scenario("label_shift")
def label_shift(arg: str = "") -> DynamicScenario:
    """Pure concept drift: static radio plane, labels rotate one class
    every ``period`` rounds (``label_shift:<period>``)."""
    period = int(arg) if arg else 4
    return DynamicScenario(
        mobility=None,
        schedules=(LabelRotation(period=period, shift=1),),
        wired_jitter=0.1)


@register_scenario("churn")
def churn(arg: str = "") -> DynamicScenario:
    """Device availability churn on top of slow pedestrian drift: UEs
    leave/rejoin round to round (their data streams keep evolving while
    offline)."""
    return DynamicScenario(
        mobility=RandomWaypoint(speed=(0.3, 1.0)),
        schedules=(JoinLeave(p_leave=0.15, p_return=0.45, min_active=2),),
        area=1500.0, dt=60.0, handover_margin_db=3.0,
        mesh_outage_p=0.03, wired_jitter=0.1)
