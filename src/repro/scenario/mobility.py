"""Geometry + UE mobility models for the dynamic scenarios.

``layout_from_network`` drops the abstract three-tier topology onto a 2-D
field (DCs on a ring, BSs clustered around their anchor DC, UEs around a
home BS of their subnetwork — the App. F-D subnetwork structure made
spatial).  Mobility models then advance UE positions each round:

* :class:`RandomWaypoint` — pick a waypoint uniformly in the field, walk
  toward it at a per-leg speed, pause, repeat (the classic pedestrian
  model; an optional *attractor* window pins waypoints to a hotspot for
  flash-crowd scenarios).
* :class:`GaussMarkov` — temporally correlated velocity process
  ``v_t = a v_{t-1} + (1-a) v_mean + sqrt(1-a^2) sigma w_t`` with boundary
  reflection (vehicular motion: smooth headings, no ping-pong).

All state lives in plain numpy arrays and every draw comes from the rng
handed in by the scenario, so trajectories are a pure function of the
engine seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class FieldLayout:
    """Positions (meters) of every node on the [0, area]^2 field."""
    area: float
    dc_pos: np.ndarray    # (S, 2)
    bs_pos: np.ndarray    # (B, 2)
    ue_pos: np.ndarray    # (N, 2)


def layout_from_network(net, rng, area: float = 2000.0) -> FieldLayout:
    """Spatialize a ``Network``: DC anchors on a ring, BSs near their DC,
    UEs near a random BS of their home subnetwork."""
    N, B, S = net.dims
    ang = 2.0 * np.pi * np.arange(S) / max(S, 1)
    dc_pos = area * (0.5 + 0.32 * np.stack([np.cos(ang), np.sin(ang)], 1))
    bs_pos = dc_pos[np.asarray(net.subnet_of_bs)] \
        + rng.uniform(-0.12, 0.12, (B, 2)) * area
    ue_pos = np.zeros((N, 2))
    for n in range(N):
        cands = np.nonzero(np.asarray(net.subnet_of_bs)
                           == net.subnet_of_ue[n])[0]
        home = int(rng.choice(cands)) if len(cands) else int(rng.choice(B))
        ue_pos[n] = bs_pos[home] + rng.uniform(-0.07, 0.07, 2) * area
    clip = lambda p: np.clip(p, 0.0, area)  # noqa: E731
    return FieldLayout(area=area, dc_pos=clip(dc_pos), bs_pos=clip(bs_pos),
                       ue_pos=clip(ue_pos))


class MobilityModel:
    """Base: ``init(rng, pos, area)`` seeds per-UE state, ``step(t, rng,
    pos, area, dt)`` returns the positions after ``dt`` seconds."""

    def init(self, rng, pos: np.ndarray, area: float) -> None:
        raise NotImplementedError

    def step(self, t: int, rng, pos: np.ndarray, area: float,
             dt: float) -> np.ndarray:
        raise NotImplementedError

    # full-state resume hooks (repro.experiments.runstate): models carry
    # only numpy arrays, so the default covers every built-in
    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, d: dict) -> None:
        pass


class RandomWaypoint(MobilityModel):
    """Random-waypoint mobility with optional hotspot attraction.

    ``speed`` is the (lo, hi) m/s range drawn per leg; while ``t`` lies in
    ``attract_rounds`` every new waypoint is the hotspot
    (``attractor`` in [0,1]^2 field fractions) plus a small scatter —
    the flash-crowd ingredient.
    """

    def __init__(self, speed: Tuple[float, float] = (0.8, 2.0),
                 pause: float = 0.0,
                 attractor: Optional[Tuple[float, float]] = None,
                 attract_rounds: Tuple[int, int] = (0, 0)):
        self.speed = speed
        self.pause = pause
        self.attractor = attractor
        self.attract_rounds = attract_rounds
        self._wp = None
        self._v = None
        self._pause_left = None

    def _new_leg(self, t, rng, n, area):
        lo, hi = self.attract_rounds
        if self.attractor is not None and lo <= t < hi:
            center = np.asarray(self.attractor) * area
            wp = center[None] + rng.uniform(-0.03, 0.03, (n, 2)) * area
        else:
            wp = rng.uniform(0.0, area, (n, 2))
        v = rng.uniform(self.speed[0], self.speed[1], n)
        return np.clip(wp, 0.0, area), v

    def init(self, rng, pos, area):
        n = len(pos)
        self._wp, self._v = self._new_leg(0, rng, n, area)
        self._pause_left = np.zeros(n)

    def state_dict(self):
        if self._wp is None:
            return {"initialized": 0}
        return {"initialized": 1, "wp": np.asarray(self._wp),
                "v": np.asarray(self._v),
                "pause_left": np.asarray(self._pause_left)}

    def load_state_dict(self, d):
        if not int(d["initialized"]):
            self._wp = self._v = self._pause_left = None
            return
        self._wp = np.asarray(d["wp"])
        self._v = np.asarray(d["v"])
        self._pause_left = np.asarray(d["pause_left"])

    def step(self, t, rng, pos, area, dt):
        n = len(pos)
        # draw the round's candidate legs unconditionally so the rng
        # consumption (and thus determinism) is independent of arrivals
        new_wp, new_v = self._new_leg(t, rng, n, area)
        pause_draw = rng.uniform(0.0, 1.0, n)
        vec = self._wp - pos
        dist = np.linalg.norm(vec, axis=1)
        paused = self._pause_left > 0.0
        self._pause_left = np.maximum(self._pause_left - dt, 0.0)
        travel = np.where(paused, 0.0, self._v * dt)
        arrive = travel >= dist
        frac = np.where(dist > 1e-9, np.minimum(travel, dist)
                        / np.maximum(dist, 1e-9), 0.0)
        out = pos + vec * frac[:, None]
        self._wp = np.where(arrive[:, None], new_wp, self._wp)
        self._v = np.where(arrive, new_v, self._v)
        self._pause_left = np.where(
            arrive, self.pause * pause_draw, self._pause_left)
        return np.clip(out, 0.0, area)


class GaussMarkov(MobilityModel):
    """Gauss-Markov mobility: AR(1) velocity with boundary reflection."""

    def __init__(self, mean_speed: float = 15.0, alpha: float = 0.8,
                 sigma: float = 4.0):
        self.mean_speed = mean_speed
        self.alpha = alpha
        self.sigma = sigma
        self._v = None
        self._v_mean = None

    def init(self, rng, pos, area):
        n = len(pos)
        heading = rng.uniform(0.0, 2.0 * np.pi, n)
        dir_ = np.stack([np.cos(heading), np.sin(heading)], 1)
        self._v_mean = dir_ * self.mean_speed
        self._v = self._v_mean + rng.normal(0.0, self.sigma, (n, 2))

    def state_dict(self):
        if self._v is None:
            return {"initialized": 0}
        return {"initialized": 1, "v": np.asarray(self._v),
                "v_mean": np.asarray(self._v_mean)}

    def load_state_dict(self, d):
        if not int(d["initialized"]):
            self._v = self._v_mean = None
            return
        self._v = np.asarray(d["v"])
        self._v_mean = np.asarray(d["v_mean"])

    def step(self, t, rng, pos, area, dt):
        a = self.alpha
        w = rng.normal(0.0, 1.0, self._v.shape)
        self._v = a * self._v + (1.0 - a) * self._v_mean \
            + np.sqrt(max(1.0 - a * a, 0.0)) * self.sigma * w
        out = pos + self._v * dt
        # reflect at the field boundary (flip position, velocity, and the
        # mean heading so the process doesn't fight the wall)
        for lo, hi in ((0.0, area),):
            under, over = out < lo, out > hi
            out = np.where(under, 2 * lo - out, out)
            out = np.where(over, 2 * hi - out, out)
            flip = under | over
            self._v = np.where(flip, -self._v, self._v)
            self._v_mean = np.where(flip, -self._v_mean, self._v_mean)
        return np.clip(out, 0.0, area)
