"""Adversarial client models: the threat layer of the scenario subsystem.

Production edge FL faces client populations the six benign presets never
exercise (the mobile-edge FL survey's first-order threat classes):

* :class:`ByzantineUpdate` — compromised UEs corrupt the *update* they
  report (sign-flipped and scaled, or Gaussian-noise-swamped).  Data is
  untouched; the attack lives at the aggregation input, which is exactly
  what the ``EngineOptions.robust_agg`` trimmed-mean/median counter
  (``core.aggregation.robust_aggregate``) defends.
* :class:`LabelPoison` — data poisoning: compromised UEs train on
  label-flipped examples (y -> C-1-y), degrading the global model
  through honest aggregation.
* :class:`Straggler` — afflicted UEs compute at ``f_n / slowdown``; the
  scaling rides through the existing Sec. II-E cost model (compute delay
  ``d_n^P ∝ 1/f_n``), so straggler-dominated wall-clock shows up in the
  reported round delay without touching the solver's idealized plan.
* :class:`Dropout` — hard i.i.d. availability failure: each round each
  UE independently contributes nothing with probability ``p`` (unlike
  the Markov :class:`~repro.scenario.drift_schedules.JoinLeave` churn,
  there is no persistence).

All adversaries implement the drift-schedule protocol (``apply`` /
``begin_round`` / ``events`` / ``state_dict``), so they compose with the
benign schedules through ``DynamicScenario(schedules=...)`` in the same
fixed UE order — a run stays a pure function of the engine seed.  The two
non-data channels ride on :class:`~repro.scenario.base.ScenarioEvents`:
``corrupted`` (consumed by the executors between local training and
aggregation) and ``compute_scale`` (consumed by ``Engine.finish_round``
cost accounting).

The compromised set is resolved deterministically at ``reset`` (bind)
time: ``ues`` wins when given, else ``round(frac * n_ue)`` evenly spaced
indices — stable across runs so fixed-seed comparisons (the robustness
acceptance test) are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.scenario.drift_schedules import _as_np, empty_like

CORRUPTION_MODES = ("sign_flip", "gauss")


def resolve_ues(n_ue: int, frac: float,
                ues: Optional[Tuple[int, ...]]) -> Tuple[int, ...]:
    """The deterministic compromised-UE set: explicit ``ues`` (clamped to
    range) or ``round(frac * n_ue)`` evenly spaced indices."""
    if ues is not None:
        return tuple(sorted({int(u) for u in ues if 0 <= int(u) < n_ue}))
    k = int(round(float(frac) * n_ue))
    if k <= 0:
        return ()
    idx = np.round(np.linspace(0, n_ue - 1, num=min(k, n_ue))).astype(int)
    return tuple(sorted({int(i) for i in idx}))


class _Stateless:
    """Adversaries whose only state is the bind-time compromised set."""

    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, d: dict) -> None:
        pass


@dataclasses.dataclass
class ByzantineUpdate(_Stateless):
    """Update-level corruption at the compromised UEs, from ``start`` on.

    ``mode="sign_flip"``: the reported accumulated gradient becomes
    ``-scale * d_i`` (and the local model ``x - scale * (x_i - x)``),
    the classical directed attack.  ``mode="gauss"``: ``scale``-std
    Gaussian noise is added instead (an undirected jammer).  Noise keys
    derive from the round's PRNG chain, so corrupted runs stay
    bit-reproducible.
    """
    mode: str = "sign_flip"
    frac: float = 0.2
    scale: float = 4.0
    ues: Optional[Tuple[int, ...]] = None
    start: int = 0

    def __post_init__(self):
        if self.mode not in CORRUPTION_MODES:
            raise ValueError(f"unknown corruption mode {self.mode!r}; "
                             f"known: {CORRUPTION_MODES}")
        self._set: Tuple[int, ...] = ()

    def reset(self, n_ue: int) -> None:
        self._set = resolve_ues(n_ue, self.frac, self.ues)

    def corrupted(self, t: int) -> Tuple[Tuple[int, str, float], ...]:
        if t < self.start:
            return ()
        return tuple((ue, self.mode, float(self.scale))
                     for ue in self._set)

    def apply(self, t, ue, data, rng):
        return data                   # the attack is post-training


@dataclasses.dataclass
class LabelPoison(_Stateless):
    """Label-flipping data poisoning (y -> num_classes-1-y) at the
    compromised UEs, from ``start`` on."""
    frac: float = 0.3
    num_classes: int = 10
    ues: Optional[Tuple[int, ...]] = None
    start: int = 0

    def __post_init__(self):
        self._set: Tuple[int, ...] = ()

    def reset(self, n_ue: int) -> None:
        self._set = resolve_ues(n_ue, self.frac, self.ues)

    def apply(self, t, ue, data, rng):
        if t < self.start or ue not in self._set or not len(data["y"]):
            return data
        x, y = _as_np(data)
        return {"x": x, "y": (self.num_classes - 1 - y) % self.num_classes}


@dataclasses.dataclass
class Straggler(_Stateless):
    """Compute-rate degradation: afflicted UEs realize ``f_n / slowdown``
    — charged through the existing cost model (``network_costs``), where
    compute delay scales as 1/f_n and compute energy as f_n^2."""
    frac: float = 0.3
    slowdown: float = 4.0
    ues: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.slowdown <= 0:
            raise ValueError("slowdown must be positive")
        self._set: Tuple[int, ...] = ()

    def reset(self, n_ue: int) -> None:
        self._set = resolve_ues(n_ue, self.frac, self.ues)

    def compute_scale(self, t: int, n_ue: int) -> Tuple[float, ...]:
        scale = np.ones(n_ue)
        for ue in self._set:
            scale[ue] = 1.0 / self.slowdown
        return tuple(float(s) for s in scale)

    def apply(self, t, ue, data, rng):
        return data


@dataclasses.dataclass
class Dropout:
    """Hard i.i.d. dropout: each round, each UE independently contributes
    an empty round dataset with probability ``p`` (no Markov persistence
    — compare :class:`~repro.scenario.drift_schedules.JoinLeave`).  At
    least ``min_active`` UEs always survive: the lowest-index down UEs
    are restored deterministically."""
    p: float = 0.1
    min_active: int = 1

    def __post_init__(self):
        self._down = None
        self._joined: Tuple[int, ...] = ()
        self._left: Tuple[int, ...] = ()

    def reset(self, n_ue: int) -> None:
        self._down = np.zeros(n_ue, bool)
        self._joined, self._left = (), ()

    def begin_round(self, t, n_ue, rng):
        if self._down is None or len(self._down) != n_ue:
            self.reset(n_ue)
        prev = self._down.copy()
        down = rng.uniform(0.0, 1.0, n_ue) < self.p
        for ue in np.nonzero(down)[0]:
            if int((~down).sum()) >= self.min_active:
                break
            down[ue] = False
        self._down = down
        self._joined = tuple(int(u) for u in np.nonzero(prev & ~down)[0])
        self._left = tuple(int(u) for u in np.nonzero(~prev & down)[0])

    def events(self):
        return self._joined, self._left

    def state_dict(self) -> dict:
        if self._down is None:
            return {"initialized": 0}
        return {"initialized": 1, "down": np.array(self._down, bool),
                "joined": np.asarray(self._joined, np.int64),
                "left": np.asarray(self._left, np.int64)}

    def load_state_dict(self, d: dict) -> None:
        if not int(d["initialized"]):
            self._down = None
            self._joined, self._left = (), ()
            return
        self._down = np.asarray(d["down"], bool)
        self._joined = tuple(int(u) for u in np.asarray(d["joined"]))
        self._left = tuple(int(u) for u in np.asarray(d["left"]))

    def apply(self, t, ue, data, rng):
        if self._down is not None and self._down[ue]:
            return empty_like(data)
        return data
