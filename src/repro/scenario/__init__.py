# Dynamic-environment subsystem: per-round network + data evolution
# (mobility, handover, mesh churn, drift schedules, adversary models)
# behind one protocol.
from repro.scenario import presets  # noqa: F401  (registers the built-ins)
from repro.scenario.adversary import (  # noqa: F401
    ByzantineUpdate, Dropout, LabelPoison, Straggler,
)
from repro.scenario.base import (  # noqa: F401
    Scenario, ScenarioEvents, StaticScenario, available_scenarios,
    get_scenario, register_scenario,
)
from repro.scenario.drift_schedules import (  # noqa: F401
    ArrivalBurst, JoinLeave, LabelRotation,
)
from repro.scenario.dynamic import DynamicScenario  # noqa: F401
from repro.scenario.mobility import (  # noqa: F401
    FieldLayout, GaussMarkov, MobilityModel, RandomWaypoint,
    layout_from_network,
)
