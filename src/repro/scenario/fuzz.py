"""Property-based scenario fuzzer: engine invariants on random draws.

Every draw is a full :class:`~repro.experiments.spec.ExperimentSpec` —
scenario (mobility x channel x drift x adversary, via the preset
registry including the randomly composed ``fuzzmix:<seed>`` axis) x
strategy x robust aggregation x engine hyper-parameters x run seed — and
every draw must satisfy the engine's standing invariants:

1. **determinism** — re-running the same spec+seed reproduces the whole
   metric/plan trace bit-exactly;
2. **conservation** — every datapoint a UE observed lands at exactly one
   DPU after ``realize_offloading`` (checked every round);
3. **no-retrace** — the replay run triggers ZERO process-wide backend
   compiles (``repro.analysis.sanitize.no_retrace``): the warm run
   already compiled everything a same-shape run needs;
4. **finiteness** — params are finite after every round, and the round
   loss is finite whenever any UE contributed data (``check_finite``);
5. **resume** — killing the run at the midpoint, checkpointing through
   ``repro.experiments.runstate``, and restoring into a FRESH engine
   reproduces the remaining rounds bit-exactly.

Failing draws serialize the exact ExperimentSpec JSON + seed to
``--out`` so any failure is a one-command replay::

    python -m repro.scenario.fuzz --n 25 --seed 0
    python -m repro.scenario.fuzz --replay fuzz_out/failing_draw_3.json

``--break-invariant determinism`` is the gate's selftest: it runs one
draw whose replay deliberately mutates the seed and exits 0 only if the
violation is caught and serialized.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
from typing import List, Optional

import numpy as np

from repro.analysis.sanitize import SanitizerError, check_finite, no_retrace
from repro.experiments import presets as _presets  # noqa: F401 (registry)
from repro.experiments.build import build_context
from repro.experiments.spec import (ConstsSpec, DataSpec, EngineSpec,
                                    ExperimentSpec, ModelSpec, NetworkSpec,
                                    from_json, to_json)

SCENARIO_POOL = (
    "static", "campus_walk", "campus_walk:fast", "vehicular",
    "flash_crowd", "label_shift", "label_shift:2", "churn",
    "byzantine", "byzantine:0.34", "poisoned", "stragglers",
    # the composed axis: mobility x channel x drift x adversary in one
    # registry string, so failing compositions replay through the spec
    "fuzzmix",
)
STRATEGY_POOL = ("cefl", "greedy_data", "greedy_rate", "fixed:0",
                 "fednova", "fedavg")
ROBUST_POOL = ("none", "none", "trimmed_mean", "median")   # none-weighted


class InvariantViolation(AssertionError):
    """One engine invariant failed on one draw."""

    def __init__(self, invariant: str, detail: str):
        super().__init__(f"[{invariant}] {detail}")
        self.invariant = invariant
        self.detail = detail


# ---------------------------------------------------------- drawing -----

def draw_spec(rng: np.random.RandomState, *, rounds: int = 3) \
        -> ExperimentSpec:
    """One random experiment cell, sized for fuzzing: fixed tiny
    model/network dims (so compile caches amortize across draws) with
    the scenario / strategy / robust-agg / seed axes randomized."""
    scenario = SCENARIO_POOL[rng.randint(len(SCENARIO_POOL))]
    if scenario == "fuzzmix":
        scenario = f"fuzzmix:{rng.randint(0, 1000)}"
    return ExperimentSpec(
        name="fuzz_draw",
        model=ModelSpec(input_shape=(8, 8, 1), hidden=(16,)),
        data=DataSpec(pool=2000, mean_arrivals=120.0, std_arrivals=12.0,
                      eval_examples=200),
        network=NetworkSpec(num_ue=4, num_bs=2, num_dc=2),
        consts=ConstsSpec(mode="fixed", L=5.0, theta=2.0, sigma=3.0),
        engine=EngineSpec(
            rounds=rounds,
            eta=float(rng.choice([0.05, 0.1])),
            solver_outer=2,
            reoptimize_every=int(rng.choice([1, 2])),
            eval_every=int(rng.choice([1, 2])),
            robust_agg=ROBUST_POOL[rng.randint(len(ROBUST_POOL))],
            trim_frac=float(rng.choice([0.1, 0.25]))),
        strategy=STRATEGY_POOL[rng.randint(len(STRATEGY_POOL))],
        scenario=scenario,
        seeds=(int(rng.randint(0, 2 ** 16)),))


# ------------------------------------------------------ the invariants --

def _trace_of(reports) -> List[tuple]:
    """The comparable bit-exact trace of a run."""
    return [(r.round, r.loss, r.acc, r.aggregator, r.dc_points,
             r.handovers, r.active_ues, r.energy, r.delay)
            for r in reports]


def _run_rounds(ctx, seed: int, *, stop_at: Optional[int] = None,
                run=None):
    """Drive (or continue) one engine run through the decomposed loop —
    begin_round / execute_round / finish_round — checking conservation
    and finiteness every round.  Returns the ``_FuzzRun``."""
    if run is None:
        engine = ctx.make_engine(seed)
        ues = ctx.make_ues(seed)
        state = engine.init_loop(ues, init_params=ctx.p0,
                                 loss_fn=ctx.loss_fn, eval_fn=ctx.eval_fn)
        run = _FuzzRun(seed=seed, engine=engine, ues=ues, state=state)
    engine, state = run.engine, run.state
    rounds = engine.opts.rounds if stop_at is None \
        else min(stop_at, engine.opts.rounds)
    while state.t < rounds and not state.stopped:
        staged = engine.begin_round(state, run.ues)
        got = sum(len(d["y"]) for d in staged.datasets if d is not None)
        want = int(staged.D_bar.sum())
        if got != want:
            raise InvariantViolation(
                "conservation",
                f"round {staged.t}: {got} datapoints at DPUs vs "
                f"{want} observed (realize_offloading leak)")
        mean_loss, acc = engine.execute_round(state, staged)
        engine.finish_round(state, staged, mean_loss, acc)
        try:
            check_finite(state.params, f"params after round {staged.t}")
        except SanitizerError as e:
            raise InvariantViolation("finiteness", str(e)) from None
        if staged.events.active_ues > 0 and not np.isfinite(mean_loss):
            raise InvariantViolation(
                "finiteness",
                f"round {staged.t}: non-finite loss {mean_loss} with "
                f"{staged.events.active_ues} active UEs")
    return run


@dataclasses.dataclass
class _FuzzRun:
    """``runstate``-compatible run shim (same attrs as ``sweep._Run``)."""
    seed: int
    engine: object
    ues: list
    state: object


def check_draw(spec: ExperimentSpec, *, mutate_seed: bool = False) -> None:
    """Assert every engine invariant on one draw; raises
    :class:`InvariantViolation`.  ``mutate_seed`` deliberately replays
    under a different seed — the determinism invariant must then fail
    (the ``--break-invariant`` selftest)."""
    from repro.experiments import runstate

    ctx = build_context(spec)
    seed = spec.run_seeds[0]

    # run A: the warm reference (also compiles everything this shape
    # needs, so run B can demand zero recompiles)
    ref = _run_rounds(ctx, seed)
    ref_trace = _trace_of(ref.state.reports)

    # run B: same seed bit-exact, with zero process-wide compiles
    replay_seed = seed + 1 if mutate_seed else seed
    try:
        if mutate_seed:
            # a different seed legitimately changes shapes/compiles;
            # only the determinism comparison is under test here
            rep = _run_rounds(ctx, replay_seed)
        else:
            with no_retrace(f"fuzz replay of {spec.scenario}"):
                rep = _run_rounds(ctx, replay_seed)
    except SanitizerError as e:
        raise InvariantViolation("no-retrace", str(e)) from None
    if _trace_of(rep.state.reports) != ref_trace:
        raise InvariantViolation(
            "determinism",
            f"seed {replay_seed} replay trace diverged from seed {seed} "
            f"reference (scenario={spec.scenario}, "
            f"strategy={spec.strategy})")

    # run C: kill at the midpoint, checkpoint, restore into a FRESH
    # engine, finish — the suffix must match the reference trace
    rounds = spec.engine.rounds
    k = max(1, rounds // 2)
    half = _run_rounds(ctx, seed, stop_at=k)
    with tempfile.TemporaryDirectory() as tmp:
        runstate.save_sweep_state(tmp, [half], spec_json=to_json(spec),
                                  round_idx=k)
        state_d, reports_d, _, _ = runstate.load_sweep_state(tmp)
    engine2 = ctx.make_engine(seed)
    ues2 = ctx.make_ues(seed)
    state2 = engine2.init_loop(ues2, init_params=ctx.p0,
                               loss_fn=ctx.loss_fn, eval_fn=ctx.eval_fn)
    resumed = _FuzzRun(seed=seed, engine=engine2, ues=ues2, state=state2)
    runstate.restore_run(resumed, state_d[str(seed)], reports_d[str(seed)],
                         engine2)
    _run_rounds(ctx, seed, run=resumed)
    if _trace_of(resumed.state.reports) != ref_trace:
        raise InvariantViolation(
            "resume",
            f"kill-and-resume at round {k} diverged from the straight "
            f"run (scenario={spec.scenario}, strategy={spec.strategy})")


# ----------------------------------------------------- fuzz campaign ----

def _write_artifact(out_dir: str, index: int, spec: ExperimentSpec,
                    err: InvariantViolation, fuzz_seed: int) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"failing_draw_{index}.json")
    with open(path, "w") as fh:
        json.dump({"spec": spec.to_dict(),
                   "seed": spec.run_seeds[0],
                   "invariant": err.invariant,
                   "detail": err.detail,
                   "draw_index": index,
                   "fuzz_seed": fuzz_seed}, fh, indent=1)
    return path


def replay_command(path: str) -> str:
    return f"PYTHONPATH=src python -m repro.scenario.fuzz --replay {path}"


def run_fuzz(n: int, seed: int, out_dir: str, *, rounds: int = 3,
             mutate_seed: bool = False, progress=print) -> List[str]:
    """Run ``n`` draws; returns the artifact paths of failing draws."""
    rng = np.random.RandomState(seed)
    artifacts = []
    for i in range(n):
        spec = draw_spec(rng, rounds=rounds)
        label = (f"draw {i}: scenario={spec.scenario} "
                 f"strategy={spec.strategy} "
                 f"robust={spec.engine.robust_agg} seed={spec.run_seeds[0]}")
        try:
            check_draw(spec, mutate_seed=mutate_seed)
        except InvariantViolation as e:
            path = _write_artifact(out_dir, i, spec, e, seed)
            artifacts.append(path)
            progress(f"[fuzz] FAIL {label}\n       {e}\n"
                     f"       replay: {replay_command(path)}")
        else:
            progress(f"[fuzz] ok   {label}")
    return artifacts


def replay(path: str) -> None:
    """Re-run one serialized failing draw (raises on violation)."""
    with open(path) as fh:
        artifact = json.load(fh)
    spec = from_json(json.dumps(artifact["spec"]))
    print(f"[fuzz] replaying {path}: invariant={artifact['invariant']} "
          f"scenario={spec.scenario} strategy={spec.strategy} "
          f"seed={artifact['seed']}")
    check_draw(spec)
    print("[fuzz] replay passed (the failure did not reproduce)")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.scenario.fuzz", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--n", type=int, default=10, help="number of draws")
    p.add_argument("--seed", type=int, default=0, help="campaign seed")
    p.add_argument("--rounds", type=int, default=3,
                   help="engine rounds per draw")
    p.add_argument("--out", default="fuzz_out",
                   help="failing-draw artifact directory")
    p.add_argument("--replay", dest="replay_path", default=None,
                   help="re-run one serialized failing draw and exit")
    p.add_argument("--break-invariant", choices=("determinism",),
                   default=None,
                   help="selftest: deliberately violate an invariant and "
                        "verify the fuzzer catches + serializes it")
    args = p.parse_args(argv)

    if args.replay_path:
        try:
            replay(args.replay_path)
        except InvariantViolation as e:
            print(f"[fuzz] replay FAILED: {e}")
            return 1
        return 0

    if args.break_invariant:
        artifacts = run_fuzz(1, args.seed, args.out, rounds=args.rounds,
                             mutate_seed=True)
        if not artifacts:
            print("[fuzz] selftest FAILED: the mutated-seed replay was "
                  "NOT caught")
            return 1
        print(f"[fuzz] selftest ok: broken {args.break_invariant} caught "
              f"and serialized to {artifacts[0]}")
        return 0

    artifacts = run_fuzz(args.n, args.seed, args.out, rounds=args.rounds)
    if artifacts:
        print(f"[fuzz] {len(artifacts)}/{args.n} draws FAILED; artifacts "
              f"in {args.out}/")
        for a in artifacts:
            print(f"  {replay_command(a)}")
        return 1
    print(f"[fuzz] all {args.n} draws passed every engine invariant")
    return 0


if __name__ == "__main__":
    sys.exit(main())
