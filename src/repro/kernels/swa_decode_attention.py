"""Pallas TPU kernel: single-token decode attention over a (sliding-window)
KV cache — the serving hot spot of decode_32k / long_500k.

Per grid cell (batch b, kv-head k): computes GQA attention of the G query
heads that share kv-head k against the cache, streaming the cache in
(CHUNK, D) tiles through VMEM with the safe-softmax (m, l, acc) recursion.
Masking uses the global cache_len (valid prefix) — rolling-window caches
pass a fully-valid cache.

Tile maths (v5e): CHUNK=512, D=128 -> k/v tiles 2x128KB bf16; acc (G, D)
f32 in VMEM.  D and CHUNK are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, chunk: int):
    # q_ref: (1,1,G,D); k_ref/v_ref: (1,1,S,D); len_ref: (1,1); o: (1,1,G,D)
    G, D = q_ref.shape[2], q_ref.shape[3]
    S = k_ref.shape[2]
    cache_len = len_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    def body(i, carry):
        acc, m, l = carry
        kblk = k_ref[0, 0, pl.ds(i * chunk, chunk), :].astype(jnp.float32)
        vblk = v_ref[0, 0, pl.ds(i * chunk, chunk), :].astype(jnp.float32)
        s = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32) * scale
        pos = i * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
        s = jnp.where(pos < cache_len, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.dot(p, vblk,
                                       preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((G, D), jnp.float32)
    m0 = jnp.full((G, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, S // chunk, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def swa_decode_attention(q, k_cache, v_cache, cache_len, *,
                         chunk: int = 512, interpret: bool = False):
    """q: (B, Hq, D); k/v_cache: (B, S, Hkv, D); cache_len scalar int32.
    Returns (B, Hq, D).  S % chunk == 0; D a multiple of 128 on real TPUs."""
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    chunk = min(chunk, S)
    assert S % chunk == 0
    qg = q.reshape(B, Hkv, G, D)
    kc = k_cache.transpose(0, 2, 1, 3)        # (B, Hkv, S, D)
    vc = v_cache.transpose(0, 2, 1, 3)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(qg, kc, vc, jnp.asarray(cache_len, jnp.int32).reshape(1, 1))
    return out.reshape(B, Hq, D)
