"""Memory-space-aware tile planning for the plane Pallas kernels.

A :class:`TilePlan` is the static 2-D (row-tile x lane-tile) block
decomposition one ``pallas_call`` runs with.  Plans are sized from the
target memory space's byte budget, the operand count, and the dtype —
not from a hardcoded ``row_tile(R)``:

* **tpu** — blocks live in VMEM (~16 MiB/core).  Half the space is
  reserved for Mosaic's double-buffered pipeline (each streamed operand
  keeps two live copies so the next block's DMA overlaps compute), so
  the planner sizes ``n_operands * 2 * rows * lanes * itemsize`` against
  an 8 MiB budget.
* **gpu** — blocks stage through SMEM (~192 KiB/SM on recent parts);
  same sizing rule, much smaller budget, so plans come out with small
  row tiles and often sub-LANE lane tiles.
* **interpret** — the CPU interpreter's per-grid-step cost is a full
  block copy, so the "budget" is unbounded and the plan degenerates to
  ONE whole-array block (the PR-2 fast path; see
  ``fedprox_update.py``'s module docstring).

Tiles honor the dtype's minimum TPU tile: the sublane count (second-to-
last dim) is a multiple of 8 for f32, 16 for bf16, 32 for int8/fp8, and
the lane count a multiple of 128.  Row/lane extents that don't divide
the plane use ``pl.cdiv`` grids with padded edge blocks — callers never
need R to be a multiple of the tile.
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp

LANE_MIN = 128        # last-dim tile quantum (vector register width)
ROW_CAP = 512         # rows per tile beyond which DMA granularity stops helping

# usable bytes per compute block set, per memory space (pipeline-adjusted
# below via DOUBLE_BUFFER)
MEMORY_BUDGET_BYTES = {
    "tpu": 8 * 2 ** 20,       # half of ~16 MiB VMEM/core
    "gpu": 160 * 2 ** 10,     # conservative SMEM slice per block
    "interpret": None,        # whole-array single block (see module doc)
}

DOUBLE_BUFFER = 2             # live copies per streamed operand (pipelining)


def sublane(dtype) -> int:
    """Minimum second-to-last-dim tile for ``dtype`` (TPU packing rule:
    8 for 4-byte, 16 for 2-byte, 32 for 1-byte types)."""
    itemsize = jnp.dtype(dtype).itemsize
    return {4: 8, 2: 16, 1: 32}.get(itemsize, 8)


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One kernel launch's static block decomposition (hashable, so it
    can ride through ``jax.jit`` static args)."""
    rows: int                 # second-to-last-dim block extent
    lanes: int                # last-dim block extent
    backend: str = "interpret"    # memory space the plan was sized for

    def block_bytes(self, n_operands: int, dtype=jnp.float32) -> int:
        """Resident bytes for ``n_operands`` double-buffered blocks."""
        return (n_operands * DOUBLE_BUFFER * self.rows * self.lanes
                * jnp.dtype(dtype).itemsize)


def _round_up(x: int, q: int) -> int:
    return -(-x // q) * q


@functools.lru_cache(maxsize=256)
def plan_tiles(R: int, L: int, *, n_operands: int, dtype=jnp.float32,
               backend: str = "tpu") -> TilePlan:
    """Largest (rows, lanes) tile whose ``n_operands`` double-buffered
    blocks fit the ``backend`` memory budget.

    ``n_operands`` counts every block resident per grid step: streamed
    inputs, outputs, and scratch accumulators (a stacked block counts
    once per stack element).  Shrinks rows first (halving, floored at
    the dtype sublane), then lanes (halving, floored at 128).
    """
    if backend not in MEMORY_BUDGET_BYTES:
        raise ValueError(f"no memory budget for backend {backend!r}; "
                         f"known: {sorted(MEMORY_BUDGET_BYTES)}")
    budget = MEMORY_BUDGET_BYTES[backend]
    if budget is None:
        return TilePlan(rows=R, lanes=L, backend=backend)
    sub = sublane(dtype)
    itemsize = jnp.dtype(dtype).itemsize
    rows = min(_round_up(R, sub), ROW_CAP)
    lanes = min(_round_up(L, LANE_MIN), L if L % LANE_MIN == 0 else
                _round_up(L, LANE_MIN))

    def fits(r, ln):
        return n_operands * DOUBLE_BUFFER * r * ln * itemsize <= budget

    while not fits(rows, lanes) and rows > sub:
        rows = max(sub, rows // 2 // sub * sub)
    while not fits(rows, lanes) and lanes > LANE_MIN:
        lanes = max(LANE_MIN, lanes // 2 // LANE_MIN * LANE_MIN)
    return TilePlan(rows=min(rows, _round_up(R, sub)),
                    lanes=min(lanes, _round_up(L, LANE_MIN)),
                    backend=backend)
