"""Jitted public wrappers around the Pallas kernels.

Handle pytree flattening / padding to kernel tile shapes, dispatch to the
kernel (interpret=True on CPU — the TPU path is the same pallas_call), and
reassemble pytrees.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fedprox_update as _fp
from repro.kernels import nova_aggregate as _na
from repro.kernels.swa_decode_attention import swa_decode_attention  # noqa: F401

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not _ON_TPU


def _flatten_pad(tree, lane, rows):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    n = flat.shape[0]
    block = lane * rows
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, lane), treedef, [x.shape for x in leaves], \
        [x.dtype for x in leaves], n


def _unflatten(flat2d, treedef, shapes, dtypes, n):
    flat = flat2d.reshape(-1)[:n]
    out, off = [], 0
    for s, dt in zip(shapes, dtypes):
        k = int(np.prod(s)) if s else 1
        out.append(flat[off:off + k].reshape(s).astype(dt))
        off += k
    return jax.tree_util.tree_unflatten(treedef, out)


def fedprox_update(params, grads, anchor, eta, mu, *,
                   interpret: bool = None):
    """Fused x <- x - eta*(g + mu*(x - anchor)) over a whole pytree."""
    interpret = INTERPRET if interpret is None else interpret
    x2, treedef, shapes, dtypes, n = _flatten_pad(params, _fp.LANE, _fp.ROWS)
    g2, *_ = _flatten_pad(grads, _fp.LANE, _fp.ROWS)
    a2, *_ = _flatten_pad(anchor, _fp.LANE, _fp.ROWS)
    out = _fp.fedprox_update_2d(x2, g2, a2, eta, mu, interpret=interpret)
    return _unflatten(out, treedef, shapes, dtypes, n)


def nova_aggregate(x, d_list: Sequence, weights, theta_eta, *,
                   interpret: bool = None):
    """x <- x - theta*eta*sum_i w_i d_i over pytrees (eq. 11)."""
    interpret = INTERPRET if interpret is None else interpret
    x2, treedef, shapes, dtypes, n = _flatten_pad(x, _na.LANE, _na.ROWS)
    ds = [_flatten_pad(d, _na.LANE, _na.ROWS)[0] for d in d_list]
    d_stack = jnp.stack(ds, axis=0)
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    out = _na.nova_aggregate_2d(x2, d_stack, w, theta_eta,
                                interpret=interpret)
    return _unflatten(out, treedef, shapes, dtypes, n)
