"""Jitted public wrappers around the Pallas kernels.

Two API levels:

* **Plane level** (the hot path): ``fedprox_plane``, ``fedprox_accum_plane``,
  ``nova_aggregate_plane`` operate directly on ``(R, LANE)`` /
  ``(G, R, LANE)`` parameter planes (see ``plane.py``) — no flattening,
  no padding, no host round-trips.  This is what ``core.fedprox``,
  ``core.round_step`` and the engine executors call every round.
* **Tree level** (convenience / API boundaries): ``fedprox_update``,
  ``nova_aggregate`` accept pytrees and convert through a cached
  :class:`~repro.kernels.plane.FlatSpec` — the layout is computed once per
  structure instead of re-deriving treedef/shapes/offsets on every call.

Dispatch rule: the pallas_call is identical on every backend; on CPU the
kernels run in ``interpret=True`` mode (traced into XLA ops when jitted),
on TPU they compile to Mosaic.  ``kernels/ref.py`` holds the pure-jnp
oracles used by the parity tests.

Weight contract (see docs/kernels.md): tree-level ``nova_aggregate`` takes
ABSOLUTE dataset sizes and normalizes exactly once; the plane/kernel level
takes already-normalized weights and never re-normalizes.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import fedprox_update as _fp
from repro.kernels import nova_aggregate as _na
from repro.kernels.plane import FlatSpec, ParamPlane, spec_of  # noqa: F401
from repro.kernels.swa_decode_attention import swa_decode_attention  # noqa: F401

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not _ON_TPU


def _interp(interpret):
    return INTERPRET if interpret is None else interpret


def normalize_weights(weights: Sequence) -> jnp.ndarray:
    """Absolute D_i -> simplex weights (f32).  THE single normalization
    point of the tree-level weight contract (docs/kernels.md); the
    kernel level below takes already-normalized weights.  Re-exported as
    ``core.aggregation.normalize_weights``."""
    w = jnp.asarray(weights, jnp.float32)
    return w / jnp.sum(w)


# ------------------------------------------------------ plane level -----

def fedprox_plane(x, g, anchor, eta, mu, *, interpret: bool = None):
    """Fused x <- x - eta*(g + mu*(x - anchor)) on (R, LANE) planes."""
    return _fp.fedprox_update_2d(x, g, anchor, eta, mu,
                                 interpret=_interp(interpret))


def fedprox_accum_plane(x, g, anchor, acc, coef, active, eta, mu, *,
                        interpret: bool = None):
    """Batched proximal step + eq.-10 accumulation on (G, R, LANE) planes
    (one launch per local iteration for a whole DPU group)."""
    return _fp.fedprox_accum_2d(x, g, anchor, acc, coef, active, eta, mu,
                                interpret=_interp(interpret))


def nova_aggregate_plane(x, d_stack, weights, theta_eta, *,
                         interpret: bool = None):
    """eq. 11 on planes.  ``weights`` must already be normalized.  ``x``
    may be (R, LANE) or (n_dpu, R, LANE) (stacked per-DPU replicas)."""
    if x.ndim == 3:
        return _na.nova_aggregate_stacked_2d(x, d_stack, weights, theta_eta,
                                             interpret=_interp(interpret))
    return _na.nova_aggregate_2d(x, d_stack, weights, theta_eta,
                                 interpret=_interp(interpret))


# ------------------------------------------------------- tree level -----

def fedprox_update(params, grads, anchor, eta, mu, *,
                   interpret: bool = None):
    """Fused x <- x - eta*(g + mu*(x - anchor)) over a whole pytree."""
    spec = spec_of(params)
    out = fedprox_plane(spec.flatten(params), spec.flatten(grads),
                        spec.flatten(anchor), eta, mu, interpret=interpret)
    return spec.unflatten(out)


def nova_aggregate(x, d_list: Sequence, weights, theta_eta, *,
                   interpret: bool = None):
    """x <- x - theta*eta*sum_i w_i d_i over pytrees (eq. 11).

    ``weights``: absolute dataset sizes D_i — normalized here (the single
    normalization point for this path, see docs/kernels.md).
    """
    spec = spec_of(x)
    d_stack = jnp.stack([spec.flatten(d) for d in d_list], axis=0)
    w = normalize_weights(weights)
    out = nova_aggregate_plane(spec.flatten(x), d_stack, w, theta_eta,
                               interpret=interpret)
    return spec.unflatten(out)
