"""Backend dispatch + jitted public wrappers around the Pallas kernels.

Two API levels:

* **Plane level** (the hot path): ``fedprox_plane``, ``fedprox_accum_plane``,
  ``nova_aggregate_plane`` operate directly on ``(R, LANE)`` /
  ``(G, R, LANE)`` parameter planes (see ``plane.py``) — no flattening,
  no padding, no host round-trips.  This is what ``core.fedprox``,
  ``core.round_step`` and the engine executors call every round.
* **Tree level** (convenience / API boundaries): ``fedprox_update``,
  ``nova_aggregate`` accept pytrees.

Backend dispatch — THE single place that decides how a kernel op runs:

* ``"tpu"`` / ``"gpu"`` — compiled ``pallas_call`` with a tiled,
  double-buffered grid sized for the backend memory space by
  :func:`repro.kernels.tiling.plan_tiles` (VMEM / SMEM byte budgets from
  dtype and plane dims).
* ``"interpret"`` — the Pallas interpreter with the grid=1 whole-array
  block fallback (the kernel body traces into plain XLA ops under jit);
  numerically identical to the compiled decomposition, and the substrate
  the tiled grids are parity-tested on.
* ``"cpu"`` — jitted pure-jnp ops (``kernels/ref.py``).  The kernel
  bodies are expression-identical to the refs, so this is bitwise equal
  to ``"interpret"`` — but skips Pallas interpreter overhead entirely,
  and at the TREE level fuses per leaf without the FlatSpec
  flatten/unflatten round-trip.  This is why the default CPU path now
  beats the unfused XLA baseline instead of losing to it.

The active backend is auto-detected from ``jax.default_backend()``
(accelerators pass through, anything else becomes ``"cpu"``), can be
seeded via the ``REPRO_KERNEL_BACKEND`` env var, overridden process-wide
with :func:`set_backend` / scoped with :func:`use_backend`, or forced
per-call with the ``backend=`` kwarg (``EngineOptions.kernel_backend``
and ``EngineSpec.kernel_backend`` thread through to it).  The legacy
``interpret=`` kwarg is still honored: ``True`` selects ``"interpret"``,
``False`` selects the detected hardware backend.

Weight contract (see docs/kernels.md): tree-level ``nova_aggregate`` takes
ABSOLUTE dataset sizes and normalizes exactly once; the plane/kernel level
takes already-normalized weights and never re-normalizes.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import fedprox_update as _fp
from repro.kernels import nova_aggregate as _na
from repro.kernels import ref as _ref
from repro.kernels import robust_aggregate as _ra
from repro.kernels.plane import FlatSpec, ParamPlane, spec_of  # noqa: F401
from repro.kernels.tiling import TilePlan, plan_tiles  # noqa: F401

# NOTE: no serving-kernel imports here.  ops.py is on the import path of
# every training module, and swa_decode_attention is a pure re-export
# used only by serving callers — reach it via ``repro.kernels`` (lazy)
# or the defining module.  Keeping ops import-light matters because
# importing it initializes the jax backend (the device probe below),
# which pins the device count before XLA_FLAGS overrides can land.

BACKENDS = ("cpu", "interpret", "gpu", "tpu")

# module-level backend probe: jax.default_backend() initializes the
# platform client (the probe below and the _BACKEND default share it);
# deliberately NOT jax.devices() — the platform name is enough and the
# device list is not needed at import time
_ON_TPU = jax.default_backend() == "tpu"
# Back-compat alias (pre-dispatch callers flag-check this): interpret-or-
# equivalent is the right default everywhere except on real TPUs.
INTERPRET = not _ON_TPU


def detect_backend() -> str:
    """Hardware-detected default: accelerator platforms pass through,
    everything else runs the jitted-ref ``"cpu"`` path."""
    plat = jax.default_backend()
    return plat if plat in ("tpu", "gpu") else "cpu"


def _validate(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; known: {BACKENDS}")
    return backend


_BACKEND = _validate(os.environ.get("REPRO_KERNEL_BACKEND") or
                     detect_backend())


def current_backend() -> str:
    """The process-wide default backend ops dispatch to."""
    return _BACKEND


def set_backend(backend: str) -> str:
    """Set the process-wide default backend (returns it)."""
    global _BACKEND
    _BACKEND = _validate(backend)
    return _BACKEND


@contextlib.contextmanager
def use_backend(backend: str):
    """Scoped :func:`set_backend` (restores the previous default)."""
    global _BACKEND
    prev = _BACKEND
    _BACKEND = _validate(backend)
    try:
        yield _BACKEND
    finally:
        _BACKEND = prev


def resolve_backend(backend: Optional[str] = None,
                    interpret: Optional[bool] = None) -> str:
    """Resolution order: explicit ``backend`` (``"auto"`` defers) >
    legacy ``interpret`` flag > process default."""
    if backend is not None and backend != "auto":
        return _validate(backend)
    if interpret is not None:
        return "interpret" if interpret else detect_backend()
    return _BACKEND


def normalize_weights(weights: Sequence) -> jnp.ndarray:
    """Absolute D_i -> simplex weights (f32).  THE single normalization
    point of the tree-level weight contract (docs/kernels.md); the
    kernel level below takes already-normalized weights.  Re-exported as
    ``core.aggregation.normalize_weights``."""
    w = jnp.asarray(weights, jnp.float32)
    return w / jnp.sum(w)


def _plan_for(backend: str, R: int, L: int, *, n_operands: int, dtype):
    """Tiled plan for accelerator backends; None (legacy whole-array /
    row_tile decomposition) elsewhere."""
    if backend in ("tpu", "gpu"):
        return plan_tiles(R, L, n_operands=n_operands, dtype=jnp.dtype(dtype),
                          backend=backend)
    return None


# jitted pure-jnp fallbacks for the "cpu" backend (bitwise equal to the
# interpret-mode kernels — the kernel bodies are expression-identical)
_fedprox_plane_cpu = jax.jit(_ref.fedprox_update_ref)
_fedprox_accum_cpu = jax.jit(_ref.fedprox_accum_ref)
_nova_plane_cpu = jax.jit(_ref.nova_aggregate_ref)
_robust_plane_cpu = jax.jit(_ref.robust_aggregate_ref,
                            static_argnames=("k", "median"))

ROBUST_MODES = ("trimmed_mean", "median")


def trim_count(n_dpu: int, trim_frac: float) -> int:
    """Per-side trim count for an n_dpu stack: floor(n * frac), clamped so
    at least one value survives (2k < n)."""
    if not 0.0 <= trim_frac < 0.5:
        raise ValueError(f"trim_frac must be in [0, 0.5), got {trim_frac}")
    return min(int(n_dpu * trim_frac), (n_dpu - 1) // 2)


def _tracing(*xs) -> bool:
    """True when any leaf is a tracer — i.e. we're already inside an outer
    jit/scan.  The "cpu" branches then inline the ref expression instead of
    calling the nested-jitted fallback: a jit-inside-jit lowers to an XLA
    call boundary that blocks fusion with the surrounding loop (measurably
    slower inside the round-step fori_loop); inlining keeps the op fusable.
    Eager calls keep the jitted fast path."""
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(xs))


@jax.jit
def _fedprox_tree_cpu(params, grads, anchor, eta, mu):
    return jax.tree_util.tree_map(
        lambda x, g, a: _ref.fedprox_update_ref(x, g, a, eta, mu),
        params, grads, anchor)


@jax.jit
def _nova_tree_cpu(x, d_list, w, theta_eta):
    return jax.tree_util.tree_map(
        lambda xl, *dl: _ref.nova_aggregate_ref(
            xl, jnp.stack(dl), w, theta_eta),
        x, *d_list)


# ------------------------------------------------------ plane level -----

def fedprox_plane(x, g, anchor, eta, mu, *,
                  interpret: Optional[bool] = None,
                  backend: Optional[str] = None):
    """Fused x <- x - eta*(g + mu*(x - anchor)) on (R, LANE) planes."""
    b = resolve_backend(backend, interpret)
    if b == "cpu":
        if _tracing(x, g, anchor):
            return _ref.fedprox_update_ref(x, g, anchor, eta, mu)
        return _fedprox_plane_cpu(x, g, anchor, eta, mu)
    plan = _plan_for(b, *x.shape, n_operands=4, dtype=x.dtype)
    return _fp.fedprox_update_2d(x, g, anchor, eta, mu,
                                 interpret=(b == "interpret"), plan=plan)


def fedprox_accum_plane(x, g, anchor, acc, coef, active, eta, mu, *,
                        interpret: Optional[bool] = None,
                        backend: Optional[str] = None):
    """Batched proximal step + eq.-10 accumulation on (G, R, LANE) planes
    (one launch per local iteration for a whole DPU group)."""
    b = resolve_backend(backend, interpret)
    if b == "cpu":
        coef = jnp.asarray(coef, jnp.float32)
        active = jnp.asarray(active, jnp.float32)
        if _tracing(x, g, anchor, acc, coef, active):
            return _ref.fedprox_accum_ref(x, g, anchor, acc, coef, active,
                                          eta, mu)
        return _fedprox_accum_cpu(x, g, anchor, acc, coef, active, eta, mu)
    # resident blocks per grid step: x, g, anchor, acc, x_new, acc_new
    plan = _plan_for(b, x.shape[1], x.shape[2], n_operands=6, dtype=x.dtype)
    return _fp.fedprox_accum_2d(x, g, anchor, acc, coef, active, eta, mu,
                                interpret=(b == "interpret"), plan=plan)


def nova_aggregate_plane(x, d_stack, weights, theta_eta, *,
                         interpret: Optional[bool] = None,
                         backend: Optional[str] = None):
    """eq. 11 on planes.  ``weights`` must already be normalized.  ``x``
    may be (R, LANE) or (n_dpu, R, LANE) (stacked per-DPU replicas)."""
    b = resolve_backend(backend, interpret)
    if b == "cpu":
        w32 = jnp.asarray(weights, jnp.float32)
        if _tracing(x, d_stack, w32):
            return _ref.nova_aggregate_ref(x, d_stack, w32, theta_eta)
        return _nova_plane_cpu(x, d_stack, w32, theta_eta)
    n = d_stack.shape[0]
    itp = b == "interpret"
    if x.ndim == 3:
        # resident: x/out keep the n-stack, d streams one tile, + scratch
        plan = _plan_for(b, x.shape[1], x.shape[2],
                         n_operands=2 * n + 2, dtype=x.dtype)
        return _na.nova_aggregate_stacked_2d(x, d_stack, weights, theta_eta,
                                             interpret=itp, plan=plan)
    plan = _plan_for(b, *x.shape, n_operands=4, dtype=x.dtype)
    return _na.nova_aggregate_2d(x, d_stack, weights, theta_eta,
                                 interpret=itp, plan=plan)


def robust_aggregate_plane(x, d_stack, theta_eta, *,
                           mode: str = "trimmed_mean",
                           trim_frac: float = 0.1,
                           interpret: Optional[bool] = None,
                           backend: Optional[str] = None):
    """Byzantine-robust eq. 11 on planes: x - theta_eta * reduce(d_stack)
    with a coordinate-wise trimmed mean (``mode="trimmed_mean"``) or
    median (``mode="median"``) over the DPU axis.  UNWEIGHTED by design —
    dataset-size weights are the lever a byzantine client inflates."""
    if mode not in ROBUST_MODES:
        raise ValueError(
            f"unknown robust mode {mode!r}; known: {ROBUST_MODES}")
    median = mode == "median"
    k = 0 if median else trim_count(d_stack.shape[0], trim_frac)
    b = resolve_backend(backend, interpret)
    if b == "cpu":
        if _tracing(x, d_stack):
            return _ref.robust_aggregate_ref(x, d_stack, theta_eta,
                                             k=k, median=median)
        return _robust_plane_cpu(x, d_stack, theta_eta, k=k, median=median)
    # the sort needs the full DPU stack resident per (rows, lanes) tile
    plan = _plan_for(b, *x.shape, n_operands=d_stack.shape[0] + 3,
                     dtype=x.dtype)
    return _ra.robust_aggregate_2d(x, d_stack, theta_eta, k=k,
                                   median=median, interpret=(b == "interpret"),
                                   plan=plan)


# ------------------------------------------------------- tree level -----

def fedprox_update(params, grads, anchor, eta, mu, *,
                   interpret: Optional[bool] = None,
                   backend: Optional[str] = None):
    """Fused x <- x - eta*(g + mu*(x - anchor)) over a whole pytree."""
    b = resolve_backend(backend, interpret)
    if b == "cpu":
        # per-leaf fused jnp — no FlatSpec flatten/unflatten round-trip
        if _tracing(params, grads, anchor):
            return jax.tree_util.tree_map(
                lambda x, g, a: _ref.fedprox_update_ref(x, g, a, eta, mu),
                params, grads, anchor)
        return _fedprox_tree_cpu(params, grads, anchor, eta, mu)
    spec = spec_of(params)
    out = fedprox_plane(spec.flatten(params), spec.flatten(grads),
                        spec.flatten(anchor), eta, mu, backend=b)
    return spec.unflatten(out)


def nova_aggregate(x, d_list: Sequence, weights, theta_eta, *,
                   interpret: Optional[bool] = None,
                   backend: Optional[str] = None):
    """x <- x - theta*eta*sum_i w_i d_i over pytrees (eq. 11).

    ``weights``: absolute dataset sizes D_i — normalized here (the single
    normalization point for this path, see docs/kernels.md).
    """
    b = resolve_backend(backend, interpret)
    w = normalize_weights(weights)
    if b == "cpu":
        if _tracing(x, list(d_list), w):
            return jax.tree_util.tree_map(
                lambda xl, *dl: _ref.nova_aggregate_ref(
                    xl, jnp.stack(dl), w, theta_eta), x, *d_list)
        return _nova_tree_cpu(x, list(d_list), w, theta_eta)
    spec = spec_of(x)
    d_stack = jnp.stack([spec.flatten(d) for d in d_list], axis=0)
    out = nova_aggregate_plane(spec.flatten(x), d_stack, w, theta_eta,
                               backend=b)
    return spec.unflatten(out)
