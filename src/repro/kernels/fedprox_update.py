"""Pallas TPU kernels: fused FedProx local update (paper eqs. 5-6, 8-10).

    x_new   = x - active * eta * (g + mu * (x - anchor))
    acc_new = acc + active * a_k * g            # eq. 10 numerator

Unfused, XLA emits sub/mul/add chains with 5 HBM reads + 3 writes over
params-sized buffers; the fused kernel does 3 reads + 1 write per element in
one VMEM pass.  This op runs every local SGD iteration of every DPU, on
every parameter — the highest-frequency elementwise hot spot in CE-FL.

Layout: parameters live on the flat parameter plane (see ``plane.py``):
(R, LANE) f32 with R a multiple of 8.  On TPU the row tile is the largest
power-of-two multiple of 8 dividing R (capped at ROWS=256): tiles of
(256, 1024) f32 keep 3 x 1MB operands per step comfortably in VMEM while
the last dim stays a multiple of the 128-lane register width.  In
interpret mode (CPU fallback) the grid collapses to a SINGLE whole-array
block: the interpreter's per-grid-step cost is a full-buffer copy, so one
fused step is the fast path and the same pallas_call lowers to plain XLA
elementwise ops under jit.

Two entry points:

* :func:`fedprox_update_2d` — single plane, plain eq. 5-6 update.
* :func:`fedprox_accum_2d` — the batched ``(G, R, LANE)`` variant used by
  the group/mesh hot paths: one launch updates every DPU of the group AND
  folds the per-step FedNova coefficient ``a_k`` and the activity mask
  into the eq.-10 accumulator, so a local iteration is one kernel launch
  instead of a per-leaf tree_map chain plus a separate accumulation pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024          # last-dim tile (multiple of 128)
ROWS = 256           # max rows per tile (multiple of 8)


def row_tile(r: int, cap: int = ROWS) -> int:
    """Largest power-of-two multiple of 8 dividing ``r`` (<= cap)."""
    assert r % 8 == 0, r    # repro: noqa(RPA004) r is a static row count (plane shape), never a tracer
    t = 8
    while t * 2 <= cap and r % (t * 2) == 0:    # repro: noqa(RPA004) static tile-size arithmetic on concrete ints
        t *= 2
    return t


def _kernel(x_ref, g_ref, a_ref, eta_ref, mu_ref, o_ref):
    eta = eta_ref[0, 0]
    mu = mu_ref[0, 0]
    x = x_ref[...]
    g = g_ref[...]
    a = a_ref[...]
    xf = x.astype(jnp.float32)
    upd = xf - eta * (g.astype(jnp.float32) + mu * (xf - a.astype(jnp.float32)))
    o_ref[...] = upd.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedprox_update_2d(x, g, anchor, eta, mu, *, interpret: bool = False):
    """x, g, anchor: (R, LANE) with R % 8 == 0."""
    R, L = x.shape
    assert L == LANE and R % 8 == 0, (R, L)
    rows = R if interpret else row_tile(R)
    grid = (R // rows,)
    spec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    eta = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    mu = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec,
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, g, anchor, eta, mu)


def _accum_kernel(x_ref, g_ref, anc_ref, acc_ref, coef_ref, act_ref,
                  eta_ref, mu_ref, ox_ref, oacc_ref):
    eta = eta_ref[0, 0]
    mu = mu_ref[0, 0]
    a_k = coef_ref[0, :][:, None, None]         # (gblk, 1, 1)
    act = act_ref[0, :][:, None, None]
    x = x_ref[...].astype(jnp.float32)          # (gblk, rows, LANE)
    g = g_ref[...].astype(jnp.float32)
    anc = anc_ref[...].astype(jnp.float32)      # (rows, LANE) or (gblk, ...)
    upd = x - act * eta * (g + mu * (x - anc))
    ox_ref[...] = upd.astype(ox_ref.dtype)
    oacc_ref[...] = (acc_ref[...].astype(jnp.float32)
                     + (act * a_k) * g).astype(oacc_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedprox_accum_2d(x, g, anchor, acc, coef, active, eta, mu, *,
                     interpret: bool = False):
    """Batched fused proximal step + eq.-10 accumulation.

    x, g, acc: (G, R, LANE); anchor: (R, LANE) shared or (G, R, LANE)
    per-DPU; coef, active: (G,) per-DPU a_{i,k} and activity mask.
    Returns (x_new, acc_new), both (G, R, LANE):

        x_new   = x - active*eta*(g + mu*(x - anchor))
        acc_new = acc + active*coef*g
    """
    G, R, L = x.shape
    assert L == LANE and R % 8 == 0, (G, R, L)
    assert g.shape == x.shape and acc.shape == x.shape
    if interpret:
        gblk, rows = G, R            # one whole-array block (see module doc)
    else:
        gblk, rows = 1, row_tile(R)  # VMEM-sized tiles, one DPU per step
    grid = (G // gblk, R // rows)
    bspec = pl.BlockSpec((gblk, rows, LANE), lambda i, j: (i, j, 0))
    if anchor.ndim == 2:
        aspec = pl.BlockSpec((rows, LANE), lambda i, j: (j, 0))
    else:
        assert anchor.shape == x.shape
        aspec = bspec
    pspec = pl.BlockSpec((1, gblk), lambda i, j: (0, i))  # per-group scalars
    sspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    coef = jnp.asarray(coef, jnp.float32).reshape(1, G)
    active = jnp.asarray(active, jnp.float32).reshape(1, G)
    eta = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    mu = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[bspec, bspec, aspec, bspec, pspec, pspec, sspec, sspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct(acc.shape, acc.dtype)],
        interpret=interpret,
    )(x, g, anchor, acc, coef, active, eta, mu)
