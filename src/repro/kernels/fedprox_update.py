"""Pallas TPU kernels: fused FedProx local update (paper eqs. 5-6, 8-10).

    x_new   = x - active * eta * (g + mu * (x - anchor))
    acc_new = acc + active * a_k * g            # eq. 10 numerator

Unfused, XLA emits sub/mul/add chains with 5 HBM reads + 3 writes over
params-sized buffers; the fused kernel does 3 reads + 1 write per element in
one VMEM pass.  This op runs every local SGD iteration of every DPU, on
every parameter — the highest-frequency elementwise hot spot in CE-FL.

Layout: parameters live on the flat parameter plane (see ``plane.py``):
(R, LANE) f32 with R a multiple of 8.  Compiled launches run a 2-D
(row-tile x lane-tile) grid whose block extents come from a
:class:`~repro.kernels.tiling.TilePlan` — sized against the target
memory space's byte budget (TPU VMEM / GPU SMEM) from the operand count
and dtype, with ``pl.cdiv`` grids padding edge blocks when the plane
extents don't divide the tile.  Mosaic double-buffers the streamed
blocks (two live copies per operand), so the next tile's DMA overlaps
the current tile's compute.

In interpret mode (the CPU fallback, ``plan=None``) the grid collapses
to a SINGLE whole-array block: the interpreter's per-grid-step cost is a
full-buffer copy, so one fused step is the fast path and the same
pallas_call lowers to plain XLA elementwise ops under jit.  (Passing an
explicit tiled ``plan`` with ``interpret=True`` runs the tiled grid in
the interpreter — that is the parity-test path for the compiled
decomposition.)

Backend selection — which of these paths a caller gets — lives in ONE
place: the dispatch layer in ``ops.py``.  Callers should not pick
``interpret``/``plan`` by hand outside tests.

Two entry points:

* :func:`fedprox_update_2d` — single plane, plain eq. 5-6 update.
* :func:`fedprox_accum_2d` — the batched ``(G, R, LANE)`` variant used by
  the group/mesh hot paths: one launch updates every DPU of the group AND
  folds the per-step FedNova coefficient ``a_k`` and the activity mask
  into the eq.-10 accumulator, so a local iteration is one kernel launch
  instead of a per-leaf tree_map chain plus a separate accumulation pass.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import TilePlan

LANE = 1024          # last-dim tile (multiple of 128)
ROWS = 256           # max rows per tile (multiple of 8)


def row_tile(r: int, cap: int = ROWS) -> int:
    """Largest power-of-two multiple of 8 dividing ``r`` (<= cap)."""
    assert r % 8 == 0, r    # repro: noqa(RPA004) r is a static row count (plane shape), never a tracer
    t = 8
    while t * 2 <= cap and r % (t * 2) == 0:    # repro: noqa(RPA004) static tile-size arithmetic on concrete ints
        t *= 2
    return t


def _default_plan(R: int, interpret: bool) -> TilePlan:
    """The no-plan fallbacks: whole-array block in interpret mode (see
    module doc), legacy ``row_tile`` decomposition otherwise."""
    if interpret:    # repro: noqa(RPA004) interpret is a jit-static flag, never a tracer
        return TilePlan(rows=R, lanes=LANE, backend="interpret")
    return TilePlan(rows=row_tile(R), lanes=LANE, backend="tpu")


def _compiler_params(plan: TilePlan, interpret: bool, semantics):
    """Mosaic dimension semantics for compiled TPU launches (the grid
    dims of these kernels are embarrassingly parallel unless marked)."""
    if interpret or plan.backend != "tpu":    # repro: noqa(RPA004) static flag + static plan metadata
        return None
    return pltpu.TPUCompilerParams(dimension_semantics=semantics)


def _kernel(x_ref, g_ref, a_ref, eta_ref, mu_ref, o_ref):
    eta = eta_ref[0, 0]
    mu = mu_ref[0, 0]
    x = x_ref[...]
    g = g_ref[...]
    a = a_ref[...]
    xf = x.astype(jnp.float32)
    upd = xf - eta * (g.astype(jnp.float32) + mu * (xf - a.astype(jnp.float32)))
    o_ref[...] = upd.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "plan"))
def fedprox_update_2d(x, g, anchor, eta, mu, *, interpret: bool = False,
                      plan: Optional[TilePlan] = None):
    """x, g, anchor: (R, LANE) with R % 8 == 0."""
    R, L = x.shape
    assert L == LANE and R % 8 == 0, (R, L)
    plan = plan or _default_plan(R, interpret)
    rows, lanes = plan.rows, plan.lanes
    grid = (pl.cdiv(R, rows), pl.cdiv(L, lanes))
    spec = pl.BlockSpec((rows, lanes), lambda i, j: (i, j))
    sspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    eta = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    mu = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec, sspec, sspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
        compiler_params=_compiler_params(plan, interpret,
                                         ("parallel", "parallel")),
    )(x, g, anchor, eta, mu)


def _accum_kernel(x_ref, g_ref, anc_ref, acc_ref, coef_ref, act_ref,
                  eta_ref, mu_ref, ox_ref, oacc_ref):
    eta = eta_ref[0, 0]
    mu = mu_ref[0, 0]
    a_k = coef_ref[0, :][:, None, None]         # (gblk, 1, 1)
    act = act_ref[0, :][:, None, None]
    x = x_ref[...].astype(jnp.float32)          # (gblk, rows, lanes)
    g = g_ref[...].astype(jnp.float32)
    anc = anc_ref[...].astype(jnp.float32)      # (rows, lanes) or (gblk, ...)
    upd = x - act * eta * (g + mu * (x - anc))
    ox_ref[...] = upd.astype(ox_ref.dtype)
    oacc_ref[...] = (acc_ref[...].astype(jnp.float32)
                     + (act * a_k) * g).astype(oacc_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "plan"))
def fedprox_accum_2d(x, g, anchor, acc, coef, active, eta, mu, *,
                     interpret: bool = False,
                     plan: Optional[TilePlan] = None):
    """Batched fused proximal step + eq.-10 accumulation.

    x, g, acc: (G, R, LANE); anchor: (R, LANE) shared or (G, R, LANE)
    per-DPU; coef, active: (G,) per-DPU a_{i,k} and activity mask.
    Returns (x_new, acc_new), both (G, R, LANE):

        x_new   = x - active*eta*(g + mu*(x - anchor))
        acc_new = acc + active*coef*g
    """
    G, R, L = x.shape
    assert L == LANE and R % 8 == 0, (G, R, L)
    assert g.shape == x.shape and acc.shape == x.shape
    plan = plan or _default_plan(R, interpret)
    if interpret and plan.backend == "interpret":
        gblk = G                     # one whole-array block (see module doc)
    else:
        gblk = 1                     # memory-budget tiles, one DPU per step
    rows, lanes = min(plan.rows, R), plan.lanes
    grid = (G // gblk, pl.cdiv(R, rows), pl.cdiv(L, lanes))
    bspec = pl.BlockSpec((gblk, rows, lanes), lambda i, j, k: (i, j, k))
    if anchor.ndim == 2:
        aspec = pl.BlockSpec((rows, lanes), lambda i, j, k: (j, k))
    else:
        assert anchor.shape == x.shape
        aspec = bspec
    pspec = pl.BlockSpec((1, gblk), lambda i, j, k: (0, i))  # per-DPU scalars
    sspec = pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))
    coef = jnp.asarray(coef, jnp.float32).reshape(1, G)
    active = jnp.asarray(active, jnp.float32).reshape(1, G)
    eta = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    mu = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[bspec, bspec, aspec, bspec, pspec, pspec, sspec, sspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct(acc.shape, acc.dtype)],
        interpret=interpret,
        compiler_params=_compiler_params(
            plan, interpret, ("parallel", "parallel", "parallel")),
    )(x, g, anchor, acc, coef, active, eta, mu)
