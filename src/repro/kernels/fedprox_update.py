"""Pallas TPU kernel: fused FedProx local update (paper eqs. 5-6).

    x_new = x - eta * (g + mu * (x - anchor))

Unfused, XLA emits sub/mul/add chains with 5 HBM reads + 3 writes over
params-sized buffers; the fused kernel does 3 reads + 1 write per element in
one VMEM pass.  This op runs every local SGD iteration of every DPU, on
every parameter — the highest-frequency elementwise hot spot in CE-FL.

Layout: parameters are flattened and padded to (rows, 1024) with rows a
multiple of 8; tiles of (256, 1024) f32 = 3 x 1MB operands per step fit VMEM
comfortably (v5e ~128MB VMEM per core) while keeping the last dim a multiple
of the 128-lane register width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024          # last-dim tile (multiple of 128)
ROWS = 256           # rows per tile (multiple of 8)


def _kernel(x_ref, g_ref, a_ref, eta_ref, mu_ref, o_ref):
    eta = eta_ref[0, 0]
    mu = mu_ref[0, 0]
    x = x_ref[...]
    g = g_ref[...]
    a = a_ref[...]
    xf = x.astype(jnp.float32)
    upd = xf - eta * (g.astype(jnp.float32) + mu * (xf - a.astype(jnp.float32)))
    o_ref[...] = upd.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedprox_update_2d(x, g, anchor, eta, mu, *, interpret: bool = False):
    """x, g, anchor: (R, LANE) with R % ROWS == 0."""
    R, L = x.shape
    assert L == LANE and R % ROWS == 0, (R, L)
    grid = (R // ROWS,)
    spec = pl.BlockSpec((ROWS, LANE), lambda i: (i, 0))
    eta = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    mu = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec,
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, g, anchor, eta, mu)
