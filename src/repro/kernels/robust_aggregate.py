"""Pallas kernel: coordinate-wise robust aggregation (byzantine counter).

    x_new = x - theta * eta * reduce(d_stack)

where ``reduce`` is the coordinate-wise k-trimmed mean or median over the
DPU axis — the classical byzantine-robust replacements for the weighted
eq.-11 sum (Yin et al. 2018).  Unlike ``nova_aggregate``, the reduction
is UNWEIGHTED: dataset-size weights are exactly what a malicious client
can inflate to dominate the average, so the robust path ignores them.

Kernel shape: the reduce needs every DPU's value of a coordinate at once
(a sort along the stack axis), so the DPU axis can never be a grid
dimension the way the nova grid-accumulation streams it.  Instead the
grid tiles the (rows, lanes) plane and each step loads the full
``(n_dpu, rows, lanes)`` d block and sorts in-register — fine for the
n_dpu counts a robust quorum makes sense at (tens), and the
:class:`~repro.kernels.tiling.TilePlan` budget accounts the n-fold
resident block (``ops.robust_aggregate_plane`` passes
``n_operands = n + 3``).  Like the PR-7 tiled grids, the compiled form
is parity-tested through the Pallas interpreter; real-hardware runs go
through the same ``ops.py`` dispatch.

``k``/``median`` are static (they shape the sort-trim expression); the
trim fraction is resolved to ``k`` once in ``ops.robust_aggregate_plane``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fedprox_update import _compiler_params, row_tile
from repro.kernels.ref import robust_reduce_ref
from repro.kernels.tiling import TilePlan

LANE = 1024
ROWS = 128


def _kernel(x_ref, d_ref, se_ref, o_ref, *, k: int, median: bool):
    d = d_ref[...].astype(jnp.float32)        # (n_dpu, rows, lanes)
    red = robust_reduce_ref(d, k=k, median=median)
    o_ref[...] = (x_ref[...].astype(jnp.float32)
                  - se_ref[0, 0] * red).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("k", "median", "interpret", "plan"))
def robust_aggregate_2d(x, d_stack, theta_eta, *, k: int = 0,
                        median: bool = False, interpret: bool = False,
                        plan: Optional[TilePlan] = None):
    """x: (R, LANE); d_stack: (n_dpu, R, LANE).  Returns
    x - theta_eta * trimmed_mean/median(d_stack, axis=0)."""
    R, L = x.shape
    n = d_stack.shape[0]
    assert L == LANE and R % 8 == 0 and d_stack.shape == (n, R, L)
    se = jnp.asarray(theta_eta, jnp.float32).reshape(1, 1)
    body = functools.partial(_kernel, k=k, median=median)
    if plan is None:
        rows = R if interpret else row_tile(R, ROWS)
        grid = (R // rows,)
        xspec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
        dspec = pl.BlockSpec((n, rows, LANE), lambda i: (0, i, 0))
        sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
        return pl.pallas_call(
            body,
            grid=grid,
            in_specs=[xspec, dspec, sspec],
            out_specs=xspec,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x, d_stack, se)
    rows, lanes = min(plan.rows, R), plan.lanes
    grid = (pl.cdiv(R, rows), pl.cdiv(L, lanes))
    xspec = pl.BlockSpec((rows, lanes), lambda i, j: (i, j))
    dspec = pl.BlockSpec((n, rows, lanes), lambda i, j: (0, i, j))
    sspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[xspec, dspec, sspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
        compiler_params=_compiler_params(
            plan, interpret, ("parallel", "parallel")),
    )(x, d_stack, se)
