# Pallas TPU kernels for CE-FL's per-round compute hot spots + serving.
# <name>.py: pl.pallas_call + BlockSpec; ops.py: jitted wrappers;
# plane.py: the canonical flat (R, LANE) parameter layout the kernels eat;
# ref.py: pure-jnp oracles (tests assert allclose across shape/dtype sweeps).
from repro.kernels import ops, plane, ref  # noqa: F401
from repro.kernels.plane import (  # noqa: F401
    FlatSpec, ParamPlane, as_plane, as_tree, spec_of,
)
