# Pallas TPU kernels for CE-FL's per-round compute hot spots + serving.
# <name>.py: pl.pallas_call + BlockSpec; ops.py: jitted wrappers;
# plane.py: the canonical flat (R, LANE) parameter layout the kernels eat;
# ref.py: pure-jnp oracles (tests assert allclose across shape/dtype sweeps).
from repro.kernels import ops, plane, ref  # noqa: F401
from repro.kernels.plane import (  # noqa: F401
    FlatSpec, ParamPlane, as_plane, as_tree, spec_of,
)

__all__ = [
    "ops", "plane", "ref",
    "FlatSpec", "ParamPlane", "as_plane", "as_tree", "spec_of",
    "swa_decode_attention",
]


def __getattr__(name):
    # serving-only kernel: loaded on first use so training imports never
    # pay for (or fail on) the attention module
    if name == "swa_decode_attention":
        from repro.kernels.swa_decode_attention import swa_decode_attention
        return swa_decode_attention
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
