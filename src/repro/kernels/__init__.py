# Pallas TPU kernels for CE-FL's per-round compute hot spots + serving.
# <name>.py: pl.pallas_call + BlockSpec; ops.py: jitted wrappers;
# ref.py: pure-jnp oracles (tests assert allclose across shape/dtype sweeps).
from repro.kernels import ops, ref  # noqa: F401
