"""Pure-jnp oracles for every Pallas kernel (shape-exact references used by
the allclose test sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fedprox_update_ref(x, g, anchor, eta, mu):
    xf = x.astype(jnp.float32)
    out = xf - eta * (g.astype(jnp.float32)
                      + mu * (xf - anchor.astype(jnp.float32)))
    return out.astype(x.dtype)


def fedprox_accum_ref(x, g, anchor, acc, coef, active, eta, mu):
    """Batched proximal step + eq.-10 accumulation (fedprox_accum_2d).
    x, g, acc: (G, R, L); anchor: (R, L) or (G, R, L); coef/active: (G,)."""
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    anc = anchor.astype(jnp.float32)
    if anc.ndim == 2:
        anc = anc[None]
    act = active.astype(jnp.float32)[:, None, None]
    ak = coef.astype(jnp.float32)[:, None, None]
    x_new = xf - act * eta * (gf + mu * (xf - anc))
    acc_new = acc.astype(jnp.float32) + act * ak * gf
    return x_new.astype(x.dtype), acc_new.astype(acc.dtype)


def nova_aggregate_ref(x, d_stack, weights, theta_eta):
    agg = jnp.einsum("n,n...->...", weights.astype(jnp.float32),
                     d_stack.astype(jnp.float32))
    return (x.astype(jnp.float32) - theta_eta * agg).astype(x.dtype)


def robust_reduce_ref(d_stack, *, k: int = 0, median: bool = False):
    """Coordinate-wise robust location estimate over the DPU axis.

    ``median=True``: the coordinate-wise median; otherwise the k-trimmed
    mean (drop the k smallest and k largest per coordinate — requires
    2k < n).  Unweighted by design: dataset-size weights are exactly the
    lever a byzantine client can inflate.
    """
    d = jnp.sort(d_stack.astype(jnp.float32), axis=0)
    n = d.shape[0]
    if median:
        mid = n // 2
        return d[mid] if n % 2 else 0.5 * (d[mid - 1] + d[mid])
    if not 0 <= 2 * k < n:
        raise ValueError(f"trim k={k} needs 0 <= 2k < n={n}")
    return jnp.mean(d[k:n - k], axis=0)


def robust_aggregate_ref(x, d_stack, theta_eta, *, k: int = 0,
                         median: bool = False):
    """eq. 11 with the weighted sum replaced by a robust reduce:
    x - theta*eta*robust_reduce(d_stack)."""
    red = robust_reduce_ref(d_stack, k=k, median=median)
    return (x.astype(jnp.float32) - theta_eta * red).astype(x.dtype)


def swa_decode_attention_ref(q, k_cache, v_cache, cache_len):
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg,
                   k_cache.astype(jnp.float32)) / jnp.sqrt(float(D))
    pos = jnp.arange(S)
    s = jnp.where((pos < cache_len)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)
