"""Pallas TPU kernel: floating-point aggregation update (paper eq. 11).

    x_new = x - theta * eta * sum_i w_i * d_i

The aggregation DC applies this to the stacked scaled accumulated gradients
it received (post-collective, the D_i/D weights folded into w).  Fusing the
weighted reduction with the model update avoids materializing sum_i w_i d_i
in HBM: one pass reads the gradient tiles plus the x tile and writes x_new.

Weight contract: ``weights`` here are ALREADY NORMALIZED (sum to 1) — the
kernels never re-normalize.  Tree/plane-level wrappers (``ops.py``,
``core.aggregation``) take absolute D_i sizes and normalize exactly once
via ``core.aggregation.normalize_weights`` (see docs/kernels.md).

Two kernel families:

* **Whole-stack einsum** (``plan=None``): each grid step loads the full
  (n_dpu, rows, LANE) d block and reduces with one einsum.  Fine in
  interpret mode (one whole-array block — see ``fedprox_update.py``) and
  for small n_dpu, but the resident block grows linearly with n_dpu.
* **Grid accumulation** (``plan`` given): the DPU axis becomes the
  innermost grid dimension.  Each step streams ONE (rows, lanes) d tile,
  a float32 scratch accumulator (``pltpu.VMEM`` scratch shape) is
  zero-initialized under ``@pl.when(k == 0)`` and flushed into the
  output under ``@pl.when(k == n-1)``, so resident bytes are independent
  of n_dpu and Mosaic overlaps the next tile's DMA with the current
  accumulate.  Row/lane extents come from the :class:`TilePlan` (sized
  for the backend memory budget); edge blocks are padded via ``pl.cdiv``
  grids.

Backend/plan selection is centralized in ``ops.py`` — callers should not
pick ``interpret``/``plan`` by hand outside tests.

Two entry points:

* :func:`nova_aggregate_2d` — single global plane x: (R, LANE).
* :func:`nova_aggregate_stacked_2d` — x: (n_dpu, R, LANE), each row
  updated with the SAME weighted reduction (the mesh round keeps one
  replica of the global model per DPU row).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fedprox_update import _compiler_params, row_tile
from repro.kernels.tiling import TilePlan

LANE = 1024
ROWS = 128


def _kernel(x_ref, d_ref, w_ref, se_ref, o_ref):
    scale = se_ref[0, 0]                     # theta * eta
    x = x_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)       # (n_dpu, rows, LANE)
    w = w_ref[0, :]                           # (n_dpu,)
    agg = jnp.einsum("n,nrl->rl", w, d)
    o_ref[...] = (x - scale * agg).astype(o_ref.dtype)


def _kernel_acc(x_ref, d_ref, w_ref, se_ref, o_ref, acc_ref):
    """Grid-accumulation body: DPU axis = innermost grid dim k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += w_ref[0, 0] * d_ref[0].astype(jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        scale = se_ref[0, 0]
        o_ref[...] = (x_ref[...].astype(jnp.float32)
                      - scale * acc_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "plan"))
def nova_aggregate_2d(x, d_stack, weights, theta_eta, *,
                      interpret: bool = False,
                      plan: Optional[TilePlan] = None):
    """x: (R, LANE); d_stack: (n_dpu, R, LANE); weights: (n_dpu,),
    normalized (sum to 1)."""
    R, L = x.shape
    n = d_stack.shape[0]
    assert L == LANE and R % 8 == 0 and d_stack.shape == (n, R, L)
    w = weights.reshape(1, n).astype(jnp.float32)
    se = jnp.asarray(theta_eta, jnp.float32).reshape(1, 1)
    if plan is None:
        # legacy whole-stack einsum decomposition
        rows = R if interpret else row_tile(R, ROWS)
        grid = (R // rows,)
        xspec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
        dspec = pl.BlockSpec((n, rows, LANE), lambda i: (0, i, 0))
        wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
        sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
        return pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[xspec, dspec, wspec, sspec],
            out_specs=xspec,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x, d_stack, w, se)
    rows, lanes = min(plan.rows, R), plan.lanes
    grid = (pl.cdiv(R, rows), pl.cdiv(L, lanes), n)
    xspec = pl.BlockSpec((rows, lanes), lambda i, j, k: (i, j))
    dspec = pl.BlockSpec((1, rows, lanes), lambda i, j, k: (k, i, j))
    wspec = pl.BlockSpec((1, 1), lambda i, j, k: (0, k))
    sspec = pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))
    return pl.pallas_call(
        _kernel_acc,
        grid=grid,
        in_specs=[xspec, dspec, wspec, sspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((rows, lanes), jnp.float32)],
        interpret=interpret,
        compiler_params=_compiler_params(
            plan, interpret, ("parallel", "parallel", "arbitrary")),
    )(x, d_stack, w, se)


def _kernel_stacked(x_ref, d_ref, w_ref, se_ref, o_ref):
    scale = se_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)        # (n_dpu, rows, LANE)
    d = d_ref[...].astype(jnp.float32)        # (n_dpu, rows, LANE)
    w = w_ref[0, :]
    agg = jnp.einsum("n,nrl->rl", w, d)
    o_ref[...] = (x - scale * agg[None]).astype(o_ref.dtype)


def _kernel_stacked_acc(x_ref, d_ref, w_ref, se_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += w_ref[0, 0] * d_ref[0].astype(jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        scale = se_ref[0, 0]
        o_ref[...] = (x_ref[...].astype(jnp.float32)
                      - scale * acc_ref[...][None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "plan"))
def nova_aggregate_stacked_2d(x, d_stack, weights, theta_eta, *,
                              interpret: bool = False,
                              plan: Optional[TilePlan] = None):
    """x, d_stack: (n_dpu, R, LANE); weights: (n_dpu,), normalized.  Every
    row of x receives the same eq.-11 update (per-DPU global replicas)."""
    n, R, L = x.shape
    assert L == LANE and R % 8 == 0 and d_stack.shape == (n, R, L)
    w = weights.reshape(1, n).astype(jnp.float32)
    se = jnp.asarray(theta_eta, jnp.float32).reshape(1, 1)
    if plan is None:
        rows = R if interpret else row_tile(R, ROWS)
        grid = (R // rows,)
        xspec = pl.BlockSpec((n, rows, LANE), lambda i: (0, i, 0))
        wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
        sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
        return pl.pallas_call(
            _kernel_stacked,
            grid=grid,
            in_specs=[xspec, xspec, wspec, sspec],
            out_specs=xspec,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x, d_stack, w, se)
    rows, lanes = min(plan.rows, R), plan.lanes
    grid = (pl.cdiv(R, rows), pl.cdiv(L, lanes), n)
    # x/out keep the full stack per block (the replicas all receive the
    # same update); only d is streamed one DPU tile at a time.
    xspec = pl.BlockSpec((n, rows, lanes), lambda i, j, k: (0, i, j))
    dspec = pl.BlockSpec((1, rows, lanes), lambda i, j, k: (k, i, j))
    wspec = pl.BlockSpec((1, 1), lambda i, j, k: (0, k))
    sspec = pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))
    return pl.pallas_call(
        _kernel_stacked_acc,
        grid=grid,
        in_specs=[xspec, dspec, wspec, sspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((rows, lanes), jnp.float32)],
        interpret=interpret,
        compiler_params=_compiler_params(
            plan, interpret, ("parallel", "parallel", "arbitrary")),
    )(x, d_stack, w, se)
