"""Pallas TPU kernel: floating-point aggregation update (paper eq. 11).

    x_new = x - theta * eta * sum_i w_i * d_i

The aggregation DC applies this to the stacked scaled accumulated gradients
it received (post-collective, the D_i/D weights folded into w).  Fusing the
weighted reduction with the model update avoids materializing sum_i w_i d_i
in HBM: one pass reads the (n_dpu, block) gradient tile plus the x tile and
writes x_new.

Weight contract: ``weights`` here are ALREADY NORMALIZED (sum to 1) — the
kernels never re-normalize.  Tree/plane-level wrappers (``ops.py``,
``core.aggregation``) take absolute D_i sizes and normalize exactly once
via ``core.aggregation.normalize_weights`` (see docs/kernels.md).

Tiles: (n_dpu, ROWS<=128, LANE=1024) f32 -> n_dpu x 512KB + 512KB in VMEM;
fine for n_dpu <= ~64.  Planes with fewer rows use the largest
power-of-two row tile that divides R (see ``fedprox_update.row_tile``).

Two entry points:

* :func:`nova_aggregate_2d` — single global plane x: (R, LANE).
* :func:`nova_aggregate_stacked_2d` — x: (n_dpu, R, LANE), each row
  updated with the SAME weighted reduction (the mesh round keeps one
  replica of the global model per DPU row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fedprox_update import row_tile

LANE = 1024
ROWS = 128


def _kernel(x_ref, d_ref, w_ref, se_ref, o_ref):
    scale = se_ref[0, 0]                     # theta * eta
    x = x_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)       # (n_dpu, rows, LANE)
    w = w_ref[0, :]                           # (n_dpu,)
    agg = jnp.einsum("n,nrl->rl", w, d)
    o_ref[...] = (x - scale * agg).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def nova_aggregate_2d(x, d_stack, weights, theta_eta, *,
                      interpret: bool = False):
    """x: (R, LANE); d_stack: (n_dpu, R, LANE); weights: (n_dpu,),
    normalized (sum to 1)."""
    R, L = x.shape
    n = d_stack.shape[0]
    assert L == LANE and R % 8 == 0 and d_stack.shape == (n, R, L)
    rows = R if interpret else row_tile(R, ROWS)
    grid = (R // rows,)
    xspec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    dspec = pl.BlockSpec((n, rows, LANE), lambda i: (0, i, 0))
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[xspec, dspec, wspec, sspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, d_stack, weights.reshape(1, n).astype(jnp.float32),
      jnp.asarray(theta_eta, jnp.float32).reshape(1, 1))


def _kernel_stacked(x_ref, d_ref, w_ref, se_ref, o_ref):
    scale = se_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)        # (n_dpu, rows, LANE)
    d = d_ref[...].astype(jnp.float32)        # (n_dpu, rows, LANE)
    w = w_ref[0, :]
    agg = jnp.einsum("n,nrl->rl", w, d)
    o_ref[...] = (x - scale * agg[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def nova_aggregate_stacked_2d(x, d_stack, weights, theta_eta, *,
                              interpret: bool = False):
    """x, d_stack: (n_dpu, R, LANE); weights: (n_dpu,), normalized.  Every
    row of x receives the same eq.-11 update (per-DPU global replicas)."""
    n, R, L = x.shape
    assert L == LANE and R % 8 == 0 and d_stack.shape == (n, R, L)
    rows = R if interpret else row_tile(R, ROWS)
    grid = (R // rows,)
    xspec = pl.BlockSpec((n, rows, LANE), lambda i: (0, i, 0))
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _kernel_stacked,
        grid=grid,
        in_specs=[xspec, xspec, wspec, sspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, d_stack, weights.reshape(1, n).astype(jnp.float32),
      jnp.asarray(theta_eta, jnp.float32).reshape(1, 1))
