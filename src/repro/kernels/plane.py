"""Flat parameter-plane representation: the canonical layout the Pallas
kernels operate on.

A pytree of parameters/gradients is stored ONCE as a padded ``(R, LANE)``
float32 plane plus a static :class:`FlatSpec` (treedef, leaf shapes/dtypes,
offsets) computed at model init and cached per structure.  Every
parameter-sized elementwise op of the CE-FL round — the proximal update
(eqs. 5-6), the FedNova-weighted accumulation (eqs. 8-10), and the
floating aggregation (eq. 11) — runs directly on planes through the
kernels in ``fedprox_update.py`` / ``nova_aggregate.py``; tree views are
materialized only at API boundaries (loss/grad evaluation, ``RoundReport``,
checkpoints, eval).

Layout rules:

* ``LANE = 1024`` (multiple of the 128-lane register width) is the fixed
  last dimension.
* ``R`` is the element count rounded up to a whole number of lanes and
  then to a multiple of ``SUBLANE = 8`` rows (the f32 min tile), so any
  plane is directly tileable by the kernels.
* Planes are always float32 — the master copy.  ``unflatten`` casts back
  to the recorded leaf dtypes (bf16 values round-trip exactly because
  f32 ⊃ bf16).
* A leading batch axis is allowed: a ``(G, R, LANE)`` plane holds one row
  per DPU of a homogeneous group (or per DPU of the mesh round).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LANE = 1024      # last-dim tile width (multiple of 128)
SUBLANE = 8      # f32 min sublane multiple; every plane has R % 8 == 0


def _row_count(n: int) -> int:
    """Rows needed for n elements, padded to a SUBLANE multiple (>= 8);
    large planes pad to a multiple of 128 rows so the TPU path gets big
    power-of-two row tiles (<= 0.5MB f32 of waste)."""
    r = max(1, -(-n // LANE))
    if r > 256:
        return -(-r // 128) * 128
    return -(-r // SUBLANE) * SUBLANE


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static description of a pytree's flat layout.  Hashable, so it can
    ride through ``jax.jit`` as a static argument or pytree aux data."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]     # start element of each leaf in the plane
    n: int                       # total real elements
    rows: int                    # padded row count (R)

    @classmethod
    def from_tree(cls, tree) -> "FlatSpec":
        return spec_of(tree)

    # -- conversions ----------------------------------------------------

    def flatten(self, tree) -> jnp.ndarray:
        """Pytree -> (R, LANE) f32 plane (zero padding past ``n``)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        assert treedef == self.treedef, (treedef, self.treedef)
        parts = [jnp.ravel(x).astype(jnp.float32) for x in leaves]
        flat = (jnp.concatenate(parts) if parts
                else jnp.zeros((0,), jnp.float32))
        flat = jnp.pad(flat, (0, self.rows * LANE - self.n))
        return flat.reshape(self.rows, LANE)

    def unflatten(self, plane: jnp.ndarray):
        """(R, LANE) plane -> pytree with the original shapes/dtypes."""
        flat = plane.reshape(-1)
        out = []
        for shape, dtype, off in zip(self.shapes, self.dtypes, self.offsets):
            k = int(np.prod(shape)) if shape else 1
            out.append(jax.lax.dynamic_slice_in_dim(flat, off, k)
                       .reshape(shape).astype(dtype))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def unflatten_batched(self, planes: jnp.ndarray):
        """(G, R, LANE) -> pytree whose leaves carry the leading G axis."""
        return jax.vmap(self.unflatten)(planes)

    # -- hashing (treedef and dtype objects are hashable) ---------------

    def _key(self):
        return (self.treedef, self.shapes,
                tuple(jnp.dtype(d).name for d in self.dtypes))

    def __hash__(self):
        return hash(self._key())

    def __eq__(self, other):
        return isinstance(other, FlatSpec) and self._key() == other._key()


_SPEC_CACHE: dict = {}


def spec_of(tree) -> FlatSpec:
    """The cached FlatSpec of a pytree — computed once per (treedef,
    shapes, dtypes) structure, at model init in practice."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(x.shape) for x in leaves)
    dtypes = tuple(jnp.dtype(x.dtype) for x in leaves)
    key = (treedef, shapes, tuple(d.name for d in dtypes))
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        sizes = [int(np.prod(s)) if s else 1 for s in shapes]
        offsets = tuple(int(o) for o in np.cumsum([0] + sizes[:-1]))
        n = int(sum(sizes))
        spec = FlatSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                        offsets=offsets, n=n, rows=_row_count(n))
        _SPEC_CACHE[key] = spec
    return spec


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ParamPlane:
    """A pytree's parameters as a flat plane: ``data`` is ``(R, LANE)``
    f32 (or ``(G, R, LANE)`` with a leading batch axis), ``spec`` the
    static layout.  Registered as a pytree (spec is aux data), so planes
    pass through jit/vmap/scan like any array."""
    data: jnp.ndarray
    spec: FlatSpec

    # -- pytree protocol ------------------------------------------------

    def tree_flatten(self):
        return (self.data,), self.spec

    @classmethod
    def tree_unflatten(cls, spec, children):
        return cls(data=children[0], spec=spec)

    # -- constructors / views -------------------------------------------

    @classmethod
    def from_tree(cls, tree) -> "ParamPlane":
        if isinstance(tree, ParamPlane):
            return tree
        spec = spec_of(tree)
        return cls(data=spec.flatten(tree), spec=spec)

    def to_tree(self):
        if self.data.ndim == 2:
            return self.spec.unflatten(self.data)
        return self.spec.unflatten_batched(self.data)

    # -- conveniences ---------------------------------------------------

    @property
    def batched(self) -> bool:
        return self.data.ndim == 3

    def __getitem__(self, i) -> "ParamPlane":
        return ParamPlane(data=self.data[i], spec=self.spec)

    def with_data(self, data) -> "ParamPlane":
        return ParamPlane(data=data, spec=self.spec)

    def broadcast(self, g: int) -> "ParamPlane":
        """(R, LANE) -> (g, R, LANE) view (no copy until mutated)."""
        assert self.data.ndim == 2
        return ParamPlane(
            data=jnp.broadcast_to(self.data[None], (g,) + self.data.shape),
            spec=self.spec)


def as_plane(params) -> ParamPlane:
    """Coerce a pytree or ParamPlane to a ParamPlane."""
    return ParamPlane.from_tree(params)


def as_tree(params):
    """Coerce a ParamPlane or pytree to a pytree (API-boundary helper)."""
    return params.to_tree() if isinstance(params, ParamPlane) else params
