"""Named experiment presets — the registry's built-ins.

Each name maps to a fully specified :class:`~repro.experiments.spec.
ExperimentSpec`; the paper touchstones reference the table/figure they
reproduce.  Override any axis from the CLI::

    python -m repro.experiments run quickstart
    python -m repro.experiments run campus_walk_vs_fixed \
        --set strategy=fixed:0 --seeds 0,1,2
"""
from __future__ import annotations

from repro.experiments.spec import (ConstsSpec, DataSpec, EngineSpec,
                                    ExperimentSpec, ModelSpec, NetworkSpec,
                                    ObjectiveSpec, register_experiment)


@register_experiment("quickstart")
def quickstart() -> ExperimentSpec:
    """CE-FL on a 6-UE / 3-BS / 2-DC synthetic edge network in ~a minute
    on CPU — the README front-door experiment."""
    return ExperimentSpec(
        name="quickstart",
        model=ModelSpec(input_shape=(14, 14, 1), hidden=(64,)),
        data=DataSpec(pool=6000, mean_arrivals=300.0, std_arrivals=30.0),
        network=NetworkSpec(num_ue=6, num_bs=3, num_dc=2),
        consts=ConstsSpec(mode="fixed", L=5.0, theta=2.0, sigma=3.0),
        engine=EngineSpec(rounds=8, eta=0.1, solver_outer=2,
                          reoptimize_every=4),
        strategy="cefl", scenario="static", seeds=(0,))


@register_experiment("paper_table1")
def paper_table1() -> ExperimentSpec:
    """Tables I-II grid cell (F-MNIST-like, paper-size 20/10/5 network,
    estimated constants): sweep ``strategy`` over cefl/fednova/fedavg and
    the seed list to reproduce energy/delay-to-accuracy rows."""
    return ExperimentSpec(
        name="paper_table1",
        model=ModelSpec(input_shape=(28, 28, 1), hidden=(200, 100)),
        data=DataSpec(pool=48000, mean_arrivals=2000.0,
                      std_arrivals=200.0, eval_examples=1000),
        network=NetworkSpec(num_ue=20, num_bs=10, num_dc=5),
        consts=ConstsSpec(mode="estimate", estimate_iters=8),
        objective=ObjectiveSpec(xi1=1.0, xi2=1e-2, xi3=2.0),
        engine=EngineSpec(rounds=40, eta=0.1, solver_outer=4,
                          reoptimize_every=3),
        strategy="cefl", scenario="static", seeds=(0, 1, 2))


@register_experiment("campus_walk_vs_fixed")
def campus_walk_vs_fixed() -> ExperimentSpec:
    """The mobility story (paper Sec. III / Figs. 3-4 dynamics): random-
    waypoint pedestrians, network re-derived every round, the floating
    aggregation point chasing the data.  Run as-is for cefl, and with
    ``--set strategy=fixed:0`` for the baseline that cannot float."""
    return ExperimentSpec(
        name="campus_walk_vs_fixed",
        model=ModelSpec(input_shape=(14, 14, 1), hidden=(32,)),
        data=DataSpec(pool=6000, mean_arrivals=300.0, std_arrivals=30.0,
                      eval_examples=400),
        network=NetworkSpec(num_ue=8, num_bs=4, num_dc=3),
        consts=ConstsSpec(mode="fixed", L=4.0, theta=2.0, sigma=1.0),
        engine=EngineSpec(rounds=20, eta=0.1, solver_outer=2,
                          reoptimize_every=1),
        strategy="cefl", scenario="campus_walk", seeds=(0,))


@register_experiment("label_shift_drift")
def label_shift_drift() -> ExperimentSpec:
    """Pure concept drift (paper Definition 1): static radio plane,
    labels rotating one class every 4 rounds."""
    return ExperimentSpec(
        name="label_shift_drift",
        model=ModelSpec(input_shape=(14, 14, 1), hidden=(64,)),
        data=DataSpec(pool=6000, mean_arrivals=300.0, std_arrivals=30.0),
        network=NetworkSpec(num_ue=8, num_bs=4, num_dc=3),
        consts=ConstsSpec(mode="fixed", L=4.0, theta=2.0, sigma=1.0),
        engine=EngineSpec(rounds=12, eta=0.1, solver_outer=2,
                          reoptimize_every=2),
        strategy="cefl", scenario="label_shift:4", seeds=(0, 1))


@register_experiment("sweep_smoke")
def sweep_smoke() -> ExperimentSpec:
    """CI-sized multi-seed sweep (2 seeds, 3 rounds, tiny net/model) —
    the spec the sweep smoke job and the parity tests run."""
    return ExperimentSpec(
        name="sweep_smoke",
        model=ModelSpec(input_shape=(8, 8, 1), hidden=(16,)),
        data=DataSpec(pool=2000, mean_arrivals=120.0, std_arrivals=12.0,
                      eval_examples=200),
        network=NetworkSpec(num_ue=4, num_bs=2, num_dc=2),
        consts=ConstsSpec(mode="fixed", L=5.0, theta=2.0, sigma=3.0),
        engine=EngineSpec(rounds=3, eta=0.1, solver_outer=2,
                          reoptimize_every=1),
        strategy="greedy_data", scenario="campus_walk", seeds=(0, 1))


@register_experiment("sweep_bench")
def sweep_bench() -> ExperimentSpec:
    """The 8-seed sweep the vmap-vs-sequential benchmark times
    (``benchmarks/sweep_bench.py`` -> BENCH_sweep.json)."""
    return ExperimentSpec(
        name="sweep_bench",
        model=ModelSpec(input_shape=(14, 14, 1), hidden=(64,)),
        data=DataSpec(pool=4000, mean_arrivals=200.0, std_arrivals=20.0,
                      eval_examples=400),
        network=NetworkSpec(num_ue=6, num_bs=3, num_dc=2),
        consts=ConstsSpec(mode="fixed", L=5.0, theta=2.0, sigma=3.0),
        engine=EngineSpec(rounds=6, eta=0.1, solver_outer=2,
                          reoptimize_every=1),
        strategy="greedy_data", scenario="static",
        seeds=(0, 1, 2, 3, 4, 5, 6, 7))


@register_experiment("lm_smoke")
def lm_smoke() -> ExperimentSpec:
    """Mesh-native CE-FL LM training, smoke-sized (the old
    ``launch/train.py`` defaults with --reduced)."""
    return ExperimentSpec(
        name="lm_smoke",
        model=ModelSpec(kind="lm", arch="mamba2-130m", reduced=True,
                        batch=8, seq=256, n_dpu=2, n_micro=1, gamma=1),
        engine=EngineSpec(rounds=20, eta=3e-2, mu=0.01),
        strategy="fixed:0", scenario="static", seeds=(0,))


@register_experiment("lm_mamba2_130m")
def lm_mamba2_130m() -> ExperimentSpec:
    """The full 130M-parameter mamba2 CE-FL run (hours on CPU, minutes
    on accelerators)."""
    return ExperimentSpec(
        name="lm_mamba2_130m",
        model=ModelSpec(kind="lm", arch="mamba2-130m", reduced=False,
                        batch=8, seq=512, n_dpu=2, n_micro=1, gamma=2),
        engine=EngineSpec(rounds=200, eta=3e-2, mu=0.01),
        strategy="fixed:0", scenario="static", seeds=(0,))


@register_experiment("bench_quick")
def bench_quick() -> ExperimentSpec:
    """The QUICK=1 benchmark harness cell (``benchmarks/common.setup``):
    scaled-down network/model so the whole suite fits one CPU core."""
    return ExperimentSpec(
        name="bench_quick",
        model=ModelSpec(input_shape=(14, 14, 1), hidden=(64,)),
        data=DataSpec(pool=8000, mean_arrivals=400.0, std_arrivals=40.0,
                      eval_examples=1000),
        network=NetworkSpec(num_ue=8, num_bs=4, num_dc=3),
        consts=ConstsSpec(mode="estimate", estimate_iters=3),
        objective=ObjectiveSpec(xi1=1.0, xi2=1e-2, xi3=2.0),
        engine=EngineSpec(rounds=10, eta=0.1, solver_outer=2,
                          reoptimize_every=3),
        strategy="cefl", scenario="static", seeds=(0,))


@register_experiment("bench_paper")
def bench_paper() -> ExperimentSpec:
    """The QUICK=0 benchmark harness cell: the paper's 20/10/5 topology
    and full-size F-MNIST-like task."""
    return ExperimentSpec(
        name="bench_paper",
        model=ModelSpec(input_shape=(28, 28, 1), hidden=(200, 100)),
        data=DataSpec(pool=48000, mean_arrivals=2000.0,
                      std_arrivals=200.0, eval_examples=1000),
        network=NetworkSpec(num_ue=20, num_bs=10, num_dc=5),
        consts=ConstsSpec(mode="estimate", estimate_iters=8),
        objective=ObjectiveSpec(xi1=1.0, xi2=1e-2, xi3=2.0),
        engine=EngineSpec(rounds=40, eta=0.1, solver_outer=4,
                          reoptimize_every=3),
        strategy="cefl", scenario="static", seeds=(0,))
