"""Mesh-native CE-FL LM training from a spec (``ModelSpec.kind="lm"``).

This is the old ``launch/train.py`` main loop expressed over
:class:`~repro.experiments.spec.ExperimentSpec`: the jitted SPMD round
step built through the engine's :class:`~repro.core.engine.MeshExecutor`
on the flat parameter plane, driven for ``engine.rounds`` rounds of
synthetic token batches.  ``launch/train.py`` remains as a thin argparse
shim over this function.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.api import RunResult, RoundReport
from repro.core.engine import MeshExecutor
from repro.core.round_step import CEFLHyper, make_dpu_meta
from repro.data import make_token_batches
from repro.experiments.spec import ExperimentSpec, get_experiment
from repro.kernels.plane import ParamPlane
from repro.models import lm as L
from repro.training.checkpoint import save_checkpoint


def run_lm(spec: ExperimentSpec, *, seed=None, checkpoint=None,
           use_plane: bool = True, verbose: bool = True) -> RunResult:
    """Train the spec's LM arch with the mesh-native CE-FL round.

    Returns a :class:`RunResult` whose reports carry the per-round loss
    (network-cost fields are zero — there is no radio plane under the
    mesh launcher); ``result.params`` is the trained tree of DPU 0.
    """
    spec = get_experiment(spec)
    m = spec.model
    assert m.kind == "lm", m.kind
    seed = spec.run_seeds[0] if seed is None else int(seed)
    cfg = get_config(m.arch)
    if m.reduced:
        cfg = reduced(cfg)
    if verbose:
        print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
              f"{m.n_dpu} DPUs x gamma={m.gamma}")
    key = jax.random.PRNGKey(seed)
    params0 = L.init_lm_params(key, cfg, jnp.float32)
    if use_plane:
        # flat-plane hot path: params stay (n_dpu, R, LANE) for the whole
        # run; the tree view is materialized only at the checkpoint
        params = ParamPlane.from_tree(params0).broadcast(m.n_dpu)
    else:
        params = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (m.n_dpu,) + x.shape),
            params0)

    def loss_fn(p, micro, mask):
        return L.lm_loss(p, cfg, micro, example_mask=mask, remat=True,
                         q_block=min(512, m.seq),
                         kv_block=min(512, m.seq))

    hyper = CEFLHyper(eta=spec.engine.eta, mu=spec.engine.mu,
                      theta=float(m.gamma),   # tau_eff compensation
                      gamma_max=m.gamma, n_micro=m.n_micro)
    step = MeshExecutor().build_step(loss_fn, hyper)   # jitted, donating
    meta = make_dpu_meta(m.n_dpu, gammas=[m.gamma] * m.n_dpu)

    mb = m.batch // (m.n_dpu * m.n_micro)
    reports = []
    for t in range(spec.engine.rounds):
        b = make_token_batches(
            cfg.vocab_size, m.n_dpu, m.n_micro, mb, m.seq,
            seed=seed * 10000 + t,
            enc_seq=cfg.encoder_seq if cfg.is_encdec else 0,
            d_model=cfg.d_model)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.time()
        params, metrics = step(params, b, meta)
        loss = float(metrics["loss"])
        wall = time.time() - t0
        if verbose:
            print(f"  round {t:4d}  loss {loss:8.4f}  ({wall:.2f}s)")
        reports.append(RoundReport(
            round=t, acc=float("nan"), loss=loss, energy=0.0, delay=0.0,
            cum_energy=0.0, cum_delay=0.0, aggregator=0, dc_points=(),
            gamma_mean=float(m.gamma), m_mean=1.0, wall_time=wall))
    final = (params[0].to_tree() if isinstance(params, ParamPlane)
             else jax.tree_util.tree_map(lambda x: x[0], params))
    if checkpoint:
        save_checkpoint(checkpoint, final, step=spec.engine.rounds,
                        metadata={"arch": m.arch, "seed": seed})
        if verbose:
            print(f"[train] checkpoint -> {checkpoint}")
    losses = [r.loss for r in reports]
    assert losses[-1] < losses[0], "loss did not decrease"
    if verbose:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return RunResult(reports=reports, params=final)
