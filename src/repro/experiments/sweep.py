"""Multi-seed sweep executors: K seeded runs, one batched device axis.

With the flat :class:`~repro.kernels.plane.ParamPlane` representation a
population of seeded runs is just one more batch axis.  Both executors
drive K per-run :class:`~repro.core.engine.LoopState`s through the SAME
``Engine.begin_round`` / ``finish_round`` host path (scenario ticks,
solver decisions, offloading, PRNG chains — per run, bit-identical to a
solo ``Engine.run``), and differ only in how the device work executes:

* :class:`SequentialSweepExecutor` — each run's round goes through its
  own ``SimExecutor.run_round`` (the pinned-bit-exact fallback, and the
  baseline the sweep benchmark compares against).
* :class:`VmapSweepExecutor` — every live (run, DPU) pair across ALL K
  runs is stacked onto the leading axis of the parameter plane and
  trained by ONE jitted scan per (gamma, m, bucket) group
  (``fedprox.local_train_multi``: per-element anchors), with evaluation
  vmapped over the K-stacked planes.  Per-run results are bit-exact vs
  the sequential executor (asserted by tests/test_experiments.py): the
  per-element math and PRNG streams do not depend on the group
  composition.

Both executors write per-round JSONL records through a
:class:`~repro.experiments.trace.TraceSink` and checkpoint/resume full
run state through ``repro.experiments.runstate``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedprox
from repro.core.api import RunResult, weighted_mean
from repro.core.engine import (SimExecutor, _aggregate, _plan_settings,
                               corrupt_local_results)
from repro.experiments import runstate
from repro.experiments.build import ExperimentContext
from repro.experiments.spec import to_json
from repro.experiments.trace import TraceSink, round_record
from repro.kernels.plane import as_plane, as_tree


@dataclasses.dataclass(frozen=True)
class RunKey:
    experiment: str
    seed: int


@dataclasses.dataclass
class SweepResult:
    """What ``sweep`` returns: per-run results plus aggregate stats."""
    runs: List[Tuple[RunKey, RunResult]]

    def __len__(self) -> int:
        return len(self.runs)

    @property
    def seeds(self) -> List[int]:
        return [k.seed for k, _ in self.runs]

    def result(self, seed: int, experiment: Optional[str] = None) \
            -> RunResult:
        for k, r in self.runs:
            if k.seed == seed and (experiment is None
                                   or k.experiment == experiment):
                return r
        raise KeyError((experiment, seed))

    def series(self, field: str) -> Dict[RunKey, list]:
        return {k: r.series(field) for k, r in self.runs}

    def stats(self) -> Dict[str, dict]:
        """Aggregate statistics per experiment name: mean/std/min/max of
        final accuracy, mean cumulative energy/delay, mean final loss."""
        by_name: Dict[str, list] = {}
        for k, r in self.runs:
            by_name.setdefault(k.experiment, []).append(r)
        out = {}
        for name, results in by_name.items():
            accs = np.array([r.final.acc for r in results], float)
            out[name] = {
                "runs": len(results),
                "final_acc_mean": float(accs.mean()),
                "final_acc_std": float(accs.std()),
                "final_acc_min": float(accs.min()),
                "final_acc_max": float(accs.max()),
                "final_loss_mean": float(np.mean(
                    [r.final.loss for r in results])),
                "cum_energy_mean": float(np.mean(
                    [r.final.cum_energy for r in results])),
                "cum_delay_mean": float(np.mean(
                    [r.final.cum_delay for r in results])),
                "rounds": int(np.mean([len(r) for r in results])),
            }
        return out

    def merged(self, other: "SweepResult") -> "SweepResult":
        return SweepResult(runs=self.runs + other.runs)


@dataclasses.dataclass
class _Run:
    """One seeded run inside a sweep: its engine, streams, loop state."""
    seed: int
    engine: object
    ues: list
    state: object


class _LockstepSweep:
    """Shared round-lockstep loop; subclasses provide the device phase.

    ``checkpoint_dir``/``checkpoint_every`` enable full-state snapshots
    every N rounds; ``resume=True`` restores the latest snapshot (a spec
    mismatch raises).  ``stop_after`` ends the loop after that many
    rounds *with* a snapshot — the tested kill point of the
    kill-and-resume guarantee.
    """

    executor_name = "sequential"

    def __init__(self, *, checkpoint_dir=None, checkpoint_every: int = 0,
                 resume: bool = False, stop_after: Optional[int] = None):
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.resume = resume
        self.stop_after = stop_after
        if (checkpoint_every or stop_after or resume) \
                and not checkpoint_dir:
            raise ValueError("checkpointing/resume needs checkpoint_dir")

    # ------------------------------------------------------ lifecycle --

    def _init_runs(self, ctx: ExperimentContext) -> List[_Run]:
        runs = []
        for seed in ctx.spec.run_seeds:
            engine = ctx.make_engine(seed, executor=SimExecutor())
            ues = ctx.make_ues(seed)
            state = engine.init_loop(ues, init_params=ctx.p0,
                                     loss_fn=ctx.loss_fn,
                                     eval_fn=ctx.eval_fn)
            runs.append(_Run(seed=seed, engine=engine, ues=ues,
                             state=state))
        return runs

    def _maybe_resume(self, ctx, runs: List[_Run]) -> None:
        import os
        if not (self.resume and self.checkpoint_dir
                and os.path.exists(os.path.join(self.checkpoint_dir,
                                                "manifest.json"))):
            return
        state, reports, spec_json, _ = runstate.load_sweep_state(
            self.checkpoint_dir)
        if spec_json != to_json(ctx.spec):
            raise ValueError(
                f"checkpoint in {self.checkpoint_dir} was written by a "
                f"different spec; refusing to resume")
        for run in runs:
            key = str(run.seed)
            if key not in state:
                raise ValueError(f"checkpoint has no state for seed "
                                 f"{run.seed}")
            runstate.restore_run(run, state[key], reports[key],
                                 run.engine)

    def _save(self, ctx, runs: List[_Run], round_idx: int) -> None:
        if self.checkpoint_dir:
            runstate.save_sweep_state(self.checkpoint_dir, runs,
                                      spec_json=to_json(ctx.spec),
                                      round_idx=round_idx)

    # ----------------------------------------------------- round loop --

    def run_sweep(self, ctx: ExperimentContext, *,
                  trace: Optional[TraceSink] = None) -> SweepResult:
        trace = trace or TraceSink(None)
        runs = self._init_runs(ctx)
        self._maybe_resume(ctx, runs)
        rounds = ctx.spec.engine.rounds
        while True:
            active = [r for r in runs
                      if r.state.t < rounds and not r.state.stopped]
            if not active:
                break
            t = active[0].state.t
            assert all(r.state.t == t for r in active), \
                "lockstep sweep requires equal round indices"
            staged = [r.engine.begin_round(r.state, r.ues)
                      for r in active]
            self._device_phase(ctx, active, staged)
            for run in active:
                rep = run.state.reports[-1]
                trace.write(round_record(ctx.spec.name, run.seed, rep,
                                         executor=self.executor_name))
            done = t + 1
            if self.checkpoint_every and done % self.checkpoint_every == 0:
                self._save(ctx, runs, done)
            if self.stop_after is not None and done >= self.stop_after:
                self._save(ctx, runs, done)
                break
        return SweepResult(runs=[
            (RunKey(ctx.spec.name, r.seed),
             RunResult(reports=r.state.reports,
                       params=as_tree(r.state.params)))
            for r in runs])

    def _device_phase(self, ctx, active: List[_Run], staged) -> None:
        raise NotImplementedError


class SequentialSweepExecutor(_LockstepSweep):
    """Per-run device work through each run's own SimExecutor — the
    bit-exactness oracle and the benchmark baseline."""

    executor_name = "sequential"

    def _device_phase(self, ctx, active, staged) -> None:
        for run, st in zip(active, staged):
            # fuse_eval=False keeps the historical sweep behavior: the
            # eval runs separately in finish_round (bit-identical result,
            # pinned against the vmapped executor's batched eval)
            mean_loss, acc = run.engine.execute_round(
                run.state, st, fuse_eval=False)
            run.engine.finish_round(run.state, st, mean_loss, acc)


class VmapSweepExecutor(_LockstepSweep):
    """All K runs' device work on one leading plane axis per round.

    Per (gamma, m, bucket) group — across runs — one
    ``fedprox.local_train_multi`` call trains every member (per-element
    anchor = that run's global plane); aggregation runs per-run on the
    fused kernel; evaluation is ONE vmapped call over the K-stacked
    planes.  Host-side decisions (scenario, solver, offloading) stay
    per-run, so plans/streams match the sequential executor exactly.
    """

    executor_name = "vmap"

    def __init__(self, **kw):
        super().__init__(**kw)
        self._eval_cache = {}

    def _batched_eval(self, ctx, spec):
        # keyed on the eval fn too: one executor instance may serve specs
        # that share a FlatSpec but evaluate on different data
        key = (id(ctx.eval_fn), spec)
        if key not in self._eval_cache:
            eval_fn = ctx.eval_fn
            self._eval_cache[key] = jax.jit(jax.vmap(
                lambda data: eval_fn(spec.unflatten(data))))
        return self._eval_cache[key]

    def _device_phase(self, ctx, active, staged) -> None:
        groups: Dict[tuple, list] = {}
        run_results = [[None] * 0 for _ in active]
        live_per_run = []
        noise_keys = [None] * len(active)
        for k, (run, st) in enumerate(zip(active, staged)):
            plan = st.plan
            gammas, ms = _plan_settings(plan)
            live = [(i, d) for i, d in enumerate(st.datasets)
                    if d is not None and len(d["y"])]
            live_per_run.append(live)
            run_results[k] = [None] * len(live)
            if not live:
                continue
            # same split count as SimExecutor.run_round: one extra key
            # only when this run's round has gaussian corruption, so the
            # vmap executor stays bit-exact vs the sequential one
            corrupt = tuple(getattr(st.events, "corrupted", ()) or ())
            needs_noise = any(mode == "gauss" for _, mode, _ in corrupt)
            keys = jax.random.split(
                st.key, len(live) + (1 if needs_noise else 0))
            if needs_noise:
                noise_keys[k] = keys[len(live)]
            anchor = as_plane(run.state.params)
            for j, (i, d) in enumerate(live):
                bucket = fedprox._bucket(
                    fedprox.batch_size(len(d["y"]), ms[i]))
                groups.setdefault(
                    (int(gammas[i]), float(ms[i]), bucket), []).append(
                        (k, j, d, anchor, keys[j]))
        for (gamma, m, _bucket), members in groups.items():
            eng0 = active[members[0][0]].engine
            out = fedprox.local_train_multi(
                [mb[3] for mb in members], ctx.loss_fn,
                [mb[2] for mb in members], gamma=gamma, m_frac=m,
                eta=eng0.opts.eta, mu=eng0.mu_effective,
                keys=[mb[4] for mb in members], keep_planes=True,
                kernel_backend=eng0.opts.kernel_backend)
            for (k, j, _, _, _), res in zip(members, out):
                run_results[k][j] = res
        # per-run aggregation (fused eq.-11 kernel on the plane), with
        # the round's corruptions applied first and the engine's robust
        # counter threaded through — same order as SimExecutor.run_round
        mean_losses = []
        for k, (run, st) in enumerate(zip(active, staged)):
            engine = run.engine
            results = run_results[k]
            if not results:
                mean_losses.append(float("nan"))
                continue
            anchor = as_plane(run.state.params)
            corrupt = tuple(getattr(st.events, "corrupted", ()) or ())
            if corrupt:
                corrupt_local_results(results, live_per_run[k], corrupt,
                                      anchor, noise_keys[k])
            run.state.params = _aggregate(
                anchor, results, engine.aggregation,
                eta=engine.opts.eta, theta=engine.opts.theta,
                robust=engine.opts.robust_agg,
                trim_frac=engine.opts.trim_frac)
            mean_losses.append(weighted_mean(
                [r.loss for r in results],
                [r.num_examples for r in results]))
        # ONE vmapped eval over the K-stacked planes (eval cadence is
        # spec-level, so every active run evals on the same rounds)
        t = staged[0].t
        if active and active[0].engine.should_eval(t):
            planes = [as_plane(r.state.params) for r in active]
            spec0 = planes[0].spec
            accs = np.asarray(self._batched_eval(ctx, spec0)(
                jnp.stack([p.data for p in planes], axis=0)))
            acc_of = {id(r): float(a) for r, a in zip(active, accs)}
        else:
            acc_of = {id(r): r.state.last_acc for r in active}
        for run, st, mean_loss in zip(active, staged, mean_losses):
            run.engine.finish_round(run.state, st, mean_loss,
                                    acc=acc_of[id(run)])


_EXECUTORS = {
    "sequential": SequentialSweepExecutor,
    "vmap": VmapSweepExecutor,
}


def get_sweep_executor(name: str, **kw) -> _LockstepSweep:
    if isinstance(name, _LockstepSweep):
        if any(v for v in kw.values()):
            raise ValueError(
                "cannot combine a pre-configured executor instance with "
                f"executor kwargs {sorted(k for k, v in kw.items() if v)}; "
                "pass the executor name and the kwargs, or configure the "
                "instance itself")
        return name
    try:
        cls = _EXECUTORS[name]
    except KeyError:
        raise KeyError(f"unknown sweep executor {name!r}; available: "
                       f"{sorted(_EXECUTORS)}") from None
    return cls(**kw)


# ------------------------------------------------ trace-level contracts -----

from repro.analysis.jaxpr.contracts import Program, contract  # noqa: E402


@contract(
    "sweep_multi_train",
    collectives={},
    memory_budget_bytes=4 << 20,
)
def _sweep_multi_train_contract():
    """Cross-run batched training scan with per-element anchors — the
    device program VmapSweepExecutor drives via local_train_multi."""
    spec, args = fedprox._audit_round_args()
    p0 = args[0]
    # per-element anchor (G, R, LANE): the multi-run form
    fn = fedprox._plane_train_fn(fedprox._audit_loss, spec,
                                 batched_anchor=True,
                                 kernel_backend="cpu")
    return Program(fn=fn, args=(p0, p0) + args[2:8])
