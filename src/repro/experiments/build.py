"""Spec -> runnable context: network, data pool, model init, constants.

``build_context`` is the ONE place the repo turns a declarative
:class:`~repro.experiments.spec.ExperimentSpec` into live objects.  The
pre-spec entry points (``benchmarks/common.py``, the examples) used to
duplicate this derivation — including the one-shot constants estimation
and its DC padding — with their own argparse and ad-hoc seeds; they now
all come through here.

Everything spec-level (network topology, data pool, initial params,
constants, objective weights) is shared across the seeds of a sweep;
only :meth:`ExperimentContext.make_ues` / :meth:`make_engine` take the
per-run seed, and both derive every stream from it (the single-seed
contract of ``spec.engine_options``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import Engine, MLConstants
from repro.core.estimation import estimate_constants
from repro.data import make_image_dataset, make_online_ues
from repro.experiments.spec import ExperimentSpec, get_experiment
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights


@dataclasses.dataclass
class ExperimentContext:
    """Live objects for one spec (shared across the seed sweep)."""
    spec: ExperimentSpec
    net: object
    p0: object
    loss_fn: Callable
    eval_fn: Callable
    consts: MLConstants
    ow: ObjectiveWeights
    train_x: np.ndarray
    train_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray

    def make_ues(self, seed: int):
        """Per-run online UE streams — every stream PRNG derives from the
        run seed (the spec's single-seed contract)."""
        d = self.spec.data
        return make_online_ues(
            self.train_x, self.train_y, num_ue=self.spec.network.num_ue,
            labels_per_ue=d.labels_per_ue, mean_arrivals=d.mean_arrivals,
            std_arrivals=d.std_arrivals, seed=int(seed),
            drift_labels=d.drift_labels)

    def make_engine(self, seed: int, *, executor=None,
                    callbacks=()) -> Engine:
        return Engine(self.net, consts=self.consts, ow=self.ow,
                      opts=self.spec.engine_options(seed),
                      executor=executor, callbacks=callbacks)


def _build_consts(spec: ExperimentSpec, ctx_parts) -> MLConstants:
    c = spec.consts
    N, S = spec.network.num_ue, spec.network.num_dc
    if c.mode == "fixed":
        nd = N + S
        return MLConstants(L=c.L, theta_i=np.full(nd, c.theta),
                           sigma_i=np.full(nd, c.sigma),
                           zeta1=c.zeta1, zeta2=c.zeta2)
    if c.mode != "estimate":
        raise ValueError(f"unknown consts mode {c.mode!r}")
    # one-shot pre-training estimation (paper Algs. 4-6, App. H-1) on
    # probe streams seeded off the spec (not the run).  Theta/sigma are
    # per-UE; DC entries (mixtures of offloaded UE data) take UE means.
    p0, loss_fn, train_x, train_y = ctx_parts
    probe = [ds.step() for ds in make_online_ues(
        train_x, train_y, num_ue=N,
        labels_per_ue=spec.data.labels_per_ue,
        mean_arrivals=spec.data.mean_arrivals,
        std_arrivals=spec.data.std_arrivals, seed=c.probe_seed)]
    consts = estimate_constants(loss_fn, p0, probe,
                                key=jax.random.PRNGKey(7),
                                iters=c.estimate_iters)
    return dataclasses.replace(
        consts,
        theta_i=np.concatenate([consts.theta_i,
                                np.full(S, consts.theta_i.mean())]),
        sigma_i=np.concatenate([consts.sigma_i,
                                np.full(S, consts.sigma_i.mean())]))


def _cache_key(spec: ExperimentSpec) -> ExperimentSpec:
    """Strip the axes that don't affect the built objects — the run axes
    (name, strategy, scenario, seeds) and ``data.drift_labels`` (it only
    parameterizes ``make_ues`` streams, never the pool/consts/eval set)
    — so a strategy/scenario/drift grid over one base spec shares a
    single context build (and a single Algs. 4-6 constants
    estimation)."""
    return dataclasses.replace(
        spec, name="", strategy="cefl", scenario="static", seeds=(),
        data=dataclasses.replace(spec.data, drift_labels=False))


@functools.lru_cache(maxsize=8)
def _build_context_cached(spec: ExperimentSpec) -> ExperimentContext:
    if spec.model.kind != "classifier":
        raise ValueError(
            f"build_context handles classifier specs; {spec.model.kind!r} "
            f"runs through repro.experiments.lm")
    m, d, n = spec.model, spec.data, spec.network
    net = make_network(NetworkConfig(num_ue=n.num_ue, num_bs=n.num_bs,
                                     num_dc=n.num_dc,
                                     seed=n.topology_seed))
    (trx, tr_y), (tex, te_y) = make_image_dataset(
        d.pool, tuple(m.input_shape), num_classes=m.num_classes,
        seed=d.pool_seed)
    ccfg = ClassifierConfig(input_shape=tuple(m.input_shape),
                            hidden=tuple(m.hidden),
                            num_classes=m.num_classes)
    p0 = init_classifier_params(jax.random.PRNGKey(d.pool_seed), ccfg)
    ex, ey = jnp.asarray(tex[:d.eval_examples]), \
        jnp.asarray(te_y[:d.eval_examples])

    def eval_fn(p):
        return classifier_accuracy(p, ex, ey)

    consts = _build_consts(spec, (p0, classifier_loss, trx, tr_y))
    o = spec.objective
    ow = ObjectiveWeights(xi1=o.xi1, xi2=o.xi2, xi3=o.xi3, drift=o.drift,
                          T=spec.engine.rounds)
    return ExperimentContext(spec=spec, net=net, p0=p0,
                             loss_fn=classifier_loss, eval_fn=eval_fn,
                             consts=consts, ow=ow,
                             train_x=trx, train_y=tr_y,
                             test_x=tex, test_y=te_y)


def build_context(spec, *, cache: bool = True) -> ExperimentContext:
    """Build (or fetch the cached) context for a spec or preset name.

    The cache key ignores name/strategy/scenario/seeds — a grid over
    those axes shares one build — and the returned context carries the
    REAL spec (``make_engine`` needs its strategy/scenario/seeds)."""
    spec = get_experiment(spec)
    if cache:
        ctx = _build_context_cached(_cache_key(spec))
    else:
        ctx = _build_context_cached.__wrapped__(_cache_key(spec))
    if ctx.spec != spec:
        ctx = dataclasses.replace(ctx, spec=spec)
    return ctx


def clear_context_cache() -> None:
    _build_context_cached.cache_clear()
