"""Declarative experiment specs: the paper's result grid as data.

An :class:`ExperimentSpec` is a frozen, JSON-round-trippable description
of ONE experiment cell — model + data + network dims + scenario +
strategy + engine hyper-parameters + the seed list — from which
``repro.experiments.run`` / ``sweep`` reproduce a result without any
hand-assembled script.  Tables I-II and Figs. 3-7 of the paper are grids
over exactly these axes; specs make the grid declarative (and the
``seeds`` axis vmappable, see ``sweep.py``).

Key invariant — **single source of truth for seeds**: the per-run seed
drives the engine PRNG chain, the scenario evolution (through the engine
rng), and the per-UE online-data streams.  ``ExperimentSpec.
engine_options(seed)`` / ``run_seeds`` are the only derivation points;
nothing else in the repo seeds an engine or a UE stream by hand anymore.

Named presets live in ``presets.py`` and are resolved through the same
string-registry pattern as strategies and scenarios::

    spec = get_experiment("quickstart")
    spec = spec.override(**{"engine.rounds": 4, "seeds": (0, 1)})
    assert from_json(to_json(spec)) == spec
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.api import EngineOptions


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """What trains.  ``kind="classifier"`` is the paper's FL workload
    (``repro.models.classifier``); ``kind="lm"`` is the mesh-native LM
    path (``repro.experiments.lm`` — the old ``launch/train.py``)."""
    kind: str = "classifier"
    # classifier fields
    input_shape: Tuple[int, ...] = (14, 14, 1)
    hidden: Tuple[int, ...] = (64,)
    num_classes: int = 10
    # lm fields (batch layout of the mesh round)
    arch: str = "mamba2-130m"
    reduced: bool = True
    batch: int = 8
    seq: int = 256
    n_dpu: int = 2
    n_micro: int = 1
    gamma: int = 2


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """The synthetic pool + per-UE online streams (paper App. G)."""
    pool: int = 6000
    pool_seed: int = 0            # the pool is shared across the sweep
    mean_arrivals: float = 300.0
    std_arrivals: float = 30.0
    labels_per_ue: int = 5
    drift_labels: bool = False
    eval_examples: int = 500


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """Topology dims (paper Sec. VI: 20/10/5 full size).  The topology
    seed is spec-level: one network, many seeded runs over it."""
    num_ue: int = 6
    num_bs: int = 3
    num_dc: int = 2
    topology_seed: int = 0


@dataclasses.dataclass(frozen=True)
class ConstsSpec:
    """ML constants (paper Table III / Algs. 4-6).  ``mode="fixed"``
    takes the values below; ``mode="estimate"`` runs the one-shot
    pre-training estimation on probe UEs (seeded off the spec, not the
    run) and pads the per-UE Theta/sigma with UE means for the DCs."""
    mode: str = "fixed"
    L: float = 4.0
    theta: float = 2.0
    sigma: float = 1.0
    zeta1: float = 2.0
    zeta2: float = 1.0
    estimate_iters: int = 3
    probe_seed: int = 99


@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    """Objective weights of problem P (xi1..xi3, drift Delta).  ``T`` is
    derived from ``engine.rounds`` at build time."""
    xi1: float = 1.0
    xi2: float = 1e-2
    xi3: float = 1e-3
    drift: float = 0.3


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Loop hyper-parameters — the frozen mirror of
    :class:`~repro.core.api.EngineOptions` minus strategy / scenario /
    seed, which live on the ExperimentSpec (seeds as the sweep axis)."""
    rounds: int = 8
    eta: float = 0.1
    mu: float = 0.01
    theta: Optional[float] = None
    reoptimize_every: int = 1
    solver_outer: int = 2
    distributed_solver: bool = False
    solver_backend: str = "jit"
    gamma_default: int = 2
    m_default: float = 0.5
    rate_jitter: float = 0.15
    eval_every: int = 1
    kernel_backend: str = "auto"    # plane kernel dispatch (kernels/ops.py)
    sanitize: bool = False
    robust_agg: str = "none"        # byzantine counter: "none" /
                                    # "trimmed_mean" / "median"
    trim_frac: float = 0.1
    mesh_shape: Optional[Tuple[int, int]] = None
                                    # ('dpu', 'rows') device-mesh split for
                                    # the sharded plane round; None ->
                                    # single-device
    cohort_size: Optional[int] = None
                                    # per-round client sampling (K UEs drawn
                                    # per round); None -> full participation

    def __post_init__(self):
        # JSON round-trip: the default is None, so _from_dict cannot infer
        # the tuple shape — coerce a deserialized list here
        if isinstance(self.mesh_shape, list):
            object.__setattr__(self, "mesh_shape",
                               tuple(int(x) for x in self.mesh_shape))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment cell; ``seeds`` is the (vmappable) sweep axis."""
    name: str = "custom"
    model: ModelSpec = ModelSpec()
    data: DataSpec = DataSpec()
    network: NetworkSpec = NetworkSpec()
    consts: ConstsSpec = ConstsSpec()
    objective: ObjectiveSpec = ObjectiveSpec()
    engine: EngineSpec = EngineSpec()
    strategy: str = "cefl"
    scenario: str = "static"
    seeds: Tuple[int, ...] = (0,)

    # ------------------------------------------------ seed derivation --

    def engine_options(self, seed: int) -> EngineOptions:
        """THE seed derivation point: one run seed feeds the engine PRNG
        chain, the scenario (via the engine rng), and — through
        ``build.ExperimentContext.make_ues`` — the per-UE data streams."""
        e = self.engine
        return EngineOptions(
            rounds=e.rounds, eta=e.eta, mu=e.mu, theta=e.theta,
            strategy=self.strategy, scenario=self.scenario,
            reoptimize_every=e.reoptimize_every,
            solver_outer=e.solver_outer,
            distributed_solver=e.distributed_solver,
            solver_backend=e.solver_backend,
            gamma_default=e.gamma_default, m_default=e.m_default,
            rate_jitter=e.rate_jitter, seed=int(seed),
            eval_every=e.eval_every, kernel_backend=e.kernel_backend,
            sanitize=e.sanitize, robust_agg=e.robust_agg,
            trim_frac=e.trim_frac,
            mesh_shape=None if e.mesh_shape is None
            else tuple(int(x) for x in e.mesh_shape),
            cohort_size=e.cohort_size)

    @property
    def run_seeds(self) -> Tuple[int, ...]:
        return tuple(int(s) for s in self.seeds)

    # ----------------------------------------------------- overriding --

    def override(self, **updates) -> "ExperimentSpec":
        """Dotted-path functional update::

            spec.override(**{"engine.rounds": 4, "strategy": "fixed:0",
                             "seeds": (0, 1)})
        """
        spec = self
        for path, value in updates.items():
            parts = path.split(".")
            spec = _replace_path(spec, parts, value)
        return spec

    # ----------------------------------------------------------- json --

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        return _from_dict(cls, d)


def _replace_path(obj, parts: List[str], value):
    field_types = {f.name: f for f in dataclasses.fields(obj)}
    head = parts[0]
    if head not in field_types:
        raise KeyError(f"{type(obj).__name__} has no field {head!r} "
                       f"(available: {sorted(field_types)})")
    if len(parts) == 1:
        value = _coerce_value(getattr(obj, head), value)
        return dataclasses.replace(obj, **{head: value})
    return dataclasses.replace(
        obj, **{head: _replace_path(getattr(obj, head), parts[1:], value)})


def _coerce_value(current, value):
    """Match the current field's shape: tuples stay tuples, and numeric
    strings (CLI ``--set``) coerce to the current type."""
    if isinstance(current, tuple) and not isinstance(value, tuple):
        if isinstance(value, str):
            value = [v for v in value.replace(",", " ").split() if v]
        return tuple(type(current[0])(v) if current else v for v in value) \
            if current else tuple(value)
    if isinstance(value, str) and not isinstance(current, str):
        if isinstance(current, bool):
            return value.lower() in ("1", "true", "yes", "on")
        if isinstance(current, int):
            return int(value)
        if isinstance(current, float) or current is None:
            return float(value)
    return value


def _from_dict(cls, d: dict):
    kwargs = {}
    for f in dataclasses.fields(cls):
        if f.name not in d:
            continue
        v = d[f.name]
        if dataclasses.is_dataclass(f.default):
            kwargs[f.name] = _from_dict(type(f.default), v)
        elif isinstance(f.default, tuple) and v is not None:
            kwargs[f.name] = tuple(
                tuple(x) if isinstance(x, list) else x for x in v)
        else:
            kwargs[f.name] = v
    extra = set(d) - {f.name for f in dataclasses.fields(cls)}
    if extra:
        raise KeyError(f"unknown {cls.__name__} fields {sorted(extra)}")
    return cls(**kwargs)


def to_json(spec: ExperimentSpec, *, indent: int = 1) -> str:
    return json.dumps(spec.to_dict(), indent=indent)


def from_json(s: str) -> ExperimentSpec:
    return ExperimentSpec.from_dict(json.loads(s))


# -------------------------------------------------------- registry -----

_EXPERIMENT_REGISTRY: Dict[str, Callable[[], ExperimentSpec]] = {}


def register_experiment(name: str):
    """Decorator registering a preset factory: ``@register_experiment(
    "quickstart")`` over a zero-arg callable returning a spec."""
    def deco(factory):
        if name in _EXPERIMENT_REGISTRY:
            raise ValueError(f"experiment {name!r} already registered")
        _EXPERIMENT_REGISTRY[name] = factory
        return factory
    return deco


def available_experiments() -> List[str]:
    return sorted(_EXPERIMENT_REGISTRY)


def get_experiment(spec) -> ExperimentSpec:
    """Resolve a preset name / an ExperimentSpec instance / a dict."""
    if isinstance(spec, ExperimentSpec):
        return spec
    if isinstance(spec, dict):
        return ExperimentSpec.from_dict(spec)
    try:
        factory = _EXPERIMENT_REGISTRY[spec]
    except KeyError:
        raise KeyError(
            f"unknown experiment {spec!r}; available: "
            f"{available_experiments()}") from None
    out = factory()
    if out.name != spec:
        out = dataclasses.replace(out, name=spec)
    return out
