"""The experiment entry points: ``run(spec)`` and ``sweep(specs)``.

    from repro import experiments

    result = experiments.run("quickstart")                 # one run
    sweep = experiments.sweep("campus_walk_vs_fixed")      # all seeds
    sweep = experiments.sweep([spec_a, spec_b])            # spec grid
    sweep.stats()

``sweep`` executes every seed of every spec: device work batched across
seeds by the :class:`~repro.experiments.sweep.VmapSweepExecutor` by
default (``executor="sequential"`` is the pinned-bit-exact fallback).
``checkpoint_dir``/``checkpoint_every`` add full-state snapshots;
``resume=True`` continues a killed sweep to results identical to an
uninterrupted one (tests pin this under ``campus_walk``).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

from repro.core.api import RunResult
from repro.experiments.build import build_context
from repro.experiments.spec import ExperimentSpec, get_experiment
from repro.experiments.sweep import (SweepResult, get_sweep_executor)
from repro.experiments.trace import TraceSink

SpecLike = Union[str, dict, ExperimentSpec]


def run(spec: SpecLike, *, seed: Optional[int] = None,
        trace: Optional[TraceSink] = None, callbacks=()) -> RunResult:
    """Run ONE seed of a spec (default: the first of ``spec.seeds``)
    through the orchestration engine; LM specs dispatch to the
    mesh-native LM trainer."""
    spec = get_experiment(spec)
    if spec.model.kind == "lm":
        from repro.experiments.lm import run_lm
        from repro.experiments.trace import round_record
        if callbacks:
            raise ValueError("per-round callbacks are not supported for "
                             "lm specs (the mesh loop owns the rounds)")
        if seed is None and len(spec.run_seeds) != 1:
            raise ValueError(
                f"lm specs run one seed at a time; spec has seeds "
                f"{spec.run_seeds} — pass seed=... or set a single seed")
        seed = spec.run_seeds[0] if seed is None else int(seed)
        result = run_lm(spec, seed=seed)
        if trace is not None:
            for rep in result.reports:
                trace.write(round_record(spec.name, seed, rep,
                                         executor="lm"))
        return result
    seed = spec.run_seeds[0] if seed is None else int(seed)
    ctx = build_context(spec)
    engine = ctx.make_engine(seed, callbacks=callbacks)
    if trace is not None:
        from repro.experiments.trace import round_record

        @engine.on_round_end
        def _write(rep):
            trace.write(round_record(spec.name, seed, rep,
                                     executor="engine"))
    return engine.run(ctx.make_ues(seed), init_params=ctx.p0,
                      loss_fn=ctx.loss_fn, eval_fn=ctx.eval_fn)


def sweep(specs: Union[SpecLike, Sequence[SpecLike]], *,
          executor: str = "vmap",
          trace: Optional[TraceSink] = None,
          checkpoint_dir=None, checkpoint_every: int = 0,
          resume: bool = False,
          stop_after: Optional[int] = None) -> SweepResult:
    """Run every seed of one spec — or a whole spec grid — and return a
    typed :class:`SweepResult`.

    With multiple specs, each spec's seed axis is swept in turn (the
    vmapped batch axis is per-spec: different specs may have different
    shapes); checkpoints go to ``checkpoint_dir/<spec.name>``.
    """
    import os
    if isinstance(specs, (str, dict, ExperimentSpec)):
        specs = [specs]
    specs = [get_experiment(s) for s in specs]
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"sweep specs must have unique names: {names}")
    result: Optional[SweepResult] = None
    for spec in specs:
        if spec.model.kind != "classifier":
            raise ValueError(
                f"sweep supports classifier specs; run {spec.name!r} "
                f"(kind={spec.model.kind!r}) through run()")
        ckpt = None
        if checkpoint_dir is not None:
            ckpt = checkpoint_dir if len(specs) == 1 else \
                os.path.join(checkpoint_dir, spec.name)
        ex = get_sweep_executor(executor, checkpoint_dir=ckpt,
                                checkpoint_every=checkpoint_every,
                                resume=resume, stop_after=stop_after)
        ctx = build_context(spec)
        part = ex.run_sweep(ctx, trace=trace)
        result = part if result is None else result.merged(part)
    return result
