"""Declarative experiments: specs, presets, vmapped multi-seed sweeps,
full-state resume.  See docs/experiments.md.

    from repro import experiments
    result = experiments.run("quickstart")
    sweep = experiments.sweep("sweep_smoke", executor="vmap")
"""
from repro.experiments import presets  # noqa: F401  (registers built-ins)
from repro.experiments.build import (  # noqa: F401
    ExperimentContext, build_context, clear_context_cache,
)
from repro.experiments.run import run, sweep  # noqa: F401
from repro.experiments.spec import (  # noqa: F401
    ConstsSpec, DataSpec, EngineSpec, ExperimentSpec, ModelSpec,
    NetworkSpec, ObjectiveSpec, available_experiments, from_json,
    get_experiment, register_experiment, to_json,
)
from repro.experiments.sweep import (  # noqa: F401
    RunKey, SequentialSweepExecutor, SweepResult, VmapSweepExecutor,
    get_sweep_executor,
)
from repro.experiments.trace import (  # noqa: F401
    TraceSink, read_trace, round_record,
)
