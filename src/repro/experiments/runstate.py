"""Full-state sweep checkpoints: kill a sweep mid-run, resume bit-exact.

A sweep's :class:`RunState` is everything the remaining rounds depend
on, per seeded run:

* the engine :class:`~repro.core.engine.LoopState` (flat param plane,
  PRNG chain, warm-start plan, cumulative costs, round index),
* the scenario's internal state (mobility positions/velocities, serving
  associations, schedule state),
* every UE's :class:`~repro.core.drift.OnlineDataset` state (stream PRNG
  + live data buffer),
* the metric trace so far (``RoundReport`` records).

Serialization rides through ``repro.training.checkpoint``: array leaves
go to the .npz, the nesting structure is packed into a JSON *skeleton*
stored in the manifest metadata (with the report records, which are
JSON-native).  ``load_checkpoint`` validates the leaf list before
unpacking; shapes are data-dependent round to round (online buffers
grow), so the like-tree is built from the manifest itself.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.engine import Engine, LoopState
from repro.experiments.trace import report_from_record, report_to_record
from repro.training.checkpoint import (load_checkpoint, read_manifest,
                                       save_checkpoint)

STATE_KIND = "cefl-sweep-state"


# ------------------------------------------------- pack / unpack --------

def _pack(obj, leaves: list):
    """Nested dict/list/scalar structure -> JSON skeleton; ndarray leaves
    are swapped for ``{"__leaf__": i}`` placeholders appended to
    ``leaves`` (depth-first, deterministic order)."""
    if isinstance(obj, np.ndarray):
        leaves.append(obj)
        return {"__leaf__": len(leaves) - 1}
    if hasattr(obj, "dtype") and hasattr(obj, "shape"):   # jax arrays
        leaves.append(np.asarray(obj))
        return {"__leaf__": len(leaves) - 1}
    if isinstance(obj, dict):
        assert "__leaf__" not in obj, "reserved key"
        return {str(k): _pack(v, leaves) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_pack(v, leaves) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str)):
        if isinstance(obj, (bool, int)) and not isinstance(obj, bool):
            obj = int(obj)
        return obj
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"cannot pack {type(obj).__name__} into run state")


def _unpack(skel, leaves: list):
    if isinstance(skel, dict):
        if set(skel) == {"__leaf__"}:
            return leaves[skel["__leaf__"]]
        return {k: _unpack(v, leaves) for k, v in skel.items()}
    if isinstance(skel, list):
        return [_unpack(v, leaves) for v in skel]
    return skel


# ------------------------------------------------- save / load ----------

def sweep_state_dict(runs) -> Tuple[dict, dict]:
    """(array-state, json-reports) for a list of ``sweep._Run``s."""
    state, reports = {}, {}
    for run in runs:
        key = str(run.seed)
        state[key] = {
            "loop": run.state.state_dict(),
            "scenario": run.engine.scenario.state_dict(),
            "ues": {str(i): u.state_dict()
                    for i, u in enumerate(run.ues)},
        }
        reports[key] = [report_to_record(r) for r in run.state.reports]
    return state, reports


def save_sweep_state(path, runs, *, spec_json: str, round_idx: int) -> None:
    state, reports = sweep_state_dict(runs)
    leaves: List[np.ndarray] = []
    skeleton = _pack(state, leaves)
    save_checkpoint(path, leaves, step=round_idx, metadata={
        "kind": STATE_KIND,
        "skeleton": skeleton,
        "reports": reports,
        "spec": spec_json,
    })


def load_sweep_state(path):
    """-> (state dict, reports dict, spec_json, round_idx).  The saved
    leaf list is validated (count/treedef/shapes) against the manifest
    before unpacking — a corrupted npz/manifest pair raises instead of
    misassigning state."""
    manifest = read_manifest(path)
    meta = manifest["metadata"]
    if meta.get("kind") != STATE_KIND:
        raise ValueError(f"{path} is not a {STATE_KIND} checkpoint "
                         f"(kind={meta.get('kind')!r})")
    like = [np.zeros(s, dtype=d) for s, d in zip(manifest["shapes"],
                                                 manifest["dtypes"])]
    leaves, step, meta = load_checkpoint(path, like)
    leaves = [np.asarray(l) for l in leaves]
    state = _unpack(meta["skeleton"], leaves)
    return state, meta["reports"], meta["spec"], step


def restore_run(run, state: dict, reports: List[dict],
                engine: Engine) -> None:
    """Load one run's state into freshly built (round-0) objects."""
    use_plane = bool(getattr(engine.executor, "use_plane", True))
    assert isinstance(run.state, LoopState)
    run.state.load_state_dict(state["loop"], use_plane=use_plane)
    engine.scenario.load_state_dict(state["scenario"])
    for i, u in enumerate(run.ues):
        u.load_state_dict(state["ues"][str(i)])
    run.state.reports = [report_from_record(r) for r in reports]
