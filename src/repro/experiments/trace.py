"""JSONL trace sink: the experiment layer's flight recorder.

Every sweep executor writes one JSON line per (run, round) through a
:class:`TraceSink` — machine-readable round metrics that CI uploads as
artifacts and the resume path replays.  Records are plain dicts; the
canonical round record comes from :func:`round_record` /
:func:`report_from_record` (exact round trip, asserted by tests).
"""
from __future__ import annotations

import json
import os
from typing import IO, Optional

import numpy as np

from repro.core.api import RoundPlan, RoundReport

def plan_to_lists(plan: Optional[RoundPlan]) -> Optional[dict]:
    if plan is None:
        return None
    return {k: np.asarray(v).tolist() for k, v in plan.to_w().items()}


def plan_from_lists(d: Optional[dict]) -> Optional[RoundPlan]:
    if d is None:
        return None
    return RoundPlan.from_w({k: np.asarray(v, np.float32)
                             for k, v in d.items()})


def report_to_record(r: RoundReport) -> dict:
    """A RoundReport as a JSON-able dict (float values survive exactly:
    python floats are binary64 ⊃ the f32 metrics)."""
    return {
        "round": int(r.round), "acc": float(r.acc), "loss": float(r.loss),
        "energy": float(r.energy), "delay": float(r.delay),
        "cum_energy": float(r.cum_energy), "cum_delay": float(r.cum_delay),
        "aggregator": int(r.aggregator),
        "dc_points": [int(x) for x in r.dc_points],
        "gamma_mean": float(r.gamma_mean), "m_mean": float(r.m_mean),
        "plan": plan_to_lists(r.plan),
        "wall_time": float(r.wall_time),
        "handovers": [[int(a), int(b), int(c)]
                      for a, b, c in r.handovers],
        "aggregator_moved": bool(r.aggregator_moved),
        "active_ues": int(r.active_ues),
    }


def report_from_record(d: dict) -> RoundReport:
    return RoundReport(
        round=int(d["round"]), acc=float(d["acc"]), loss=float(d["loss"]),
        energy=float(d["energy"]), delay=float(d["delay"]),
        cum_energy=float(d["cum_energy"]),
        cum_delay=float(d["cum_delay"]),
        aggregator=int(d["aggregator"]),
        dc_points=tuple(int(x) for x in d["dc_points"]),
        gamma_mean=float(d["gamma_mean"]), m_mean=float(d["m_mean"]),
        plan=plan_from_lists(d.get("plan")),
        wall_time=float(d["wall_time"]),
        handovers=tuple((int(a), int(b), int(c))
                        for a, b, c in d["handovers"]),
        aggregator_moved=bool(d["aggregator_moved"]),
        active_ues=int(d["active_ues"]))


def round_record(name: str, seed: int, report: RoundReport, *,
                 executor: str = "", with_plan: bool = False) -> dict:
    """The JSONL line a sweep executor writes per (run, round).  Plans
    are omitted by default (they dominate line size); ``with_plan=True``
    keeps them for full-fidelity traces."""
    rec = report_to_record(report)
    if not with_plan:
        rec.pop("plan")
    rec.update(kind="round", experiment=name, seed=int(seed))
    if executor:
        rec["executor"] = executor
    return rec


class TraceSink:
    """Append-only JSONL writer.  ``TraceSink(None)`` is a no-op sink, so
    executors write unconditionally.  Lines are flushed as written —
    a killed run's trace is complete up to its last finished round."""

    def __init__(self, path=None, *, append: bool = False):
        self.path = os.fspath(path) if path is not None else None
        self._fh: Optional[IO] = None
        if self.path:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "a" if append else "w")

    def write(self, record: dict) -> None:
        if self._fh is None:
            return
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "TraceSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_trace(path) -> list:
    """All records of a JSONL trace file."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
