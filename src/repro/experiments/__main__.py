"""CLI for the declarative experiment API.

    python -m repro.experiments list
    python -m repro.experiments show quickstart
    python -m repro.experiments run quickstart
    python -m repro.experiments run sweep_smoke --executor vmap \
        --trace out/trace.jsonl
    python -m repro.experiments run campus_walk_vs_fixed \
        --set strategy=fixed:0 --seeds 0,1 --set engine.rounds=10
    python -m repro.experiments run sweep_smoke --checkpoint out/ck \
        --checkpoint-every 1 --resume

``NAME`` is a preset (``list`` shows them) or a path to a spec JSON
(written by ``show`` / ``--dump``).  ``--set`` takes dotted spec paths.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.experiments import (TraceSink, available_experiments,
                               build_context, from_json, get_experiment,
                               run as run_one, sweep, to_json)


def _load_spec(name: str):
    if name.endswith(".json") or os.path.sep in name:
        with open(name) as f:
            return from_json(f.read())
    return get_experiment(name)


def _apply_overrides(spec, args):
    updates = {}
    for kv in args.set or []:
        k, _, v = kv.partition("=")
        if not _:
            raise SystemExit(f"--set needs key=value, got {kv!r}")
        updates[k] = v
    if args.seeds:
        updates["seeds"] = tuple(
            int(s) for s in args.seeds.replace(",", " ").split())
    if args.rounds is not None:
        updates["engine.rounds"] = args.rounds
    if args.strategy:
        updates["strategy"] = args.strategy
    if args.scenario:
        updates["scenario"] = args.scenario
    return spec.override(**updates) if updates else spec


def _cmd_list(args):
    for name in available_experiments():
        spec = get_experiment(name)
        print(f"{name:22s} kind={spec.model.kind:10s} "
              f"strategy={spec.strategy:12s} scenario={spec.scenario:16s} "
              f"rounds={spec.engine.rounds:<4d} seeds={list(spec.seeds)}")
    return 0


def _cmd_show(args):
    spec = _apply_overrides(_load_spec(args.name), args)
    print(to_json(spec))
    return 0


def _cmd_run(args):
    spec = _apply_overrides(_load_spec(args.name), args)
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(to_json(spec))
    # append on resume: the pre-kill rounds are already in the file
    trace = TraceSink(args.trace, append=args.resume) if args.trace \
        else None
    if spec.model.kind == "lm":
        if args.checkpoint or args.resume or args.stop_after:
            raise SystemExit(
                "--checkpoint/--resume/--stop-after apply to classifier "
                "sweeps; for lm specs use repro.experiments.lm.run_lm("
                "spec, checkpoint=...) directly")
        res = run_one(spec, trace=trace)
        if trace:
            trace.close()
        print(f"final loss {res.final.loss:.4f}")
        return 0
    if (args.checkpoint_every or args.stop_after or args.resume) \
            and not args.checkpoint:
        raise SystemExit("--checkpoint-every/--stop-after/--resume need "
                         "--checkpoint <dir>")
    if len(spec.run_seeds) == 1 and not (args.checkpoint or args.resume
                                         or args.stop_after
                                         or args.executor == "vmap"):
        _print_header()
        res = run_one(spec, trace=trace, callbacks=(_print_round,))
        _print_final(spec.name, spec.run_seeds[0], res)
        if trace:
            trace.close()
        return 0
    result = sweep(spec, executor=args.executor, trace=trace,
                   checkpoint_dir=args.checkpoint,
                   checkpoint_every=args.checkpoint_every,
                   resume=args.resume, stop_after=args.stop_after)
    if trace:
        trace.close()
    for key, res in result.runs:
        _print_final(key.experiment, key.seed, res)
    print("\naggregate stats:")
    print(json.dumps(result.stats(), indent=1))
    return 0


def _print_header():
    print("round  acc    loss   aggregator  energy(J)  delay(s)")


def _print_round(r):
    print(f"{r.round:5d}  {r.acc:.3f}  {r.loss:6.3f}  DC{r.aggregator:<9d}"
          f" {r.energy:9.2f} {r.delay:9.2f}")


def _print_final(name, seed, res):
    f = res.final
    print(f"[{name} seed={seed}] rounds={len(res)} acc={f.acc:.3f} "
          f"loss={f.loss:.3f} E={f.cum_energy:.1f}J "
          f"delay={f.cum_delay:.1f}s aggregators={res.series('aggregator')}")


def _cmd_validate(args):
    spec = _apply_overrides(_load_spec(args.name), args)
    back = from_json(to_json(spec))
    assert back == spec, "spec JSON round-trip failed"
    build_context(spec)
    print(f"spec {spec.name!r} OK (json round-trip + context build)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.experiments")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="available presets")
    for cmd, fn in (("show", _cmd_show), ("run", _cmd_run),
                    ("validate", _cmd_validate)):
        p = sub.add_parser(cmd)
        p.add_argument("name", help="preset name or spec JSON path")
        p.add_argument("--set", action="append", metavar="PATH=VALUE",
                       help="dotted spec override, e.g. engine.rounds=4")
        p.add_argument("--seeds", help="comma-separated seed list")
        p.add_argument("--rounds", type=int)
        p.add_argument("--strategy")
        p.add_argument("--scenario")
        if cmd == "run":
            p.add_argument("--executor", default="vmap",
                           choices=("vmap", "sequential"))
            p.add_argument("--trace", help="JSONL trace output path")
            p.add_argument("--checkpoint", help="full-state snapshot dir")
            p.add_argument("--checkpoint-every", type=int, default=0)
            p.add_argument("--resume", action="store_true")
            p.add_argument("--stop-after", type=int, default=None,
                           help="stop (with snapshot) after N rounds")
            p.add_argument("--dump", help="write the resolved spec JSON")
    args = ap.parse_args(argv)
    if args.cmd == "list":
        return _cmd_list(args)
    return {"show": _cmd_show, "run": _cmd_run,
            "validate": _cmd_validate}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
