"""Three-tier edge network (paper Sec. II-A, Fig. 2): UEs, BSs, DCs.

Includes the wireless channel model (eqs. 12-13), wired capacities (14-15),
and the synthetic testbed generator reproducing App. F-D: measurements are
summarized as per-link normal distributions (subnetwork structure: each DC
anchors 2 BSs + 4 UEs; high intra- / low inter-subnetwork rates), then every
link rate is an i.i.d. draw from its distribution.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class NetworkConfig:
    num_ue: int = 20
    num_bs: int = 10
    num_dc: int = 5
    # radio (eq. 12-13)
    bandwidth_hz: float = 20e6           # V_{n,b}
    noise_density: float = 4e-21         # N0 (W/Hz)  (~ -174 dBm/Hz)
    ue_tx_power: float = 0.2             # W
    bs_tx_power: float = 10.0            # W
    # wired
    dc_capacity_gbps: tuple = (40.0, 50.0)     # R_s^max range
    bs_dc_capacity_gbps: tuple = (3.0, 4.0)    # R_{b,s}^max range
    dc_dc_gbps: tuple = (5.0, 10.0)
    # payloads.  Table III prints beta_M=6272, beta_D=4e7, but 6272 bits is
    # exactly one 28x28x8bit F-MNIST image and 4e7 bits ~ a 1.25M-param f32
    # model — the labels are clearly swapped; we use the physical reading
    # (see DESIGN.md §Assumptions).
    beta_data: float = 6272.0            # bits per datapoint
    beta_model: float = 4e7              # bits per model payload
    # UE compute (eqs. 26-27).  cycles_per_point/alpha re-based to physical
    # magnitudes (Table III's c_n=300, alpha=2e-16 yield absurd joules):
    # ~1e7 cycles per datapoint training pass (a small NN fwd+bwd) makes
    # UEs genuine stragglers on thousands of points — the paper's (C1)
    # premise — while DCs (eq. 28-29 server-farm model) finish instantly.
    f_min: float = 1e5                   # Hz
    f_max: float = 2.3e9
    cycles_per_point: float = 1e7        # c_n (cycles per datapoint-pass)
    alpha_eff: float = 1e-26             # chip effective capacitance
    # DC compute (eqs. 28-29)
    machines_per_dc: int = 700           # M_s
    dc_point_capacity: float = 5e6       # C_s points/s per machine
    dc_peak_power: float = 200.0         # \bar P_s (W)
    idle_fraction: float = 0.4           # 1 - rho
    # wired link powers
    bs_dc_link_power: float = 5.0        # P_{b,s} W
    dc_dc_link_power: float = 5.0        # P_{s,s'} W
    seed: int = 0


@dataclasses.dataclass
class Network:
    """Realized network with per-link rate distributions and draws."""
    cfg: NetworkConfig
    # rate means (bit/s)
    R_nb: np.ndarray      # (N, B) uplink UE->BS
    R_bn: np.ndarray      # (B, N) downlink BS->UE (broadcast rate per pair)
    R_bs_max: np.ndarray  # (B, S)
    R_s_max: np.ndarray   # (S,)
    R_ss: np.ndarray      # (S, S) DC<->DC
    R_sb: np.ndarray      # (S, B) DC->BS (model broadcast path)
    subnet_of_bs: np.ndarray  # (B,) DC index
    subnet_of_ue: np.ndarray  # (N,) DC index
    adjacency: np.ndarray     # (N+B+S, N+B+S) consensus graph H

    @property
    def dims(self):
        return self.cfg.num_ue, self.cfg.num_bs, self.cfg.num_dc

    def node_count(self):
        return self.cfg.num_ue + self.cfg.num_bs + self.cfg.num_dc

    def resample_rates(self, rng: np.random.RandomState, jitter: float = 0.1):
        """Per-round congestion: multiplicative lognormal jitter (App. F-D
        style resampling from measured distributions)."""
        def jit(x):
            return x * np.exp(rng.normal(0, jitter, x.shape))
        return dataclasses.replace(
            self, R_nb=jit(self.R_nb), R_bn=jit(self.R_bn),
            R_ss=jit(self.R_ss), R_sb=jit(self.R_sb))


def shannon_rate(bw_hz, tx_power, gain, noise_density):
    """eq. (12)/(13)."""
    noise = noise_density * bw_hz
    return bw_hz * np.log2(1.0 + tx_power * gain / noise)


def pathloss_gain(d_m):
    """3GPP urban macro path loss 128.1 + 37.6 log10(d_km) as a linear
    power gain.  Distances are in meters and clamped to >= 1 m; this is the
    mean channel — multiply by a squared-Rayleigh draw for small-scale
    fading (the eq. 12-13 channel model used by both ``make_network`` and
    the mobility scenarios)."""
    d_km = np.maximum(np.asarray(d_m, float), 1.0) / 1000.0
    return 10.0 ** (-(128.1 + 37.6 * np.log10(d_km)) / 10.0)


def subnetwork(net: Network, ue_idx) -> Network:
    """The network restricted to the UE subset ``ue_idx`` (BSs/DCs kept).

    The cohort-sampling view: per-round client sampling solves the
    orchestration problem over the K drawn UEs only, so every UE-indexed
    rate matrix is gathered to the cohort rows.  The consensus graph is
    dropped (a UE-subset of H is not a valid consensus topology —
    cohort runs use the centralized solver).
    """
    ue_idx = np.asarray(ue_idx, int)
    cfg = dataclasses.replace(net.cfg, num_ue=int(ue_idx.shape[0]))
    return dataclasses.replace(
        net, cfg=cfg, R_nb=net.R_nb[ue_idx], R_bn=net.R_bn[:, ue_idx],
        subnet_of_ue=net.subnet_of_ue[ue_idx],
        adjacency=np.zeros((0, 0), dtype=int))


def make_network(cfg: NetworkConfig = NetworkConfig(),
                 edge_prob: float = 0.3, *,
                 consensus: bool = True) -> Network:
    """Synthetic 5G/CBRS-testbed-like network (App. F-D).

    ``consensus=False`` skips the O(V^2) consensus graph (only the
    distributed solver reads ``adjacency``) and draws the channel gains
    vectorized — required past ~10^4 UEs, where the per-pair Python loop
    and the dense (V, V) adjacency become the wall.  The two modes draw
    from the rng in different orders, so a seeded topology is
    reproducible only within one mode.
    """
    rng = np.random.RandomState(cfg.seed)
    N, B, S = cfg.num_ue, cfg.num_bs, cfg.num_dc
    bs_per_dc = max(1, B // S)
    ue_per_dc = max(1, N // S)
    subnet_of_bs = np.minimum(np.arange(B) // bs_per_dc, S - 1)
    subnet_of_ue = np.minimum(np.arange(N) // ue_per_dc, S - 1)

    # channel gains: intra-subnet strong, inter-subnet weak (path loss)
    if consensus:
        gain = np.zeros((N, B))
        for n in range(N):
            for b in range(B):
                same = subnet_of_ue[n] == subnet_of_bs[b]
                d = rng.uniform(50, 200) if same else rng.uniform(400, 1200)
                gain[n, b] = pathloss_gain(d) * rng.rayleigh(1.0) ** 2
    else:
        same = subnet_of_ue[:, None] == subnet_of_bs[None, :]
        d = np.where(same, rng.uniform(50, 200, (N, B)),
                     rng.uniform(400, 1200, (N, B)))
        gain = pathloss_gain(d) * rng.rayleigh(1.0, (N, B)) ** 2
    R_nb = shannon_rate(cfg.bandwidth_hz, cfg.ue_tx_power, gain,
                        cfg.noise_density)
    R_bn = shannon_rate(cfg.bandwidth_hz, cfg.bs_tx_power, gain.T,
                        cfg.noise_density)

    def urange(lo_hi, shape):
        return rng.uniform(lo_hi[0], lo_hi[1], shape) * 1e9

    R_bs_max = urange(cfg.bs_dc_capacity_gbps, (B, S))
    # intra-subnet wired links are faster
    for b in range(B):
        R_bs_max[b, subnet_of_bs[b]] *= 2.0
    R_s_max = urange(cfg.dc_capacity_gbps, (S,))
    R_ss = urange(cfg.dc_dc_gbps, (S, S))
    np.fill_diagonal(R_ss, np.inf)
    R_sb = R_bs_max.T * rng.uniform(1.0, 1.5, (S, B))

    # consensus communication graph H (App. G-C): random edges, p=0.3,
    # plus connectivity guarantees (UE>=1 BS, BS>=1 DC, DC>=1 DC)
    if not consensus:
        return Network(cfg=cfg, R_nb=R_nb, R_bn=R_bn, R_bs_max=R_bs_max,
                       R_s_max=R_s_max, R_ss=R_ss, R_sb=R_sb,
                       subnet_of_bs=subnet_of_bs, subnet_of_ue=subnet_of_ue,
                       adjacency=np.zeros((0, 0), dtype=int))
    V = N + B + S
    A = np.zeros((V, V), dtype=int)
    def add(i, j):
        A[i, j] = A[j, i] = 1
    for n in range(N):
        for b in range(B):
            if rng.rand() < edge_prob:
                add(n, N + b)
        # D2D edges among UEs in the same subnet
        for n2 in range(n + 1, N):
            if subnet_of_ue[n] == subnet_of_ue[n2] and rng.rand() < edge_prob:
                add(n, n2)
    for b in range(B):
        for s in range(S):
            if rng.rand() < edge_prob:
                add(N + b, N + B + s)
    for s in range(S):
        for s2 in range(s + 1, S):
            if rng.rand() < edge_prob:
                add(N + B + s, N + B + s2)
    # connectivity guarantees
    for n in range(N):
        if not A[n, N:N + B].any():
            add(n, N + int(np.argmax(R_nb[n])))
    for b in range(B):
        if not A[N + b, N + B:].any():
            add(N + b, N + B + int(subnet_of_bs[b]))
    for s in range(S):
        others = [s2 for s2 in range(S) if s2 != s]
        if not any(A[N + B + s, N + B + s2] for s2 in others):
            add(N + B + s, N + B + ((s + 1) % S))
    return Network(cfg=cfg, R_nb=R_nb, R_bn=R_bn, R_bs_max=R_bs_max,
                   R_s_max=R_s_max, R_ss=R_ss, R_sb=R_sb,
                   subnet_of_bs=subnet_of_bs, subnet_of_ue=subnet_of_ue,
                   adjacency=A)
