from repro.network.costs import (  # noqa: F401
    data_configuration, network_costs, round_delay, round_energy,
)
from repro.network.topology import (  # noqa: F401
    Network, NetworkConfig, make_network, pathloss_gain, shannon_rate,
)
