"""Delay / energy models (paper eqs. 16-40), differentiable in jnp so the
distributed solver can take gradients through them.

Decision variables (dict w):
  rho_nb (N,B), rho_bs (B,S), f_n (N,), z_s (S,), gamma (N+S,), m (N+S,),
  I_s (S,), I_nb (N,B), I_bn (B,N), R_bs (B,S), delta_A (), delta_R ().
Context: Network topology + per-UE data sizes D_bar (N,).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

EPS = 1e-9


def data_configuration(w, D_bar):
    """eqs. (16)-(18)."""
    rho_nb, rho_bs = w["rho_nb"], w["rho_bs"]
    D_n = (1.0 - jnp.sum(rho_nb, axis=1)) * D_bar          # kept at UEs
    D_b = jnp.sum(rho_nb * D_bar[:, None], axis=0)          # (B,)
    D_s = jnp.sum(rho_bs * D_b[:, None], axis=0)            # (S,)
    return D_n, D_b, D_s


def network_costs(w: Dict, net, D_bar) -> Dict:
    """All cost terms of Sec. II-E for decision w.  Arrays are jnp."""
    cfg = net.cfg
    N, B, S = net.dims
    R_nb = jnp.asarray(net.R_nb)
    R_bn = jnp.asarray(net.R_bn)
    R_ss = jnp.asarray(net.R_ss)
    R_sb = jnp.asarray(net.R_sb)
    D_bar = jnp.asarray(D_bar, jnp.float32)
    rho_nb, rho_bs = w["rho_nb"], w["rho_bs"]
    I_s, I_nb, I_bn = w["I_s"], w["I_nb"], w["I_bn"]
    R_bs = w["R_bs"]

    D_n, D_b, D_s = data_configuration(w, D_bar)

    # --- UE->BS transfers (eqs. 19-20)
    d_nb_D = cfg.beta_data * D_bar[:, None] * rho_nb / (R_nb + EPS)
    d_nb_M = cfg.beta_model / (R_nb + EPS)
    E_nb_D = d_nb_D * cfg.ue_tx_power
    E_nb_M = d_nb_M * cfg.ue_tx_power

    # --- BS->DC transfers (eqs. 21, 23)
    d_bs_D = cfg.beta_data * D_b[:, None] * rho_bs / (R_bs + EPS)
    d_bs_M = cfg.beta_model / (R_bs + EPS)
    E_bs_D = d_bs_D * cfg.bs_dc_link_power
    E_bs_M = d_bs_M * cfg.bs_dc_link_power

    # --- data collection delay at DCs (eq. 22)
    d_s_D = jnp.max(d_bs_D, axis=0) + jnp.max(d_nb_D)

    # --- DC<->DC (eq. 24)
    d_ss_M = cfg.beta_model / (R_ss + EPS)
    d_ss_M = d_ss_M * (1.0 - jnp.eye(S))
    E_ss_M = d_ss_M * cfg.dc_dc_link_power

    # --- processing (eqs. 26-29)
    gamma_n, gamma_s = w["gamma"][:N], w["gamma"][N:]
    m_n, m_s = w["m"][:N], w["m"][N:]
    d_n_P = cfg.cycles_per_point * gamma_n * m_n * D_n / (w["f_n"] + EPS)
    E_n_P = cfg.cycles_per_point * gamma_n * m_n * D_n \
        * w["f_n"] ** 2 * cfg.alpha_eff / 2.0
    d_s_P = gamma_s * m_s * D_s / (cfg.machines_per_dc * w["z_s"] + EPS)
    rho_pow = 1.0 - cfg.idle_fraction
    E_s_P = d_s_P * (rho_pow * (w["z_s"] / cfg.dc_point_capacity) ** 2
                     * cfg.dc_peak_power * cfg.machines_per_dc
                     + cfg.idle_fraction * cfg.dc_peak_power
                     * cfg.machines_per_dc)

    # --- aggregation path (eqs. 30-35).  The BS->DC hop is factored as
    # I_nb @ (d_bs_M @ I_s): the naive sum_{b,s} I_nb*d_bs_M*I_s form
    # materializes an (N, B, S) tensor — ~10 GB at N=10^5 — for what is
    # two small matvecs.
    d_n_A = jnp.sum(d_nb_M * I_nb, axis=1) + I_nb @ (d_bs_M @ I_s)
    E_n_A = jnp.sum(E_nb_M * I_nb, axis=1) + I_nb @ (E_bs_M @ I_s)
    d_s_A = jnp.sum(d_ss_M * I_s[None, :], axis=1)
    E_s_A = jnp.sum(E_ss_M * I_s[None, :], axis=1)
    delta_A_req = jnp.maximum(jnp.max(d_n_A + d_n_P),
                              jnp.max(d_s_D + d_s_P + d_s_A))
    E_A = jnp.sum(E_n_A) + jnp.sum(E_s_A)

    # --- broadcast/reception path (eqs. 36-40)
    d_sb_M = cfg.beta_model / (R_sb + EPS)
    E_sb_M = d_sb_M * cfg.dc_dc_link_power
    d_b_R = jnp.sum(d_sb_M * I_s[:, None], axis=0)
    E_b_R = jnp.sum(E_sb_M * I_s[:, None], axis=0)
    d_bn_M = cfg.beta_model / (R_bn + EPS)
    d_b_B = jnp.max(d_bn_M * I_bn, axis=1)
    E_b_B = d_b_B * cfg.bs_tx_power
    d_s_R = jnp.sum(d_ss_M.T * I_s[:, None], axis=0)
    E_s_R = jnp.sum(E_ss_M.T * I_s[:, None], axis=0)
    delta_R_req = jnp.maximum(jnp.max(d_b_R + d_b_B), jnp.max(d_s_R))
    E_R = jnp.sum(E_b_R + E_b_B) + jnp.sum(E_s_R)

    return {
        "D_n": D_n, "D_b": D_b, "D_s": D_s,
        "d_nb_D": d_nb_D, "d_bs_D": d_bs_D, "d_s_D": d_s_D,
        "E_nb_D": E_nb_D, "E_bs_D": E_bs_D,
        "d_n_P": d_n_P, "d_s_P": d_s_P, "E_n_P": E_n_P, "E_s_P": E_s_P,
        "d_n_A": d_n_A, "d_s_A": d_s_A, "delta_A_req": delta_A_req,
        "E_A": E_A,
        "d_b_R": d_b_R, "d_b_B": d_b_B, "d_s_R": d_s_R,
        "delta_R_req": delta_R_req, "E_R": E_R,
        "E_data": jnp.sum(E_nb_D) + jnp.sum(E_bs_D),
        "E_proc": jnp.sum(E_n_P) + jnp.sum(E_s_P),
    }


def round_delay(costs: Dict):
    """tau^t upper bound used in the objective: delta^A + delta^R."""
    return costs["delta_A_req"] + costs["delta_R_req"]


def round_energy(costs: Dict, xi3=(1.0,) * 6):
    """Total weighted energy (terms c,d,e of eq. 44)."""
    x1, x2, x3, x4, x5, x6 = xi3
    return (x1 * jnp.sum(costs["E_nb_D"]) + x2 * jnp.sum(costs["E_bs_D"])
            + x3 * jnp.sum(costs["E_n_P"]) + x4 * jnp.sum(costs["E_s_P"])
            + x5 * costs["E_A"] + x6 * costs["E_R"])
