"""MoE routing invariants + data pipeline determinism/drift."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core.drift import OnlineDataset, estimate_drift
from repro.data import make_image_dataset, make_online_ues, \
    make_token_batches
from repro.models.classifier import classifier_loss, init_classifier_params
from repro.models.moe import init_moe_params, moe_capacity, moe_forward

KEY = jax.random.PRNGKey(0)


def _moe(E=4, k=2, d=16, ff=32, cf=2.0):
    m = MoEConfig(num_experts=E, top_k=k, expert_ff=ff, capacity_factor=cf)
    p = init_moe_params(KEY, d, m, jnp.float32)
    return m, p


def test_moe_dropfree_equals_dense_topk():
    """With capacity = group size (drop-free), output == explicit weighted
    sum over the top-k experts."""
    m, p = _moe()
    x = jax.random.normal(KEY, (2, 8, 16)) * 0.5
    y, aux = moe_forward(p, x, m, group_size=16, capacity=16)
    # explicit dense computation
    logits = jnp.einsum("btd,de->bte", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    h = jnp.einsum("btd,edf->btef", x, p["w_in"])
    g = jnp.einsum("btd,edf->btef", x, p["w_gate"])
    ye = jnp.einsum("btef,efd->bted", jax.nn.silu(g) * h, p["w_out"])
    dense = jnp.zeros_like(x)
    for kk in range(m.top_k):
        sel = jnp.take_along_axis(ye, ids[..., kk][..., None, None],
                                  axis=2)[:, :, 0]
        dense = dense + gate[..., kk][..., None] * sel
    np.testing.assert_allclose(y, dense, atol=2e-5)


def test_moe_capacity_drops_tokens():
    m, p = _moe(cf=0.3)
    x = jax.random.normal(KEY, (2, 16, 16))
    y_small, _ = moe_forward(p, x, m, group_size=32)
    y_free, _ = moe_forward(p, x, m, group_size=32, capacity=32)
    assert float(jnp.max(jnp.abs(y_small - y_free))) > 1e-6


def test_moe_aux_losses():
    m, p = _moe()
    x = jax.random.normal(KEY, (2, 32, 16))
    _, aux = moe_forward(p, x, m, group_size=64)
    assert float(aux["load_balance"]) >= 1.0 - 1e-3   # >= 1 at uniformity
    assert float(aux["router_z"]) >= 0


def test_capacity_formula():
    m = MoEConfig(num_experts=8, top_k=2, expert_ff=4, capacity_factor=1.25)
    assert moe_capacity(256, m) == int(256 * 2 * 1.25 / 8)


def test_image_dataset_learnable_and_deterministic():
    (x1, y1), _ = make_image_dataset(500, (8, 8, 1), seed=3)
    (x2, y2), _ = make_image_dataset(500, (8, 8, 1), seed=3)
    np.testing.assert_array_equal(x1, x2)
    # classes are separable by template correlation
    assert len(np.unique(y1)) == 10


def test_online_dataset_arrivals_and_support():
    (x, y), _ = make_image_dataset(2000, (8, 8, 1))
    ues = make_online_ues(x, y, num_ue=3, labels_per_ue=5,
                          mean_arrivals=300, std_arrivals=10, seed=1)
    d = ues[0].step()
    labels = np.unique(np.asarray(d["y"]))
    assert len(labels) <= 5
    assert 200 < len(d["y"]) < 400
    # deterministic across re-creation
    ues2 = make_online_ues(x, y, num_ue=3, labels_per_ue=5,
                           mean_arrivals=300, std_arrivals=10, seed=1)
    d2 = ues2[0].step()
    np.testing.assert_array_equal(np.asarray(d["y"]), np.asarray(d2["y"]))


def test_drift_estimate_positive_under_label_shift():
    (x, y), _ = make_image_dataset(2000, (8, 8, 1))
    ds = OnlineDataset(features=x, labels=y, label_support=np.arange(5),
                       mean_arrivals=200, std_arrivals=10, seed=0,
                       drift_labels=True)
    d_t = ds.step()
    d_tp1 = ds.step()
    from repro.configs.cefl_paper import ClassifierConfig
    cfg = ClassifierConfig(input_shape=(8, 8, 1), hidden=(16,))
    probes = [init_classifier_params(jax.random.PRNGKey(i), cfg)
              for i in range(3)]
    delta = estimate_drift(classifier_loss, probes, d_t, d_tp1,
                           len(d_t["y"]) * 3, len(d_tp1["y"]) * 3, tau=1.0)
    assert np.isfinite(delta)


def test_drift_estimate_vmap_matches_loop():
    """The vmapped probe batching is a pure perf change: it must reproduce
    the per-probe Python loop (the pre-vmap implementation) exactly."""
    from repro.configs.cefl_paper import ClassifierConfig
    from repro.core.drift import _estimate_drift_loop
    (x, y), _ = make_image_dataset(1500, (8, 8, 1))
    ds = OnlineDataset(features=x, labels=y, label_support=np.arange(4),
                       mean_arrivals=150, std_arrivals=10, seed=3,
                       drift_labels=True)
    d_t, d_tp1 = ds.step(), ds.step()
    cfg = ClassifierConfig(input_shape=(8, 8, 1), hidden=(16,))
    probes = [init_classifier_params(jax.random.PRNGKey(i), cfg)
              for i in range(4)]
    args = (classifier_loss, probes, d_t, d_tp1,
            len(d_t["y"]) * 2, len(d_tp1["y"]) * 2)
    np.testing.assert_allclose(estimate_drift(*args, tau=0.5),
                               _estimate_drift_loop(*args, tau=0.5),
                               rtol=1e-5)


def test_token_batches_layout():
    b = make_token_batches(vocab=100, n_dpu=2, n_micro=3, mb=4, seq=16,
                           enc_seq=8, d_model=12)
    assert b["tokens"].shape == (2, 3, 4, 16)
    assert b["enc_embed"].shape == (2, 3, 4, 8, 12)
    assert b["tokens"].max() < 100
    np.testing.assert_array_equal(b["labels"][..., :-1], b["tokens"][..., 1:])
