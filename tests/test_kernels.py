"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles (ref.py),
interpret=True on CPU.  The tiled-plan tests run the compiled grid
decomposition (explicit TilePlan) in the interpreter — the parity
substrate for the accelerator launches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fedprox_update import LANE, ROWS, fedprox_accum_2d, \
    fedprox_update_2d
from repro.kernels.nova_aggregate import nova_aggregate_2d, \
    nova_aggregate_stacked_2d
from repro.kernels.swa_decode_attention import swa_decode_attention
from repro.kernels.tiling import (DOUBLE_BUFFER, LANE_MIN,
                                  MEMORY_BUDGET_BYTES, TilePlan, plan_tiles,
                                  sublane)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows", [ROWS, 2 * ROWS])
def test_fedprox_kernel_sweep(dtype, rows):
    x = jax.random.normal(KEY, (rows, LANE)).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (rows, LANE)).astype(dtype)
    a = jax.random.normal(jax.random.PRNGKey(2), (rows, LANE)).astype(dtype)
    out = fedprox_update_2d(x, g, a, 0.1, 0.05, interpret=True)
    exp = ref.fedprox_update_ref(x, g, a, 0.1, 0.05)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


@pytest.mark.parametrize("n_dpu", [1, 2, 5])
def test_nova_kernel_sweep(n_dpu):
    from repro.kernels.nova_aggregate import LANE as NL, ROWS as NR
    x = jax.random.normal(KEY, (NR, NL))
    d = jax.random.normal(jax.random.PRNGKey(1), (n_dpu, NR, NL))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n_dpu,))) + 0.1
    wn = w / jnp.sum(w)
    out = nova_aggregate_2d(x, d, wn, 0.05, interpret=True)
    exp = ref.nova_aggregate_ref(x, d, wn, 0.05)
    np.testing.assert_allclose(out, exp, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 8, 2, 32, 256), (1, 4, 4, 16, 128)])
@pytest.mark.parametrize("cache_len_frac", [0.4, 1.0])
def test_swa_decode_kernel_sweep(dtype, shape, cache_len_frac):
    B, Hq, Hkv, D, S = shape
    cache_len = max(1, int(S * cache_len_frac))
    q = jax.random.normal(KEY, (B, Hq, D)).astype(dtype)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D)).astype(dtype)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D)).astype(dtype)
    out = swa_decode_attention(q, kc, vc, cache_len, chunk=64,
                               interpret=True)
    exp = ref.swa_decode_attention_ref(q, kc, vc, cache_len)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


# ------------------------------------------------ tiled-grid parity -----
# Explicit TilePlans run the compiled 2-D block decomposition (pl.cdiv
# padded edge grids, gblk=1 DPU streaming, scratch grid-accumulation) in
# the interpreter, where it must match the oracles bit-for-bit in f32.

TILED = TilePlan(rows=16, lanes=512, backend="tpu")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows", [16, 24, 40])   # 24, 40: padded edge rows
def test_fedprox_tiled_plan_parity(dtype, rows):
    x = jax.random.normal(KEY, (rows, LANE)).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (rows, LANE)).astype(dtype)
    a = jax.random.normal(jax.random.PRNGKey(2), (rows, LANE)).astype(dtype)
    out = fedprox_update_2d(x, g, a, 0.1, 0.05, interpret=True, plan=TILED)
    exp = ref.fedprox_update_ref(x, g, a, 0.1, 0.05)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


@pytest.mark.parametrize("anchor_form", ["shared", "per_dpu"])
@pytest.mark.parametrize("rows", [16, 24])
def test_fedprox_accum_tiled_plan_parity(anchor_form, rows):
    """The batched G-axis kernel on the tiled (gblk=1) grid, with both
    the shared anchor and the per-DPU anchor form ``VmapSweepExecutor``
    uses (every sweep member anchors at its own round-start params)."""
    G = 3
    x = jax.random.normal(KEY, (G, rows, LANE))
    g = jax.random.normal(jax.random.PRNGKey(1), (G, rows, LANE))
    anchor = (x * 0.9 if anchor_form == "per_dpu"
              else jax.random.normal(jax.random.PRNGKey(2), (rows, LANE)))
    acc = jax.random.normal(jax.random.PRNGKey(3), (G, rows, LANE))
    coef = jnp.asarray([1.0, 0.5, 0.25])
    active = jnp.asarray([1.0, 1.0, 0.0])
    out = fedprox_accum_2d(x, g, anchor, acc, coef, active, 0.1, 0.05,
                           interpret=True, plan=TILED)
    exp = ref.fedprox_accum_ref(x, g, anchor, acc, coef, active, 0.1, 0.05)
    for o, e in zip(out, exp):
        np.testing.assert_allclose(np.asarray(o), np.asarray(e), atol=1e-6)


@pytest.mark.parametrize("rows", [16, 24])
@pytest.mark.parametrize("n_dpu", [1, 5])
def test_nova_tiled_plan_parity(rows, n_dpu):
    """Grid accumulation over the DPU axis (scratch zero-init under
    @pl.when(k==0), flush at k==n-1) vs the einsum oracle."""
    x = jax.random.normal(KEY, (rows, LANE))
    d = jax.random.normal(jax.random.PRNGKey(1), (n_dpu, rows, LANE))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n_dpu,))) + 0.1
    wn = w / jnp.sum(w)
    out = nova_aggregate_2d(x, d, wn, 0.05, interpret=True, plan=TILED)
    exp = ref.nova_aggregate_ref(x, d, wn, 0.05)
    np.testing.assert_allclose(out, exp, atol=1e-5)
    xs = jnp.broadcast_to(x[None], (n_dpu, rows, LANE))
    outs = nova_aggregate_stacked_2d(xs, d, wn, 0.05, interpret=True,
                                     plan=TILED)
    exps = ref.nova_aggregate_ref(xs, d, wn, 0.05)
    np.testing.assert_allclose(outs, exps, atol=1e-5)


# -------------------------------------------------- tiling planner -----

@pytest.mark.parametrize("backend", ["tpu", "gpu"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_operands", [4, 6, 18])
def test_plan_tiles_fits_budget(backend, dtype, n_operands):
    plan = plan_tiles(2048, 1024, n_operands=n_operands, dtype=dtype,
                      backend=backend)
    budget = MEMORY_BUDGET_BYTES[backend]
    assert plan.block_bytes(n_operands, dtype) <= budget
    assert plan.rows % sublane(dtype) == 0
    assert plan.lanes % LANE_MIN == 0
    assert plan.backend == backend


def test_plan_tiles_interpret_is_whole_array():
    plan = plan_tiles(2048, 1024, n_operands=6, backend="interpret")
    assert (plan.rows, plan.lanes) == (2048, 1024)


def test_plan_tiles_is_jit_static():
    """Plans are hashable and cached — usable as jit static args."""
    a = plan_tiles(256, 1024, n_operands=4, backend="tpu")
    b = plan_tiles(256, 1024, n_operands=4, backend="tpu")
    assert a is b and hash(a) == hash(b)
    assert plan_tiles(256, 1024, n_operands=4, backend="gpu") != a
    with pytest.raises(ValueError):
        plan_tiles(256, 1024, n_operands=4, backend="mainframe")


# ---------------------------------------------- backend dispatch -----

def test_backend_dispatch_no_retrace(assert_no_retrace):
    """Round-over-round calls through the dispatch layer must hit the
    jit caches — backend resolution happens at trace time and must not
    leak anything retrace-inducing into the traced graph."""
    x = jax.random.normal(KEY, (3, 16, LANE))
    acc = jnp.zeros_like(x)
    coef = jnp.ones((3,))
    w = jnp.full((3,), 1 / 3)

    @jax.jit
    def round_like(x, acc):
        x1, a1 = ops.fedprox_accum_plane(x, x * 0.1, x, acc, coef, coef,
                                         0.1, 0.01)
        return ops.nova_aggregate_plane(x1, a1, w, 0.05)

    out = round_like(x, acc)          # warmup: compiles here are fine
    with assert_no_retrace():
        for _ in range(3):
            out = round_like(out, acc)
    assert out.shape == x.shape


def test_backend_dispatch_cpu_matches_interpret():
    """The "cpu" jitted-ref path is bitwise equal to interpret mode (the
    kernel bodies are expression-identical), eagerly and under jit."""
    x = jax.random.normal(KEY, (16, LANE))
    g, a = x * 0.1, x * 0.9
    cpu = ops.fedprox_plane(x, g, a, 0.1, 0.01, backend="cpu")
    itp = ops.fedprox_plane(x, g, a, 0.1, 0.01, backend="interpret")
    jit_cpu = jax.jit(lambda *t: ops.fedprox_plane(*t, 0.1, 0.01,
                                                   backend="cpu"))(x, g, a)
    np.testing.assert_array_equal(np.asarray(cpu), np.asarray(itp))
    np.testing.assert_array_equal(np.asarray(cpu), np.asarray(jit_cpu))


def test_ops_pytree_roundtrip():
    params = {"w": jax.random.normal(KEY, (37, 13)),
              "b": jax.random.normal(KEY, (7,)),
              "nested": {"u": jax.random.normal(KEY, (2, 3, 5))}}
    grads = jax.tree_util.tree_map(lambda x: 0.3 * x, params)
    anchor = jax.tree_util.tree_map(lambda x: 0.7 * x, params)
    out = ops.fedprox_update(params, grads, anchor, 0.1, 0.2)
    exp = jax.tree_util.tree_map(
        lambda x, g, a: ref.fedprox_update_ref(x, g, a, 0.1, 0.2),
        params, grads, anchor)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(exp)):
        assert a.shape == b.shape
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_ops_nova_matches_aggregation_module():
    """Kernel wrapper == repro.core.aggregation.aggregate on pytrees."""
    from repro.core.aggregation import aggregate
    params = {"w": jax.random.normal(KEY, (33, 9))}
    ds = [jax.tree_util.tree_map(lambda x: (i + 1) * 0.1 * x, params)
          for i in range(3)]
    out = ops.nova_aggregate(params, ds, [1.0, 2.0, 1.0], 0.02)
    exp = aggregate(params, ds, [1.0, 2.0, 1.0], theta=1.0, eta=0.02)
    np.testing.assert_allclose(out["w"], exp["w"], atol=1e-5)
