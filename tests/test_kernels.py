"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles (ref.py),
interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fedprox_update import LANE, ROWS, fedprox_update_2d
from repro.kernels.nova_aggregate import nova_aggregate_2d
from repro.kernels.swa_decode_attention import swa_decode_attention

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows", [ROWS, 2 * ROWS])
def test_fedprox_kernel_sweep(dtype, rows):
    x = jax.random.normal(KEY, (rows, LANE)).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (rows, LANE)).astype(dtype)
    a = jax.random.normal(jax.random.PRNGKey(2), (rows, LANE)).astype(dtype)
    out = fedprox_update_2d(x, g, a, 0.1, 0.05, interpret=True)
    exp = ref.fedprox_update_ref(x, g, a, 0.1, 0.05)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


@pytest.mark.parametrize("n_dpu", [1, 2, 5])
def test_nova_kernel_sweep(n_dpu):
    from repro.kernels.nova_aggregate import LANE as NL, ROWS as NR
    x = jax.random.normal(KEY, (NR, NL))
    d = jax.random.normal(jax.random.PRNGKey(1), (n_dpu, NR, NL))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n_dpu,))) + 0.1
    wn = w / jnp.sum(w)
    out = nova_aggregate_2d(x, d, wn, 0.05, interpret=True)
    exp = ref.nova_aggregate_ref(x, d, wn, 0.05)
    np.testing.assert_allclose(out, exp, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 8, 2, 32, 256), (1, 4, 4, 16, 128)])
@pytest.mark.parametrize("cache_len_frac", [0.4, 1.0])
def test_swa_decode_kernel_sweep(dtype, shape, cache_len_frac):
    B, Hq, Hkv, D, S = shape
    cache_len = max(1, int(S * cache_len_frac))
    q = jax.random.normal(KEY, (B, Hq, D)).astype(dtype)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D)).astype(dtype)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D)).astype(dtype)
    out = swa_decode_attention(q, kc, vc, cache_len, chunk=64,
                               interpret=True)
    exp = ref.swa_decode_attention_ref(q, kc, vc, cache_len)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


def test_ops_pytree_roundtrip():
    params = {"w": jax.random.normal(KEY, (37, 13)),
              "b": jax.random.normal(KEY, (7,)),
              "nested": {"u": jax.random.normal(KEY, (2, 3, 5))}}
    grads = jax.tree_util.tree_map(lambda x: 0.3 * x, params)
    anchor = jax.tree_util.tree_map(lambda x: 0.7 * x, params)
    out = ops.fedprox_update(params, grads, anchor, 0.1, 0.2)
    exp = jax.tree_util.tree_map(
        lambda x, g, a: ref.fedprox_update_ref(x, g, a, 0.1, 0.2),
        params, grads, anchor)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(exp)):
        assert a.shape == b.shape
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_ops_nova_matches_aggregation_module():
    """Kernel wrapper == repro.core.aggregation.aggregate on pytrees."""
    from repro.core.aggregation import aggregate
    params = {"w": jax.random.normal(KEY, (33, 9))}
    ds = [jax.tree_util.tree_map(lambda x: (i + 1) * 0.1 * x, params)
          for i in range(3)]
    out = ops.nova_aggregate(params, ds, [1.0, 2.0, 1.0], 0.02)
    exp = aggregate(params, ds, [1.0, 2.0, 1.0], theta=1.0, eta=0.02)
    np.testing.assert_allclose(out["w"], exp["w"], atol=1e-5)
