"""Typed orchestration API (core/api.py + core/engine.py): strategy
registry, RoundPlan validation, executor parity, history back-compat, and
the realize_offloading conservation guarantees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import (CEFLOptions, Engine, EngineOptions, MeshExecutor,
                        MLConstants, RoundPlan, SimExecutor,
                        available_strategies, get_strategy,
                        realize_offloading, register_strategy, run_cefl)
from repro.core import strategies as S
from repro.data import make_image_dataset, make_online_ues
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights
from repro.solver.greedy import fixed_aggregator
from repro.solver.variables import round_indicators

NET = make_network(NetworkConfig(num_ue=4, num_bs=2, num_dc=2))
(TRX, TRY), (TEX, TEY) = make_image_dataset(2000, (8, 8, 1))
CCFG = ClassifierConfig(input_shape=(8, 8, 1), hidden=(16,))
P0 = init_classifier_params(jax.random.PRNGKey(0), CCFG)
CONSTS = MLConstants(L=5.0, theta_i=np.ones(6) * 2, sigma_i=np.ones(6) * 3,
                     zeta1=2.0, zeta2=1.0)
OW = ObjectiveWeights()
D_BAR = np.full(4, 500.0)


def _eval(p):
    return classifier_accuracy(p, jnp.asarray(TEX[:200]),
                               jnp.asarray(TEY[:200]))


def _engine(strategy, executor=None, **opt_kw):
    opts = EngineOptions(rounds=opt_kw.pop("rounds", 3), eta=0.1,
                         solver_outer=2, **opt_kw)
    return Engine(NET, strategy, consts=CONSTS, ow=OW, opts=opts,
                  executor=executor)


def _run(engine, seed=0):
    ues = make_online_ues(TRX, TRY, num_ue=4, mean_arrivals=150,
                          std_arrivals=15, seed=seed)
    return engine.run(ues, init_params=P0, loss_fn=classifier_loss,
                      eval_fn=_eval)


def _fixed_plan(s=0):
    w = fixed_aggregator(NET, D_BAR, s)
    return RoundPlan.from_w(round_indicators(w))


# ------------------------------------------------------- registry -----

def test_registry_roundtrip():
    assert {"cefl", "greedy_data", "greedy_rate", "fixed", "fednova",
            "fedavg"} <= set(available_strategies())

    @register_strategy("_test_dummy")
    class Dummy:
        def decide(self, net, D_bar, ctx):
            return _fixed_plan(0)

    try:
        assert isinstance(get_strategy("_test_dummy"), Dummy)
        # duplicate registration is rejected
        with pytest.raises(ValueError, match="already registered"):
            register_strategy("_test_dummy")(Dummy)
    finally:
        from repro.core.api import _STRATEGY_REGISTRY
        _STRATEGY_REGISTRY.pop("_test_dummy")


def test_registry_unknown_name_and_args():
    with pytest.raises(KeyError, match="unknown strategy 'nope'"):
        get_strategy("nope")
    assert get_strategy("fixed:1").s_idx == 1
    with pytest.raises(ValueError, match="fixed:<s>"):
        get_strategy("fixed")
    # instances pass through untouched
    strat = get_strategy("cefl")
    assert get_strategy(strat) is strat


# ------------------------------------------------------ RoundPlan -----

def test_roundplan_roundtrip_and_validate():
    plan = _fixed_plan(1)
    plan.validate(NET)
    assert plan.aggregator == 1
    w = plan.to_w()
    assert RoundPlan.from_w(w).to_w().keys() == w.keys()
    with pytest.raises(KeyError, match="missing keys"):
        RoundPlan.from_w({"rho_nb": w["rho_nb"]})


def test_roundplan_validation_rejects_bad_simplex_and_indicators():
    plan = _fixed_plan(0)
    bad = plan.replace(rho_bs=jnp.asarray(plan.rho_bs) * 3.0)
    with pytest.raises(ValueError, match="rho_bs"):
        bad.validate(NET)
    bad = plan.replace(I_s=jnp.full_like(jnp.asarray(plan.I_s), 0.5))
    with pytest.raises(ValueError, match="I_s"):
        bad.validate(NET)
    bad = plan.replace(rho_nb=jnp.ones_like(jnp.asarray(plan.rho_nb)))
    with pytest.raises(ValueError, match="rho_nb"):
        bad.validate(NET)
    bad = plan.replace(m=jnp.zeros_like(jnp.asarray(plan.m)))
    with pytest.raises(ValueError, match="m must"):
        bad.validate(NET)


# -------------------------------------------------- engine + parity -----

def test_sim_vs_mesh_executor_parity():
    """Same seed, strategy fixed:0, full mini-batches -> both executors
    must produce the same trajectory (the mesh step is the same math with
    deterministic full batches)."""
    kw = dict(m_default=1.0, gamma_default=2, rounds=3)
    res_sim = _run(_engine("fixed:0", SimExecutor(), **kw))
    res_mesh = _run(_engine("fixed:0", MeshExecutor(), **kw))
    np.testing.assert_allclose(res_sim.series("acc"),
                               res_mesh.series("acc"), atol=0.02)
    for a, b in zip(jax.tree_util.tree_leaves(res_sim.params),
                    jax.tree_util.tree_leaves(res_mesh.params)):
        np.testing.assert_allclose(a, b, atol=1e-3)
    # identical decisions -> identical accounting
    np.testing.assert_allclose(res_sim.series("energy"),
                               res_mesh.series("energy"), rtol=1e-6)
    assert res_sim.series("aggregator") == res_mesh.series("aggregator")


def test_sim_batched_matches_sequential():
    """Vmapped homogeneous-(gamma, m) batching preserves the per-DPU
    trajectories of the sequential path."""
    res_b = _run(_engine("fixed:0", SimExecutor(batch_homogeneous=True)))
    res_s = _run(_engine("fixed:0", SimExecutor(batch_homogeneous=False)))
    for a, b in zip(jax.tree_util.tree_leaves(res_b.params),
                    jax.tree_util.tree_leaves(res_s.params)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_engine_reports_and_loss_series():
    res = _run(_engine("greedy_data"))
    assert len(res) == 3
    assert all(np.isfinite(r.loss) for r in res.reports)
    assert res.reports[0].loss > res.reports[-1].loss - 0.5  # training signal
    assert all(r.plan is not None for r in res.reports)
    assert res.final is res.reports[-1]


def test_to_history_backcompat_schema():
    res = _run(_engine("fixed:1"))
    h = res.to_history()
    legacy_keys = {"round", "acc", "loss", "energy", "delay", "aggregator",
                   "cum_energy", "cum_delay", "dc_points", "gamma_mean",
                   "m_mean"}
    assert set(h.keys()) == legacy_keys
    assert all(len(v) == len(res) for v in h.values())
    assert h["loss"] and np.isfinite(h["loss"]).all()   # satellite: filled
    assert h["aggregator"] == [1, 1, 1]
    assert isinstance(h["dc_points"][0], list)


def test_run_cefl_shim_warns_and_matches_engine():
    ues = make_online_ues(TRX, TRY, num_ue=4, mean_arrivals=150,
                          std_arrivals=15, seed=0)
    with pytest.warns(DeprecationWarning, match="run_cefl is deprecated"):
        h = run_cefl(NET, ues, init_params=P0, loss_fn=classifier_loss,
                     eval_fn=_eval, consts=CONSTS, ow=OW,
                     opts=CEFLOptions(rounds=2, strategy="fixed:0", eta=0.1,
                                      solver_outer=2))
    h2 = _run(_engine("fixed:0", rounds=2)).to_history()
    np.testing.assert_allclose(h["acc"], h2["acc"], atol=1e-6)
    np.testing.assert_allclose(h["loss"], h2["loss"], atol=1e-6)


def test_warm_start_threads_previous_plan(monkeypatch):
    seen = []
    orig = S.sca.solve

    def spy(net, D_bar, consts, ow, **kw):
        seen.append(kw.get("w0"))
        return orig(net, D_bar, consts, ow, **kw)

    monkeypatch.setattr(S.sca, "solve", spy)
    _run(_engine("cefl", rounds=2, reoptimize_every=1))
    assert len(seen) == 2
    assert seen[0] is None and seen[1] is not None
    assert set(seen[1]) == set(RoundPlan.from_w(seen[1]).to_w())


def test_callback_early_stop_and_decorator():
    eng = _engine("fixed:0", rounds=5)
    rounds_seen = []

    @eng.on_round_end
    def stop_after_two(report):
        rounds_seen.append(report.round)
        return report.round >= 1

    res = _run(eng)
    assert rounds_seen == [0, 1] and len(res) == 2


def test_mesh_executor_rejects_fedavg():
    with pytest.raises(NotImplementedError, match="FedAvg"):
        _run(_engine("fedavg", MeshExecutor(), rounds=1))


# --------------------------------------- offloading conservation -----

def _ue_batches(rng, sizes):
    return [{"x": rng.randn(D, 4).astype(np.float32),
             "y": rng.randint(0, 10, D)} for D in sizes]


def _total_points(ue_data, dc_data):
    return sum(len(d["y"]) for d in ue_data) + \
        sum(0 if d is None else len(d["y"]) for d in dc_data)


def test_realize_offloading_conserves_points_all_offload():
    """Every datapoint lands at exactly one DPU, even when rho_nb rows sum
    to 1 (all-offload) — the old path duplicated a point per UE."""
    rng = np.random.RandomState(0)
    N, B, S = NET.dims
    sizes = [97, 64, 31, 128]
    data = _ue_batches(rng, sizes)
    plan = _fixed_plan(0)
    w = plan.to_w()
    w["rho_nb"] = jnp.ones((N, B)) / B          # rows sum to exactly 1
    ue_data, dc_data = realize_offloading(rng, data, w, NET)
    assert _total_points(ue_data, dc_data) == sum(sizes)
    assert all(len(d["y"]) >= 1 for d in ue_data)   # every UE keeps a point


def test_realize_offloading_conserves_points_floored_rho_bs():
    """BS pools whose rho_bs shares all floor to zero still forward the
    whole pool to the largest-share DC."""
    rng = np.random.RandomState(1)
    N, B, S = NET.dims
    sizes = [3, 2, 2, 3]                        # tiny pools -> floors to 0
    data = _ue_batches(rng, sizes)
    w = _fixed_plan(0).to_w()
    w["rho_nb"] = jnp.full((N, B), 0.45)        # offload most points
    w["rho_bs"] = jnp.tile(jnp.asarray([[0.4, 0.6]]), (B, 1))
    ue_data, dc_data = realize_offloading(rng, data, w, NET)
    assert _total_points(ue_data, dc_data) == sum(sizes)
    # the remainder went to the larger-share DC, not silently to DC 0
    if dc_data[1] is not None and dc_data[0] is not None:
        assert len(dc_data[1]["y"]) >= len(dc_data[0]["y"])


def test_realize_offloading_random_plans_conserve():
    rng = np.random.RandomState(2)
    N, B, S = NET.dims
    for trial in range(5):
        sizes = rng.randint(1, 200, N)
        data = _ue_batches(rng, list(sizes))
        w = _fixed_plan(0).to_w()
        rho = rng.rand(N, B)
        w["rho_nb"] = jnp.asarray(rho / rho.sum(1, keepdims=True)
                                  * rng.rand(N, 1))
        rbs = rng.rand(B, S)
        w["rho_bs"] = jnp.asarray(rbs / rbs.sum(1, keepdims=True))
        ue_data, dc_data = realize_offloading(rng, data, w, NET)
        assert _total_points(ue_data, dc_data) == sizes.sum()
