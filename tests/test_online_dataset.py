"""Regression pins for the App. G online data dynamics (``core.drift``):
seed-determinism of the arrival streams and the ``drift_labels`` rotation.
"""
import numpy as np

from repro.core.drift import OnlineDataset


def _pool(n=400, num_classes=10, seed=0):
    rng = np.random.RandomState(seed)
    x = rng.randn(n, 4).astype(np.float32)
    y = np.arange(n) % num_classes
    return x, y


def _mk(seed=3, **kw):
    x, y = _pool()
    kw.setdefault("mean_arrivals", 120.0)
    kw.setdefault("std_arrivals", 12.0)
    return OnlineDataset(features=x, labels=y,
                         label_support=np.array([0, 2, 4, 6, 8]),
                         seed=seed, **kw)


def test_same_seed_identical_streams():
    a, b = _mk(seed=5, retention=0.3), _mk(seed=5, retention=0.3)
    for _ in range(5):
        da, db = a.step(), b.step()
        np.testing.assert_array_equal(np.asarray(da["x"]),
                                      np.asarray(db["x"]))
        np.testing.assert_array_equal(np.asarray(da["y"]),
                                      np.asarray(db["y"]))


def test_different_seeds_diverge():
    a, b = _mk(seed=5), _mk(seed=6)
    da, db = a.step(), b.step()
    assert (len(da["y"]) != len(db["y"])
            or not np.array_equal(np.asarray(da["x"]), np.asarray(db["x"])))


def test_static_support_without_drift():
    ds = _mk(seed=1, drift_labels=False)
    for _ in range(4):
        got = set(np.unique(np.asarray(ds.step()["y"])))
        assert got <= {0, 2, 4, 6, 8}


def test_drift_labels_rotates_support():
    """With drift on, the observed label support actually moves: round r
    shifts the support by r mod num_classes (App. G concept drift)."""
    ds = _mk(seed=1, drift_labels=True)
    base = {0, 2, 4, 6, 8}
    got0 = set(np.unique(np.asarray(ds.step()["y"])))
    assert got0 <= base                       # round 0: unshifted
    got1 = set(np.unique(np.asarray(ds.step()["y"])))
    assert got1 <= {(c + 1) % 10 for c in base}
    # the rotated support really changes what the UE observes: round 1
    # draws only odd labels, disjoint from the even round-0 support
    assert got1 and got1.isdisjoint(got0)
    got2 = set(np.unique(np.asarray(ds.step()["y"])))
    assert got2 <= {(c + 2) % 10 for c in base}


def test_retention_carries_points_forward():
    ds = _mk(seed=9, retention=1.0)
    n0 = len(ds.step()["y"])
    n1 = len(ds.step()["y"])
    assert n1 > n0  # full retention: round-1 data contains all of round-0
