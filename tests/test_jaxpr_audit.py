"""Trace-level program auditor (repro.analysis.jaxpr): each pass must
catch its planted violation (known-bad), stay silent on the clean twin
(known-good), and the audit over the repo's registered contracts must be
violation-free.  The exact-vs-psum distinguishability gate runs when 8
devices are available (the CI audit lane forces 8 virtual CPUs)."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.jaxpr import ContractSpec, Program
from repro.analysis.jaxpr.audit import audit_contract, run_audit

pytestmark = pytest.mark.filterwarnings("ignore::UserWarning")


def _spec(name, build, **kw):
    return ContractSpec(name=name, build=build, module=__name__, **kw)


def _codes(report):
    return {f.pass_id for f in report.violations}


# ------------------------------------------------- JXP001 collectives --

def _psum_program():
    # single-device psum: vmap with an axis name makes lax.psum traceable
    fn = jax.jit(jax.vmap(lambda x: jax.lax.psum(x, "i"), axis_name="i"))
    return Program(fn=fn, args=(jnp.ones((4, 8), jnp.float32),))


def test_collective_audit_flags_planted_psum():
    spec = _spec("planted_psum", _psum_program, collectives={"psum": 0})
    report = audit_contract(spec, pass_ids=["JXP001"])
    assert "JXP001" in _codes(report)


def test_collective_audit_accepts_declared_psum():
    spec = _spec("declared_psum", _psum_program,
                 collectives={"psum": 1})
    assert not audit_contract(spec, pass_ids=["JXP001"]).violations


def test_collective_audit_unmentioned_prims_expected_absent():
    # zero-surprise default: a psum with only all_gather declared fails
    spec = _spec("surprise_psum", _psum_program,
                 collectives={"all_gather": 0})
    assert "JXP001" in _codes(audit_contract(spec, pass_ids=["JXP001"]))


def test_collective_audit_at_least_syntax():
    spec = _spec("atleast", _psum_program, collectives={"psum": "1+"})
    assert not audit_contract(spec, pass_ids=["JXP001"]).violations


# ------------------------------------------------------ JXP002 dtypes --

def test_dtype_audit_flags_planted_f64_literal():
    def build():
        # strong float-list literal: becomes f64 under the x64 probe
        fn = jax.jit(lambda x: x * jnp.array([0.5, 2.0]))
        return Program(fn=fn, args=(jnp.ones((2,), jnp.float32),))

    spec = _spec("planted_f64", build)
    assert "JXP002" in _codes(audit_contract(spec, pass_ids=["JXP002"]))


def test_dtype_audit_accepts_weak_scalars():
    def build():
        # Python scalars and pinned-dtype constants stay narrow
        fn = jax.jit(lambda x: x * 0.5 + jnp.array([1.0], jnp.float32))
        return Program(fn=fn, args=(jnp.ones((2,), jnp.float32),))

    spec = _spec("weak_ok", build)
    assert not audit_contract(spec, pass_ids=["JXP002"]).violations


def test_dtype_audit_checks_declared_out_dtypes():
    def build():
        fn = jax.jit(lambda x: x.astype(jnp.float32))   # widens bf16
        return Program(fn=fn, args=(jnp.ones((4,), jnp.bfloat16),))

    spec = _spec("bf16_widened", build, out_dtypes=("bfloat16",),
                 forbid_f64=False)
    assert "JXP002" in _codes(audit_contract(spec, pass_ids=["JXP002"]))


# ------------------------------------------------------ JXP003 memory --

def test_memory_audit_flags_budget_blowout():
    def build():
        def fn(x):
            big = jnp.outer(x, x)               # (1024, 1024) f32 = 4 MiB
            return jnp.sum(big)
        return Program(fn=jax.jit(fn),
                       args=(jnp.ones((1024,), jnp.float32),))

    spec = _spec("blowout", build, memory_budget_bytes=1 << 16)
    assert "JXP003" in _codes(audit_contract(spec, pass_ids=["JXP003"]))


def test_memory_audit_accepts_within_budget():
    def build():
        return Program(fn=jax.jit(lambda x: jnp.sum(x * 2)),
                       args=(jnp.ones((1024,), jnp.float32),))

    spec = _spec("small", build, memory_budget_bytes=1 << 16)
    assert not audit_contract(spec, pass_ids=["JXP003"]).violations


# ---------------------------------------------------- JXP004 donation --

def test_donation_audit_flags_undonatable_buffer():
    def build():
        # no output matches the donated input's shape -> XLA cannot
        # alias it; the donation silently buys nothing
        fn = jax.jit(lambda p, g: jnp.sum(p + g), donate_argnums=(0,))
        return Program(fn=fn, args=(jnp.ones((8, 128), jnp.float32),
                                    jnp.ones((8, 128), jnp.float32)),
                       donate_argnums=(0,))

    spec = _spec("undonated", build)
    assert "JXP004" in _codes(audit_contract(spec, pass_ids=["JXP004"]))


def test_donation_audit_accepts_aliased_buffer():
    def build():
        fn = jax.jit(lambda p, g: p - 0.1 * g, donate_argnums=(0,))
        return Program(fn=fn, args=(jnp.ones((8, 128), jnp.float32),
                                    jnp.ones((8, 128), jnp.float32)),
                       donate_argnums=(0,))

    spec = _spec("donated", build)
    assert not audit_contract(spec, pass_ids=["JXP004"]).violations


# ------------------------------------------------------ JXP005 fusion --

def test_fusion_audit_flags_nested_jit_in_scan():
    @jax.jit
    def inner(x):
        return x * 2.0 + 1.0

    def build():
        def body(c, x):
            return c + inner(x), None           # pjit inside the scan

        fn = jax.jit(lambda xs: jax.lax.scan(body, jnp.zeros(()), xs)[0])
        return Program(fn=fn, args=(jnp.ones((16,), jnp.float32),))

    spec = _spec("nested_jit", build)
    assert "JXP005" in _codes(audit_contract(spec, pass_ids=["JXP005"]))


def test_fusion_audit_accepts_inline_body():
    def build():
        def body(c, x):
            return c + x * 2.0 + 1.0, None

        fn = jax.jit(lambda xs: jax.lax.scan(body, jnp.zeros(()), xs)[0])
        return Program(fn=fn, args=(jnp.ones((16,), jnp.float32),))

    spec = _spec("inline_body", build)
    assert not audit_contract(spec, pass_ids=["JXP005"]).violations


def test_fusion_audit_allowlist():
    @jax.jit
    def inner(x):
        return x * 2.0

    def build():
        def body(c, x):
            return c + inner(x), None

        fn = jax.jit(lambda xs: jax.lax.scan(body, jnp.zeros(()), xs)[0])
        return Program(fn=fn, args=(jnp.ones((16,), jnp.float32),))

    spec = _spec("allowed_inner", build, fusion_allow=("inner",))
    assert not audit_contract(spec, pass_ids=["JXP005"]).violations


# ----------------------------------------------------------- waivers --

def test_waiver_reports_but_does_not_fail():
    spec = _spec("waived_psum", _psum_program, collectives={"psum": 0},
                 waivers={"JXP001": "known: exercised by this test"})
    report = audit_contract(spec, pass_ids=["JXP001"])
    assert report.findings and all(f.waived for f in report.findings)
    assert not report.violations


# ------------------------------------------- the repo's own contracts --

def test_registered_contracts_audit_clean():
    """The standing gate: every registered hot-path contract traces and
    passes (sharded contracts skip below 8 devices, never fail)."""
    report = run_audit()
    traced = [c for c in report.contracts if not c.skipped]
    assert len(traced) >= 5, [c.name for c in report.contracts]
    for c in traced:
        assert len(c.passes_run) >= 3, (c.name, c.passes_run)
    assert report.ok, "\n".join(
        f.render() for f in report.violations)


def test_run_audit_unknown_contract_name_raises():
    with pytest.raises(ValueError, match="unknown contract"):
        run_audit(select=["no_such_contract"])


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI audit lane forces "
                           "XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
def test_collective_audit_distinguishes_exact_from_psum():
    """The PR-9 regression gate, now trace-enforced: the exact and psum
    reduction modes of the sharded eq.-11 aggregation have provably
    different collective schedules, and the audit can tell them apart."""
    from repro.analysis.jaxpr.contracts import discover
    registry = discover()
    exact, psum = (registry["nova_sharded_exact"],
                   registry["nova_sharded_psum"])
    # each passes under its own expectations...
    assert not audit_contract(exact, pass_ids=["JXP001"]).violations
    assert not audit_contract(psum, pass_ids=["JXP001"]).violations
    # ...and FAILS under the other's: the two jaxprs are distinguishable
    import dataclasses
    swapped_exact = dataclasses.replace(exact,
                                        collectives=psum.collectives)
    swapped_psum = dataclasses.replace(psum,
                                       collectives=exact.collectives)
    assert audit_contract(swapped_exact, pass_ids=["JXP001"]).violations
    assert audit_contract(swapped_psum, pass_ids=["JXP001"]).violations


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices")
def test_sharded_round_contracts_audit_clean():
    report = run_audit(select=["sharded_round_exact",
                               "sharded_round_psum",
                               "mesh_round_gspmd"])
    assert not any(c.skipped for c in report.contracts)
    assert report.ok, "\n".join(
        f.render() for f in report.violations)
