"""End-to-end behaviour tests: CE-FL orchestration improves the model while
keeping costs accounted; baselines run; estimation feeds the solver;
decode == forward consistency across families (the 'system works' tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.cefl_paper import ClassifierConfig
from repro.core import CEFLOptions, MLConstants, run_cefl
from repro.core.estimation import estimate_constants
from repro.data import make_image_dataset, make_online_ues
from repro.models import lm as L
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights

NET = make_network(NetworkConfig(num_ue=4, num_bs=2, num_dc=2))
(TRX, TRY), (TEX, TEY) = make_image_dataset(2500, (10, 10, 1))
CCFG = ClassifierConfig(input_shape=(10, 10, 1), hidden=(32,))
P0 = init_classifier_params(jax.random.PRNGKey(0), CCFG)
CONSTS = MLConstants(L=5.0, theta_i=np.ones(6) * 2, sigma_i=np.ones(6) * 3,
                     zeta1=2.0, zeta2=1.0)


def _eval(p):
    return classifier_accuracy(p, jnp.asarray(TEX[:300]),
                               jnp.asarray(TEY[:300]))


def _run(strategy, rounds=5):
    ues = make_online_ues(TRX, TRY, num_ue=4, mean_arrivals=200,
                          std_arrivals=20)
    opts = CEFLOptions(rounds=rounds, strategy=strategy, eta=0.1,
                       solver_outer=2, reoptimize_every=3)
    with pytest.warns(DeprecationWarning, match="run_cefl is deprecated"):
        return run_cefl(NET, ues, init_params=P0, loss_fn=classifier_loss,
                        eval_fn=_eval, consts=CONSTS, ow=ObjectiveWeights(),
                        opts=opts)


def test_cefl_learns_and_accounts_costs():
    h = _run("cefl")
    assert h["acc"][-1] > h["acc"][0]
    assert h["cum_energy"][-1] > 0 and h["cum_delay"][-1] > 0
    assert len(h["aggregator"]) == 5


@pytest.mark.parametrize("strategy", ["fednova", "fedavg", "greedy_data",
                                      "greedy_rate", "fixed:0"])
def test_baselines_run_and_learn(strategy):
    h = _run(strategy, rounds=3)
    assert np.isfinite(h["acc"]).all()
    assert h["acc"][-1] >= h["acc"][0] - 0.05


def test_cefl_offloading_uses_dcs():
    h = _run("cefl", rounds=3)
    assert max(sum(p) for p in h["dc_points"]) > 0   # data reached the DCs


def test_estimation_feeds_solver():
    ues = make_online_ues(TRX, TRY, num_ue=4, mean_arrivals=150,
                          std_arrivals=10)
    datasets = [ds.step() for ds in ues]
    c = estimate_constants(classifier_loss, P0, datasets,
                           key=jax.random.PRNGKey(1), iters=2)
    assert c.L > 0 and c.zeta1 >= 1.0 and c.zeta2 >= 0.0
    assert (c.theta_i > 0).all() and (c.sigma_i > 0).all()


@pytest.mark.parametrize("arch", ["qwen3-32b", "mamba2-130m",
                                  "llama4-maverick-400b-a17b"])
def test_decode_matches_forward(arch):
    """prefill + decode steps reproduce teacher-forced logits."""
    cfg = reduced(get_config(arch))
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    p = L.init_lm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S, S0 = 2, 24, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    x = L.embed_tokens(p, cfg, tokens)
    xb, _ = L.lm_backbone(p, cfg, x, remat=False, q_block=8, kv_block=8)
    full = L.unembed(p, cfg, xb)
    lg, cache = L.prefill(p, cfg, tokens[:, :S0], cache_len=S,
                          q_block=8, kv_block=8)
    np.testing.assert_allclose(lg, full[:, S0 - 1], atol=3e-4)
    step = jax.jit(lambda tok, c: L.lm_decode_step(p, cfg, tok, c))
    for t in range(S0, S):
        lg, cache = step(tokens[:, t], cache)
    np.testing.assert_allclose(lg, full[:, -1], atol=3e-4)


def test_train_launcher_decreases_loss():
    from repro.launch.train import main as train_main
    losses = train_main(["--arch", "mamba2-130m", "--reduced", "--steps",
                         "5", "--batch", "4", "--seq", "64", "--n-dpu", "2",
                         "--gamma", "2"])
    assert losses[-1] < losses[0]


def test_checkpoint_roundtrip(tmp_path):
    from repro.training import load_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,))}}
    save_checkpoint(tmp_path / "ck", tree, step=7, metadata={"tag": "t"})
    back, step, meta = load_checkpoint(tmp_path / "ck", tree)
    assert step == 7
    assert meta == {"tag": "t"}
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
