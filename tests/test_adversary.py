"""Adversarial client models (scenario.adversary), the robust-aggregation
counter (kernels + core.aggregation + EngineOptions.robust_agg), their
engine threading (corruption between training and aggregation, straggler
cost accounting), and the ISSUE-8 robustness acceptance gate: under a 20%
sign-flip byzantine population, trimmed-mean CE-FL retains >= 80% of its
clean final accuracy while plain FedAvg demonstrably degrades."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import Engine, EngineOptions, MLConstants, aggregation
from repro.data import make_image_dataset, make_online_ues
from repro.kernels import ops
from repro.kernels.plane import as_plane
from repro.kernels.ref import robust_aggregate_ref, robust_reduce_ref
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.scenario import (ByzantineUpdate, Dropout, DynamicScenario,
                            LabelPoison, Straggler)
from repro.scenario.adversary import resolve_ues
from repro.solver import ObjectiveWeights

from _hypothesis_compat import given, settings, st

NET = make_network(NetworkConfig(num_ue=6, num_bs=3, num_dc=2))
(TRX, TRY), (TEX, TEY) = make_image_dataset(2500, (8, 8, 1))
CCFG = ClassifierConfig(input_shape=(8, 8, 1), hidden=(16,))
P0 = init_classifier_params(jax.random.PRNGKey(0), CCFG)
CONSTS = MLConstants(L=5.0, theta_i=np.ones(8) * 2, sigma_i=np.ones(8) * 3,
                     zeta1=2.0, zeta2=1.0)
OW = ObjectiveWeights()


def _eval_fn(p):
    return classifier_accuracy(p, np.asarray(TEX[:400]), np.asarray(TEY[:400]))


def _run(strategy, scenario, *, robust="none", trim_frac=0.2, rounds=4,
         seed=0, arrivals=120):
    ues = make_online_ues(TRX, TRY, num_ue=6, mean_arrivals=arrivals,
                          std_arrivals=arrivals / 10, seed=seed)
    eng = Engine(NET, strategy, consts=CONSTS, ow=OW, scenario=scenario,
                 opts=EngineOptions(rounds=rounds, eta=0.1, solver_outer=2,
                                    seed=seed, robust_agg=robust,
                                    trim_frac=trim_frac))
    return eng.run(ues, init_params=P0, loss_fn=classifier_loss,
                   eval_fn=_eval_fn)


# ------------------------------------------------------- unit: models --

def test_resolve_ues_frac_is_deterministic_and_spread():
    assert resolve_ues(10, 0.2, None) == resolve_ues(10, 0.2, None)
    assert len(resolve_ues(10, 0.2, None)) == 2
    assert resolve_ues(10, 0.0, None) == ()
    assert resolve_ues(6, 0.2, None) == (0,)        # round(1.2) == 1
    got = resolve_ues(10, 1.0, None)
    assert got == tuple(range(10))                   # frac=1 -> everyone
    # explicit set wins, is clamped to range, deduped and sorted
    assert resolve_ues(5, 0.9, (4, 1, 1, 7, -2)) == (1, 4)


def test_byzantine_update_events_and_start_gating():
    adv = ByzantineUpdate(mode="gauss", frac=0.5, scale=2.5, start=3)
    adv.reset(4)
    assert adv.corrupted(2) == ()                    # not started yet
    got = adv.corrupted(3)
    assert got == ((0, "gauss", 2.5), (3, "gauss", 2.5))
    data = {"x": np.zeros((3, 1)), "y": np.arange(3)}
    rng = np.random.RandomState(0)
    assert adv.apply(5, 0, data, rng) is data        # data untouched
    with pytest.raises(ValueError, match="unknown corruption mode"):
        ByzantineUpdate(mode="zero_out")


def test_label_poison_flips_only_compromised_ues():
    adv = LabelPoison(frac=0.5, num_classes=10, ues=(1,), start=1)
    adv.reset(4)
    data = {"x": np.zeros((4, 1)), "y": np.array([0, 3, 5, 9])}
    rng = np.random.RandomState(0)
    assert adv.apply(0, 1, data, rng) is data        # before start
    np.testing.assert_array_equal(adv.apply(1, 1, data, rng)["y"],
                                  np.array([9, 6, 4, 0]))
    assert adv.apply(1, 0, data, rng) is data        # honest UE untouched
    empty = {"x": np.zeros((0, 1)), "y": np.zeros(0, int)}
    assert adv.apply(1, 1, empty, rng) is empty      # no-data round


def test_straggler_compute_scale_shape_and_values():
    adv = Straggler(frac=0.5, slowdown=4.0)
    adv.reset(4)
    assert adv.compute_scale(0, 4) == (0.25, 1.0, 1.0, 0.25)
    with pytest.raises(ValueError, match="slowdown"):
        Straggler(slowdown=0.0)


def test_dropout_respects_min_active_floor():
    adv = Dropout(p=1.0, min_active=2)
    adv.reset(5)
    rng = np.random.RandomState(0)
    adv.begin_round(0, 5, rng)
    data = {"x": np.zeros((3, 1)), "y": np.arange(3)}
    alive = [len(adv.apply(0, u, data, rng)["y"]) > 0 for u in range(5)]
    assert sum(alive) == 2 and alive[:2] == [True, True]  # lowest indices
    _, left = adv.events()
    assert len(left) == 3


# --------------------------------------------------- robust reduction --

@given(st.integers(min_value=3, max_value=9),
       st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=20, deadline=None)
def test_robust_reduce_ref_matches_numpy_sort_oracle(n, seed):
    rng = np.random.RandomState(seed)
    stack = rng.randn(n, 4, 8).astype(np.float32) * 3
    k = (n - 1) // 2 if n > 2 else 0
    srt = np.sort(stack, axis=0)
    np.testing.assert_allclose(
        np.asarray(robust_reduce_ref(jnp.asarray(stack), k=k)),
        srt[k:n - k].mean(axis=0), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(robust_reduce_ref(jnp.asarray(stack), median=True)),
        np.median(stack, axis=0), rtol=1e-5, atol=1e-6)


def test_robust_reduce_rejects_overtrim():
    with pytest.raises(ValueError, match="2k < n"):
        robust_reduce_ref(jnp.zeros((4, 2, 2)), k=2)


def test_trim_count_properties():
    assert ops.trim_count(10, 0.1) == 1
    assert ops.trim_count(10, 0.0) == 0
    assert ops.trim_count(3, 0.49) == 1
    for n in range(1, 12):
        for f in (0.0, 0.1, 0.2, 0.3, 0.49):
            k = ops.trim_count(n, f)
            assert 0 <= 2 * k < n                    # survivor guarantee
    with pytest.raises(ValueError, match="trim_frac"):
        ops.trim_count(10, 0.5)


def test_trimmed_mean_ignores_a_planted_outlier():
    rng = np.random.RandomState(0)
    honest = rng.randn(5, 2, 16).astype(np.float32)
    evil = np.concatenate([honest, np.full((1, 2, 16), 1e4, np.float32)])
    out = np.asarray(robust_reduce_ref(jnp.asarray(evil), k=1))
    assert np.abs(out).max() < 10                    # outlier trimmed away
    # the plain mean is swamped
    assert np.abs(evil.mean(axis=0)).max() > 1e3


def test_robust_aggregate_plane_cpu_matches_interpret():
    rng = np.random.RandomState(1)
    x = rng.randn(8, 1024).astype(np.float32)
    stack = rng.randn(6, 8, 1024).astype(np.float32)
    for mode in ops.ROBUST_MODES:
        cpu = ops.robust_aggregate_plane(x, stack, 0.2, mode=mode,
                                         trim_frac=0.2, backend="cpu")
        itp = ops.robust_aggregate_plane(x, stack, 0.2, mode=mode,
                                         trim_frac=0.2, backend="interpret")
        np.testing.assert_allclose(np.asarray(cpu), np.asarray(itp),
                                   rtol=1e-5, atol=1e-5)
        ref = robust_aggregate_ref(
            jnp.asarray(x), jnp.asarray(stack), 0.2,
            k=0 if mode == "median" else ops.trim_count(6, 0.2),
            median=(mode == "median"))
        np.testing.assert_allclose(np.asarray(cpu), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_robust_aggregate_tree_and_plane_paths_agree():
    rng = np.random.RandomState(2)
    tree = {"w": rng.randn(8, 4).astype(np.float32),
            "b": rng.randn(4).astype(np.float32)}
    ds = [{"w": rng.randn(8, 4).astype(np.float32),
           "b": rng.randn(4).astype(np.float32)} for _ in range(5)]
    out_tree = aggregation.robust_aggregate(
        tree, ds, theta=2.0, eta=0.1, mode="trimmed_mean", trim_frac=0.2)
    plane = as_plane(tree)
    out_plane = aggregation.robust_aggregate(
        plane, [as_plane(d) for d in ds], theta=2.0, eta=0.1,
        mode="trimmed_mean", trim_frac=0.2).to_tree()
    for k in tree:
        np.testing.assert_allclose(np.asarray(out_tree[k]),
                                   np.asarray(out_plane[k]),
                                   rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="unknown robust mode"):
        aggregation.robust_aggregate(tree, ds, theta=2.0, eta=0.1,
                                     mode="krum")


def test_robust_fedavg_reduces_to_plain_mean_without_trim():
    rng = np.random.RandomState(3)
    ps = [as_plane({"w": rng.randn(4, 4).astype(np.float32)})
          for _ in range(3)]
    out = aggregation.robust_fedavg_aggregate(ps, mode="trimmed_mean",
                                              trim_frac=0.0)
    mean = np.mean([np.asarray(p.data) for p in ps], axis=0)
    np.testing.assert_allclose(np.asarray(out.data), mean,
                               rtol=1e-5, atol=1e-6)


# ----------------------------------------------------- engine threading --

def test_byzantine_scenario_changes_the_run():
    """byzantine:0 is the rng-identical clean twin; any nonzero fraction
    must actually alter the training trajectory."""
    clean = _run("cefl", "byzantine:0.0")
    byz = _run("cefl", "byzantine")
    assert clean.series("loss") != byz.series("loss")
    # and the corrupted events surface in staged rounds, not reports: the
    # clean twin's accuracy series must also differ
    assert clean.series("acc") != byz.series("acc")


def test_gauss_corruption_is_seed_deterministic():
    def mk():
        return DynamicScenario(
            mobility=None,
            schedules=(ByzantineUpdate(mode="gauss", frac=0.34, scale=1.0),))
    a = _run("greedy_data", mk(), rounds=3)
    b = _run("greedy_data", mk(), rounds=3)
    assert a.series("loss") == b.series("loss")
    assert a.series("acc") == b.series("acc")


def test_straggler_slowdown_raises_round_delay():
    """f_n scaling rides through network_costs: the straggler run's
    cumulative delay must exceed the identically-seeded clean run's."""
    def mk(slowdown):
        return DynamicScenario(
            mobility=None,
            schedules=(Straggler(frac=0.5, slowdown=slowdown),))
    slow = _run("greedy_data", mk(8.0), rounds=3)
    clean = _run("greedy_data", mk(1.0), rounds=3)
    assert clean.series("loss") == slow.series("loss")   # learning equal
    assert slow.reports[-1].cum_delay > 1.5 * clean.reports[-1].cum_delay


def test_robust_agg_flag_threads_through_spec():
    from repro.experiments import get_experiment
    spec = get_experiment("quickstart").override(**{
        "engine.robust_agg": "median", "engine.trim_frac": 0.25})
    opts = spec.engine_options(0)
    assert opts.robust_agg == "median" and opts.trim_frac == 0.25
    from repro.experiments.spec import from_json, to_json
    assert from_json(to_json(spec)) == spec


# ------------------------------------------------- acceptance (ISSUE 8) --

def test_robust_cefl_survives_byzantine_population():
    """THE robustness gate: 20% sign-flip byzantines (byzantine preset,
    scale 4).  Trimmed-mean CE-FL keeps >= 80% of the clean twin's final
    accuracy; plain FedAvg and unprotected CE-FL demonstrably degrade.
    byzantine:0.0 consumes identical rng, so the comparison is exact."""
    rounds, arrivals = 8, 150
    clean_cefl = _run("cefl", "byzantine:0.0", rounds=rounds,
                      arrivals=arrivals).reports[-1].acc
    robust_byz = _run("cefl", "byzantine", robust="trimmed_mean",
                      trim_frac=0.2, rounds=rounds,
                      arrivals=arrivals).reports[-1].acc
    naked_byz = _run("cefl", "byzantine", rounds=rounds,
                     arrivals=arrivals).reports[-1].acc
    clean_avg = _run("fedavg", "byzantine:0.0", rounds=rounds,
                     arrivals=arrivals).reports[-1].acc
    byz_avg = _run("fedavg", "byzantine", rounds=rounds,
                   arrivals=arrivals).reports[-1].acc
    # the counter works: >= 80% of clean accuracy retained
    assert robust_byz >= 0.8 * clean_cefl, (robust_byz, clean_cefl)
    # the attack works: unprotected runs visibly degrade
    assert byz_avg < clean_avg - 0.1, (byz_avg, clean_avg)
    assert naked_byz < robust_byz - 0.05, (naked_byz, robust_byz)
