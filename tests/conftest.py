"""Shared fixtures.  NOTE: no XLA device-count flags here — smoke tests and
benches run on the single real device; only launch/dryrun.py (run as its own
process) forces 512 host devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def naive_attention(q, k, v, causal=True, window=None):
    """O(S^2) reference attention with GQA."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                   k.astype(jnp.float32)) / np.sqrt(D)
    pos = np.arange(S)
    kpos = np.arange(k.shape[1])
    mask = np.ones((S, k.shape[1]), bool)
    if causal:
        mask &= pos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= pos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D)
