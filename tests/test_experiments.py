"""Declarative experiment API (repro/experiments/): spec JSON round
trips, preset registry, vmapped-vs-sequential sweep parity (bit-exact),
kill-and-resume determinism under campus_walk, checkpoint validation,
trace record round trips, and the single-source-of-seeds contract."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import experiments as E
from repro.core.api import EngineOptions
from repro.experiments.trace import report_from_record, report_to_record


def _smoke(**over):
    spec = E.get_experiment("sweep_smoke")
    return spec.override(**over) if over else spec


def _flat(params):
    return np.concatenate([np.ravel(np.asarray(x))
                           for x in jax.tree_util.tree_leaves(params)])


def _assert_runs_identical(a, b):
    assert a.series("loss") == b.series("loss")
    assert a.series("acc") == b.series("acc")
    assert a.series("aggregator") == b.series("aggregator")
    assert [r.handovers for r in a.reports] == \
        [r.handovers for r in b.reports]
    assert [r.dc_points for r in a.reports] == \
        [r.dc_points for r in b.reports]
    for ra, rb in zip(a.reports, b.reports):
        for k, va in ra.plan.to_w().items():
            assert np.array_equal(np.asarray(va),
                                  np.asarray(rb.plan.to_w()[k])), \
                (ra.round, k)
    assert np.array_equal(_flat(a.params), _flat(b.params))


# ------------------------------------------------------------- spec -----

def test_spec_json_roundtrip_all_presets():
    for name in E.available_experiments():
        spec = E.get_experiment(name)
        back = E.from_json(E.to_json(spec))
        assert back == spec, name
        assert isinstance(back.seeds, tuple)
        assert isinstance(back.model.input_shape, tuple)


def test_spec_override_paths_and_coercion():
    spec = _smoke()
    out = spec.override(**{"engine.rounds": 5, "strategy": "fixed:0",
                           "seeds": "0,3", "network.num_ue": "7",
                           "data.drift_labels": "true"})
    assert out.engine.rounds == 5
    assert out.strategy == "fixed:0"
    assert out.seeds == (0, 3)
    assert out.network.num_ue == 7
    assert out.data.drift_labels is True
    assert spec.engine.rounds == 3          # original untouched
    with pytest.raises(KeyError, match="no field"):
        spec.override(**{"engine.nope": 1})


def test_registry_and_engine_options_seed_contract():
    assert {"quickstart", "paper_table1", "campus_walk_vs_fixed",
            "sweep_smoke"} <= set(E.available_experiments())
    with pytest.raises(KeyError, match="unknown experiment"):
        E.get_experiment("nope")
    spec = _smoke()
    opts = spec.engine_options(17)
    assert isinstance(opts, EngineOptions)
    # ONE seed feeds engine + scenario + (via make_ues) the data streams
    assert opts.seed == 17
    assert opts.strategy == spec.strategy
    assert opts.scenario == spec.scenario
    ctx = E.build_context(spec)
    ues_a = ctx.make_ues(17)
    ues_b = ctx.make_ues(17)
    da, db = ues_a[0].step(), ues_b[0].step()
    assert np.array_equal(np.asarray(da["y"]), np.asarray(db["y"]))
    assert len(ues_a) == spec.network.num_ue


def test_context_cache_shared_across_strategy_grid():
    base = _smoke()
    a = E.build_context(base)
    b = E.build_context(base.override(**{"name": "other",
                                         "strategy": "fixed:0"}))
    assert a.net is b.net                  # one build for the whole grid
    assert b.spec.strategy == "fixed:0"    # but the real spec rides along


# ------------------------------------------------------------ sweep -----

def test_spec_roundtrip_runs_identically():
    spec = _smoke(**{"engine.rounds": 2, "scenario": "static"})
    r1 = E.run(spec, seed=0)
    r2 = E.run(E.from_json(E.to_json(spec)), seed=0)
    _assert_runs_identical(r1, r2)


def test_vmap_vs_sequential_sweep_parity():
    """The acceptance bit-exactness: same per-seed losses/accs/plans from
    the vmapped executor and the sequential fallback."""
    spec = _smoke()
    seq = E.sweep(spec, executor="sequential")
    vm = E.sweep(spec, executor="vmap")
    assert seq.seeds == vm.seeds == list(spec.run_seeds)
    for seed in spec.run_seeds:
        _assert_runs_identical(seq.result(seed), vm.result(seed))
    st = vm.stats()["sweep_smoke"]
    assert st["runs"] == len(spec.run_seeds)
    assert 0.0 <= st["final_acc_mean"] <= 1.0


def test_vmap_sweep_no_retrace_across_seeds(assert_no_retrace):
    """The K-seed vmapped sweep compiles once per (gamma, m, bucket)
    group, not per seed: the jitted scans and the K-stacked eval are
    shape-keyed on the group, so replaying the identical spec through
    the same (warmed) executor performs ZERO XLA backend compiles."""
    from repro.experiments.sweep import VmapSweepExecutor
    spec = _smoke()
    ex = VmapSweepExecutor()
    warm = E.sweep(spec, executor=ex)
    with assert_no_retrace():
        vm = E.sweep(spec, executor=ex)
    assert vm.seeds == warm.seeds == list(spec.run_seeds)
    for seed in spec.run_seeds:
        _assert_runs_identical(warm.result(seed), vm.result(seed))


def test_sweep_spec_grid_unique_names_and_merge():
    base = _smoke(**{"engine.rounds": 2, "scenario": "static",
                     "seeds": (0,)})
    grid = [base.override(**{"name": "a"}),
            base.override(**{"name": "b", "strategy": "fixed:0"})]
    res = E.sweep(grid, executor="sequential")
    assert len(res) == 2
    assert set(res.stats()) == {"a", "b"}
    with pytest.raises(ValueError, match="unique names"):
        E.sweep([base, base])


def test_trace_sink_jsonl(tmp_path):
    spec = _smoke(**{"engine.rounds": 2, "scenario": "static",
                     "seeds": (0,)})
    path = tmp_path / "trace.jsonl"
    with E.TraceSink(path) as sink:
        E.sweep(spec, executor="vmap", trace=sink)
    records = E.read_trace(path)
    assert len(records) == 2
    assert all(r["kind"] == "round" and r["experiment"] == spec.name
               and r["executor"] == "vmap" for r in records)
    assert [r["round"] for r in records] == [0, 1]


def test_report_record_roundtrip():
    res = E.run(_smoke(**{"engine.rounds": 1, "scenario": "static"}),
                seed=0)
    rep = res.reports[0]
    back = report_from_record(report_to_record(rep))
    assert back.loss == rep.loss and back.acc == rep.acc
    assert back.handovers == rep.handovers
    assert back.dc_points == rep.dc_points
    for k, v in rep.plan.to_w().items():
        assert np.array_equal(np.asarray(v),
                              np.asarray(back.plan.to_w()[k])), k


# -------------------------------------------------- resume / ckpt -------

def test_kill_and_resume_matches_uninterrupted(tmp_path):
    """The acceptance determinism guarantee: a sweep killed after round 2
    (full-state snapshot) and resumed reproduces the uninterrupted run's
    loss/plan/handover traces and final params exactly — under the
    dynamic campus_walk scenario (mobility state, stream PRNGs, warm
    starts all round-trip the checkpoint)."""
    spec = _smoke(**{"engine.rounds": 4})
    assert spec.scenario == "campus_walk"
    full = E.sweep(spec, executor="vmap")
    ck = tmp_path / "ck"
    part = E.sweep(spec, executor="vmap", checkpoint_dir=ck, stop_after=2)
    for seed in spec.run_seeds:
        assert len(part.result(seed)) == 2
    res = E.sweep(spec, executor="vmap", checkpoint_dir=ck, resume=True)
    for seed in spec.run_seeds:
        _assert_runs_identical(full.result(seed), res.result(seed))


def test_resume_refuses_spec_mismatch(tmp_path):
    spec = _smoke(**{"engine.rounds": 3})
    ck = tmp_path / "ck"
    E.sweep(spec, executor="sequential", checkpoint_dir=ck, stop_after=1)
    other = spec.override(**{"engine.eta": 0.2})
    with pytest.raises(ValueError, match="different spec"):
        E.sweep(other, executor="sequential", checkpoint_dir=ck,
                resume=True)


def test_checkpoint_validates_structure(tmp_path):
    from repro.training.checkpoint import load_checkpoint, save_checkpoint
    tree = {"a": np.arange(6.0).reshape(2, 3), "b": {"c": np.ones(4)}}
    save_checkpoint(tmp_path / "ck", tree, step=3, metadata={"k": "v"})
    back, step, meta = load_checkpoint(tmp_path / "ck", tree)
    assert step == 3 and meta == {"k": "v"}
    # treedef mismatch (extra leaf) -> clear error, nothing misassigned
    with pytest.raises(ValueError, match="leaf count"):
        load_checkpoint(tmp_path / "ck",
                        {"a": tree["a"], "b": {"c": tree["b"]["c"],
                                               "d": np.ones(1)}})
    # same leaf count, different structure -> treedef error
    with pytest.raises(ValueError, match="treedef"):
        load_checkpoint(tmp_path / "ck",
                        {"x": tree["a"], "y": np.ones(4)})
    # shape mismatch -> error unless strict_shapes=False
    bad = {"a": np.zeros((3, 2)), "b": {"c": np.ones(4)}}
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(tmp_path / "ck", bad)
    back, _, _ = load_checkpoint(tmp_path / "ck", bad,
                                 strict_shapes=False)
    assert np.asarray(back["a"]).shape == (2, 3)   # saved shapes win
    # float64 leaves survive exactly (no jnp truncation on restore)
    assert np.asarray(back["a"]).dtype == np.float64


# ------------------------------------------------------ eval cadence ----

def test_eval_cadence_carries_acc_forward():
    spec = _smoke(**{"engine.rounds": 4, "scenario": "static",
                     "seeds": (0,), "engine.eval_every": 3})
    res = E.run(spec, seed=0)
    accs = res.series("acc")
    # evals at t=0 and t=3 (cadence + final round); t=1,2 carry t=0
    assert accs[1] == accs[0] and accs[2] == accs[0]
    assert len(accs) == 4


# -------------------------------------------------------------- lm ------

def test_lm_spec_dispatch_smoke():
    spec = E.get_experiment("lm_smoke").override(
        **{"engine.rounds": 4, "model.batch": 4, "model.seq": 64})
    res = E.run(spec)
    assert len(res.reports) == 4
    assert res.reports[-1].loss < res.reports[0].loss


# -------------------------------------------------------------- cli -----

def test_cli_show_and_validate(capsys):
    from repro.experiments.__main__ import main
    assert main(["list"]) == 0
    assert main(["show", "sweep_smoke", "--set", "engine.rounds=5"]) == 0
    out = capsys.readouterr().out
    assert '"rounds": 5' in out
    assert main(["validate", "sweep_smoke"]) == 0


def test_run_state_pack_unpack_roundtrip():
    from repro.experiments.runstate import _pack, _unpack
    state = {"a": np.arange(5), "nested": {"b": 1.5, "c": "s",
                                           "d": None, "e": True,
                                           "arr": np.eye(2)},
             "lst": [np.zeros(3), 7]}
    leaves = []
    skel = _pack(state, leaves)
    assert len(leaves) == 3
    back = _unpack(skel, leaves)
    assert np.array_equal(back["a"], state["a"])
    assert back["nested"]["b"] == 1.5 and back["nested"]["d"] is None
    assert back["nested"]["e"] is True
    assert np.array_equal(back["lst"][0], state["lst"][0])
    assert dataclasses.is_dataclass(E.get_experiment("quickstart"))
