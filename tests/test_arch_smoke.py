"""Per-assigned-architecture smoke tests (assignment requirement):
REDUCED variant (2 layers, d_model <= 512, <= 4 experts) — one forward +
one CE-FL train step on CPU, asserting output shapes and no NaNs; plus a
one-token decode step for decoder archs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core.round_step import CEFLHyper, build_cefl_round_step, \
    make_dpu_meta
from repro.models import lm as L

KEY = jax.random.PRNGKey(0)


def _batchify(cfg, B=2, S=32):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["enc_embed"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = L.init_lm_params(KEY, cfg, jnp.float32)
    B, S = 2, 32
    batch = _batchify(cfg, B, S)

    # forward: backbone output shape + finite loss
    x = L.embed_tokens(params, cfg, batch["tokens"])
    assert x.shape == (B, S, cfg.d_model)
    loss, aux = L.lm_loss(params, cfg, batch, remat=False,
                          q_block=16, kv_block=16)
    assert loss.shape == () and bool(jnp.isfinite(loss)), arch

    # one CE-FL train step (2 DPUs, heterogeneous gamma)
    def loss_fn(p, micro, mask):
        return L.lm_loss(p, cfg, micro, example_mask=mask, remat=True,
                         q_block=16, kv_block=16)

    step = build_cefl_round_step(loss_fn, CEFLHyper(
        eta=1e-2, mu=0.01, theta=1.0, gamma_max=2, n_micro=1))
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (2,) + l.shape), params)
    bb = jax.tree_util.tree_map(
        lambda v: jnp.stack([v, v])[:, None], batch)
    meta = make_dpu_meta(2, gammas=[2, 1], m_fracs=[1.0, 0.5])
    new_params, metrics = jax.jit(step)(stacked, bb, meta)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    for leaf, old in zip(jax.tree_util.tree_leaves(new_params),
                         jax.tree_util.tree_leaves(stacked)):
        assert leaf.shape == old.shape
        assert bool(jnp.all(jnp.isfinite(leaf))), arch
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(new_params),
        jax.tree_util.tree_leaves(stacked)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ["mamba2-130m", "qwen3-32b",
                                  "jamba-v0.1-52b", "whisper-medium",
                                  "starcoder2-15b"])
def test_reduced_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = L.init_lm_params(KEY, cfg, jnp.float32)
    B, cache_len = 2, 64
    cache = L.init_cache(cfg, B, cache_len, jnp.float32)
    tokens = jax.random.randint(KEY, (B,), 0, cfg.vocab_size)
    logits, new_cache = L.lm_decode_step(params, cfg, tokens, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(new_cache["pos"]) == 1
