"""Scenario subsystem: registry, mobility models, network evolution
invariants (handover / mesh churn / consensus graph), drift schedules, the
engine threading (RoundReport dynamics fields), end-to-end seed
determinism, and the no-retrace guarantee for per-round re-solves."""
import jax
import numpy as np
import pytest

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import Engine, EngineOptions, MLConstants
from repro.data import make_image_dataset, make_online_ues
from repro.models.classifier import (classifier_accuracy, classifier_loss,
                                     init_classifier_params)
from repro.network import NetworkConfig, make_network
from repro.scenario import (ArrivalBurst, DynamicScenario, GaussMarkov,
                            JoinLeave, LabelRotation, RandomWaypoint,
                            available_scenarios, get_scenario,
                            layout_from_network)
from repro.solver import ObjectiveWeights

from _hypothesis_compat import given, settings, st

NET = make_network(NetworkConfig(num_ue=6, num_bs=3, num_dc=2))
(TRX, TRY), (TEX, TEY) = make_image_dataset(2500, (8, 8, 1))
CCFG = ClassifierConfig(input_shape=(8, 8, 1), hidden=(16,))
P0 = init_classifier_params(jax.random.PRNGKey(0), CCFG)
CONSTS = MLConstants(L=5.0, theta_i=np.ones(8) * 2, sigma_i=np.ones(8) * 3,
                     zeta1=2.0, zeta2=1.0)
OW = ObjectiveWeights()


class _Opts:
    rate_jitter = 0.15


def _ues(seed=0, n=6, arrivals=120):
    return make_online_ues(TRX, TRY, num_ue=n, mean_arrivals=arrivals,
                           std_arrivals=arrivals / 10, seed=seed)


def _steps(scen, rounds, seed=0, net=NET):
    scen.bind(net, _Opts())
    rng = np.random.RandomState(seed)
    ues = _ues(seed)
    return [scen.step(t, ues, rng) for t in range(rounds)]


# ------------------------------------------------------------ registry --

def test_registry_has_presets_and_args():
    assert {"static", "campus_walk", "vehicular", "flash_crowd",
            "label_shift", "churn"} <= set(available_scenarios())
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")
    scen = get_scenario("label_shift:3")
    assert scen.schedules[0].period == 3
    inst = get_scenario("campus_walk")
    assert get_scenario(inst) is inst   # instances pass through


def test_static_scenario_matches_legacy_resample():
    scen = get_scenario("static")
    scen.bind(NET, _Opts())
    rng = np.random.RandomState(7)
    net_t, data, ev = scen.step(0, _ues(), rng)
    ref = NET.resample_rates(np.random.RandomState(7), 0.15)
    np.testing.assert_allclose(net_t.R_nb, ref.R_nb)
    assert ev.handovers == () and len(data) == 6


# ------------------------------------------------------------ mobility --

def test_layout_respects_subnet_structure():
    rng = np.random.RandomState(0)
    lay = layout_from_network(NET, rng, area=1000.0)
    N, B, S = NET.dims
    assert lay.ue_pos.shape == (N, 2) and lay.bs_pos.shape == (B, 2)
    assert (lay.ue_pos >= 0).all() and (lay.ue_pos <= 1000.0).all()
    # each BS sits nearer its anchor DC than any other DC
    for b in range(B):
        d = np.linalg.norm(lay.dc_pos - lay.bs_pos[b], axis=1)
        assert int(np.argmin(d)) == int(NET.subnet_of_bs[b])


@pytest.mark.parametrize("model", [RandomWaypoint(speed=(1.0, 2.0)),
                                   GaussMarkov(mean_speed=10.0)])
def test_mobility_moves_and_stays_in_field(model):
    rng = np.random.RandomState(0)
    area = 500.0
    pos = rng.uniform(0, area, (5, 2))
    model.init(rng, pos, area)
    total = np.zeros(5)
    for t in range(10):
        new = model.step(t, rng, pos, area, dt=30.0)
        assert (new >= 0).all() and (new <= area).all()
        total += np.linalg.norm(new - pos, axis=1)
        pos = new
    assert (total > 0).all()    # everyone moved


def test_mobility_deterministic_given_rng():
    def run():
        rng = np.random.RandomState(3)
        m = GaussMarkov(mean_speed=12.0)
        pos = rng.uniform(0, 400.0, (4, 2))
        m.init(rng, pos, 400.0)
        for t in range(5):
            pos = m.step(t, rng, pos, 400.0, dt=10.0)
        return pos
    np.testing.assert_array_equal(run(), run())


# ----------------------------------------------------- network evolution --

def test_dynamic_scenario_preserves_dims_and_cfg():
    for net_t, _, _ in _steps(get_scenario("campus_walk"), 4):
        assert net_t.dims == NET.dims and net_t.cfg is NET.cfg
        assert (net_t.R_nb > 0).all() and np.isfinite(net_t.R_nb).all()


def test_handovers_update_association_and_graph():
    scen = get_scenario("vehicular")
    scen.bind(NET, _Opts())
    rng = np.random.RandomState(0)
    ues = _ues()
    N, B, S = NET.dims
    total = 0
    for t in range(12):
        net_t, _, ev = scen.step(t, ues, rng)
        total += len(ev.handovers)
        # serving association drives both subnet and the consensus graph
        serving = scen.serving_bs
        np.testing.assert_array_equal(
            net_t.subnet_of_ue, np.asarray(NET.subnet_of_bs)[serving])
        A = net_t.adjacency
        assert (A == A.T).all()
        for n in range(N):
            row = A[n, N:N + B]
            assert row.sum() == 1 and row[serving[n]] == 1
        for u, old, new in ev.handovers:
            assert old != new and 0 <= u < N
    assert total >= 1     # vehicular speeds must produce handovers


def test_mesh_churn_keeps_dcs_connected():
    """Outages must never disconnect the DC mesh — not just degree >= 1
    (a 4-DC mesh can split into two pairs), actual single-component
    connectivity, on a larger net where pair-splits are likely."""
    from repro.scenario.dynamic import _components
    net4 = make_network(NetworkConfig(num_ue=8, num_bs=4, num_dc=4))
    scen = DynamicScenario(mobility=GaussMarkov(mean_speed=15.0),
                           mesh_outage_p=0.6, area=1000.0, dt=10.0)
    scen.bind(net4, _Opts())
    rng = np.random.RandomState(0)
    ues = _ues(n=8)
    N, B, S = net4.dims
    for t in range(30):
        net_t, _, ev = scen.step(t, ues, rng)
        A_dc = np.array(net_t.adjacency[N + B:, N + B:])
        assert len(_components(A_dc)) == 1
        for i, j in ev.mesh_down:
            assert net_t.R_ss[i, j] < net4.R_ss[i, j]   # outage rate cut


def test_static_radio_scenarios_keep_base_graph_and_rates():
    """mobility=None presets (label_shift) must not touch associations or
    the consensus graph — the radio plane only gets the configured
    jitter (EngineOptions.rate_jitter threaded through bind)."""
    scen = get_scenario("label_shift")

    class O:
        rate_jitter = 0.0
    scen.bind(NET, O())
    rng = np.random.RandomState(0)
    net_t, _, ev = scen.step(0, _ues(), rng)
    N, B, S = NET.dims
    np.testing.assert_array_equal(net_t.adjacency[:N, N:N + B],
                                  NET.adjacency[:N, N:N + B])
    np.testing.assert_array_equal(net_t.subnet_of_ue, NET.subnet_of_ue)
    np.testing.assert_allclose(net_t.R_nb, NET.R_nb)    # jitter 0.0 -> exact
    assert ev.handovers == ()


# ------------------------------------------------------ drift schedules --

def test_label_rotation_rotates():
    sch = LabelRotation(period=2, shift=1, num_classes=10)
    data = {"x": np.zeros((4, 1)), "y": np.array([0, 1, 8, 9])}
    rng = np.random.RandomState(0)
    assert (sch.apply(0, 0, data, rng)["y"] == data["y"]).all()
    np.testing.assert_array_equal(sch.apply(2, 0, data, rng)["y"],
                                  np.array([1, 2, 9, 0]))


def test_arrival_burst_scales_volume():
    sch = ArrivalBurst(start=1, length=1, factor=3.0)
    data = {"x": np.arange(10)[:, None], "y": np.arange(10)}
    rng = np.random.RandomState(0)
    assert len(sch.apply(0, 0, data, rng)["y"]) == 10    # outside window
    assert len(sch.apply(1, 0, data, rng)["y"]) == 30


def test_join_leave_min_active_and_events():
    sch = JoinLeave(p_leave=1.0, p_return=0.0, min_active=2)
    sch.reset(5)
    rng = np.random.RandomState(0)
    sch.begin_round(0, 5, rng)
    joined, left = sch.events()
    assert len(left) == 3 and not joined      # floor at min_active=2
    data = {"x": np.zeros((4, 1)), "y": np.arange(4)}
    gone = [len(sch.apply(0, u, data, rng)["y"]) == 0 for u in range(5)]
    assert sum(gone) == 3


# ----------------------------------------------- engine + determinism --

def _eval_fn(p):
    # module-level (stable identity): the fused-round cache keys on the
    # eval_fn object, so a per-call lambda would defeat replay no-retrace
    return classifier_accuracy(p, np.asarray(TEX[:200]), np.asarray(TEY[:200]))


def _run_engine(strategy, scenario, seed=0, rounds=5, arrivals=120):
    ues = _ues(seed, arrivals=arrivals)
    eng = Engine(NET, strategy, consts=CONSTS, ow=OW, scenario=scenario,
                 opts=EngineOptions(rounds=rounds, eta=0.1, solver_outer=2,
                                    seed=seed))
    return eng.run(ues, init_params=P0, loss_fn=classifier_loss,
                   eval_fn=_eval_fn)


def test_engine_records_dynamics_in_reports():
    res = _run_engine("greedy_data", "vehicular", rounds=6)
    assert sum(len(r.handovers) for r in res.reports) >= 1
    aggs = res.series("aggregator")
    moved = [r.aggregator_moved for r in res.reports]
    assert moved[0] is False
    assert moved[1:] == [a != b for a, b in zip(aggs, aggs[1:])]
    assert all(r.active_ues >= 1 for r in res.reports)


# the cheap presets gate tier-1; the rest ride the full-suite job
_E2E_FAST = ("campus_walk", "byzantine", "poisoned", "stragglers",
             "fuzzmix:1")
_E2E_SLOW = ("static", "vehicular", "flash_crowd", "label_shift", "churn",
             "byzantine:0.34", "fuzzmix:15")


@pytest.mark.parametrize(
    "preset",
    list(_E2E_FAST) + [pytest.param(p, marks=pytest.mark.slow)
                       for p in _E2E_SLOW])
def test_engine_seed_determinism_under_dynamic_scenario(preset):
    """Same seed => identical loss series, plans, and association traces;
    the run is a pure function of (seed, scenario, strategy) — for EVERY
    registered preset, the adversarial ones included."""
    a = _run_engine("greedy_data", preset, seed=0, rounds=4)
    b = _run_engine("greedy_data", preset, seed=0, rounds=4)
    assert a.series("loss") == b.series("loss")
    assert a.series("acc") == b.series("acc")
    assert a.series("aggregator") == b.series("aggregator")
    assert [r.handovers for r in a.reports] == \
        [r.handovers for r in b.reports]
    for ra, rb in zip(a.reports, b.reports):
        for k, va in ra.plan.to_w().items():
            np.testing.assert_array_equal(np.asarray(va),
                                          np.asarray(rb.plan.to_w()[k]))
    if preset == "campus_walk":
        c = _run_engine("greedy_data", preset, seed=1, rounds=4)
        assert a.series("loss") != c.series("loss")  # seed actually matters


def test_e2e_determinism_covers_every_registered_preset():
    """The parametrization above must not silently miss a new preset."""
    covered = {p.split(":")[0] for p in _E2E_FAST + _E2E_SLOW}
    assert covered == set(available_scenarios())


def test_churn_scenario_runs_with_empty_ues():
    res = _run_engine("greedy_data", "churn", rounds=5)
    assert np.isfinite(res.series("energy")).all()
    assert min(r.active_ues for r in res.reports) >= 1


def test_cefl_resolves_do_not_retrace_across_dynamic_rounds(
        assert_no_retrace):
    """The evolving Network keeps cfg/dims static, so every per-round
    re-solve hits the jitted outer-step cache (PR-3 NetView design).
    Generalized onto the process-wide retrace guard: after a warmup run
    populates every cache, replaying the identical dynamic run performs
    ZERO XLA backend compiles — solver, local training, aggregation and
    eval included, not just the sca cache the bespoke probe watched."""
    from repro.solver import sca
    _run_engine("cefl", "campus_walk", rounds=3, arrivals=80)
    before = sca.jit_cache_size()
    with assert_no_retrace():
        _run_engine("cefl", "campus_walk", rounds=3, arrivals=80)
    assert sca.jit_cache_size() == before


def test_dynamic_scenario_rebind_resets_state():
    scen = get_scenario("campus_walk")
    tr1 = [e.handovers for _, _, e in _steps(scen, 4, seed=0)]
    tr2 = [e.handovers for _, _, e in _steps(scen, 4, seed=0)]
    assert tr1 == tr2


def test_flash_crowd_bursts_arrivals():
    scen = get_scenario("flash_crowd")
    sizes = [sum(len(d["y"]) for d in data)
             for _, data, _ in _steps(scen, 8)]
    pre, burst = np.mean(sizes[:5]), np.mean(sizes[5:])
    assert burst > 1.8 * pre


# --------------------------------------- drift-schedule edge cases ------

def test_join_leave_all_ues_offline_round_stays_finite(assert_no_retrace):
    """min_active=0 + p_leave=1: every UE drops at round 0.  The engine
    must skip aggregation (params unchanged, finite) without NaN in
    params/costs and without a retrace on replay."""
    def scen():
        return DynamicScenario(
            mobility=None,
            schedules=(JoinLeave(p_leave=1.0, p_return=0.6,
                                 min_active=0),))
    res = _run_engine("greedy_data", scen(), rounds=4, arrivals=80)
    assert any(r.active_ues == 0 for r in res.reports)
    for leaf in jax.tree_util.tree_leaves(res.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert np.isfinite(res.series("energy")).all()
    with assert_no_retrace():
        _run_engine("greedy_data", scen(), rounds=4, arrivals=80)


def test_arrival_burst_zero_arrival_window():
    sch = ArrivalBurst(start=1, length=2, factor=0.0)
    data = {"x": np.arange(10)[:, None], "y": np.arange(10)}
    rng = np.random.RandomState(0)
    assert len(sch.apply(0, 0, data, rng)["y"]) == 10    # outside window
    out = sch.apply(1, 0, data, rng)
    assert len(out["y"]) == 0 and len(out["x"]) == 0
    assert out["x"].shape[1:] == data["x"].shape[1:]
    # a lull (0 < factor < 1) still never silences a UE entirely
    assert len(ArrivalBurst(start=0, length=1, factor=0.01).apply(
        0, 0, data, rng)["y"]) == 1


def test_arrival_burst_zero_window_engine_round_stays_finite():
    scen = DynamicScenario(
        mobility=None,
        schedules=(ArrivalBurst(start=1, length=1, factor=0.0),))
    res = _run_engine("greedy_data", scen, rounds=3, arrivals=80)
    assert res.reports[1].active_ues == 0
    for leaf in jax.tree_util.tree_leaves(res.params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_label_rotation_shift_beyond_num_classes():
    data = {"x": np.zeros((4, 1)), "y": np.array([0, 1, 8, 9])}
    rng = np.random.RandomState(0)
    # shift == num_classes is the identity at every round
    sch = LabelRotation(period=1, shift=10, num_classes=10)
    for t in range(4):
        np.testing.assert_array_equal(sch.apply(t, 0, data, rng)["y"],
                                      data["y"])
    # shift > num_classes wraps mod C and labels stay in range
    sch = LabelRotation(period=1, shift=13, num_classes=10)
    out = sch.apply(1, 0, data, rng)["y"]
    np.testing.assert_array_equal(out, (data["y"] + 3) % 10)
    for t in range(8):
        y = sch.apply(t, 0, data, rng)["y"]
        assert ((0 <= y) & (y < 10)).all()


def _schedule_instances():
    from repro.scenario import (ByzantineUpdate, Dropout, LabelPoison,
                                Straggler)
    return [LabelRotation(period=2, shift=3),
            ArrivalBurst(start=1, length=2, factor=2.0),
            JoinLeave(p_leave=0.4, p_return=0.4, min_active=1),
            ByzantineUpdate(mode="gauss", frac=0.4, scale=2.0),
            LabelPoison(frac=0.5),
            Straggler(frac=0.5, slowdown=3.0),
            Dropout(p=0.4, min_active=1)]


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=20, deadline=None)
def test_every_schedule_class_state_dict_round_trips(seed):
    """Property: for every schedule class, advancing K rounds, snapshotting
    ``state_dict`` into a fresh instance, and continuing produces the same
    data/events trace as the uninterrupted run."""
    n_ue, k, total = 5, 3, 6
    data = {"x": np.arange(12)[:, None].astype(float), "y": np.arange(12)}

    def trace(sch, rng, t0, t1):
        out = []
        for t in range(t0, t1):
            if hasattr(sch, "begin_round"):
                sch.begin_round(t, n_ue, rng)
            ev = sch.events() if hasattr(sch, "events") else ()
            rows = [sch.apply(t, ue, data, rng)["y"].tolist()
                    for ue in range(n_ue)]
            extra = (tuple(sch.corrupted(t))
                     if hasattr(sch, "corrupted") else (),
                     tuple(sch.compute_scale(t, n_ue))
                     if hasattr(sch, "compute_scale") else ())
            out.append((ev, rows, extra))
        return out

    for a, b in zip(_schedule_instances(), _schedule_instances()):
        assert hasattr(a, "state_dict"), type(a).__name__
        if hasattr(a, "reset"):
            a.reset(n_ue)
        rng = np.random.RandomState(seed)
        head = trace(a, rng, 0, k)
        snap = a.state_dict()
        rng_state = rng.get_state()
        tail_a = trace(a, rng, k, total)
        if hasattr(b, "reset"):
            b.reset(n_ue)
        b.load_state_dict(snap)
        rng2 = np.random.RandomState(seed)
        rng2.set_state(rng_state)
        tail_b = trace(b, rng2, k, total)
        assert tail_a == tail_b, type(a).__name__
        del head
