"""Distributed solver (Algorithms 1-3): projections, consensus, SCA
monotonic improvement, centralized-vs-distributed agreement, rounding."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.convergence import MLConstants
from repro.network import NetworkConfig, make_network
from repro.solver import (ObjectiveWeights, PDHyper, consensus_error,
                          consensus_rounds, consensus_scan,
                          consensus_weights, constraint_vector, solve)
from repro.solver.greedy import (datapoint_greedy, e2e_rate, heuristic_base,
                                 rate_greedy)
from repro.solver.variables import (Scaler, _project_simplex,
                                    _project_simplex_ineq, init_w,
                                    ownership_masks, project)

NET = make_network(NetworkConfig(num_ue=6, num_bs=3, num_dc=2))
D_BAR = np.full(6, 1000.0)
CONSTS = MLConstants(L=4.0, theta_i=np.ones(8) * 2, sigma_i=np.ones(8),
                     zeta1=2.0, zeta2=1.0)
OW = ObjectiveWeights()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-5, 5), min_size=3, max_size=8))
def test_simplex_projection_properties(vals):
    v = jnp.asarray([vals])
    p = _project_simplex(v)
    assert float(jnp.min(p)) >= -1e-6
    np.testing.assert_allclose(float(jnp.sum(p)), 1.0, atol=1e-5)
    # idempotent
    np.testing.assert_allclose(p, _project_simplex(p), atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-5, 5), min_size=3, max_size=8))
def test_simplex_ineq_projection(vals):
    v = jnp.asarray([vals])
    p = _project_simplex_ineq(v)
    assert float(jnp.min(p)) >= -1e-6
    assert float(jnp.sum(p)) <= 1.0 + 1e-5
    # points already inside are untouched
    inside = jnp.clip(v, 0.0, None)
    inside = inside / jnp.maximum(jnp.sum(inside), 2.0)
    np.testing.assert_allclose(_project_simplex_ineq(inside), inside,
                               atol=1e-6)


def test_project_feasibility():
    w = init_w(NET, D_BAR)
    w = {k: v + 10.0 for k, v in w.items()}           # blow everything up
    p = project(w, NET)
    assert float(jnp.max(jnp.sum(p["rho_nb"], 1))) <= 1 + 1e-5
    np.testing.assert_allclose(np.asarray(jnp.sum(p["rho_bs"], 1)), 1.0,
                               atol=1e-5)
    np.testing.assert_allclose(float(jnp.sum(p["I_s"])), 1.0, atol=1e-5)
    assert float(jnp.max(p["R_bs"] - np.asarray(NET.R_bs_max))) <= 1e-3
    cap = np.asarray(jnp.sum(p["R_bs"], 0)) - NET.R_s_max
    assert cap.max() <= 1e-3


def test_ownership_masks_partition():
    masks = ownership_masks(NET)
    total = {}
    for m in masks:
        for k, v in m.items():
            total[k] = total.get(k, 0) + np.asarray(v, dtype=float)
    for k, v in total.items():
        np.testing.assert_allclose(v, np.ones_like(v), atol=1e-6,
                                   err_msg=k)


def test_scaler_roundtrip():
    sc = Scaler(NET)
    w = project(init_w(NET, D_BAR), NET)
    back = sc.to_phys(sc.from_phys(w))
    for k in w:
        np.testing.assert_allclose(np.asarray(back[k]), np.asarray(w[k]),
                                   rtol=1e-6)


def test_consensus_weights_doubly_stochastic_degenerate_graphs():
    """Self-loops and one-directional edges in the adjacency input must not
    break the Xiao-Boyd construction: W stays nonnegative and doubly
    stochastic (rows AND columns sum to 1), so consensus preserves the
    network-wide dual average."""
    rng = np.random.RandomState(3)
    A = (rng.rand(7, 7) < 0.4).astype(int)        # asymmetric directed draw
    np.fill_diagonal(A, 1)                        # plus self-loops
    W = consensus_weights(A)
    assert W.min() >= 0.0
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)
    # the average is exactly preserved by every round
    vals = rng.randn(7, 3)
    out = consensus_rounds(vals, W, 17)
    np.testing.assert_allclose(out.mean(0), vals.mean(0), atol=1e-12)


def test_consensus_error_contracts_monotonically():
    """For a connected graph, each averaging round is a convex combination
    per component, so consensus_error must be non-increasing round over
    round (and strictly shrink overall)."""
    W = consensus_weights(NET.adjacency)
    vals = np.random.RandomState(1).randn(NET.node_count(), 5)
    errs = [consensus_error(consensus_rounds(vals, W, j))
            for j in range(0, 40, 2)]
    for e_prev, e_next in zip(errs, errs[1:]):
        assert e_next <= e_prev + 1e-12
    assert errs[-1] < 0.5 * errs[0]


def test_consensus_scan_matches_numpy_rounds():
    W = consensus_weights(NET.adjacency)
    vals = np.random.RandomState(2).randn(NET.node_count(), 4)
    ref = consensus_rounds(vals, W, 25)
    scanned = np.asarray(consensus_scan(
        jnp.asarray(vals, jnp.float32), jnp.asarray(W, jnp.float32), 25))
    np.testing.assert_allclose(scanned, ref, atol=1e-4)


def test_consensus_converges_to_mean():
    W = consensus_weights(NET.adjacency)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9)
    vals = np.random.RandomState(0).randn(NET.node_count(), 4)
    e0 = consensus_error(vals)
    out = consensus_rounds(vals, W, 3000)
    np.testing.assert_allclose(
        out, np.broadcast_to(vals.mean(0, keepdims=True), out.shape),
        atol=1e-2)
    # strictly contracting after a few rounds
    e30 = consensus_error(consensus_rounds(vals, W, 30))
    assert e30 < e0


def test_sca_centralized_decreases():
    res = solve(NET, D_BAR, CONSTS, OW, distributed=False, max_outer=5)
    assert res.objective_history[-1] < res.objective_history[0]


def test_sca_distributed_tracks_centralized():
    res_c = solve(NET, D_BAR, CONSTS, OW, distributed=False, max_outer=6)
    res_d = solve(NET, D_BAR, CONSTS, OW, distributed=True, max_outer=6,
                  pd=PDHyper(max_iters=3, consensus_rounds=40))
    assert res_d.objective_history[-1] < res_d.objective_history[0]
    gap = abs(res_d.objective_history[-1] - res_c.objective_history[-1])
    assert gap / abs(res_c.objective_history[-1]) < 0.5


def test_rounded_solution_feasible():
    res = solve(NET, D_BAR, CONSTS, OW, distributed=False, max_outer=3)
    w = res.w_rounded
    assert set(np.unique(np.asarray(w["I_s"]))) <= {0.0, 1.0}
    assert float(jnp.sum(w["I_s"])) == 1.0
    viol = float(jnp.max(constraint_vector(w, NET, D_BAR)))
    assert viol <= 1e-3, viol


def test_greedy_baselines():
    base = heuristic_base(NET, D_BAR)
    wd = datapoint_greedy(NET, D_BAR, base)
    wr = rate_greedy(NET, D_BAR, base)
    assert float(jnp.sum(wd["I_s"])) == 1.0
    assert float(jnp.sum(wr["I_s"])) == 1.0
    assert e2e_rate(NET).shape == (6, 2)
    # skewing data toward subnet 1 flips the datapoint-greedy choice
    skew = np.array([1, 1, 1, 1, 5000, 5000.0]) * 100
    w2 = datapoint_greedy(NET, skew, base)
    assert int(jnp.argmax(w2["I_s"])) == NET.subnet_of_ue[-1]
