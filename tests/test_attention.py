"""Blocked/flash attention vs the O(S^2) reference: outputs, gradients,
sliding windows, cross-attention lengths, decode paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import naive_attention
from repro.models.attention import (blocked_attention, decode_attention_plain)


def _qkv(B=2, S=64, Hq=4, Hkv=2, D=16, S_kv=None):
    S_kv = S_kv or S
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hq, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S_kv, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S_kv, Hkv, D))
    return q, k, v


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("blocks", [(16, 16), (32, 8), (64, 64)])
def test_blocked_matches_naive(window, blocks):
    q, k, v = _qkv()
    out = blocked_attention(q, k, v, causal=True, window=window,
                            q_block=blocks[0], kv_block=blocks[1])
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_non_causal_cross_lengths():
    q, k, v = _qkv(S=32, S_kv=24)   # 24 not divisible by default blocks
    out = blocked_attention(q, k, v, causal=False, q_block=16, kv_block=16)
    ref = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("window", [None, 16])
def test_flash_vjp_matches_naive_grads(window):
    q, k, v = _qkv()

    def f(q, k, v):
        return jnp.sum(jnp.sin(blocked_attention(
            q, k, v, causal=True, window=window, q_block=16, kv_block=16)))

    def g(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, causal=True,
                                               window=window)))

    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-4)


def test_decode_matches_naive_row():
    q, k, v = _qkv(S=64)
    kc = jnp.zeros_like(k).at[:, :40].set(k[:, :40])
    vc = jnp.zeros_like(v).at[:, :40].set(v[:, :40])
    out = decode_attention_plain(q[:, 39], kc, vc, 40)
    ref = naive_attention(q[:, :40], k[:, :40], v[:, :40])[:, 39]
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_decode_window_mask():
    q, k, v = _qkv(S=64)
    out = decode_attention_plain(q[:, 39], k, v, 40, window=8)
    ref = naive_attention(q[:, :40], k[:, :40], v[:, :40], window=8)[:, 39]
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_flash_memory_no_s2_residual():
    """The custom-vjp grad jaxpr must not save O(S^2) probability tensors."""
    q, k, v = _qkv(B=1, S=256, Hq=2, Hkv=1, D=8)

    def f(q):
        return jnp.sum(blocked_attention(q, k, v, q_block=32, kv_block=32))

    jaxpr = jax.make_jaxpr(jax.grad(f))(q)
    biggest = 0
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            if hasattr(var, "aval") and var.aval.shape:
                biggest = max(biggest, int(np.prod(var.aval.shape)))
    # S^2 tensors would be >= 256*256*2 = 131072
    assert biggest < 256 * 256, biggest
