"""repro.analysis: linter rules vs their corpus, noqa suppression, the
CLI, the runtime sanitizers (compile monitor, key-reuse detector,
NaN/Inf), EngineOptions.sanitize, and the repo-wide acceptance gates —
`lint src/` stays clean and a warmed 5-round campus_walk run triggers
zero XLA recompiles."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import __main__ as cli
from repro.analysis.corpus import CORPUS, CYCLE_CORPUS
from repro.analysis.linter import (lint_paths, lint_project, lint_source,
                                   render_findings)
from repro.analysis.rules import RULES
from repro.analysis.sanitize import (CompileMonitor, KeyReuseDetector,
                                     SanitizerError, check_finite,
                                     no_retrace)

# ------------------------------------------------------------- rules ----


def test_every_rule_has_bad_and_good_corpus():
    """Acceptance: each rule ships >= 1 failing and >= 1 passing case."""
    for code in RULES:
        if code in ("RPA000", "RPA007"):      # syntax / cycle: own corpora
            continue
        assert CORPUS[code]["bad"], code
        assert CORPUS[code]["good"], code
    assert CYCLE_CORPUS                       # RPA007 has cycle corpora


@pytest.mark.parametrize("code", sorted(c for c in RULES
                                        if c not in ("RPA000", "RPA007")))
def test_rule_corpus(code):
    """Every known-bad snippet trips exactly its rule; known-good don't."""
    for i, snippet in enumerate(CORPUS[code]["bad"]):
        hits = {f.code for f in lint_source(snippet)}
        assert code in hits, f"{code} bad[{i}] missed: got {sorted(hits)}"
    for i, snippet in enumerate(CORPUS[code]["good"]):
        hits = {f.code for f in lint_source(snippet)}
        assert code not in hits, f"{code} good[{i}] false positive"


def test_cycle_corpus():
    for name, case in CYCLE_CORPUS.items():
        hits = {f.code for f in lint_project(case["files"],
                                             select=["RPA007"])}
        assert ("RPA007" in hits) == case["expect"], name


def test_syntax_error_becomes_finding():
    fs = lint_source("def broken(:\n    pass\n", path="x.py")
    assert [f.code for f in fs] == ["RPA000"]
    assert fs[0].path == "x.py"


def test_noqa_suppression():
    bad = ("import jax\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    if x > 0:{noqa}\n"
           "        return x\n"
           "    return -x\n")
    assert any(f.code == "RPA004"
               for f in lint_source(bad.format(noqa="")))
    # rule-coded and bare suppressions both silence the line
    assert not lint_source(bad.format(noqa="  # repro: noqa(RPA004)"))
    assert not lint_source(bad.format(noqa="  # repro: noqa"))
    # a different code does NOT suppress
    assert lint_source(bad.format(noqa="  # repro: noqa(RPA001)"))


def test_findings_render_text_and_json():
    fs = lint_source("import jax\n"
                     "k = jax.random.PRNGKey(0)\n"
                     "a = jax.random.normal(k, (2,))\n"
                     "b = jax.random.uniform(k, (2,))\n", path="m.py")
    assert [f.code for f in fs] == ["RPA001"]
    txt = render_findings(fs)
    assert "m.py:4" in txt and "RPA001" in txt and "hint:" in txt
    recs = json.loads(render_findings(fs, fmt="json"))
    assert recs[0]["code"] == "RPA001" and recs[0]["line"] == 4


# --------------------------------------------------------------- cli ----


def test_cli_selftest_and_rules(capsys):
    assert cli.main(["selftest"]) == 0
    assert "selftest OK" in capsys.readouterr().out
    assert cli.main(["rules"]) == 0
    out = capsys.readouterr().out
    for code in RULES:
        if code != "RPA000":
            assert code in out


def test_cli_lint_exit_codes_and_artifact(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n"
                   "k = jax.random.PRNGKey(0)\n"
                   "a = jax.random.normal(k, (2,))\n"
                   "b = jax.random.uniform(k, (2,))\n")
    good = tmp_path / "good.py"
    good.write_text("import jax\n"
                    "k = jax.random.PRNGKey(0)\n"
                    "k1, k2 = jax.random.split(k)\n"
                    "a = jax.random.normal(k1, (2,))\n")
    out = tmp_path / "artifacts" / "report.txt"   # --out creates parents
    assert cli.main(["lint", str(bad), "--out", str(out)]) == 1
    assert "RPA001" in out.read_text()
    capsys.readouterr()
    assert cli.main(["lint", str(good)]) == 0
    assert cli.main(["lint", str(bad), "--select", "RPA003"]) == 0


def test_lint_src_tree_is_clean():
    """Acceptance: the shipped tree lints clean (justified noqa only)."""
    findings = lint_paths(["src"])
    assert not findings, render_findings(findings)


# --------------------------------------------------- runtime sanitizers --


def test_compile_monitor_counts_compiles():
    @jax.jit
    def f(x):
        return x * 2.0 + 1.0

    with CompileMonitor() as cold:
        f(jnp.ones((3,)))
    assert cold.compiles >= 1
    with CompileMonitor() as warm:
        f(jnp.ones((3,)))
    assert warm.compiles == 0


def test_no_retrace_passes_warm_and_raises_cold():
    @jax.jit
    def g(x):
        return jnp.sum(x ** 2)

    x4, x5, x6 = jnp.ones((4,)), jnp.ones((5,)), jnp.ones((6,))
    g(x4)                                 # warmup
    with no_retrace("warm g"):
        g(x4)
    with pytest.raises(SanitizerError, match="backend compile"):
        with no_retrace("cold g"):
            g(x5)                         # new shape => real compile
    # the allowance escape hatch
    with no_retrace("cold g, allowed", allow_compiles=1):
        g(x6)


def test_key_reuse_detector_raises_and_records():
    with KeyReuseDetector() as det:
        k = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(k)
        jax.random.normal(k1, (2,))
        jax.random.uniform(k2, (2,))      # distinct subkeys: fine
        with pytest.raises(SanitizerError, match="consumed twice"):
            jax.random.normal(k1, (2,))
    assert len(det.reuses) == 1
    with KeyReuseDetector(mode="record") as det:
        k = jax.random.PRNGKey(1)
        jax.random.normal(k, ())
        jax.random.normal(k, ())          # recorded, not raised
    assert len(det.reuses) == 1
    # exit restores the real functions
    assert jax.random.split.__module__.startswith("jax.")


def test_key_reuse_detector_skips_traced_keys():
    @jax.jit
    def draw(k):
        a = jax.random.normal(k, ())
        b = jax.random.normal(k, ())      # tracer: static rule territory
        return a + b

    with KeyReuseDetector():
        draw(jax.random.PRNGKey(2))       # key itself concrete-consumed once


def test_check_finite():
    check_finite({"a": jnp.ones((2,)), "n": np.arange(3)}, "ok tree")
    with pytest.raises(SanitizerError, match="non-finite"):
        check_finite({"a": jnp.array([1.0, float("nan")])}, "bad tree")
    with pytest.raises(SanitizerError, match="non-finite"):
        check_finite([jnp.array([float("inf")])], "inf tree")


# ----------------------------------------------- EngineOptions.sanitize --


def _tiny_engine(sanitize, *, eta=0.1, rounds=2):
    from repro.configs.cefl_paper import ClassifierConfig
    from repro.core import Engine, EngineOptions, MLConstants
    from repro.data import make_image_dataset, make_online_ues
    from repro.models.classifier import (classifier_accuracy,
                                         classifier_loss,
                                         init_classifier_params)
    from repro.network import NetworkConfig, make_network
    from repro.solver import ObjectiveWeights
    net = make_network(NetworkConfig(num_ue=4, num_bs=2, num_dc=2))
    (trx, tr_y), (tex, te_y) = make_image_dataset(1200, (8, 8, 1))
    p0 = init_classifier_params(
        jax.random.PRNGKey(0), ClassifierConfig(input_shape=(8, 8, 1),
                                                hidden=(16,)))
    consts = MLConstants(L=5.0, theta_i=np.ones(6) * 2,
                         sigma_i=np.ones(6) * 3, zeta1=2.0, zeta2=1.0)
    eng = Engine(net, "greedy_data", consts=consts, ow=ObjectiveWeights(),
                 opts=EngineOptions(rounds=rounds, eta=eta, solver_outer=2,
                                    sanitize=sanitize))
    ues = make_online_ues(trx, tr_y, num_ue=4, mean_arrivals=100,
                          std_arrivals=10)
    return eng, ues, p0, classifier_loss, \
        lambda p: classifier_accuracy(p, jnp.asarray(tex[:100]),
                                      jnp.asarray(te_y[:100]))


def test_engine_sanitize_mode_clean_run():
    eng, ues, p0, loss_fn, eval_fn = _tiny_engine(True)
    res = eng.run(ues, init_params=p0, loss_fn=loss_fn, eval_fn=eval_fn)
    assert len(res) == 2 and np.isfinite(res.final.acc)


def test_engine_sanitize_mode_catches_divergence():
    """An exploding step size drives params to Inf/NaN; sanitize mode
    turns the silent garbage run into a SanitizerError."""
    eng, ues, p0, loss_fn, eval_fn = _tiny_engine(True, eta=1e12)
    with pytest.raises(SanitizerError, match="non-finite"):
        eng.run(ues, init_params=p0, loss_fn=loss_fn, eval_fn=eval_fn)


def test_spec_threads_sanitize():
    from repro import experiments as E
    spec = E.get_experiment("sweep_smoke").override(
        **{"engine.sanitize": True})
    opts = spec.engine_options(0)
    assert opts.sanitize is True
    assert E.get_experiment("sweep_smoke").engine_options(0).sanitize \
        is False


# -------------------------------------------- engine no-retrace pinning --


def test_campus_walk_five_rounds_no_retrace(assert_no_retrace):
    """Acceptance: a 5-round dynamic campus_walk run, replayed after an
    identical warmup, performs ZERO XLA backend compiles — solver
    re-solves, fedprox local training, aggregation kernels, and eval all
    hit their caches (the process-wide generalization of the PR-3/PR-4
    per-module cache probes)."""
    from repro.configs.cefl_paper import ClassifierConfig
    from repro.core import Engine, EngineOptions, MLConstants
    from repro.data import make_image_dataset, make_online_ues
    from repro.models.classifier import (classifier_accuracy,
                                         classifier_loss,
                                         init_classifier_params)
    from repro.network import NetworkConfig, make_network
    from repro.solver import ObjectiveWeights
    net = make_network(NetworkConfig(num_ue=6, num_bs=3, num_dc=2))
    (trx, tr_y), (tex, te_y) = make_image_dataset(2000, (8, 8, 1))
    p0 = init_classifier_params(
        jax.random.PRNGKey(0), ClassifierConfig(input_shape=(8, 8, 1),
                                                hidden=(16,)))
    consts = MLConstants(L=5.0, theta_i=np.ones(8) * 2,
                         sigma_i=np.ones(8) * 3, zeta1=2.0, zeta2=1.0)
    tex, te_y = jnp.asarray(tex[:200]), jnp.asarray(te_y[:200])

    def run():
        eng = Engine(net, "cefl", consts=consts, ow=ObjectiveWeights(),
                     scenario="campus_walk",
                     opts=EngineOptions(rounds=5, eta=0.1, solver_outer=2,
                                        seed=0))
        ues = make_online_ues(trx, tr_y, num_ue=6, mean_arrivals=80,
                              std_arrivals=8, seed=0)
        return eng.run(ues, init_params=p0, loss_fn=classifier_loss,
                       eval_fn=lambda p: classifier_accuracy(p, tex, te_y))

    warm = run()                          # populates every cache
    with assert_no_retrace():
        rerun = run()                     # same seed => same shapes
    assert rerun.series("loss") == warm.series("loss")
