"""The segment-sum ownership ops (solver/variables.py) against the dense
(V, P) ownership-matrix oracle on small dims — the correctness anchor for
the 10^5-UE solver scaling path, which never materializes the matrix."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.solver.variables import (node_sq_norms, owner_index, owner_mask,
                                    ownership_matrix, ownership_merge)

DIMS_CASES = [(3, 2, 2), (5, 3, 2), (7, 2, 4), (2, 2, 1)]


def _flat_size(dims):
    return owner_index(dims).shape[0]


@pytest.mark.parametrize("dims", DIMS_CASES)
def test_owner_index_partitions_every_component(dims):
    N, B, S = dims
    own = owner_index(dims)
    # every entry is a valid node id or the co-owned marker
    assert own.min() >= -1 and own.max() < N + B + S
    # exactly the two scalar deltas are co-owned
    assert int((own == -1).sum()) == 2


@pytest.mark.parametrize("dims", DIMS_CASES)
def test_ownership_merge_matches_dense_masked_merge(dims):
    V, P = sum(dims), _flat_size(dims)
    rng = np.random.RandomState(0)
    cands = jnp.asarray(rng.normal(size=(V, P)), jnp.float32)
    M = ownership_matrix(dims)
    ref = np.einsum("vp,vp->p", M, np.asarray(cands))
    out = np.asarray(ownership_merge(cands, dims))
    np.testing.assert_allclose(out, ref, atol=1e-6)


@pytest.mark.parametrize("dims", DIMS_CASES)
def test_owner_mask_matches_dense_rows(dims):
    M = ownership_matrix(dims)
    for v in range(sum(dims)):
        np.testing.assert_allclose(
            np.asarray(owner_mask(jnp.asarray(v), dims)), M[v], atol=1e-7)


@pytest.mark.parametrize("dims", DIMS_CASES)
def test_node_sq_norms_matches_dense_reference(dims):
    P = _flat_size(dims)
    rng = np.random.RandomState(1)
    d = jnp.asarray(rng.normal(size=(P,)), jnp.float32)
    M = ownership_matrix(dims)
    ref = ((M * np.asarray(d)[None, :]) ** 2).sum(axis=1)
    out = np.asarray(node_sq_norms(d, dims))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


def test_dense_matrix_never_needed_at_scale():
    # the flat owner index is O(P); sanity-check its footprint at a
    # paper-scale population without ever building the (V, P) matrix
    dims = (100_000, 8, 4)
    own = owner_index(dims)
    assert own.shape[0] == _flat_size(dims)
    assert int((own == -1).sum()) == 2
    assert own.max() == sum(dims) - 1
