"""Use `hypothesis` when installed; otherwise a tiny deterministic stand-in.

The container the tier-1 suite runs in has no network access and no
``hypothesis`` wheel baked in, which used to fail collection for five test
modules.  The fallback here implements just the strategy surface those
modules use (floats / integers / sampled_from / lists) and runs each
``@given`` test on a handful of seeded pseudo-random draws — strictly
weaker than hypothesis, but it keeps the properties exercised.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    import numpy as np

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw            # draw(rng) -> value

    class st:  # noqa: N801  (mimics the hypothesis.strategies namespace)
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value=0, max_value=100, **_):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randint(len(seq))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10, **_):
            def draw(rng):
                n = int(rng.randint(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    def given(*garg, **gkw):
        def deco(fn):
            # no functools.wraps: pytest must NOT see fn's parameters
            # (it would treat the strategy-filled ones as fixtures)
            def wrapper():
                for case in range(_FALLBACK_EXAMPLES):
                    rng = np.random.RandomState(20260728 + case)
                    vals = [s.draw(rng) for s in garg]
                    kv = {k: s.draw(rng) for k, s in gkw.items()}
                    fn(*vals, **kv)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
