"""Use `hypothesis` when installed; otherwise a tiny deterministic stand-in.

The container the tier-1 suite runs in has no network access and no
``hypothesis`` wheel baked in, which used to fail collection for five test
modules.  The fallback here implements just the strategy surface those
modules use (floats / integers / booleans / sampled_from / lists / tuples
/ one_of / composite) and runs each ``@given`` test on a handful of seeded
pseudo-random draws — strictly weaker than hypothesis, but it keeps the
properties exercised.  Set ``REPRO_FUZZ_EXAMPLES`` to scale the fallback
draw count (default 5) — the nightly fuzz job turns it up.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    import os

    import numpy as np

    _FALLBACK_EXAMPLES = 5

    def _n_examples() -> int:
        return int(os.environ.get("REPRO_FUZZ_EXAMPLES",
                                  _FALLBACK_EXAMPLES))

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw            # draw(rng) -> value

    class st:  # noqa: N801  (mimics the hypothesis.strategies namespace)
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value=0, max_value=100, **_):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.randint(2)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randint(len(seq))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10, unique=False, **_):
            def draw(rng):
                n = int(rng.randint(min_size, max_size + 1))
                if not unique:
                    return [elem.draw(rng) for _ in range(n)]
                out, seen = [], set()
                # rejection-sample distinct values; give up gracefully
                # once the element space looks exhausted
                for _attempt in range(100 * max(n, 1)):
                    if len(out) >= n:
                        break
                    v = elem.draw(rng)
                    try:
                        key = v
                        hash(key)
                    except TypeError:
                        key = repr(v)
                    if key not in seen:
                        seen.add(key)
                        out.append(v)
                if len(out) < min_size:
                    raise ValueError(
                        f"could not draw {min_size} unique elements")
                return out
            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.draw(rng) for s in strategies))

        @staticmethod
        def one_of(*strategies):
            # hypothesis accepts both one_of(a, b) and one_of([a, b])
            if len(strategies) == 1 and not isinstance(strategies[0],
                                                       _Strategy):
                strategies = tuple(strategies[0])
            seq = list(strategies)
            return _Strategy(lambda rng: seq[rng.randint(len(seq))].draw(rng))

        @staticmethod
        def composite(fn):
            """``@st.composite`` over the fallback: the wrapped function
            receives a ``draw`` callable resolving sub-strategies against
            the case rng."""
            def build(*args, **kwargs):
                def draw_value(rng):
                    return fn(lambda s: s.draw(rng), *args, **kwargs)
                return _Strategy(draw_value)
            return build

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    def given(*garg, **gkw):
        def deco(fn):
            # no functools.wraps: pytest must NOT see fn's parameters
            # (it would treat the strategy-filled ones as fixtures)
            def wrapper():
                for case in range(_n_examples()):
                    rng = np.random.RandomState(20260728 + case)
                    vals = [s.draw(rng) for s in garg]
                    kv = {k: s.draw(rng) for k, s in gkw.items()}
                    fn(*vals, **kv)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
