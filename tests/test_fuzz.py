"""The scenario fuzzer (repro.scenario.fuzz) and the hypothesis-compat
fallback shim it leans on: draw validity, the invariant gates, the
broken-invariant selftest with its replayable artifact, and the shim's
extended strategy surface."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.experiments.spec import from_json, to_json
from repro.scenario.fuzz import (ROBUST_POOL, SCENARIO_POOL, STRATEGY_POOL,
                                 InvariantViolation, draw_spec,
                                 replay_command, run_fuzz)

from _hypothesis_compat import given, settings, st


# ------------------------------------------------------------- draws ----

@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=15, deadline=None)
def test_draw_spec_is_valid_and_json_round_trips(seed):
    from repro.core import get_strategy
    from repro.scenario import get_scenario
    rng = np.random.RandomState(seed)
    spec = draw_spec(rng, rounds=3)
    assert from_json(to_json(spec)) == spec
    # every drawn axis value resolves through its registry
    assert get_scenario(spec.scenario) is not None
    assert get_strategy(spec.strategy) is not None
    assert spec.engine.robust_agg in ("none", "trimmed_mean", "median")
    assert 0 <= spec.run_seeds[0] < 2 ** 16
    opts = spec.engine_options(spec.run_seeds[0])
    assert opts.robust_agg == spec.engine.robust_agg


def test_draw_spec_is_deterministic_in_the_campaign_seed():
    a = [draw_spec(np.random.RandomState(7)) for _ in range(3)]
    b = [draw_spec(np.random.RandomState(7)) for _ in range(3)]
    assert a == b


def test_pools_only_reference_registered_names():
    from repro.core import available_strategies
    from repro.scenario import available_scenarios
    scen_names = set(available_scenarios())
    strat_names = set(available_strategies())
    assert {s.split(":")[0] for s in SCENARIO_POOL} <= scen_names
    assert {s.split(":")[0] for s in STRATEGY_POOL} <= strat_names
    assert set(ROBUST_POOL) <= {"none", "trimmed_mean", "median"}


# ------------------------------------------------------------ the gate --

@pytest.mark.fuzz
def test_fuzz_smoke_two_draws(tmp_path):
    """Two full draws through every invariant — the cheap always-on gate
    (CI runs 5 through the CLI; see .github/workflows/ci.yml)."""
    lines = []
    artifacts = run_fuzz(2, 11, str(tmp_path), rounds=2,
                         progress=lines.append)
    assert artifacts == [], lines
    assert len(lines) == 2 and all("ok" in ln for ln in lines)


@pytest.mark.fuzz
def test_broken_invariant_is_caught_and_replayable(tmp_path):
    """The selftest path: a mutated-seed replay MUST trip the determinism
    invariant, and the serialized artifact must contain the exact spec
    (which replays clean, since the spec itself is healthy)."""
    from repro.scenario.fuzz import check_draw, replay
    lines = []
    artifacts = run_fuzz(1, 3, str(tmp_path), rounds=2, mutate_seed=True,
                         progress=lines.append)
    assert len(artifacts) == 1, lines
    path = artifacts[0]
    assert os.path.exists(path)
    with open(path) as fh:
        art = json.load(fh)
    assert art["invariant"] == "determinism"
    assert art["fuzz_seed"] == 3 and art["draw_index"] == 0
    spec = from_json(json.dumps(art["spec"]))
    assert spec.run_seeds[0] == art["seed"]
    assert "--replay" in replay_command(path)
    # the artifact's spec is itself healthy: a straight replay passes
    replay(path)
    # and the same mutation raises through the public single-draw API
    with pytest.raises(InvariantViolation) as ei:
        check_draw(spec, mutate_seed=True)
    assert ei.value.invariant == "determinism"


@pytest.mark.fuzz
@pytest.mark.slow
def test_fuzz_cli_break_invariant_selftest(tmp_path):
    """End-to-end CLI: --break-invariant exits 0 only when the violation
    is caught and serialized."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.scenario.fuzz", "--n", "1",
         "--seed", "3", "--rounds", "2", "--out", str(tmp_path),
         "--break-invariant", "determinism"],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "selftest ok" in out.stdout


# -------------------------------------------------- hypothesis shim -----

def _rng():
    return np.random.RandomState(0)


def _is_fallback():
    return not hasattr(st, "data")     # real hypothesis has st.data


@pytest.mark.skipif(not _is_fallback(), reason="real hypothesis in use")
class TestFallbackShim:
    def test_booleans_tuples_one_of(self):
        rng = _rng()
        vals = [st.booleans().draw(rng) for _ in range(20)]
        assert set(vals) == {True, False}
        t = st.tuples(st.integers(0, 3), st.booleans()).draw(rng)
        assert isinstance(t, tuple) and len(t) == 2
        assert isinstance(t[0], int) and isinstance(t[1], bool)
        vals = [st.one_of(st.integers(0, 0), st.integers(5, 5)).draw(rng)
                for _ in range(30)]
        assert set(vals) == {0, 5}
        # list form accepted too
        v = st.one_of([st.integers(7, 7)]).draw(rng)
        assert v == 7

    def test_unique_lists(self):
        rng = _rng()
        got = st.lists(st.integers(0, 4), min_size=5, max_size=5,
                       unique=True).draw(rng)
        assert sorted(got) == [0, 1, 2, 3, 4]
        with pytest.raises(ValueError, match="unique"):
            st.lists(st.integers(0, 1), min_size=3, max_size=3,
                     unique=True).draw(rng)

    def test_composite(self):
        @st.composite
        def pair(draw, lo):
            a = draw(st.integers(lo, lo + 5))
            return (a, draw(st.integers(a, a)))
        a, b = pair(100).draw(_rng())
        assert 100 <= a <= 105 and b == a

    def test_examples_env_scales_draw_count(self, monkeypatch):
        calls = []

        @given(st.integers(0, 10))
        def prop(x):
            calls.append(x)

        monkeypatch.setenv("REPRO_FUZZ_EXAMPLES", "9")
        prop()
        assert len(calls) == 9
        calls.clear()
        monkeypatch.delenv("REPRO_FUZZ_EXAMPLES")
        prop()
        assert len(calls) == 5                      # the default

    def test_given_is_seeded_and_deterministic(self):
        seen = []

        @given(st.integers(0, 10 ** 9))
        def prop(x):
            seen.append(x)

        prop()
        first = list(seen)
        seen.clear()
        prop()
        assert seen == first
