"""Per-round client sampling (EngineOptions.cohort_size): gather/scatter
plan embedding, subnetwork restriction, engine integration, and the
default-off bit-identity contract.  Single-device — runs in tier-1 and in
the shard-parity CI lane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, EngineOptions, MLConstants
from repro.core.engine import _gather_plan, _scatter_plan
from repro.data import make_image_dataset, make_online_ues
from repro.models.classifier import (ClassifierConfig, classifier_accuracy,
                                     classifier_loss,
                                     init_classifier_params)
from repro.network.topology import NetworkConfig, make_network, subnetwork
from repro.solver import ObjectiveWeights

N_UE, N_BS, N_DC = 8, 3, 2
NET = make_network(NetworkConfig(num_ue=N_UE, num_bs=N_BS, num_dc=N_DC))
CONSTS = MLConstants(L=5.0, theta_i=np.ones(N_UE + N_DC) * 2,
                     sigma_i=np.ones(N_UE + N_DC) * 3, zeta1=2.0,
                     zeta2=1.0)
CCFG = ClassifierConfig(input_shape=(10, 10, 1), hidden=(32,))


# ------------------------------------------------------- subnetwork ------

def test_subnetwork_restricts_ue_rows():
    cohort = np.array([1, 4, 6])
    sub = subnetwork(NET, cohort)
    assert sub.cfg.num_ue == 3
    assert sub.dims == (3, N_BS, N_DC)
    np.testing.assert_array_equal(np.asarray(sub.R_nb),
                                  np.asarray(NET.R_nb)[cohort])
    np.testing.assert_array_equal(np.asarray(sub.R_bn),
                                  np.asarray(NET.R_bn)[:, cohort])
    np.testing.assert_array_equal(sub.subnet_of_ue,
                                  NET.subnet_of_ue[cohort])
    # BS/DC-side arrays are untouched
    np.testing.assert_array_equal(np.asarray(sub.R_bs_max),
                                  np.asarray(NET.R_bs_max))


# ------------------------------------------- plan gather / scatter -------

def _full_plan():
    eng = Engine(NET, "cefl", consts=CONSTS,
                 ow=ObjectiveWeights(T=3),
                 opts=EngineOptions(rounds=3, seed=0, solver_outer=2))
    D_bar = np.linspace(200.0, 600.0, N_UE)
    return eng, eng.decide(NET, D_bar, 0, None)


def test_gather_scatter_roundtrip_validates_and_preserves_cohort():
    eng, plan = _full_plan()
    cohort = np.array([0, 2, 5, 7])
    sub = _gather_plan(plan, cohort, N_UE)
    assert sub.rho_nb.shape == (4, N_BS)
    assert sub.I_bn.shape == (N_BS, 4)
    assert sub.gamma.shape == (4 + N_DC,)

    full = _scatter_plan(sub, cohort, NET, eng.opts).validate(NET)
    # cohort rows round-trip exactly
    np.testing.assert_array_equal(np.asarray(full.rho_nb)[cohort],
                                  np.asarray(sub.rho_nb))
    np.testing.assert_array_equal(np.asarray(full.f_n)[cohort],
                                  np.asarray(sub.f_n))
    np.testing.assert_array_equal(np.asarray(full.I_nb)[cohort],
                                  np.asarray(sub.I_nb))
    # non-cohort UEs sit the round out: no offloading, idle frequency,
    # default local-training settings
    rest = np.setdiff1d(np.arange(N_UE), cohort)
    assert np.all(np.asarray(full.rho_nb)[rest] == 0.0)
    assert np.all(np.asarray(full.f_n)[rest] == NET.cfg.f_min)
    g = np.asarray(full.gamma)
    m = np.asarray(full.m)
    assert np.all(g[:N_UE][rest] == float(eng.opts.gamma_default))
    assert np.all(m[:N_UE][rest] == float(eng.opts.m_default))
    # DC tail comes from the sub-plan, not the defaults
    np.testing.assert_array_equal(g[N_UE:], np.asarray(sub.gamma)[4:])
    # associations stay one-hot rows / columns at full dims
    I_nb = np.asarray(full.I_nb)
    I_bn = np.asarray(full.I_bn)
    np.testing.assert_allclose(I_nb.sum(axis=1), 1.0, atol=1e-6)
    np.testing.assert_allclose(I_bn.sum(axis=0), 1.0, atol=1e-6)


# --------------------------------------------------- engine runs ---------

(_TRX, _TRY), (_TEX, _TEY) = make_image_dataset(2500, (10, 10, 1))
_P0 = init_classifier_params(jax.random.PRNGKey(0), CCFG)


def _run(**opt_kw):
    opts = EngineOptions(rounds=3, seed=0, solver_outer=2, **opt_kw)
    ues = make_online_ues(_TRX, _TRY, num_ue=N_UE, mean_arrivals=150,
                          std_arrivals=15)
    eng = Engine(NET, "cefl", consts=CONSTS, ow=ObjectiveWeights(T=3),
                 opts=opts)

    def eval_fn(p):
        return classifier_accuracy(p, jnp.asarray(_TEX[:300]),
                                   jnp.asarray(_TEY[:300]))

    return eng.run(ues, init_params=_P0, loss_fn=classifier_loss,
                   eval_fn=eval_fn)


def test_cohort_run_produces_finite_costed_rounds():
    res = _run(cohort_size=4)
    assert len(res.reports) == 3
    for r in res.reports:
        assert np.isfinite(r.loss) and np.isfinite(r.acc)
        assert np.isfinite(r.energy) and np.isfinite(r.delay)
    # costs come from the K-UE subproblem, so a quarter-strength cohort
    # must spend less than full participation
    full = _run()
    assert res.final.cum_energy < full.final.cum_energy


def test_cohort_off_is_bit_identical_and_k_ge_n_is_noop():
    a = _run()
    b = _run()            # cohort machinery off: trace fully deterministic
    big = _run(cohort_size=N_UE)   # K >= N draws nothing: same trace
    for x, y in ((a, b), (a, big)):
        assert [r.acc for r in x.reports] == [r.acc for r in y.reports]
        assert [r.energy for r in x.reports] == \
            [r.energy for r in y.reports]
        for la, lb in zip(jax.tree_util.tree_leaves(x.params),
                          jax.tree_util.tree_leaves(y.params)):
            assert bool(jnp.all(la == lb))


def test_cohort_rejects_distributed_solver():
    with pytest.raises(ValueError, match="cohort"):
        _run(cohort_size=4, distributed_solver=True)


def test_cohort_spec_roundtrips_through_json():
    from repro.experiments.spec import ExperimentSpec, from_json, to_json
    spec = ExperimentSpec().override(**{"engine.cohort_size": 4,
                                        "engine.mesh_shape": (4, 2)})
    back = from_json(to_json(spec))
    assert back.engine.cohort_size == 4
    assert back.engine.mesh_shape == (4, 2)
    opts = back.engine_options(0)
    assert opts.cohort_size == 4 and opts.mesh_shape == (4, 2)
