"""Sharded parameter-plane parity: the ('dpu', 'rows') shard_map path
must be BITWISE identical to the single-device plane round.

These tests need a multi-device mesh; the `shard-parity` CI lane provides
8 virtual CPU devices via XLA_FLAGS=--xla_force_host_platform_device_count=8
(tests/conftest.py deliberately sets no device-count flags, so under plain
tier-1 the module skips on the single real device).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedprox
from repro.kernels import ops
from repro.models.classifier import (ClassifierConfig, classifier_loss,
                                     init_classifier_params)
from repro.sharding import plane as sp

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8); the shard-parity CI lane sets this")

MESH_SHAPES = [(8, 1), (4, 2), (2, 4), (1, 8), (2, 2)]


# --------------------------------------------------- fixtures / data -----

CCFG = ClassifierConfig(input_shape=(10, 10, 1), hidden=(32,))


def _round_inputs(G=4, examples=64):
    params = init_classifier_params(jax.random.PRNGKey(0), CCFG)
    rng = np.random.RandomState(0)
    datasets = [
        {"x": jnp.asarray(rng.normal(size=(examples, 10, 10, 1)),
                          jnp.float32),
         "y": jnp.asarray(rng.randint(0, 10, size=(examples,)), jnp.int32)}
        for _ in range(G)]
    keys = [jax.random.PRNGKey(i + 1) for i in range(G)]
    kw = dict(gamma=3, m_frac=0.25, eta=0.05, mu=0.1, theta=1.0)
    return params, datasets, keys, kw


def _op_inputs(G=8, R=16):
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.normal(size=(R, 1024)), jnp.float32)
    d = jnp.asarray(rng.normal(size=(G, R, 1024)), jnp.float32)
    w = jnp.asarray(np.abs(rng.normal(size=(G,))), jnp.float32)
    return x, d, w / w.sum(), rng


# ------------------------------------------------ standalone plane ops ---

def test_plane_mesh_shapes_and_validation():
    mesh = sp.plane_mesh((4, 2))
    assert mesh.shape == {"dpu": 4, "rows": 2}
    assert sp.plane_mesh(None).shape["dpu"] == jax.device_count()
    with pytest.raises(ValueError, match="devices"):
        sp.plane_mesh((jax.device_count(), 2))
    with pytest.raises(ValueError):
        sp.plane_mesh((0, 1))


def test_nova_aggregate_sharded_exact_is_bitwise():
    x, d, w, _ = _op_inputs()
    ref = ops.nova_aggregate_plane(x, d, w, 0.3)
    for shape in MESH_SHAPES:
        out = sp.nova_aggregate_plane_sharded(
            x, d, w, 0.3, mesh=sp.plane_mesh(shape))
        assert bool(jnp.all(ref == out)), f"mesh {shape} not bitwise"


def test_nova_aggregate_sharded_psum_is_allclose():
    x, d, w, _ = _op_inputs()
    ref = ops.nova_aggregate_plane(x, d, w, 0.3)
    out = sp.nova_aggregate_plane_sharded(
        x, d, w, 0.3, mesh=sp.plane_mesh((4, 2)), reduce="psum")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)
    with pytest.raises(ValueError, match="reduce"):
        sp.nova_aggregate_plane_sharded(
            x, d, w, 0.3, mesh=sp.plane_mesh((4, 2)), reduce="mean")


def test_nova_aggregate_sharded_ragged_group_degrades_bitwise():
    # G=7 divides no 8/4/2-way dpu axis: the spec degrades that dim to
    # replication (sanitize rule) and the result stays bitwise
    x, d, w, _ = _op_inputs(G=7)
    ref = ops.nova_aggregate_plane(x, d, w, 0.3)
    out = sp.nova_aggregate_plane_sharded(
        x, d, w, 0.3, mesh=sp.plane_mesh((4, 2)))
    assert bool(jnp.all(ref == out))


def test_robust_aggregate_sharded_is_bitwise():
    x, d, _, _ = _op_inputs()
    for mode in ("trimmed_mean", "median"):
        ref = ops.robust_aggregate_plane(x, d, 0.3, mode=mode,
                                         trim_frac=0.2)
        out = sp.robust_aggregate_plane_sharded(
            x, d, 0.3, mesh=sp.plane_mesh((4, 2)), mode=mode,
            trim_frac=0.2)
        assert bool(jnp.all(ref == out)), mode


def test_fedprox_accum_sharded_is_bitwise():
    x, d, _, rng = _op_inputs()
    G, R = d.shape[0], x.shape[0]
    xs = jnp.asarray(rng.normal(size=(G, R, 1024)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(G, R, 1024)), jnp.float32)
    coef = jnp.asarray(np.abs(rng.normal(size=(G,))), jnp.float32)
    act = jnp.ones((G,), jnp.float32)
    ref = ops.fedprox_accum_plane(xs, g, x, jnp.zeros_like(xs), coef, act,
                                  0.05, 0.1)
    out = sp.fedprox_accum_plane_sharded(
        xs, g, x, jnp.zeros_like(xs), coef, act, 0.05, 0.1,
        mesh=sp.plane_mesh((4, 2)))
    for a, b in zip(ref, out):
        assert bool(jnp.all(a == b))


# ------------------------------------------------- fused sharded round ---

def test_sharded_round_bitwise_across_mesh_shapes():
    params, datasets, keys, kw = _round_inputs()
    ref_plane, ref_loss, _ = fedprox.local_round_plane(
        params, classifier_loss, datasets, keys=keys, **kw)
    for shape in MESH_SHAPES:
        out_plane, out_loss, _ = sp.local_round_plane_sharded(
            params, classifier_loss, datasets, keys=keys,
            mesh=sp.plane_mesh(shape), **kw)
        assert bool(jnp.all(out_plane.data == ref_plane.data)), \
            f"params diverge on mesh {shape}"
        assert np.all(out_loss == ref_loss), \
            f"losses diverge on mesh {shape}"


def test_sharded_round_psum_mode_allclose():
    params, datasets, keys, kw = _round_inputs()
    ref_plane, _, _ = fedprox.local_round_plane(
        params, classifier_loss, datasets, keys=keys, **kw)
    out_plane, _, _ = sp.local_round_plane_sharded(
        params, classifier_loss, datasets, keys=keys,
        mesh=sp.plane_mesh((4, 2)), reduce="psum", **kw)
    np.testing.assert_allclose(np.asarray(out_plane.data),
                               np.asarray(ref_plane.data),
                               atol=1e-6, rtol=1e-6)


def test_sharded_round_ragged_group_bitwise():
    params, datasets, keys, kw = _round_inputs()
    ref_plane, ref_loss, _ = fedprox.local_round_plane(
        params, classifier_loss, datasets[:3], keys=keys[:3], **kw)
    out_plane, out_loss, _ = sp.local_round_plane_sharded(
        params, classifier_loss, datasets[:3], keys=keys[:3],
        mesh=sp.plane_mesh((8, 1)), **kw)
    assert bool(jnp.all(out_plane.data == ref_plane.data))
    assert np.all(out_loss == ref_loss)


def test_sharded_round_warm_no_retrace(assert_no_retrace):
    params, datasets, keys, kw = _round_inputs()
    mesh = sp.plane_mesh((4, 2))
    sp.local_round_plane_sharded(params, classifier_loss, datasets,
                                 keys=keys, mesh=mesh, **kw)
    with assert_no_retrace():
        for i in range(3):
            keys2 = [jax.random.PRNGKey(100 + i) for _ in keys]
            sp.local_round_plane_sharded(params, classifier_loss, datasets,
                                         keys=keys2, mesh=mesh, **kw)


# ------------------------------------------------------ engine parity ----

def _engine_run(**opt_kw):
    from repro.core import Engine, EngineOptions, MLConstants
    from repro.data import make_image_dataset, make_online_ues
    from repro.models.classifier import classifier_accuracy
    from repro.network import NetworkConfig, make_network
    from repro.solver import ObjectiveWeights

    net = make_network(NetworkConfig(num_ue=4, num_bs=2, num_dc=2))
    (trx, tr_y), (tex, te_y) = make_image_dataset(2000, (10, 10, 1))
    p0 = init_classifier_params(jax.random.PRNGKey(0), CCFG)
    consts = MLConstants(L=5.0, theta_i=np.ones(6) * 2,
                         sigma_i=np.ones(6) * 3, zeta1=2.0, zeta2=1.0)
    eng = Engine(net, "fednova", consts=consts, ow=ObjectiveWeights(T=3),
                 opts=EngineOptions(rounds=3, seed=0, **opt_kw))
    ues = make_online_ues(trx, tr_y, num_ue=4, mean_arrivals=200,
                          std_arrivals=20)

    def eval_fn(p):
        return classifier_accuracy(p, jnp.asarray(tex[:300]),
                                   jnp.asarray(te_y[:300]))

    return eng.run(ues, init_params=p0, loss_fn=classifier_loss,
                   eval_fn=eval_fn)


def test_engine_sharded_matches_single_device_bitwise():
    """EngineOptions.mesh_shape end to end: accuracy, loss AND final
    params of the sharded engine equal the single-device run bitwise."""
    ref = _engine_run()
    for shape in [(4, 2), (2, 2)]:
        out = _engine_run(mesh_shape=shape)
        assert [r.acc for r in out.reports] == [r.acc for r in ref.reports]
        assert [r.loss for r in out.reports] == \
            [r.loss for r in ref.reports]
        for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                        jax.tree_util.tree_leaves(out.params)):
            assert bool(jnp.all(a == b)), f"params diverge on mesh {shape}"


def test_mesh_executor_sharded_plane_allclose():
    """MeshExecutor.mesh_shape device_puts the plane stack with a
    NamedSharding; GSPMD may re-partition reductions, so the contract is
    allclose (not bitwise)."""
    from repro.core import (Engine, EngineOptions, MeshExecutor,
                            MLConstants)
    from repro.data import make_image_dataset, make_online_ues
    from repro.network import NetworkConfig, make_network
    from repro.solver import ObjectiveWeights

    from repro.models.classifier import classifier_accuracy

    net = make_network(NetworkConfig(num_ue=4, num_bs=2, num_dc=2))
    (trx, tr_y), (tex, te_y) = make_image_dataset(1200, (8, 8, 1))
    ccfg = ClassifierConfig(input_shape=(8, 8, 1), hidden=(16,))
    p0 = init_classifier_params(jax.random.PRNGKey(0), ccfg)
    consts = MLConstants(L=5.0, theta_i=np.ones(6) * 2,
                         sigma_i=np.ones(6) * 3, zeta1=2.0, zeta2=1.0)

    def run(executor):
        eng = Engine(net, "fixed:0", consts=consts,
                     ow=ObjectiveWeights(T=2),
                     opts=EngineOptions(rounds=2, seed=0, solver_outer=2),
                     executor=executor)
        ues = make_online_ues(trx, tr_y, num_ue=4, mean_arrivals=120,
                              std_arrivals=12, seed=0)
        return eng.run(ues, init_params=p0, loss_fn=classifier_loss,
                       eval_fn=lambda p: classifier_accuracy(
                           p, jnp.asarray(tex[:100]),
                           jnp.asarray(te_y[:100])))

    ref = run(MeshExecutor())
    out = run(MeshExecutor(mesh_shape=(4, 2)))
    np.testing.assert_allclose(out.series("loss"), ref.series("loss"),
                               atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(out.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)
