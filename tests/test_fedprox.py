"""FedProx local-training math (paper eqs. 5-11)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import aggregation, fedprox
from repro.core.round_step import CEFLHyper, build_cefl_round_step, \
    make_dpu_meta
from repro.models.classifier import classifier_loss, init_classifier_params

CFG = ClassifierConfig(input_shape=(6, 6, 1), hidden=(16,))
KEY = jax.random.PRNGKey(0)


def _data(n=16, seed=1):
    k = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(k, (n, 6, 6, 1)),
            "y": jax.random.randint(k, (n,), 0, 10)}


def test_a_coefficients():
    a = fedprox.a_coefficients(4, eta=0.1, mu=0.5)
    r = 1 - 0.1 * 0.5
    np.testing.assert_allclose(a, [r ** 3, r ** 2, r, 1.0], rtol=1e-6)


def test_eq9_identity_mu0():
    """eq. (9): with mu=0, sum_l a_l grad F == (x^t - x^{t,gamma})/eta."""
    p0 = init_classifier_params(KEY, CFG)
    data = _data()
    res = fedprox.local_train(p0, classifier_loss, data, gamma=3,
                              m_frac=1.0, eta=0.05, mu=0.0, key=KEY)
    dev = fedprox.verify_accumulation_identity(p0, res, eta=0.05, mu=0.0)
    assert dev < 1e-4, dev


def test_prox_pulls_toward_anchor():
    """Large mu keeps the local model closer to the anchor."""
    p0 = init_classifier_params(KEY, CFG)
    data = _data()

    def dist(mu):
        res = fedprox.local_train(p0, classifier_loss, data, gamma=5,
                                  m_frac=1.0, eta=0.1, mu=mu, key=KEY)
        return sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
            jax.tree_util.tree_leaves(res.params),
            jax.tree_util.tree_leaves(p0)))

    assert dist(5.0) < dist(0.0)


def test_aggregate_eq11():
    p0 = init_classifier_params(KEY, CFG)
    d1 = jax.tree_util.tree_map(jnp.ones_like, p0)
    d2 = jax.tree_util.tree_map(lambda x: 2 * jnp.ones_like(x), p0)
    out = aggregation.aggregate(p0, [d1, d2], [100, 300], theta=2.0, eta=0.1)
    # weighted mean d = (100*1 + 300*2)/400 = 1.75; update = -0.2*1.75
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(p0)):
        np.testing.assert_allclose(a, b - 0.35, rtol=1e-5)


def test_bs_relay_sum_preserves_total():
    p0 = init_classifier_params(KEY, CFG)
    grads = [jax.tree_util.tree_map(lambda x: jnp.full_like(x, i + 1.0), p0)
             for i in range(4)]
    relayed = aggregation.bs_relay_sum(grads, [[0, 2], [1], [3]])
    tot = relayed[0]
    for r in relayed[1:]:
        tot = jax.tree_util.tree_map(jnp.add, tot, r)
    direct = grads[0]
    for g in grads[1:]:
        direct = jax.tree_util.tree_map(jnp.add, direct, g)
    for a, b in zip(jax.tree_util.tree_leaves(tot),
                    jax.tree_util.tree_leaves(direct)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_mesh_round_equals_simulation():
    """The jittable SPMD round (round_step) must equal local_train +
    aggregate exactly (full batch => deterministic)."""
    p0 = init_classifier_params(KEY, CFG)
    n_dpu, mb = 2, 8
    x = jax.random.normal(KEY, (n_dpu, 1, mb, 6, 6, 1))
    y = jax.random.randint(KEY, (n_dpu, 1, mb), 0, 10)

    def loss_fn(p, micro, mask):
        return classifier_loss(p, {"x": micro["x"], "y": micro["y"]},
                               mask), {}

    hyper = CEFLHyper(eta=0.05, mu=0.01, theta=1.0, gamma_max=3, n_micro=1)
    step = build_cefl_round_step(loss_fn, hyper)
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (n_dpu,) + l.shape), p0)
    meta = make_dpu_meta(n_dpu, gammas=[3, 2], m_fracs=[1.0, 1.0],
                         weights=[0.5, 0.5])
    new_params, _ = jax.jit(step)(stacked, {"x": x, "y": y}, meta)

    results = []
    for i, g in enumerate([3, 2]):
        r = fedprox.local_train(p0, classifier_loss,
                                {"x": x[i, 0], "y": y[i, 0]},
                                gamma=g, m_frac=1.0, eta=0.05, mu=0.01,
                                key=KEY)
        results.append(r)
    ref = aggregation.aggregate(p0, [r.d_i for r in results], [8, 8],
                                theta=1.0, eta=0.05)
    for k in ref:
        np.testing.assert_allclose(new_params[k][0], ref[k], atol=2e-6)


def test_fednova_vs_fedavg_one_step_equivalence():
    """With gamma=1 and equal weights, FedNova reduces to FedAvg on the
    same gradients."""
    p0 = init_classifier_params(KEY, CFG)
    data = [_data(seed=s) for s in range(3)]
    res = [fedprox.local_train(p0, classifier_loss, d, gamma=1, m_frac=1.0,
                               eta=0.1, mu=0.0, key=KEY) for d in data]
    w = [r.num_examples for r in res]
    nova = aggregation.fednova_aggregate(p0, [r.d_i for r in res], w,
                                         [1, 1, 1], eta=0.1)
    avg = aggregation.fedavg_aggregate([r.params for r in res], w)
    for a, b in zip(jax.tree_util.tree_leaves(nova),
                    jax.tree_util.tree_leaves(avg)):
        np.testing.assert_allclose(a, b, atol=1e-5)
