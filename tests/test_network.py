"""Network substrate: topology generation, data configuration conservation,
delay/energy model sanity (eqs. 12-40)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.network import (NetworkConfig, data_configuration, make_network,
                           network_costs, round_delay, round_energy)
from repro.solver.variables import init_w, project

NET = make_network(NetworkConfig(num_ue=6, num_bs=3, num_dc=2))
D_BAR = np.full(6, 1000.0)


def test_topology_invariants():
    N, B, S = NET.dims
    assert NET.R_nb.shape == (N, B) and (NET.R_nb > 0).all()
    A = NET.adjacency
    assert (A == A.T).all()
    # every UE reaches a BS; every BS reaches a DC; DCs interconnected
    assert A[:N, N:N + B].sum(axis=1).min() >= 1
    assert A[N:N + B, N + B:].sum(axis=1).min() >= 1
    assert (A[N + B:, N + B:].sum(axis=1) >= 1).all()


def test_intra_subnet_rates_higher():
    N, B, S = NET.dims
    intra, inter = [], []
    for n in range(N):
        for b in range(B):
            (intra if NET.subnet_of_ue[n] == NET.subnet_of_bs[b]
             else inter).append(NET.R_nb[n, b])
    assert np.mean(intra) > np.mean(inter)


@settings(max_examples=20, deadline=None)
@given(frac=st.floats(0.0, 0.9))
def test_data_conservation(frac):
    """Offloading moves points but never creates/destroys them (16)-(18)."""
    w = project(init_w(NET, D_BAR), NET)
    w = dict(w)
    w["rho_nb"] = jnp.full_like(w["rho_nb"], frac / 3)  # row sums = frac
    D_n, D_b, D_s = data_configuration(w, jnp.asarray(D_BAR))
    np.testing.assert_allclose(float(jnp.sum(D_n) + jnp.sum(D_s)),
                               D_BAR.sum(), rtol=1e-5)
    np.testing.assert_allclose(float(jnp.sum(D_b)), float(jnp.sum(D_s)),
                               rtol=1e-5)


def test_more_offloading_more_transfer_delay():
    w0 = project(init_w(NET, D_BAR), NET)
    w1 = dict(w0)
    w1["rho_nb"] = jnp.full_like(w0["rho_nb"], 0.3)
    w1 = project(w1, NET)
    c0 = network_costs(w0, NET, D_BAR)
    c1 = network_costs(w1, NET, D_BAR)
    assert float(jnp.sum(c1["d_nb_D"])) > float(jnp.sum(c0["d_nb_D"]))


def test_processing_energy_scales_with_frequency():
    w = project(init_w(NET, D_BAR), NET)
    lo = dict(w); lo["f_n"] = jnp.full_like(w["f_n"], 1e7)
    hi = dict(w); hi["f_n"] = jnp.full_like(w["f_n"], 1e9)
    c_lo = network_costs(lo, NET, D_BAR)
    c_hi = network_costs(hi, NET, D_BAR)
    assert float(jnp.sum(c_hi["E_n_P"])) > float(jnp.sum(c_lo["E_n_P"]))
    assert float(jnp.sum(c_hi["d_n_P"])) < float(jnp.sum(c_lo["d_n_P"]))


def test_aggregator_choice_changes_delay():
    w = project(init_w(NET, D_BAR), NET)
    delays = []
    for s in range(NET.cfg.num_dc):
        ws = dict(w)
        ws["I_s"] = jnp.zeros(NET.cfg.num_dc).at[s].set(1.0)
        c = network_costs(ws, NET, D_BAR)
        delays.append(float(c["delta_A_req"] + c["delta_R_req"]))
    assert max(delays) > min(delays)   # the floating point matters


def test_costs_nonnegative_and_finite():
    w = project(init_w(NET, D_BAR), NET)
    c = network_costs(w, NET, D_BAR)
    for k, v in c.items():
        arr = np.asarray(v)
        assert np.all(np.isfinite(arr)), k
        assert np.all(arr >= -1e-6), k
    assert round_delay(c) > 0
    assert round_energy(c) > 0


def test_resample_preserves_shapes():
    rng = np.random.RandomState(0)
    net2 = NET.resample_rates(rng, 0.2)
    assert net2.R_nb.shape == NET.R_nb.shape
    assert not np.allclose(net2.R_nb, NET.R_nb)
